"""Roofline table builder: reads the dry-run artifacts and emits the
per-(arch x shape x mesh) analysis (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / peak_FLOPs            (per chip)
    memory term     = HLO_bytes / HBM_bw                (per chip)
    collective term = collective_bytes / ICI link bw    (per chip)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute
ratio MODEL_FLOPS / (chips * HLO_FLOPs).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, base as cfgs

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def model_flops(arch: str, shape: str) -> float:
    """6 * N(active) * tokens for the workload shape (per step, global)."""
    if arch == "pgf_tpch":
        from repro.configs import pgf_tpch
        qc = pgf_tpch.CONFIG
        # analytic: ~46 flop-equivalents per (tuple, frequency) pair for
        # the log-CF path (phase modmult, cos/sin, |z|^2, log, atan2),
        # global over the step
        return 46.0 * qc.n_tuples * qc.num_freq
    cfg = cfgs.get_config(arch)
    n_active = cfg.active_param_count()
    spec = SHAPES[shape]
    if spec["kind"] == "train":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 6.0 * n_active * tokens
    if spec["kind"] == "prefill":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * spec["global_batch"]


def load_rows(artifact_dir: str = ARTIFACT_DIR):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        with open(path) as f:
            res = json.load(f)
        if "roofline" not in res:
            rows.append(dict(cell=res.get("cell", path), error=True))
            continue
        r = res["roofline"]
        arch, shape = res["cell"].split("/")
        mf = model_flops(arch, shape)
        chips = r["chips"]
        useful = mf / max(chips * r["hlo_flops"], 1e-9)
        terms = {"compute": r["t_compute"], "memory": r["t_memory"],
                 "collective": r["t_collective"]}
        dom = r["dominant"]
        bound = max(terms.values())
        # roofline fraction: ideal-time(compute term if it were the only
        # one) over the bounding term — how close the cell is to its roof
        frac = r["t_compute"] / max(bound, 1e-12)
        rows.append(dict(
            cell=res["cell"], mesh=res["mesh"], chips=chips,
            t_compute=r["t_compute"], t_memory=r["t_memory"],
            t_collective=r["t_collective"], dominant=dom,
            model_flops=mf, hlo_flops=r["hlo_flops"],
            useful_ratio=useful, roofline_fraction=frac,
            mem_gb=_mem_gb(res)))
    return rows


def _mem_gb(res) -> float:
    ma = res.get("memory_analysis") or {}
    tot = sum(ma.get(k, 0) for k in ("argument_size_in_bytes",
                                     "output_size_in_bytes",
                                     "temp_size_in_bytes")
              if isinstance(ma.get(k), int))
    # aliased outputs (donated) are double-counted by arg+out; subtract
    tot -= ma.get("alias_size_in_bytes", 0) or 0
    return tot / 1e9


def bench():
    rows = load_rows()
    out = []
    for r in rows:
        if r.get("error"):
            out.append((f"roofline/{r['cell']}", float("nan"), "ERROR"))
            continue
        out.append((
            f"roofline/{r['cell']}@{r['mesh']}",
            r["t_compute"] * 1e6,
            f"t_m={r['t_memory']:.3e};t_x={r['t_collective']:.3e};"
            f"dom={r['dominant']};useful={r['useful_ratio']:.3f};"
            f"mem={r['mem_gb']:.1f}GB"))
    return out


def markdown_table(rows) -> str:
    hdr = ("| cell | mesh | t_compute | t_memory | t_collective | dominant "
           "| useful MODEL/HLO | mem GB/dev |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("error"):
            lines.append(f"| {r['cell']} | — | ERROR | | | | | |")
            continue
        lines.append(
            f"| {r['cell']} | {r['mesh']} | {r['t_compute']:.3e} "
            f"| {r['t_memory']:.3e} | {r['t_collective']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.3f} "
            f"| {r['mem_gb']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = load_rows()
    print(markdown_table(rows))
