"""Roofline table builder: reads the dry-run artifacts and emits the
per-(arch x shape x mesh) analysis (EXPERIMENTS.md §Roofline), plus the
grouped-CF kernel tile sweep.

    compute term    = HLO_FLOPs / peak_FLOPs            (per chip)
    memory term     = HLO_bytes / HBM_bw                (per chip)
    collective term = collective_bytes / ICI link bw    (per chip)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute
ratio MODEL_FLOPS / (chips * HLO_FLOPs).

The sweep (``--sweep-group-cf``, also part of ``bench()``) times the
(G, F)-tiled grouped log-CF kernel (`repro.kernels.group_cf`) across
(gb, fb, tb) block shapes so tile choices are measured, not guessed —
on CPU the kernel runs in interpret mode at reduced problem sizes (the
numbers rank tilings; absolute throughput only means something on TPU).
"""
from __future__ import annotations

import glob
import json
import os
import sys
import time

from repro.configs import SHAPES, base as cfgs

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")

#: (gb, fb, tb) grouped-CF tilings worth comparing: the default, wider and
#: narrower frequency tiles (lane multiples), deeper tuple streaming, and a
#: taller group tile (two f32 sublane quanta).
GROUP_CF_TILES = ((8, 256, 512), (8, 128, 512), (8, 512, 512),
                  (8, 256, 1024), (16, 256, 512))


def group_cf_flops(n: int, num_freq: int, gb: int) -> float:
    """Analytic flop count of one grouped log-CF accumulation: ~46
    flop-equivalents per (tuple, frequency) pair for the phase tile
    (modmult, cos/sin, |z|^2, log, atan2) plus the 2*gb-wide mask-matmul
    scatter each tuple block pays for the one group block it intersects
    (inputs are sorted by group, so non-intersecting blocks are skipped)."""
    return (46.0 + 2.0 * gb) * n * num_freq


def sweep_group_cf(n: int | None = None, num_groups: int = 64,
                   num_freq: int | None = None, tiles=GROUP_CF_TILES,
                   repeat: int = 3):
    """Time the grouped-CF kernel per (gb, fb, tb) tiling; returns rows."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import group_cf

    on_cpu = jax.default_backend() == "cpu"
    if n is None:
        n = 4096 if on_cpu else 1 << 18
    if num_freq is None:
        # Keep F >= the widest fb in `tiles` even at the reduced CPU size:
        # a frequency grid smaller than a tile's fb would time that tiling
        # with pure padding lanes and mis-rank it.
        num_freq = 512 if on_cpu else 2048
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32)
    v = jnp.asarray(rng.integers(0, 50, n), jnp.int32)
    g = jnp.asarray(rng.integers(0, num_groups, n), jnp.int32)

    rows = []
    for gb, fb, tb in tiles:
        flops = group_cf_flops(n, num_freq, gb)
        def run(gb=gb, fb=fb, tb=tb):
            return jax.block_until_ready(group_cf.group_logcf(
                p, v, g, num_groups=num_groups, num_freq=num_freq,
                gb=gb, fb=fb, tb=tb))
        run()                                        # compile + warm
        best = float("inf")
        for _ in range(repeat):
            t0 = time.perf_counter()
            run()
            best = min(best, time.perf_counter() - t0)
        rows.append((
            f"roofline/group_cf/gb{gb}xfb{fb}xtb{tb}", best * 1e6,
            f"n={n};G={num_groups};F={num_freq};"
            f"{flops / best / 1e9:.2f}GFLOP/s"
            + (";interpret" if on_cpu else "")))
    return rows


def model_flops(arch: str, shape: str) -> float:
    """6 * N(active) * tokens for the workload shape (per step, global)."""
    if arch == "pgf_tpch":
        from repro.configs import pgf_tpch
        qc = pgf_tpch.CONFIG
        # analytic: ~46 flop-equivalents per (tuple, frequency) pair for
        # the log-CF path (phase modmult, cos/sin, |z|^2, log, atan2),
        # global over the step
        return 46.0 * qc.n_tuples * qc.num_freq
    cfg = cfgs.get_config(arch)
    n_active = cfg.active_param_count()
    spec = SHAPES[shape]
    if spec["kind"] == "train":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 6.0 * n_active * tokens
    if spec["kind"] == "prefill":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * spec["global_batch"]


def load_rows(artifact_dir: str = ARTIFACT_DIR):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        with open(path) as f:
            res = json.load(f)
        if "roofline" not in res:
            rows.append(dict(cell=res.get("cell", path), error=True))
            continue
        r = res["roofline"]
        arch, shape = res["cell"].split("/")
        mf = model_flops(arch, shape)
        chips = r["chips"]
        useful = mf / max(chips * r["hlo_flops"], 1e-9)
        terms = {"compute": r["t_compute"], "memory": r["t_memory"],
                 "collective": r["t_collective"]}
        dom = r["dominant"]
        bound = max(terms.values())
        # roofline fraction: ideal-time(compute term if it were the only
        # one) over the bounding term — how close the cell is to its roof
        frac = r["t_compute"] / max(bound, 1e-12)
        rows.append(dict(
            cell=res["cell"], mesh=res["mesh"], chips=chips,
            t_compute=r["t_compute"], t_memory=r["t_memory"],
            t_collective=r["t_collective"], dominant=dom,
            model_flops=mf, hlo_flops=r["hlo_flops"],
            useful_ratio=useful, roofline_fraction=frac,
            mem_gb=_mem_gb(res)))
    return rows


def _mem_gb(res) -> float:
    ma = res.get("memory_analysis") or {}
    tot = sum(ma.get(k, 0) for k in ("argument_size_in_bytes",
                                     "output_size_in_bytes",
                                     "temp_size_in_bytes")
              if isinstance(ma.get(k), int))
    # aliased outputs (donated) are double-counted by arg+out; subtract
    tot -= ma.get("alias_size_in_bytes", 0) or 0
    return tot / 1e9


def bench():
    rows = load_rows()
    out = sweep_group_cf()
    for r in rows:
        if r.get("error"):
            out.append((f"roofline/{r['cell']}", float("nan"), "ERROR"))
            continue
        out.append((
            f"roofline/{r['cell']}@{r['mesh']}",
            r["t_compute"] * 1e6,
            f"t_m={r['t_memory']:.3e};t_x={r['t_collective']:.3e};"
            f"dom={r['dominant']};useful={r['useful_ratio']:.3f};"
            f"mem={r['mem_gb']:.1f}GB"))
    return out


def markdown_table(rows) -> str:
    hdr = ("| cell | mesh | t_compute | t_memory | t_collective | dominant "
           "| useful MODEL/HLO | mem GB/dev |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("error"):
            lines.append(f"| {r['cell']} | — | ERROR | | | | | |")
            continue
        lines.append(
            f"| {r['cell']} | {r['mesh']} | {r['t_compute']:.3e} "
            f"| {r['t_memory']:.3e} | {r['t_collective']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.3f} "
            f"| {r['mem_gb']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    if "--sweep-group-cf" in sys.argv:
        for name, us, extra in sweep_group_cf():
            print(f"{name},{us:.1f},{extra}")
    else:
        rows = load_rows()
        print(markdown_table(rows))
