"""Per-PR perf smoke: one tiny planner-compiled TPC-H query per UDA method,
gated against a checked-in baseline.

Runs Q3-shaped GroupAgg plans through ``compile_plan`` (the unified
segment-UDA path) for every aggregation method — normal, cumulants, exact
(grouped log-CF), min/max — plus the ReweightGreater plan shape, and prints
wall times, so refactors of the UDA subsystem show perf regressions per-PR.
It also measures the grouped-exact planner path against a per-group scalar
``logcf`` loop (the pre-kernel execution strategy) at G >= 64, the
sharded relational frontend (the full shard_map pipeline on a 1-device
('data',) mesh) so the distributed scan/join/group-id path is gated too,
the gather- vs shuffle-lowered FK join (a per-join gather_budget forces
the hash-exchange strategies), and the fused CoPartitionedJoin +
PartitionedAgg pipeline vs shuffle + gather-home on the Q3-shaped
workload (with the shuffle_back round-trips saved, gated structurally).
The baseline JSON additionally records the static replicated-vs-sharded
peak rows/device accounting of the frontend AND the gather-vs-shuffle
build-side rows/device of a join whose build side exceeds the gather
budget (the ShuffleJoin memory contract).  The out-of-core streamed path
is gated three ways: the double-buffered vs synchronous wave-transfer
wall times (the overlap win, floored on multi-core hosts), the static
streamed-vs-resident peak rows/device at 1x and 8x data — the streamed
peak must stay FLAT as the table grows 8x past the device row budget —
and the column-pruned slab bytes of the streamed Q6 pass, which must
stay strictly below the unpruned bytes (Q6 reads 3 of lineitem's 10
columns) alongside a per-wave host-slice time row.
The self-healing happy path is gated too: the with-ExecutionReport run
of the Q1-shaped plan must stay within ``TOLERANCE`` of the plain run
and ``run_plan`` must resolve it in one attempt (diagnostics are free
when nothing is wrong).  The query-serving layer is gated three ways:
the cached-submit latency row (baseline), the plan-cache hit-vs-cold
ratio (floored at ``MIN_CACHE_HIT_SPEEDUP`` — a 'hit' that re-traces
collapses it), and the 64-point parameterized Q6 sweep vs 64 sequential
per-point compiles (floored at ``MIN_BATCH_SPEEDUP`` — amortising the
compile is the feature).

    PYTHONPATH=src python benchmarks/smoke.py [--mesh] [--check] [--update]

--check  compares against benchmarks/BENCH_smoke_baseline.json and exits
         nonzero on a > ``TOLERANCE``x per-method regression (or on a
         grouped-exact speedup below ``MIN_EXACT_SPEEDUP``x).
--update rewrites the baseline from this run.
--mesh   additionally compiles the same plans against a host-device mesh and
         reports the distributed timings (requires >1 device or XLA_FLAGS
         host device count).

Timings are best-of-``repeat`` (not mean): the gate needs the low-noise
floor of each method, not its scheduler-jitter average.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.db import tpch
from repro.db.plans import (GroupAgg, Map, ReweightGreater, Scan, Select,
                            compile_plan, shard_capacity)

BASELINE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_smoke_baseline.json")
TOLERANCE = 1.3             # per-method regression gate (cur <= tol * base)
STREAM_TOLERANCE = 2.0      # streamed host-loop rows: the eager wave loop
                            # (host slicing + per-wave dispatch) has far
                            # higher run-to-run variance than the pure
                            # device rows, especially on 1-core hosts
MIN_EXACT_SPEEDUP = 5.0     # grouped exact vs per-group scalar loop floor
MIN_STREAM_OVERLAP = 1.2    # sync / double-buffered streamed-pass floor
MIN_CACHE_HIT_SPEEDUP = 50.0  # plan-cache hit vs cold compile floor
MIN_BATCH_SPEEDUP = 10.0    # batched-64 sweep vs 64 sequential compiles


def _stream_overlap_floor() -> float:
    """The overlap gate needs a second core: host slab assembly and the
    XLA compute pool can only run concurrently on multi-core hosts.  On a
    single core the double-buffered pipeline cannot beat the serialised
    loop — and its wall time swings with allocator state — so the gate
    degrades to a catastrophe check (>= 0.3x, 'double buffering is not
    pathologically broken') while the overlap_win row is still recorded
    for machines where the win is physical.  The double_buffer timing row
    is gated ONLY through this ratio (relative to the same-run sync row),
    never against the baseline."""
    return MIN_STREAM_OVERLAP if (os.cpu_count() or 1) > 1 else 0.3


def _plans(max_groups: int = 256):
    li = Select(Scan("lineitem"), lambda t: t["l_shipdate"] > tpch.DAY0_1995)
    keys = ("l_orderkey",)
    return {
        "normal": GroupAgg(li, keys, "l_quantity", "SUM", max_groups,
                           "normal"),
        "cumulants": GroupAgg(li, keys, "l_quantity", "SUM", max_groups,
                              "cumulants"),
        # exact grouped SUM + COUNT distributions sharing one pass; per-order
        # quantity sums fit the 256-frequency grid of the synthetic data.
        "exact": GroupAgg(li, keys, "l_quantity", "SUM", max_groups,
                          "exact", num_freq=256,
                          extra=(("count", "", "COUNT", "exact"),)),
        "min": GroupAgg(li, keys, "l_quantity", "MIN", max_groups, kappa=32),
        "max": GroupAgg(li, keys, "l_quantity", "MAX", max_groups, kappa=32),
        "reweight": ReweightGreater(li, keys, "l_quantity", "", max_groups,
                                    threshold=60.0),
    }


def _time(fn, args, repeat):
    out = fn(*args)                                  # compile + warm
    jax.block_until_ready(jax.tree.leaves(out))
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree.leaves(out))
        best = min(best, time.perf_counter() - t0)
    return best


def bench(n_orders: int = 1000, repeat: int = 5, mesh=None):
    db = tpch.generate(n_orders=n_orders, seed=0)
    tables = db.tables()
    rows = []
    for method, plan in _plans().items():
        fn = jax.jit(compile_plan(plan, mesh))
        dt = _time(fn, (tables,), repeat)
        tag = "mesh" if mesh is not None else "1dev"
        rows.append((f"smoke/{method}/{tag}", dt * 1e6,
                     f"n_orders={n_orders}"))
    return rows


def bench_exact_speedup(G: int = 64, tuples_per_group: int = 64,
                        num_freq: int = 256, repeat: int = 3):
    """Grouped-exact planner path vs the per-group scalar logcf loop it
    replaces: G separate single-group CF accumulations over the full
    (masked) tuple column, i.e. the only way to run grouped exact before
    the (G, F)-tiled path existed."""
    from repro.core import uda
    from repro.db.table import Table

    rng = np.random.default_rng(0)
    n = G * tuples_per_group
    gids = jnp.asarray(rng.integers(0, G, n), jnp.int32)
    probs = jnp.asarray(rng.uniform(0.0, 1.0, n), jnp.float32)
    vals = jnp.asarray(rng.integers(1, 4, n), jnp.int32)
    t = Table.from_columns({"g": gids, "v": vals}, prob=probs)
    plan = GroupAgg(Scan("t"), ("g",), "v", "SUM", G, "exact",
                    num_freq=num_freq)
    grouped = jax.jit(compile_plan(plan))
    t_grouped = _time(grouped, ({"t": t},), repeat)

    @jax.jit
    def loop(p, v):
        rows = []
        for g in range(G):
            pg = jnp.where(gids == g, p, 0.0)
            st = uda.accumulate({"cf": uda.SumCF(num_freq)}, pg, v, None,
                                max_groups=1)["cf"]
            rows.append(uda.SumCF(num_freq).finalize(st)[0])
        return jnp.stack(rows)
    t_loop = _time(loop, (probs, vals), repeat)
    return [(f"smoke/exact_speedup/G{G}", t_loop / max(t_grouped, 1e-12),
             f"grouped={t_grouped * 1e6:.1f}us,loop={t_loop * 1e6:.1f}us")]


def bench_sharded_frontend(n_orders: int = 1000, repeat: int = 5):
    """The full sharded frontend (scan/select/join/group-ids inside one
    shard_map) on a 1-device ('data',) mesh: same Q3-shaped plan as
    smoke/normal plus an FKJoin, timed against the baseline so shard_map
    pipeline overhead regressions are caught per-PR even though the
    parent process only sees one device."""
    from repro.compat import make_mesh
    from repro.db.plans import FKJoin

    db = tpch.generate(n_orders=n_orders, seed=0)
    mesh = make_mesh((1,), ("data",))
    li = Select(Scan("lineitem"), lambda t: t["l_shipdate"] > tpch.DAY0_1995)
    j = FKJoin(li, Scan("orders"), "l_orderkey", "o_orderkey",
               ("o_totalprice",))
    plan = GroupAgg(j, ("l_orderkey",), "l_quantity", "SUM", 256, "normal")
    fn = jax.jit(compile_plan(plan, mesh))
    dt = _time(fn, (db.tables(),), repeat)
    return [("smoke/sharded_frontend/mesh1", dt * 1e6,
             f"n_orders={n_orders}")]


def frontend_layout(n_orders: int = 1000, shards: int = 8,
                    chunks: int = 8) -> dict:
    """Static peak rows/device of the biggest relation (lineitem): the
    replicated frontend keeps every (chunk-padded) row on every device;
    the sharded frontend keeps the contiguous 1/shards block.  Uses the
    same ``plans.shard_capacity`` padding formula as ``compile_plan``, and
    is gated against the checked-in baseline by ``--check`` so a layout
    regression (e.g. the frontend quietly re-replicating scans, or chunk
    padding blowing up) fails the smoke gate."""
    db = tpch.generate(n_orders=n_orders, seed=0)
    npad = shard_capacity(db.lineitem.capacity, chunks, shards)
    return {"replicated": npad, "sharded": npad // shards, "shards": shards}


def shuffle_layout(n_orders: int = 1000, shards: int = 8,
                   chunks: int = 8, slack: float = 4.0) -> dict:
    """Static peak BUILD-side rows/device of an FK join whose build side
    (orders) exceeds the gather budget: the gather strategy replicates the
    whole build table on every device; the shuffle strategy keeps the hash
    bucket plus the static exchange buffers.  Computed from the lowered
    physical plan (the same ``physical.lower_plan`` the compiler runs), so
    the O(build/shards) memory contract of the ShuffleJoin is gated by
    ``--check`` against the baseline."""
    from repro.db import physical as phys
    from repro.db.plans import FKJoin

    db = tpch.generate(n_orders=n_orders, seed=0)
    caps = {k: shard_capacity(t.capacity, chunks, shards)
            for k, t in db.tables().items()}
    join = FKJoin(Select(Scan("lineitem"),
                         lambda t: t["l_shipdate"] > tpch.DAY0_1995),
                  Scan("orders"), "l_orderkey", "o_orderkey",
                  ("o_totalprice",))
    lowered = phys.lower_plan(join, caps, n_shards=shards, sharded=True,
                              join_gather_budget=caps["orders"] - 1,
                              shuffle_slack=slack)
    assert isinstance(lowered, phys.ShuffleJoin), phys.explain(lowered)
    # gather: the whole build table lands on every device; shuffle: the
    # received hash bucket (n_shards send buckets of build_bucket rows)
    # plus the probe request/response buffers.
    return {"gather_build_rows": caps["orders"],
            "shuffle_build_rows": shards * lowered.build_bucket,
            "shuffle_probe_rows": shards * lowered.probe_bucket,
            "shards": shards}


def bench_shuffle_join(n_orders: int = 1000, repeat: int = 5):
    """Gather- vs shuffle-lowered FK join wall time on the 1-device
    ('data',) mesh: the same Q3-shaped join as smoke/sharded_frontend,
    compiled once per strategy (a tiny per-join gather_budget forces the
    shuffle lowering), so the shuffle path's exchange overhead is gated
    per-PR alongside its memory accounting."""
    from repro.compat import make_mesh
    from repro.db.plans import FKJoin

    db = tpch.generate(n_orders=n_orders, seed=0)
    mesh = make_mesh((1,), ("data",))
    li = Select(Scan("lineitem"), lambda t: t["l_shipdate"] > tpch.DAY0_1995)
    rows = []
    for tag, budget in (("gather", None), ("shuffle", 1)):
        j = FKJoin(li, Scan("orders"), "l_orderkey", "o_orderkey",
                   ("o_totalprice",), gather_budget=budget)
        plan = GroupAgg(j, ("l_orderkey",), "l_quantity", "SUM", 256,
                        "normal")
        # copartition=False pins the ShuffleJoin + shuffle-home strategy
        # (the GROUP BY keys on the join key, so the cost model would
        # otherwise fuse it — bench_copartitioned_agg measures that).
        fn = jax.jit(compile_plan(plan, mesh, copartition=False))
        dt = _time(fn, (db.tables(),), repeat)
        rows.append((f"smoke/shuffle_join/{tag}/mesh1", dt * 1e6,
                     f"n_orders={n_orders}"))
    return rows


def bench_copartitioned_agg(n_orders: int = 1000, repeat: int = 5):
    """The fused shuffle -> aggregate pipeline vs shuffle + gather-home on
    the Q3-shaped workload (GROUP BY on the FK-join key, build side over
    the gather budget): same logical plan, compiled once with the fused
    CoPartitionedJoin + PartitionedAgg lowering and once with
    ``copartition=False`` (ShuffleJoin + shuffle_back + PartialAgg).
    Alongside the wall times, counts the shuffle_back round-trips each
    strategy traces — the fused pipeline must save at least one, and
    ``--check`` gates both the saving and fused-beats-shuffle."""
    from repro.compat import make_mesh
    from repro.db import distributed as dist
    from repro.db.plans import FKJoin

    db = tpch.generate(n_orders=n_orders, seed=0)
    mesh = make_mesh((1,), ("data",))
    li = Select(Scan("lineitem"), lambda t: t["l_shipdate"] > tpch.DAY0_1995)
    j = FKJoin(li, Scan("orders"), "l_orderkey", "o_orderkey",
               ("o_totalprice",), gather_budget=1)
    plan = GroupAgg(j, ("l_orderkey",), "l_quantity", "SUM", 256, "normal")
    rows, back = [], {}
    for tag, copart in (("fused", True), ("shuffle_home", False)):
        fn = jax.jit(compile_plan(plan, mesh, copartition=copart))
        dist.reset_collective_counts()
        dt = _time(fn, (db.tables(),), repeat)   # warm call traces once
        back[tag] = dist.COLLECTIVE_COUNTS.get("shuffle_back", 0)
        rows.append((f"smoke/copartitioned_agg/{tag}/mesh1", dt * 1e6,
                     f"n_orders={n_orders}"))
    rows.append(("smoke/copartitioned_agg/roundtrips_saved",
                 back["shuffle_home"] - back["fused"],
                 f"shuffle_back {back['shuffle_home']}->{back['fused']}"))
    return rows


def bench_streamed(n_orders: int = 8000, repeat: int = 5):
    """Out-of-core streamed aggregation: the Q1-shaped pass over a host
    lineitem 16x the per-device row budget, double-buffered vs synchronous
    transfer.  ``compile_plan`` is called ONCE per variant and the
    compiled fn reused (the streamed path is an eager host wave loop
    whose per-wave jit cache lives in the compile closure), and the
    canonical chunk grid is scaled with the table (csz ~= 500 rows) so the
    wave size tracks the budget, not the table.  Alongside the wall
    times, reports the sync/double-buffer ratio — the overlap win the
    transfer pipeline exists for — which ``--check`` gates against
    ``MIN_STREAM_OVERLAP``."""
    from repro.db.table import HostTable

    db = tpch.generate(n_orders=n_orders, seed=0)
    n_li = db.lineitem.capacity                       # n_orders * 4 rows
    chunks = max(8, n_li // 500)
    budget = 2000                                     # waves of 2k rows
    li = Select(Scan("lineitem"), lambda t: t["l_shipdate"] > tpch.DAY0_1995)
    plan = GroupAgg(li, ("l_returnflag", "l_linestatus"), "l_quantity",
                    "SUM", 8, "normal")
    tables = dict(db.tables())
    tables["lineitem"] = HostTable.from_table(db.lineitem)
    rows, times = [], {}
    for tag, db_buf in (("double_buffer", True), ("sync", False)):
        fn = compile_plan(plan, None, device_row_budget=budget,
                          canonical_chunks=chunks, stream_double_buffer=db_buf)
        times[tag] = _time(fn, (tables,), repeat)
        rows.append((f"smoke/streamed/{tag}/1dev", times[tag] * 1e6,
                     f"n_li={n_li},budget={budget}"))
    rows.append(("smoke/streamed/overlap_win",
                 times["sync"] / max(times["double_buffer"], 1e-12),
                 f"sync={times['sync'] * 1e6:.1f}us,"
                 f"db={times['double_buffer'] * 1e6:.1f}us"))
    return rows


def bench_stream_pruning(n_orders: int = 2000, repeat: int = 3):
    """Column pruning on the streamed Q6 pass, measured in slab bytes:
    the Q6 predicate + value expression read 3 of lineitem's 10 columns,
    so the pruned wave slabs must ship strictly fewer host->device bytes
    than the unpruned slabs over the same table — ``--check`` gates the
    strict inequality, and both byte counters are baseline-gated (they
    are static properties of the lowering, so any growth is a pruning
    regression).  Also records the per-wave host-slice time of the
    pruned pass (the zero-alloc ping-pong slab assembly path), averaged
    over ``repeat`` full passes to damp scheduler jitter."""
    from repro.db import plans as P
    from repro.db.table import HostTable

    db = tpch.generate(n_orders=n_orders, seed=0)
    n_li = db.lineitem.capacity
    chunks = max(8, n_li // 500)
    budget = 2000
    plan = tpch.q6_plan()
    tables = dict(db.tables())
    tables["lineitem"] = HostTable.from_table(db.lineitem)
    rows, stats = [], {}
    for tag, prune in (("pruned", True), ("unpruned", False)):
        fn = compile_plan(plan, None, device_row_budget=budget,
                          canonical_chunks=chunks,
                          stream_prune_columns=prune)
        out = fn(tables)                              # warm per-wave jits
        jax.block_until_ready(jax.tree.leaves(out))
        P.reset_stream_stats()
        for _ in range(repeat):
            out = fn(tables)
            jax.block_until_ready(jax.tree.leaves(out))
        s = P.stream_stats()
        stats[tag] = s
        rows.append((f"smoke/streamed/slab_bytes/{tag}",
                     s["slab_bytes"] / repeat,
                     f"waves={s['waves'] // repeat},n_li={n_li}"))
    s = stats["pruned"]
    rows.append(("smoke/streamed/slice_us_per_wave",
                 s["slice_s"] / max(s["waves"], 1) * 1e6,
                 f"waves={s['waves'] // repeat},repeat={repeat}"))
    return rows


def bench_retry_overhead(n_orders: int = 1000, repeat: int = 5):
    """The happy path of the self-healing controller must be (nearly)
    free: the Q1-shaped resident plan jitted once plain and once with
    ``with_report=True`` (the ExecutionReport threaded through the run),
    reported as the with-report / plain wall-time ratio.  ``--check``
    gates the ratio at ``TOLERANCE`` — diagnostics may not tax clean
    runs — and the bench asserts ``run_plan`` resolves the clean plan in
    ONE attempt (zero retries burned when nothing is wrong)."""
    from repro.db.plans import RetryPolicy, run_plan

    db = tpch.generate(n_orders=n_orders, seed=0)
    tables = db.tables()
    li = Select(Scan("lineitem"), lambda t: t["l_shipdate"] > tpch.DAY0_1995)
    plan = GroupAgg(li, ("l_returnflag", "l_linestatus"), "l_quantity",
                    "SUM", 8, "normal")
    t_base = _time(jax.jit(compile_plan(plan)), (tables,), repeat)
    t_rep = _time(jax.jit(compile_plan(plan, with_report=True)),
                  (tables,), repeat)
    _, rep = run_plan(plan, tables, policy=RetryPolicy(max_attempts=2))
    assert rep.waves["attempts"] == 1, rep.describe()
    assert rep.issues() == {}, rep.describe()
    return [("smoke/retry_overhead", t_rep / max(t_base, 1e-12),
             f"base={t_base * 1e6:.1f}us,report={t_rep * 1e6:.1f}us")]


def bench_serving(n_orders: int = 1000, repeat: int = 5):
    """The query-serving layer's reason to exist, measured: round 0
    submits every TPC-H serving plan cold (full trace + compile), later
    rounds resubmit FRESH plan objects — the structural plan cache must
    serve them from the same executables.  Gated two ways: the cached
    submit latency is baseline-gated like any timing row, and the
    cold/hit ratio is floored at ``MIN_CACHE_HIT_SPEEDUP`` (if a cache
    'hit' ever re-traces, the ratio collapses to ~1 and the gate
    fires)."""
    from repro.db.serving import QueryService

    db = tpch.generate(n_orders=n_orders, seed=0)
    svc = QueryService(db.tables(), capacity=16)
    plans = tpch.serving_plans()
    t0 = time.perf_counter()
    for name, plan in plans.items():
        out, info = svc.submit(plan)
        jax.block_until_ready(jax.tree.leaves(out))
        assert not info["hit"], name
    t_cold = (time.perf_counter() - t0) / len(plans)
    best = float("inf")
    for _ in range(repeat):
        fresh = tpch.serving_plans()        # new objects: hits must be
        t0 = time.perf_counter()            # structural, not identity
        for name, plan in fresh.items():
            out, info = svc.submit(plan)
            jax.block_until_ready(jax.tree.leaves(out))
            assert info["hit"], name
        best = min(best, (time.perf_counter() - t0) / len(fresh))
    return [("smoke/serving/hit/1dev", best * 1e6,
             f"qps={1.0 / best:.0f},n_orders={n_orders}"),
            ("smoke/serving/cache_hit_speedup", t_cold / best,
             f"cold={t_cold * 1e6:.0f}us,hit={best * 1e6:.0f}us")]


def bench_batched_sweep(n_orders: int = 200, n_points: int = 64):
    """A 64-point Q6 what-if sweep, both ways: 64 per-point plans with
    baked constants (64 traces + 64 compiles — what the engine did
    before parameter lifting) vs ONE compiled q6_family executable
    running all 64 points as one batched device program.  ``--check``
    floors the ratio at ``MIN_BATCH_SPEEDUP``; wall times include each
    arm's compiles because amortising the compile IS the feature."""
    from repro.db.serving import QueryService

    db = tpch.generate(n_orders=n_orders, seed=0)
    tables = db.tables()
    lims = [float(i + 1) for i in range(n_points)]

    def baked(lim):
        sel = Select(Scan("lineitem"),
                     lambda t: (t["l_shipdate"] >= tpch.DAY0_1995 - 400)
                     & (t["l_shipdate"] < tpch.DAY0_1995)
                     & (t["l_discount"] >= 5.0) & (t["l_discount"] <= 7.0)
                     & (t["l_quantity"] < lim))
        val = Map(sel, "q6_value",
                  lambda t: t["l_quantity"] * t["l_discount"])
        return GroupAgg(val, (), "q6_value", "SUM", 1, "normal",
                        extra=(("cumulants", "q6_value", "SUM",
                                "cumulants"),))

    t0 = time.perf_counter()
    for lim in lims:
        out = jax.jit(compile_plan(baked(lim)))(tables)
        jax.block_until_ready(jax.tree.leaves(out))
    t_seq = time.perf_counter() - t0
    jax.clear_caches()      # drop the 64 accreted executables (the
    #                         failure mode the serving layer bounds)
    svc = QueryService(tables, capacity=4)
    batch = dict(disc_lo=jnp.full((n_points,), 5.0),
                 disc_hi=jnp.full((n_points,), 7.0),
                 qty_lim=jnp.asarray(lims))
    t0 = time.perf_counter()
    out, info = svc.sweep(tpch.q6_family(), batch)
    jax.block_until_ready(jax.tree.leaves(out))
    t_batch = time.perf_counter() - t0
    return [(f"smoke/serving/batched{n_points}_speedup", t_seq / t_batch,
             f"seq={t_seq:.2f}s,batched={t_batch:.2f}s,"
             f"launches={info['launches']}")]


def streamed_layout(n_orders: int = 1000, budget: int = 2000,
                    csz: int = 500) -> dict:
    """Static peak rows/device of the streamed scan at 1x and 8x data:
    the resident compile keeps the whole padded table on the device; the
    streamed compile keeps two double-buffered wave slabs sized by the
    budget.  The canonical chunk grid scales with the table (fixed
    ~``csz``-row chunks) so the wave slab — and the streamed peak — is
    FLAT under 8x table growth, while the resident footprint grows 8x.
    Computed from the lowered physical plan's modeled cost and gated
    structurally and against the baseline by ``--check``."""
    from repro.db import physical as phys

    peaks = {}
    for scale in (1, 8):
        n_li = n_orders * 4 * scale
        chunks = max(8, n_li // csz)
        cap = shard_capacity(n_li, chunks, 1)
        plan = GroupAgg(Select(Scan("lineitem"),
                               lambda t: t["l_shipdate"] > tpch.DAY0_1995),
                        ("l_returnflag", "l_linestatus"), "l_quantity",
                        "SUM", 8, "normal")
        lowered = phys.lower_plan(plan, {"lineitem": cap}, n_shards=1,
                                  sharded=False, canonical_chunks=chunks,
                                  device_row_budget=budget)
        sc = lowered.child.child.child
        assert isinstance(sc, phys.StreamedScan), phys.explain(lowered)
        peaks[scale] = {"resident_rows": cap,
                        "streamed_peak_rows": int(sc.cost.peak_rows)}
    return {"x1": peaks[1], "x8": peaks[8], "budget": budget}


def _check(rows) -> int:
    if not os.path.exists(BASELINE_PATH):
        print(f"FAIL: no baseline at {BASELINE_PATH}; run --update first")
        return 1
    with open(BASELINE_PATH) as f:
        base_all = json.load(f)
    base = base_all["rows"]
    failures = 0
    missing = set(base) - {name for name, _, _ in rows}
    for name in sorted(missing):   # a dropped/renamed method is a failure,
        print(f"FAIL {name}: in baseline but not measured "
              "(renamed or broken method? run --update to drop it)")
        failures += 1              # not a silently disarmed gate
    values = {name: value for name, value, _ in rows}
    saved = values.get("smoke/copartitioned_agg/roundtrips_saved")
    if saved is not None:
        base_saved = base_all.get("copartitioned_roundtrips_saved", 1)
        if saved < base_saved:
            print(f"FAIL copartitioned_agg: {saved} shuffle_back "
                  f"round-trips saved < baseline {base_saved} (the fused "
                  "pipeline is paying the trip home again)")
            failures += 1
        fused = values.get("smoke/copartitioned_agg/fused/mesh1")
        home = values.get("smoke/copartitioned_agg/shuffle_home/mesh1")
        if fused is not None and home is not None and fused > home * TOLERANCE:
            print(f"FAIL copartitioned_agg: fused {fused:.1f}us > "
                  f"{TOLERANCE} x shuffle_home {home:.1f}us (the fused "
                  "pipeline stopped beating shuffle + gather-home)")
            failures += 1
    retry = values.get("smoke/retry_overhead")
    if retry is not None and retry > TOLERANCE:
        print(f"FAIL retry_overhead: with-report run {retry:.2f}x plain "
              f"> {TOLERANCE}x (diagnostics are taxing the happy path)")
        failures += 1
    hit = values.get("smoke/serving/cache_hit_speedup")
    if hit is not None and hit < MIN_CACHE_HIT_SPEEDUP:
        print(f"FAIL serving: cache-hit speedup {hit:.1f}x < "
              f"{MIN_CACHE_HIT_SPEEDUP}x floor (structural hits are "
              "re-tracing)")
        failures += 1
    batched = values.get("smoke/serving/batched64_speedup")
    if batched is not None and batched < MIN_BATCH_SPEEDUP:
        print(f"FAIL serving: batched-64 sweep {batched:.1f}x < "
              f"{MIN_BATCH_SPEEDUP}x over 64 sequential compiles")
        failures += 1
    pruned = values.get("smoke/streamed/slab_bytes/pruned")
    unpruned = values.get("smoke/streamed/slab_bytes/unpruned")
    if pruned is not None and unpruned is not None and pruned >= unpruned:
        print(f"FAIL streamed: pruned slab bytes {pruned:.0f} >= unpruned "
              f"{unpruned:.0f} (column pruning stopped shrinking the Q6 "
              "wave slabs — Q6 reads 3 of lineitem's 10 columns)")
        failures += 1
    overlap = values.get("smoke/streamed/overlap_win")
    if overlap is not None and overlap < _stream_overlap_floor():
        print(f"FAIL streamed: overlap win {overlap:.2f}x < "
              f"{_stream_overlap_floor()}x floor (double-buffered transfer "
              "stopped hiding the host->device copy)")
        failures += 1
    for name, value, _ in rows:
        if name in ("smoke/copartitioned_agg/roundtrips_saved",
                    "smoke/streamed/overlap_win",
                    "smoke/streamed/double_buffer/1dev",
                    "smoke/retry_overhead",
                    "smoke/serving/cache_hit_speedup",
                    "smoke/serving/batched64_speedup"):
            continue                     # ratio/structural rows, gated above
        if name.startswith("smoke/exact_speedup"):
            if value < MIN_EXACT_SPEEDUP:
                print(f"FAIL {name}: speedup {value:.2f}x < "
                      f"{MIN_EXACT_SPEEDUP}x floor")
                failures += 1
            continue
        if name not in base:
            print(f"WARN {name}: not in baseline (run --update to record)")
            continue
        tol = STREAM_TOLERANCE if name.startswith("smoke/streamed/") \
            else TOLERANCE
        if value > tol * base[name]:
            print(f"FAIL {name}: {value:.1f}us > {tol} x "
                  f"{base[name]:.1f}us baseline")
            failures += 1
    base_layout = base_all.get("peak_rows_per_device")
    layout = frontend_layout()
    if base_layout is None:
        print("WARN layout: no peak_rows_per_device in baseline "
              "(run --update to record)")
    elif (layout["replicated"] != base_layout["replicated"]
          or layout["sharded"] > base_layout["sharded"]):
        print(f"FAIL layout: peak rows/device {layout} regressed vs "
              f"baseline {base_layout} (the sharded frontend's "
              "O(rows/shards) accounting changed)")
        failures += 1
    base_shuffle = base_all.get("shuffle_join_rows_per_device")
    shuffle = shuffle_layout()
    if shuffle["shuffle_build_rows"] >= shuffle["gather_build_rows"]:
        print(f"FAIL shuffle layout: {shuffle} — the shuffle join no "
              "longer beats replicating the build side")
        failures += 1
    if base_shuffle is None:
        print("WARN shuffle layout: no shuffle_join_rows_per_device in "
              "baseline (run --update to record)")
    elif (shuffle["shuffle_build_rows"] > base_shuffle["shuffle_build_rows"]
          or shuffle["shuffle_probe_rows"]
          > base_shuffle["shuffle_probe_rows"]):
        print(f"FAIL shuffle layout: {shuffle} regressed vs baseline "
              f"{base_shuffle} (the ShuffleJoin's O(build/shards) "
              "accounting changed)")
        failures += 1
    base_stream = base_all.get("streamed_rows_per_device")
    stream = streamed_layout()
    if stream["x8"]["streamed_peak_rows"] != stream["x1"]["streamed_peak_rows"]:
        print(f"FAIL streamed layout: {stream} — streamed peak rows are "
              "not flat under 8x table growth (the wave slab is tracking "
              "the table, not the budget)")
        failures += 1
    if stream["x8"]["streamed_peak_rows"] >= stream["x8"]["resident_rows"]:
        print(f"FAIL streamed layout: {stream} — streaming no longer "
              "beats keeping the table resident")
        failures += 1
    if base_stream is None:
        print("WARN streamed layout: no streamed_rows_per_device in "
              "baseline (run --update to record)")
    elif (stream["x8"]["streamed_peak_rows"]
          > base_stream["x8"]["streamed_peak_rows"]):
        print(f"FAIL streamed layout: {stream} regressed vs baseline "
              f"{base_stream} (the double-buffered O(wave) residency "
              "accounting changed)")
        failures += 1
    print("CHECK " + ("FAILED" if failures else "PASSED")
          + f" ({len(rows)} rows, tol {TOLERANCE}x)")
    return 1 if failures else 0


def _update(rows):
    skip = ("smoke/exact_speedup", "smoke/copartitioned_agg/roundtrips",
            "smoke/streamed/overlap_win", "smoke/streamed/double_buffer",
            "smoke/retry_overhead", "smoke/serving/cache_hit_speedup",
            "smoke/serving/batched64_speedup")
    recorded = {name: us for name, us, _ in rows
                if not name.startswith(skip)}
    saved = {name: v for name, v, _ in rows
             if name == "smoke/copartitioned_agg/roundtrips_saved"}
    with open(BASELINE_PATH, "w") as f:
        json.dump({"tolerance": TOLERANCE, "repeat": "best-of",
                   "peak_rows_per_device": frontend_layout(),
                   "shuffle_join_rows_per_device": shuffle_layout(),
                   "streamed_rows_per_device": streamed_layout(),
                   "copartitioned_roundtrips_saved":
                       int(min(saved.values())) if saved else 1,
                   "rows": recorded}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {BASELINE_PATH} ({len(recorded)} rows)")


def main() -> int:
    rows = bench()
    rows += bench_sharded_frontend()
    rows += bench_shuffle_join()
    rows += bench_copartitioned_agg()
    rows += bench_streamed()
    rows += bench_stream_pruning()
    rows += bench_retry_overhead()
    rows += bench_serving()
    rows += bench_batched_sweep()
    rows += bench_exact_speedup()
    if "--mesh" in sys.argv and len(jax.devices()) > 1:
        from repro.launch.mesh import make_host_mesh
        rows += bench(mesh=make_host_mesh())
    for name, v, extra in rows:
        print(f"{name},{v:.1f},{extra}")
    if "--update" in sys.argv:
        _update(rows)
    if "--check" in sys.argv:
        return _check(rows)
    return 0


if __name__ == "__main__":
    sys.exit(main())
