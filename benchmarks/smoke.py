"""Per-PR perf smoke: one tiny planner-compiled TPC-H query per UDA method.

Runs Q3-shaped GroupAgg plans through ``compile_plan`` (the unified
segment-UDA path) for every aggregation method — normal, cumulants,
min/max — plus the ReweightGreater plan shape, and prints wall times, so
refactors of the UDA subsystem show perf regressions per-PR.

    PYTHONPATH=src python benchmarks/smoke.py [--mesh]

--mesh additionally compiles the same plans against a host-device mesh and
reports the distributed timings (requires >1 device or XLA_FLAGS host
device count).
"""
from __future__ import annotations

import sys
import time

import jax

sys.path.insert(0, "src")

from repro.db import tpch
from repro.db.plans import GroupAgg, ReweightGreater, Scan, Select, compile_plan


def _plans(max_groups: int = 256):
    li = Select(Scan("lineitem"), lambda t: t["l_shipdate"] > tpch.DAY0_1995)
    keys = ("l_orderkey",)
    return {
        "normal": GroupAgg(li, keys, "l_quantity", "SUM", max_groups,
                           "normal"),
        "cumulants": GroupAgg(li, keys, "l_quantity", "SUM", max_groups,
                              "cumulants"),
        "min": GroupAgg(li, keys, "l_quantity", "MIN", max_groups, kappa=32),
        "max": GroupAgg(li, keys, "l_quantity", "MAX", max_groups, kappa=32),
        "reweight": ReweightGreater(li, keys, "l_quantity", "", max_groups,
                                    threshold=60.0),
    }


def bench(n_orders: int = 1000, repeat: int = 3, mesh=None):
    db = tpch.generate(n_orders=n_orders, seed=0)
    tables = db.tables()
    rows = []
    for method, plan in _plans().items():
        fn = jax.jit(compile_plan(plan, mesh))
        out = fn(tables)                             # compile + warm
        jax.block_until_ready(jax.tree.leaves(out))
        t0 = time.perf_counter()
        for _ in range(repeat):
            out = fn(tables)
            jax.block_until_ready(jax.tree.leaves(out))
        dt = (time.perf_counter() - t0) / repeat
        tag = "mesh" if mesh is not None else "1dev"
        rows.append((f"smoke/{method}/{tag}", dt * 1e6,
                     f"n_orders={n_orders}"))
    return rows


def main():
    for name, us, extra in bench():
        print(f"{name},{us:.1f},{extra}")
    if "--mesh" in sys.argv and len(jax.devices()) > 1:
        from repro.launch.mesh import make_host_mesh
        for name, us, extra in bench(mesh=make_host_mesh()):
            print(f"{name},{us:.1f},{extra}")


if __name__ == "__main__":
    main()
