"""Unified benchmark runner: ``python -m benchmarks.run [--fast]``.

One section per paper artifact (Fig. 7 / Fig. 9 / Fig. 10), plus engine
microbenchmarks and the roofline table (from dry-run artifacts, if any).
Prints ``name,value,derived`` CSV lines.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from repro.core import enable_x64


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sizes")
    ap.add_argument("--only", default=None,
                    help="comma list: fig7,fig9,fig10,engine,roofline")
    args = ap.parse_args(argv)
    enable_x64()

    from . import engine, fig7_tpch, fig9_count, fig10_error, roofline
    sections = {
        "fig7": lambda: fig7_tpch.bench(n_orders=1000 if args.fast else 4000),
        "fig9": lambda: fig9_count.bench(
            sizes=(5_000, 20_000) if args.fast else (10_000, 40_000, 160_000)),
        "fig10": lambda: fig10_error.bench(
            sizes=(2_000, 8_000) if args.fast else (2_000, 8_000, 32_000,
                                                    128_000)),
        "engine": engine.bench,
        "roofline": roofline.bench,
    }
    only = set(args.only.split(",")) if args.only else set(sections)

    failures = 0
    for name, fn in sections.items():
        if name not in only:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            for row, value, extra in fn():
                print(f"{row},{value},{extra}", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
