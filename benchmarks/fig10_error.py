"""Figure 10 reproduction: relative error of the approximate COUNT/SUM
.95-confidence-interval lower end vs the exact distribution.

The paper reports 3e-7 .. 2e-9 at 100M..1B tuples; error shrinks with n
(CLT + 6 matched moments).  We measure the same quantity at CPU-feasible n
and additionally report the normal approximation for contrast.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import approx, poisson_binomial as pb
from repro.core.config import default_float


def ci_low_exact(probs):
    f = pb.count_pgf(probs)
    cdf = np.cumsum(np.asarray(f.coeffs))
    return float(np.searchsorted(cdf, 0.025))


def bench(sizes=(2_000, 8_000, 32_000, 128_000)):
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        p_np = rng.uniform(0, 1, n)
        probs = jnp.asarray(p_np, default_float())
        lo_exact = ci_low_exact(probs)

        gm = approx.fit_from_data(p_np, np.ones(n), p=3)
        lo_gm, _ = gm.confidence_interval(0.95)
        rel_gm = abs(lo_gm - lo_exact) / lo_exact
        rows.append((f"fig10/moment_rel_err/n={n}", rel_gm, ""))

        na = approx.fit_normal(p_np, np.ones(n))
        lo_na, _ = na.confidence_interval(0.95)
        rel_na = abs(lo_na - lo_exact) / lo_exact
        rows.append((f"fig10/normal_rel_err/n={n}", rel_na, ""))
    return rows


if __name__ == "__main__":
    for name, v, extra in bench():
        print(f"{name},{v:.3e},{extra}")
