"""Figure 7 reproduction: TPC-H query runtimes in the four modes.

The paper runs 17 TPC-H variants at 1 TB on a 48-core server; this
container is a CPU laptop-scale environment, so the benchmark runs the
implemented query suite (Q1/Q3/Q6/Q18/Q20 — the paper's worked examples)
at synthetic scale factors and reports per-mode wall time.  The paper's
headline shape — aggregate-mode probabilistic queries within a small
factor of deterministic ones — is the claim being measured.
"""
from __future__ import annotations

import time

import jax

from repro.db import tpch


def _time(jfn, db, repeat):
    out = jfn(db)                                     # compile + warm
    jax.block_until_ready(jax.tree.leaves(out))
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = jfn(db)
        jax.block_until_ready(jax.tree.leaves(out))
    return (time.perf_counter() - t0) / repeat


def bench(n_orders: int = 4000, repeat: int = 3, mesh=None):
    """Per-query/mode wall times; with ``mesh`` every probabilistic mode
    runs the sharded frontend (the whole plan inside one shard_map, rows
    partitioned over the data axes) — same results bit-for-bit, O(rows /
    shards) per-device memory."""
    db = tpch.generate(n_orders=n_orders, seed=0)
    tag = "/mesh" if mesh is not None else ""
    rows = []
    for qname, fn in tpch.QUERIES.items():
        jfn = {m: jax.jit(lambda db, m=m, fn=fn: fn(db, m, mesh=mesh))
               for m in tpch.MODES}
        for mode in tpch.MODES:
            dt = _time(jfn[mode], db, repeat)
            rows.append((f"fig7/{qname}/{mode}{tag}", dt * 1e6,
                         f"n_orders={n_orders}"))
    # grouped exact-CF through the planner (GroupAgg method="exact"):
    # q18's per-order quantity sums fit a 256-frequency grid exactly and
    # max_groups covers every order at the default scale (an overflowed
    # fill bucket would wrap mod num_freq); q6's row is a fixed-grid timing
    # proxy — 4096 frequencies cover the ~200-order instances the
    # correctness tests use, while at larger n_orders the distribution
    # wraps mod 4096 (the accumulation cost being timed is identical; size
    # num_freq >= max SUM + 1 for exact answers).
    groups = max(1024, 1 << (n_orders + 1).bit_length())
    exact = {
        "q18": lambda db: tpch.q18(db, "aggregate", method="exact",
                                   max_groups=groups, mesh=mesh),
        "q6": lambda db: tpch.q6(db, "aggregate", num_freq=1 << 12,
                                 mesh=mesh),
    }
    for qname, fn in exact.items():
        dt = _time(jax.jit(fn), db, repeat)
        rows.append((f"fig7/{qname}/aggregate_exact{tag}", dt * 1e6,
                     f"n_orders={n_orders}"))
    # the paper's claim: aggregate within small factor of deterministic
    for q in tpch.QUERIES:
        det = next(r[1] for r in rows
                   if r[0] == f"fig7/{q}/deterministic{tag}")
        agg = next(r[1] for r in rows if r[0] == f"fig7/{q}/aggregate{tag}")
        rows.append((f"fig7/{q}/agg_over_det{tag}", agg / max(det, 1e-9),
                     "ratio"))
    return rows


def bench_streamed(n_orders: int = 16000, budget: int = 2000,
                   repeat: int = 3, mesh=None):
    """Out-of-core rows: Q1/Q6 aggregate-mode with lineitem (``4 *
    n_orders`` rows) HOST-side and streamed in budget-sized waves — the
    regime past the device-residency wall, where the resident compile
    would need the whole table on the device.  The compiled fn is built
    ONCE per query and reused across repeats (the streamed path is an
    eager host wave loop; its per-wave jit cache lives in the compile
    closure), and the canonical chunk grid scales with the table
    (~500-row chunks) so the wave size tracks the budget.  The plans are
    the Q1/Q6 aggregate shapes built inline (the ``tpch.q1``/``q6``
    helpers compile per call)."""
    from repro.db.plans import GroupAgg, Map, Scan, Select, compile_plan
    from repro.db.table import HostTable

    db = tpch.generate(n_orders=n_orders, seed=0)
    n_li = db.lineitem.capacity
    tables = dict(db.tables())
    tables["lineitem"] = HostTable.from_table(db.lineitem)
    opts = dict(device_row_budget=budget,
                canonical_chunks=max(8, n_li // 500))
    q1_sel = Select(Scan("lineitem"),
                    lambda t: t["l_shipdate"] <= tpch.DAY0_1995 + 500)
    q6_val = Map(Select(
        Scan("lineitem"),
        lambda t: (t["l_shipdate"] >= tpch.DAY0_1995 - 400)
        & (t["l_shipdate"] < tpch.DAY0_1995)
        & (t["l_discount"] >= 5) & (t["l_discount"] <= 7)
        & (t["l_quantity"] < 24)), "q6_value",
        lambda t: t["l_quantity"] * t["l_discount"])
    plans = {
        "q1": GroupAgg(q1_sel, ("l_returnflag", "l_linestatus"),
                       "l_quantity", "SUM", 8, "normal",
                       extra=(("price", "l_extendedprice", "SUM", "normal"),
                              ("count", "", "COUNT", "normal"))),
        "q6": GroupAgg(q6_val, (), "q6_value", "SUM", 1, "normal",
                       extra=(("cumulants", "q6_value", "SUM",
                               "cumulants"),)),
    }
    tag = "/mesh" if mesh is not None else ""
    rows = []
    for qname, plan in plans.items():
        fn = compile_plan(plan, mesh, **opts)
        t0 = _time(fn, tables, repeat)
        rows.append((f"fig7/{qname}/aggregate_streamed{tag}", t0 * 1e6,
                     f"n_li={n_li},budget={budget}"))
    return rows


if __name__ == "__main__":
    import sys
    mesh = None
    if "--mesh" in sys.argv:   # sharded frontend over the host devices
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    rows = bench(mesh=mesh)
    if "--streamed" in sys.argv:   # out-of-core host->device wave rows
        rows += bench_streamed(mesh=mesh)
    for name, v, extra in rows:
        print(f"{name},{v:.1f},{extra}")
