"""Figure 7 reproduction: TPC-H query runtimes in the four modes.

The paper runs 17 TPC-H variants at 1 TB on a 48-core server; this
container is a CPU laptop-scale environment, so the benchmark runs the
implemented query suite (Q1/Q3/Q6/Q18/Q20 — the paper's worked examples)
at synthetic scale factors and reports per-mode wall time.  The paper's
headline shape — aggregate-mode probabilistic queries within a small
factor of deterministic ones — is the claim being measured.
"""
from __future__ import annotations

import time

import jax

from repro.db import tpch


def bench(n_orders: int = 4000, repeat: int = 3):
    db = tpch.generate(n_orders=n_orders, seed=0)
    rows = []
    for qname, fn in tpch.QUERIES.items():
        jfn = {m: jax.jit(lambda db, m=m, fn=fn: fn(db, m))
               for m in tpch.MODES}
        for mode in tpch.MODES:
            out = jfn[mode](db)                       # compile + warm
            jax.block_until_ready(jax.tree.leaves(out))
            t0 = time.perf_counter()
            for _ in range(repeat):
                out = jfn[mode](db)
                jax.block_until_ready(jax.tree.leaves(out))
            dt = (time.perf_counter() - t0) / repeat
            rows.append((f"fig7/{qname}/{mode}", dt * 1e6,
                         f"n_orders={n_orders}"))
    # the paper's claim: aggregate within small factor of deterministic
    for q in tpch.QUERIES:
        det = next(r[1] for r in rows if r[0] == f"fig7/{q}/deterministic")
        agg = next(r[1] for r in rows if r[0] == f"fig7/{q}/aggregate")
        rows.append((f"fig7/{q}/agg_over_det", agg / max(det, 1e-9),
                     "ratio"))
    return rows


if __name__ == "__main__":
    for name, v, extra in bench():
        print(f"{name},{v:.1f},{extra}")
