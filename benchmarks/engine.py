"""PGF-engine microbenchmarks: the paper's §VII implementation choices.

  * product-tree (paper-faithful FFTW path) vs log-CF (TPU adaptation)
  * schoolbook-vs-FFT polynomial multiply crossover (paper's 5000 threshold)
  * grouped aggregation throughput (tuples/s through the UDA layer)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pgf as P, poisson_binomial as pb
from repro.core.config import default_float


def _t(f, repeat=3):
    f()
    t0 = time.perf_counter()
    for _ in range(repeat):
        f()
    return (time.perf_counter() - t0) / repeat


def bench():
    rows = []
    rng = np.random.default_rng(0)

    # exact COUNT: product tree vs log-CF
    for n in (2048, 8192):
        probs = rng.uniform(0.05, 0.95, n)
        factors = jnp.asarray(
            np.stack([1 - probs, probs], axis=1), default_float())
        t_tree = _t(lambda: jax.block_until_ready(
            P.product_tree(factors).coeffs), 1)
        pj = jnp.asarray(probs, default_float())
        cf = jax.jit(lambda p: pb.logcf_finalize(
            *pb.logcf_terms(p, jnp.ones_like(p), n + 1)))
        t_cf = _t(lambda: jax.block_until_ready(cf(pj)), 1)
        rows.append((f"engine/product_tree/n={n}", t_tree * 1e6, ""))
        rows.append((f"engine/logcf/n={n}", t_cf * 1e6, ""))

    # polymul crossover
    for k in (256, 1024, 4096):
        a = jnp.asarray(rng.dirichlet(np.ones(k)), default_float())
        b = jnp.asarray(rng.dirichlet(np.ones(k)), default_float())
        t_school = _t(lambda: jax.block_until_ready(jnp.convolve(a, b)))
        t_fft = _t(lambda: jax.block_until_ready(P.fft_convolve(a, b)))
        rows.append((f"engine/conv_school/k={k}", t_school * 1e6, ""))
        rows.append((f"engine/conv_fft/k={k}", t_fft * 1e6, ""))

    # UDA throughput (grouped normal+cumulant accumulate, jitted)
    from repro.db import operators as ops
    from repro.db.table import Table
    n, G = 1 << 18, 1024
    t = Table.from_columns(
        {"g": jnp.asarray(rng.integers(0, G, n)),
         "v": jnp.asarray(rng.integers(1, 50, n).astype(float))},
        prob=jnp.asarray(rng.uniform(0, 1, n)))

    @jax.jit
    def agg(t):
        ids, _, _ = ops.group_ids(t, ["g"], G)
        v = t["v"].astype(t.prob.dtype)
        mu, var = ops.group_normal_terms(t, v, ids, G)
        cum = ops.group_cumulant_terms(t, v, ids, G)
        return mu, var, cum

    dt = _t(lambda: jax.block_until_ready(agg(t)))
    rows.append((f"engine/uda_grouped_throughput", dt * 1e6,
                 f"{n / dt / 1e6:.1f}Mtuples/s"))
    return rows


if __name__ == "__main__":
    for name, v, extra in bench():
        print(f"{name},{v:.1f},{extra}")
