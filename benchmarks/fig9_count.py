"""Figure 9 reproduction: exact vs approximate COUNT aggregate scaling.

The paper filters lineitem to 100M..1B tuples and compares deterministic
COUNT, moment-based approximate COUNT, and the exact distribution (FFTW
product tree there; log-CF + FFT here).  Same three curves, CPU-feasible
n, plus the paper-faithful product-tree path for reference.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import approx, poisson_binomial as pb
from repro.core.config import default_float


def _time(f, repeat=3):
    f()                                    # warm/compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        f()
    return (time.perf_counter() - t0) / repeat


def bench(sizes=(10_000, 40_000, 160_000), repeat: int = 3):
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        probs = jnp.asarray(rng.uniform(0, 1, n), default_float())

        det = jax.jit(lambda p: (p > 0.5).sum())
        t_det = _time(lambda: jax.block_until_ready(det(probs)), repeat)
        rows.append((f"fig9/deterministic/n={n}", t_det * 1e6, ""))

        cum = jax.jit(lambda p: approx.cumulant_terms(p, jnp.ones_like(p), 6))
        t_apx = _time(lambda: jax.block_until_ready(cum(probs)), repeat)
        # host-side mixture solve included (it is O(p^3), constant)
        terms = np.asarray(cum(probs))
        t0 = time.perf_counter()
        approx.fit_gamma_mixture(terms, p=3)
        t_fit = time.perf_counter() - t0
        rows.append((f"fig9/approx_moment/n={n}", (t_apx + t_fit) * 1e6, ""))

        # exact: the paper-style dispatch (log-CF below TREE_THRESHOLD,
        # pairwise FFT product tree above — §VII-B one level up)
        t_ex = _time(lambda: jax.block_until_ready(
            pb.count_pgf(probs).coeffs), 1)
        rows.append((f"fig9/exact/n={n}", t_ex * 1e6,
                     "tree" if n >= pb.TREE_THRESHOLD else "cf"))

        rows.append((f"fig9/exact_over_approx/n={n}",
                     t_ex / max(t_apx + t_fit, 1e-9), "ratio"))
    return rows


if __name__ == "__main__":
    for name, v, extra in bench():
        print(f"{name},{v:.1f},{extra}")
