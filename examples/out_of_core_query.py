"""Out-of-core streamed query: a probabilistic aggregation over a host
table LARGER than the per-device row budget.

The fact table stays host-side as a :class:`~repro.db.table.HostTable`
(numpy columns, never device-resident as a whole); ``compile_plan`` with
``device_row_budget`` lowers its scan to a StreamedScan and runs the
aggregation pass as waves — canonical-chunk-aligned slabs shipped
host->device with double-buffered transfer, per-(chunk, group) UDA
states folded across waves, ONE canonical fold at the end.  The result
is bit-identical to the fully device-resident compile at any wave size
(the streaming contract of db/plans.py), while peak device residency is
two wave slabs instead of the table.

The second half goes one step further down the memory hierarchy: the
table is saved to one ``.npy`` file per column (``HostTable.save``),
reopened memory-mapped (``HostTable.open``), and the SAME streamed plan
runs against the disk-backed table — slab assembly reads only the
touched row ranges of the columns the plan demands (the lowering's
column pruning), so neither device memory NOR host RAM ever holds the
whole table.  See docs/out_of_core.md.

    PYTHONPATH=src python examples/out_of_core_query.py
"""
import os
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core import enable_x64

enable_x64()

import jax

from repro.db import physical as phys
from repro.db import plans
from repro.db.plans import GroupAgg, Scan, Select, compile_plan
from repro.db.table import HostTable


def main():
    # A synthetic fact table: 200k uncertain rows, 16 groups.  Build it
    # straight into numpy — the point is that it NEVER becomes a single
    # device array.
    n = 200_000
    rng = np.random.default_rng(0)
    fact = HostTable(
        {"region": rng.integers(0, 16, n).astype(np.int64),
         "amount": rng.integers(1, 100, n).astype(np.int64)},
        prob=rng.uniform(0.2, 1.0, n))
    print(f"host table: {fact.capacity} rows, "
          f"{len(fact.columns) + 2} columns (numpy, host memory)")

    plan = GroupAgg(Select(Scan("fact"), lambda t: t["amount"] > 10),
                    ("region",), "amount", "SUM", 16, "normal",
                    extra=(("count", "", "COUNT", "normal"),))

    # Budget: at most 4096 resident rows per device for the fact scan.
    # ~500-row canonical chunks keep the wave size tracking the budget
    # (not the table), so the device footprint is flat however large the
    # host table grows.
    opts = dict(device_row_budget=4096, canonical_chunks=n // 500)

    lowered = phys.lower_plan(
        GroupAgg(Select(Scan("fact"), lambda t: t["amount"] > 10),
                 ("region",), "amount", "SUM", 16, "normal"),
        {"fact": fact.pad_to_multiple(n // 500).capacity},
        n_shards=1, sharded=False, **opts)
    print("\nphysical plan:\n" + phys.explain(lowered) + "\n")

    streamed = compile_plan(plan, None, **opts)({"fact": fact})
    jax.block_until_ready(jax.tree.leaves(streamed))
    print("streamed result (per-region SUM distribution, first 4 groups):")
    mu, var = streamed["sum"]
    for g in range(4):
        print(f"  region {g}: E[sum]={float(mu[g]):12.2f} "
              f"sd={float(np.sqrt(var[g])):9.2f} "
              f"E[count]={float(streamed['count'][0][g]):9.1f}")

    # The contract: bit-identical to the fully resident compile — same
    # plan, same canonical chunk grid (the grid defines the summation
    # order), no budget, whole table on the device.
    resident = compile_plan(plan, None, canonical_chunks=n // 500)(
        {"fact": fact.to_table()})
    la = jax.tree.leaves(streamed)
    lb = jax.tree.leaves(resident)
    assert all(np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
               for a, b in zip(la, lb))
    print("\nstreamed == resident, bit for bit "
          f"({sum(np.asarray(x).size for x in la)} result elements)")

    # ---- the disk-backed half: save -> open (mmap) -> stream ----------
    # One .npy per column + a manifest; np.memmap-backed on open, so
    # slab assembly touches only the demanded columns' row ranges and
    # the table never needs to fit in host RAM either.  The row budget
    # (4096) is ~50x smaller than the table.
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "fact.cols")
        fact.save(path)
        on_disk = sum(os.path.getsize(os.path.join(path, f))
                      for f in os.listdir(path))
        disk = HostTable.open(path)         # mmap_mode="r"
        assert isinstance(disk.prob, np.memmap)
        print(f"\nsaved to {len(os.listdir(path))} files "
              f"({on_disk / 1e6:.1f} MB on disk), reopened memory-mapped")

        plans.reset_stream_stats()
        mapped = compile_plan(plan, None, **opts)({"fact": disk})
        jax.block_until_ready(jax.tree.leaves(mapped))
        st = plans.stream_stats()
        lm = jax.tree.leaves(mapped)
        assert all(np.array_equal(np.asarray(a), np.asarray(b),
                                  equal_nan=True)
                   for a, b in zip(lm, lb))
        print(f"mmap-streamed == resident, bit for bit — {st['waves']} "
              f"waves, {st['slab_bytes'] / 1e6:.1f} MB shipped "
              "(column-pruned slabs: only the demanded columns leave "
              "the page cache)")


if __name__ == "__main__":
    main()
