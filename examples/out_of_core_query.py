"""Out-of-core streamed query: a probabilistic aggregation over a host
table LARGER than the per-device row budget.

The fact table stays host-side as a :class:`~repro.db.table.HostTable`
(numpy columns, never device-resident as a whole); ``compile_plan`` with
``device_row_budget`` lowers its scan to a StreamedScan and runs the
aggregation pass as waves — canonical-chunk-aligned slabs shipped
host->device with double-buffered transfer, per-(chunk, group) UDA
states folded across waves, ONE canonical fold at the end.  The result
is bit-identical to the fully device-resident compile at any wave size
(the streaming contract of db/plans.py), while peak device residency is
two wave slabs instead of the table.

    PYTHONPATH=src python examples/out_of_core_query.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import enable_x64

enable_x64()

import jax

from repro.db import physical as phys
from repro.db.plans import GroupAgg, Scan, Select, compile_plan
from repro.db.table import HostTable


def main():
    # A synthetic fact table: 200k uncertain rows, 16 groups.  Build it
    # straight into numpy — the point is that it NEVER becomes a single
    # device array.
    n = 200_000
    rng = np.random.default_rng(0)
    fact = HostTable(
        {"region": rng.integers(0, 16, n).astype(np.int64),
         "amount": rng.integers(1, 100, n).astype(np.int64)},
        prob=rng.uniform(0.2, 1.0, n))
    print(f"host table: {fact.capacity} rows, "
          f"{len(fact.columns) + 2} columns (numpy, host memory)")

    plan = GroupAgg(Select(Scan("fact"), lambda t: t["amount"] > 10),
                    ("region",), "amount", "SUM", 16, "normal",
                    extra=(("count", "", "COUNT", "normal"),))

    # Budget: at most 4096 resident rows per device for the fact scan.
    # ~500-row canonical chunks keep the wave size tracking the budget
    # (not the table), so the device footprint is flat however large the
    # host table grows.
    opts = dict(device_row_budget=4096, canonical_chunks=n // 500)

    lowered = phys.lower_plan(
        GroupAgg(Select(Scan("fact"), lambda t: t["amount"] > 10),
                 ("region",), "amount", "SUM", 16, "normal"),
        {"fact": fact.pad_to_multiple(n // 500).capacity},
        n_shards=1, sharded=False, **opts)
    print("\nphysical plan:\n" + phys.explain(lowered) + "\n")

    streamed = compile_plan(plan, None, **opts)({"fact": fact})
    jax.block_until_ready(jax.tree.leaves(streamed))
    print("streamed result (per-region SUM distribution, first 4 groups):")
    mu, var = streamed["sum"]
    for g in range(4):
        print(f"  region {g}: E[sum]={float(mu[g]):12.2f} "
              f"sd={float(np.sqrt(var[g])):9.2f} "
              f"E[count]={float(streamed['count'][0][g]):9.1f}")

    # The contract: bit-identical to the fully resident compile — same
    # plan, same canonical chunk grid (the grid defines the summation
    # order), no budget, whole table on the device.
    resident = compile_plan(plan, None, canonical_chunks=n // 500)(
        {"fact": fact.to_table()})
    la = jax.tree.leaves(streamed)
    lb = jax.tree.leaves(resident)
    assert all(np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
               for a, b in zip(la, lb))
    print("\nstreamed == resident, bit for bit "
          f"({sum(np.asarray(x).size for x in la)} result elements)")


if __name__ == "__main__":
    main()
