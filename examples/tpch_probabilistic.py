"""Probabilistic TPC-H end-to-end: generate a synthetic probabilistic
database, run the paper's query suite in all four modes, and show the Q20
plan (the paper's Fig. 6 worked example) step by step.

    PYTHONPATH=src python examples/tpch_probabilistic.py [--orders 2000]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import enable_x64

enable_x64()

from repro.db import tpch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--orders", type=int, default=2000)
    args = ap.parse_args()

    print(f"generating TPC-H-like probabilistic db (n_orders={args.orders})")
    db = tpch.generate(n_orders=args.orders, seed=0)
    print({k: v for k, v in db.scale.items()})

    print(f"\n{'query':8s} {'mode':18s} {'wall s':>8s}  result summary")
    for qname, fn in tpch.QUERIES.items():
        for mode in tpch.MODES:
            t0 = time.perf_counter()
            out = fn(db, mode)
            jax.block_until_ready(jax.tree.leaves(out))
            dt = time.perf_counter() - t0
            if "confidence" in out and np.ndim(out["confidence"]) == 0:
                summary = f"confidence={float(out['confidence']):.4f}"
            elif "valid" in out:
                nv = int(np.asarray(out["valid"]).sum())
                summary = f"{nv} groups"
            else:
                summary = ",".join(sorted(out))
            print(f"{qname:8s} {mode:18s} {dt:8.3f}  {summary}")

    # --- Q20 narrated (paper Fig. 6) ------------------------------------
    print("\nQ20 aggregate mode (suppliers in nation 3 with excess "
          "'forest' stock):")
    out = tpch.q20(db, "aggregate")
    valid = np.asarray(out["valid"])
    names = np.asarray(out["s_name"])[valid]
    probs = np.asarray(out["prob"])[valid]
    for n_, p_ in sorted(zip(names, probs), key=lambda x: -x[1])[:10]:
        print(f"  supplier {int(n_):4d}  P(qualifies) = {p_:.4f}")


if __name__ == "__main__":
    main()
