"""End-to-end training driver: train a ~100M-param yi-family model for a
few hundred steps on CPU with the full production substrate — PGF-based
probabilistic data sampling, microbatch accumulation, checkpoint-restart
(a failure is injected mid-run to prove it), and final perplexity.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--small]
"""
import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.train.data import ProbabilisticSampler, TokenStream
from repro.train.optimizer import AdamW
from repro.train.trainer import Trainer, run_with_failures


def config(small: bool) -> ModelConfig:
    if small:
        return ModelConfig(
            name="yi_tiny", family="dense", n_layers=2, d_model=128,
            n_heads=4, n_kv_heads=2, d_ff=352, vocab_size=2048,
            mlp="swiglu", dtype="float32")
    # ~100M params: 12L x 768, llama/yi family
    return ModelConfig(
        name="yi_100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
        mlp="swiglu", dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true",
                    help="tiny model (CI-speed)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = config(args.small)
    n_params = cfg.param_count()
    print(f"arch={cfg.name}: ~{n_params/1e6:.0f}M params, "
          f"{args.steps} steps of batch {args.batch} x seq {args.seq}")

    # PGF-backed probabilistic sampling (paper as substrate): per-example
    # inclusion probabilities; the Poisson-binomial PGF sizes the batch
    # capacity with provable overflow probability.
    rng = np.random.default_rng(0)
    sampler = ProbabilisticSampler(rng.uniform(0.5, 0.95, args.batch * 4))
    cap = sampler.capacity_for(1e-6)
    f = sampler.batch_size_pgf()
    print(f"probabilistic sampler: pool={args.batch*4} "
          f"E[batch]={float(f.mean()):.1f} capacity(1e-6)={cap}")

    stream = TokenStream(cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    opt = AdamW(lr=6e-4, warmup=40)
    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(cfg, opt, stream, ckdir, accum=2,
                          ckpt_every=max(20, args.steps // 4))
        fail_step = args.steps // 2
        print(f"injecting a node failure at step {fail_step} "
              f"(restart from latest checkpoint)...")
        t0 = time.time()
        params, _, hist = run_with_failures(
            trainer, args.steps, {fail_step})[:3]
        dt = time.time() - t0
    tokens = args.steps * args.batch * args.seq
    print(f"done in {dt:.1f}s ({tokens/dt:.0f} tok/s CPU)")
    print(f"loss: {hist[0]:.4f} -> {hist[-1]:.4f} "
          f"(ppl {np.exp(hist[0]):.1f} -> {np.exp(hist[-1]):.1f})")
    assert hist[-1] < hist[0], "training must reduce loss"


if __name__ == "__main__":
    main()
