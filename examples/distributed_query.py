"""Distributed PGF query on a host-device mesh: the paper's aggregate
query as one shard_map program — per-shard UDA accumulate, one psum merge,
replicated FFT finalize (DESIGN.md §2).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_query.py
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.db import distributed as dist


def main():
    n_dev = len(jax.devices())
    data = max(1, n_dev // 2)
    from repro.compat import make_mesh
    mesh = make_mesh((data, n_dev // data), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {n_dev} host devices")

    n, G, F = 1 << 18, 256, 1024
    rng = np.random.default_rng(0)
    probs = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    # selective query: most tuples fail the predicate (value 0); the exact
    # global SUM distribution lives on the F-grid of the survivors
    v_np = np.zeros(n, np.float32)
    hot = rng.choice(n, 400, replace=False)
    v_np[hot] = rng.integers(1, 4, 400)
    values = jnp.asarray(v_np)
    gids = jnp.asarray(rng.integers(0, G, n), jnp.int32)

    step = dist.make_query_step(mesh, max_groups=G, num_freq=F)
    pd, vd, gd = dist.shard_columns(mesh, (probs, values, gids))
    out = step(pd, vd, gd)                       # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    conf, normal, cum, coeffs = jax.block_until_ready(step(pd, vd, gd))
    dt = time.perf_counter() - t0

    print(f"{n:,} probabilistic tuples -> {G} groups + exact global "
          f"distribution ({F} support) in {dt*1e3:.1f} ms "
          f"({n/dt/1e6:.2f} Mtuples/s on host-CPU stand-in devices)")
    print(f"  global COUNT-ish distribution mass: {float(coeffs.sum()):.6f}")
    print(f"  group 0: confidence={float(conf[0]):.4f} "
          f"E[SUM]={float(normal[0,0]):.1f} "
          f"sigma={float(jnp.sqrt(normal[0,1])):.2f}")
    mean_exact = float((coeffs * jnp.arange(F)).sum())
    print(f"  E[global SUM] from exact PGF = {mean_exact:.1f} "
          f"(closed form {float((probs*values).sum()):.1f})")

    # ---- the sharded relational frontend: a full TPC-H plan on the mesh.
    # Scans, the FK join, group-id assignment and the aggregation all run
    # on shard-local row blocks inside one shard_map (db/plans.py), and
    # the result is BIT-IDENTICAL to the single-device compile.
    from repro.db import tpch
    db = tpch.generate(n_orders=2000, seed=0)
    ref = tpch.q3(db, "aggregate")
    t0 = time.perf_counter()
    got = jax.block_until_ready(tpch.q3(db, "aggregate", mesh=mesh))
    dt = time.perf_counter() - t0
    bit_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)))
    shards = mesh.shape["data"]
    print(f"TPC-H Q3 via the sharded frontend on {shards} data shards in "
          f"{dt*1e3:.1f} ms: bit-equal to single-device = {bit_equal} "
          f"(rows/device {db.lineitem.capacity // shards:,} vs "
          f"{db.lineitem.capacity:,} replicated)")

    # ---- the shuffle-partitioned FK join: force every over-budget build
    # side onto the hash-exchange strategy (db/physical.py ShuffleJoin —
    # build rows and probe keys alltoall'd to key % n_shards owners,
    # matched shard-locally, responses shuffled home).  Same bits, but
    # peak build rows/device drop from O(build) to O(build/shards).
    # copartition=False pins the shuffle-home strategy: Q3's GROUP BY
    # keys on the join key, so the cost model would otherwise fuse it —
    # that pipeline is the next section.
    t0 = time.perf_counter()
    shuf = jax.block_until_ready(
        tpch.q3(db, "aggregate", mesh=mesh,
                plan_opts=dict(join_gather_budget=64, copartition=False)))
    dt = time.perf_counter() - t0
    bit_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(shuf)))
    print(f"TPC-H Q3 with shuffle-partitioned joins (gather budget 64) in "
          f"{dt*1e3:.1f} ms: bit-equal to single-device = {bit_equal} "
          f"(build rows/device {db.orders.capacity // shards:,} vs "
          f"{db.orders.capacity:,} gathered)")

    # ---- the co-partitioned shuffle -> aggregate pipeline: Q3's GROUP BY
    # keys on the join key, so the cost model (db/cost.py) fuses the
    # orders join with the aggregation — matched rows STAY at their
    # l_orderkey % n_shards owner (CoPartitionedJoin), the whole GROUP BY
    # runs owner-locally (PartitionedAgg), and the merge is ONE psum of
    # the folded group states.  Zero shuffle-home round-trips, same bits.
    dist.reset_collective_counts()
    t0 = time.perf_counter()
    fused = jax.block_until_ready(
        tpch.q3(db, "aggregate", mesh=mesh, order_join_budget=64))
    dt = time.perf_counter() - t0
    bit_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(fused)))
    counts = dict(dist.COLLECTIVE_COUNTS)
    print(f"TPC-H Q3 with the co-partitioned join->agg pipeline in "
          f"{dt*1e3:.1f} ms: bit-equal to single-device = {bit_equal}, "
          f"shuffle_back round-trips = {counts.get('shuffle_back', 0)} "
          f"(collectives: {counts})")


if __name__ == "__main__":
    main()
