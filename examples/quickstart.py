"""Quickstart: the paper's core in 60 lines.

Builds the paper's Figure-1 table, computes exact COUNT/SUM/MIN
distributions via PGFs, compares exact vs approximate on a larger table,
and answers a probabilistic threshold query with the PGF ADT.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import enable_x64

enable_x64()

from repro.core import approx, compare, poisson_binomial as pb
from repro.core.pgf import PGF


def main():
    # --- the paper's Figure 1 table: probabilities 0.7, 0.8, 0.5 --------
    probs = jnp.asarray([0.7, 0.8, 0.5], jnp.float64)
    values = jnp.asarray([3.0, 8.0, 5.0], jnp.float64)

    count = pb.count_pgf(probs)
    print("COUNT PGF coefficients (X^0..X^3):",
          np.round(np.asarray(count.coeffs), 4))
    # paper §IV-A: 0.28X^3 + 0.47X^2 + 0.22X + 0.03

    ssum = pb.sum_pgf(probs, values)
    nz = {k: round(float(v), 4) for k, v in
          enumerate(np.asarray(ssum.coeffs)) if v > 1e-12}
    print("SUM distribution:", nz)
    # paper: 0.28X^16 + 0.12X^13 + 0.28X^11 + 0.19X^8 + 0.03X^5 + 0.07X^3 + 0.03

    f1 = PGF.bernoulli(0.7, 3, "MIN")
    f2 = PGF.bernoulli(0.8, 8, "MIN")
    fmin = f1.mul_min(f2)
    print(f"MIN of first two tuples: P(3)={float(fmin.mass_at(3)):.2f} "
          f"P(8)={float(fmin.mass_at(8)):.2f} "
          f"P(undefined)={float(fmin.p_pos_inf):.2f}")

    # --- exact vs approximate at scale ----------------------------------
    rng = np.random.default_rng(0)
    n = 50_000
    p_big = rng.uniform(0, 1, n)
    v_big = rng.integers(1, 10, n).astype(float)

    gm = approx.fit_from_data(p_big, v_big, p=3)       # moment method
    na = approx.fit_normal(p_big, v_big)               # normal
    exact = pb.sum_pgf(jnp.asarray(p_big), jnp.asarray(v_big))
    cdf = np.cumsum(np.asarray(exact.coeffs))
    s0 = int(gm.mean())
    print(f"\nn={n}: P(SUM <= mean) exact={cdf[s0]:.6f} "
          f"moment={gm.cdf(s0):.6f} normal={na.cdf(s0):.6f}")
    lo_e = float(np.searchsorted(cdf, 0.025))
    lo_g, _ = gm.confidence_interval(0.95)
    print(f".95 CI lower end: exact={lo_e:.0f} moment={lo_g:.1f} "
          f"rel err={abs(lo_g - lo_e) / lo_e:.2e}")

    # --- the PGF ADT answering a query predicate ------------------------
    p_gt = compare.prob_greater(gm, s0 + 500)
    print(f"P(SUM > mean+500) = {p_gt:.4f}  (drives Table I row III "
          f"reweighting, e.g. TPC-H Q20)")


if __name__ == "__main__":
    main()
