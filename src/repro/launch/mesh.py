"""Production meshes (DESIGN.md §5).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int | None = None):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    if data is None or model is None:
        model = 1
        while model * 2 <= min(4, n) and n % (model * 2) == 0:
            model *= 2
        data = n // model
    return make_mesh((data, model), ("data", "model"))
