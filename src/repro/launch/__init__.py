"""Launchers: production meshes, dry-run, train, serve.

NOTE: dryrun must run as its own process (it pins 512 host devices before
jax initialises); do not import repro.launch.dryrun from a live session.
"""
from . import mesh

__all__ = ["mesh"]
