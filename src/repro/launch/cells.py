"""Per-cell step builders: (arch x shape x mesh) -> jit-able step + specs.

A *cell* is one dry-run/roofline unit.  For LM archs:

    train_4k     full train step (fwd + bwd + clip + AdamW update), remat,
                 microbatch accumulation for the big configs
    prefill_32k  forward logits over the full sequence
    decode_32k   one-token serve_step against a seq_len KV cache
    long_500k    one-token serve_step against a 512k context
                 (sub-quadratic archs only)

plus the paper's own `pgf_tpch` cell (distributed aggregate-query step).

Memory posture knobs per arch (DESIGN.md §5): FSDP always on; SP-style
residual sharding and bf16 Adam moments for d_model >= 5120; bf16 grad
accumulation + accum=8 for the 340B config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import base as cfgs
from ..models import api
from ..sharding import Rules
from ..train.optimizer import AdamW
from ..train.trainer import make_train_step


def arch_knobs(cfg) -> dict:
    big = cfg.d_model >= 5120
    huge = cfg.d_model >= 16384
    # Universal microbatching: 16 rows/device at 4k seq blows the 16 GB
    # HBM budget for EVERY family (yi 22.9 GB, rgemma 29 GB, ... §Perf);
    # accum=4 caps per-micro tokens/device at 16k.  Cost: the per-micro
    # gradient reduce-scatter runs A times (GSPMD can't defer it through
    # the scan) — memory fit is the hard constraint, so accept and record.
    accum = 8 if huge else 4
    return dict(
        sp=big,
        accum=accum,
        moment_dtype="bfloat16" if big else None,
        accum_dtype=jnp.bfloat16 if huge else jnp.float32,
    )


@dataclasses.dataclass
class Cell:
    name: str
    fn: Callable                     # jit-able python callable
    args: dict                       # kwarg name -> ShapeDtypeStruct pytree
    in_shardings: dict               # same structure, NamedShardings
    donate: tuple = ()


# ------------------------------------------------------------- shardings
def _batch_shardings(rules: Rules, args: dict) -> dict:
    out = {}
    for k, v in args.items():
        if k in ("tokens", "labels"):
            name = "tokens" if len(v.shape) == 2 else "residual"
            out[k] = rules.input_sharding(name, v.shape)
        else:
            out[k] = NamedSharding(rules.mesh, P())
    return out


def _cache_shardings(rules: Rules, cache) -> Any:
    mesh = rules.mesh
    dp = rules.dp

    def spec_for(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        shape = leaf.shape

        def div(i, ax):
            return shape[i] % mesh.shape[ax] == 0 if ax in mesh.axis_names \
                else False

        dp_ok = dp and shape[1] % _axsize(mesh, dp) == 0
        batch = dp if dp_ok else None
        parts = [None, batch] + [None] * (len(shape) - 2)
        if name in ("k", "v") and len(shape) == 5 and div(3, "model"):
            parts[3] = "model"                      # (n, B, S, KV, hd)
        elif name in ("k", "v") and len(shape) == 5 and div(2, "model"):
            parts[2] = "model"                      # sequence-sharded cache
        elif name == "s" and len(shape) == 5 and div(2, "model"):
            parts[2] = "model"                      # (n, B, H, K, V)
        elif name == "h" and len(shape) == 3 and div(2, "model"):
            parts[2] = "model"                      # (n, B, W)
        elif name == "conv" and len(shape) == 4 and div(3, "model"):
            parts[3] = "model"
        elif name in ("shift", "shift_c") and len(shape) == 3 \
                and div(2, "model"):
            parts[2] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def _axsize(mesh: Mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


# ------------------------------------------------------------------ cells
def calibration_pattern(cfg):
    """(repeating base pattern, trip count) for the calibration cost
    model.  Tail layers sit in the model's intercept (they appear in both
    calibration variants), so the two-point fit is exact."""
    return cfg.pattern, cfg.n_periods


def build_lm_cell(arch: str, shape_name: str, mesh: Mesh, *,
                  cfg=None, accum: int | None = None,
                  unroll: bool = False) -> Cell:
    base_cfg = cfgs.get_config(arch)
    assert shape_name in cfgs.runnable_cells(base_cfg), \
        f"{arch} skips {shape_name} (DESIGN.md §4)"
    knobs = arch_knobs(base_cfg)
    if accum is not None:
        knobs["accum"] = accum
    cfg = cfg or base_cfg
    rules = Rules(mesh, fsdp=True, sp=knobs["sp"])
    spec = cfgs.SHAPES[shape_name]

    import contextlib
    from ..models.runmode import unrolled
    ctx = unrolled if unroll else contextlib.nullcontext

    params_shapes = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    params_sh = rules.params_tree(params_shapes)
    args = cfgs.input_specs(cfg, shape_name)

    if spec["kind"] == "train":
        opt = AdamW(moment_dtype=knobs["moment_dtype"])
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        opt_sh = rules.params_tree(opt_shapes)
        step = make_train_step(cfg, opt, accum=knobs["accum"], remat=True,
                               donate=False, accum_dtype=knobs["accum_dtype"],
                               jit=False)

        def fn(params, opt_state, tokens, labels):
            with ctx(), rules.activate():
                return step(params, opt_state,
                            dict(tokens=tokens, labels=labels))

        in_sh = dict(params=params_sh, opt_state=opt_sh,
                     **_batch_shardings(rules, args))
        return Cell(f"{arch}/{shape_name}", fn,
                    dict(params=params_shapes, opt_state=opt_shapes, **args),
                    in_sh, donate=("params", "opt_state"))

    if spec["kind"] == "prefill":
        def fn(params, tokens):
            with ctx(), rules.activate():
                return api.prefill(cfg, params, tokens)

        in_sh = dict(params=params_sh, **_batch_shardings(rules, args))
        return Cell(f"{arch}/{shape_name}", fn,
                    dict(params=params_shapes, **args), in_sh)

    # decode
    def fn(params, tokens, cache, cache_len):
        with ctx(), rules.activate():
            return api.decode_step(cfg, params, tokens, cache, cache_len)

    cache_shapes = args["cache"]
    in_sh = dict(params=params_sh,
                 tokens=rules.input_sharding(
                     "tokens" if len(args["tokens"].shape) == 2
                     else "residual", args["tokens"].shape),
                 cache=_cache_shardings(rules, cache_shapes),
                 cache_len=NamedSharding(mesh, P()))
    return Cell(f"{arch}/{shape_name}", fn,
                dict(params=params_shapes, **args), in_sh,
                donate=("cache",))


def build_pgf_cell(mesh: Mesh, reduced: bool = False,
                   n_tuples: int | None = None,
                   unroll: bool = False) -> Cell:
    import contextlib
    from ..configs import pgf_tpch
    from ..db import distributed as dist
    from ..models.runmode import unrolled
    qc = pgf_tpch.reduced() if reduced else pgf_tpch.CONFIG
    step = dist.make_query_step(mesh, max_groups=qc.max_groups,
                                num_freq=qc.num_freq, orders=qc.orders)
    args = dist.input_specs(n_tuples=n_tuples or qc.n_tuples)
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sh = NamedSharding(mesh, P(axes))
    in_sh = {k: sh for k in args}
    ctx = unrolled if unroll else contextlib.nullcontext

    def fn(**kw):
        with ctx():
            return step(**kw)

    return Cell(f"pgf_tpch/query", fn, args, in_sh)


def build_cell(arch: str, shape_name: str, mesh: Mesh) -> Cell:
    if arch == "pgf_tpch":
        return build_pgf_cell(mesh)
    return build_lm_cell(arch, shape_name, mesh)


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) pair + the pgf cell (DESIGN.md §4)."""
    cells = []
    for arch in cfgs.ARCH_IDS:
        cfg = cfgs.get_config(arch)
        for s in cfgs.runnable_cells(cfg):
            cells.append((arch, s))
    cells.append(("pgf_tpch", "query"))
    return cells
