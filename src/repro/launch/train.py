"""Training launcher.

CPU-runnable path (reduced configs, e2e driver for examples/tests):

    PYTHONPATH=src python -m repro.launch.train --arch yi_6b --reduced \
        --steps 100 --ckpt-dir /tmp/ckpt

Production path: the same Trainer under the production mesh — on a real
pod this process runs per-host with jax.distributed.initialize(); the mesh,
sharding rules and step function are exactly the ones the dry-run compiles
(launch/cells.py), so a cell that passes the dry-run is launchable
unchanged.
"""
from __future__ import annotations

import argparse

import jax

from ..configs import base as cfgs
from ..train.data import TokenStream
from ..train.optimizer import AdamW
from ..train.trainer import Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = cfgs.get_reduced(args.arch) if args.reduced \
        else cfgs.get_config(args.arch)
    stream = TokenStream(
        cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
        embedding_dim=cfg.d_model if cfg.embedding_inputs else None)
    opt = AdamW(lr=args.lr)
    trainer = Trainer(cfg, opt, stream, args.ckpt_dir, accum=args.accum,
                      ckpt_every=args.ckpt_every)
    params, opt_state, hist = trainer.run(args.steps, seed=args.seed)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n/1e6:.1f}M "
          f"loss {hist[0]:.4f} -> {hist[-1]:.4f} over {len(hist)} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
