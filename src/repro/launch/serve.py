"""Serving launcher: batched prefill + decode loop (CPU-runnable demo) and
the probabilistic-DB query service (the paper's workload as a server).

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --reduced \
        --batch 4 --prompt-len 32 --gen 16

    PYTHONPATH=src python -m repro.launch.serve --db --scale 200 \
        --rounds 3 --sweep 64 --cache-capacity 16

The ``--db`` loop drives :class:`repro.db.serving.QueryService`: each
round submits every TPC-H serving plan (round 0 compiles cold, later
rounds are structural plan-cache hits — same executables, bit-identical
results), then a parameterized Q6 what-if sweep runs ``--sweep`` points
as ONE vmapped device program.  The loop prints per-round latency,
cached queries-per-second and the service's hit/miss/eviction counters.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import base as cfgs
from ..models import api


def generate(cfg, params, prompt, max_len: int, gen: int, greedy=True):
    """Prefill the prompt token-by-token into the cache, then decode."""
    b, t = prompt.shape[:2]
    dt = jnp.dtype(cfg.dtype)
    cache = api.init_cache(cfg, b, max_len, dtype=dt)
    step = jax.jit(lambda p, tok, c, l: api.decode_step(cfg, p, tok, c, l))
    cl = jnp.zeros((), jnp.int32)
    logits = None
    for i in range(t):
        logits, cache, cl = step(params, prompt[:, i:i + 1], cache, cl)
    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    for _ in range(gen):
        out.append(tok)
        logits, cache, cl = step(params, tok, cache, cl)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
    return jnp.concatenate(out, axis=1)


def serve_db(args) -> int:
    """The ``--db`` service loop: submit / cached-hit / evict over the
    TPC-H plan library, then the batched parameterized Q6 sweep."""
    from ..db import tpch
    from ..db.serving import QueryService

    db = tpch.generate(n_orders=args.scale)
    svc = QueryService(db.tables(), capacity=args.cache_capacity)
    plans = tpch.serving_plans()
    hit_seconds = 0.0
    hit_requests = 0
    for r in range(max(1, args.rounds)):
        t0 = time.time()
        hits = 0
        for name, plan in plans.items():
            out, info = svc.submit(plan)
            jax.block_until_ready(jax.tree.leaves(out))
            hits += int(info["hit"])
        dt = time.time() - t0
        if r > 0:                     # warm rounds measure serving QPS
            hit_seconds += dt
            hit_requests += len(plans)
        print(f"[serve-db] round {r}: {len(plans)} queries in {dt:.3f}s "
              f"({hits}/{len(plans)} cache hits)")
    if hit_requests:
        print(f"[serve-db] cached throughput: "
              f"{hit_requests / hit_seconds:.1f} queries/s")
    if args.sweep > 0:
        n = args.sweep
        batch = dict(disc_lo=jnp.full((n,), 5),
                     disc_hi=jnp.full((n,), 7),
                     qty_lim=jnp.arange(1, n + 1))
        t0 = time.time()
        out, info = svc.sweep(tpch.q6_family(), batch)
        jax.block_until_ready(jax.tree.leaves(out))
        print(f"[serve-db] batched q6 sweep: {n} points as "
              f"{info['launches']} device program(s) in "
              f"{time.time() - t0:.3f}s")
    print(f"[serve-db] stats: {svc.stats.as_dict()}")
    print(f"[serve-db] plan cache: {svc.cache.info()}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--db", action="store_true",
                    help="serve probabilistic TPC-H queries instead")
    ap.add_argument("--scale", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=3,
                    help="--db: request rounds over the plan library "
                         "(round 0 is cold, later rounds hit the cache)")
    ap.add_argument("--sweep", type=int, default=64,
                    help="--db: parameter points of the batched Q6 "
                         "what-if sweep (0 disables)")
    ap.add_argument("--cache-capacity", type=int, default=16,
                    help="--db: bounded plan-cache entries")
    args = ap.parse_args(argv)

    if args.db:
        return serve_db(args)

    cfg = cfgs.get_reduced(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    if cfg.embedding_inputs:
        prompt = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32)
    else:
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
    t0 = time.time()
    toks = generate(cfg, params, prompt,
                    args.prompt_len + args.gen + 1, args.gen)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
