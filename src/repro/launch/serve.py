"""Serving launcher: batched prefill + decode loop (CPU-runnable demo) and
the probabilistic-DB query service (the paper's workload as a server).

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --reduced \
        --batch 4 --prompt-len 32 --gen 16

    PYTHONPATH=src python -m repro.launch.serve --db --scale 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import base as cfgs
from ..models import api


def generate(cfg, params, prompt, max_len: int, gen: int, greedy=True):
    """Prefill the prompt token-by-token into the cache, then decode."""
    b, t = prompt.shape[:2]
    dt = jnp.dtype(cfg.dtype)
    cache = api.init_cache(cfg, b, max_len, dtype=dt)
    step = jax.jit(lambda p, tok, c, l: api.decode_step(cfg, p, tok, c, l))
    cl = jnp.zeros((), jnp.int32)
    logits = None
    for i in range(t):
        logits, cache, cl = step(params, prompt[:, i:i + 1], cache, cl)
    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    for _ in range(gen):
        out.append(tok)
        logits, cache, cl = step(params, tok, cache, cl)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--db", action="store_true",
                    help="serve probabilistic TPC-H queries instead")
    ap.add_argument("--scale", type=int, default=200)
    args = ap.parse_args(argv)

    if args.db:
        from ..db import tpch
        db = tpch.generate(n_orders=args.scale)
        t0 = time.time()
        for q in ("q1", "q6", "q18", "q20"):
            for mode in tpch.MODES:
                out = tpch.QUERIES[q](db, mode)
                jax.block_until_ready(jax.tree.leaves(out))
        print(f"[serve-db] 16 query/mode cells at scale {args.scale}: "
              f"{time.time() - t0:.2f}s")
        return 0

    cfg = cfgs.get_reduced(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    if cfg.embedding_inputs:
        prompt = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32)
    else:
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
    t0 = time.time()
    toks = generate(cfg, params, prompt,
                    args.prompt_len + args.gen + 1, args.gen)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
