import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
# the production meshes, record memory/cost/collective analysis.
#
# MUST be run as its own process (the two lines above run before any jax
# import — jax locks the device count on first init):
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
#
# Artifacts: one JSON per cell with
#   memory_analysis   bytes per device (args/outputs/temps/code)
#   cost_analysis     HLO flops / bytes accessed (per device)
#   collectives       per-op-kind operand bytes parsed from the HLO
#   roofline terms    compute/memory/collective seconds (v5e constants)
import argparse
import json
import re
import sys
import time
import traceback

# --- v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (conservative single-link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_RE = re.compile(
    r"=\s+\S+\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(([^)]*)\)")
DEF_RE = re.compile(r"(%?[\w.\-]+)\s+=\s+\(?([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from HLO text.

    Builds a name->bytes table from every instruction definition, then sums
    the operand sizes of each collective op (tuples/variadic included).
    `-done` ops are skipped (the `-start` carries the operands).
    """
    sizes: dict[str, int] = {}
    for m in DEF_RE.finditer(hlo_text):
        name, dtype, dims = m.groups()
        sizes[name.lstrip("%")] = _shape_bytes(dtype, dims)

    per_kind: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind, operands = m.groups()
        total = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            # operands may carry inline types: "bf16[2,4]{1,0} %name"
            name = op.split(" ")[-1].lstrip("%")
            if name in sizes:
                total += sizes[name]
            else:
                tm = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", op)
                if tm:
                    total += _shape_bytes(*tm.groups())
        per_kind[kind] = per_kind.get(kind, 0) + total
        count[kind] = count.get(kind, 0) + 1
    return {"bytes": per_kind, "count": count,
            "total_bytes": sum(per_kind.values())}


def analyze(lowered, compiled) -> dict:
    out: dict = {}
    try:
        ma = compiled.memory_analysis()
        out["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(ma, k)} if ma is not None else None
    except Exception as e:  # CPU backend may not implement it
        out["memory_analysis"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        out["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
    except Exception as e:
        out["cost_analysis"] = {"error": str(e)}
    out["collectives"] = parse_collective_bytes(compiled.as_text())
    return out


def roofline_terms(analysis: dict, chips: int) -> dict:
    ca = analysis.get("cost_analysis") or {}
    flops = ca.get("flops", 0.0)
    bytes_acc = ca.get("bytes accessed", 0.0)
    coll = analysis["collectives"]["total_bytes"]
    # cost_analysis is per-device for SPMD modules
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_collective = coll / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return dict(t_compute=t_compute, t_memory=t_memory,
                t_collective=t_collective, dominant=dominant,
                hlo_flops=flops, hlo_bytes=bytes_acc,
                collective_bytes=coll, chips=chips)


def _lower_costs(cell, mesh) -> dict:
    """Lower+compile one cell, return its cost vector."""
    import jax
    order = list(cell.args)
    donate = tuple(i for i, k in enumerate(order) if k in cell.donate)
    fn = jax.jit(lambda *a: cell.fn(**dict(zip(order, a))),
                 in_shardings=tuple(cell.in_shardings[k] for k in order),
                 donate_argnums=donate)
    with mesh:
        lowered = fn.lower(*[cell.args[k] for k in order])
        compiled = lowered.compile()
    out = analyze(lowered, compiled)
    ca = out.get("cost_analysis") or {}
    return dict(
        flops=ca.get("flops", 0.0),
        bytes=ca.get("bytes accessed", 0.0),
        coll=float(out["collectives"]["total_bytes"]),
        analysis=out)


def _affine(one_trip, two_trips, extra: float) -> dict:
    """cost(1 trip) + extra * per-trip-slope, component-wise (clamped at
    the one-trip floor: slope noise must not extrapolate below reality)."""
    return {n: max(one_trip[n] + extra * (two_trips[n] - one_trip[n]), 0.0)
            for n in ("flops", "bytes", "coll")}


def calibrate(arch: str, shape: str, mesh) -> dict:
    """Corrected per-device cost vector via unrolled calibration lowers.

    XLA cost_analysis counts while-loop bodies once; we lower small
    UNROLLED variants at full tensor widths and extrapolate the linear
    cost model  cost = outside + trips * body  (+ accum axis for train).
    """
    import dataclasses
    from repro.configs import base as cfgs
    from repro.launch import cells as C

    if arch == "pgf_tpch":
        from repro.configs import pgf_tpch
        qc = pgf_tpch.CONFIG
        shards = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                shards *= mesh.shape[a]
        block = 2048                      # uda._block_size cap at qc.num_freq
        u1 = _lower_costs(C.build_pgf_cell(mesh, n_tuples=shards * block,
                                           unroll=True), mesh)
        u2 = _lower_costs(C.build_pgf_cell(mesh, n_tuples=2 * shards * block,
                                           unroll=True), mesh)
        trips = qc.n_tuples / (shards * block)
        return _affine(u1, u2, trips - 1.0)

    cfg = cfgs.get_config(arch)
    base_pat, trips = C.calibration_pattern(cfg)
    knobs = C.arch_knobs(cfg)
    mk = lambda k: dataclasses.replace(
        cfg, n_layers=k * len(base_pat) + len(cfg.tail_pattern),
        pattern=base_pat)
    u11 = _lower_costs(C.build_lm_cell(arch, shape, mesh, cfg=mk(1),
                                       accum=1, unroll=True), mesh)
    u12 = _lower_costs(C.build_lm_cell(arch, shape, mesh, cfg=mk(2),
                                       accum=1, unroll=True), mesh)
    corrected = _affine(u11, u12, trips - 1.0)
    a = knobs["accum"]
    if cfgs.SHAPES[shape]["kind"] == "train" and a > 1:
        u21 = _lower_costs(C.build_lm_cell(arch, shape, mesh, cfg=mk(1),
                                           accum=2, unroll=True), mesh)
        u22 = _lower_costs(C.build_lm_cell(arch, shape, mesh, cfg=mk(2),
                                           accum=2, unroll=True), mesh)
        dA1 = {k: u21[k] - u11[k] for k in ("flops", "bytes", "coll")}
        a1 = {k: (u22[k] - u12[k]) - dA1[k] for k in dA1}
        for k in ("flops", "bytes", "coll"):
            corrected[k] += (a - 1) * dA1[k] + (a - 1) * (trips - 1) * a1[k]
    return corrected


def run_cell(arch: str, shape: str, multi_pod: bool,
             calibrated: bool = True) -> dict:
    import jax
    from repro.launch import cells as C
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = C.build_cell(arch, shape, mesh)
    full = _lower_costs(cell, mesh)
    t1 = time.time()
    result = dict(cell=cell.name,
                  mesh="2x16x16" if multi_pod else "16x16",
                  chips=512 if multi_pod else 256,
                  compile_seconds=round(t1 - t0, 1))
    result.update(full["analysis"])
    result["roofline_raw"] = roofline_terms(result, result["chips"])
    if calibrated:
        try:
            corr = calibrate(arch, shape, mesh)
            result["corrected"] = corr
            fake = dict(cost_analysis={"flops": corr["flops"],
                                       "bytes accessed": corr["bytes"]},
                        collectives={"total_bytes": corr["coll"]})
            result["roofline"] = roofline_terms(fake, result["chips"])
        except Exception as e:
            result["calibration_error"] = traceback.format_exc()
            result["roofline"] = result["roofline_raw"]
    else:
        result["roofline"] = result["roofline_raw"]
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="artifact directory")
    args = ap.parse_args(argv)

    from repro.launch import cells as C
    todo = C.all_cells() if args.all else [(args.arch, args.shape or "query")]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}/{shape}@{'2x16x16' if mp else '16x16'}"
            try:
                res = run_cell(arch, shape, mp)
                status = "OK"
            except Exception as e:
                failures += 1
                res = dict(cell=f"{arch}/{shape}", error=str(e),
                           traceback=traceback.format_exc())
                status = f"FAIL: {type(e).__name__}"
            line = f"[dryrun] {tag:56s} {status}"
            if "roofline" in res:
                r = res["roofline"]
                line += (f"  t_c={r['t_compute']:.3e}s t_m={r['t_memory']:.3e}s"
                         f" t_x={r['t_collective']:.3e}s dom={r['dominant']}")
            print(line, flush=True)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                fname = f"{arch}_{shape}_{'mp' if mp else 'sp'}.json"
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(res, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
