"""Dense MLP variants: SwiGLU, (non-gated) GELU, squared-ReLU, RWKV channel-mix."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L


def mlp_params(cfg, key, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {"w_in": L.dense_init(k1, d, f, dt),
                "w_gate": L.dense_init(k2, d, f, dt),
                "w_out": L.dense_init(k3, f, d, dt, scale=f ** -0.5)}
    if cfg.mlp in ("gelu", "relu2"):
        return {"w_in": L.dense_init(k1, d, f, dt),
                "w_out": L.dense_init(k3, f, d, dt, scale=f ** -0.5)}
    if cfg.mlp == "rwkv_channel":
        return {"w_in": L.dense_init(k1, d, f, dt),
                "w_out": L.dense_init(k3, f, d, dt, scale=f ** -0.5),
                "w_r": L.dense_init(k2, d, d, dt),
                "mu_k": jnp.ones((d,), dt) * 0.5,
                "mu_r": jnp.ones((d,), dt) * 0.5}
    raise ValueError(cfg.mlp)


def mlp(cfg, p, x, shifted=None):
    """x: (B, T, D).  `shifted` = token-shifted x (rwkv_channel only)."""
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    elif cfg.mlp == "gelu":
        h = jax.nn.gelu(x @ p["w_in"], approximate=True)
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_in"]))
    elif cfg.mlp == "rwkv_channel":
        xx = shifted - x
        xk = x + xx * p["mu_k"]
        xr = x + xx * p["mu_r"]
        h = jnp.square(jax.nn.relu(xk @ p["w_in"]))
        return L.constrain(
            (jax.nn.sigmoid(xr @ p["w_r"]) * (h @ p["w_out"])), "residual")
    else:
        raise ValueError(cfg.mlp)
    h = L.constrain(h, "ffn")
    return L.constrain(h @ p["w_out"], "residual")
