"""Cost-calibration run mode: unroll every lax.scan into a python loop.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip
count; the dry-run therefore lowers small UNROLLED variants (1 vs 2
periods, 1 vs 2 microbatches, ...) at full tensor widths and solves the
linear cost model to extrapolate exact per-cell FLOPs/bytes/collective
counts (launch/dryrun.py §calibration).  Production paths always use
lax.scan; this flag exists only for those calibration lowers.
"""
from __future__ import annotations

import contextlib
import contextvars

_UNROLL: contextvars.ContextVar = contextvars.ContextVar("unroll",
                                                         default=False)


@contextlib.contextmanager
def unrolled():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


def unroll_mode() -> bool:
    return _UNROLL.get()
