"""Unified model API over all assigned architectures.

    init_params(cfg, key)            parameter pytree (eval_shape-able)
    init_cache(cfg, batch, max_len)  decode state (KV / ring / recurrent)
    forward(cfg, params, tokens, ..) logits (+ cache, aux)
    loss_fn(cfg, params, batch)      token cross-entropy (+ MoE aux)
    prefill / decode_step            serving entry points

Layer stacking: the repeating pattern period is scanned with lax.scan
(stacked params, leading dim n_periods), with full per-period remat during
training — the compile-time and memory posture that survives 96-layer
configs.  Heterogeneous patterns (recurrentgemma's rglru/rglru/local) and
period-per-model patterns (its trailing 2 layers make the period the whole
stack) both fit this scheme.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .runmode import unroll_mode, unrolled  # re-export (dryrun calibration)

from . import layers as L
from . import moe as MOE
from . import rglru as RG
from . import rwkv6 as RW
from . import transformer as T
from .mlp import mlp, mlp_params


# ---------------------------------------------------------------- params
def _block_params(cfg, key, kind: str):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.norm_params(cfg, k1, cfg.d_model),
                         "norm2": L.norm_params(cfg, k2, cfg.d_model)}
    if kind in ("attn", "attn_local"):
        p["mixer"] = T.attn_params(cfg, k3, kind)
    elif kind == "rglru":
        p["mixer"] = RG.rglru_params(cfg, k3)
    elif kind == "rwkv6":
        p["mixer"] = RW.rwkv6_params(cfg, k3)
    else:
        raise ValueError(kind)
    p["ffn"] = MOE.moe_params(cfg, k4) if cfg.n_experts \
        else mlp_params(cfg, k4)
    return p


def init_params(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    nk = len(cfg.pattern) + len(cfg.tail_pattern) + 3
    keys = jax.random.split(key, nk)
    blocks = {}
    for j, kind in enumerate(cfg.pattern):
        per_period = jax.vmap(lambda k: _block_params(cfg, k, kind))(
            jax.random.split(keys[j], cfg.n_periods))
        blocks[str(j)] = per_period
    params: Dict[str, Any] = {"blocks": blocks,
                              "final_norm": L.norm_params(cfg, keys[-3],
                                                          cfg.d_model)}
    if cfg.tail_pattern:
        params["tail"] = {
            str(j): _block_params(cfg, keys[len(cfg.pattern) + j], kind)
            for j, kind in enumerate(cfg.tail_pattern)}
    if not cfg.embedding_inputs:
        params["embed"] = L.truncnorm(keys[-2], (cfg.vocab_size, cfg.d_model),
                                      dt, 1.0)
    if not cfg.tie_embeddings or cfg.embedding_inputs:
        params["head"] = L.dense_init(keys[-1], cfg.d_model, cfg.vocab_size,
                                      dt)
    return params


# ---------------------------------------------------------------- cache
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode state, stacked (n_periods, ...) per pattern position."""
    def stack(tree):
        return jax.tree.map(
            lambda l: jnp.zeros((cfg.n_periods,) + l.shape, l.dtype), tree)

    def one(kind):
        if kind in ("attn", "attn_local"):
            return T.init_attn_cache(cfg, kind, batch, max_len, dtype)
        if kind == "rglru":
            return RG.init_rglru_state(cfg, batch, dtype)
        if kind == "rwkv6":
            return RW.init_rwkv_state(cfg, batch, dtype)
        raise ValueError(kind)

    cache = {}
    for j, kind in enumerate(cfg.pattern):
        cache[str(j)] = stack(one(kind))
    if cfg.tail_pattern:
        cache["tail"] = {str(j): one(kind)
                         for j, kind in enumerate(cfg.tail_pattern)}
    return cache


# ---------------------------------------------------------------- blocks
def _apply_block(cfg, kind, p, x, positions, cache, cache_len):
    """One (mixer + ffn) block.  Returns (x, new_cache, aux)."""
    h = L.apply_norm(cfg, p["norm1"], x)
    if kind in ("attn", "attn_local"):
        mixed, new_cache = T.attention(cfg, p["mixer"], h, kind=kind,
                                       positions=positions, cache=cache,
                                       cache_len=cache_len)
    elif kind == "rglru":
        mixed, new_cache = RG.rglru(cfg, p["mixer"], h, state=cache)
    elif kind == "rwkv6":
        state = None if cache is None else dict(s=cache["s"],
                                                shift=cache["shift"])
        mixed, new_state = RW.rwkv6_timemix(cfg, p["mixer"], h, state=state)
        new_cache = None if cache is None else dict(new_state,
                                                    shift_c=cache["shift_c"])
    else:
        raise ValueError(kind)
    x = x + mixed

    h2 = L.apply_norm(cfg, p["norm2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        out, aux = MOE.moe(cfg, p["ffn"], h2)
    elif cfg.mlp == "rwkv_channel":
        if cache is None:
            shifted = jnp.concatenate(
                [jnp.zeros_like(h2[:, :1]), h2[:, :-1]], axis=1)
        else:
            shifted = cache["shift_c"][:, None].astype(h2.dtype)
            new_cache = dict(new_cache,
                             shift_c=h2[:, -1].astype(cache["shift_c"].dtype))
        out = mlp(cfg, p["ffn"], h2, shifted=shifted)
    else:
        out = mlp(cfg, p["ffn"], h2)
    x = L.constrain(x + out, "residual")
    # dummy caches must keep a stable pytree structure for lax.scan
    return x, new_cache, aux


def _period_body(cfg, remat: bool):
    """The scanned function over periods."""
    def body(carry, xs):
        x, cache_len, aux = carry
        bp, bc, positions = xs["params"], xs["cache"], xs["positions"]
        for j, kind in enumerate(cfg.pattern):
            cj = None if bc is None else bc[str(j)]
            x, ncj, a = _apply_block(cfg, kind, bp[str(j)], x, positions,
                                     cj, cache_len)
            if bc is not None:
                bc[str(j)] = ncj
            aux = aux + a
        return (x, cache_len, aux), bc

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    return body


def forward(cfg, params, tokens, *, cache=None, cache_len=None,
            remat: bool = False, return_cache: bool = False):
    """tokens: (B, S) int32 or (B, S, D) embeddings.

    Returns (logits, new_cache_or_None, aux_loss).
    """
    dt = jnp.dtype(cfg.dtype)
    if cfg.embedding_inputs:
        x = tokens.astype(dt)
    else:
        x = params["embed"][tokens]
    x = L.constrain(x, "residual")
    t = x.shape[1]
    if cache_len is None:
        positions = jnp.arange(t, dtype=jnp.int32)
    else:
        positions = cache_len + jnp.arange(t, dtype=jnp.int32)

    n = cfg.n_periods
    body = _period_body(cfg, remat)
    cl0 = jnp.zeros((), jnp.int32) if cache_len is None else cache_len
    carry0 = (x, cl0, jnp.zeros((), jnp.float32))
    body_cache = None if cache is None else \
        {k: v for k, v in cache.items() if k != "tail"}
    xs = {"params": params["blocks"], "cache": body_cache,
          "positions": jnp.broadcast_to(positions, (n, t))}

    if unroll_mode():
        carry = carry0
        collected = []
        for i in range(n):
            sl = jax.tree.map(lambda l: l[i], xs)
            carry, bc = body(carry, sl)
            collected.append(bc)
        (x, _, aux) = carry
        new_cache = (None if cache is None else
                     jax.tree.map(lambda *ls: jnp.stack(ls), *collected))
    else:
        (x, _, aux), new_cache = jax.lax.scan(body, carry0, xs)
        if cache is None:
            new_cache = None

    # trailing layers that don't complete a period (rgemma's final 2)
    if cfg.tail_pattern:
        new_tail = {}
        for j, kind in enumerate(cfg.tail_pattern):
            cj = None if cache is None else cache["tail"][str(j)]
            blk = _apply_block
            if remat:
                blk = jax.checkpoint(_apply_block,
                                     static_argnums=(0, 1), prevent_cse=False)
            x, ncj, a = blk(cfg, kind, params["tail"][str(j)], x,
                            positions, cj, cl0)
            new_tail[str(j)] = ncj
            aux = aux + a
        if new_cache is not None:
            new_cache = dict(new_cache, tail=new_tail)

    x = L.apply_norm(cfg, params["final_norm"], x)
    if "head" in params:
        logits = x @ params["head"]
    else:
        logits = x @ params["embed"].T
    logits = L.constrain(logits, "logits")
    return logits, new_cache, aux


# ------------------------------------------------------------------ loss
def loss_fn(cfg, params, tokens, labels, *, remat: bool = True):
    logits, _, aux = forward(cfg, params, tokens, remat=remat)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + 0.01 * aux, dict(ce=ce, aux=aux)


# --------------------------------------------------------------- serving
def prefill(cfg, params, tokens):
    """Forward pass producing logits; the per-layer K/V come out as the
    scan-collected cache for subsequent decode."""
    logits, cache, _ = forward(cfg, params, tokens, return_cache=False)
    return logits


def decode_step(cfg, params, tokens, cache, cache_len):
    """One-token decode against the cache.  tokens (B,1) or (B,1,D)."""
    logits, new_cache, _ = forward(cfg, params, tokens, cache=cache,
                                   cache_len=cache_len)
    return logits, new_cache, cache_len + 1
