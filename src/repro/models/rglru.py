"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = (W_x input proj + short causal depthwise conv + RG-LRU) gated by a
GeLU branch (W_y), then W_out.  The RG-LRU recurrence:

    r_t = sigmoid(u_t @ W_a)                  recurrence gate
    i_t = sigmoid(u_t @ W_ix)                 input gate
    a_t = exp(-c * softplus(Lambda) * r_t)    data-dependent decay (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training runs the linear recurrence as a parallel associative scan over the
sequence (the TPU-native replacement for the GPU linear-scan kernel);
decode is a single fused step on an O(width) state.  The short conv keeps a
(conv_width-1, W) tail as decode state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L

RGLRU_C = 8.0


def rglru_params(cfg, key):
    d = cfg.d_model
    w = cfg.rglru_width or d
    dt = jnp.dtype(cfg.dtype)
    kx, ky, ka, ki, ko, kc = jax.random.split(key, 6)
    return {
        "w_x": L.dense_init(kx, d, w, dt),
        "w_y": L.dense_init(ky, d, w, dt),
        "w_a": L.dense_init(ka, w, w, dt),
        "w_ix": L.dense_init(ki, w, w, dt),
        "w_rnn_out": L.dense_init(ko, w, d, dt, scale=w ** -0.5),
        "conv_w": L.truncnorm(kc, (cfg.conv_width, w), dt, 0.5),
        "conv_b": jnp.zeros((w,), dt),
        # Lambda init so that a = sigmoid(Lambda)^c is in ~[0.9, 0.999]
        "lam": jnp.linspace(2.0, 6.0, w).astype(jnp.float32),
    }


def _conv_causal(p, u, tail=None):
    """Depthwise causal conv, width cw.  tail: (B, cw-1, W) decode state."""
    cw = p["conv_w"].shape[0]
    if tail is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = tail.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)          # (B, T+cw-1, W)
    out = sum(ext[:, i:i + u.shape[1]] * p["conv_w"][i] for i in range(cw))
    new_tail = ext[:, -(cw - 1):] if cw > 1 else pad
    return out + p["conv_b"], new_tail


def _gates(p, u):
    r = jax.nn.sigmoid(u @ p["w_a"])
    i = jax.nn.sigmoid(u @ p["w_ix"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]).astype(jnp.float32) \
        * r.astype(jnp.float32)                       # (…, W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, gated


def rglru(cfg, p, x, state=None):
    """x: (B, T, D).  state: None (training) or dict(h=(B,W), conv=(B,cw-1,W)).

    Returns (out (B,T,D), new_state).
    """
    u = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_y"], approximate=True)
    conv_tail = None if state is None else state["conv"]
    u, new_tail = _conv_causal(p, u, conv_tail)
    a, b = _gates(p, u)                                # (B,T,W) f32

    if state is None:
        # parallel linear recurrence h_t = a_t h_{t-1} + b_t: chunked —
        # an associative scan over the full T keeps log2(T) full-size
        # (B, T, W) f32 intermediates live (the §Perf rgemma memory wall);
        # chunking runs the log-depth scan per 256-chunk and carries h
        # sequentially between chunks (recurrence flops are negligible,
        # liveness drops ~T/256-fold).
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        t = a.shape[1]
        ck = min(256, t)
        if t % ck:
            ck = t                       # odd lengths: single chunk
        nc = t // ck
        ac = a.reshape(a.shape[0], nc, ck, -1).transpose(1, 0, 2, 3)
        bc = b.reshape(b.shape[0], nc, ck, -1).transpose(1, 0, 2, 3)

        def chunk_body(h0, xs):
            aci, bci = xs
            # fold the carried state into the chunk's first step
            bci = bci.at[:, 0].add((aci[:, 0] * h0).astype(bci.dtype))
            aa, hh = jax.lax.associative_scan(combine, (aci, bci), axis=1)
            return hh[:, -1], hh

        from .runmode import unroll_mode
        if unroll_mode():
            hcur, outs = jnp.zeros_like(a[:, 0]), []
            for i in range(nc):
                hcur, hh = chunk_body(hcur, (ac[i], bc[i]))
                outs.append(hh)
            hs = jnp.stack(outs)
        else:
            _, hs = jax.lax.scan(chunk_body, jnp.zeros_like(a[:, 0]),
                                 (ac, bc))
        h = hs.transpose(1, 0, 2, 3).reshape(a.shape)
        new_state = None
    else:
        h0 = state["h"].astype(jnp.float32)            # (B, W)
        h = a[:, 0] * h0 + b[:, 0]
        h = h[:, None]
        new_state = dict(h=h[:, -1].astype(state["h"].dtype),
                         conv=new_tail.astype(state["conv"].dtype))
    h = L.constrain(h.astype(x.dtype), "ffn")
    out = (h * gate) @ p["w_rnn_out"]
    return L.constrain(out, "residual"), new_state


def init_rglru_state(cfg, batch: int, dtype):
    w = cfg.rglru_width or cfg.d_model
    return dict(h=jnp.zeros((batch, w), dtype),
                conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype))
