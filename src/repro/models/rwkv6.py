"""RWKV-6 "Finch" time-mix (arXiv:2404.05892): attention-free token mixing
with data-dependent per-channel decay.

Per head (dim K = V = head_dim), with r/k/v/g projections of the
token-shift-interpolated input and a LoRA-produced decay w_t:

    w_t = exp(-exp(w0 + tanh(x_w @ A) @ B))          in (0, 1)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t              (K, V) state
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)          u = per-channel bonus

Training uses a CHUNKED scan (DESIGN.md §2 hardware adaptation): within a
chunk of length Cw the recurrence unrolls into dense einsums (decay powers
via cumulative log-sums), and a lax.scan carries S between chunks — the
classic linear-attention chunk form that keeps the MXU busy instead of
stepping one token at a time.  Decode is the O(1) single-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L

LORA_R = 64
CHUNK = 64


def rwkv6_params(cfg, key):
    d = cfg.d_model
    h = cfg.rnn_heads
    hd = d // h
    dt = jnp.dtype(cfg.dtype)
    kr, kk, kv, kg, ko, ka, kb = jax.random.split(key, 7)
    return {
        "wr": L.dense_init(kr, d, d, dt),
        "wk": L.dense_init(kk, d, d, dt),
        "wv": L.dense_init(kv, d, d, dt),
        "wg": L.dense_init(kg, d, d, dt),
        "wo": L.dense_init(ko, d, d, dt, scale=d ** -0.5),
        "lora_a_w": L.dense_init(ka, d, LORA_R, dt),
        "lora_b_w": L.dense_init(kb, LORA_R, d, dt),
        "w0": jnp.full((d,), -5.0, jnp.float32),     # slow default decay
        "u": jnp.zeros((h, hd), jnp.float32),        # bonus
        "mu_r": jnp.full((d,), 0.5, dt),
        "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "mu_w": jnp.full((d,), 0.5, dt),
        "ln_g": jnp.ones((d,), jnp.float32),         # per-head group norm
    }


def _heads(x, h):
    b, t, d = x.shape
    return x.reshape(b, t, h, d // h)


def _chunked_wkv(r, k, v, w, u, s0):
    """Chunked WKV recurrence.

    r,k,v,w: (B, T, H, K) with w in (0,1) (decay), u: (H, K), s0: (B,H,K,V).
    Returns (y (B,T,H,V), sT).  T must be a multiple of CHUNK (caller pads).
    """
    b, t, h, dk = r.shape
    nc = t // CHUNK
    rc = r.reshape(b, nc, CHUNK, h, dk).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, nc, CHUNK, h, dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, CHUNK, h, dk).transpose(1, 0, 2, 3, 4)
    wc = w.reshape(b, nc, CHUNK, h, dk).transpose(1, 0, 2, 3, 4)

    def body(s, xs):
        rr, kk, vv, ww = xs                       # (B, C, H, K)
        logw = jnp.log(jnp.maximum(ww, 1e-12))
        cum = jnp.cumsum(logw, axis=1)            # log prod_{<=i} w
        cum_excl = cum - logw                     # log prod_{<i}  w
        # clamp the *cumulative* decay: a channel that decays below e^-30
        # inside one chunk has washed out; clamping keeps the factored
        # exp(+/-cum) terms inside f32 range (documented approximation).
        cum = jnp.maximum(cum, -30.0)
        cum_excl = jnp.maximum(cum_excl, -30.0)
        # inter-chunk: y_i += r_i diag(prod_{<i} w) S
        ri = rr * jnp.exp(cum_excl)               # (B,C,H,K)
        y = jnp.einsum("bihk,bhkv->bihv", ri, s)
        # intra-chunk (j < i): A[i,j] = sum_k ri[k] * (k_j exp(-cum_j))[k]
        kj = kk * jnp.exp(-cum)                   # (B,C,H,K)
        att = jnp.einsum("bihk,bjhk->bhij", ri, kj)
        mask = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        # bonus diagonal: y_i += (r_i . u . k_i) v_i
        diag = jnp.einsum("bihk,hk,bihk->bih", rr, u, kk)
        y = y + jnp.einsum("bhij,bjhv->bihv", att, vv) \
            + diag[..., None] * vv
        # state: S' = diag(prod w) S + sum_j diag(prod_{>j} w) k_j v_j
        k_dec = kk * jnp.exp(cum[:, -1:] - cum)
        s_new = jnp.exp(cum[:, -1])[..., None] * s \
            + jnp.einsum("bjhk,bjhv->bhkv", k_dec, vv)
        return s_new, y

    from .runmode import unroll_mode
    if unroll_mode():
        s, outs = s0, []
        for i in range(nc):
            s, yi = body(s, (rc[i], kc[i], vc[i], wc[i]))
            outs.append(yi)
        sT, ys = s, jnp.stack(outs)
    else:
        sT, ys = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, -1)
    return y, sT


def rwkv6_timemix(cfg, p, x, state=None):
    """x: (B, T, D).  state: None (training) or dict(s=(B,H,K,V),
    shift=(B,D)) for decode.  Returns (out, new_state)."""
    b, t, d = x.shape
    h = cfg.rnn_heads
    if state is None:
        prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
        s0 = jnp.zeros((b, h, d // h, d // h), jnp.float32)
    else:
        prev = state["shift"][:, None].astype(x.dtype)
        s0 = state["s"].astype(jnp.float32)
    xx = prev - x
    xr, xk, xv, xg = (x + xx * p[m] for m in ("mu_r", "mu_k", "mu_v", "mu_g"))
    xw = x + xx * p["mu_w"]

    r = _heads(xr @ p["wr"], h).astype(jnp.float32)
    k = _heads(xk @ p["wk"], h).astype(jnp.float32)
    v = _heads(xv @ p["wv"], h).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    logit = p["w0"] + (jnp.tanh(xw @ p["lora_a_w"]) @ p["lora_b_w"]) \
        .astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logit))                      # (B,T,D) in (0,1)
    w = _heads(w, h)

    if state is None:
        pad = (-t) % CHUNK
        if pad:
            zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
            # pad with w=1 (no decay), k=0 (no writes), r=0 (no reads)
            r_, k_, v_ = zp(r), zp(k), zp(v)
            w_ = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                         constant_values=1.0)
        else:
            r_, k_, v_, w_ = r, k, v, w
        y, sT = _chunked_wkv(r_, k_, v_, w_, p["u"], s0)
        y = y[:, :t]
        new_state = None
    else:
        # O(1) decode step (t == 1)
        r1, k1, v1, w1 = r[:, 0], k[:, 0], v[:, 0], w[:, 0]
        y1 = jnp.einsum("bhk,bhkv->bhv", r1,
                        s0 + p["u"][None, :, :, None] *
                        jnp.einsum("bhk,bhv->bhkv", k1, v1))
        sT = w1[..., None] * s0 + jnp.einsum("bhk,bhv->bhkv", k1, v1)
        y = y1[:, None]
        new_state = dict(s=sT.astype(state["s"].dtype),
                         shift=x[:, -1].astype(state["shift"].dtype))

    # per-head group norm then output gate (back in the residual dtype)
    y = L.rms_norm(y.reshape(b, t, h, -1),
                   p["ln_g"].reshape(h, -1)).reshape(b, t, d)
    out = (y.astype(x.dtype) * g) @ p["wo"]
    return L.constrain(out, "residual"), new_state


def init_rwkv_state(cfg, batch: int, dtype):
    d = cfg.d_model
    h = cfg.rnn_heads
    return dict(s=jnp.zeros((batch, h, d // h, d // h), jnp.float32),
                shift=jnp.zeros((batch, d), dtype),
                shift_c=jnp.zeros((batch, d), dtype))
