"""Assigned-architecture model stack (exercises the distributed runtime).

    layers.py       norms, RoPE, init, sharding hooks
    transformer.py  GQA attention: causal/bidir/local, KV cache, streaming
    mlp.py          swiglu / gelu / relu^2 / rwkv channel-mix
    moe.py          top-k expert routing with static capacity (EP)
    rglru.py        RecurrentGemma RG-LRU recurrent block
    rwkv6.py        RWKV-6 chunked WKV time-mix
    api.py          init/forward/loss/prefill/decode over any ModelConfig
    frontends.py    [vlm]/[audio] embedding stubs
"""
from . import api, frontends, layers, mlp, moe, rglru, rwkv6, transformer

__all__ = ["api", "frontends", "layers", "mlp", "moe", "rglru", "rwkv6",
           "transformer"]
