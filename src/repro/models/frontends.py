"""Modality frontends for [vlm]/[audio] archs — STUBS per the assignment.

The assigned internvl2 (ViT patch frontend) and hubert (waveform CNN
frontend) cells specify the transformer BACKBONE only; `input_specs()`
delivers precomputed patch/frame embeddings of shape (B, S, d_model)
(`ModelConfig.embedding_inputs = True`), and the backbone consumes them
directly (models.api.forward skips the token embedding).

For runnable smoke tests/examples, `fake_embeddings` below synthesises
deterministic embeddings with the right statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fake_embeddings(key, batch: int, seq: int, d_model: int,
                    dtype=jnp.float32):
    """Unit-variance stand-in for frontend outputs."""
    return jax.random.normal(key, (batch, seq, d_model), dtype)


def fake_frame_labels(key, batch: int, seq: int, vocab: int):
    return jax.random.randint(key, (batch, seq), 0, vocab)
