"""Attention sublayer: GQA/MQA/MHA, causal/bidirectional/local, RoPE,
KV-cache prefill/decode, online-softmax KV-chunk streaming.

One implementation covers all seven attention-bearing assigned archs:
  * GQA with any kv<=heads (yi 4, nemotron/internvl 8, granite/rgemma MQA 1)
  * full causal, bidirectional (hubert), sliding-window (recurrentgemma)
  * partial rotary (nemotron/chatglm 0.5, hubert 0)
  * decode against a ring-buffered (local) or linear (global) KV cache

The softmax streams over KV chunks with a running (max, denom, acc) carry —
the TPU-native fixed-VMEM attention pattern (flash-style); the (Tq, Tk)
score matrix never materialises beyond (Tq, chunk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L

NEG_INF = -1e30


def attn_params(cfg, key, kind: str):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": L.dense_init(kq, d, cfg.n_heads * hd, dt),
        "wk": L.dense_init(kk, d, cfg.n_kv_heads * hd, dt),
        "wv": L.dense_init(kv, d, cfg.n_kv_heads * hd, dt),
        "wo": L.dense_init(ko, cfg.n_heads * hd, d, dt,
                           scale=(cfg.n_heads * hd) ** -0.5),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    return p


def _project_qkv(cfg, p, x):
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    return q, k, v


def _sdpa_streamed(q, k, v, q_pos, kv_pos, *, causal: bool, window: int,
                   chunk: int = 1024):
    """Online-softmax attention.

    q: (B, Tq, H, hd); k/v: (B, Tk, KV, hd); q_pos (Tq,), kv_pos (Tk,)
    absolute positions (int32; kv_pos < 0 marks an invalid cache slot).
    Returns (B, Tq, H, hd) in q.dtype; accumulation in f32.
    """
    from .. import sharding
    from jax.sharding import PartitionSpec as P

    b, tq, h, hd = q.shape
    tk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    # Keep QK/PV einsum INPUTS in the residual dtype (bf16 on TPU) with
    # f32 accumulation via preferred_element_type — halves score-tensor
    # traffic and avoids f32 copies of the KV cache (§Perf universal).
    qf = (q * jnp.asarray(scale, q.dtype)).reshape(b, tq, kv, g, hd)
    # Divisibility-aware head sharding: kv heads over `model` when they
    # divide it (internvl/nemotron-class), else shard the query time dim
    # (context parallelism; decode tq=1 falls through to replicated —
    # the S-sharded cache carries the parallelism there).
    dp = sharding.current_dp()
    qf = sharding.constrain_first_fit(qf, [
        P(dp, None, "model", None, None),
        P(dp, "model", None, None, None),
    ])

    if tq == 1:
        # decode: single-shot attention over the (possibly S-sharded)
        # cache; GSPMD turns the contraction over S into local partials
        # + one small all-reduce.
        s = jnp.einsum("btkgh,bckh->btkgc", qf, k.astype(qf.dtype),
                       preferred_element_type=jnp.float32)
        ok = kv_pos[None, :] >= 0
        if causal:
            ok = ok & (kv_pos[None, :] <= q_pos[:, None])
        if window:
            ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("btkgc,bckh->btkgh", p.astype(v.dtype),
                         v, preferred_element_type=jnp.float32) \
            / jnp.maximum(p.sum(-1)[..., None], 1e-30)
        return out.reshape(b, tq, h, hd).astype(q.dtype)

    nchunks = max(1, (tk + chunk - 1) // chunk)
    csize = (tk + nchunks - 1) // nchunks
    pad = nchunks * csize - tk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pp = jnp.pad(kv_pos, (0, pad), constant_values=-1)
    kc = kp.reshape(b, nchunks, csize, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nchunks, csize, kv, hd).transpose(1, 0, 2, 3, 4)
    pc = pp.reshape(nchunks, csize)

    def body(carry, xs):
        m, l, acc = carry
        kch, vch, pch = xs                      # (B,C,KV,hd), (C,)
        s = jnp.einsum("btkgh,bckh->btkgc", qf, kch.astype(qf.dtype),
                       preferred_element_type=jnp.float32)
        ok = pch[None, :] >= 0                  # (1, C) valid slot
        if causal:
            ok = ok & (pch[None, :] <= q_pos[:, None])
        if window:
            ok = ok & (pch[None, :] > q_pos[:, None] - window)
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "btkgc,bckh->btkgh", pexp.astype(vch.dtype), vch,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, tq, kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, tq, kv, g), jnp.float32)
    a0 = jnp.zeros((b, tq, kv, g, hd), jnp.float32)
    from .runmode import unroll_mode
    if unroll_mode():
        carry = (m0, l0, a0)
        for i in range(nchunks):
            carry, _ = body(carry, (kc[i], vc[i], pc[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, tq, h, hd).astype(q.dtype)


def attention(cfg, p, x, *, kind: str, positions, cache=None,
              cache_len=None):
    """The full attention sublayer (projections + RoPE + SDPA + out proj).

    positions: (T,) absolute positions of x's tokens.
    cache: None (training/prefill without cache) or dict(k=(B,S,KV,hd),
    v=...) to decode against; cache_len = number of valid entries.
    Returns (out, new_cache).
    """
    window = cfg.window if kind == "attn_local" else 0
    causal = cfg.causal
    q, k, v = _project_qkv(cfg, p, x)
    tables = L.rope_tables(positions, cfg.resolved_head_dim, cfg.rotary_pct,
                           cfg.rope_theta)
    q = L.apply_rope(q, tables)
    k = L.apply_rope(k, tables)

    if cache is None:
        # TP-divisibility: when kv doesn't divide the model axis, replicate
        # kv heads to the smallest kv*r that does (and still divides H) —
        # numerically identical GQA, but the head dim then shards cleanly
        # instead of triggering involuntary SPMD rematerialisation
        # (§Perf: internvl prefill collective fix).  Transient only; the
        # decode path keeps the compact cache (S-sharded there).
        from .. import sharding as SH
        rules = SH.current_rules()
        if rules is not None and "model" in rules.mesh.axis_names:
            m = rules.mesh.shape["model"]
            kv_n, h_n = cfg.n_kv_heads, cfg.n_heads
            if kv_n % m and h_n % m == 0:
                for r in range(2, h_n // kv_n + 1):
                    if (kv_n * r) % m == 0 and h_n % (kv_n * r) == 0:
                        k = jnp.repeat(k, r, axis=2)
                        v = jnp.repeat(v, r, axis=2)
                        break
        kv_pos = positions
        out = _sdpa_streamed(q, k, v, positions, kv_pos, causal=causal,
                             window=window)
        new_cache = dict(k=k, v=v)
    else:
        s = cache["k"].shape[1]
        # write the new entries at cache_len (ring for local windows)
        write_at = (cache_len % s if window else cache_len).astype(jnp.int32)
        zero = jnp.zeros((), jnp.int32)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype),
            (zero, write_at, zero, zero))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype),
            (zero, write_at, zero, zero))
        slots = jnp.arange(s, dtype=jnp.int32)
        if window:
            # slot i holds the largest pos <= cache_len with pos % s == i
            delta = (cache_len - slots) % s
            kv_pos = cache_len - delta
        else:
            kv_pos = jnp.where(slots <= cache_len, slots, -1)
        q_pos = positions
        out = _sdpa_streamed(q, ck, cv, q_pos, kv_pos, causal=causal,
                             window=window)
        new_cache = dict(k=ck, v=cv)

    b, t = x.shape[:2]
    out = out.reshape(b, t, -1) @ p["wo"]
    return L.constrain(out, "residual"), new_cache


def init_attn_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    s = min(cfg.window, max_len) if kind == "attn_local" else max_len
    return dict(
        k=jnp.zeros((batch, s, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((batch, s, cfg.n_kv_heads, hd), dtype),
    )
