"""Mixture-of-Experts MLP: top-k routing with static capacity, EP-sharded.

Covers both assigned MoE shapes:
  * olmoe-1b-7b:  64 experts, top-8, no shared expert
  * llama4-scout: 16 experts, top-1, + always-on shared expert

TPU mapping: tokens are scattered into a static (E, C, D) dispatch buffer
(sharded over the `model` axis = expert parallelism; the scatter lowers to
an all-to-all under GSPMD), two grouped einsums run the expert FFNs on the
MXU, and results gather back weighted by router probabilities.  Overflowing
tokens beyond capacity C = ceil(T*top_k/E * cf) are dropped (their combine
weight is 0) — the classic capacity-factor contract; the router's aux load
balancing keeps drops rare at cf=1.25.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .mlp import mlp, mlp_params


def moe_params(cfg, key):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    dt = jnp.dtype(cfg.dtype)
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(kr, d, e, jnp.float32),
        "experts_in": L.truncnorm(k1, (e, d, f), dt, d ** -0.5),
        "experts_gate": L.truncnorm(k2, (e, d, f), dt, d ** -0.5),
        "experts_out": L.truncnorm(k3, (e, f, d), dt, f ** -0.5),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(cfg, ks, cfg.d_ff * cfg.n_shared_experts)
    return p


def capacity(cfg, tokens: int) -> int:
    c = int(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe(cfg, p, x):
    """x: (B, T, D) -> (B, T, D); returns (out, aux_loss)."""
    b, t, d = x.shape
    n_tok = b * t
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, n_tok)
    xf = x.reshape(n_tok, d)

    logits = (xf.astype(jnp.float32) @ p["router"])           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                       # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (n_tok * k)
    aux = e * jnp.sum(me * ce)

    # Assignment -> capacity-slot mapping, entirely on SMALL integer
    # arrays (O(kT log kT)), then data movement as two GATHERS:
    #   dispatch: buf[e, s] = x[token_of_slot[e, s]]
    #   combine:  y[t] = sum_r out_buf_flat[slot_of[t, r]] * gate[t, r]
    # Gathers partition cleanly under GSPMD (operand all-gather, local
    # gather); the scatter formulation replicated (kT, D) f32 update
    # tensors on every device — the §Perf baseline memory wall.
    idx_flat = idx.T.reshape(-1)                              # (k*T,) slot-major
    order = jnp.argsort(idx_flat, stable=True)                # expert-major
    rank_in_sorted = jnp.argsort(order, stable=True)          # inverse perm
    counts = jnp.zeros((e,), jnp.int32).at[idx_flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_flat = rank_in_sorted - starts[idx_flat]
    keep = pos_flat < c
    pos_flat = jnp.where(keep, pos_flat, 0)

    # slot grid: which token feeds (expert e, slot s); sentinel = n_tok
    tok_of = jnp.tile(jnp.arange(n_tok), k)
    sorted_tok = tok_of[order]                                # (kT,)
    slot_src = starts[:, None] + jnp.arange(c)[None, :]       # (E, C)
    slot_valid = (jnp.arange(c)[None, :] < counts[:, None]) \
        & (slot_src < k * n_tok)
    token_of_slot = jnp.where(
        slot_valid, sorted_tok[jnp.clip(slot_src, 0, k * n_tok - 1)], n_tok)

    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), x.dtype)])
    buf = xf_pad[token_of_slot]                               # (E, C, D)
    buf = L.constrain(buf, "moe_buffer")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["experts_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["experts_in"])
    h = L.constrain(h, "moe_hidden")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["experts_out"])
    out_buf = L.constrain(out_buf, "moe_buffer")

    # combine: per-token gather of its k expert outputs
    slot_of = (idx * c + pos_flat.reshape(k, n_tok).T)        # (T, k)
    picked = out_buf.reshape(e * c, d)[slot_of]               # (T, k, D)
    w = (gate * keep.reshape(k, n_tok).T).astype(jnp.float32)
    yf = jnp.einsum("tkd,tk->td", picked.astype(jnp.float32), w)

    if cfg.n_shared_experts:
        yf = yf + mlp(cfg, p["shared"], xf[None]).astype(jnp.float32)[0]
    out = yf.reshape(b, t, d).astype(x.dtype)
    return L.constrain(out, "residual"), aux
