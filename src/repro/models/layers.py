"""Shared building blocks: norms, RoPE, initialisers, sharding hooks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding


def truncnorm(key, shape, dtype, scale: float):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return truncnorm(key, (d_in, d_out), dtype, scale)


# ---------------------------------------------------------------- norms
def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def norm_params(cfg, key, d: int):
    if cfg.norm == "layer":
        return {"gamma": jnp.ones((d,), jnp.float32),
                "beta": jnp.zeros((d,), jnp.float32)}
    return {"gamma": jnp.ones((d,), jnp.float32)}


def apply_norm(cfg, p, x):
    if cfg.norm == "layer":
        return layer_norm(x, p["gamma"], p["beta"])
    return rms_norm(x, p["gamma"])


# ---------------------------------------------------------------- RoPE
def rope_tables(positions, head_dim: int, rotary_pct: float, theta: float,
                dtype=jnp.float32):
    """cos/sin tables for the rotated fraction of head_dim.

    positions: (T,) int array (absolute).  Returns (T, rot/2) each, or None
    when rotary_pct == 0 (e.g. hubert's conv-positional stub).
    """
    rot = int(head_dim * rotary_pct)
    rot -= rot % 2
    if rot == 0:
        return None
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, tables):
    """x: (B, T, H, hd); tables from rope_tables (T-aligned).

    Rotates the first `rot` dims pairwise (interleaved convention), passes
    the rest through — covers full (pct=1), half/'2d' (pct=0.5), none.
    """
    if tables is None:
        return x
    cos, sin = tables
    rot = 2 * cos.shape[-1]
    xr, xp = x[..., :rot], x[..., rot:]
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


def constrain(x, spec_name: str):
    """Apply the active mesh's activation sharding rule (no-op if none)."""
    return sharding.constrain(x, spec_name)
