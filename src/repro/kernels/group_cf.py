"""Pallas TPU kernel: (G, F)-tiled grouped log-characteristic-function
accumulation — the grouped exact-CF hot path.

The scalar kernel (:mod:`repro.kernels.pb_cf`) accumulates ONE summed log CF
over all tuples; grouped exact SUM/COUNT (paper §VI row VI with the §V-A
exact PGF) needs G of them, one per group:

    log_abs[g, k] = sum_{i : gid_i = g} 0.5*log|q_i + p_i w^{k a_i}|^2
    angle[g, k]   = sum_{i : gid_i = g} arg (q_i + p_i w^{k a_i})

with w = exp(2 pi i / N).  A per-group loop over the scalar kernel would
re-stream the tuple column G times; this kernel streams it once per group
*block* and scatters each tuple's contribution to its group row in-register.

TPU mapping
-----------
grid = (G_blocks, F_blocks, T_blocks); the tuple axis is the innermost
reduction axis so each (GB, FB) output tile stays resident in VMEM while
tuple blocks stream through.  Per grid step the kernel materialises one
(FB, TB) phase/log-abs/angle tile (identical math to pb_cf.py) and scatters
it to the (GB, FB) accumulators with an in-kernel segment mask:

    M[r, t]   = 1 if gid_t == gi*GB + r else 0          (GB, TB)
    acc[r, f] += sum_t M[r, t] * tile[f, t]             one MXU matmul

i.e. the scatter is a (GB, TB) x (TB, FB) matmul contracting the tuple
axis — exact (M is 0/1) and MXU-shaped, so the scatter costs 2*GB flops per
(tuple, frequency) pair on top of the ~46 VPU flops of the phase tile.

Tuples are pre-sorted by group id in the wrapper, and each tuple block's
[min gid, max gid] range rides along in SMEM: a (gi, ti) step whose group
rows don't intersect the block's range skips all vector work, so with
sorted inputs each tuple block is materialised O(1) times instead of
G_blocks times and total work stays ~n*F, not ~n*F*G/GB.

VMEM budget (defaults gb=8, fb=256, tb=512, f32):
    phase/log-abs/angle tiles  3 x (FB, TB) x 4B  = 1.5 MB
    segment mask               (GB, TB) x 4B      = 16 KB
    accumulators               2 x (GB, FB) x 4B  = 16 KB
well inside the ~16 MB v5e VMEM with double-buffering headroom.  All lane
dims are multiples of 128; GB is a multiple of the f32 sublane (8).

Frequency slabs: ``freq_lo``/``freq_cnt`` select a [freq_lo, freq_lo+cnt)
slice of the N-point DFT grid so callers can chunk the (G, F) state against
a memory budget (the planner's multi-pass slab path: each slab is one
kernel launch + one additive merge, see db/plans.py).  Phase exactness uses
the same split-modmult as pb_cf.py: k = k_hi*2^S + k_lo with
a2 = (a << S) mod N needs k_lo*a < 2^(S + b) and k_hi*a2 < 2^(2b - S)
(b = bit length of N-1) both below 2^31, which S = b//2 + 1 satisfies
exactly for N <= 2^20 — ``pb_cf.split_modmult_operands`` (shared with the
scalar kernel) asserts that bound and the ops.py / uda.py dispatch guards
route larger grids to the pure-JAX path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import pb_cf


def _group_logcf_kernel(gmin_ref, gmax_ref, p_ref, a_ref, a2_ref, g_ref,
                        la_ref, an_ref, *, num_freq: int, freq_lo: int,
                        shift: int, gb: int, fb: int, tb: int):
    gi = pl.program_id(0)
    fi = pl.program_id(1)
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        la_ref[...] = jnp.zeros_like(la_ref)
        an_ref[...] = jnp.zeros_like(an_ref)

    # Skip the whole tile when this tuple block (sorted by gid) cannot touch
    # any of this block's group rows [gi*gb, gi*gb + gb).
    row_lo = gi * gb
    hit = (gmin_ref[0, 0] < row_lo + gb) & (gmax_ref[0, 0] >= row_lo)

    @pl.when(hit)
    def _acc():
        n = num_freq
        # Global frequency index for every lane of this tile: (FB, 1).
        k = freq_lo + fi * fb + jax.lax.broadcasted_iota(jnp.int32, (fb, 1), 0)
        k = jnp.minimum(k, n - 1)       # freq padding: extra lanes discarded
        k_hi = k >> shift
        k_lo = k & ((1 << shift) - 1)

        a = a_ref[...]                  # (1, TB) int32, already mod N
        a2 = a2_ref[...]                # (1, TB) int32, (a << shift) mod N
        p = p_ref[...]                  # (1, TB)

        # (FB, TB) exact phase: ((k_hi*a2) mod N + (k_lo*a) mod N) mod N
        phase = ((k_hi * a2) % n + (k_lo * a) % n) % n
        theta = phase.astype(p.dtype) * (2.0 * math.pi / n)

        q = 1.0 - p
        re = q + p * jnp.cos(theta)     # (FB, TB)
        im = p * jnp.sin(theta)
        tiny = jnp.asarray(1e-30 if p.dtype == jnp.float32 else 1e-300,
                           p.dtype)
        la = 0.5 * jnp.log(jnp.maximum(re * re + im * im, tiny))
        an = jnp.arctan2(im, re)

        # Segment-mask scatter: rows (GB, 1) vs gids (1, TB) -> (GB, TB)
        # 0/1 mask; one MXU matmul contracts the tuple axis into (GB, FB).
        rows = row_lo + jax.lax.broadcasted_iota(jnp.int32, (gb, 1), 0)
        m = (g_ref[...] == rows).astype(p.dtype)
        dims = (((1,), (1,)), ((), ()))
        la_ref[...] += jax.lax.dot_general(m, la, dims,
                                           preferred_element_type=p.dtype)
        an_ref[...] += jax.lax.dot_general(m, an, dims,
                                           preferred_element_type=p.dtype)


def presort_operands(probs: jnp.ndarray, values: jnp.ndarray,
                     gids: jnp.ndarray, num_freq: int):
    """The argsort(gids) + split-modmult operand prep of
    :func:`group_logcf`, hoisted so callers can run it ONCE and reuse it
    across frequency slabs (the prep depends only on (values, gids,
    num_freq), never on the slab window; each slab is a separately
    dispatched step, so nothing else de-duplicates the sort).

    Returns ``(p_sorted, a, a2, g_sorted)`` — pass as ``operands=`` to
    :func:`group_logcf` (directly or through ``kernels.ops.group_logcf``).
    """
    order = jnp.argsort(jnp.asarray(gids))
    a, a2, _ = pb_cf.split_modmult_operands(jnp.asarray(values)[order],
                                            num_freq)
    return (probs[order], a, a2,
            jnp.asarray(gids)[order].astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=(
    "num_groups", "num_freq", "freq_lo", "freq_cnt", "gb", "fb", "tb",
    "interpret"))
def group_logcf(probs: jnp.ndarray, values: jnp.ndarray, gids: jnp.ndarray,
                *, num_groups: int, num_freq: int, freq_lo: int = 0,
                freq_cnt: int | None = None, gb: int = 8, fb: int = 256,
                tb: int = 512, interpret: bool | None = None,
                operands=None):
    """(G, F)-tiled Pallas grouped log-CF accumulation.

    probs:  (n,) float tuple probabilities (p = 0 rows contribute nothing).
    values: (n,) integer tuple values (any int dtype; reduced mod num_freq).
    gids:   (n,) int group ids in [0, num_groups).
    operands: optional pre-sorted columns from :func:`presort_operands`;
    when given, probs/values/gids are ignored and the per-call sort +
    operand prep is skipped (the frequency-slab hoist).
    Returns (log_abs, angle), each (num_groups, freq_cnt) float, matching
    :func:`repro.kernels.ref.group_logcf_ref` — frequencies
    [freq_lo, freq_lo + freq_cnt) of the num_freq-point DFT grid.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = num_freq
    f = n - freq_lo if freq_cnt is None else freq_cnt
    assert 0 <= freq_lo and freq_lo + f <= n
    dtype = probs.dtype

    nt = probs.shape[0]
    ntp = pl.cdiv(nt, tb) * tb
    if operands is None:
        # Sort tuples by group id so each block spans a narrow group range
        # and the kernel's block-range skip prunes non-intersecting
        # (gi, ti) steps.
        operands = presort_operands(probs, values, gids, n)
    p, a, a2, g = operands
    shift = pb_cf.phase_shift(n)
    # p = 0 padding contributes log(1) = 0 to both outputs (any group row).
    p = jnp.pad(p, (0, ntp - nt))
    g = jnp.pad(g, (0, ntp - nt),
                constant_values=max(0, num_groups - 1))
    a = jnp.pad(a, (0, ntp - nt))
    a2 = jnp.pad(a2, (0, ntp - nt))

    gblocks = g.reshape(-1, tb)
    gmin = gblocks.min(axis=1).reshape(1, -1)      # (1, T_blocks) for SMEM
    gmax = gblocks.max(axis=1).reshape(1, -1)

    ngp = pl.cdiv(num_groups, gb) * gb
    nfp = pl.cdiv(f, fb) * fb
    grid = (ngp // gb, nfp // fb, ntp // tb)

    smem = dict(memory_space=pltpu.SMEM) if not interpret else {}
    la, an = pl.pallas_call(
        functools.partial(_group_logcf_kernel, num_freq=n, freq_lo=freq_lo,
                          shift=shift, gb=gb, fb=fb, tb=tb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda g, f, t: (0, t), **smem),
            pl.BlockSpec((1, 1), lambda g, f, t: (0, t), **smem),
            pl.BlockSpec((1, tb), lambda g, f, t: (0, t)),
            pl.BlockSpec((1, tb), lambda g, f, t: (0, t)),
            pl.BlockSpec((1, tb), lambda g, f, t: (0, t)),
            pl.BlockSpec((1, tb), lambda g, f, t: (0, t)),
        ],
        out_specs=[
            pl.BlockSpec((gb, fb), lambda g, f, t: (g, f)),
            pl.BlockSpec((gb, fb), lambda g, f, t: (g, f)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ngp, nfp), dtype),
            jax.ShapeDtypeStruct((ngp, nfp), dtype),
        ],
        interpret=interpret,
    )(gmin, gmax, p.reshape(1, -1), a.reshape(1, -1), a2.reshape(1, -1),
      g.reshape(1, -1))
    return la[:num_groups, :f], an[:num_groups, :f]
