"""Pallas TPU kernels for the PGF engine's compute hot spots.

    pb_cf.py       blocked log-CF accumulation (exact COUNT/SUM, one group)
    group_cf.py    (G, F)-tiled grouped log-CF accumulation with in-kernel
                   segment-mask scatter (grouped exact SUM/COUNT)
    polymul.py     blocked schoolbook polynomial multiply (small-degree path)
    cumulants.py   fused one-pass cumulant accumulation (moment method)
    ops.py         jit'd public wrappers with size/dtype dispatch
    ref.py         pure-jnp oracles (tests assert_allclose kernel vs ref)

All kernels use pl.pallas_call with explicit BlockSpec VMEM tiling and are
validated on CPU with interpret=True; lane dims are 128-multiples for the
TPU target.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
