"""Pallas TPU kernel: blocked log-characteristic-function accumulation.

This is the hot loop of the exact COUNT/SUM path (DESIGN.md §2): for the
Poisson-binomial product  Q(X) = prod_i (q_i + p_i X^{a_i})  we accumulate

    log_abs[k] = sum_i 0.5*log|q_i + p_i w^{k a_i}|^2
    angle[k]   = sum_i arg (q_i + p_i w^{k a_i}),     w = exp(2 pi i / N)

over all tuples i for every DFT frequency k < N.  The paper's FFTW product
tree becomes this additive accumulation + one FFT at Finalize.

TPU mapping
-----------
grid = (F_blocks, T_blocks); the tuple axis is the (fast, innermost)
reduction axis so each (1, FB) output block stays resident in VMEM while all
tuple blocks stream through.  Per grid step the kernel materialises a
(FB, TB) phase tile — FB=256, TB=1024 f32 ~ 1 MB per intermediate, inside
the ~16 MB v5e VMEM budget with headroom for cos/sin/log tiles.  All lane
dims are multiples of 128.

Phase precision: theta = 2*pi*((k*a) mod N)/N must be exact; k*a overflows
f32 (and int32 for large N), so the wrapper splits k = k_hi*2^S + k_lo and
supplies a2 = (a << S) mod N.  Then

    (k*a) mod N = ((k_hi * a2) mod N + (k_lo * a) mod N) mod N

with k_lo*a < 2^(S + b) and k_hi*a2 < 2^(2b - S) (b = bit length of N-1)
both below 2^31 — S = b//2 + 1 satisfies that exactly for N <= 2^20, the
bound the ops.py dispatch guard enforces.  Integer-exact on the VPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logcf_kernel(p_ref, a_ref, a2_ref, la_ref, an_ref, *,
                  num_freq: int, shift: int, fb: int, tb: int):
    fi = pl.program_id(0)
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        la_ref[...] = jnp.zeros_like(la_ref)
        an_ref[...] = jnp.zeros_like(an_ref)

    n = num_freq
    # Global frequency index for every lane of this output block: (FB, 1).
    k = fi * fb + jax.lax.broadcasted_iota(jnp.int32, (fb, 1), 0)
    k = jnp.minimum(k, n - 1)              # freq padding: recomputed lanes are discarded
    k_hi = k >> shift
    k_lo = k & ((1 << shift) - 1)

    a = a_ref[...]                         # (1, TB) int32, already mod N
    a2 = a2_ref[...]                       # (1, TB) int32, (a << shift) mod N
    p = p_ref[...]                         # (1, TB)

    # (FB, TB) exact phase: ((k_hi*a2) mod N + (k_lo*a) mod N) mod N
    phase = ((k_hi * a2) % n + (k_lo * a) % n) % n
    theta = phase.astype(p.dtype) * (2.0 * math.pi / n)

    q = 1.0 - p
    re = q + p * jnp.cos(theta)            # (FB, TB)
    im = p * jnp.sin(theta)
    tiny = jnp.asarray(1e-30 if p.dtype == jnp.float32 else 1e-300, p.dtype)
    la = 0.5 * jnp.log(jnp.maximum(re * re + im * im, tiny))
    an = jnp.arctan2(im, re)

    la_ref[...] += la.sum(axis=1)[None, :]
    an_ref[...] += an.sum(axis=1)[None, :]


def phase_shift(num_freq: int) -> int:
    """The static split-modmult shift S for an N-point grid (k = k_hi*2^S +
    k_lo; see module docstring) — shared so callers holding precomputed
    operands recover the same S without re-running the prep."""
    return max(1, (num_freq - 1).bit_length() // 2 + 1)


def split_modmult_operands(values: jnp.ndarray, num_freq: int):
    """Exact int32 phase operands shared by the CF kernels (this module and
    :mod:`repro.kernels.group_cf`): reduce ``values`` mod N in the SOURCE
    integer dtype (a 64-bit value truncated to int32 first would wrap mod
    2^32, changing the residue for non-power-of-two N), narrow to int32,
    and precompute a2 = (a << shift) mod N by repeated doubling —
    int32-overflow-free for any N <= 2^30 (each intermediate < 2N <= 2^31).
    Returns (a, a2, shift), asserting the N <= 2^20 split-modmult
    exactness bound (see module docstring); zero-padding a/a2 afterwards
    is safe (phase 0, and p = 0 pad rows contribute log(1) = 0 anyway).
    """
    n = num_freq
    # int32 split-modmult exactness bound (see module docstring).
    assert n <= 1 << 20, f"num_freq {n} > 2^20 overflows the exact phase"
    shift = phase_shift(n)
    v = jnp.asarray(values)
    if jnp.issubdtype(v.dtype, jnp.integer) or v.dtype == jnp.bool_:
        v = v % n
    a = v.astype(jnp.int32) % n
    a2 = a
    for _ in range(shift):
        a2 = (a2 * 2) % n
    return a, a2, shift


@functools.partial(jax.jit, static_argnames=("num_freq", "fb", "tb", "interpret"))
def logcf(probs: jnp.ndarray, values: jnp.ndarray, *, num_freq: int,
          fb: int = 256, tb: int = 1024, interpret: bool | None = None):
    """Blocked Pallas log-CF accumulation.

    probs:  (n,) float tuple probabilities.
    values: (n,) integer tuple values (any int dtype; reduced mod num_freq).
    Returns (log_abs, angle), each (num_freq,) float, matching
    :func:`repro.kernels.ref.logcf_ref`.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = num_freq
    dtype = probs.dtype
    a, a2, shift = split_modmult_operands(values, n)

    nt = probs.shape[0]
    ntp = pl.cdiv(nt, tb) * tb
    # p = 0 padding contributes log(1) = 0 to both outputs.
    p = jnp.pad(probs, (0, ntp - nt))
    a = jnp.pad(a, (0, ntp - nt))
    a2 = jnp.pad(a2, (0, ntp - nt))

    nfp = pl.cdiv(n, fb) * fb
    grid = (nfp // fb, ntp // tb)

    la, an = pl.pallas_call(
        functools.partial(_logcf_kernel, num_freq=n, shift=shift, fb=fb, tb=tb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tb), lambda f, t: (0, t)),
            pl.BlockSpec((1, tb), lambda f, t: (0, t)),
            pl.BlockSpec((1, tb), lambda f, t: (0, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, fb), lambda f, t: (0, f)),
            pl.BlockSpec((1, fb), lambda f, t: (0, f)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, nfp), dtype),
            jax.ShapeDtypeStruct((1, nfp), dtype),
        ],
        interpret=interpret,
    )(p.reshape(1, -1), a.reshape(1, -1), a2.reshape(1, -1))
    return la[0, :n], an[0, :n]
