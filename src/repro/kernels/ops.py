"""Public jit'd wrappers for the Pallas kernels, with shape dispatch.

These are the entry points the engine uses; each transparently falls back to
the pure-jnp oracle when a kernel is a bad fit (tiny inputs where padding
dominates, or f64 mode where the TPU kernels don't apply).  The kernels
themselves live in pb_cf.py / polymul.py / cumulants.py; oracles in ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import cumulants as _cum
from . import pb_cf as _cf
from . import polymul as _pm
from . import ref

# Below these sizes, block padding exceeds useful work — use the oracle.
MIN_KERNEL_TUPLES = 256
MIN_KERNEL_DEGREE = 128


def logcf(probs: jnp.ndarray, values: jnp.ndarray, num_freq: int,
          use_kernel: bool | None = None):
    """Summed log CF at num_freq DFT frequencies. Kernel or oracle."""
    if use_kernel is None:
        use_kernel = (probs.shape[0] >= MIN_KERNEL_TUPLES
                      and probs.dtype == jnp.float32)
    if use_kernel:
        return _cf.logcf(probs, values, num_freq=num_freq)
    return ref.logcf_ref(probs, values, num_freq)


def polymul(a: jnp.ndarray, b: jnp.ndarray,
            use_kernel: bool | None = None) -> jnp.ndarray:
    """Linear convolution of coefficient vectors. Kernel or oracle."""
    if use_kernel is None:
        use_kernel = (min(a.shape[0], b.shape[0]) >= MIN_KERNEL_DEGREE
                      and a.dtype == jnp.float32)
    if use_kernel:
        return _pm.polymul(a, b)
    return ref.polymul_ref(a, b)


def cumulant_sums(probs: jnp.ndarray, values: jnp.ndarray, orders: int = 8,
                  use_kernel: bool | None = None) -> jnp.ndarray:
    """Fused one-pass cumulant partial sums. Kernel or oracle."""
    if use_kernel is None:
        use_kernel = (probs.shape[0] >= MIN_KERNEL_TUPLES
                      and probs.dtype == jnp.float32)
    if use_kernel:
        return _cum.cumulant_sums(probs, values, orders=orders)
    return ref.cumulants_ref(probs, values, orders)
