"""Public jit'd wrappers for the Pallas kernels, with shape dispatch.

These are the entry points the engine uses; each transparently falls back to
the pure-jnp oracle when a kernel is a bad fit (tiny inputs where padding
dominates, or f64 mode where the TPU kernels don't apply).  The kernels
themselves live in pb_cf.py / polymul.py / cumulants.py; oracles in ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import cumulants as _cum
from . import group_cf as _gcf
from . import pb_cf as _cf
from . import polymul as _pm
from . import ref

# Below these sizes, block padding exceeds useful work — use the oracle.
MIN_KERNEL_TUPLES = 256
MIN_KERNEL_DEGREE = 128
# Above this frequency-grid size the CF kernels' int32 split-modmult phase
# would overflow (exact only for num_freq <= 2^20) — use the oracle.
MAX_KERNEL_FREQ = 1 << 20


def logcf(probs: jnp.ndarray, values: jnp.ndarray, num_freq: int,
          use_kernel: bool | None = None):
    """Summed log CF at num_freq DFT frequencies. Kernel or oracle."""
    if use_kernel is None:
        use_kernel = (probs.shape[0] >= MIN_KERNEL_TUPLES
                      and probs.dtype == jnp.float32
                      and num_freq <= MAX_KERNEL_FREQ)
    if use_kernel:
        return _cf.logcf(probs, values, num_freq=num_freq)
    return ref.logcf_ref(probs, values, num_freq)


def presort_group_operands(probs: jnp.ndarray, values: jnp.ndarray,
                           gids: jnp.ndarray, num_freq: int):
    """Pre-sorted grouped-CF kernel operands (argsort(gids) + split-modmult
    prep) to reuse across frequency slabs — see
    :func:`repro.kernels.group_cf.presort_operands`."""
    return _gcf.presort_operands(probs, values, gids, num_freq)


def group_logcf(probs: jnp.ndarray, values: jnp.ndarray, gids: jnp.ndarray,
                num_groups: int, num_freq: int, *, freq_lo: int = 0,
                freq_cnt: int | None = None, use_kernel: bool | None = None,
                operands=None):
    """Per-group summed log CF -> (G, F) log_abs/angle. Kernel or oracle.

    The kernel truncates values to int32 for its exact integer-phase
    arithmetic, so the auto guard additionally requires an integer-typed
    values array; callers that know their float column is integral (e.g.
    the UDA layer, which tracks source dtypes) pass ``use_kernel=True``.
    ``operands`` (from :func:`presort_group_operands`) skip the kernel's
    per-call sort/prep; the oracle path ignores them.
    """
    if use_kernel is None:
        use_kernel = (probs.shape[0] >= MIN_KERNEL_TUPLES
                      and probs.dtype == jnp.float32
                      and num_freq <= MAX_KERNEL_FREQ
                      and jnp.issubdtype(values.dtype, jnp.integer))
    if use_kernel:
        return _gcf.group_logcf(probs, values, gids, num_groups=num_groups,
                                num_freq=num_freq, freq_lo=freq_lo,
                                freq_cnt=freq_cnt, operands=operands)
    return ref.group_logcf_ref(probs, values, gids, num_groups, num_freq,
                               freq_lo, freq_cnt)


def polymul(a: jnp.ndarray, b: jnp.ndarray,
            use_kernel: bool | None = None) -> jnp.ndarray:
    """Linear convolution of coefficient vectors. Kernel or oracle."""
    if use_kernel is None:
        use_kernel = (min(a.shape[0], b.shape[0]) >= MIN_KERNEL_DEGREE
                      and a.dtype == jnp.float32)
    if use_kernel:
        return _pm.polymul(a, b)
    return ref.polymul_ref(a, b)


def cumulant_sums(probs: jnp.ndarray, values: jnp.ndarray, orders: int = 8,
                  use_kernel: bool | None = None) -> jnp.ndarray:
    """Fused one-pass cumulant partial sums. Kernel or oracle."""
    if use_kernel is None:
        use_kernel = (probs.shape[0] >= MIN_KERNEL_TUPLES
                      and probs.dtype == jnp.float32)
    if use_kernel:
        return _cum.cumulant_sums(probs, values, orders=orders)
    return ref.cumulants_ref(probs, values, orders)
