"""Pure-jnp oracles for the Pallas kernels.

Each function computes exactly what the corresponding kernel computes, with
no blocking, padding or VMEM concerns.  Tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle; the oracles themselves are validated
against the possible-worlds enumeration in ``tests/test_aggregates.py``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def logcf_ref(probs: jnp.ndarray, values: jnp.ndarray, num_freq: int):
    """Summed log characteristic function of sum_i a_i * Bernoulli(p_i).

    Returns (log_abs, angle), each (num_freq,):
        log Q(w^k) = sum_i log( (1-p_i) + p_i * w^{k a_i} ),  w = e^{2 pi i/N}.

    The angle is the sum of per-factor principal arguments (NOT the argument
    of the product) — branch offsets are multiples of 2*pi*i and cancel at
    exp() time, and per-factor angles are what a blocked accumulator can
    compute, so the kernel contract is defined this way.
    """
    dtype = probs.dtype
    n = num_freq
    k = jnp.arange(n, dtype=dtype)
    # phase[k, i] = (k * a_i) mod N, computed in f64-exactness range
    phase = (k[:, None] * values[None, :]) % n
    theta = (2.0 * np.pi / n) * phase
    q = 1.0 - probs
    re = q[None, :] + probs[None, :] * jnp.cos(theta)
    im = probs[None, :] * jnp.sin(theta)
    log_abs = 0.5 * jnp.log(jnp.maximum(re * re + im * im, 1e-300))
    ang = jnp.arctan2(im, re)
    return log_abs.sum(-1), ang.sum(-1)


def polymul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Full linear convolution c[k] = sum_{i+j=k} a[i] b[j] (schoolbook)."""
    return jnp.convolve(a, b)


def cumulants_ref(probs: jnp.ndarray, values: jnp.ndarray,
                  orders: int = 8) -> jnp.ndarray:
    """Partial cumulant sums s_j = sum_i v_i^j kappa_j(p_i), j = 1..orders.

    kappa_j(p) follows the paper's recursion kappa_{j+1} = p(1-p) dk_j/dp.
    Computed unblocked, directly from the polynomial table — independent of
    the repro.core.uda accumulation (which may itself dispatch to the kernel
    under test)."""
    from repro.core.approx import MAX_ORDER, _bernoulli_cumulant_polys
    dtype = probs.dtype
    table = jnp.asarray(_bernoulli_cumulant_polys()[1:orders + 1], dtype)
    powers = probs[None, :] ** jnp.arange(MAX_ORDER + 1, dtype=dtype)[:, None]
    kappas = table @ powers                      # (orders, n)
    vpow = values[None, :] ** jnp.arange(1, orders + 1, dtype=dtype)[:, None]
    return jnp.sum(kappas * vpow, axis=-1)       # (orders,)


def atleastone_ref(probs: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int) -> jnp.ndarray:
    """Per-group 1 - prod(1 - p) (paper Table I row V), as a direct product
    — independent of the log-domain accumulation in repro.core.uda."""
    import jax
    q = jax.ops.segment_prod(1.0 - probs, segment_ids,
                             num_segments=num_segments)
    return 1.0 - q
