"""Pure-jnp oracles for the Pallas kernels.

Each function computes exactly what the corresponding kernel computes, with
no blocking, padding or VMEM concerns.  Tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle; the oracles themselves are validated
against the possible-worlds enumeration in ``tests/test_aggregates.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _cf_terms(probs, values, k, num_freq):
    """Per-(frequency, tuple) log-abs and angle of (1-p) + p w^{k v} on a
    broadcastable (k, values, probs) grid — the one copy of the CF term
    math both oracles below anchor their kernels to.

    The phase (k*v) mod N runs at f64 exactness independent of the probs
    dtype (integer values are pre-reduced mod N, so under x64 the product
    stays below 2^53 for any N the kernels accept); only the trig epilogue
    drops to the probs dtype, mirroring the kernels' f32 theta."""
    dtype = probs.dtype
    if jnp.issubdtype(values.dtype, jnp.integer) \
            or values.dtype == jnp.bool_:
        values = values % num_freq
    ph_dtype = jnp.float64 if jax.config.jax_enable_x64 else dtype
    phase = (k.astype(ph_dtype) * values.astype(ph_dtype)) % num_freq
    theta = ((2.0 * np.pi / num_freq) * phase).astype(dtype)
    q = 1.0 - probs
    re = q + probs * jnp.cos(theta)
    im = probs * jnp.sin(theta)
    tiny = 1e-30 if dtype == jnp.float32 else 1e-300
    la = 0.5 * jnp.log(jnp.maximum(re * re + im * im, tiny))
    return la, jnp.arctan2(im, re)


def logcf_ref(probs: jnp.ndarray, values: jnp.ndarray, num_freq: int):
    """Summed log characteristic function of sum_i a_i * Bernoulli(p_i).

    Returns (log_abs, angle), each (num_freq,):
        log Q(w^k) = sum_i log( (1-p_i) + p_i * w^{k a_i} ),  w = e^{2 pi i/N}.

    The angle is the sum of per-factor principal arguments (NOT the argument
    of the product) — branch offsets are multiples of 2*pi*i and cancel at
    exp() time, and per-factor angles are what a blocked accumulator can
    compute, so the kernel contract is defined this way.
    """
    dtype = probs.dtype
    # phase = (k * a_i) mod N, computed in f64-exactness range
    k = jnp.arange(num_freq, dtype=dtype)
    la, an = _cf_terms(probs[None, :], values[None, :], k[:, None], num_freq)
    return la.sum(-1), an.sum(-1)


def group_logcf_ref(probs: jnp.ndarray, values: jnp.ndarray,
                    gids: jnp.ndarray, num_groups: int, num_freq: int,
                    freq_lo: int = 0, freq_cnt: int | None = None):
    """Grouped summed log CF: per-group log Q_g(w^k) over the tuples of each
    group (the group_cf.py kernel contract).

    Returns (log_abs, angle), each (num_groups, freq_cnt), for frequencies
    [freq_lo, freq_lo + freq_cnt) of the num_freq-point DFT grid.  Computed
    unblocked with a segment-sum scatter — independent of the blocked
    repro.core.uda accumulation and of the Pallas kernel under test.
    """
    dtype = probs.dtype
    f = num_freq - freq_lo if freq_cnt is None else freq_cnt
    k = freq_lo + jnp.arange(f, dtype=dtype)
    la, an = _cf_terms(probs[:, None], jnp.asarray(values)[:, None],
                       k[None, :], num_freq)              # (n_tuples, f)
    seg = jnp.asarray(gids)
    return (jax.ops.segment_sum(la, seg, num_segments=num_groups),
            jax.ops.segment_sum(an, seg, num_segments=num_groups))


def polymul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Full linear convolution c[k] = sum_{i+j=k} a[i] b[j] (schoolbook)."""
    return jnp.convolve(a, b)


def cumulants_ref(probs: jnp.ndarray, values: jnp.ndarray,
                  orders: int = 8) -> jnp.ndarray:
    """Partial cumulant sums s_j = sum_i v_i^j kappa_j(p_i), j = 1..orders.

    kappa_j(p) follows the paper's recursion kappa_{j+1} = p(1-p) dk_j/dp.
    Computed unblocked, directly from the polynomial table — independent of
    the repro.core.uda accumulation (which may itself dispatch to the kernel
    under test)."""
    from repro.core.approx import MAX_ORDER, _bernoulli_cumulant_polys
    dtype = probs.dtype
    table = jnp.asarray(_bernoulli_cumulant_polys()[1:orders + 1], dtype)
    powers = probs[None, :] ** jnp.arange(MAX_ORDER + 1, dtype=dtype)[:, None]
    kappas = table @ powers                      # (orders, n)
    vpow = values[None, :] ** jnp.arange(1, orders + 1, dtype=dtype)[:, None]
    return jnp.sum(kappas * vpow, axis=-1)       # (orders,)


def atleastone_ref(probs: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int) -> jnp.ndarray:
    """Per-group 1 - prod(1 - p) (paper Table I row V), as a direct product
    — independent of the log-domain accumulation in repro.core.uda."""
    import jax
    q = jax.ops.segment_prod(1.0 - probs, segment_ids,
                             num_segments=num_segments)
    return 1.0 - q
