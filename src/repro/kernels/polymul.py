"""Pallas TPU kernel: blocked schoolbook polynomial multiplication.

The paper (§VII-B) dispatches small-degree PGF products to the classical
O(n^2) algorithm because FFT overhead dominates below ~5000 coefficients.
On TPU the same regime exists (FFT lowers to many small kernels; a blocked
convolution is one fused VPU loop), so we keep the dispatch and implement
the O(n^2) path as a Pallas kernel.

TPU mapping
-----------
c = a * b (linear convolution).  a is padded to A = ceil(na/B)*B, the output
to C = ceil((na+nb-1)/B)*B, and b is embedded into b_pad of length A + C
with A leading zeros, so every window the kernel touches is in range.

grid = (C/B, A/B): output block `o` accumulates over a-blocks `ia`.  For
block pair (o, ia) the contribution is

    c[o*B + t] += sum_u a[ia*B + u] * b[(o - ia - 1)*B + (B + t - u)]

i.e. a size-B dot between the a-block and a sliding window of the
*two adjacent* b blocks (o-ia-1, o-ia) — both fetched via aligned
BlockSpecs, the shift happens in VMEM.  All blocks are (1, B) with B a
multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _polymul_kernel(a_ref, b1_ref, b2_ref, c_ref, *, bsize: int):
    ia = pl.program_id(1)

    @pl.when(ia == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    a = a_ref[...]                                   # (1, B)
    bwin = jnp.concatenate([b1_ref[...], b2_ref[...]], axis=1)  # (1, 2B)

    def body(u, acc):
        # bwin[B + t - u] for t in [0, B): slice of length B starting B - u.
        window = jax.lax.dynamic_slice(bwin, (0, bsize - u), (1, bsize))
        coef = jax.lax.dynamic_slice(a, (0, u), (1, 1))
        return acc + coef * window

    acc = jax.lax.fori_loop(0, bsize, body,
                            jnp.zeros((1, bsize), a.dtype))
    c_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bsize", "interpret"))
def polymul(a: jnp.ndarray, b: jnp.ndarray, *, bsize: int = 128,
            interpret: bool | None = None) -> jnp.ndarray:
    """Blocked schoolbook linear convolution; matches jnp.convolve(a, b)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    na, nb = a.shape[0], b.shape[0]
    nc = na + nb - 1
    A = pl.cdiv(na, bsize) * bsize
    C = pl.cdiv(nc, bsize) * bsize
    a_p = jnp.pad(a, (0, A - na)).reshape(1, -1)
    # A leading zeros so window index (o - ia - 1 + A/B) is always >= 0.
    b_p = jnp.pad(b, (A, C - nb)).reshape(1, -1)
    nA = A // bsize

    c = pl.pallas_call(
        functools.partial(_polymul_kernel, bsize=bsize),
        grid=(C // bsize, nA),
        in_specs=[
            pl.BlockSpec((1, bsize), lambda o, ia: (0, ia)),
            pl.BlockSpec((1, bsize), lambda o, ia: (0, o - ia - 1 + nA)),
            pl.BlockSpec((1, bsize), lambda o, ia: (0, o - ia + nA)),
        ],
        out_specs=pl.BlockSpec((1, bsize), lambda o, ia: (0, o)),
        out_shape=jax.ShapeDtypeStruct((1, C), a.dtype),
        interpret=interpret,
    )(a_p, b_p, b_p)
    return c[0, :nc]
