"""The query-serving layer: bounded structural plan cache + batched
parameterized execution — the front end that turns the engine from a
script into a server.

A production deployment answers MANY queries against one catalogue, and
the raw compiler is the wrong interface for that twice over:

* every ``compile_plan`` call re-traces from scratch, even for a plan
  structurally identical to one compiled a moment ago (logical nodes
  carry lambdas, which hash by identity); and
* the CPU jaxlib backend SEGFAULTS once a single process accretes a few
  hundred live compiled executables — the failure PR 7 documented, which
  the test suite masks by clearing jit caches at module boundaries.  A
  long-lived server cannot use that workaround; it must BOUND its
  executable population instead.

:class:`PlanCache` solves both: compiled executables are cached under a
STRUCTURAL key — :func:`repro.db.plans.plan_key` (node structure +
predicate bytecode + captured constants) + mesh identity + every
lowering parameter — in a bounded LRU whose evictions drop the evicted
executables' compiled code (``jit.clear_cache``).  A cache hit returns
the SAME executable object, so hit results are BIT-IDENTICAL to the cold
compile by construction; distinct plans past the capacity recycle slots
instead of accreting.

:class:`QueryService` is the request loop over one catalogue: submit a
logical plan (optionally with a :class:`~repro.db.plans.RetryPolicy` —
the self-healing controller compiles each attempt through the cache, and
the service REMEMBERS the converged ``final_params`` per plan so later
identical submits start at the healed point and hit the cache in one
clean attempt), or sweep a PARAMETERIZED plan family over N parameter
points: the plan's :class:`~repro.db.plans.Param` holes become traced
arguments, one executable is compiled for the family, and the whole
sweep runs as ONE device program — a 64-point what-if sweep costs one
compile instead of 64 (``benchmarks/smoke.py`` gates the speedup).

Two batching modes.  The default, ``sweep_mode='scan'``, lowers the
sweep as ``jax.lax.map`` — each point executes the IDENTICAL unbatched
graph inside one device loop, so per-point slices are bit-equal to N
sequential runs of the family's jitted executable (the engine's
determinism contract extended to the batch).  ``sweep_mode='vmap'``
vectorises across points instead; XLA fuses batched shapes differently
(FMA/reassociation differs per batch size on CPU), so vmap trades the
bit-equality guarantee for lane-parallel throughput — results still
match sequential runs to ~1 ULP.  Both modes pass the catalogue as an
executable ARGUMENT, never a closure: constant-folding baked-in table
columns changes fusion rounding, which is exactly the bug class this
layer exists to keep out of cached paths.  See docs/serving.md.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from . import cost as C
from . import physical as phys
from .plans import (LRUCache, Node, Scan, compile_plan, mesh_fingerprint,
                    plan_key, plan_params, run_plan)
from .report import ServingStats
from .table import Table


def cache_key(root: Node, mesh=None, jit: bool = True,
              opts: dict | None = None) -> tuple:
    """The plan cache's full key: plan structure + mesh identity + jit
    wrapping + every lowering option (frozen structurally, so option
    values like a CostModel dataclass key by content)."""
    frozen = tuple(sorted((k, phys.structural_key(v))
                          for k, v in (opts or {}).items()))
    return ("serve", plan_key(root), mesh_fingerprint(mesh), bool(jit),
            frozen)


def _scan_names(root: Node) -> tuple:
    """Base tables a logical plan reads (for sweep residency sizing)."""
    names: set = set()

    def walk(n):
        if isinstance(n, Scan):
            names.add(n.name)
        for f in ("child", "left", "right"):
            c = getattr(n, f, None)
            if isinstance(c, Node):
                walk(c)

    walk(root)
    return tuple(sorted(names))


class _Entry:
    """One cached plan: the raw compiled closure, the submit-path
    callable (jit-wrapped unless the plan streams), and the lazily built
    batched sweep executable (tables are an argument, so one executable
    serves any catalogue of the same shapes)."""
    __slots__ = ("fn", "call", "batched", "batched_mode", "__weakref__")

    def __init__(self, fn, call):
        self.fn = fn
        self.call = call
        self.batched = None
        self.batched_mode = None


class PlanCache:
    """Bounded LRU of compiled plan executables, keyed structurally.

    A hit returns the same executable object — results bit-identical to
    the cold compile by construction.  Evictions call ``clear_cache`` on
    the evicted jit wrappers so the process's live-executable count
    stays flat (the accretion-segfault guard a long-lived server needs).
    """

    def __init__(self, capacity: int = 16):
        self._lru = LRUCache(capacity, on_evict=self._drop)

    @staticmethod
    def _drop(entry: _Entry) -> None:
        for f in (entry.call, entry.batched):
            clear = getattr(f, "clear_cache", None)
            if clear is not None:
                clear()

    def entry(self, root: Node, mesh=None, jit: bool = True,
              **opts) -> tuple:
        """-> (cache entry, was it a hit).  ``jit=True`` wraps the
        compiled function in ``jax.jit`` (illegal for streamed plans —
        the wave loop runs on host; callers gate on
        ``device_row_budget``)."""
        key = cache_key(root, mesh, jit, opts)
        e = self._lru.get(key)
        if e is not None:
            return e, True
        fn = compile_plan(root, mesh, **opts)
        e = _Entry(fn, jax.jit(fn) if jit else fn)
        self._lru.put(key, e)
        return e, False

    def compile(self, root: Node, mesh=None, jit: bool = False, **opts):
        """:func:`repro.db.plans.run_plan`-compatible compiler hook: the
        cached executable for THIS attempt's exact (plan, lowering
        params).  Each escalation attempt keys its own entry, so retries
        never poison or duplicate the base entry, and a later submit at
        the converged ``final_params`` hits the final attempt's entry."""
        return self.entry(root, mesh, jit=jit, **opts)[0].call

    @property
    def hits(self) -> int:
        return self._lru.hits

    @property
    def misses(self) -> int:
        return self._lru.misses

    def info(self) -> dict:
        return self._lru.info()

    def clear(self) -> None:
        self._lru.clear()


class QueryService:
    """The serving loop over one catalogue (name -> Table) and one mesh.

    ``capacity`` bounds the plan cache; ``jit=True`` (default) serves
    resident submits through ``jax.jit`` (streamed plans — any submit
    with a ``device_row_budget`` — always run eagerly); ``policy`` is
    the default self-healing :class:`~repro.db.plans.RetryPolicy`
    (None = no retry loop); ``batch_row_budget`` caps a sweep's batched
    peak rows, splitting it into chunked launches
    (:func:`repro.db.cost.sweep_chunk_points`); ``sweep_mode`` is
    ``'scan'`` (bit-exact, default) or ``'vmap'`` (lane-parallel, ~1 ULP
    — see the module docstring).  Remaining keywords become default
    ``compile_plan`` options for every request.
    """

    def __init__(self, tables: Dict[str, Table], mesh=None, *,
                 capacity: int = 16, jit: bool = True, policy=None,
                 batch_row_budget: int | None = None,
                 sweep_mode: str = "scan", **default_opts):
        if sweep_mode not in ("scan", "vmap"):
            raise ValueError(f"sweep_mode must be 'scan' or 'vmap', "
                             f"got {sweep_mode!r}")
        self.tables = tables
        self.mesh = mesh
        self.cache = PlanCache(capacity)
        self.jit = jit
        self.policy = policy
        self.batch_row_budget = batch_row_budget
        self.sweep_mode = sweep_mode
        self.default_opts = default_opts
        self.stats = ServingStats()
        #: plan-key -> remembered run_plan escalation overrides, so a
        #: resubmit of a healed plan starts AT its final_params.
        self._healed: Dict[tuple, dict] = {}

    # ------------------------------------------------------------ helpers
    def _merged(self, opts: dict) -> dict:
        return {**self.default_opts, **opts}

    def _use_jit(self, opts: dict) -> bool:
        # Streamed plans execute a host-side wave loop: never jit them.
        return self.jit and opts.get("device_row_budget") is None

    # ------------------------------------------------------------ submit
    def submit(self, root: Node, params: dict | None = None, *,
               policy=None, **opts):
        """Run one query: ``-> (result, info)``.

        ``info`` is a dict with ``hit`` (was the first compile served
        from the plan cache), ``seconds``, ``attempts`` and — when a
        retry policy ran — the final :class:`~repro.db.report.
        ExecutionReport` under ``report``.  Cached hits are bit-identical
        to a cold compile (same executable object); post-retry resubmits
        replay the remembered ``final_params`` and hit the final
        attempt's cache entry in one clean attempt.
        """
        merged = self._merged(opts)
        use_jit = self._use_jit(merged)
        policy = policy if policy is not None else self.policy
        t0 = time.perf_counter()
        h0 = self.cache.hits
        if policy is not None:
            key = cache_key(root, self.mesh, use_jit, merged)
            healed = self._healed.get(key, {})
            out, report = run_plan(root, self.tables, self.mesh,
                                   policy=policy, jit=use_jit,
                                   params=params,
                                   compiler=self.cache.compile,
                                   **{**merged, **healed})
            self._healed[key] = {
                k: v for k, v in report.final_params.items()
                if v is not None
                and not (k in ("kappa_scale", "groups_scale") and v == 1)}
            attempts = int(report.waves.get("attempts", 1))
            hit = self.cache.hits > h0
            self.stats.record(hit=hit, attempts=attempts)
            return out, dict(hit=hit, attempts=attempts,
                             seconds=time.perf_counter() - t0,
                             report=report)
        fn = self.cache.compile(root, self.mesh, jit=use_jit, **merged)
        out = fn(self.tables, params)
        hit = self.cache.hits > h0
        self.stats.record(hit=hit)
        return out, dict(hit=hit, attempts=1,
                         seconds=time.perf_counter() - t0)

    # ------------------------------------------------------------- sweep
    def sweep(self, root: Node, param_batch: Dict[str, jnp.ndarray],
              **opts):
        """Run a parameterized plan family over N parameter points as
        ONE device program: ``-> (batched result, info)``.

        ``param_batch`` maps each of the plan's :class:`~repro.db.plans.
        Param` names to a length-N vector; the result pytree gains a
        leading N axis.  In the default ``sweep_mode='scan'`` each point
        runs the identical unbatched graph inside one device loop, so
        point i of any leaf is BIT-EQUAL to a sequential run of the
        family's jitted executable at point i's scalars — regardless of
        N or chunking; ``'vmap'`` vectorises across points instead (~1
        ULP, see module docstring).  One executable is compiled (and
        cached) for the FAMILY; every further sweep of any size is a
        cache hit.  ``batch_row_budget`` (service-level) caps the
        batched residency by splitting the sweep into chunked launches.
        Streamed plans are not batchable (host wave loop).
        """
        merged = self._merged(opts)
        if merged.get("device_row_budget") is not None:
            raise NotImplementedError(
                "parameter sweeps run the plan under vmap, which cannot "
                "drive the streamed executor's host wave loop: drop "
                "device_row_budget for batched families")
        names = sorted(plan_params(root))
        if not names:
            raise ValueError("sweep() needs a parameterized plan (no "
                             "Param holes found — use submit())")
        batch = {k: jnp.asarray(v) for k, v in param_batch.items()}
        sizes = {k: v.shape[0] for k, v in batch.items()}
        if sorted(batch) != names or len(set(sizes.values())) != 1:
            raise ValueError(
                f"param_batch must map exactly {names} to equal-length "
                f"vectors, got { {k: v.shape for k, v in batch.items()} }")
        n = next(iter(sizes.values()))
        t0 = time.perf_counter()
        h0 = self.cache.hits
        # The entry is cached UNJITTED (jit=False key): the sweep path
        # jits the batched wrapper itself.  Tables are an ARGUMENT of
        # the wrapper — closed-over columns would be constant-folded,
        # and XLA folds/fuses constants with different rounding than the
        # sequential executable sees (breaking bit-equality).
        entry, _ = self.cache.entry(root, self.mesh, jit=False, **merged)
        if entry.batched is None or entry.batched_mode != self.sweep_mode:
            fn = entry.fn
            if self.sweep_mode == "scan":
                entry.batched = jax.jit(lambda tb, pv: jax.lax.map(
                    lambda p: fn(tb, p), pv))
            else:
                entry.batched = jax.jit(lambda tb, pv: jax.vmap(
                    lambda p: fn(tb, p))(pv))
            entry.batched_mode = self.sweep_mode
        chunk = C.sweep_chunk_points(self._per_point_rows(root),
                                     self.batch_row_budget, n)
        outs = [entry.batched(self.tables,
                              {k: v[lo:lo + chunk]
                               for k, v in batch.items()})
                for lo in range(0, n, chunk)]
        out = outs[0] if len(outs) == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *outs)
        hit = self.cache.hits > h0
        self.stats.record(hit=hit, points=n)
        return out, dict(hit=hit, points=n, chunk=chunk,
                         launches=len(outs),
                         seconds=time.perf_counter() - t0)

    def _per_point_rows(self, root: Node) -> float:
        """Residency one sweep point adds: the referenced base tables'
        column elements (a vmap lane materialises its own intermediates;
        scan chunks bound the stacked OUTPUT slab the same way —
        :func:`repro.db.cost.batched`)."""
        total = 0.0
        for name in _scan_names(root):
            t = self.tables.get(name)
            if t is not None:
                total += t.capacity * (len(t.columns) + 2)
        return total
