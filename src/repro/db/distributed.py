"""Distributed query execution: generic shard_map glue over the segment-UDA
protocol of :mod:`repro.core.uda`.

The paper scales by streaming partitions through per-core UDAs and merging
(Glade's Accumulate/Merge).  On a TPU pod the same structure is:

    Accumulate  per-shard: the ONE canonical blocked accumulation loop
                (`uda.accumulate`) over the local tuple partition
    Merge       `uda.reduce_collective`: one psum over the data axes per
                additive state (log-CF / cumulants / log(1-p) are all
                additive — DESIGN.md §2); MinMax gather-folds instead
    Finalize    replicated FFT / mixture solve epilogue

``make_uda_step`` builds that pipeline for ANY dict of registered UDAs —
the generic aggregation-only step that ``make_query_step`` specialises to
the canonical fixed query shape (confidence + normal + cumulants + exact
global CF) which launch/dryrun.py lowers for the `pgf_tpch` cell.
Tuples are sharded over ('pod','data') — the (batch-like) scale axis — and
replicated over 'model'; frequency grids of the exact CF path are sharded
over 'model' so the O(n*F) phase work splits both ways (the beyond-paper
optimization validated in §Perf).

The sharded relational frontend (`db/plans.py compile_plan(root, mesh)`)
runs the WHOLE plan inside one shard_map and uses the collective helpers
below instead of a per-node step:

    gather_table        broadcast a row-partitioned Table (FK-join build
                        sides, final sharded results): one tiled
                        all-gather per column, shard-major == global row
                        order under the contiguous row partitioning
    group_ids_sharded   two-phase distributed group-id assignment —
                        per-shard jnp.unique, all-gather + merge of the
                        per-shard code tables, searchsorted against the
                        merged codes (exact vs the single-pass oracle,
                        overflow included: operators.merge_group_codes)
    allgather_merge     ONE collective Merge per aggregation pass: gather
                        every shard's partial UDA state and fold with the
                        canonical pairwise tree (uda.tree_fold) — the
                        bit-reproducible form of the additive psum, which
                        also covers non-additive states (MinMax)
    group_key_columns_sharded   per-shard segment_max + one pmax (max is
                        exact, so bit-equal to the replicated reduction)
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core import uda
from . import operators as ops
from .table import Table


def _tuple_axes(mesh: Mesh, data_axes: Sequence[str]) -> tuple:
    return tuple(a for a in ("pod",) + tuple(data_axes)
                 if a in mesh.axis_names)


def make_uda_step(mesh: Mesh, uda_factory: Callable[[int, object], dict], *,
                  max_groups: int, data_axes: Sequence[str] = ("data",),
                  model_axis: str | None = "model", block: int = 8192,
                  post=None):
    """Build a jit-able distributed Accumulate/Merge/Finalize step.

    uda_factory(model_size, model_rank) -> {name: UDA}; ``model_rank`` is a
    traced axis index inside shard_map (0 without a model axis), so CF UDAs
    can bind their per-shard frequency slice.

    The returned step takes (probs, values, gids) with tuples sharded over
    the data axes (values may be a dict of per-UDA columns) and returns the
    replicated finalized results — or ``post(udas, states)`` if given.
    """
    axes = _tuple_axes(mesh, data_axes)
    model = model_axis if (model_axis and model_axis in mesh.axis_names) \
        else None
    model_size = mesh.shape[model] if model else 1
    in_spec = P(axes)

    def step(probs, values, gids):
        def shard_fn(p, v, g):
            rank = jax.lax.axis_index(model) if model else 0
            udas = uda_factory(model_size, rank)
            states = uda.accumulate(udas, p, v, g, max_groups=max_groups,
                                    block=block)
            states = uda.reduce_collective(udas, states, axes, model)
            if post is not None:
                return post(udas, states)
            return uda.finalize(udas, states)

        fn = shard_map(shard_fn, mesh=mesh,
                       in_specs=(in_spec, in_spec, in_spec),
                       out_specs=P(), check_vma=False)
        return fn(probs, values, gids)

    return jax.jit(step)


def pad_for(mesh: Mesh, probs, values, gids, *, max_groups: int,
            data_axes: Sequence[str] = ("data",)):
    """Zero-pad tuple columns so the shard count divides them (p = 0 pads
    contribute nothing to any UDA; they land in the overflow group)."""
    shards = 1
    for a in _tuple_axes(mesh, data_axes):
        shards *= mesh.shape[a]
    n = probs.shape[0]
    pad = (-n) % shards
    if pad == 0:
        return probs, values, gids
    probs = jnp.pad(probs, (0, pad))
    gids = jnp.pad(gids, (0, pad), constant_values=max_groups - 1)
    if isinstance(values, dict):
        # Pad each distinct source array once so aggregates sharing a column
        # keep sharing it (uda.accumulate dedups value columns by identity).
        padded: dict = {}
        values = {k: None if v is None
                  else padded.setdefault(id(v), jnp.pad(v, (0, pad)))
                  for k, v in values.items()}
    elif values is not None:
        values = jnp.pad(values, (0, pad))
    return probs, values, gids


# ----------------------------------------------------- sharded frontend
def gather_table(t: Table, axis_names) -> Table:
    """Broadcast a row-partitioned Table (call inside shard_map): tiled
    all-gather of every column plus p and valid.  With the contiguous row
    partitioning of the sharded frontend, shard-major concatenation IS the
    original global row order, so the gathered table is bit-identical to
    the unsharded one."""
    axis_names = tuple(axis_names)
    g = lambda x: jax.lax.all_gather(x, axis_names, axis=0, tiled=True)
    return Table({k: g(v) for k, v in t.columns.items()},
                 g(t.prob), g(t.valid))


def group_ids_sharded(table: Table, keys: Sequence[str], max_groups: int,
                      axis_names):
    """Two-phase distributed group-id assignment (call inside shard_map).

    Phase 1: per-shard ``jnp.unique`` of the live key codes (size
    max_groups, sentinel fill).  Phase 2: one tiled all-gather of the
    per-shard code tables + a second unique merge, giving every shard the
    same global code table; ids come from searchsorted of the LOCAL codes
    against it.  Replaces the replicated full-table unique: per-shard
    work/memory is O(local rows + shards * max_groups), and the result is
    bit-identical to ``operators.group_ids`` (see
    ``operators.merge_group_codes`` for the overflow argument).
    """
    axis_names = tuple(axis_names)
    code_live, big = ops.live_key_codes(table, keys)
    local = ops.merge_group_codes(code_live, max_groups)
    gathered = jax.lax.all_gather(local, axis_names, axis=0, tiled=True)
    merged = ops.merge_group_codes(gathered, max_groups)
    return ops.codes_to_ids(code_live, merged), merged, merged != big


def group_key_columns_sharded(table: Table, keys: Sequence[str], ids,
                              max_groups: int, axis_names):
    """Per-group key representatives over a row-partitioned table: local
    segment_max, then one pmax over the data axes (max is exact, so this
    is bit-equal to the replicated reduction)."""
    axis_names = tuple(axis_names)
    cols = ops.group_key_columns(table, keys, ids, max_groups)
    return {k: jax.lax.pmax(v, axis_names) for k, v in cols.items()}


def allgather_merge(udas: dict, states: dict, axis_names) -> dict:
    """The sharded frontend's ONE collective Merge per aggregation pass:
    all-gather every shard's partial state (shard-major, so the leaf order
    is the canonical chunk order) and fold with ``uda.tree_fold``.

    For additive states this computes exactly what a psum would, but in
    the fixed pairwise tree that continues the shard-local
    ``uda.accumulate_chunked`` fold — hence bit-identical to the
    single-device compile — and it covers non-additive states (MinMax)
    with the same code path.
    """
    axis_names = tuple(axis_names)
    out = {}
    for name, u in udas.items():
        g = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis_names, axis=0, tiled=False),
            states[name])
        shards = jax.tree.leaves(g)[0].shape[0]        # static
        parts = [jax.tree.map(lambda x, s=s: x[s], g)
                 for s in range(shards)]
        out[name] = uda.tree_fold(u, parts)
    return out


def make_query_step(mesh: Mesh, *, max_groups: int = 1024,
                    num_freq: int = 4096, orders: int = 8,
                    data_axes: Sequence[str] = ("data",),
                    model_axis: str | None = "model"):
    """The canonical distributed aggregate-query step for `mesh`.

    Inputs (sharded over data axes):
        probs  (n,) f32, values (n,) f32, gids (n,) int32
    Output (replicated): finalized per-group confidence, normal terms,
    cumulant sums, and the exact global distribution (num_freq coeffs),
    the latter accumulated over the model axis's frequency slices.
    """
    model = model_axis if (model_axis and model_axis in mesh.axis_names) \
        else None
    model_size = mesh.shape[model] if model else 1
    assert num_freq % model_size == 0
    f_loc = num_freq // model_size

    def factory(size, rank):
        cf = uda.SumCF(num_freq, freq_lo=rank * f_loc, freq_cnt=f_loc)
        cf.scalar = True          # global distribution: one group
        return dict(conf=uda.AtLeastOne(), normal=uda.SumNormal(),
                    cum=uda.SumCumulants(orders), cf=cf)

    def post(udas, states):
        confidence = udas["conf"].finalize(states["conf"])
        coeffs = udas["cf"].finalize(states["cf"])[0]
        return (confidence, states["normal"].terms, states["cum"].terms,
                coeffs)

    return make_uda_step(mesh, factory, max_groups=max_groups,
                         data_axes=data_axes, model_axis=model_axis,
                         post=post)


def shard_columns(mesh: Mesh, arrays, data_axes: Sequence[str] = ("data",)):
    """Place host arrays with tuple-sharded layout on the mesh."""
    sharding = NamedSharding(mesh, P(_tuple_axes(mesh, data_axes)))
    return tuple(jax.device_put(a, sharding) for a in arrays)


def input_specs(*, n_tuples: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the distributed query step inputs."""
    return dict(
        probs=jax.ShapeDtypeStruct((n_tuples,), dtype),
        values=jax.ShapeDtypeStruct((n_tuples,), dtype),
        gids=jax.ShapeDtypeStruct((n_tuples,), jnp.int32),
    )
