"""Distributed query execution: shard_map over the mesh's data axes.

The paper scales by streaming partitions through per-core UDAs and merging
(Glade's Accumulate/Merge).  On a TPU pod the same structure is:

    Accumulate  per-shard vectorised UDA over the local tuple partition
    Merge       ONE psum over the data axes (log-CF / cumulants / log(1-p)
                states are all additive — DESIGN.md §2)
    Finalize    replicated FFT / mixture solve epilogue

``query_step`` below is the canonical distributed aggregate query — the
paper's workload as a jit-able function over sharded columns.  It is what
launch/dryrun.py lowers for the `pgf_tpch` cell and what the TPC-H
benchmarks run multi-device.  Tuples are sharded over ('pod','data') — the
(batch-like) scale axis — and replicated over 'model'; frequency grids of
the exact CF path are sharded over 'model' so the O(n*F) phase work splits
both ways (the beyond-paper optimization validated in §Perf).
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import poisson_binomial as pb
from ..core.approx import MAX_ORDER, _bernoulli_cumulant_polys


def local_query_contrib(probs, values, gids, *, max_groups: int,
                        num_freq: int, orders: int = 8,
                        freq_lo: int = 0, freq_cnt: int | None = None,
                        block: int = 8192):
    """Per-shard UDA accumulation for a grouped aggregate query.

    ONE blocked pass over the local tuples (lax.scan), all accumulators
    carried: the (freq_cnt, block) phase tile is the only large live
    intermediate, mirroring the VMEM tiling of kernels/pb_cf.py.  An
    unblocked formulation materialises an (F, n_local) matrix — hundreds
    of GB per device at production scale (the §Perf baseline bug).

    Returns the additive state tuple:
      conf_logq   (G,)        sum log(1-p) per group          (AtLeastOne)
      normal      (G, 2)      [sum v p, sum v^2 p (1-p)]      (Normal)
      cumulants   (G, orders) sum v^j kappa_j(p)              (moments)
      logcf       (2, F_loc)  global exact-CF accumulation over the
                              [freq_lo, freq_lo+freq_cnt) frequency slice
    """
    dtype = probs.dtype
    if freq_cnt is None:
        freq_cnt = num_freq
    n = probs.shape[0]
    block = max(256, min(block, (1 << 23) // max(1, freq_cnt)))
    nfull = ((n + block - 1) // block) * block
    pad = nfull - n
    probs = jnp.pad(probs, (0, pad))            # p=0: no contribution
    values = jnp.pad(values, (0, pad))
    gids = jnp.pad(gids, (0, pad), constant_values=max_groups - 1)

    table_c = jnp.asarray(_bernoulli_cumulant_polys()[1:orders + 1], dtype)
    k = (freq_lo + jnp.arange(freq_cnt, dtype=dtype))
    tiny = 1e-30 if dtype == jnp.float32 else 1e-300

    def body(carry, chunk):
        conf, normal, cum, la_acc, an_acc = carry
        p, v, g = chunk
        logq = jnp.log1p(-p)
        conf = conf.at[g].add(logq)
        mu_t = v * p
        var_t = v * v * p * (1 - p)
        normal = normal.at[g].add(jnp.stack([mu_t, var_t], axis=-1))
        powers = p[None, :] ** jnp.arange(MAX_ORDER + 1, dtype=dtype)[:, None]
        kappas = table_c @ powers                       # (orders, B)
        vpow = v[None, :] ** jnp.arange(1, orders + 1, dtype=dtype)[:, None]
        cum = cum.at[g].add((kappas * vpow).T)
        # exact log-CF over this shard's frequency slice
        phase = (k[:, None] * v[None, :]) % num_freq    # (F_loc, B)
        theta = (2.0 * math.pi / num_freq) * phase
        q = 1.0 - p[None, :]
        re = q + p[None, :] * jnp.cos(theta)
        im = p[None, :] * jnp.sin(theta)
        la = 0.5 * jnp.log(jnp.maximum(re * re + im * im, tiny))
        an = jnp.arctan2(im, re)
        return (conf, normal, cum, la_acc + la.sum(-1),
                an_acc + an.sum(-1)), None

    init = (jnp.zeros((max_groups,), dtype),
            jnp.zeros((max_groups, 2), dtype),
            jnp.zeros((max_groups, orders), dtype),
            jnp.zeros((freq_cnt,), dtype),
            jnp.zeros((freq_cnt,), dtype))
    chunks = (probs.reshape(-1, block), values.reshape(-1, block),
              gids.reshape(-1, block))
    from ..models.runmode import unroll_mode
    if unroll_mode():
        carry = init
        for i in range(nfull // block):
            carry, _ = body(carry, (chunks[0][i], chunks[1][i],
                                    chunks[2][i]))
        conf, normal, cum, la, an = carry
    else:
        (conf, normal, cum, la, an), _ = jax.lax.scan(body, init, chunks)
    return conf, normal, cum, jnp.stack([la, an])


def make_query_step(mesh: Mesh, *, max_groups: int = 1024,
                    num_freq: int = 4096, orders: int = 8,
                    data_axes: Sequence[str] = ("data",),
                    model_axis: str | None = "model"):
    """Build the jit-able distributed aggregate-query step for `mesh`.

    Inputs (sharded over data axes):
        probs  (n,) f32, values (n,) f32, gids (n,) int32
    Output (replicated): finalized per-group confidence, normal terms,
    cumulant sums, and the exact global distribution (num_freq coeffs).
    """
    data_axes = tuple(a for a in data_axes if a in mesh.axis_names)
    axes = tuple(a for a in ("pod",) + tuple(data_axes) if a in mesh.axis_names)
    model = model_axis if (model_axis and model_axis in mesh.axis_names) else None
    model_size = mesh.shape[model] if model else 1
    assert num_freq % model_size == 0
    f_loc = num_freq // model_size

    in_spec = P(axes)                         # tuples sharded over data axes
    out_spec = P()                            # replicated results

    def step(probs, values, gids):
        def shard_fn(p, v, g):
            freq_lo = 0
            if model:
                freq_lo = jax.lax.axis_index(model) * f_loc
            conf, normal, cum, logcf = local_query_contrib(
                p, v, g, max_groups=max_groups, num_freq=num_freq,
                orders=orders, freq_lo=freq_lo, freq_cnt=f_loc)
            # Merge = one psum per state over the tuple-sharding axes.
            conf, normal, cum = jax.lax.psum((conf, normal, cum), axes)
            logcf = jax.lax.psum(logcf, axes)
            if model:
                # Frequency slices live on different model shards;
                # all-gather them for the replicated FFT epilogue.
                logcf = jax.lax.all_gather(logcf, model, axis=1, tiled=True)
                conf = jax.lax.pmean(conf, model)
                normal = jax.lax.pmean(normal, model)
                cum = jax.lax.pmean(cum, model)
            coeffs = pb.logcf_finalize(logcf[0], logcf[1])
            confidence = 1.0 - jnp.exp(conf)
            return confidence, normal, cum, coeffs

        specs_in = (in_spec, in_spec, in_spec)
        fn = shard_map(shard_fn, mesh=mesh, in_specs=specs_in,
                       out_specs=(out_spec, out_spec, out_spec, out_spec),
                       check_vma=False)
        return fn(probs, values, gids)

    return jax.jit(step)


def shard_columns(mesh: Mesh, arrays, data_axes: Sequence[str] = ("data",)):
    """Place host arrays with tuple-sharded layout on the mesh."""
    axes = tuple(a for a in ("pod",) + tuple(data_axes) if a in mesh.axis_names)
    sharding = NamedSharding(mesh, P(axes))
    return tuple(jax.device_put(a, sharding) for a in arrays)


def input_specs(*, n_tuples: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the distributed query step inputs."""
    return dict(
        probs=jax.ShapeDtypeStruct((n_tuples,), dtype),
        values=jax.ShapeDtypeStruct((n_tuples,), dtype),
        gids=jax.ShapeDtypeStruct((n_tuples,), jnp.int32),
    )
