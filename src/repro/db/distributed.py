"""Distributed query execution: generic shard_map glue over the segment-UDA
protocol of :mod:`repro.core.uda`.

The paper scales by streaming partitions through per-core UDAs and merging
(Glade's Accumulate/Merge).  On a TPU pod the same structure is:

    Accumulate  per-shard: the ONE canonical blocked accumulation loop
                (`uda.accumulate`) over the local tuple partition
    Merge       `uda.reduce_collective`: one psum over the data axes per
                additive state (log-CF / cumulants / log(1-p) are all
                additive — DESIGN.md §2); MinMax gather-folds instead
    Finalize    replicated FFT / mixture solve epilogue

``make_uda_step`` builds that pipeline for ANY dict of registered UDAs —
it is what the mesh-aware plan compiler (`db/plans.py compile_plan(root,
mesh)`) emits for `GroupAgg`/`ReweightGreater` nodes.  ``make_query_step``
is the canonical fixed query shape (confidence + normal + cumulants +
exact global CF) that launch/dryrun.py lowers for the `pgf_tpch` cell.
Tuples are sharded over ('pod','data') — the (batch-like) scale axis — and
replicated over 'model'; frequency grids of the exact CF path are sharded
over 'model' so the O(n*F) phase work splits both ways (the beyond-paper
optimization validated in §Perf).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core import uda


def _tuple_axes(mesh: Mesh, data_axes: Sequence[str]) -> tuple:
    return tuple(a for a in ("pod",) + tuple(data_axes)
                 if a in mesh.axis_names)


def make_uda_step(mesh: Mesh, uda_factory: Callable[[int, object], dict], *,
                  max_groups: int, data_axes: Sequence[str] = ("data",),
                  model_axis: str | None = "model", block: int = 8192,
                  post=None):
    """Build a jit-able distributed Accumulate/Merge/Finalize step.

    uda_factory(model_size, model_rank) -> {name: UDA}; ``model_rank`` is a
    traced axis index inside shard_map (0 without a model axis), so CF UDAs
    can bind their per-shard frequency slice.

    The returned step takes (probs, values, gids) with tuples sharded over
    the data axes (values may be a dict of per-UDA columns) and returns the
    replicated finalized results — or ``post(udas, states)`` if given.
    """
    axes = _tuple_axes(mesh, data_axes)
    model = model_axis if (model_axis and model_axis in mesh.axis_names) \
        else None
    model_size = mesh.shape[model] if model else 1
    in_spec = P(axes)

    def step(probs, values, gids):
        def shard_fn(p, v, g):
            rank = jax.lax.axis_index(model) if model else 0
            udas = uda_factory(model_size, rank)
            states = uda.accumulate(udas, p, v, g, max_groups=max_groups,
                                    block=block)
            states = uda.reduce_collective(udas, states, axes, model)
            if post is not None:
                return post(udas, states)
            return uda.finalize(udas, states)

        fn = shard_map(shard_fn, mesh=mesh,
                       in_specs=(in_spec, in_spec, in_spec),
                       out_specs=P(), check_vma=False)
        return fn(probs, values, gids)

    return jax.jit(step)


def pad_for(mesh: Mesh, probs, values, gids, *, max_groups: int,
            data_axes: Sequence[str] = ("data",)):
    """Zero-pad tuple columns so the shard count divides them (p = 0 pads
    contribute nothing to any UDA; they land in the overflow group)."""
    shards = 1
    for a in _tuple_axes(mesh, data_axes):
        shards *= mesh.shape[a]
    n = probs.shape[0]
    pad = (-n) % shards
    if pad == 0:
        return probs, values, gids
    probs = jnp.pad(probs, (0, pad))
    gids = jnp.pad(gids, (0, pad), constant_values=max_groups - 1)
    if isinstance(values, dict):
        # Pad each distinct source array once so aggregates sharing a column
        # keep sharing it (uda.accumulate dedups value columns by identity).
        padded: dict = {}
        values = {k: None if v is None
                  else padded.setdefault(id(v), jnp.pad(v, (0, pad)))
                  for k, v in values.items()}
    elif values is not None:
        values = jnp.pad(values, (0, pad))
    return probs, values, gids


def make_query_step(mesh: Mesh, *, max_groups: int = 1024,
                    num_freq: int = 4096, orders: int = 8,
                    data_axes: Sequence[str] = ("data",),
                    model_axis: str | None = "model"):
    """The canonical distributed aggregate-query step for `mesh`.

    Inputs (sharded over data axes):
        probs  (n,) f32, values (n,) f32, gids (n,) int32
    Output (replicated): finalized per-group confidence, normal terms,
    cumulant sums, and the exact global distribution (num_freq coeffs),
    the latter accumulated over the model axis's frequency slices.
    """
    model = model_axis if (model_axis and model_axis in mesh.axis_names) \
        else None
    model_size = mesh.shape[model] if model else 1
    assert num_freq % model_size == 0
    f_loc = num_freq // model_size

    def factory(size, rank):
        cf = uda.SumCF(num_freq, freq_lo=rank * f_loc, freq_cnt=f_loc)
        cf.scalar = True          # global distribution: one group
        return dict(conf=uda.AtLeastOne(), normal=uda.SumNormal(),
                    cum=uda.SumCumulants(orders), cf=cf)

    def post(udas, states):
        confidence = udas["conf"].finalize(states["conf"])
        coeffs = udas["cf"].finalize(states["cf"])[0]
        return (confidence, states["normal"].terms, states["cum"].terms,
                coeffs)

    return make_uda_step(mesh, factory, max_groups=max_groups,
                         data_axes=data_axes, model_axis=model_axis,
                         post=post)


def shard_columns(mesh: Mesh, arrays, data_axes: Sequence[str] = ("data",)):
    """Place host arrays with tuple-sharded layout on the mesh."""
    sharding = NamedSharding(mesh, P(_tuple_axes(mesh, data_axes)))
    return tuple(jax.device_put(a, sharding) for a in arrays)


def input_specs(*, n_tuples: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the distributed query step inputs."""
    return dict(
        probs=jax.ShapeDtypeStruct((n_tuples,), dtype),
        values=jax.ShapeDtypeStruct((n_tuples,), dtype),
        gids=jax.ShapeDtypeStruct((n_tuples,), jnp.int32),
    )
