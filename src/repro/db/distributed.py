"""Distributed query execution: generic shard_map glue over the segment-UDA
protocol of :mod:`repro.core.uda`.

The paper scales by streaming partitions through per-core UDAs and merging
(Glade's Accumulate/Merge).  On a TPU pod the same structure is:

    Accumulate  per-shard: the ONE canonical blocked accumulation loop
                (`uda.accumulate`) over the local tuple partition
    Merge       `uda.reduce_collective`: one psum over the data axes per
                additive state (log-CF / cumulants / log(1-p) are all
                additive — DESIGN.md §2); MinMax gather-folds instead
    Finalize    replicated FFT / mixture solve epilogue

``make_uda_step`` builds that pipeline for ANY dict of registered UDAs —
the generic aggregation-only step that ``make_query_step`` specialises to
the canonical fixed query shape (confidence + normal + cumulants + exact
global CF) which launch/dryrun.py lowers for the `pgf_tpch` cell.
Tuples are sharded over ('pod','data') — the (batch-like) scale axis — and
replicated over 'model'; frequency grids of the exact CF path are sharded
over 'model' so the O(n*F) phase work splits both ways (the beyond-paper
optimization validated in §Perf).

The sharded relational frontend (`db/plans.py`, strategies lowered by
`db/physical.py`) runs the WHOLE physical plan inside one shard_map and
uses the collective helpers below instead of a per-node step:

    gather_table        broadcast a row-partitioned Table (small FK-join
                        build sides, final sharded results): one tiled
                        all-gather per column, shard-major == global row
                        order under the contiguous row partitioning
    shuffle_by_key      static-shape all_to_all exchange: each row goes to
                        shard ``key % n_shards`` through per-destination
                        send buckets of fixed capacity, with overflow
                        accounting (operators.bucket_slots)
    shuffle_fk_join     the ShuffleJoin executor: build rows hashed to
                        their key's owner shard, probe keys exchanged as
                        requests, matched shard-locally (ops.fk_join on
                        the hash bucket), responses shuffled home — peak
                        build rows/device O(build/shards), output
                        bit-identical to the gathered join
    copartitioned_fk_join   the CoPartitionedJoin executor: the same two
                        exchanges, but probe rows carry (p, canonical
                        chunk id, aggregation columns) and matched rows
                        STAY at their key's owner — no shuffle_back
                        round-trip; output is HashPartitioned(left_key)
    repartition_by_key  hash-exchange aggregation inputs to their
                        group-key owner (the no-join feed of
                        PartitionedAgg)
    partitioned_merge   the HashPartitioned Merge: every group lives
                        wholly at one owner, so each owner finishes the
                        canonical chunk tree_fold LOCALLY and ONE psum
                        combines the folded additive states (exact zeros
                        elsewhere => bit-identical to allgather_merge);
                        MinMax states gather-fold across owners
    group_ids_sharded   two-phase distributed group-id assignment —
                        per-shard jnp.unique, all-gather + merge of the
                        per-shard code tables, searchsorted against the
                        merged codes (exact vs the single-pass oracle,
                        overflow included: operators.merge_group_codes)
    allgather_merge     ONE collective Merge per aggregation pass: gather
                        every shard's per-canonical-chunk partial states
                        and fold ALL chunk states with the one fixed tree
                        (uda.tree_fold) — the bit-reproducible form of the
                        additive psum for ANY shard count (pow2 or not),
                        which also covers non-additive states (MinMax)
    group_key_columns_sharded   per-shard segment_max + one pmax (max is
                        exact, so bit-equal to the replicated reduction)
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core import uda
from ..testing import faults
from . import operators as ops
from . import physical as phys
from .table import Table


def _tuple_axes(mesh: Mesh, data_axes: Sequence[str]) -> tuple:
    return tuple(a for a in ("pod",) + tuple(data_axes)
                 if a in mesh.axis_names)


#: trace-time counts of the collective exchanges issued by the sharded
#: frontend, keyed by kind ("shuffle", "shuffle_back", "gather_table",
#: "merge_psum", "merge_gather").  Incremented while a plan traces (once
#: per eager execution, once per jit trace), so tests and benchmarks can
#: assert structural properties — e.g. that a co-partitioned pipeline
#: issues ZERO shuffle_back round-trips.
COLLECTIVE_COUNTS: dict = {}


def reset_collective_counts() -> None:
    COLLECTIVE_COUNTS.clear()


def _count(kind: str) -> None:
    COLLECTIVE_COUNTS[kind] = COLLECTIVE_COUNTS.get(kind, 0) + 1


def data_rank(axis_names):
    """Linearized shard rank over the data axes (row-major — the order of
    the contiguous row partitioning).  Call inside shard_map."""
    r = jnp.zeros((), jnp.int32)
    for a in tuple(axis_names):
        r = r * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return r


def make_uda_step(mesh: Mesh, uda_factory: Callable[[int, object], dict], *,
                  max_groups: int, data_axes: Sequence[str] = ("data",),
                  model_axis: str | None = "model", block: int = 8192,
                  post=None):
    """Build a jit-able distributed Accumulate/Merge/Finalize step.

    uda_factory(model_size, model_rank) -> {name: UDA}; ``model_rank`` is a
    traced axis index inside shard_map (0 without a model axis), so CF UDAs
    can bind their per-shard frequency slice.

    The returned step takes (probs, values, gids) with tuples sharded over
    the data axes (values may be a dict of per-UDA columns) and returns the
    replicated finalized results — or ``post(udas, states)`` if given.
    """
    axes = _tuple_axes(mesh, data_axes)
    model = model_axis if (model_axis and model_axis in mesh.axis_names) \
        else None
    model_size = mesh.shape[model] if model else 1
    in_spec = P(axes)

    def step(probs, values, gids):
        def shard_fn(p, v, g):
            rank = jax.lax.axis_index(model) if model else 0
            udas = uda_factory(model_size, rank)
            states = uda.accumulate(udas, p, v, g, max_groups=max_groups,
                                    block=block)
            states = uda.reduce_collective(udas, states, axes, model)
            if post is not None:
                return post(udas, states)
            return uda.finalize(udas, states)

        fn = shard_map(shard_fn, mesh=mesh,
                       in_specs=(in_spec, in_spec, in_spec),
                       out_specs=P(), check_vma=False)
        return fn(probs, values, gids)

    return jax.jit(step)


def pad_for(mesh: Mesh, probs, values, gids, *, max_groups: int,
            data_axes: Sequence[str] = ("data",)):
    """Zero-pad tuple columns so the shard count divides them (p = 0 pads
    contribute nothing to any UDA; they land in the overflow group)."""
    shards = 1
    for a in _tuple_axes(mesh, data_axes):
        shards *= mesh.shape[a]
    n = probs.shape[0]
    pad = (-n) % shards
    if pad == 0:
        return probs, values, gids
    probs = jnp.pad(probs, (0, pad))
    gids = jnp.pad(gids, (0, pad), constant_values=max_groups - 1)
    if isinstance(values, dict):
        # Pad each distinct source array once so aggregates sharing a column
        # keep sharing it (uda.accumulate dedups value columns by identity).
        padded: dict = {}
        values = {k: None if v is None
                  else padded.setdefault(id(v), jnp.pad(v, (0, pad)))
                  for k, v in values.items()}
    elif values is not None:
        values = jnp.pad(values, (0, pad))
    return probs, values, gids


# ----------------------------------------------------- sharded frontend
def gather_table(t: Table, axis_names) -> Table:
    """Broadcast a row-partitioned Table (call inside shard_map): tiled
    all-gather of every column plus p and valid.  With the contiguous row
    partitioning of the sharded frontend, shard-major concatenation IS the
    original global row order, so the gathered table is bit-identical to
    the unsharded one."""
    axis_names = tuple(axis_names)
    _count("gather_table")
    g = lambda x: jax.lax.all_gather(x, axis_names, axis=0, tiled=True)
    return Table({k: g(v) for k, v in t.columns.items()},
                 g(t.prob), g(t.valid), phys.Replicated())


def shuffle_by_key(keys, cols: dict, axis_names, *, n_shards: int,
                   capacity: int, valid=None):
    """Static-shape shuffle exchange (call inside shard_map): row i goes
    to shard ``keys[i] % n_shards``.

    Every shard fills ``n_shards`` send buckets of ``capacity`` rows
    (``operators.bucket_slots`` assigns slots; ok-rows beyond a bucket's
    capacity overflow and are DROPPED but counted) and one ``all_to_all``
    transposes the buckets, so per-device exchange memory is the static
    ``n_shards * capacity`` rows regardless of skew.

    Returns ``(recv, recv_mask, slot, sent, overflow)``:
        recv       {name: (n_shards * capacity,) array} — bucket j*capacity
                   + r holds sender j's r-th row for THIS shard; empty
                   slots zero
        recv_mask  (n_shards * capacity,) bool occupancy
        slot, sent the local send-slot bookkeeping (route responses home
                   through the same buckets: ``shuffle_back`` +
                   ``operators.take_from_buckets``)
        overflow   local count of ok-rows dropped for capacity
    """
    axis_names = tuple(axis_names)
    _count("shuffle")
    faults.on_exchange()
    ok = jnp.ones(keys.shape, bool) if valid is None else valid
    dest = jnp.mod(keys.astype(jnp.int32), n_shards)
    slot, sent, overflow = ops.bucket_slots(dest, ok, n_shards, capacity)
    size = n_shards * capacity
    inv = ops.bucket_fill_index(slot, size)
    send = ops.scatter_to_buckets(cols, slot, size, inv=inv)
    mask = inv < keys.shape[0]          # slot filled by a sent row
    recv = {k: _all_to_all_rows(v, axis_names, n_shards, capacity)
            for k, v in send.items()}
    recv_mask = _all_to_all_rows(mask, axis_names, n_shards, capacity)
    return recv, recv_mask, slot, sent, overflow


def shuffle_back(cols: dict, axis_names, n_shards: int, capacity: int):
    """Return per-request responses to their origin shards: the inverse
    exchange of :func:`shuffle_by_key` (all_to_all is an involution on the
    (n_shards, capacity) bucket layout), landing each response in the send
    slot its request came from."""
    axis_names = tuple(axis_names)
    _count("shuffle_back")
    return {k: _all_to_all_rows(v, axis_names, n_shards, capacity)
            for k, v in cols.items()}


def _all_to_all_rows(x, axis_names, n_shards: int, capacity: int):
    b = x.reshape((n_shards, capacity) + x.shape[1:])
    out = jax.lax.all_to_all(b, axis_names, split_axis=0, concat_axis=0,
                             tiled=False)
    return out.reshape((n_shards * capacity,) + x.shape[1:])


# Internal exchange fields ride the same bucket dicts as the carried
# user columns; the "\x00" prefix keeps them out of any legal column
# namespace (a user column can't collide silently — it is rejected).
_KEY, _PROB = "\x00key", "\x00prob"


def _check_exchange_cols(what: str, cols) -> None:
    bad = [c for c in cols if c.startswith("\x00")]
    if bad:
        raise ValueError(f"{what} may not start with '\\x00' (reserved "
                         f"for exchange fields): {bad}")


def _send_demand(keys, valid, n_shards: int):
    """Peak per-owner send demand of one shard: the largest number of
    valid rows this shard wants to route to any single owner.  pmax'd
    across shards this is exactly the bucket capacity that makes the
    exchange overflow-free — the concrete escalation target the retry
    controller reads from ``ExecutionReport.exchange_demand``."""
    dest = jnp.mod(keys.astype(jnp.int32), n_shards)
    cnt = jnp.zeros((n_shards,), jnp.int32).at[dest].add(
        valid.astype(jnp.int32))
    return jnp.max(cnt)


def _record_leg(report, label: str, leg: str, axis_names, overflow,
                keys, valid, n_shards: int, capacity: int) -> None:
    """File one exchange leg into the ReportBuilder (costs two extra
    collectives per leg, so the joins only call this when a report was
    requested)."""
    if report is None:
        return
    report.exchange_leg(
        label, leg, jax.lax.psum(overflow, axis_names),
        jax.lax.pmax(_send_demand(keys, valid, n_shards), axis_names),
        capacity)


def _exchange_build(right: Table, right_key: str, right_cols, axis_names,
                    n_shards: int, build_bucket: int):
    """Shuffle the build side's valid rows to their ``right_key %
    n_shards`` owner: each owner holds its hash bucket of the dimension
    table, O(build/shards) rows.  Returns (bucket Table, local overflow)."""
    bcols = {_KEY: right[right_key].astype(jnp.int32), _PROB: right.prob}
    for c in right_cols:
        bcols[c] = right[c]
    brecv, bmask, _, _, b_over = shuffle_by_key(
        bcols[_KEY], bcols, axis_names, n_shards=n_shards,
        capacity=build_bucket, valid=right.valid)
    return Table({right_key: brecv[_KEY],
                  **{c: brecv[c] for c in right_cols}},
                 brecv[_PROB], bmask,
                 phys.HashPartitioned(right_key)), b_over


def _chunk_ids(capacity: int, axis_names, chunk_size: int,
               num_chunks: int):
    """Canonical-chunk id of each local row (clipped into the canonical
    grid; shard-alignment padding rows are invalid and never shipped)."""
    gid0 = data_rank(axis_names) * capacity
    return jnp.clip((gid0 + jnp.arange(capacity)) // chunk_size,
                    0, num_chunks - 1).astype(jnp.int32)


def shuffle_fk_join(left: Table, right: Table, left_key: str,
                    right_key: str, right_cols: Sequence[str], axis_names,
                    *, n_shards: int, build_bucket: int,
                    probe_bucket: int, report=None,
                    label: str = "") -> Table:
    """Hash-partitioned FK join (call inside shard_map): the ShuffleJoin
    strategy of :mod:`repro.db.physical`.

    1. Build exchange: the (row-partitioned) build side's valid rows are
       shuffled to shard ``right_key % n_shards`` — each owner holds its
       hash bucket of the dimension table, O(build/shards) rows.
    2. Probe requests: each shard shuffles its probe keys to the same
       owners.
    3. Local match: one ``ops.fk_join`` of the request rows against the
       local build bucket (requests carry p = 1, so the join returns the
       matched build probability directly, zero / zero-filled columns on
       miss).
    4. Responses shuffle home through the same static buckets and land in
       the probe rows' original positions — the output keeps the LEFT
       side's RowBlocked layout and is bit-identical to the gathered
       ``ops.fk_join`` (same matches, same float products, same
       deterministic zeros on miss).

    Overflow accounting: bucket overflows on either exchange lose rows the
    exact result needs, so the total overflow (one psum, so every shard
    agrees) POISONS the output probabilities with NaN rather than
    returning silently wrong masses.  The NaN propagates through every
    probabilistic epilogue (confidence / group_confidence / aggregate all
    consume the p column), but a purely BOOLEAN consumer of the join —
    e.g. a deterministic-mode predicate like ``p > 0.5`` — collapses NaN
    to False and can present the corruption as an empty result; validity
    flags and integer columns have no NaN to carry.  Where that matters,
    make overflow impossible instead of detectable: ``shuffle_slack >=
    n_shards`` pins every bucket at the sender's full local rows (the
    default slack 4.0 already guarantees this for meshes of up to 4 data
    shards), or keep join keys balanced mod n_shards.
    """
    axis_names = tuple(axis_names)
    right_cols = list(right_cols)
    KEY, PROB, HIT = _KEY, _PROB, "\x00hit"
    _check_exchange_cols("shuffle_fk_join right_cols", right_cols)

    # 1. build side -> hash owners
    build, b_over = _exchange_build(right, right_key, right_cols,
                                    axis_names, n_shards, build_bucket)

    # 2. probe keys -> the same owners
    lkey = left[left_key].astype(jnp.int32)
    preq, pmask, slot, sent, p_over = shuffle_by_key(
        lkey, {KEY: lkey}, axis_names, n_shards=n_shards,
        capacity=probe_bucket, valid=left.valid)

    # 3. shard-local match on the hash bucket
    req = Table({left_key: preq[KEY]},
                jnp.ones(pmask.shape, left.prob.dtype), pmask)
    matched = ops.fk_join(req, build, left_key, right_key, right_cols)

    # 4. responses home, into the probe rows' original positions
    resp = {PROB: matched.prob, HIT: matched.valid}
    for c in right_cols:
        resp[c] = matched[c]
    back = shuffle_back(resp, axis_names, n_shards, probe_bucket)
    got = ops.take_from_buckets(back, slot, sent)

    _record_leg(report, label, "build", axis_names, b_over,
                right[right_key], right.valid, n_shards, build_bucket)
    _record_leg(report, label, "probe", axis_names, p_over,
                lkey, left.valid, n_shards, probe_bucket)
    over = jax.lax.psum(b_over + p_over, axis_names)
    prob = left.prob * got[PROB]
    prob = jnp.where(over > 0, jnp.asarray(jnp.nan, prob.dtype), prob)
    cols = dict(left.columns)
    for c in right_cols:
        cols[c] = got[c]
    return Table(cols, prob, left.valid & got[HIT], left.part)


def copartitioned_fk_join(left: Table, right: Table, left_key: str,
                          right_key: str, right_cols: Sequence[str],
                          carry_cols: Sequence[str], axis_names, *,
                          n_shards: int, build_bucket: int,
                          probe_bucket: int, chunk_size: int,
                          num_chunks: int, report=None,
                          label: str = "") -> Table:
    """Hash-partitioned FK join WITHOUT the response round-trip (the
    CoPartitionedJoin strategy of :mod:`repro.db.physical`): matched rows
    STAY at their ``left_key % n_shards`` owner so a downstream GROUP BY
    on the join key aggregates in place.

    Differences from :func:`shuffle_fk_join`:

    * probe rows ship (key, p, canonical-chunk id, ``carry_cols``) — the
      columns the downstream aggregation reads — instead of the key alone;
    * the owner-local ``ops.fk_join`` consumes the REAL probe
      probabilities, so the output probability (p_l * p_r, deterministic
      zero on miss) is final at the owner;
    * there is no ``shuffle_back``: the output keeps the exchange's
      (sender-major, in-sender row order) bucket layout — which IS the
      global row order restricted to the owner — with the shipped chunk
      id under ``physical.CHUNK_COL``, and carries
      ``HashPartitioned(left_key)``.

    Overflow on either exchange is psum-accounted and NaN-poisons the
    output probabilities, exactly like :func:`shuffle_fk_join` (same
    boolean-consumer caveat; concrete-key adaptive buckets or
    ``shuffle_slack >= n_shards`` make overflow impossible).
    """
    axis_names = tuple(axis_names)
    right_cols = list(right_cols)
    carry_cols = list(carry_cols)
    _check_exchange_cols("copartitioned_fk_join columns",
                         right_cols + carry_cols)

    build, b_over = _exchange_build(right, right_key, right_cols,
                                    axis_names, n_shards, build_bucket)

    # The routing key is int32 (hash arithmetic); the key COLUMN ships in
    # its original dtype so group representatives keep their identity
    # values bit-identical to the unshuffled paths.
    lkey = left[left_key].astype(jnp.int32)
    pcols = {_KEY: left[left_key], _PROB: left.prob,
             phys.CHUNK_COL: _chunk_ids(left.capacity, axis_names,
                                        chunk_size, num_chunks)}
    for c in carry_cols:
        pcols[c] = left[c]
    precv, pmask, _, _, p_over = shuffle_by_key(
        lkey, pcols, axis_names, n_shards=n_shards,
        capacity=probe_bucket, valid=left.valid)

    probe = Table({left_key: precv[_KEY],
                   phys.CHUNK_COL: precv[phys.CHUNK_COL],
                   **{c: precv[c] for c in carry_cols}},
                  precv[_PROB], pmask, phys.HashPartitioned(left_key))
    out = ops.fk_join(probe, build, left_key, right_key, right_cols)
    _record_leg(report, label, "build", axis_names, b_over,
                right[right_key], right.valid, n_shards, build_bucket)
    _record_leg(report, label, "probe", axis_names, p_over,
                lkey, left.valid, n_shards, probe_bucket)
    over = jax.lax.psum(b_over + p_over, axis_names)
    return out.with_prob(jnp.where(
        over > 0, jnp.asarray(jnp.nan, out.prob.dtype), out.prob))


def repartition_by_key(t: Table, key: str, carry_cols: Sequence[str],
                       axis_names, *, n_shards: int, bucket: int,
                       chunk_size: int, num_chunks: int, report=None,
                       label: str = "") -> Table:
    """Hash-exchange a RowBlocked relation to its ``key % n_shards``
    owners (the Repartition strategy): the no-join feed of a
    PartitionedAgg.  Rows ship (key, p, canonical-chunk id, carry_cols);
    the output has the same bucket layout / chunk-id column /
    overflow-NaN contract as :func:`copartitioned_fk_join`."""
    axis_names = tuple(axis_names)
    carry_cols = list(carry_cols)
    _check_exchange_cols("repartition_by_key carry_cols", carry_cols)
    kcol = t[key].astype(jnp.int32)     # routing only; column ships as-is
    cols = {_KEY: t[key], _PROB: t.prob,
            phys.CHUNK_COL: _chunk_ids(t.capacity, axis_names,
                                       chunk_size, num_chunks)}
    for c in carry_cols:
        cols[c] = t[c]
    recv, mask, _, _, over = shuffle_by_key(
        kcol, cols, axis_names, n_shards=n_shards, capacity=bucket,
        valid=t.valid)
    _record_leg(report, label, "repart", axis_names, over,
                kcol, t.valid, n_shards, bucket)
    over = jax.lax.psum(over, axis_names)
    prob = jnp.where(over > 0, jnp.asarray(jnp.nan, recv[_PROB].dtype),
                     recv[_PROB])
    return Table({key: recv[_KEY], phys.CHUNK_COL: recv[phys.CHUNK_COL],
                  **{c: recv[c] for c in carry_cols}},
                 prob, mask, phys.HashPartitioned(key))


def group_ids_sharded(table: Table, keys: Sequence[str], max_groups: int,
                      axis_names):
    """Two-phase distributed group-id assignment (call inside shard_map).

    Phase 1: per-shard ``jnp.unique`` of the live key codes (size
    max_groups, sentinel fill).  Phase 2: one tiled all-gather of the
    per-shard code tables + a second unique merge, giving every shard the
    same global code table; ids come from searchsorted of the LOCAL codes
    against it.  Replaces the replicated full-table unique: per-shard
    work/memory is O(local rows + shards * max_groups), and the result is
    bit-identical to ``operators.group_ids`` (see
    ``operators.merge_group_codes`` for the overflow argument).
    """
    axis_names = tuple(axis_names)
    code_live, big = ops.live_key_codes(table, keys)
    local = ops.merge_group_codes(code_live, max_groups)
    gathered = jax.lax.all_gather(local, axis_names, axis=0, tiled=True)
    merged = ops.merge_group_codes(gathered, max_groups)
    return ops.codes_to_ids(code_live, merged), merged, merged != big


def group_key_columns_sharded(table: Table, keys: Sequence[str], ids,
                              max_groups: int, axis_names):
    """Per-group key representatives over a row-partitioned table: local
    segment_max, then one pmax over the data axes (max is exact, so this
    is bit-equal to the replicated reduction)."""
    axis_names = tuple(axis_names)
    cols = ops.group_key_columns(table, keys, ids, max_groups)
    return {k: jax.lax.pmax(v, axis_names) for k, v in cols.items()}


def allgather_merge(udas: dict, parts: list, axis_names,
                    num_chunks: int, shards: int) -> dict:
    """The sharded frontend's ONE collective Merge per aggregation pass:
    all-gather every shard's per-canonical-chunk partial states and fold
    ALL ``num_chunks`` chunk states with ``uda.tree_fold``, identically on
    every shard.

    ``parts`` is this shard's list of per-chunk state dicts
    (``uda.accumulate_chunk_states`` over its contiguous chunk run); under
    the contiguous chunk assignment the shard-major gather order IS the
    global chunk order, and slots past the canonical grid (the padding
    chunks of shard counts that don't divide ``num_chunks``) sort last and
    are sliced away before the fold.  Because the fold consumes the SAME
    chunk leaves in the SAME fixed tree as the single-device
    ``uda.accumulate_chunked``, the result is bit-identical for ANY shard
    count — power of two or not.  For additive states this computes
    exactly what a psum would; non-additive states (MinMax) ride the same
    code path.

    Bandwidth: when every shard's chunk run is an ALIGNED power-of-two
    subtree of the canonical tree (pow2 shard count dividing a pow2 grid
    — the common case), each shard pre-folds its run locally and the
    gather moves ONE state per shard; only non-dividing shard counts pay
    for gathering ceil(num_chunks / shards) chunk states each.
    """
    axis_names = tuple(axis_names)
    local = len(parts)
    aligned = (shards * local == num_chunks
               and local & (local - 1) == 0 and shards & (shards - 1) == 0)
    out = {}
    for name, u in udas.items():
        mine = [p[name] for p in parts]
        if aligned:
            mine = [uda.tree_fold(u, mine)]     # the local aligned subtree
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *mine)
        g = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis_names, axis=0, tiled=True),
            stacked)
        leaves = shards if aligned else num_chunks
        states = [jax.tree.map(lambda x, c=c: x[c], g)
                  for c in range(leaves)]
        out[name] = uda.tree_fold(u, states)
    return out


def gather_chunk_states(udas: dict, parts: list, axis_names) -> list:
    """All-gather per-chunk partial states WITHOUT folding them: the
    per-wave collective of the streamed executor.

    ``parts`` is this shard's list of per-chunk state dicts for ONE wave;
    the return value is the global list (shard-major = the wave's chunk
    slot order) of per-chunk state dicts, replicated on every shard.  The
    caller (plans.run's streamed wave loop) maps each entry to its
    canonical chunk slot and folds ONCE after the last wave, so the fold
    consumes exactly the leaves — in exactly the tree — of the resident
    ``allgather_merge`` / ``accumulate_chunked`` path."""
    axis_names = tuple(axis_names)
    _count("gather_chunks")
    states: list | None = None
    for name, u in udas.items():
        mine = [p[name] for p in parts]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *mine)
        g = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis_names, axis=0, tiled=True),
            stacked)
        total = jax.tree.leaves(g)[0].shape[0]
        if states is None:
            states = [dict() for _ in range(total)]
        for c in range(total):
            states[c][name] = jax.tree.map(lambda x, c=c: x[c], g)
    return states or []


def _scatter_sum_gather(state, axis_names, n_shards: int):
    """psum via reduce-scatter + all-gather: each leaf is split along its
    leading (group) axis, every shard sums ONLY its 1/n_shards stripe, and
    the gather reassembles the full state — (2/n_shards) x the psum's
    per-device payload.  Bit-identical to the psum here because every
    element is exact init-zero on all shards but its group's owner, so
    whatever the summation order, it adds x + 0 + ... + 0 = x."""
    def leaf(x):
        g = x.shape[0]
        pad = (-g) % n_shards
        if pad:
            x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
        y = jax.lax.psum_scatter(x, axis_names, scatter_dimension=0,
                                 tiled=True)
        y = jax.lax.all_gather(y, axis_names, axis=0, tiled=True)
        return y[:g] if pad else y
    return jax.tree.map(leaf, state)


def partitioned_merge(udas: dict, parts: list, axis_names,
                      n_shards: int | None = None) -> dict:
    """The HashPartitioned Merge (PartitionedAgg): combine per-owner
    canonical-chunk states into the replicated final state.

    ``parts`` is this owner's list of ALL ``num_chunks`` canonical chunk
    states (the compound (chunk, group) accumulate of the fused pipeline
    computes every chunk's slice locally; a chunk's slice is nonzero only
    for groups this shard owns).  Because a group's tuples live wholly at
    its ``key % n_shards`` owner, the owner's chunk-c state for group g
    IS the global chunk-c state for g — so folding the chunks LOCALLY
    with the one fixed :func:`repro.core.uda.tree_fold` gives the exact
    canonical fold for the owned groups, and every other shard holds
    exact init-zeros there.  The cross-shard merge is then

    * additive states: ONE reduce-scatter onto the group owners + one
      all-gather of the owner stripes (``n_shards`` given; a plain psum
      else) — x + 0 + ... + 0 is bitwise x whichever shard sums it, so
      the result is BIT-IDENTICAL to the RowBlocked ``allgather_merge``
      fold (and to mesh=None), while moving O(state / n_shards) bytes
      per leg instead of the psum's O(state) — each owner only ever sums
      the stripe it is about to broadcast;
    * non-additive states (MinMax): one all-gather + the owner-order
      merge fold — ``MinMax.merge(init, x) == x`` bitwise (the run-fold
      merge preserves singleton runs exactly), so the same argument
      applies.

    The bit-identity argument needs every group wholly at one owner,
    which the group-id protocol guarantees as long as the key
    cardinality fits ``max_groups``; the overflow fill bucket (invalid
    in every path) may psum several owners' garbage together.
    """
    axis_names = tuple(axis_names)
    out = {}
    for name, u in udas.items():
        folded = uda.tree_fold(u, [p[name] for p in parts])
        _count("merge_psum" if u.additive else "merge_gather")
        if u.additive and n_shards is not None and n_shards > 1:
            out[name] = _scatter_sum_gather(folded, axis_names, n_shards)
        else:
            # reduce_data for both shapes: the additive default psums,
            # MinMax overrides it with the all-gather + merge fold.
            out[name] = u.reduce_data(folded, axis_names)
    return out


def make_query_step(mesh: Mesh, *, max_groups: int = 1024,
                    num_freq: int = 4096, orders: int = 8,
                    data_axes: Sequence[str] = ("data",),
                    model_axis: str | None = "model"):
    """The canonical distributed aggregate-query step for `mesh`.

    Inputs (sharded over data axes):
        probs  (n,) f32, values (n,) f32, gids (n,) int32
    Output (replicated): finalized per-group confidence, normal terms,
    cumulant sums, and the exact global distribution (num_freq coeffs),
    the latter accumulated over the model axis's frequency slices.
    """
    model = model_axis if (model_axis and model_axis in mesh.axis_names) \
        else None
    model_size = mesh.shape[model] if model else 1
    assert num_freq % model_size == 0
    f_loc = num_freq // model_size

    def factory(size, rank):
        cf = uda.SumCF(num_freq, freq_lo=rank * f_loc, freq_cnt=f_loc)
        cf.scalar = True          # global distribution: one group
        return dict(conf=uda.AtLeastOne(), normal=uda.SumNormal(),
                    cum=uda.SumCumulants(orders), cf=cf)

    def post(udas, states):
        confidence = udas["conf"].finalize(states["conf"])
        coeffs = udas["cf"].finalize(states["cf"])[0]
        return (confidence, states["normal"].terms, states["cum"].terms,
                coeffs)

    return make_uda_step(mesh, factory, max_groups=max_groups,
                         data_axes=data_axes, model_axis=model_axis,
                         post=post)


def shard_columns(mesh: Mesh, arrays, data_axes: Sequence[str] = ("data",)):
    """Place host arrays with tuple-sharded layout on the mesh."""
    sharding = NamedSharding(mesh, P(_tuple_axes(mesh, data_axes)))
    return tuple(jax.device_put(a, sharding) for a in arrays)


def input_specs(*, n_tuples: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the distributed query step inputs."""
    return dict(
        probs=jax.ShapeDtypeStruct((n_tuples,), dtype),
        values=jax.ShapeDtypeStruct((n_tuples,), dtype),
        gids=jax.ShapeDtypeStruct((n_tuples,), jnp.int32),
    )
