"""Distributed query execution: generic shard_map glue over the segment-UDA
protocol of :mod:`repro.core.uda`.

The paper scales by streaming partitions through per-core UDAs and merging
(Glade's Accumulate/Merge).  On a TPU pod the same structure is:

    Accumulate  per-shard: the ONE canonical blocked accumulation loop
                (`uda.accumulate`) over the local tuple partition
    Merge       `uda.reduce_collective`: one psum over the data axes per
                additive state (log-CF / cumulants / log(1-p) are all
                additive — DESIGN.md §2); MinMax gather-folds instead
    Finalize    replicated FFT / mixture solve epilogue

``make_uda_step`` builds that pipeline for ANY dict of registered UDAs —
the generic aggregation-only step that ``make_query_step`` specialises to
the canonical fixed query shape (confidence + normal + cumulants + exact
global CF) which launch/dryrun.py lowers for the `pgf_tpch` cell.
Tuples are sharded over ('pod','data') — the (batch-like) scale axis — and
replicated over 'model'; frequency grids of the exact CF path are sharded
over 'model' so the O(n*F) phase work splits both ways (the beyond-paper
optimization validated in §Perf).

The sharded relational frontend (`db/plans.py`, strategies lowered by
`db/physical.py`) runs the WHOLE physical plan inside one shard_map and
uses the collective helpers below instead of a per-node step:

    gather_table        broadcast a row-partitioned Table (small FK-join
                        build sides, final sharded results): one tiled
                        all-gather per column, shard-major == global row
                        order under the contiguous row partitioning
    shuffle_by_key      static-shape all_to_all exchange: each row goes to
                        shard ``key % n_shards`` through per-destination
                        send buckets of fixed capacity, with overflow
                        accounting (operators.bucket_slots)
    shuffle_fk_join     the ShuffleJoin executor: build rows hashed to
                        their key's owner shard, probe keys exchanged as
                        requests, matched shard-locally (ops.fk_join on
                        the hash bucket), responses shuffled home — peak
                        build rows/device O(build/shards), output
                        bit-identical to the gathered join
    group_ids_sharded   two-phase distributed group-id assignment —
                        per-shard jnp.unique, all-gather + merge of the
                        per-shard code tables, searchsorted against the
                        merged codes (exact vs the single-pass oracle,
                        overflow included: operators.merge_group_codes)
    allgather_merge     ONE collective Merge per aggregation pass: gather
                        every shard's per-canonical-chunk partial states
                        and fold ALL chunk states with the one fixed tree
                        (uda.tree_fold) — the bit-reproducible form of the
                        additive psum for ANY shard count (pow2 or not),
                        which also covers non-additive states (MinMax)
    group_key_columns_sharded   per-shard segment_max + one pmax (max is
                        exact, so bit-equal to the replicated reduction)
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core import uda
from . import operators as ops
from . import physical as phys
from .table import Table


def _tuple_axes(mesh: Mesh, data_axes: Sequence[str]) -> tuple:
    return tuple(a for a in ("pod",) + tuple(data_axes)
                 if a in mesh.axis_names)


def make_uda_step(mesh: Mesh, uda_factory: Callable[[int, object], dict], *,
                  max_groups: int, data_axes: Sequence[str] = ("data",),
                  model_axis: str | None = "model", block: int = 8192,
                  post=None):
    """Build a jit-able distributed Accumulate/Merge/Finalize step.

    uda_factory(model_size, model_rank) -> {name: UDA}; ``model_rank`` is a
    traced axis index inside shard_map (0 without a model axis), so CF UDAs
    can bind their per-shard frequency slice.

    The returned step takes (probs, values, gids) with tuples sharded over
    the data axes (values may be a dict of per-UDA columns) and returns the
    replicated finalized results — or ``post(udas, states)`` if given.
    """
    axes = _tuple_axes(mesh, data_axes)
    model = model_axis if (model_axis and model_axis in mesh.axis_names) \
        else None
    model_size = mesh.shape[model] if model else 1
    in_spec = P(axes)

    def step(probs, values, gids):
        def shard_fn(p, v, g):
            rank = jax.lax.axis_index(model) if model else 0
            udas = uda_factory(model_size, rank)
            states = uda.accumulate(udas, p, v, g, max_groups=max_groups,
                                    block=block)
            states = uda.reduce_collective(udas, states, axes, model)
            if post is not None:
                return post(udas, states)
            return uda.finalize(udas, states)

        fn = shard_map(shard_fn, mesh=mesh,
                       in_specs=(in_spec, in_spec, in_spec),
                       out_specs=P(), check_vma=False)
        return fn(probs, values, gids)

    return jax.jit(step)


def pad_for(mesh: Mesh, probs, values, gids, *, max_groups: int,
            data_axes: Sequence[str] = ("data",)):
    """Zero-pad tuple columns so the shard count divides them (p = 0 pads
    contribute nothing to any UDA; they land in the overflow group)."""
    shards = 1
    for a in _tuple_axes(mesh, data_axes):
        shards *= mesh.shape[a]
    n = probs.shape[0]
    pad = (-n) % shards
    if pad == 0:
        return probs, values, gids
    probs = jnp.pad(probs, (0, pad))
    gids = jnp.pad(gids, (0, pad), constant_values=max_groups - 1)
    if isinstance(values, dict):
        # Pad each distinct source array once so aggregates sharing a column
        # keep sharing it (uda.accumulate dedups value columns by identity).
        padded: dict = {}
        values = {k: None if v is None
                  else padded.setdefault(id(v), jnp.pad(v, (0, pad)))
                  for k, v in values.items()}
    elif values is not None:
        values = jnp.pad(values, (0, pad))
    return probs, values, gids


# ----------------------------------------------------- sharded frontend
def gather_table(t: Table, axis_names) -> Table:
    """Broadcast a row-partitioned Table (call inside shard_map): tiled
    all-gather of every column plus p and valid.  With the contiguous row
    partitioning of the sharded frontend, shard-major concatenation IS the
    original global row order, so the gathered table is bit-identical to
    the unsharded one."""
    axis_names = tuple(axis_names)
    g = lambda x: jax.lax.all_gather(x, axis_names, axis=0, tiled=True)
    return Table({k: g(v) for k, v in t.columns.items()},
                 g(t.prob), g(t.valid), phys.Replicated())


def shuffle_by_key(keys, cols: dict, axis_names, *, n_shards: int,
                   capacity: int, valid=None):
    """Static-shape shuffle exchange (call inside shard_map): row i goes
    to shard ``keys[i] % n_shards``.

    Every shard fills ``n_shards`` send buckets of ``capacity`` rows
    (``operators.bucket_slots`` assigns slots; ok-rows beyond a bucket's
    capacity overflow and are DROPPED but counted) and one ``all_to_all``
    transposes the buckets, so per-device exchange memory is the static
    ``n_shards * capacity`` rows regardless of skew.

    Returns ``(recv, recv_mask, slot, sent, overflow)``:
        recv       {name: (n_shards * capacity,) array} — bucket j*capacity
                   + r holds sender j's r-th row for THIS shard; empty
                   slots zero
        recv_mask  (n_shards * capacity,) bool occupancy
        slot, sent the local send-slot bookkeeping (route responses home
                   through the same buckets: ``shuffle_back`` +
                   ``operators.take_from_buckets``)
        overflow   local count of ok-rows dropped for capacity
    """
    axis_names = tuple(axis_names)
    ok = jnp.ones(keys.shape, bool) if valid is None else valid
    dest = jnp.mod(keys.astype(jnp.int32), n_shards)
    slot, sent, overflow = ops.bucket_slots(dest, ok, n_shards, capacity)
    size = n_shards * capacity
    send = ops.scatter_to_buckets(cols, slot, size)
    mask = jnp.zeros((size,), bool).at[slot].set(sent, mode="drop")
    recv = {k: _all_to_all_rows(v, axis_names, n_shards, capacity)
            for k, v in send.items()}
    recv_mask = _all_to_all_rows(mask, axis_names, n_shards, capacity)
    return recv, recv_mask, slot, sent, overflow


def shuffle_back(cols: dict, axis_names, n_shards: int, capacity: int):
    """Return per-request responses to their origin shards: the inverse
    exchange of :func:`shuffle_by_key` (all_to_all is an involution on the
    (n_shards, capacity) bucket layout), landing each response in the send
    slot its request came from."""
    axis_names = tuple(axis_names)
    return {k: _all_to_all_rows(v, axis_names, n_shards, capacity)
            for k, v in cols.items()}


def _all_to_all_rows(x, axis_names, n_shards: int, capacity: int):
    b = x.reshape((n_shards, capacity) + x.shape[1:])
    out = jax.lax.all_to_all(b, axis_names, split_axis=0, concat_axis=0,
                             tiled=False)
    return out.reshape((n_shards * capacity,) + x.shape[1:])


def shuffle_fk_join(left: Table, right: Table, left_key: str,
                    right_key: str, right_cols: Sequence[str], axis_names,
                    *, n_shards: int, build_bucket: int,
                    probe_bucket: int) -> Table:
    """Hash-partitioned FK join (call inside shard_map): the ShuffleJoin
    strategy of :mod:`repro.db.physical`.

    1. Build exchange: the (row-partitioned) build side's valid rows are
       shuffled to shard ``right_key % n_shards`` — each owner holds its
       hash bucket of the dimension table, O(build/shards) rows.
    2. Probe requests: each shard shuffles its probe keys to the same
       owners.
    3. Local match: one ``ops.fk_join`` of the request rows against the
       local build bucket (requests carry p = 1, so the join returns the
       matched build probability directly, zero / zero-filled columns on
       miss).
    4. Responses shuffle home through the same static buckets and land in
       the probe rows' original positions — the output keeps the LEFT
       side's RowBlocked layout and is bit-identical to the gathered
       ``ops.fk_join`` (same matches, same float products, same
       deterministic zeros on miss).

    Overflow accounting: bucket overflows on either exchange lose rows the
    exact result needs, so the total overflow (one psum, so every shard
    agrees) POISONS the output probabilities with NaN rather than
    returning silently wrong masses.  The NaN propagates through every
    probabilistic epilogue (confidence / group_confidence / aggregate all
    consume the p column), but a purely BOOLEAN consumer of the join —
    e.g. a deterministic-mode predicate like ``p > 0.5`` — collapses NaN
    to False and can present the corruption as an empty result; validity
    flags and integer columns have no NaN to carry.  Where that matters,
    make overflow impossible instead of detectable: ``shuffle_slack >=
    n_shards`` pins every bucket at the sender's full local rows (the
    default slack 4.0 already guarantees this for meshes of up to 4 data
    shards), or keep join keys balanced mod n_shards.
    """
    axis_names = tuple(axis_names)
    right_cols = list(right_cols)
    # Internal exchange fields ride the same bucket dicts as the carried
    # user columns; the "\x00" prefix keeps them out of any legal column
    # namespace (a user column can't collide silently — it is rejected).
    KEY, PROB, HIT = "\x00key", "\x00prob", "\x00hit"
    bad = [c for c in right_cols if c.startswith("\x00")]
    if bad:
        raise ValueError(f"shuffle_fk_join right_cols may not start with "
                         f"'\\x00' (reserved for exchange fields): {bad}")

    # 1. build side -> hash owners
    bcols = {KEY: right[right_key].astype(jnp.int32), PROB: right.prob}
    for c in right_cols:
        bcols[c] = right[c]
    brecv, bmask, _, _, b_over = shuffle_by_key(
        bcols[KEY], bcols, axis_names, n_shards=n_shards,
        capacity=build_bucket, valid=right.valid)
    build = Table({right_key: brecv[KEY],
                   **{c: brecv[c] for c in right_cols}},
                  brecv[PROB], bmask, phys.HashPartitioned(right_key))

    # 2. probe keys -> the same owners
    lkey = left[left_key].astype(jnp.int32)
    preq, pmask, slot, sent, p_over = shuffle_by_key(
        lkey, {KEY: lkey}, axis_names, n_shards=n_shards,
        capacity=probe_bucket, valid=left.valid)

    # 3. shard-local match on the hash bucket
    req = Table({left_key: preq[KEY]},
                jnp.ones(pmask.shape, left.prob.dtype), pmask)
    matched = ops.fk_join(req, build, left_key, right_key, right_cols)

    # 4. responses home, into the probe rows' original positions
    resp = {PROB: matched.prob, HIT: matched.valid}
    for c in right_cols:
        resp[c] = matched[c]
    back = shuffle_back(resp, axis_names, n_shards, probe_bucket)
    got = ops.take_from_buckets(back, slot, sent)

    over = jax.lax.psum(b_over + p_over, axis_names)
    prob = left.prob * got[PROB]
    prob = jnp.where(over > 0, jnp.asarray(jnp.nan, prob.dtype), prob)
    cols = dict(left.columns)
    for c in right_cols:
        cols[c] = got[c]
    return Table(cols, prob, left.valid & got[HIT], left.part)


def group_ids_sharded(table: Table, keys: Sequence[str], max_groups: int,
                      axis_names):
    """Two-phase distributed group-id assignment (call inside shard_map).

    Phase 1: per-shard ``jnp.unique`` of the live key codes (size
    max_groups, sentinel fill).  Phase 2: one tiled all-gather of the
    per-shard code tables + a second unique merge, giving every shard the
    same global code table; ids come from searchsorted of the LOCAL codes
    against it.  Replaces the replicated full-table unique: per-shard
    work/memory is O(local rows + shards * max_groups), and the result is
    bit-identical to ``operators.group_ids`` (see
    ``operators.merge_group_codes`` for the overflow argument).
    """
    axis_names = tuple(axis_names)
    code_live, big = ops.live_key_codes(table, keys)
    local = ops.merge_group_codes(code_live, max_groups)
    gathered = jax.lax.all_gather(local, axis_names, axis=0, tiled=True)
    merged = ops.merge_group_codes(gathered, max_groups)
    return ops.codes_to_ids(code_live, merged), merged, merged != big


def group_key_columns_sharded(table: Table, keys: Sequence[str], ids,
                              max_groups: int, axis_names):
    """Per-group key representatives over a row-partitioned table: local
    segment_max, then one pmax over the data axes (max is exact, so this
    is bit-equal to the replicated reduction)."""
    axis_names = tuple(axis_names)
    cols = ops.group_key_columns(table, keys, ids, max_groups)
    return {k: jax.lax.pmax(v, axis_names) for k, v in cols.items()}


def allgather_merge(udas: dict, parts: list, axis_names,
                    num_chunks: int, shards: int) -> dict:
    """The sharded frontend's ONE collective Merge per aggregation pass:
    all-gather every shard's per-canonical-chunk partial states and fold
    ALL ``num_chunks`` chunk states with ``uda.tree_fold``, identically on
    every shard.

    ``parts`` is this shard's list of per-chunk state dicts
    (``uda.accumulate_chunk_states`` over its contiguous chunk run); under
    the contiguous chunk assignment the shard-major gather order IS the
    global chunk order, and slots past the canonical grid (the padding
    chunks of shard counts that don't divide ``num_chunks``) sort last and
    are sliced away before the fold.  Because the fold consumes the SAME
    chunk leaves in the SAME fixed tree as the single-device
    ``uda.accumulate_chunked``, the result is bit-identical for ANY shard
    count — power of two or not.  For additive states this computes
    exactly what a psum would; non-additive states (MinMax) ride the same
    code path.

    Bandwidth: when every shard's chunk run is an ALIGNED power-of-two
    subtree of the canonical tree (pow2 shard count dividing a pow2 grid
    — the common case), each shard pre-folds its run locally and the
    gather moves ONE state per shard; only non-dividing shard counts pay
    for gathering ceil(num_chunks / shards) chunk states each.
    """
    axis_names = tuple(axis_names)
    local = len(parts)
    aligned = (shards * local == num_chunks
               and local & (local - 1) == 0 and shards & (shards - 1) == 0)
    out = {}
    for name, u in udas.items():
        mine = [p[name] for p in parts]
        if aligned:
            mine = [uda.tree_fold(u, mine)]     # the local aligned subtree
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *mine)
        g = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis_names, axis=0, tiled=True),
            stacked)
        leaves = shards if aligned else num_chunks
        states = [jax.tree.map(lambda x, c=c: x[c], g)
                  for c in range(leaves)]
        out[name] = uda.tree_fold(u, states)
    return out


def make_query_step(mesh: Mesh, *, max_groups: int = 1024,
                    num_freq: int = 4096, orders: int = 8,
                    data_axes: Sequence[str] = ("data",),
                    model_axis: str | None = "model"):
    """The canonical distributed aggregate-query step for `mesh`.

    Inputs (sharded over data axes):
        probs  (n,) f32, values (n,) f32, gids (n,) int32
    Output (replicated): finalized per-group confidence, normal terms,
    cumulant sums, and the exact global distribution (num_freq coeffs),
    the latter accumulated over the model axis's frequency slices.
    """
    model = model_axis if (model_axis and model_axis in mesh.axis_names) \
        else None
    model_size = mesh.shape[model] if model else 1
    assert num_freq % model_size == 0
    f_loc = num_freq // model_size

    def factory(size, rank):
        cf = uda.SumCF(num_freq, freq_lo=rank * f_loc, freq_cnt=f_loc)
        cf.scalar = True          # global distribution: one group
        return dict(conf=uda.AtLeastOne(), normal=uda.SumNormal(),
                    cum=uda.SumCumulants(orders), cf=cf)

    def post(udas, states):
        confidence = udas["conf"].finalize(states["conf"])
        coeffs = udas["cf"].finalize(states["cf"])[0]
        return (confidence, states["normal"].terms, states["cum"].terms,
                coeffs)

    return make_uda_step(mesh, factory, max_groups=max_groups,
                         data_axes=data_axes, model_axis=model_axis,
                         post=post)


def shard_columns(mesh: Mesh, arrays, data_axes: Sequence[str] = ("data",)):
    """Place host arrays with tuple-sharded layout on the mesh."""
    sharding = NamedSharding(mesh, P(_tuple_axes(mesh, data_axes)))
    return tuple(jax.device_put(a, sharding) for a in arrays)


def input_specs(*, n_tuples: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the distributed query step inputs."""
    return dict(
        probs=jax.ShapeDtypeStruct((n_tuples,), dtype),
        values=jax.ShapeDtypeStruct((n_tuples,), dtype),
        gids=jax.ShapeDtypeStruct((n_tuples,), jnp.int32),
    )
