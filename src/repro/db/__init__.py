"""Probabilistic relational layer (paper §IV-F, §VI, §VIII).

    table.py        columnar probabilistic tables with validity masks
    operators.py    sigma / pi / join operators (Table I) + grouped views
                    over the segment-UDA registry (repro.core.uda)
    plans.py        probabilistic -> deterministic plan DSL; compile_plan
                    is mesh-aware (same plan, single-device or distributed)
    tpch.py         synthetic TPC-H workload; Q1/Q3/Q6/Q18/Q20 in 4 modes,
                    expressed as plans and run through compile_plan
    distributed.py  generic shard_map glue over the UDA protocol
                    (Accumulate per shard / one-psum Merge / Finalize)
    serving.py      the query-serving layer: bounded structural plan
                    cache + batched parameterized execution (QueryService)
"""
from . import distributed, operators, plans, serving, tpch
from .table import Table

__all__ = ["Table", "distributed", "operators", "plans", "serving", "tpch"]
