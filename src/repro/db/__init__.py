"""Probabilistic relational layer (paper §IV-F, §VI, §VIII).

    table.py        columnar probabilistic tables with validity masks
    operators.py    sigma / pi / join / grouped-UDA operators (Table I)
    plans.py        probabilistic -> deterministic plan DSL
    tpch.py         synthetic TPC-H workload + Q1/Q3/Q6/Q18/Q20 in 4 modes
    distributed.py  shard_map query execution (psum UDA merge)
"""
from . import distributed, operators, plans, tpch
from .table import Table

__all__ = ["Table", "distributed", "operators", "plans", "tpch"]
