"""Cost model for the physical planner (:mod:`repro.db.physical`).

``lower_plan`` is a two-phase optimizer: it ENUMERATES candidate physical
pipelines per logical node (GatherJoin vs ShuffleJoin vs CoPartitionedJoin
for an FKJoin; PartialAgg vs Repartition/PartitionedAgg for an
aggregation) and COSTS each candidate here, picking the cheapest.  This
module is the whole model: every number the planner compares lives in one
place and is unit-tested directly (tests/test_cost.py), instead of being
implied by ``if rows > budget`` branches scattered through the lowering.

A :class:`Cost` is three device-level quantities:

    bytes_moved   collective payload bytes per device — all-gather /
                  all_to_all / psum traffic, scaled by ``(n-1)/n`` (a
                  1-shard collective moves nothing)
    peak_rows     peak resident column elements per device added by the
                  candidate (replicated build sides, exchange buffers,
                  live aggregation state)
    flops         per-tuple UDA state-update work (the §V kernels:
                  elements touched per tuple per aggregate)

and :meth:`CostModel.total` collapses them to comparable units: bytes,
plus ``peak_weight`` bytes charged per resident byte (memory pressure is
a real cost but cheaper than moving the byte), plus ``flop_weight`` bytes
per flop (the PGF pipeline is interconnect-bound at scale — §VII — so
compute is discounted).

Budget knobs survive ONLY as cost-model overrides: ``gather_budget``
(the PR-4 ``join_gather_budget``) adds an infinite-cost penalty to
GatherJoin above the budget and to the hash-exchange strategies at or
under it, so the gather/exchange flip point is exactly the PR-4 golden
behaviour; ``copartition`` and ``agg_shuffle_budget`` gate the fused
candidates the same way (see :func:`repro.db.physical.lower_plan`).
With the overrides disabled (``gather_budget=None``) the pure physical
estimates decide.
"""
from __future__ import annotations

import dataclasses

INF = float("inf")

#: orders carried by the SumCumulants UDA state (core/uda.py default).
CUMULANT_ORDERS = 8


@dataclasses.dataclass(frozen=True)
class Cost:
    """Device-level cost of one physical-plan candidate (see module doc)."""
    bytes_moved: float = 0.0
    peak_rows: float = 0.0
    flops: float = 0.0

    def __add__(self, other: "Cost") -> "Cost":
        # Pipeline stages stream: traffic and work add, residency peaks.
        return Cost(self.bytes_moved + other.bytes_moved,
                    max(self.peak_rows, other.peak_rows),
                    self.flops + other.flops)

    def fmt(self) -> str:
        return (f"bytes={int(self.bytes_moved)}, "
                f"rows={int(self.peak_rows)}, flops={int(self.flops)}")


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Parameters + overrides of the planner's cost model.

    ``gather_budget`` / ``copartition`` / ``agg_shuffle_budget`` are the
    budget-knob OVERRIDES (None / "auto" = decide purely from estimates);
    the remaining fields are the physical constants the estimates use.
    """
    n_shards: int = 1
    elem_bytes: int = 8           # f64 columns (enable_x64 test config)
    peak_weight: float = 0.05     # bytes charged per resident byte
    flop_weight: float = 0.02     # bytes charged per state-update flop
    gather_budget: int | None = 1 << 20
    copartition: object = "auto"  # True force / False never / "auto" cost
    agg_shuffle_budget: int | None = None
    shuffle_slack: float = 4.0
    #: out-of-core override: max resident rows per device for one scan.
    #: A Scan whose per-shard rows exceed it lowers to StreamedScan and
    #: its table stays host-side (None = everything device-resident).
    device_row_budget: int | None = None

    def total(self, c: Cost) -> float:
        """Collapse a Cost to one comparable number (bytes-equivalent)."""
        return (c.bytes_moved + self.peak_weight * self.elem_bytes
                * c.peak_rows + self.flop_weight * c.flops)

    @property
    def xfer(self) -> float:
        """Fraction of a collective payload that crosses the interconnect
        per device: (n-1)/n — one shard moves nothing."""
        return (self.n_shards - 1) / self.n_shards


# ------------------------------------------------------------ join costs
def gather_join(m: CostModel, build_rows: int, n_right_cols: int) -> Cost:
    """Broadcast join: all-gather the build side's (key, p, valid) +
    carried columns onto every device, probe locally."""
    w = n_right_cols + 3
    return Cost(bytes_moved=build_rows * w * m.elem_bytes * m.xfer,
                peak_rows=build_rows * w)


def shuffle_join(m: CostModel, build_bucket: int, probe_bucket: int,
                 n_right_cols: int) -> Cost:
    """Hash-partitioned join WITH the response round-trip home: build
    exchange (key, p + carried cols), probe-key requests, and the
    (p, hit + carried cols) responses each cross the all_to_all once.
    Buckets are per-(sender, owner) static capacities, so per-device
    buffer rows are ``n_shards * bucket``."""
    n = m.n_shards
    wb = n_right_cols + 2                 # build: key, p, cols
    wr = n_right_cols + 2                 # response: p, hit, cols
    bytes_moved = (n * build_bucket * wb + n * probe_bucket * (1 + wr)) \
        * m.elem_bytes * m.xfer
    peak = n * build_bucket * wb + n * probe_bucket * (1 + wr)
    return Cost(bytes_moved=bytes_moved, peak_rows=peak)


def copartitioned_join(m: CostModel, build_bucket: int, probe_bucket: int,
                       n_right_keep: int, n_carry: int) -> Cost:
    """Hash-partitioned join WITHOUT the trip home: probe rows ship their
    probability, canonical-chunk id and the columns the downstream
    aggregation needs, and matched rows STAY at their ``key % n_shards``
    owner.  No response exchange; the build exchange only carries the
    columns the aggregation reads (``n_right_keep <= n_right_cols``)."""
    n = m.n_shards
    wb = n_right_keep + 2                 # build: key, p, kept cols
    wp = n_carry + 3                      # probe: key, p, chunk, carries
    bytes_moved = (n * build_bucket * wb + n * probe_bucket * wp) \
        * m.elem_bytes * m.xfer
    peak = n * build_bucket * wb + n * probe_bucket * (wp + n_right_keep)
    return Cost(bytes_moved=bytes_moved, peak_rows=peak)


# ----------------------------------------------------- aggregation costs
def agg_state_elems(specs, max_groups: int, kappa: int, num_freq: int):
    """State footprint of one aggregation pass: ``(additive_elems,
    fold_elems, row_flops)``.

    ``additive_elems`` counts psum-able state elements (confidence +
    normal / cumulant / exact-CF states), ``fold_elems`` the gather-fold
    (MinMax) states, ``row_flops`` the per-tuple update work summed over
    the pass's UDAs — the units :class:`Cost` carries.
    """
    add = max_groups                      # AtLeastOne rides every pass
    fold = 0
    flops = 1.0
    for _name, _value, agg, method in specs:
        if agg in ("MIN", "MAX"):
            fold += max_groups * (2 * kappa + 2)
            flops += kappa
        elif method == "exact":
            add += max_groups * 2 * num_freq
            flops += num_freq
        elif method == "cumulants":
            add += max_groups * CUMULANT_ORDERS
            flops += 2 * CUMULANT_ORDERS
        else:                             # normal / COUNT
            add += max_groups * 2
            flops += 2
    return add, fold, flops


def partial_agg(m: CostModel, local_rows: int, chunks: int, add_elems: int,
                fold_elems: int, row_flops: float) -> Cost:
    """RowBlocked aggregation: per-shard per-canonical-chunk Accumulate,
    then ONE all-gather of ALL ``chunks`` chunk states (additive and
    fold states alike ride it) and the replicated canonical fold."""
    state = add_elems + fold_elems
    return Cost(bytes_moved=chunks * state * m.elem_bytes * m.xfer,
                peak_rows=chunks * state,
                flops=local_rows * row_flops)


def partitioned_agg(m: CostModel, buffer_rows: int, chunks: int,
                    add_elems: int, fold_elems: int,
                    row_flops: float) -> Cost:
    """HashPartitioned aggregation: every group lives wholly at its owner,
    so each owner folds its canonical-chunk states LOCALLY and the merge
    is ONE psum of the folded additive state (2x payload: reduce-scatter
    + all-gather) plus one ``n_shards``-way gather-fold for MinMax states
    — chunk-count-independent traffic, vs the ``chunks * state`` gather
    of :func:`partial_agg`.  Accumulation runs over the static exchange
    buffer (``n_shards * bucket`` rows, empty slots masked) in ONE
    compound (chunk, group) pass, so the live state is ``chunks`` times
    the per-group footprint — additive and MinMax alike."""
    bytes_moved = (2 * add_elems + m.n_shards * fold_elems) \
        * m.elem_bytes * m.xfer
    return Cost(bytes_moved=bytes_moved,
                peak_rows=chunks * (add_elems + fold_elems) + buffer_rows,
                flops=buffer_rows * row_flops)


# ----------------------------------------------------- out-of-core scans
@dataclasses.dataclass(frozen=True)
class WaveSchedule:
    """Static wave plan of one :class:`~repro.db.physical.StreamedScan`.

    The streamed executor ships the host table to the mesh as ``n_waves``
    uniform slabs of ``chunks_per_wave`` canonical-chunk slots
    (``wave_rows`` rows globally, ``wave_rows / n_shards`` per device);
    the host table is padded to ``padded_capacity`` rows so EVERY wave —
    ragged tail included — has the same shape, which keeps one compiled
    wave function and makes per-chunk UDA states independent of the wave
    size (the bit-identical-streaming contract).  Frozen + hashable so it
    rides on the physical node and keys the executor's jit cache.
    """
    chunk_rows: int          # csz: rows per canonical chunk slot
    local_chunks_per_wave: int
    n_waves: int
    n_shards: int

    @property
    def chunks_per_wave(self) -> int:
        return self.local_chunks_per_wave * self.n_shards

    @property
    def wave_rows(self) -> int:
        return self.chunks_per_wave * self.chunk_rows

    @property
    def padded_capacity(self) -> int:
        return self.n_waves * self.wave_rows


def wave_schedule(chunk_rows: int, chunks: int, shards: int,
                  budget: int | None,
                  override_chunks: int | None = None,
                  width: float = 1.0) -> WaveSchedule:
    """Pick the wave size for a streamed scan whose canonical chunk grid
    is ``chunks`` slots of ``chunk_rows`` rows.

    Double buffering holds 2 slabs per device, so the largest wave that
    fits the per-device row ``budget`` has ``budget // (2 * chunk_rows)``
    local chunk slots; clamped to [1, local_slots].  ``width`` is the
    pruned-slab relative row width ``(pruned_cols + 2) / (full_cols + 2)``
    — ``device_row_budget`` is calibrated against FULL rows, so a
    column-pruned slab of width 0.5 fits twice the rows in the same
    bytes and the wave widens accordingly (fewer waves, fewer
    transfers).  ``override_chunks`` (global chunk slots per wave,
    rounded up to the shard count) bypasses both — the test hook for
    pinning {1 chunk, ragged tail, whole-table} schedules."""
    csz = chunk_rows
    local_slots = -(-chunks // shards)            # chunk slots per shard
    if override_chunks is not None:
        local_cpw = max(1, -(-override_chunks // shards))
    else:
        eff = (budget or 0) if width >= 1.0 else int((budget or 0) / width)
        local_cpw = max(1, eff // (2 * csz))
    local_cpw = min(local_cpw, local_slots)
    n_waves = -(-local_slots // local_cpw)
    return WaveSchedule(chunk_rows=csz, local_chunks_per_wave=local_cpw,
                        n_waves=n_waves, n_shards=shards)


# ------------------------------------------------- batched parameter axis
def batched(c: Cost, n_points: int) -> Cost:
    """Cost of running one compiled plan vmapped over ``n_points``
    parameter points: every relational intermediate (and its traffic and
    work) materialises once PER POINT — the batch axis multiplies all
    three components.  What batching saves is the per-point TRACE +
    COMPILE, not the device work; the serving layer uses this to bound
    how many points share one launch (:func:`sweep_chunk_points`)."""
    return Cost(bytes_moved=c.bytes_moved * n_points,
                peak_rows=c.peak_rows * n_points,
                flops=c.flops * n_points)


def sweep_chunk_points(per_point_rows: float, budget_rows: int | None,
                       n_points: int) -> int:
    """Largest per-launch point count of an ``n_points`` parameter sweep
    whose batched peak rows (``per_point_rows`` each, the batch axis
    multiplies residency — see :func:`batched`) fit ``budget_rows``;
    floored at 1 so progress is always possible, and the whole sweep
    when no budget is set."""
    if not budget_rows or per_point_rows <= 0:
        return max(1, n_points)
    return max(1, min(n_points, int(budget_rows // per_point_rows)))


# ----------------------------------------------------- retry escalation
def escalated_slack(slack: float, n_shards: int) -> float:
    """The next ``shuffle_slack`` after an overflow: doubled, capped at
    ``n_shards`` — where :func:`repro.db.physical.bucket_capacity` pins
    every bucket at the sender's full local rows and overflow becomes
    impossible, so the ladder terminates in O(log n_shards) doublings
    even without a demand observation."""
    return min(float(n_shards), max(2.0 * slack, 1.0))


def halved_wave_chunks(sched: WaveSchedule) -> int:
    """The next ``stream_wave_chunks`` (global chunk slots per wave)
    after a persistent transfer fault: half the wave, floored at one
    chunk slot per shard — the smallest slab the streamed executor can
    ship, so the ladder terminates."""
    return max(1, sched.local_chunks_per_wave // 2) * sched.n_shards


def streamed_scan(m: CostModel, rows: int, wave_rows: int,
                  n_cols: int) -> Cost:
    """Out-of-core scan: every row crosses host→device once per streamed
    pass (column + p + valid payload, no (n-1)/n discount — it is a
    transfer, not a collective; the executor's group-discovery pass
    re-streams, the model charges the accumulate pass), and residency is
    two double-buffered slabs per device instead of the table.
    ``n_cols`` is the PRUNED column count when the lowering computed a
    ``StreamedScan.columns`` demand set — only demanded columns ride the
    wave slabs."""
    w = n_cols + 2
    return Cost(bytes_moved=rows * w * m.elem_bytes,
                peak_rows=2 * (wave_rows // max(1, m.n_shards)) * w)


def repartition(m: CostModel, bucket: int, n_carry: int) -> Cost:
    """Hash-exchange of aggregation inputs to their group-key owner:
    (key, p, chunk) + the value/carry columns the pass reads."""
    n = m.n_shards
    w = n_carry + 3
    return Cost(bytes_moved=n * bucket * w * m.elem_bytes * m.xfer,
                peak_rows=n * bucket * w)
