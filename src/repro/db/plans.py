"""Probabilistic -> deterministic plan mapping (paper §VI, Table I),
mesh-aware.

A Plan is a small dataflow DAG of operator nodes.  ``compile_plan`` walks
the DAG and emits one jit-able function  tables -> results , realising the
paper's central claim: probabilistic queries run on a *deterministic*
engine (here: XLA) once every probabilistic operator is rewritten to a
deterministic one + segment-UDA calls (:mod:`repro.core.uda`).

``compile_plan(root, mesh)`` compiles the SAME plan for a device mesh:
the relational scaffolding (scan/select/join/group-id assignment) stays
replicated, while every `GroupAgg` / `ReweightGreater` aggregation runs
the distributed Accumulate -> one-psum Merge -> replicated Finalize path
of :mod:`repro.db.distributed`, so any plan runs on any mesh with results
identical to the single-device compile.

Node zoo (Table I rows in brackets):

    Scan(name)                               [I]   R -> R^p
    Select(child, pred)                      [II]  sigma, deterministic cond
    Map(child, name, fn)                     [--]  computed column
    FKJoin(l, r, lk, rk, cols)               [IV]  join, deterministic cond
    Project(child, keys, max_groups)         [V]   GROUP BY + AtLeastOne
    GroupAgg(child, keys, agg, value, ...)   [VI]  GROUP BY + PGF UDAs
                                                   (+ `extra` riders share
                                                   ONE accumulation pass)
    ReweightGreater(child, agg_of, vs, ...)  [III] p *= P(SUM > threshold)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence

import jax.numpy as jnp

from ..core import uda
from . import operators as ops
from .table import Table


class Node:
    pass


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    name: str


@dataclasses.dataclass(frozen=True)
class Select(Node):
    child: Node
    pred: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Map(Node):
    """Attach a computed column `name` = fn(table) to the child relation."""
    child: Node
    name: str
    fn: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class FKJoin(Node):
    left: Node
    right: Node
    left_key: str
    right_key: str
    right_cols: tuple


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    keys: tuple
    max_groups: int


@dataclasses.dataclass(frozen=True)
class GroupAgg(Node):
    """Returns a dict of per-group UDA results, not a Table (PGF-valued
    columns live outside the 1NF Table, §VI-C).

    The primary aggregate lands under "sum" / "cumulants" / "minmax" (by
    method/agg); each `extra` entry (name, value_col, agg, method) rides the
    SAME accumulation pass and lands under its own name.  Group confidence
    (AtLeastOne) is always included.  `value` == "" means COUNT (all-ones).
    """
    child: Node
    keys: tuple
    value: str            # column to aggregate ("" = COUNT)
    agg: str              # SUM | COUNT | MIN | MAX
    max_groups: int
    method: str = "normal"  # normal | cumulants  (exact: ROADMAP open item)
    extra: tuple = ()
    kappa: int = 64       # MIN/MAX support capacity per group


@dataclasses.dataclass(frozen=True)
class ReweightGreater(Node):
    """sigma_{AGG(B) > C}: group child by keys, SUM(value), then keep each
    group with p = AtLeastOne * P(SUM > threshold) (Table I row III).
    The threshold is `threshold_col` (per-group column) when set, else the
    constant `threshold`; `carry_cols` are extra per-group columns kept on
    the output Table (all valid writers of a group agree)."""
    child: Node
    keys: tuple
    value: str
    threshold_col: str
    max_groups: int
    threshold: float | None = None
    carry_cols: tuple = ()


def _agg_uda(agg: str, method: str, kappa: int) -> uda.UDA:
    if agg in ("SUM", "COUNT"):
        if method == "normal":
            return uda.SumNormal()
        if method == "cumulants":
            return uda.SumCumulants()
        raise ValueError(
            f"GroupAgg method {method!r} is not supported by the planner "
            "(grouped exact-CF is a ROADMAP open item; use "
            "operators.group_logcf directly)")
    if agg in ("MIN", "MAX"):
        return uda.MinMax(kappa=kappa, sign=1.0 if agg == "MIN" else -1.0)
    raise ValueError(agg)


def _out_key(agg: str, method: str) -> str:
    if agg in ("MIN", "MAX"):
        return "minmax"
    return "cumulants" if method == "cumulants" else "sum"


_RESERVED_OUT_KEYS = frozenset({"valid", "keys", "confidence"})


def compile_plan(root: Node, mesh=None, *,
                 data_axes: Sequence[str] = ("data",),
                 model_axis: str | None = "model"):
    """Emit a function tables -> result (Table or dict of arrays).

    With ``mesh``, `GroupAgg` / `ReweightGreater` aggregation runs under
    shard_map on the mesh's data axes; results match the mesh=None compile.
    """
    # One jitted distributed step per aggregation node, built on first call
    # (the step depends only on the node's static config, not its data).
    dist_steps: dict = {}

    def accumulate(node, udas, t, values, ids, max_groups):
        """ONE pass over the child's tuples for every UDA of the node —
        distributed Accumulate/Merge when a mesh is given."""
        probs = t.masked_prob()
        if mesh is None:
            return uda.accumulate(udas, probs, values, ids,
                                  max_groups=max_groups)
        from . import distributed as dist
        step = dist_steps.get(id(node))
        if step is None:
            step = dist.make_uda_step(mesh, lambda size, rank: udas,
                                      max_groups=max_groups,
                                      data_axes=data_axes,
                                      model_axis=model_axis,
                                      post=lambda _u, states: states)
            dist_steps[id(node)] = step
        probs, values, ids = dist.pad_for(mesh, probs, values, ids,
                                          max_groups=max_groups,
                                          data_axes=data_axes)
        return step(probs, values, ids)

    def run(node: Node, tables: Dict[str, Table]):
        if isinstance(node, Scan):
            return tables[node.name]
        if isinstance(node, Select):
            return ops.select(run(node.child, tables), node.pred)
        if isinstance(node, Map):
            t = run(node.child, tables)
            return t.with_column(node.name, node.fn(t))
        if isinstance(node, FKJoin):
            return ops.fk_join(run(node.left, tables),
                               run(node.right, tables),
                               node.left_key, node.right_key,
                               list(node.right_cols))
        if isinstance(node, Project):
            return ops.project(run(node.child, tables), list(node.keys),
                               node.max_groups)
        if isinstance(node, GroupAgg):
            t = run(node.child, tables)
            ids, codes, gvalid = ops.group_ids(t, list(node.keys),
                                               node.max_groups)

            specs = [(_out_key(node.agg, node.method), node.value, node.agg,
                      node.method)] + list(node.extra)
            names = [s[0] for s in specs]
            clashes = set(names) & _RESERVED_OUT_KEYS
            if clashes or len(set(names)) != len(names):
                raise ValueError(
                    f"GroupAgg aggregate names must be unique and avoid "
                    f"{sorted(_RESERVED_OUT_KEYS)}; got {names}")
            udas = {"confidence": uda.AtLeastOne()}
            values: dict = {}
            cols: dict = {}        # convert each source column exactly once
            for name, value, agg, method in specs:
                udas[name] = _agg_uda(agg, method, node.kappa)
                if agg == "COUNT" or not value:
                    values[name] = None
                else:
                    if value not in cols:
                        cols[value] = t[value].astype(t.prob.dtype)
                    values[name] = cols[value]
            states = accumulate(node, udas, t, values, ids, node.max_groups)

            out = dict(valid=gvalid,
                       keys=ops.group_key_columns(t, list(node.keys), ids,
                                                  node.max_groups),
                       confidence=udas["confidence"].finalize(
                           states["confidence"]))
            for name, value, agg, method in specs:
                u, st = udas[name], states[name]
                if agg in ("MIN", "MAX"):
                    out[name] = ops.minmax_runs(u, st)
                else:
                    out[name] = u.finalize(st)
            return out
        if isinstance(node, ReweightGreater):
            if not node.threshold_col and node.threshold is None:
                raise ValueError("ReweightGreater needs threshold_col or a "
                                 "constant threshold")
            t = run(node.child, tables)
            ids, codes, gvalid = ops.group_ids(t, list(node.keys),
                                               node.max_groups)
            udas = {"confidence": uda.AtLeastOne(), "sum": uda.SumNormal()}
            values = {"sum": t[node.value].astype(t.prob.dtype)}
            states = accumulate(node, udas, t, values, ids, node.max_groups)
            mu, var = udas["sum"].finalize(states["sum"])
            conf = udas["confidence"].finalize(states["confidence"])

            carry = list(node.keys) + list(node.carry_cols)
            if node.threshold_col:
                gcols = ops.group_key_columns(
                    t, carry + [node.threshold_col], ids, node.max_groups)
                thr = gcols[node.threshold_col].astype(mu.dtype)
            else:
                gcols = ops.group_key_columns(t, carry, ids, node.max_groups)
                thr = jnp.asarray(node.threshold, mu.dtype)
            p_gt = ops.normal_greater(mu, var, thr)
            cols = {k: gcols[k] for k in carry}
            return Table(cols, conf * p_gt, gvalid)
        raise TypeError(node)

    return lambda tables: run(root, tables)
