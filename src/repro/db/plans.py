"""Probabilistic -> deterministic plan mapping (paper §VI, Table I).

A Plan is a small dataflow DAG of operator nodes.  ``compile_plan`` walks
the DAG and emits one jit-able function  tables -> results , realising the
paper's central claim: probabilistic queries run on a *deterministic*
engine (here: XLA) once every probabilistic operator is rewritten to a
deterministic one + PGF UDA calls.

Node zoo (Table I rows in brackets):

    Scan(name)                               [I]   R -> R^p
    Select(child, pred)                      [II]  sigma, deterministic cond
    FKJoin(l, r, lk, rk, cols)               [IV]  join, deterministic cond
    Project(child, keys, max_groups)         [V]   GROUP BY + AtLeastOne
    GroupAgg(child, keys, agg, value, ...)   [VI]  GROUP BY + PGF UDA
    ReweightGreater(child, agg_of, vs, ...)  [III] p *= P(SUM > threshold)

This layer is deliberately small — the paper's queries are hand-planned in
tpch.py; Plan exists so *new* queries compose without touching operators.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence

import jax.numpy as jnp

from . import operators as ops
from .table import Table


class Node:
    pass


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    name: str


@dataclasses.dataclass(frozen=True)
class Select(Node):
    child: Node
    pred: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class FKJoin(Node):
    left: Node
    right: Node
    left_key: str
    right_key: str
    right_cols: tuple


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    keys: tuple
    max_groups: int


@dataclasses.dataclass(frozen=True)
class GroupAgg(Node):
    """Returns a dict of per-group UDA results, not a Table (PGF-valued
    columns live outside the 1NF Table, §VI-C)."""
    child: Node
    keys: tuple
    value: str            # column to aggregate ("" = COUNT)
    agg: str              # SUM | COUNT | MIN | MAX
    max_groups: int
    method: str = "normal"  # normal | cumulants | exact


@dataclasses.dataclass(frozen=True)
class ReweightGreater(Node):
    """sigma_{AGG(B) > C}: group child by keys, SUM(value), then keep each
    group with p = AtLeastOne * P(SUM > threshold_col) (Table I row III)."""
    child: Node
    keys: tuple
    value: str
    threshold_col: str
    max_groups: int


def compile_plan(root: Node) -> Callable[[Dict[str, Table]], object]:
    """Emit a function tables -> result (Table or dict of arrays)."""

    def run(node: Node, tables: Dict[str, Table]):
        if isinstance(node, Scan):
            return tables[node.name]
        if isinstance(node, Select):
            return ops.select(run(node.child, tables), node.pred)
        if isinstance(node, FKJoin):
            return ops.fk_join(run(node.left, tables),
                               run(node.right, tables),
                               node.left_key, node.right_key,
                               list(node.right_cols))
        if isinstance(node, Project):
            return ops.project(run(node.child, tables), list(node.keys),
                               node.max_groups)
        if isinstance(node, GroupAgg):
            t = run(node.child, tables)
            ids, codes, gvalid = ops.group_ids(t, list(node.keys),
                                               node.max_groups)
            vals = (jnp.ones_like(t.prob) if node.agg == "COUNT" or not node.value
                    else t[node.value].astype(t.prob.dtype))
            out = dict(valid=gvalid,
                       keys=ops.group_key_columns(t, list(node.keys), ids,
                                                  node.max_groups),
                       confidence=ops.group_atleastone(t, ids,
                                                       node.max_groups))
            if node.agg in ("SUM", "COUNT"):
                if node.method == "normal":
                    out["sum"] = ops.group_normal_terms(t, vals, ids,
                                                        node.max_groups)
                elif node.method == "cumulants":
                    out["cumulants"] = ops.group_cumulant_terms(
                        t, vals, ids, node.max_groups)
                else:
                    raise ValueError(node.method)
            elif node.agg in ("MIN", "MAX"):
                out["minmax"] = ops.group_minmax(
                    t, t[node.value].astype(t.prob.dtype), ids,
                    node.max_groups, sign=1.0 if node.agg == "MIN" else -1.0)
            else:
                raise ValueError(node.agg)
            return out
        if isinstance(node, ReweightGreater):
            t = run(node.child, tables)
            ids, codes, gvalid = ops.group_ids(t, list(node.keys),
                                               node.max_groups)
            vals = t[node.value].astype(t.prob.dtype)
            mu, var = ops.group_normal_terms(t, vals, ids, node.max_groups)
            thr_cols = ops.group_key_columns(
                t, list(node.keys) + [node.threshold_col], ids,
                node.max_groups)
            p_gt = ops.normal_greater(
                mu, var, thr_cols[node.threshold_col].astype(mu.dtype))
            conf = ops.group_atleastone(t, ids, node.max_groups)
            cols = {k: thr_cols[k] for k in node.keys}
            return Table(cols, conf * p_gt, gvalid)
        raise TypeError(node)

    return lambda tables: run(root, tables)
