"""Probabilistic -> deterministic plan compilation (paper §VI, Table I):
a two-stage compiler over the logical plan DAG.

A Plan is a small dataflow DAG of LOGICAL operator nodes (the zoo below).
``compile_plan`` no longer interprets it directly: it first LOWERS the
logical DAG to an explicit physical-plan IR — :mod:`repro.db.physical`,
where every node carries its execution strategy and a partitioning
property (Replicated / RowBlocked / HashPartitioned) — and then an
EXECUTOR (this module) interprets the physical plan, realising the
paper's central claim: probabilistic queries run on a *deterministic*
engine (here: XLA) once every probabilistic operator is rewritten to a
deterministic one + segment-UDA calls (:mod:`repro.core.uda`).

``compile_plan(root, mesh)`` lowers the SAME logical plan for a device
mesh and runs the whole physical plan inside ONE shard_map — no stage
keeps a replicated copy of any base table:

    ShardScan       the shard-local block of the (chunk-padded) base table
    Select / Map    embarrassingly parallel on the local block
    GatherJoin      small build side: all-gather the right relation's
                    (key, p, cols) columns, probe locally
    ShuffleJoin     build side above ``join_gather_budget`` (the
                    ``FKJoin.gather_budget`` per-node override wins):
                    hash-partition build rows AND probe keys to
                    ``key % n_shards`` owners with ``dist.shuffle_by_key``
                    (static buckets, overflow accounted), match
                    shard-locally, shuffle responses home — peak build
                    rows/device O(build/shards), no replicated fallback
    CoPartitioned-  the fused shuffle -> aggregate pipeline: when the
    Join /          downstream GROUP BY keys on the probe join key, probe
    Repartition     rows ship (p, canonical chunk id, value columns) and
                    matched rows STAY at their owner — no shuffle-home
                    round-trip (``dist.copartitioned_fk_join``);
                    ``dist.repartition_by_key`` is the no-join feed
    group ids       two-phase distributed unique (exact under overflow;
                    `db.distributed.group_ids_sharded`) — owner-local
                    over HashPartitioned blocks, same merged code table
    PartialAgg /    per-shard, per-canonical-chunk UDA Accumulate, then
    MergeAgg        ONE collective per aggregation pass assembling every
                    chunk state (`db.distributed.allgather_merge`) and the
                    replicated Finalize; group-level outputs are
                    replicated Tables
    PartitionedAgg  the HashPartitioned Accumulate: ONE compound
                    (chunk, group) pass over the exchange buffer, the
                    canonical chunk fold finished LOCALLY per owner, and
                    one psum / gather-fold Merge
                    (`db.distributed.partitioned_merge`)

    Strategy choice is the enumerate -> cost -> pick pass of
    ``physical.lower_plan`` over the explicit model in ``db/cost.py``;
    the budget knobs survive as cost overrides.

Determinism contract: every aggregation pass folds its tuples over a
fixed grid of ``canonical_chunks`` contiguous chunks and merges the chunk
states in the one fixed tree of :func:`repro.core.uda.tree_fold`
(pow2-base + sequential tail).  Each chunk is computed wholly on one
shard and ALL chunk states are gathered before the fold, so ANY shard
count — 2, 3, 4, ... — computes the SAME tree and
``compile_plan(root, mesh)`` results are BIT-IDENTICAL to
``compile_plan(root, None)`` — asserted per-plan by the mesh-equivalence
harness in tests/conftest.py, including plans that lower to ShuffleJoin.
Per-device memory is O(rows / shards) for every pipeline stage (plus
gathered small build sides and group-level state), not O(total rows).

Node zoo (Table I rows in brackets):

    Scan(name)                               [I]   R -> R^p
    Select(child, pred)                      [II]  sigma, deterministic cond
    Map(child, name, fn)                     [--]  computed column
    FKJoin(l, r, lk, rk, cols[, budget])     [IV]  join, deterministic cond
    Project(child, keys, max_groups)         [V]   GROUP BY + AtLeastOne
    GroupAgg(child, keys, agg, value, ...)   [VI]  GROUP BY + PGF UDAs
                                                   (+ `extra` riders share
                                                   ONE accumulation pass)
    ReweightGreater(child, agg_of, vs, ...)  [III] p *= P(SUM > threshold)
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from types import SimpleNamespace
from typing import Callable, Dict, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..core import uda
from ..testing import faults
from . import cost as C
from . import operators as ops
from . import physical as phys
from .report import ExecutionReport, ReportBuilder, nan_count
from .table import HostTable, Table


class Node:
    pass


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    name: str


@dataclasses.dataclass(frozen=True)
class Select(Node):
    child: Node
    pred: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Map(Node):
    """Attach a computed column `name` = fn(table) to the child relation."""
    child: Node
    name: str
    fn: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class FKJoin(Node):
    """Many-to-one equijoin.  ``gather_budget`` overrides the compiler's
    global ``join_gather_budget`` for THIS join (rows of build side that
    may be all-gathered; larger builds lower to ShuffleJoin on a mesh), so
    mixed plans can gather small dimensions while shuffling large ones."""
    left: Node
    right: Node
    left_key: str
    right_key: str
    right_cols: tuple
    gather_budget: int | None = None


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    keys: tuple
    max_groups: int


@dataclasses.dataclass(frozen=True)
class GroupAgg(Node):
    """Returns a dict of per-group UDA results, not a Table (PGF-valued
    columns live outside the 1NF Table, §VI-C).

    The primary aggregate lands under "sum" / "cumulants" / "exact" /
    "minmax" (by method/agg); each `extra` entry (name, value_col, agg,
    method) rides the SAME accumulation pass and lands under its own name.
    Group confidence (AtLeastOne) is always included.  `value` == "" means
    COUNT (all-ones).

    ``method="exact"`` computes the full per-group SUM/COUNT distribution
    via the grouped log-CF UDA (Pallas-accelerated on TPU) and requires
    ``num_freq`` = max aggregate value + 1; the result is a (max_groups,
    num_freq) row-stochastic coefficient matrix.  When max_groups *
    num_freq exceeds the planner's ``cf_budget_elems``, the compiler
    accumulates the state in multiple passes over frequency slabs (each
    slab additively merged on a mesh) — see ``compile_plan``.
    """
    child: Node
    keys: tuple
    value: str            # column to aggregate ("" = COUNT)
    agg: str              # SUM | COUNT | MIN | MAX
    max_groups: int
    method: str = "normal"  # normal | cumulants | exact
    extra: tuple = ()
    kappa: int = 64       # MIN/MAX support capacity per group
    num_freq: int = 0     # exact: distribution capacity (max sum + 1)


@dataclasses.dataclass(frozen=True)
class ReweightGreater(Node):
    """sigma_{AGG(B) > C}: group child by keys, SUM(value), then keep each
    group with p = AtLeastOne * P(SUM > threshold) (Table I row III).
    The threshold is `threshold_col` (per-group column) when set, else the
    constant `threshold`; `carry_cols` are extra per-group columns kept on
    the output Table (all valid writers of a group agree)."""
    child: Node
    keys: tuple
    value: str
    threshold_col: str
    max_groups: int
    threshold: float | None = None
    carry_cols: tuple = ()


# ---------------------------------------------------- parameterized plans
@dataclasses.dataclass(frozen=True)
class Param:
    """A named scalar hole in a logical plan: the value arrives at RUN
    time (``compiled(tables, params={name: value})``) instead of being
    baked into the trace, so one compiled executable serves a whole
    family of queries — and ``jax.vmap`` over the params runs an N-point
    parameter sweep as ONE device program (see
    :class:`repro.db.serving.QueryService.sweep`).  Legal as
    :attr:`ReweightGreater.threshold` and inside :class:`Parameterized`
    predicates/column functions."""
    name: str


@dataclasses.dataclass(frozen=True)
class Parameterized:
    """A Select predicate / Map column function with lifted scalar
    parameters: ``fn(table, *values)`` receives the named params' values
    in ``params`` order.  Structurally hashable (the plan cache keys on
    the wrapped function's bytecode + the param names), and the executor
    feeds it the run's parameter environment."""
    fn: Callable
    params: tuple

    def __call__(self, t: Table, env: Dict[str, jnp.ndarray]):
        return self.fn(t, *(env[p] for p in self.params))


def plan_params(root: Node) -> frozenset:
    """The set of parameter names a logical plan needs at run time."""
    names: set = set()

    def walk(n):
        for f in ("child", "left", "right"):
            c = getattr(n, f, None)
            if isinstance(c, Node):
                walk(c)
        for f in ("pred", "fn"):
            v = getattr(n, f, None)
            if isinstance(v, Parameterized):
                names.update(v.params)
        if isinstance(getattr(n, "threshold", None), Param):
            names.add(n.threshold.name)

    walk(root)
    return frozenset(names)


def plan_key(root: Node) -> tuple:
    """Stable structural cache key of a logical plan: two separately
    constructed but identical plans (same node structure, same predicate
    bytecode and captured constants, same static knobs) produce EQUAL
    keys — the property the serving layer's plan cache and the streamed
    wave cache key executables on.  Delegates to
    :func:`repro.db.physical.structural_key`; unknown objects degrade to
    identity keys (a possible miss, never a false hit)."""
    return ("plan", phys.structural_key(root))


def mesh_fingerprint(mesh) -> tuple | None:
    """Hashable identity of a mesh for compile-cache keys (axis names,
    mesh shape and device ids — what the lowering and the collectives
    depend on); None for single-device compiles."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


class LRUCache:
    """Bounded least-recently-used mapping with hit/miss/eviction
    counters and an ``on_evict`` hook — the executable-cache primitive
    behind the streamed wave cache and :class:`repro.db.serving.
    PlanCache`.  The CPU jaxlib backend segfaults once a process
    accretes a few hundred live compiled executables (see
    docs/serving.md), so every cache holding compiled functions must
    bound its population and drop executables on eviction."""

    def __init__(self, capacity: int, on_evict: Callable | None = None):
        if capacity < 1:
            raise ValueError(f"LRUCache capacity must be >= 1, got "
                             f"{capacity}")
        self.capacity = capacity
        self.on_evict = on_evict
        self._data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def get(self, key, default=None):
        if key in self._data:
            self._data.move_to_end(key)
            self.hits += 1
            return self._data[key]
        self.misses += 1
        return default

    def put(self, key, value) -> None:
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        self._trim()

    def _trim(self) -> None:
        while len(self._data) > self.capacity:
            _, old = self._data.popitem(last=False)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(old)

    def set_capacity(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"LRUCache capacity must be >= 1, got "
                             f"{capacity}")
        self.capacity = capacity
        self._trim()

    def clear(self) -> None:
        """Evict everything (the on_evict hook runs for each entry)."""
        while self._data:
            _, old = self._data.popitem(last=False)
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(old)

    def info(self) -> dict:
        return dict(size=len(self._data), capacity=self.capacity,
                    hits=self.hits, misses=self.misses,
                    evictions=self.evictions)


def _drop_executables(fns) -> None:
    """LRU eviction hook: drop a jitted callable's (or tuple of
    callables') compiled executables so the compiler footprint stays
    flat."""
    if not isinstance(fns, tuple):
        fns = (fns,)
    for f in fns:
        clear = getattr(f, "clear_cache", None)
        if clear is not None:
            clear()


#: Process-wide BOUNDED jit cache of the streamed executor's per-wave
#: functions, keyed structurally (plan + mesh + grid params) so repeated
#: compiles of the same streamed plan — including separately constructed
#: identical plans — reuse one traced wave pair, while distinct plans
#: past the capacity evict the least-recently-used executables instead
#: of accreting until the CPU backend segfaults.  Replaces the unbounded
#: per-``compile_plan`` ``_wave_cache`` dict.
_WAVE_CACHE = LRUCache(capacity=32, on_evict=_drop_executables)


def set_wave_cache_capacity(capacity: int) -> int:
    """Resize the streamed executor's bounded wave-function cache
    (evicting down to the new capacity if needed).  Returns the
    previous capacity so callers can restore it."""
    old = _WAVE_CACHE.capacity
    _WAVE_CACHE.set_capacity(capacity)
    return old


def wave_cache_info() -> dict:
    """Size/capacity/hit/miss/eviction counters of the wave cache."""
    return _WAVE_CACHE.info()


#: Process-wide streamed-transfer counters (benchmarks / tests): bytes
#: actually shipped in wave slabs (column-pruned slabs count only the
#: pruned payload), wave count, and the host-side slab-slice seconds
#: (the gather from [mmap] host arrays into the ping-pong buffers — the
#: measured bottleneck on thin hosts that pruning attacks).
_STREAM_STATS = {"slab_bytes": 0, "waves": 0, "slice_s": 0.0}


def reset_stream_stats() -> None:
    """Zero the streamed-transfer counters (call before a measured run)."""
    _STREAM_STATS.update(slab_bytes=0, waves=0, slice_s=0.0)


def stream_stats() -> dict:
    """Snapshot of the streamed-transfer counters since the last reset."""
    return dict(_STREAM_STATS)


def _agg_uda(agg: str, method: str, kappa: int, num_freq: int = 0,
             freq_lo: int = 0, freq_cnt: int | None = None) -> uda.UDA:
    if agg in ("SUM", "COUNT"):
        if method == "normal":
            return uda.SumNormal()
        if method == "cumulants":
            return uda.SumCumulants()
        if method == "exact":
            if num_freq <= 0:
                raise ValueError(
                    "GroupAgg(method='exact') needs num_freq = max "
                    "aggregate value + 1 (the static distribution capacity)")
            return uda.SumCF(num_freq, freq_lo=freq_lo, freq_cnt=freq_cnt)
        raise ValueError(
            f"GroupAgg method {method!r} is not supported by the planner "
            "(expected 'normal', 'cumulants' or 'exact')")
    if agg in ("MIN", "MAX"):
        if method == "exact":
            raise ValueError(
                "GroupAgg method 'exact' applies to SUM/COUNT only; MIN/MAX "
                "distributions come from the MinMax UDA (kappa support)")
        return uda.MinMax(kappa=kappa, sign=1.0 if agg == "MIN" else -1.0)
    raise ValueError(agg)


def _out_key(agg: str, method: str) -> str:
    if agg in ("MIN", "MAX"):
        return "minmax"
    return {"cumulants": "cumulants", "exact": "exact"}.get(method, "sum")


def _freq_slabs(num_freq: int, max_groups: int, budget: int) -> tuple:
    """Split [0, num_freq) into slabs so each (max_groups, slab) exact-CF
    state stays within ``budget`` elements; slab widths stay lane-aligned
    (multiples of 128) so the Pallas kernel's frequency padding is bounded."""
    f_slab = max(1, budget // max(1, max_groups))
    if f_slab >= num_freq:
        return ((0, num_freq),)
    if f_slab > 128:
        f_slab -= f_slab % 128
    return tuple((lo, min(f_slab, num_freq - lo))
                 for lo in range(0, num_freq, f_slab))


# ---------------------------------------------------------------------------
# Aggregation-pass plumbing shared by the resident executor (run_agg) and
# the streamed wave loop: spec/value collection, the frequency-slab
# schedule, per-slab UDA construction, slab-state concatenation and the
# kind epilogue.  One copy => the two paths cannot drift apart.
def _pass_values(specs, t: Table) -> dict:
    """Fetch each spec's value column exactly once (uda.accumulate dedups
    shared columns by identity; the raw dtype is kept so integer sources
    stay kernel-eligible)."""
    values: dict = {}
    cols: dict = {}
    for name, value, agg, _method in specs:
        if agg == "COUNT" or not value:
            values[name] = None
        else:
            if value not in cols:
                cols[value] = t[value]
            values[name] = cols[value]
    return values


def _pass_slabs(pa, cf_budget_elems: int) -> tuple:
    """(exact aggregate names, frequency-slab schedule) of one pass."""
    exact_names = [s[0] for s in pa.specs if s[3] == "exact"]
    slabs = (_freq_slabs(pa.num_freq, pa.max_groups,
                         cf_budget_elems // (2 * len(exact_names)))
             if exact_names else ((0, pa.num_freq),))
    return exact_names, slabs


def _slab_udas(pa, si: int, lo: int, cnt: int, values: dict) -> tuple:
    """UDA dict + value dict of frequency-slab pass ``si``: pass 0 carries
    confidence and every non-exact aggregate; every pass carries the
    exact aggregates' (lo, cnt) frequency window."""
    udas_i: dict = {}
    vals_i: dict = {}
    if si == 0:
        udas_i["confidence"] = uda.AtLeastOne()
        vals_i["confidence"] = None
        for name, _value, agg, method in pa.specs:
            if method != "exact":
                udas_i[name] = _agg_uda(agg, method, pa.kappa)
                vals_i[name] = values[name]
    for name, _value, agg, method in pa.specs:
        if method == "exact":
            udas_i[name] = _agg_uda(agg, method, pa.kappa, pa.num_freq,
                                    lo, cnt)
            vals_i[name] = values[name]
    return udas_i, vals_i


def _append_slab(states: dict, udas: dict, udas_i: dict, sts: dict) -> None:
    """Fold one slab pass's merged states in: first slab registers the
    state (and its Finalize UDA), later slabs append their frequency
    window at the FOLDED level."""
    for name, st in sts.items():
        if name in states:              # append the frequency slab
            prev = states[name]
            states[name] = uda.CFState(
                jnp.concatenate([prev.log_abs, st.log_abs], -1),
                jnp.concatenate([prev.angle, st.angle], -1))
        else:
            states[name] = st
            udas[name] = udas_i[name]


def _lost_group_count(code_live, big, merged, ids):
    """Live rows whose group code was dropped past ``max_groups``: a
    dropped code can never equal ``merged[ids]`` (the table holds only
    the kept distinct codes), while every kept live code does — so the
    mismatch count is exactly the rows the group-code table lost."""
    return jnp.sum((code_live != big)
                   & (merged[ids] != code_live)).astype(jnp.int32)


def _finalize_pass(node, pa, udas: dict, states: dict, gvalid,
                   key_columns, rb=None, label: str = "", params=None):
    """The replicated epilogue of one aggregation pass, selected by
    ``node.kind``; ``key_columns(cols)`` returns the per-group
    representatives of the named columns.  With a :class:`ReportBuilder`
    the pass also files its diagnostics: NaN counts of every folded UDA
    state and the per-group §V-B.2 truncation mass of each MIN/MAX
    aggregate."""
    if rb is not None:
        for name, st in states.items():
            rb.state_nan(f"{label}.{name}", nan_count(st))
        for name, _value, agg, _method in pa.specs:
            if agg in ("MIN", "MAX"):
                rb.tail(f"{label}.{name}",
                        udas[name].tail_mass(states[name]))
    conf = udas["confidence"].finalize(states["confidence"])
    if node.kind == "project":
        gcols = key_columns(list(pa.keys))
        return Table(gcols, conf, gvalid, node.part)
    if node.kind == "reweight":
        mu, var = udas["sum"].finalize(states["sum"])
        carry = list(pa.keys) + list(node.carry_cols)
        if node.threshold_col:
            gcols = key_columns(carry + [node.threshold_col])
            thr = gcols[node.threshold_col].astype(mu.dtype)
        else:
            gcols = key_columns(carry)
            thr = node.threshold
            if isinstance(thr, Param):      # lifted constant: value at run
                thr = (params or {})[thr.name]
            thr = jnp.asarray(thr, mu.dtype)
        p_gt = ops.normal_greater(mu, var, thr)
        return Table({k: gcols[k] for k in carry}, conf * p_gt,
                     gvalid, node.part)
    out = dict(valid=gvalid, keys=key_columns(list(pa.keys)),
               confidence=conf)
    for name, _value, agg, _method in pa.specs:
        u, st = udas[name], states[name]
        if agg in ("MIN", "MAX"):
            out[name] = ops.minmax_runs(u, st)
        else:
            out[name] = u.finalize(st)
    return out


# ------------------------------------------------- streamed-plan surgery
def _iter_phys(node):
    yield node
    for f in ("child", "left", "right"):
        c = getattr(node, f, None)
        if isinstance(c, phys.PhysNode):
            yield from _iter_phys(c)


def _lowest_streamed_agg(node):
    """The LOWEST MergeAgg whose subtree contains a StreamedScan — the
    pass the wave loop executes; everything above it sees only the pass's
    replicated group-level output and runs resident."""
    if not phys._contains_streamed(node):
        return None
    for f in ("child", "left", "right"):
        c = getattr(node, f, None)
        if isinstance(c, phys.PhysNode):
            found = _lowest_streamed_agg(c)
            if found is not None:
                return found
    return node if isinstance(node, phys.MergeAgg) else None


def _swap_node(node, target, repl):
    """Rebuild the (frozen-dataclass) physical plan with ``target``
    replaced by ``repl``."""
    if node is target:
        return repl
    for f in ("child", "left", "right"):
        c = getattr(node, f, None)
        if isinstance(c, phys.PhysNode):
            new = _swap_node(c, target, repl)
            if new is not c:
                return dataclasses.replace(node, **{f: new})
    return node


#: reserved base-table name the streamed pass's replicated result is
#: re-injected under when the plan continues above it.
_STREAMED_RESULT = "\x00streamed"


def shard_capacity(capacity: int, canonical_chunks: int, shards: int) -> int:
    """The padded capacity ``compile_plan`` gives a base table: first the
    canonical chunk grid (chunk size csz = ceil(n / chunks)), then enough
    whole PADDING CHUNKS that every shard owns the same number of chunk
    slots — shards * ceil(chunks / shards) * csz rows.  For shard counts
    dividing the grid this adds nothing beyond the chunk padding; padding
    chunks hold only invalid p = 0 rows and their (identity) states are
    sliced away before the canonical fold."""
    csz = -(-capacity // canonical_chunks)
    local = -(-canonical_chunks // shards)
    return shards * local * csz


def compile_plan(root: Node, mesh=None, *,
                 data_axes: Sequence[str] = ("data",),
                 model_axis: str | None = "model",
                 cf_budget_elems: int = 1 << 22,
                 canonical_chunks: int = 8,
                 join_gather_budget: int = 1 << 20,
                 shuffle_slack: float = 4.0,
                 copartition: object = "auto",
                 agg_shuffle_budget: int | None = None,
                 cost_model=None,
                 device_row_budget: int | None = None,
                 stream_wave_chunks: int | None = None,
                 stream_double_buffer: bool = True,
                 stream_prune_columns: bool = True,
                 stats_tables: Dict[str, "Table | HostTable"] | None = None,
                 with_report: bool = False,
                 shuffle_bucket_floor: int | None = None,
                 stream_wave_retries: int = 2):
    """Emit a function tables -> result (Table or dict of arrays).

    With ``mesh``, the logical plan lowers to a sharded physical plan
    (:func:`repro.db.physical.lower_plan`) and the WHOLE plan runs inside
    one shard_map over the mesh's data axes — scans, selects, joins,
    group-id assignment and aggregation all consume shard-local row
    blocks (see module docstring for the per-operator strategies);
    results are bit-identical to the mesh=None compile for ANY data-shard
    count.  Tuples stay replicated over ``model_axis`` (every collective
    here runs on the data axes only, so model replicas remain
    bit-identical and need no reconciliation).

    ``canonical_chunks`` (any positive count) is the fixed accumulation
    grid that makes results shard-count-invariant.  ``join_gather_budget``
    caps the rows of an FKJoin build side that may be all-gathered; larger
    build sides lower to a hash-partitioned strategy, whose static bucket
    capacities come from the concrete ``key % n_shards`` histogram when
    the key column is concrete at compile time (eager compiles; overflow
    impossible) and otherwise from ``shuffle_slack`` times the uniform
    share (overflow is counted and poisons the join output with NaN — see
    ``dist.shuffle_fk_join``).  A per-node ``FKJoin.gather_budget``
    overrides the global for that join.

    Which hash-partitioned strategy runs is a COST decision
    (``db/cost.py`` via ``physical.lower_plan``): when the downstream
    GROUP BY keys on the probe join key, the fused CoPartitionedJoin +
    PartitionedAgg pipeline (matched rows stay at their owner, zero
    shuffle-home round-trips, one psum merge) competes with ShuffleJoin +
    PartialAgg.  ``copartition`` overrides it: "auto" (default) lets the
    estimates decide, True forces the fused pipeline whenever legal and
    the join may not gather, False disables it.  ``agg_shuffle_budget``
    (default None = off) makes single-key aggregations over more input
    rows hash-exchange their tuples to per-group owners
    (``Repartition`` + PartitionedAgg) — the fused pipeline without a
    join.  ``cost_model`` replaces the knob-derived
    :class:`repro.db.cost.CostModel` wholesale.  Every strategy is
    bit-identical to every other and to mesh=None (the canonical-chunk
    fold contract extends to owner-local folds; see
    ``dist.partitioned_merge``).

    ``cf_budget_elems`` bounds the total live exact-CF state elements of a
    `GroupAgg(method="exact")` node — counting both the log-abs and angle
    (max_groups, slab) arrays of every exact aggregate on the node.  When
    the full (max_groups, num_freq) state would exceed it, the compiler
    runs multiple accumulation passes over frequency slabs (each slab
    collective-merged on a mesh) and concatenates the slab states before
    the one batched-FFT Finalize; the grouped kernel's argsort/operand
    prep is hoisted above the slab loop (:func:`repro.core.uda.
    cf_chunk_operands`).

    Out-of-core execution: ``device_row_budget`` caps the resident rows
    per device of any one base table.  A Scan over the budget lowers to
    :class:`repro.db.physical.StreamedScan` and its table stays
    HOST-side (pass a :class:`repro.db.table.HostTable`, or a device
    Table — it is pulled to host): the compiled function then runs the
    aggregation pass above that scan as a sequence of WAVES, each
    shipping one chunk-aligned slab to the mesh and folding its
    per-canonical-chunk UDA states into a cross-wave accumulator
    (:class:`repro.core.uda.ChunkStateAccumulator`); the canonical fold
    runs once after the last wave, so results are BIT-IDENTICAL to the
    fully-resident compile for any wave size.  Transfers are
    double-buffered — wave k+1's ``jax.device_put`` overlaps wave k's
    accumulate, so the device holds 2 slabs + the group-level state,
    never the table (``stream_double_buffer=False`` serialises
    ship/compute — the control benchmarks compare against).
    ``stream_wave_chunks`` pins the wave size (in global chunk slots)
    for tests.  HostTables without a budget are simply materialised.
    The streamed path executes eagerly (host wave loop): don't wrap the
    compiled function in an outer jit when streaming.
    ``stream_prune_columns`` (default on) ships only the columns the
    plan above the scan actually reads (the lowering's
    :func:`repro.db.physical.required_scan_columns` demand set) and
    widens the waves to match — fewer bytes per row, fewer transfers;
    off streams every column (the control for byte-counting
    benchmarks).  A :class:`~repro.db.table.HostTable` opened from a
    :meth:`~repro.db.table.HostTable.save` directory streams straight
    from its memory-mapped column files: only the touched row ranges of
    the touched columns are ever paged in.

    ``stats_tables`` (name -> representative Table or HostTable) feeds
    the skew-adaptive concrete-key bucket sizing when the RUNTIME
    tables are traced (the compiled function called under jit): the
    stats tables are padded exactly like the runtime ones and their
    concrete (numpy — a HostTable's columns are histogrammed directly)
    key histograms size the exchange buckets, replacing the flat
    ``shuffle_slack`` capacity (the overflow-NaN guard stays as the
    backstop for stale stats).

    Self-healing hooks (see :mod:`repro.db.report` and :func:`run_plan`):
    ``with_report=True`` makes the compiled function return
    ``(result, ExecutionReport)`` — per-exchange overflow / demand /
    capacity, group-code-table overflow, per-MIN/MAX §V-B.2 truncation
    mass, NaN counts of the folded UDA states, and (streamed) wave
    progress.  ``shuffle_bucket_floor`` raises every slack-sized exchange
    bucket to at least that many rows (the retry controller re-lowers
    with the observed peak demand).  ``stream_wave_retries`` bounds the
    IN-PLACE re-ship attempts of a faulted wave transfer before the
    fault propagates (annotated with the halved wave size for the
    controller); the wave loop always resumes from the last retired
    wave — completed waves are never re-streamed.
    """
    from . import distributed as dist

    mesh_mode = mesh is not None
    axes = dist._tuple_axes(mesh, data_axes) if mesh_mode else ()
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    chunks = canonical_chunks
    if chunks <= 0:
        raise ValueError(f"canonical_chunks must be positive, got {chunks}")
    local_chunks = -(-chunks // shards)

    # Compile-time stand-ins for the concrete-key bucket sizing, padded
    # HERE — eagerly, host-side — not inside `compiled`: under jax.jit
    # omnistaging would turn a jnp pad of even a concrete table into
    # tracers, and the histogram sizing would silently fall back to
    # slack.  HostTable keeps the padding in numpy so the key columns
    # stay concrete no matter what trace `compiled` runs under.
    if stats_tables:
        stats_tables = {
            k: (st if isinstance(st, HostTable)
                else HostTable.from_table(st))
            .pad_to_multiple(chunks)
            .pad_to(shard_capacity(st.capacity, chunks, shards))
            for k, st in stats_tables.items()}

    # Canonical (chunk-grid-only) capacities of the base tables, set by
    # `compiled` before tracing: the shape a relational result has in the
    # mesh=None compile, before any shard-alignment padding chunks.
    canon_caps: dict = {}

    def _canonical_rows(pnode: phys.PhysNode) -> int:
        """Root output rows of a relational subtree under mesh=None padding
        (row capacity follows the probe/left lineage down to its scan)."""
        if isinstance(pnode, (phys.ShardScan, phys.StreamedScan)):
            return canon_caps[pnode.name]
        if isinstance(pnode, (phys.PhysSelect, phys.PhysMap,
                              phys.Repartition)):
            return _canonical_rows(pnode.child)
        if isinstance(pnode, (phys.GatherJoin, phys.ShuffleJoin,
                              phys.CoPartitionedJoin)):
            return _canonical_rows(pnode.left)
        if isinstance(pnode, phys.MergeAgg):
            return pnode.child.max_groups
        raise TypeError(pnode)

    def make_runner(sh_tables: Dict[str, Table],
                    rb: ReportBuilder | None = None,
                    params: dict | None = None) -> SimpleNamespace:
        """Bind the physical-plan interpreter to one dict of (shard-local)
        tables; in mesh mode the closures run inside shard_map.  The
        streamed executor binds the SAME interpreter to every wave's slab
        (the StreamedScan resolves to the slab), so resident and streamed
        execution share one code path for everything below the merge.
        ``rb`` (a :class:`ReportBuilder`) collects the run's diagnostics
        while the plan traces."""

        def sharded(t: Table) -> bool:
            return bool(axes) and isinstance(t.part, phys.RowBlocked)

        def hash_partitioned(t: Table) -> bool:
            return bool(axes) and isinstance(t.part, phys.HashPartitioned)

        def acc(udas_d, table: Table, values, ids, max_groups,
                cf_operands=None):
            """ONE canonical chunked pass over the relation's tuples for
            every UDA of the pass.  The chunk grid is the same in every
            compile: a RowBlocked pass computes its local chunk slots'
            states and allgather_merge assembles ALL chunk states so every
            shard finishes the identical fold tree; a HashPartitioned
            pass (the fused pipeline) computes EVERY canonical chunk's
            slice in one compound (chunk, group) accumulate over the
            exchange buffer — received rows arrive in global row order,
            so each (chunk, group) slot folds the same tuples in the same
            order as the RowBlocked chunk pass — and partitioned_merge
            finishes the identical fold owner-locally before one psum."""
            probs = table.masked_prob()
            if hash_partitioned(table):
                cid = jnp.clip(table[phys.CHUNK_COL].astype(jnp.int32),
                               0, chunks - 1)
                comp = cid * max_groups + ids
                flat = uda.accumulate(udas_d, probs, values, comp,
                                      max_groups=chunks * max_groups)
                parts = [{name: jax.tree.map(
                    lambda x, c=c: x[c * max_groups:(c + 1) * max_groups],
                    st) for name, st in flat.items()}
                    for c in range(chunks)]
                return dist.partitioned_merge(udas_d, parts, axes,
                                              n_shards=shards)
            if sharded(table):
                parts = uda.accumulate_chunk_states(
                    udas_d, probs, values, ids, max_groups=max_groups,
                    num_chunks=local_chunks, cf_operands=cf_operands)
                return dist.allgather_merge(udas_d, parts, axes, chunks,
                                            shards)
            return uda.accumulate_chunked(
                udas_d, probs, values, ids, max_groups=max_groups,
                num_chunks=chunks, cf_operands=cf_operands)

        def rel_group_ids(t: Table, keys, max_groups):
            if sharded(t) or hash_partitioned(t):
                return dist.group_ids_sharded(t, list(keys), max_groups,
                                              axes)
            return ops.group_ids(t, list(keys), max_groups)

        def rel_key_columns(t: Table, keys, ids, max_groups):
            if sharded(t) or hash_partitioned(t):
                return dist.group_key_columns_sharded(t, keys, ids,
                                                      max_groups, axes)
            return ops.group_key_columns(t, keys, ids, max_groups)

        def run_agg(node: phys.MergeAgg):
            """The PartialAgg/MergeAgg pair executes as one unit: group
            ids, then per frequency slab one Accumulate (per-chunk
            partials) + ONE collective Merge, then the replicated Finalize
            selected by ``kind`` — the module-level ``_pass_*`` /
            ``_finalize_pass`` helpers, which the streamed wave loop runs
            piecewise across waves."""
            pa = node.child
            t = run(pa.child)
            mg = pa.max_groups
            ids, merged, gvalid = rel_group_ids(t, pa.keys, mg)
            label = rb.begin_agg(node.kind) if rb is not None else ""
            if rb is not None:
                code_live, big = ops.live_key_codes(t, list(pa.keys))
                lost = _lost_group_count(code_live, big, merged, ids)
                if sharded(t) or hash_partitioned(t):
                    # Row-partitioned input: each shard counted its own
                    # rows.  Replicated inputs count every row on every
                    # shard — summing would multiply by the shard count.
                    lost = jax.lax.psum(lost, axes)
                rb.group_overflow(label, lost)
            values = _pass_values(pa.specs, t)
            exact_names, slabs = _pass_slabs(pa, cf_budget_elems)
            cf_operands: dict = {}
            if len(slabs) > 1 and not hash_partitioned(t):
                # Hoist the grouped kernel's argsort(gids) + operand prep
                # above the slab loop: prepared once per canonical chunk,
                # reused by every slab pass (None when the kernel would
                # not be dispatched — the scan/oracle paths sort nothing;
                # the compound pass of the fused pipeline sorts per call).
                probs_m = t.masked_prob()
                nloc = local_chunks if sharded(t) else chunks
                for name in exact_names:
                    prepared = uda.cf_chunk_operands(
                        pa.num_freq, probs_m, values[name], ids,
                        max_groups=mg, num_chunks=nloc)
                    if prepared is not None:
                        cf_operands[name] = prepared
            udas: dict = {}
            states: dict = {}
            for si, (lo, cnt) in enumerate(slabs):
                udas_i, vals_i = _slab_udas(pa, si, lo, cnt, values)
                sts = acc(udas_i, t, vals_i, ids, mg,
                          cf_operands=cf_operands or None)
                _append_slab(states, udas, udas_i, sts)
            for name in exact_names:            # full-range Finalize UDA
                udas[name] = _agg_uda("SUM", "exact", pa.kappa, pa.num_freq)
            return _finalize_pass(
                node, pa, udas, states, gvalid,
                lambda cols: rel_key_columns(t, cols, ids, mg),
                rb=rb, label=label, params=params)

        def run(node: phys.PhysNode):
            if isinstance(node, (phys.ShardScan, phys.StreamedScan)):
                # A StreamedScan resolves here only under the streamed
                # executor, which binds the scan's name to the current
                # wave's slab.
                return sh_tables[node.name].with_part(node.part)
            if isinstance(node, phys.PhysSelect):
                pred = node.pred
                if isinstance(pred, Parameterized):
                    return ops.select(run(node.child),
                                      lambda t: pred(t, params))
                return ops.select(run(node.child), pred)
            if isinstance(node, phys.PhysMap):
                t = run(node.child)
                fn = node.fn
                col = fn(t, params) if isinstance(fn, Parameterized) \
                    else fn(t)
                return t.with_column(node.name, col)
            if isinstance(node, phys.GatherJoin):
                lt = run(node.left)
                rt = run(node.right)
                if sharded(rt):
                    # Broadcast the small build side: all-gather only the
                    # probe key + carried columns (plus p and valid).
                    rt = dist.gather_table(
                        rt.select_columns(
                            dict.fromkeys((node.right_key,)
                                          + tuple(node.right_cols))),
                        axes)
                return ops.fk_join(lt, rt, node.left_key, node.right_key,
                                   list(node.right_cols))
            if isinstance(node, phys.ShuffleJoin):
                lt = run(node.left)
                rt = run(node.right)
                lbl = rb.begin_exchange("shuffle_join") \
                    if rb is not None else ""
                return dist.shuffle_fk_join(
                    lt, rt, node.left_key, node.right_key,
                    list(node.right_cols), axes, n_shards=shards,
                    build_bucket=node.build_bucket,
                    probe_bucket=node.probe_bucket,
                    report=rb, label=lbl)
            if isinstance(node, phys.CoPartitionedJoin):
                lt = run(node.left)
                rt = run(node.right)
                lbl = rb.begin_exchange("copart_join") \
                    if rb is not None else ""
                return dist.copartitioned_fk_join(
                    lt, rt, node.left_key, node.right_key,
                    list(node.right_cols), list(node.carry_cols), axes,
                    n_shards=shards, build_bucket=node.build_bucket,
                    probe_bucket=node.probe_bucket,
                    chunk_size=_canonical_rows(node.left) // chunks,
                    num_chunks=chunks, report=rb, label=lbl)
            if isinstance(node, phys.Repartition):
                t = run(node.child)
                lbl = rb.begin_exchange("repartition") \
                    if rb is not None else ""
                return dist.repartition_by_key(
                    t, node.key, list(node.carry_cols), axes,
                    n_shards=shards, bucket=node.bucket,
                    chunk_size=_canonical_rows(node.child) // chunks,
                    num_chunks=chunks, report=rb, label=lbl)
            if isinstance(node, phys.MergeAgg):
                return run_agg(node)
            raise TypeError(node)

        return SimpleNamespace(run=run, run_agg=run_agg, acc=acc,
                               rel_group_ids=rel_group_ids,
                               rel_key_columns=rel_key_columns,
                               sharded=sharded)

    def interpret(sh_tables: Dict[str, Table], proot: phys.PhysNode,
                  rb: ReportBuilder | None = None,
                  params: dict | None = None):
        """Interpret the physical plan end-to-end (the resident path)."""
        r = make_runner(sh_tables, rb, params)
        out = r.run(proot)
        if isinstance(out, Table):
            if r.sharded(out):
                out = dist.gather_table(out, axes)
                # Drop the whole-padding chunks appended for shard counts
                # that don't divide the grid: the caller-visible capacity
                # is the canonical (chunk-grid) one of the mesh=None
                # compile (the dropped rows are all invalid p = 0).
                n = _canonical_rows(proot)
                if n < out.capacity:
                    out = Table({k: v[:n] for k, v in out.columns.items()},
                                out.prob[:n], out.valid[:n], out.part)
            return out.with_part(phys.Replicated())
        return out

    # ------------------------------------------------- streamed execution
    def _build_wave_fns(proot, agg, sc):
        """The two per-wave device functions of the streamed executor —
        phase A (group-code discovery) and phase B (chunk-state
        accumulation) — each re-running the plan spine below ``agg`` on
        one slab, shard_mapped over the mesh and jitted.  Cached in the
        process-wide bounded ``_WAVE_CACHE`` under the plan's STRUCTURAL
        key plus everything else the traces depend on (mesh identity,
        data axes, the canonical grid and the CF slab budget), so
        separately constructed identical plans share one executable and
        distinct plans past the capacity evict instead of accreting."""
        key = ("wave", phys.structural_key(proot), mesh_fingerprint(mesh),
               axes, shards, chunks, cf_budget_elems)
        cached = _WAVE_CACHE.get(key)
        if cached is not None:
            return cached
        pa = agg.child
        spine = pa.child
        mg = pa.max_groups
        keys = list(pa.keys)
        kcols = list(pa.keys)
        if agg.kind == "reweight":
            kcols += [c for c in agg.carry_cols if c not in kcols]
            if agg.threshold_col and agg.threshold_col not in kcols:
                kcols.append(agg.threshold_col)
        exact_names, slabs = _pass_slabs(pa, cf_budget_elems)

        def wave_a(slab, res, pv):
            t = make_runner({**res, sc.name: slab}, params=pv).run(spine)
            code_live, _ = ops.live_key_codes(t, keys)
            local = ops.merge_group_codes(code_live, mg)
            if axes:
                gathered = jax.lax.all_gather(local, axes, axis=0,
                                              tiled=True)
                local = ops.merge_group_codes(gathered, mg)
            return local

        def wave_b(slab, res, merged, pv):
            t = make_runner({**res, sc.name: slab}, params=pv).run(spine)
            code_live, big = ops.live_key_codes(t, keys)
            ids = ops.codes_to_ids(code_live, merged)
            # The wave's group-overflow contribution is always computed
            # (one compare + sum — keeping the jit cache's trace
            # signature independent of report collection).
            lost = _lost_group_count(code_live, big, merged, ids)
            if axes:
                lost = jax.lax.psum(lost, axes)
            values = _pass_values(pa.specs, t)
            out_states = []
            for si, (lo, cnt) in enumerate(slabs):
                udas_i, vals_i = _slab_udas(pa, si, lo, cnt, values)
                parts = uda.accumulate_chunk_states(
                    udas_i, t.masked_prob(), vals_i, ids, max_groups=mg,
                    num_chunks=sc.schedule.local_chunks_per_wave)
                out_states.append(
                    dist.gather_chunk_states(udas_i, parts, axes)
                    if axes else parts)
            gcols = ops.group_key_columns(t, kcols, ids, mg)
            if axes:
                gcols = {k: jax.lax.pmax(v, axes) for k, v in gcols.items()}
            return out_states, gcols, lost

        if axes:
            wave_a = shard_map(wave_a, mesh=mesh,
                               in_specs=(P(axes), P(axes), P()),
                               out_specs=P(), check_vma=False)
            wave_b = shard_map(wave_b, mesh=mesh,
                               in_specs=(P(axes), P(axes), P(), P()),
                               out_specs=P(), check_vma=False)
        # Donating the slab lets XLA reuse wave k's buffers for wave k+2
        # (the CPU backend does not support donation — avoid the warning).
        donate = (0,) if jax.default_backend() != "cpu" else ()
        fns = (jax.jit(wave_a, donate_argnums=donate),
               jax.jit(wave_b, donate_argnums=donate))
        _WAVE_CACHE.put(key, fns)
        return fns

    def _stream(ht: HostTable, sched, wave_call, collect) -> int:
        """The double-buffered wave loop: slab w+1 is sliced and
        ``device_put`` WHILE the device works on slab w (JAX async
        dispatch — the host never blocks on the wave computation), so
        transfer and compute overlap and device residency is two slabs.
        With ``stream_double_buffer=False`` the loop blocks around every
        wave — the serialised control the streaming benchmarks compare
        against.

        Fault tolerance: each host→device transfer passes through
        ``testing.faults.on_transfer``; a :class:`~repro.testing.faults.
        TransferFault` re-ships the SAME wave up to ``stream_wave_retries``
        times.  Wave w is retired (``collect``-ed) BEFORE slab w+1 is
        prefetched, so the loop's position IS the checkpoint: a fault only
        ever re-ships waves whose states are not yet filed, and completed
        waves are never re-streamed.  A fault that survives the in-place
        retries propagates annotated with the halved wave size
        (``wave_chunks``) so :func:`run_plan` can re-lower a smaller
        schedule.  Returns the number of re-ship retries.

        Slab assembly is ZERO-ALLOC: two preallocated ping-pong host
        buffers (matching the double-buffer depth) are filled with
        ``np.copyto`` instead of per-wave fresh allocations.  Reusing
        buffer ``w % 2`` for wave w is safe because slab w+1 only ships
        after wave w-1's output is ready (the block below) — and w-1's
        compute finishing implies its input transfer (same parity
        buffer) has been consumed."""
        csz = sched.chunk_rows
        lrows = sched.local_chunks_per_wave * csz
        lslots = sched.n_waves * sched.local_chunks_per_wave
        n_retries = 0
        bufs = (ht.alloc_slab(lrows * shards), ht.alloc_slab(lrows * shards))
        wave_bytes = sum(a.nbytes for a in
                         jax.tree.leaves((bufs[0].columns, bufs[0].prob,
                                          bufs[0].valid)))

        def ship(w):
            # Wave w takes the next `lrows` rows of EVERY shard's slot
            # range — strided slices host-side, split back per device by
            # the sharded transfer.
            faults.on_transfer(w, lrows * shards)
            starts = tuple(s * lslots * csz + w * lrows
                           for s in range(shards))
            t0 = time.perf_counter()
            slab = ht.wave_slab(starts, lrows, out=bufs[w % 2])
            _STREAM_STATS["slice_s"] += time.perf_counter() - t0
            _STREAM_STATS["slab_bytes"] += wave_bytes
            _STREAM_STATS["waves"] += 1
            if mesh_mode:
                return jax.device_put(slab, NamedSharding(mesh, P(axes)))
            return jax.device_put(slab)

        def try_ship(w):
            nonlocal n_retries
            for attempt in range(stream_wave_retries + 1):
                try:
                    return ship(w)
                except faults.TransferFault as e:
                    if attempt == stream_wave_retries:
                        e.wave_chunks = C.halved_wave_chunks(sched)
                        e.at_minimum = sched.local_chunks_per_wave == 1
                        raise
                    n_retries += 1

        nxt = try_ship(0)
        prev = None
        for w in range(sched.n_waves):
            cur, nxt = nxt, None
            if not stream_double_buffer:
                jax.block_until_ready(cur)
            out = wave_call(w, cur)
            if not stream_double_buffer:
                out = jax.block_until_ready(out)
            elif prev is not None:
                # True double buffering: wave w-1 must have retired
                # before slab w+1 ships, bounding in-flight slabs to two
                # (unbounded run-ahead trades the overlap win away to
                # allocator pressure).
                jax.block_until_ready(prev)
            # Retire wave w before prefetching w+1 (collect is host
            # bookkeeping on async values — it doesn't block the
            # overlap): the fault-resume contract above.
            collect(w, out)
            if w + 1 < sched.n_waves:
                nxt = try_ship(w + 1)
            prev = out
        return n_retries

    def _streamed_exec(proot, padded, rb: ReportBuilder | None = None,
                       params: dict | None = None):
        """Run a physical plan containing a StreamedScan: the lowest
        aggregation pass above the scan executes as waves (see
        ``compile_plan``'s docstring); any plan suffix above that pass
        runs resident on the pass's replicated group-level output."""
        scans = [n for n in _iter_phys(proot)
                 if isinstance(n, phys.StreamedScan)]
        if len(scans) > 1:
            raise NotImplementedError(
                "one StreamedScan per plan: raise device_row_budget so at "
                f"most one table streams (got {[s.name for s in scans]})")
        sc = scans[0]
        agg = _lowest_streamed_agg(proot)
        if agg is None:
            raise NotImplementedError(
                "a StreamedScan must feed a grouped aggregation (Project /"
                " GroupAgg / ReweightGreater): the wave loop folds "
                "per-chunk UDA states, not raw relational output — raise "
                "device_row_budget so the table stays resident, or "
                "materialise it first via HostTable.to_table()")
        pa = agg.child
        sched = sc.schedule
        ht = padded[sc.name]
        ht = (ht if isinstance(ht, HostTable)
              else HostTable.from_table(ht)).pad_to(sched.padded_capacity)
        if sc.columns is not None:
            # Required-column pruning: wave slabs carry only the demand
            # set the lowering recorded (plus prob/valid, always).
            ht = ht.select_columns([c for c in sc.columns
                                    if c in ht.columns])
        resident = {k: (t.to_table() if isinstance(t, HostTable) else t)
                    for k, t in padded.items() if k != sc.name}
        wave_a, wave_b = _build_wave_fns(proot, agg, sc)
        pv = dict(params or {})

        # Phase A: stream once for the global group-code table — exact
        # under hierarchical merging (ops.merge_group_codes), so merging
        # the per-wave tables reproduces the resident table bit for bit.
        code_tabs = [None] * sched.n_waves
        retries = _stream(ht, sched,
                          lambda w, slab: wave_a(slab, resident, pv),
                          lambda w, out: code_tabs.__setitem__(w, out))
        mg = pa.max_groups
        merged = ops.merge_group_codes(jnp.concatenate(code_tabs), mg)
        gvalid = merged != jnp.iinfo(merged.dtype).max

        # Phase B: stream again, filing every wave's per-chunk states
        # under their global canonical chunk ids; the canonical fold runs
        # ONCE after the last wave (the bit-identical-streaming contract).
        exact_names, slabs = _pass_slabs(pa, cf_budget_elems)
        dummy_vals = {s[0]: None for s in pa.specs}
        slab_udas, accs = [], []
        for si, (lo, cnt) in enumerate(slabs):
            udas_i, _ = _slab_udas(pa, si, lo, cnt, dummy_vals)
            slab_udas.append(udas_i)
            accs.append(uda.ChunkStateAccumulator(udas_i, chunks))
        lcpw = sched.local_chunks_per_wave
        lslots = sched.n_waves * lcpw
        gcols_run: dict = {}
        lost_waves: list = []

        def collect_b(w, out):
            out_states, gcols, lost = out
            slot_ids = [s * lslots + w * lcpw + j
                        for s in range(shards) for j in range(lcpw)]
            for si, parts in enumerate(out_states):
                accs[si].add_wave(slot_ids, parts)
            lost_waves.append(lost)     # async values; summed after loop
            for k, v in gcols.items():
                # Per-group key representatives: segment_max identities
                # fill absent groups, so a max across waves is exact.
                gcols_run[k] = (v if k not in gcols_run
                                else jnp.maximum(gcols_run[k], v))

        retries += _stream(
            ht, sched, lambda w, slab: wave_b(slab, resident, merged, pv),
            collect_b)

        label = rb.begin_agg(agg.kind) if rb is not None else ""
        if rb is not None:
            rb.group_overflow(label, sum(lost_waves))
            rb.set_waves(completed=2 * sched.n_waves,
                         total=2 * sched.n_waves, retries=retries)
        udas: dict = {}
        states: dict = {}
        for si in range(len(slabs)):
            _append_slab(states, udas, slab_udas[si], accs[si].fold())
        for name in exact_names:                # full-range Finalize UDA
            udas[name] = _agg_uda("SUM", "exact", pa.kappa, pa.num_freq)
        result = _finalize_pass(
            agg, pa, udas, states, gvalid,
            lambda cols: {k: gcols_run[k] for k in cols},
            rb=rb, label=label, params=pv)
        if agg is proot:
            return (result.with_part(phys.Replicated())
                    if isinstance(result, Table) else result)
        # Plan suffix above the streamed pass: re-inject the replicated
        # group-level Table as a scan and run the rest resident.
        assert isinstance(result, Table), \
            "a non-root streamed aggregation must produce a Table"
        outer = _swap_node(proot, agg,
                           phys.ShardScan(_STREAMED_RESULT,
                                          phys.Replicated(),
                                          result.capacity))
        canon_caps[_STREAMED_RESULT] = result.capacity
        if not mesh_mode:
            return interpret({**resident, _STREAMED_RESULT: result},
                             outer, rb, pv)
        if rb is None:
            fn = shard_map(
                lambda sh, ex, p: interpret({**sh, **ex}, outer,
                                            params=p),
                mesh=mesh, in_specs=(P(axes), P(), P()),
                out_specs=P(), check_vma=False)
            return fn(resident, {_STREAMED_RESULT: result}, pv)
        # The suffix traces under shard_map, so its diagnostics must ride
        # the traced outputs: a forked builder (label counters continue
        # from the streamed pass) collects inside, its built report is
        # returned as replicated leaves, and the concrete copy is
        # absorbed back host-side.
        sub = rb.fork()
        fn = shard_map(
            lambda sh, ex, p: (interpret({**sh, **ex}, outer, sub, p),
                               sub.build()),
            mesh=mesh, in_specs=(P(axes), P(), P()), out_specs=P(),
            check_vma=False)
        out, rep = fn(resident, {_STREAMED_RESULT: result}, pv)
        rb.absorb(rep)
        return out

    needed_params = plan_params(root)

    def compiled(tables: Dict[str, Table], params: dict | None = None):
        # Lifted-parameter environment: every Param hole must be bound,
        # and only Param holes may be (a typo'd name would silently bake
        # nothing).  Values may be traced — under jax.vmap each is one
        # lane of the parameter batch (see repro.db.serving).
        env = dict(params or {})
        missing = needed_params - env.keys()
        extra = env.keys() - needed_params
        if missing or extra:
            raise ValueError(
                f"plan parameters mismatch: missing {sorted(missing)}, "
                f"unexpected {sorted(extra)} (plan needs "
                f"{sorted(needed_params)})")
        env = {k: jnp.asarray(env[k]) for k in sorted(env)}
        # Every compile pads every base table to the canonical chunk grid
        # (the chunk boundaries define the deterministic fold tree) plus
        # whole padding chunks so any shard count owns equal chunk runs.
        padded = {k: t.pad_to_multiple(chunks)
                   .pad_to(shard_capacity(t.capacity, chunks, shards))
                  for k, t in tables.items()}
        caps = {k: t.capacity for k, t in padded.items()}
        canon_caps.clear()
        canon_caps.update({k: -(-t.capacity // chunks) * chunks
                           for k, t in tables.items()})
        plan_tables = dict(padded)
        if stats_tables:
            # Substitute the pre-padded host-side stand-ins so the key %
            # n_shards histograms see a concrete row population even
            # when the runtime tables are tracers.
            for k, st in stats_tables.items():
                if k in padded:
                    plan_tables[k] = st
        proot = phys.lower_plan(root, caps, n_shards=shards,
                                sharded=mesh_mode and bool(axes),
                                join_gather_budget=join_gather_budget,
                                shuffle_slack=shuffle_slack,
                                copartition=copartition,
                                agg_shuffle_budget=agg_shuffle_budget,
                                canonical_chunks=chunks,
                                model=cost_model, tables=plan_tables,
                                device_row_budget=device_row_budget,
                                stream_wave_chunks=stream_wave_chunks,
                                stream_prune_columns=stream_prune_columns,
                                bucket_floor=shuffle_bucket_floor)
        rb = ReportBuilder() if with_report else None
        if any(isinstance(n, phys.StreamedScan) for n in _iter_phys(proot)):
            out = _streamed_exec(proot, padded, rb, env)
            return (out, rb.build()) if with_report else out
        resident = {k: (t.to_table() if isinstance(t, HostTable) else t)
                    for k, t in padded.items()}
        if not mesh_mode:
            out = interpret(resident, proot, rb, env)
            return (out, rb.build()) if with_report else out
        if not with_report:
            fn = shard_map(lambda sh, p: interpret(sh, proot, params=p),
                           mesh=mesh, in_specs=(P(axes), P()),
                           out_specs=P(), check_vma=False)
            return fn(resident, env)
        # The report's leaves are traced inside shard_map; returning the
        # built pytree alongside the result is what carries them out
        # (every recorded value is psum/pmax-replicated, honouring the
        # P() out_spec).
        fn = shard_map(
            lambda sh, p: (interpret(sh, proot, rb, p), rb.build()),
            mesh=mesh, in_specs=(P(axes), P()), out_specs=P(),
            check_vma=False)
        return fn(resident, env)

    return compiled


# ======================================================================
# the escalating retry controller
# ======================================================================
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How :func:`run_plan` escalates when a run's
    :class:`~repro.db.report.ExecutionReport` shows a problem.

    max_attempts   total compile+run attempts (first run included)
    tail_tol       largest acceptable per-group MIN/MAX §V-B.2
                   truncation mass; above it kappa doubles
    wave_retries   in-place re-ship attempts per faulted wave transfer
                   before the streamed executor gives the fault back to
                   the controller (which then halves the wave size)
    """
    max_attempts: int = 3
    tail_tol: float = 0.0
    wave_retries: int = 2


class RetryExhausted(RuntimeError):
    """The retry ladder ran out of attempts with issues outstanding; the
    last run's report is attached for diagnosis."""

    def __init__(self, msg: str, report=None):
        super().__init__(msg)
        self.report = report


def _scale_plan(node: Node, kappa_scale: int, groups_scale: int) -> Node:
    """Rebuild the logical DAG with every GroupAgg kappa (and, on group
    overflow, every grouped node's max_groups) scaled — the logical-level
    escalations; a scale of 1 returns the node unchanged (same object, so
    an unescalated retry reuses compile caches)."""
    reb: dict = {}
    for f in ("child", "left", "right"):
        c = getattr(node, f, None)
        if isinstance(c, Node):
            nc = _scale_plan(c, kappa_scale, groups_scale)
            if nc is not c:
                reb[f] = nc
    if isinstance(node, GroupAgg) and kappa_scale != 1:
        reb["kappa"] = node.kappa * kappa_scale
    if groups_scale != 1 and isinstance(node, (GroupAgg, Project,
                                               ReweightGreater)):
        reb["max_groups"] = node.max_groups * groups_scale
    return dataclasses.replace(node, **reb) if reb else node


def _default_compiler(root: Node, mesh=None, jit: bool = False, **opts):
    """The retry controller's default compile hook: a fresh
    ``compile_plan`` (jit-wrapped on request) per attempt.  A serving
    layer substitutes :meth:`repro.db.serving.PlanCache.compile` here, so
    every attempt's executable is cached under (plan structure, attempt
    params) — a later identical submit hits the FINAL attempt's entry
    bit-identically, and intermediate attempts never poison it."""
    fn = compile_plan(root, mesh, **opts)
    return jax.jit(fn) if jit else fn


def run_plan(root: Node, tables: Dict[str, Table], mesh=None, *,
             policy: RetryPolicy | None = None, jit: bool = False,
             params: dict | None = None, compiler=None,
             kappa_scale: int = 1, groups_scale: int = 1,
             **opts):
    """Run a logical plan under the self-healing retry loop: compile
    (``compile_plan(..., with_report=True)``), run, DIAGNOSE the
    :class:`~repro.db.report.ExecutionReport`, and re-lower with
    escalated parameters until the run is clean (or ``policy.
    max_attempts`` is spent — :class:`RetryExhausted`).  Escalations:

    * exchange overflow  -> ``shuffle_bucket_floor`` = the observed peak
      per-(sender, owner) send demand (exact, so ONE retry suffices) and
      ``shuffle_slack`` doubled (capped at n_shards, where overflow is
      impossible) as the belt-and-braces ladder;
    * truncation tail mass above ``policy.tail_tol`` -> kappa doubled;
    * group-code-table overflow -> max_groups doubled;
    * a transfer fault surviving the in-loop wave retries -> wave size
      halved (``stream_wave_chunks``).

    NaN counts WITHOUT an exchange overflow mean the NaN came in with
    the data — nothing to escalate, so the result returns as-is with the
    report flagging it.

    Returns ``(result, report)``; ``report.final_params`` records the
    final attempt's overrides and ``report.waves["attempts"]`` the
    attempt count.  Because every attempt is a fresh compile at its own
    parameters, the converged result is bit-identical to a first run
    launched with ``final_params`` — the determinism contract extended
    to the retry loop.

    ``jit=True`` wraps the compiled function in ``jax.jit`` (required to
    exercise the traced-key slack sizing: eager runs size buckets from
    concrete key histograms and cannot overflow).  Not available for
    streamed plans (the wave loop is a host loop).

    ``params`` binds the plan's lifted :class:`Param` holes (passed
    through to every attempt unchanged).  ``compiler`` replaces the
    per-attempt compile (signature ``compiler(root, mesh, jit=...,
    **opts) -> fn``) — the serving layer passes its bounded plan cache
    here, keyed on each attempt's exact (scaled plan, lowering params),
    so retries create per-attempt entries instead of poisoning the base
    one.  ``kappa_scale`` / ``groups_scale`` seed the escalation ladder
    (a service replaying a remembered ``final_params`` starts AT the
    converged point: attempt 1 is clean and its compile is a cache hit).
    """
    policy = policy or RetryPolicy()
    compiler = compiler or _default_compiler
    opts = dict(opts)
    slack = float(opts.pop("shuffle_slack", 4.0))
    floor = opts.pop("shuffle_bucket_floor", None)
    wave_chunks = opts.pop("stream_wave_chunks", None)
    n_shards = 1
    if mesh is not None:
        from . import distributed as dist
        for a in dist._tuple_axes(mesh, opts.get("data_axes", ("data",))):
            n_shards *= mesh.shape[a]

    out = report = None
    attempt = 0
    for attempt in range(1, policy.max_attempts + 1):
        fn = compiler(_scale_plan(root, kappa_scale, groups_scale),
                      mesh, jit=jit, with_report=True, shuffle_slack=slack,
                      shuffle_bucket_floor=floor,
                      stream_wave_chunks=wave_chunks,
                      stream_wave_retries=policy.wave_retries,
                      **opts)
        try:
            out, report = fn(tables, params)
        except faults.TransferFault as e:
            if (e.wave_chunks is None or e.at_minimum
                    or attempt == policy.max_attempts):
                raise
            wave_chunks = e.wave_chunks
            continue
        issues = report.issues(policy.tail_tol)
        if not any(k != "nan" for k in issues):
            break
        if attempt == policy.max_attempts:
            raise RetryExhausted(
                f"unresolved after {attempt} attempts: "
                f"{report.describe(policy.tail_tol)}", report)
        if "overflow" in issues:
            floor = max(floor or 0,
                        max(int(jnp.max(report.exchange_demand[k]))
                            for k in issues["overflow"]))
            slack = C.escalated_slack(slack, n_shards)
        if "tail" in issues:
            kappa_scale *= 2
        if "group_overflow" in issues:
            groups_scale *= 2

    report.final_params.update(
        shuffle_slack=slack, shuffle_bucket_floor=floor,
        stream_wave_chunks=wave_chunks, kappa_scale=kappa_scale,
        groups_scale=groups_scale)
    report.waves["attempts"] = attempt
    return out, report
