"""Probabilistic -> deterministic plan mapping (paper §VI, Table I),
mesh-aware.

A Plan is a small dataflow DAG of operator nodes.  ``compile_plan`` walks
the DAG and emits one jit-able function  tables -> results , realising the
paper's central claim: probabilistic queries run on a *deterministic*
engine (here: XLA) once every probabilistic operator is rewritten to a
deterministic one + segment-UDA calls (:mod:`repro.core.uda`).

``compile_plan(root, mesh)`` compiles the SAME plan for a device mesh:
the relational scaffolding (scan/select/join/group-id assignment) stays
replicated, while every `GroupAgg` / `ReweightGreater` aggregation runs
the distributed Accumulate -> one-psum Merge -> replicated Finalize path
of :mod:`repro.db.distributed`, so any plan runs on any mesh with results
identical to the single-device compile.

Node zoo (Table I rows in brackets):

    Scan(name)                               [I]   R -> R^p
    Select(child, pred)                      [II]  sigma, deterministic cond
    Map(child, name, fn)                     [--]  computed column
    FKJoin(l, r, lk, rk, cols)               [IV]  join, deterministic cond
    Project(child, keys, max_groups)         [V]   GROUP BY + AtLeastOne
    GroupAgg(child, keys, agg, value, ...)   [VI]  GROUP BY + PGF UDAs
                                                   (+ `extra` riders share
                                                   ONE accumulation pass)
    ReweightGreater(child, agg_of, vs, ...)  [III] p *= P(SUM > threshold)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence

import jax.numpy as jnp

from ..core import uda
from . import operators as ops
from .table import Table


class Node:
    pass


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    name: str


@dataclasses.dataclass(frozen=True)
class Select(Node):
    child: Node
    pred: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Map(Node):
    """Attach a computed column `name` = fn(table) to the child relation."""
    child: Node
    name: str
    fn: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class FKJoin(Node):
    left: Node
    right: Node
    left_key: str
    right_key: str
    right_cols: tuple


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    keys: tuple
    max_groups: int


@dataclasses.dataclass(frozen=True)
class GroupAgg(Node):
    """Returns a dict of per-group UDA results, not a Table (PGF-valued
    columns live outside the 1NF Table, §VI-C).

    The primary aggregate lands under "sum" / "cumulants" / "exact" /
    "minmax" (by method/agg); each `extra` entry (name, value_col, agg,
    method) rides the SAME accumulation pass and lands under its own name.
    Group confidence (AtLeastOne) is always included.  `value` == "" means
    COUNT (all-ones).

    ``method="exact"`` computes the full per-group SUM/COUNT distribution
    via the grouped log-CF UDA (Pallas-accelerated on TPU) and requires
    ``num_freq`` = max aggregate value + 1; the result is a (max_groups,
    num_freq) row-stochastic coefficient matrix.  When max_groups *
    num_freq exceeds the planner's ``cf_budget_elems``, the compiler
    accumulates the state in multiple passes over frequency slabs (each
    slab additively psum-merged on a mesh) — see ``compile_plan``.
    """
    child: Node
    keys: tuple
    value: str            # column to aggregate ("" = COUNT)
    agg: str              # SUM | COUNT | MIN | MAX
    max_groups: int
    method: str = "normal"  # normal | cumulants | exact
    extra: tuple = ()
    kappa: int = 64       # MIN/MAX support capacity per group
    num_freq: int = 0     # exact: distribution capacity (max sum + 1)


@dataclasses.dataclass(frozen=True)
class ReweightGreater(Node):
    """sigma_{AGG(B) > C}: group child by keys, SUM(value), then keep each
    group with p = AtLeastOne * P(SUM > threshold) (Table I row III).
    The threshold is `threshold_col` (per-group column) when set, else the
    constant `threshold`; `carry_cols` are extra per-group columns kept on
    the output Table (all valid writers of a group agree)."""
    child: Node
    keys: tuple
    value: str
    threshold_col: str
    max_groups: int
    threshold: float | None = None
    carry_cols: tuple = ()


def _agg_uda(agg: str, method: str, kappa: int, num_freq: int = 0,
             freq_lo: int = 0, freq_cnt: int | None = None) -> uda.UDA:
    if agg in ("SUM", "COUNT"):
        if method == "normal":
            return uda.SumNormal()
        if method == "cumulants":
            return uda.SumCumulants()
        if method == "exact":
            if num_freq <= 0:
                raise ValueError(
                    "GroupAgg(method='exact') needs num_freq = max "
                    "aggregate value + 1 (the static distribution capacity)")
            return uda.SumCF(num_freq, freq_lo=freq_lo, freq_cnt=freq_cnt)
        raise ValueError(
            f"GroupAgg method {method!r} is not supported by the planner "
            "(expected 'normal', 'cumulants' or 'exact')")
    if agg in ("MIN", "MAX"):
        if method == "exact":
            raise ValueError(
                "GroupAgg method 'exact' applies to SUM/COUNT only; MIN/MAX "
                "distributions come from the MinMax UDA (kappa support)")
        return uda.MinMax(kappa=kappa, sign=1.0 if agg == "MIN" else -1.0)
    raise ValueError(agg)


def _out_key(agg: str, method: str) -> str:
    if agg in ("MIN", "MAX"):
        return "minmax"
    return {"cumulants": "cumulants", "exact": "exact"}.get(method, "sum")


def _freq_slabs(num_freq: int, max_groups: int, budget: int) -> tuple:
    """Split [0, num_freq) into slabs so each (max_groups, slab) exact-CF
    state stays within ``budget`` elements; slab widths stay lane-aligned
    (multiples of 128) so the Pallas kernel's frequency padding is bounded."""
    f_slab = max(1, budget // max(1, max_groups))
    if f_slab >= num_freq:
        return ((0, num_freq),)
    if f_slab > 128:
        f_slab -= f_slab % 128
    return tuple((lo, min(f_slab, num_freq - lo))
                 for lo in range(0, num_freq, f_slab))


_RESERVED_OUT_KEYS = frozenset({"valid", "keys", "confidence"})


def compile_plan(root: Node, mesh=None, *,
                 data_axes: Sequence[str] = ("data",),
                 model_axis: str | None = "model",
                 cf_budget_elems: int = 1 << 22):
    """Emit a function tables -> result (Table or dict of arrays).

    With ``mesh``, `GroupAgg` / `ReweightGreater` aggregation runs under
    shard_map on the mesh's data axes; results match the mesh=None compile.

    ``cf_budget_elems`` bounds the total live exact-CF state elements of a
    `GroupAgg(method="exact")` node — counting both the log-abs and angle
    (max_groups, slab) arrays of every exact aggregate on the node.  When
    the full (max_groups, num_freq) state would exceed it, the compiler
    runs multiple accumulation passes over frequency slabs (additively
    psum-merged per slab on a mesh) and concatenates the slab states
    before the one batched-FFT Finalize.
    """
    # One jitted distributed step per (aggregation node, slab), built on
    # first call (a step depends only on static config, not data).
    dist_steps: dict = {}

    def accumulate(node, udas, t, values, ids, max_groups, step_key=0):
        """ONE pass over the child's tuples for every UDA of the node —
        distributed Accumulate/Merge when a mesh is given."""
        probs = t.masked_prob()
        if mesh is None:
            return uda.accumulate(udas, probs, values, ids,
                                  max_groups=max_groups)
        from . import distributed as dist
        step = dist_steps.get((id(node), step_key))
        if step is None:
            # Grouped exact-CF states keep their frequency window replicated
            # over the model axis (the kernel needs a static freq_lo); the
            # psum over the data axes is the only cross-shard Merge, and
            # model replicas stay bit-identical, so model-axis
            # reconciliation is skipped for passes that carry a CF state.
            m_axis = None if any(isinstance(u, uda.SumCF)
                                 for u in udas.values()) else model_axis
            step = dist.make_uda_step(mesh, lambda size, rank: udas,
                                      max_groups=max_groups,
                                      data_axes=data_axes,
                                      model_axis=m_axis,
                                      post=lambda _u, states: states)
            dist_steps[(id(node), step_key)] = step
        probs, values, ids = dist.pad_for(mesh, probs, values, ids,
                                          max_groups=max_groups,
                                          data_axes=data_axes)
        return step(probs, values, ids)

    def run(node: Node, tables: Dict[str, Table]):
        if isinstance(node, Scan):
            return tables[node.name]
        if isinstance(node, Select):
            return ops.select(run(node.child, tables), node.pred)
        if isinstance(node, Map):
            t = run(node.child, tables)
            return t.with_column(node.name, node.fn(t))
        if isinstance(node, FKJoin):
            return ops.fk_join(run(node.left, tables),
                               run(node.right, tables),
                               node.left_key, node.right_key,
                               list(node.right_cols))
        if isinstance(node, Project):
            return ops.project(run(node.child, tables), list(node.keys),
                               node.max_groups)
        if isinstance(node, GroupAgg):
            t = run(node.child, tables)
            ids, codes, gvalid = ops.group_ids(t, list(node.keys),
                                               node.max_groups)

            specs = [(_out_key(node.agg, node.method), node.value, node.agg,
                      node.method)] + list(node.extra)
            names = [s[0] for s in specs]
            clashes = set(names) & _RESERVED_OUT_KEYS
            if clashes or len(set(names)) != len(names):
                raise ValueError(
                    f"GroupAgg aggregate names must be unique and avoid "
                    f"{sorted(_RESERVED_OUT_KEYS)}; got {names}")
            values: dict = {}
            cols: dict = {}        # fetch each source column exactly once
            for name, value, agg, method in specs:
                if agg == "COUNT" or not value:
                    values[name] = None
                else:
                    # Keep the raw column (uda.accumulate casts to the prob
                    # dtype itself): an integer source dtype is what makes
                    # an exact-CF aggregate eligible for the Pallas kernel.
                    if value not in cols:
                        cols[value] = t[value]
                    values[name] = cols[value]

            # Exact-CF states are (G, F) — chunk F against the memory
            # budget.  Pass 0 carries every aggregate (the riders share ONE
            # accumulation); later passes re-stream the tuples for the
            # remaining frequency slabs of the exact aggregates only.
            exact_names = [s[0] for s in specs if s[3] == "exact"]
            # The budget bounds TOTAL live exact-state elements: each exact
            # aggregate carries two (G, slab) arrays (log-abs + angle) and
            # every exact aggregate rides the same slab pass.
            slabs = (_freq_slabs(node.num_freq, node.max_groups,
                                 cf_budget_elems // (2 * len(exact_names)))
                     if exact_names else ((0, node.num_freq),))
            udas: dict = {}
            states: dict = {}
            for si, (lo, cnt) in enumerate(slabs):
                udas_i: dict = {}
                vals_i: dict = {}
                if si == 0:
                    udas_i["confidence"] = uda.AtLeastOne()
                    vals_i["confidence"] = None
                    for name, value, agg, method in specs:
                        if method != "exact":
                            udas_i[name] = _agg_uda(agg, method, node.kappa)
                            vals_i[name] = values[name]
                for name, value, agg, method in specs:
                    if method == "exact":
                        udas_i[name] = _agg_uda(agg, method, node.kappa,
                                                node.num_freq, lo, cnt)
                        vals_i[name] = values[name]
                sts = accumulate(node, udas_i, t, vals_i, ids,
                                 node.max_groups, step_key=si)
                for name, st in sts.items():
                    if name in states:          # append the frequency slab
                        prev = states[name]
                        states[name] = uda.CFState(
                            jnp.concatenate([prev.log_abs, st.log_abs], -1),
                            jnp.concatenate([prev.angle, st.angle], -1))
                    else:
                        states[name] = st
                        udas[name] = udas_i[name]
            for name in exact_names:            # full-range Finalize UDA
                udas[name] = _agg_uda("SUM", "exact", node.kappa,
                                      node.num_freq)

            out = dict(valid=gvalid,
                       keys=ops.group_key_columns(t, list(node.keys), ids,
                                                  node.max_groups),
                       confidence=udas["confidence"].finalize(
                           states["confidence"]))
            for name, value, agg, method in specs:
                u, st = udas[name], states[name]
                if agg in ("MIN", "MAX"):
                    out[name] = ops.minmax_runs(u, st)
                else:
                    out[name] = u.finalize(st)
            return out
        if isinstance(node, ReweightGreater):
            if not node.threshold_col and node.threshold is None:
                raise ValueError("ReweightGreater needs threshold_col or a "
                                 "constant threshold")
            t = run(node.child, tables)
            ids, codes, gvalid = ops.group_ids(t, list(node.keys),
                                               node.max_groups)
            udas = {"confidence": uda.AtLeastOne(), "sum": uda.SumNormal()}
            values = {"sum": t[node.value].astype(t.prob.dtype)}
            states = accumulate(node, udas, t, values, ids, node.max_groups)
            mu, var = udas["sum"].finalize(states["sum"])
            conf = udas["confidence"].finalize(states["confidence"])

            carry = list(node.keys) + list(node.carry_cols)
            if node.threshold_col:
                gcols = ops.group_key_columns(
                    t, carry + [node.threshold_col], ids, node.max_groups)
                thr = gcols[node.threshold_col].astype(mu.dtype)
            else:
                gcols = ops.group_key_columns(t, carry, ids, node.max_groups)
                thr = jnp.asarray(node.threshold, mu.dtype)
            p_gt = ops.normal_greater(mu, var, thr)
            cols = {k: gcols[k] for k in carry}
            return Table(cols, conf * p_gt, gvalid)
        raise TypeError(node)

    return lambda tables: run(root, tables)
