"""Probabilistic -> deterministic plan mapping (paper §VI, Table I),
mesh-aware.

A Plan is a small dataflow DAG of operator nodes.  ``compile_plan`` walks
the DAG and emits one jit-able function  tables -> results , realising the
paper's central claim: probabilistic queries run on a *deterministic*
engine (here: XLA) once every probabilistic operator is rewritten to a
deterministic one + segment-UDA calls (:mod:`repro.core.uda`).

``compile_plan(root, mesh)`` compiles the SAME plan for a device mesh with
the WHOLE pipeline sharded — no stage keeps a replicated copy of the data.
Every base table is row-partitioned over the mesh's data axes (contiguous
blocks, valid masks riding along; :mod:`repro.db.table`) and the plan runs
inside ONE shard_map:

    Scan            the shard-local block of the (chunk-padded) base table
    Select / Map    embarrassingly parallel on the local block
    FKJoin          build-side broadcast: all-gather the right relation's
                    (key, p, cols) columns, probe locally by sort +
                    searchsorted; right subtrees above
                    ``join_gather_budget`` rows are evaluated replicated
                    instead (their scans are fed unsharded)
    group ids       two-phase distributed unique: per-shard jnp.unique of
                    the live key codes -> all-gather + merge of the
                    per-shard code tables -> globally consistent ids via
                    searchsorted (`db.distributed.group_ids_sharded`) —
                    no replicated full-table unique on the data axis
    GroupAgg /      per-shard UDA Accumulate over the local tuples, ONE
    ReweightGreater collective Merge per aggregation pass
    / Project       (`db.distributed.allgather_merge`), replicated
                    Finalize; group-level outputs are replicated Tables

Determinism contract: every aggregation pass folds its tuples over a fixed
grid of ``canonical_chunks`` contiguous chunks and merges the partial
states in a balanced pairwise tree (:func:`repro.core.uda.
accumulate_chunked`).  A mesh whose shard count divides the grid computes
each shard's subtree locally and the cross-shard Merge finishes the SAME
tree, so ``compile_plan(root, mesh)`` results are BIT-IDENTICAL to
``compile_plan(root, None)`` — asserted per-plan by the mesh-equivalence
harness in tests/conftest.py.  Per-device memory is O(rows / shards) for
every pipeline stage (plus gathered join build sides and group-level
state), not O(total rows).

Node zoo (Table I rows in brackets):

    Scan(name)                               [I]   R -> R^p
    Select(child, pred)                      [II]  sigma, deterministic cond
    Map(child, name, fn)                     [--]  computed column
    FKJoin(l, r, lk, rk, cols)               [IV]  join, deterministic cond
    Project(child, keys, max_groups)         [V]   GROUP BY + AtLeastOne
    GroupAgg(child, keys, agg, value, ...)   [VI]  GROUP BY + PGF UDAs
                                                   (+ `extra` riders share
                                                   ONE accumulation pass)
    ReweightGreater(child, agg_of, vs, ...)  [III] p *= P(SUM > threshold)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core import uda
from . import operators as ops
from .table import Table


class Node:
    pass


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    name: str


@dataclasses.dataclass(frozen=True)
class Select(Node):
    child: Node
    pred: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Map(Node):
    """Attach a computed column `name` = fn(table) to the child relation."""
    child: Node
    name: str
    fn: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class FKJoin(Node):
    left: Node
    right: Node
    left_key: str
    right_key: str
    right_cols: tuple


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    keys: tuple
    max_groups: int


@dataclasses.dataclass(frozen=True)
class GroupAgg(Node):
    """Returns a dict of per-group UDA results, not a Table (PGF-valued
    columns live outside the 1NF Table, §VI-C).

    The primary aggregate lands under "sum" / "cumulants" / "exact" /
    "minmax" (by method/agg); each `extra` entry (name, value_col, agg,
    method) rides the SAME accumulation pass and lands under its own name.
    Group confidence (AtLeastOne) is always included.  `value` == "" means
    COUNT (all-ones).

    ``method="exact"`` computes the full per-group SUM/COUNT distribution
    via the grouped log-CF UDA (Pallas-accelerated on TPU) and requires
    ``num_freq`` = max aggregate value + 1; the result is a (max_groups,
    num_freq) row-stochastic coefficient matrix.  When max_groups *
    num_freq exceeds the planner's ``cf_budget_elems``, the compiler
    accumulates the state in multiple passes over frequency slabs (each
    slab additively psum-merged on a mesh) — see ``compile_plan``.
    """
    child: Node
    keys: tuple
    value: str            # column to aggregate ("" = COUNT)
    agg: str              # SUM | COUNT | MIN | MAX
    max_groups: int
    method: str = "normal"  # normal | cumulants | exact
    extra: tuple = ()
    kappa: int = 64       # MIN/MAX support capacity per group
    num_freq: int = 0     # exact: distribution capacity (max sum + 1)


@dataclasses.dataclass(frozen=True)
class ReweightGreater(Node):
    """sigma_{AGG(B) > C}: group child by keys, SUM(value), then keep each
    group with p = AtLeastOne * P(SUM > threshold) (Table I row III).
    The threshold is `threshold_col` (per-group column) when set, else the
    constant `threshold`; `carry_cols` are extra per-group columns kept on
    the output Table (all valid writers of a group agree)."""
    child: Node
    keys: tuple
    value: str
    threshold_col: str
    max_groups: int
    threshold: float | None = None
    carry_cols: tuple = ()


def _agg_uda(agg: str, method: str, kappa: int, num_freq: int = 0,
             freq_lo: int = 0, freq_cnt: int | None = None) -> uda.UDA:
    if agg in ("SUM", "COUNT"):
        if method == "normal":
            return uda.SumNormal()
        if method == "cumulants":
            return uda.SumCumulants()
        if method == "exact":
            if num_freq <= 0:
                raise ValueError(
                    "GroupAgg(method='exact') needs num_freq = max "
                    "aggregate value + 1 (the static distribution capacity)")
            return uda.SumCF(num_freq, freq_lo=freq_lo, freq_cnt=freq_cnt)
        raise ValueError(
            f"GroupAgg method {method!r} is not supported by the planner "
            "(expected 'normal', 'cumulants' or 'exact')")
    if agg in ("MIN", "MAX"):
        if method == "exact":
            raise ValueError(
                "GroupAgg method 'exact' applies to SUM/COUNT only; MIN/MAX "
                "distributions come from the MinMax UDA (kappa support)")
        return uda.MinMax(kappa=kappa, sign=1.0 if agg == "MIN" else -1.0)
    raise ValueError(agg)


def _out_key(agg: str, method: str) -> str:
    if agg in ("MIN", "MAX"):
        return "minmax"
    return {"cumulants": "cumulants", "exact": "exact"}.get(method, "sum")


def _freq_slabs(num_freq: int, max_groups: int, budget: int) -> tuple:
    """Split [0, num_freq) into slabs so each (max_groups, slab) exact-CF
    state stays within ``budget`` elements; slab widths stay lane-aligned
    (multiples of 128) so the Pallas kernel's frequency padding is bounded."""
    f_slab = max(1, budget // max(1, max_groups))
    if f_slab >= num_freq:
        return ((0, num_freq),)
    if f_slab > 128:
        f_slab -= f_slab % 128
    return tuple((lo, min(f_slab, num_freq - lo))
                 for lo in range(0, num_freq, f_slab))


_RESERVED_OUT_KEYS = frozenset({"valid", "keys", "confidence"})


@dataclasses.dataclass
class _Rel:
    """A relation mid-plan: a (possibly shard-local) Table plus whether its
    rows are partitioned over the mesh's data axes.  Group-level outputs
    (ReweightGreater / Project) and gathered build sides are replicated —
    every shard holds the identical full Table."""
    table: Table
    sharded: bool


def compile_plan(root: Node, mesh=None, *,
                 data_axes: Sequence[str] = ("data",),
                 model_axis: str | None = "model",
                 cf_budget_elems: int = 1 << 22,
                 canonical_chunks: int = 8,
                 join_gather_budget: int = 1 << 20):
    """Emit a function tables -> result (Table or dict of arrays).

    With ``mesh``, the WHOLE plan runs inside one shard_map over the
    mesh's data axes — scans, selects, joins, group-id assignment and
    aggregation all consume shard-local row blocks (see module docstring
    for the per-operator protocol); results are bit-identical to the
    mesh=None compile.  Tuples stay replicated over ``model_axis`` (every
    collective here runs on the data axes only, so model replicas remain
    bit-identical and need no reconciliation).

    ``canonical_chunks`` is the fixed accumulation grid that makes results
    shard-count-invariant: it must be a power of two and a multiple of the
    mesh's data-shard count.  ``join_gather_budget`` caps the rows of an
    FKJoin build side that may be all-gathered; larger right subtrees are
    evaluated replicated instead.

    ``cf_budget_elems`` bounds the total live exact-CF state elements of a
    `GroupAgg(method="exact")` node — counting both the log-abs and angle
    (max_groups, slab) arrays of every exact aggregate on the node.  When
    the full (max_groups, num_freq) state would exceed it, the compiler
    runs multiple accumulation passes over frequency slabs (each slab
    collective-merged on a mesh) and concatenates the slab states before
    the one batched-FFT Finalize.
    """
    from . import distributed as dist

    mesh_mode = mesh is not None
    axes = dist._tuple_axes(mesh, data_axes) if mesh_mode else ()
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    chunks = canonical_chunks
    if chunks & (chunks - 1) or chunks <= 0:
        raise ValueError(f"canonical_chunks must be a power of two, "
                         f"got {chunks}")
    if chunks % shards:
        raise ValueError(
            f"the canonical chunk grid ({chunks}) must be a multiple of the "
            f"mesh's data-shard count ({shards}): pass a larger power-of-two "
            f"canonical_chunks to compile_plan (bit-reproducible sharding "
            f"needs a power-of-two data-shard count)")
    local_chunks = chunks // shards

    # Global (pre-shard) padded capacities of the current compile, set by
    # `compiled` before tracing: the build-side budget must see global row
    # counts even inside shard_map, where tables are 1/shards-sized blocks.
    global_caps: dict = {}

    def _cap(node: Node) -> int:
        """Static GLOBAL output capacity (rows) of a relational subtree."""
        if isinstance(node, Scan):
            return global_caps[node.name]
        if isinstance(node, (Select, Map)):
            return _cap(node.child)
        if isinstance(node, FKJoin):
            return _cap(node.left)
        if isinstance(node, (Project, ReweightGreater)):
            return node.max_groups
        raise TypeError(node)

    def _repl_scans(node: Node, out: set, repl: bool = False):
        """Names of base tables that some over-budget FKJoin build subtree
        scans — these are fed into the shard_map replicated as well."""
        if isinstance(node, Scan):
            if repl:
                out.add(node.name)
        elif isinstance(node, FKJoin):
            _repl_scans(node.left, out, repl)
            big = _cap(node.right) > join_gather_budget
            _repl_scans(node.right, out, repl or big)
        else:
            _repl_scans(node.child, out, repl)

    def run_plan(sh_tables: Dict[str, Table], rp_tables: Dict[str, Table]):
        """Execute the plan; in mesh mode this body runs inside shard_map
        (sh_tables are local row blocks, rp_tables replicated)."""

        def acc(udas_d, rel: _Rel, values, ids, max_groups):
            """ONE canonical chunked pass over the relation's tuples for
            every UDA of the node, plus the cross-shard Merge when the
            rows are partitioned.  The chunk grid is the same in every
            compile: a sharded pass runs its chunks/shards local chunks
            and allgather_merge finishes the identical fold tree."""
            probs = rel.table.masked_prob()
            states = uda.accumulate_chunked(
                udas_d, probs, values, ids, max_groups=max_groups,
                num_chunks=local_chunks if rel.sharded else chunks)
            if rel.sharded and axes:
                states = dist.allgather_merge(udas_d, states, axes)
            return states

        def rel_group_ids(rel: _Rel, keys, max_groups):
            if rel.sharded and axes:
                return dist.group_ids_sharded(rel.table, list(keys),
                                              max_groups, axes)
            return ops.group_ids(rel.table, list(keys), max_groups)

        def rel_key_columns(rel: _Rel, keys, ids, max_groups):
            if rel.sharded and axes:
                return dist.group_key_columns_sharded(rel.table, keys, ids,
                                                      max_groups, axes)
            return ops.group_key_columns(rel.table, keys, ids, max_groups)

        def run(node: Node, repl: bool):
            if isinstance(node, Scan):
                if repl:
                    return _Rel(rp_tables[node.name], False)
                return _Rel(sh_tables[node.name], mesh_mode and bool(axes))
            if isinstance(node, Select):
                r = run(node.child, repl)
                return _Rel(ops.select(r.table, node.pred), r.sharded)
            if isinstance(node, Map):
                r = run(node.child, repl)
                return _Rel(r.table.with_column(node.name, node.fn(r.table)),
                            r.sharded)
            if isinstance(node, FKJoin):
                lrel = run(node.left, repl)
                big = mesh_mode and _cap(node.right) > join_gather_budget
                rrel = run(node.right, repl or big)
                rtab = rrel.table
                if rrel.sharded and axes:
                    # Broadcast the small build side: all-gather only the
                    # probe key + carried columns (plus p and valid).
                    rtab = dist.gather_table(
                        rtab.select_columns(
                            dict.fromkeys((node.right_key,)
                                          + tuple(node.right_cols))),
                        axes)
                return _Rel(ops.fk_join(lrel.table, rtab, node.left_key,
                                        node.right_key,
                                        list(node.right_cols)),
                            lrel.sharded)
            if isinstance(node, Project):
                rel = run(node.child, repl)
                ids, _, gvalid = rel_group_ids(rel, node.keys,
                                               node.max_groups)
                u = uda.AtLeastOne()
                st = acc({"conf": u}, rel, {"conf": None}, ids,
                         node.max_groups)["conf"]
                cols = rel_key_columns(rel, list(node.keys), ids,
                                       node.max_groups)
                return _Rel(Table(cols, u.finalize(st), gvalid), False)
            if isinstance(node, GroupAgg):
                rel = run(node.child, repl)
                ids, _, gvalid = rel_group_ids(rel, node.keys,
                                               node.max_groups)

                specs = [(_out_key(node.agg, node.method), node.value,
                          node.agg, node.method)] + list(node.extra)
                names = [s[0] for s in specs]
                clashes = set(names) & _RESERVED_OUT_KEYS
                if clashes or len(set(names)) != len(names):
                    raise ValueError(
                        f"GroupAgg aggregate names must be unique and avoid "
                        f"{sorted(_RESERVED_OUT_KEYS)}; got {names}")
                values: dict = {}
                cols: dict = {}    # fetch each source column exactly once
                for name, value, agg, method in specs:
                    if agg == "COUNT" or not value:
                        values[name] = None
                    else:
                        # Keep the raw column (uda.accumulate casts to the
                        # prob dtype itself): an integer source dtype is
                        # what makes an exact-CF aggregate eligible for the
                        # Pallas kernel.
                        if value not in cols:
                            cols[value] = rel.table[value]
                        values[name] = cols[value]

                # Exact-CF states are (G, F) — chunk F against the memory
                # budget.  Pass 0 carries every aggregate (the riders share
                # ONE accumulation); later passes re-stream the tuples for
                # the remaining frequency slabs of the exact aggregates.
                exact_names = [s[0] for s in specs if s[3] == "exact"]
                # The budget bounds TOTAL live exact-state elements: each
                # exact aggregate carries two (G, slab) arrays (log-abs +
                # angle) and every exact aggregate rides the same slab pass.
                slabs = (_freq_slabs(node.num_freq, node.max_groups,
                                     cf_budget_elems // (2 * len(exact_names)))
                         if exact_names else ((0, node.num_freq),))
                udas: dict = {}
                states: dict = {}
                for si, (lo, cnt) in enumerate(slabs):
                    udas_i: dict = {}
                    vals_i: dict = {}
                    if si == 0:
                        udas_i["confidence"] = uda.AtLeastOne()
                        vals_i["confidence"] = None
                        for name, value, agg, method in specs:
                            if method != "exact":
                                udas_i[name] = _agg_uda(agg, method,
                                                        node.kappa)
                                vals_i[name] = values[name]
                    for name, value, agg, method in specs:
                        if method == "exact":
                            udas_i[name] = _agg_uda(agg, method, node.kappa,
                                                    node.num_freq, lo, cnt)
                            vals_i[name] = values[name]
                    sts = acc(udas_i, rel, vals_i, ids, node.max_groups)
                    for name, st in sts.items():
                        if name in states:      # append the frequency slab
                            prev = states[name]
                            states[name] = uda.CFState(
                                jnp.concatenate([prev.log_abs, st.log_abs],
                                                -1),
                                jnp.concatenate([prev.angle, st.angle], -1))
                        else:
                            states[name] = st
                            udas[name] = udas_i[name]
                for name in exact_names:        # full-range Finalize UDA
                    udas[name] = _agg_uda("SUM", "exact", node.kappa,
                                          node.num_freq)

                out = dict(valid=gvalid,
                           keys=rel_key_columns(rel, list(node.keys), ids,
                                                node.max_groups),
                           confidence=udas["confidence"].finalize(
                               states["confidence"]))
                for name, value, agg, method in specs:
                    u, st = udas[name], states[name]
                    if agg in ("MIN", "MAX"):
                        out[name] = ops.minmax_runs(u, st)
                    else:
                        out[name] = u.finalize(st)
                return out
            if isinstance(node, ReweightGreater):
                if not node.threshold_col and node.threshold is None:
                    raise ValueError("ReweightGreater needs threshold_col "
                                     "or a constant threshold")
                rel = run(node.child, repl)
                ids, _, gvalid = rel_group_ids(rel, node.keys,
                                               node.max_groups)
                udas = {"confidence": uda.AtLeastOne(),
                        "sum": uda.SumNormal()}
                values = {"sum":
                          rel.table[node.value].astype(rel.table.prob.dtype)}
                states = acc(udas, rel, values, ids, node.max_groups)
                mu, var = udas["sum"].finalize(states["sum"])
                conf = udas["confidence"].finalize(states["confidence"])

                carry = list(node.keys) + list(node.carry_cols)
                if node.threshold_col:
                    gcols = rel_key_columns(
                        rel, carry + [node.threshold_col], ids,
                        node.max_groups)
                    thr = gcols[node.threshold_col].astype(mu.dtype)
                else:
                    gcols = rel_key_columns(rel, carry, ids,
                                            node.max_groups)
                    thr = jnp.asarray(node.threshold, mu.dtype)
                p_gt = ops.normal_greater(mu, var, thr)
                cols = {k: gcols[k] for k in carry}
                return _Rel(Table(cols, conf * p_gt, gvalid), False)
            raise TypeError(node)

        out = run(root, False)
        if isinstance(out, _Rel):
            if out.sharded and axes:
                return dist.gather_table(out.table, axes)
            return out.table
        return out

    def compiled(tables: Dict[str, Table]):
        # Both compiles pad every base table to the canonical chunk grid:
        # the chunk boundaries define the deterministic fold tree (and the
        # even contiguous row partition on a mesh).
        padded = {k: t.pad_to_multiple(chunks) for k, t in tables.items()}
        global_caps.clear()
        global_caps.update({k: t.capacity for k, t in padded.items()})
        if not mesh_mode:
            return run_plan(padded, padded)
        repl_names: set = set()
        _repl_scans(root, repl_names)
        rp_tables = {k: padded[k] for k in sorted(repl_names)}
        fn = shard_map(run_plan, mesh=mesh,
                       in_specs=(P(axes), P()), out_specs=P(),
                       check_vma=False)
        return fn(padded, rp_tables)

    return compiled
