"""Probabilistic -> deterministic plan compilation (paper §VI, Table I):
a two-stage compiler over the logical plan DAG.

A Plan is a small dataflow DAG of LOGICAL operator nodes (the zoo below).
``compile_plan`` no longer interprets it directly: it first LOWERS the
logical DAG to an explicit physical-plan IR — :mod:`repro.db.physical`,
where every node carries its execution strategy and a partitioning
property (Replicated / RowBlocked / HashPartitioned) — and then an
EXECUTOR (this module) interprets the physical plan, realising the
paper's central claim: probabilistic queries run on a *deterministic*
engine (here: XLA) once every probabilistic operator is rewritten to a
deterministic one + segment-UDA calls (:mod:`repro.core.uda`).

``compile_plan(root, mesh)`` lowers the SAME logical plan for a device
mesh and runs the whole physical plan inside ONE shard_map — no stage
keeps a replicated copy of any base table:

    ShardScan       the shard-local block of the (chunk-padded) base table
    Select / Map    embarrassingly parallel on the local block
    GatherJoin      small build side: all-gather the right relation's
                    (key, p, cols) columns, probe locally
    ShuffleJoin     build side above ``join_gather_budget`` (the
                    ``FKJoin.gather_budget`` per-node override wins):
                    hash-partition build rows AND probe keys to
                    ``key % n_shards`` owners with ``dist.shuffle_by_key``
                    (static buckets, overflow accounted), match
                    shard-locally, shuffle responses home — peak build
                    rows/device O(build/shards), no replicated fallback
    CoPartitioned-  the fused shuffle -> aggregate pipeline: when the
    Join /          downstream GROUP BY keys on the probe join key, probe
    Repartition     rows ship (p, canonical chunk id, value columns) and
                    matched rows STAY at their owner — no shuffle-home
                    round-trip (``dist.copartitioned_fk_join``);
                    ``dist.repartition_by_key`` is the no-join feed
    group ids       two-phase distributed unique (exact under overflow;
                    `db.distributed.group_ids_sharded`) — owner-local
                    over HashPartitioned blocks, same merged code table
    PartialAgg /    per-shard, per-canonical-chunk UDA Accumulate, then
    MergeAgg        ONE collective per aggregation pass assembling every
                    chunk state (`db.distributed.allgather_merge`) and the
                    replicated Finalize; group-level outputs are
                    replicated Tables
    PartitionedAgg  the HashPartitioned Accumulate: ONE compound
                    (chunk, group) pass over the exchange buffer, the
                    canonical chunk fold finished LOCALLY per owner, and
                    one psum / gather-fold Merge
                    (`db.distributed.partitioned_merge`)

    Strategy choice is the enumerate -> cost -> pick pass of
    ``physical.lower_plan`` over the explicit model in ``db/cost.py``;
    the budget knobs survive as cost overrides.

Determinism contract: every aggregation pass folds its tuples over a
fixed grid of ``canonical_chunks`` contiguous chunks and merges the chunk
states in the one fixed tree of :func:`repro.core.uda.tree_fold`
(pow2-base + sequential tail).  Each chunk is computed wholly on one
shard and ALL chunk states are gathered before the fold, so ANY shard
count — 2, 3, 4, ... — computes the SAME tree and
``compile_plan(root, mesh)`` results are BIT-IDENTICAL to
``compile_plan(root, None)`` — asserted per-plan by the mesh-equivalence
harness in tests/conftest.py, including plans that lower to ShuffleJoin.
Per-device memory is O(rows / shards) for every pipeline stage (plus
gathered small build sides and group-level state), not O(total rows).

Node zoo (Table I rows in brackets):

    Scan(name)                               [I]   R -> R^p
    Select(child, pred)                      [II]  sigma, deterministic cond
    Map(child, name, fn)                     [--]  computed column
    FKJoin(l, r, lk, rk, cols[, budget])     [IV]  join, deterministic cond
    Project(child, keys, max_groups)         [V]   GROUP BY + AtLeastOne
    GroupAgg(child, keys, agg, value, ...)   [VI]  GROUP BY + PGF UDAs
                                                   (+ `extra` riders share
                                                   ONE accumulation pass)
    ReweightGreater(child, agg_of, vs, ...)  [III] p *= P(SUM > threshold)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core import uda
from . import operators as ops
from . import physical as phys
from .table import Table


class Node:
    pass


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    name: str


@dataclasses.dataclass(frozen=True)
class Select(Node):
    child: Node
    pred: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Map(Node):
    """Attach a computed column `name` = fn(table) to the child relation."""
    child: Node
    name: str
    fn: Callable[[Table], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class FKJoin(Node):
    """Many-to-one equijoin.  ``gather_budget`` overrides the compiler's
    global ``join_gather_budget`` for THIS join (rows of build side that
    may be all-gathered; larger builds lower to ShuffleJoin on a mesh), so
    mixed plans can gather small dimensions while shuffling large ones."""
    left: Node
    right: Node
    left_key: str
    right_key: str
    right_cols: tuple
    gather_budget: int | None = None


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    keys: tuple
    max_groups: int


@dataclasses.dataclass(frozen=True)
class GroupAgg(Node):
    """Returns a dict of per-group UDA results, not a Table (PGF-valued
    columns live outside the 1NF Table, §VI-C).

    The primary aggregate lands under "sum" / "cumulants" / "exact" /
    "minmax" (by method/agg); each `extra` entry (name, value_col, agg,
    method) rides the SAME accumulation pass and lands under its own name.
    Group confidence (AtLeastOne) is always included.  `value` == "" means
    COUNT (all-ones).

    ``method="exact"`` computes the full per-group SUM/COUNT distribution
    via the grouped log-CF UDA (Pallas-accelerated on TPU) and requires
    ``num_freq`` = max aggregate value + 1; the result is a (max_groups,
    num_freq) row-stochastic coefficient matrix.  When max_groups *
    num_freq exceeds the planner's ``cf_budget_elems``, the compiler
    accumulates the state in multiple passes over frequency slabs (each
    slab additively merged on a mesh) — see ``compile_plan``.
    """
    child: Node
    keys: tuple
    value: str            # column to aggregate ("" = COUNT)
    agg: str              # SUM | COUNT | MIN | MAX
    max_groups: int
    method: str = "normal"  # normal | cumulants | exact
    extra: tuple = ()
    kappa: int = 64       # MIN/MAX support capacity per group
    num_freq: int = 0     # exact: distribution capacity (max sum + 1)


@dataclasses.dataclass(frozen=True)
class ReweightGreater(Node):
    """sigma_{AGG(B) > C}: group child by keys, SUM(value), then keep each
    group with p = AtLeastOne * P(SUM > threshold) (Table I row III).
    The threshold is `threshold_col` (per-group column) when set, else the
    constant `threshold`; `carry_cols` are extra per-group columns kept on
    the output Table (all valid writers of a group agree)."""
    child: Node
    keys: tuple
    value: str
    threshold_col: str
    max_groups: int
    threshold: float | None = None
    carry_cols: tuple = ()


def _agg_uda(agg: str, method: str, kappa: int, num_freq: int = 0,
             freq_lo: int = 0, freq_cnt: int | None = None) -> uda.UDA:
    if agg in ("SUM", "COUNT"):
        if method == "normal":
            return uda.SumNormal()
        if method == "cumulants":
            return uda.SumCumulants()
        if method == "exact":
            if num_freq <= 0:
                raise ValueError(
                    "GroupAgg(method='exact') needs num_freq = max "
                    "aggregate value + 1 (the static distribution capacity)")
            return uda.SumCF(num_freq, freq_lo=freq_lo, freq_cnt=freq_cnt)
        raise ValueError(
            f"GroupAgg method {method!r} is not supported by the planner "
            "(expected 'normal', 'cumulants' or 'exact')")
    if agg in ("MIN", "MAX"):
        if method == "exact":
            raise ValueError(
                "GroupAgg method 'exact' applies to SUM/COUNT only; MIN/MAX "
                "distributions come from the MinMax UDA (kappa support)")
        return uda.MinMax(kappa=kappa, sign=1.0 if agg == "MIN" else -1.0)
    raise ValueError(agg)


def _out_key(agg: str, method: str) -> str:
    if agg in ("MIN", "MAX"):
        return "minmax"
    return {"cumulants": "cumulants", "exact": "exact"}.get(method, "sum")


def _freq_slabs(num_freq: int, max_groups: int, budget: int) -> tuple:
    """Split [0, num_freq) into slabs so each (max_groups, slab) exact-CF
    state stays within ``budget`` elements; slab widths stay lane-aligned
    (multiples of 128) so the Pallas kernel's frequency padding is bounded."""
    f_slab = max(1, budget // max(1, max_groups))
    if f_slab >= num_freq:
        return ((0, num_freq),)
    if f_slab > 128:
        f_slab -= f_slab % 128
    return tuple((lo, min(f_slab, num_freq - lo))
                 for lo in range(0, num_freq, f_slab))


def shard_capacity(capacity: int, canonical_chunks: int, shards: int) -> int:
    """The padded capacity ``compile_plan`` gives a base table: first the
    canonical chunk grid (chunk size csz = ceil(n / chunks)), then enough
    whole PADDING CHUNKS that every shard owns the same number of chunk
    slots — shards * ceil(chunks / shards) * csz rows.  For shard counts
    dividing the grid this adds nothing beyond the chunk padding; padding
    chunks hold only invalid p = 0 rows and their (identity) states are
    sliced away before the canonical fold."""
    csz = -(-capacity // canonical_chunks)
    local = -(-canonical_chunks // shards)
    return shards * local * csz


def compile_plan(root: Node, mesh=None, *,
                 data_axes: Sequence[str] = ("data",),
                 model_axis: str | None = "model",
                 cf_budget_elems: int = 1 << 22,
                 canonical_chunks: int = 8,
                 join_gather_budget: int = 1 << 20,
                 shuffle_slack: float = 4.0,
                 copartition: object = "auto",
                 agg_shuffle_budget: int | None = None,
                 cost_model=None):
    """Emit a function tables -> result (Table or dict of arrays).

    With ``mesh``, the logical plan lowers to a sharded physical plan
    (:func:`repro.db.physical.lower_plan`) and the WHOLE plan runs inside
    one shard_map over the mesh's data axes — scans, selects, joins,
    group-id assignment and aggregation all consume shard-local row
    blocks (see module docstring for the per-operator strategies);
    results are bit-identical to the mesh=None compile for ANY data-shard
    count.  Tuples stay replicated over ``model_axis`` (every collective
    here runs on the data axes only, so model replicas remain
    bit-identical and need no reconciliation).

    ``canonical_chunks`` (any positive count) is the fixed accumulation
    grid that makes results shard-count-invariant.  ``join_gather_budget``
    caps the rows of an FKJoin build side that may be all-gathered; larger
    build sides lower to a hash-partitioned strategy, whose static bucket
    capacities come from the concrete ``key % n_shards`` histogram when
    the key column is concrete at compile time (eager compiles; overflow
    impossible) and otherwise from ``shuffle_slack`` times the uniform
    share (overflow is counted and poisons the join output with NaN — see
    ``dist.shuffle_fk_join``).  A per-node ``FKJoin.gather_budget``
    overrides the global for that join.

    Which hash-partitioned strategy runs is a COST decision
    (``db/cost.py`` via ``physical.lower_plan``): when the downstream
    GROUP BY keys on the probe join key, the fused CoPartitionedJoin +
    PartitionedAgg pipeline (matched rows stay at their owner, zero
    shuffle-home round-trips, one psum merge) competes with ShuffleJoin +
    PartialAgg.  ``copartition`` overrides it: "auto" (default) lets the
    estimates decide, True forces the fused pipeline whenever legal and
    the join may not gather, False disables it.  ``agg_shuffle_budget``
    (default None = off) makes single-key aggregations over more input
    rows hash-exchange their tuples to per-group owners
    (``Repartition`` + PartitionedAgg) — the fused pipeline without a
    join.  ``cost_model`` replaces the knob-derived
    :class:`repro.db.cost.CostModel` wholesale.  Every strategy is
    bit-identical to every other and to mesh=None (the canonical-chunk
    fold contract extends to owner-local folds; see
    ``dist.partitioned_merge``).

    ``cf_budget_elems`` bounds the total live exact-CF state elements of a
    `GroupAgg(method="exact")` node — counting both the log-abs and angle
    (max_groups, slab) arrays of every exact aggregate on the node.  When
    the full (max_groups, num_freq) state would exceed it, the compiler
    runs multiple accumulation passes over frequency slabs (each slab
    collective-merged on a mesh) and concatenates the slab states before
    the one batched-FFT Finalize; the grouped kernel's argsort/operand
    prep is hoisted above the slab loop (:func:`repro.core.uda.
    cf_chunk_operands`).
    """
    from . import distributed as dist

    mesh_mode = mesh is not None
    axes = dist._tuple_axes(mesh, data_axes) if mesh_mode else ()
    shards = 1
    for a in axes:
        shards *= mesh.shape[a]
    chunks = canonical_chunks
    if chunks <= 0:
        raise ValueError(f"canonical_chunks must be positive, got {chunks}")
    local_chunks = -(-chunks // shards)

    # Canonical (chunk-grid-only) capacities of the base tables, set by
    # `compiled` before tracing: the shape a relational result has in the
    # mesh=None compile, before any shard-alignment padding chunks.
    canon_caps: dict = {}

    def _canonical_rows(pnode: phys.PhysNode) -> int:
        """Root output rows of a relational subtree under mesh=None padding
        (row capacity follows the probe/left lineage down to its scan)."""
        if isinstance(pnode, phys.ShardScan):
            return canon_caps[pnode.name]
        if isinstance(pnode, (phys.PhysSelect, phys.PhysMap,
                              phys.Repartition)):
            return _canonical_rows(pnode.child)
        if isinstance(pnode, (phys.GatherJoin, phys.ShuffleJoin,
                              phys.CoPartitionedJoin)):
            return _canonical_rows(pnode.left)
        if isinstance(pnode, phys.MergeAgg):
            return pnode.child.max_groups
        raise TypeError(pnode)

    def run_plan(sh_tables: Dict[str, Table], proot: phys.PhysNode):
        """Interpret the physical plan; in mesh mode this body runs inside
        shard_map (sh_tables are shard-local row blocks)."""

        def sharded(t: Table) -> bool:
            return bool(axes) and isinstance(t.part, phys.RowBlocked)

        def hash_partitioned(t: Table) -> bool:
            return bool(axes) and isinstance(t.part, phys.HashPartitioned)

        def acc(udas_d, table: Table, values, ids, max_groups,
                cf_operands=None):
            """ONE canonical chunked pass over the relation's tuples for
            every UDA of the pass.  The chunk grid is the same in every
            compile: a RowBlocked pass computes its local chunk slots'
            states and allgather_merge assembles ALL chunk states so every
            shard finishes the identical fold tree; a HashPartitioned
            pass (the fused pipeline) computes EVERY canonical chunk's
            slice in one compound (chunk, group) accumulate over the
            exchange buffer — received rows arrive in global row order,
            so each (chunk, group) slot folds the same tuples in the same
            order as the RowBlocked chunk pass — and partitioned_merge
            finishes the identical fold owner-locally before one psum."""
            probs = table.masked_prob()
            if hash_partitioned(table):
                cid = jnp.clip(table[phys.CHUNK_COL].astype(jnp.int32),
                               0, chunks - 1)
                comp = cid * max_groups + ids
                flat = uda.accumulate(udas_d, probs, values, comp,
                                      max_groups=chunks * max_groups)
                parts = [{name: jax.tree.map(
                    lambda x, c=c: x[c * max_groups:(c + 1) * max_groups],
                    st) for name, st in flat.items()}
                    for c in range(chunks)]
                return dist.partitioned_merge(udas_d, parts, axes)
            if sharded(table):
                parts = uda.accumulate_chunk_states(
                    udas_d, probs, values, ids, max_groups=max_groups,
                    num_chunks=local_chunks, cf_operands=cf_operands)
                return dist.allgather_merge(udas_d, parts, axes, chunks,
                                            shards)
            return uda.accumulate_chunked(
                udas_d, probs, values, ids, max_groups=max_groups,
                num_chunks=chunks, cf_operands=cf_operands)

        def rel_group_ids(t: Table, keys, max_groups):
            if sharded(t) or hash_partitioned(t):
                return dist.group_ids_sharded(t, list(keys), max_groups,
                                              axes)
            return ops.group_ids(t, list(keys), max_groups)

        def rel_key_columns(t: Table, keys, ids, max_groups):
            if sharded(t) or hash_partitioned(t):
                return dist.group_key_columns_sharded(t, keys, ids,
                                                      max_groups, axes)
            return ops.group_key_columns(t, keys, ids, max_groups)

        def run_agg(node: phys.MergeAgg):
            """The PartialAgg/MergeAgg pair executes as one unit: group
            ids, then per frequency slab one Accumulate (per-chunk
            partials) + ONE collective Merge, then the replicated Finalize
            selected by ``kind``."""
            pa = node.child
            t = run(pa.child)
            mg = pa.max_groups
            ids, _, gvalid = rel_group_ids(t, pa.keys, mg)

            specs = list(pa.specs)
            values: dict = {}
            cols: dict = {}    # fetch each source column exactly once
            for name, value, agg, method in specs:
                if agg == "COUNT" or not value:
                    values[name] = None
                else:
                    # Keep the raw column (uda.accumulate casts to the
                    # prob dtype itself): an integer source dtype is
                    # what makes an exact-CF aggregate eligible for the
                    # Pallas kernel.
                    if value not in cols:
                        cols[value] = t[value]
                    values[name] = cols[value]

            # Exact-CF states are (G, F) — chunk F against the memory
            # budget.  Pass 0 carries every aggregate (the riders share
            # ONE accumulation); later passes re-stream the tuples for
            # the remaining frequency slabs of the exact aggregates.
            exact_names = [s[0] for s in specs if s[3] == "exact"]
            # The budget bounds TOTAL live exact-state elements: each
            # exact aggregate carries two (G, slab) arrays (log-abs +
            # angle) and every exact aggregate rides the same slab pass.
            slabs = (_freq_slabs(pa.num_freq, mg,
                                 cf_budget_elems // (2 * len(exact_names)))
                     if exact_names else ((0, pa.num_freq),))
            cf_operands: dict = {}
            if len(slabs) > 1 and not hash_partitioned(t):
                # Hoist the grouped kernel's argsort(gids) + operand prep
                # above the slab loop: prepared once per canonical chunk,
                # reused by every slab pass (None when the kernel would
                # not be dispatched — the scan/oracle paths sort nothing;
                # the compound pass of the fused pipeline sorts per call).
                probs_m = t.masked_prob()
                nloc = local_chunks if sharded(t) else chunks
                for name in exact_names:
                    prepared = uda.cf_chunk_operands(
                        pa.num_freq, probs_m, values[name], ids,
                        max_groups=mg, num_chunks=nloc)
                    if prepared is not None:
                        cf_operands[name] = prepared
            udas: dict = {}
            states: dict = {}
            for si, (lo, cnt) in enumerate(slabs):
                udas_i: dict = {}
                vals_i: dict = {}
                if si == 0:
                    udas_i["confidence"] = uda.AtLeastOne()
                    vals_i["confidence"] = None
                    for name, value, agg, method in specs:
                        if method != "exact":
                            udas_i[name] = _agg_uda(agg, method, pa.kappa)
                            vals_i[name] = values[name]
                for name, value, agg, method in specs:
                    if method == "exact":
                        udas_i[name] = _agg_uda(agg, method, pa.kappa,
                                                pa.num_freq, lo, cnt)
                        vals_i[name] = values[name]
                sts = acc(udas_i, t, vals_i, ids, mg,
                          cf_operands=cf_operands or None)
                for name, st in sts.items():
                    if name in states:          # append the frequency slab
                        prev = states[name]
                        states[name] = uda.CFState(
                            jnp.concatenate([prev.log_abs, st.log_abs], -1),
                            jnp.concatenate([prev.angle, st.angle], -1))
                    else:
                        states[name] = st
                        udas[name] = udas_i[name]
            for name in exact_names:            # full-range Finalize UDA
                udas[name] = _agg_uda("SUM", "exact", pa.kappa, pa.num_freq)

            conf = udas["confidence"].finalize(states["confidence"])
            if node.kind == "project":
                gcols = rel_key_columns(t, list(pa.keys), ids, mg)
                return Table(gcols, conf, gvalid, node.part)
            if node.kind == "reweight":
                mu, var = udas["sum"].finalize(states["sum"])
                carry = list(pa.keys) + list(node.carry_cols)
                if node.threshold_col:
                    gcols = rel_key_columns(t, carry + [node.threshold_col],
                                            ids, mg)
                    thr = gcols[node.threshold_col].astype(mu.dtype)
                else:
                    gcols = rel_key_columns(t, carry, ids, mg)
                    thr = jnp.asarray(node.threshold, mu.dtype)
                p_gt = ops.normal_greater(mu, var, thr)
                return Table({k: gcols[k] for k in carry}, conf * p_gt,
                             gvalid, node.part)
            out = dict(valid=gvalid,
                       keys=rel_key_columns(t, list(pa.keys), ids, mg),
                       confidence=conf)
            for name, value, agg, method in specs:
                u, st = udas[name], states[name]
                if agg in ("MIN", "MAX"):
                    out[name] = ops.minmax_runs(u, st)
                else:
                    out[name] = u.finalize(st)
            return out

        def run(node: phys.PhysNode):
            if isinstance(node, phys.ShardScan):
                return sh_tables[node.name].with_part(node.part)
            if isinstance(node, phys.PhysSelect):
                return ops.select(run(node.child), node.pred)
            if isinstance(node, phys.PhysMap):
                t = run(node.child)
                return t.with_column(node.name, node.fn(t))
            if isinstance(node, phys.GatherJoin):
                lt = run(node.left)
                rt = run(node.right)
                if sharded(rt):
                    # Broadcast the small build side: all-gather only the
                    # probe key + carried columns (plus p and valid).
                    rt = dist.gather_table(
                        rt.select_columns(
                            dict.fromkeys((node.right_key,)
                                          + tuple(node.right_cols))),
                        axes)
                return ops.fk_join(lt, rt, node.left_key, node.right_key,
                                   list(node.right_cols))
            if isinstance(node, phys.ShuffleJoin):
                lt = run(node.left)
                rt = run(node.right)
                return dist.shuffle_fk_join(
                    lt, rt, node.left_key, node.right_key,
                    list(node.right_cols), axes, n_shards=shards,
                    build_bucket=node.build_bucket,
                    probe_bucket=node.probe_bucket)
            if isinstance(node, phys.CoPartitionedJoin):
                lt = run(node.left)
                rt = run(node.right)
                return dist.copartitioned_fk_join(
                    lt, rt, node.left_key, node.right_key,
                    list(node.right_cols), list(node.carry_cols), axes,
                    n_shards=shards, build_bucket=node.build_bucket,
                    probe_bucket=node.probe_bucket,
                    chunk_size=_canonical_rows(node.left) // chunks,
                    num_chunks=chunks)
            if isinstance(node, phys.Repartition):
                t = run(node.child)
                return dist.repartition_by_key(
                    t, node.key, list(node.carry_cols), axes,
                    n_shards=shards, bucket=node.bucket,
                    chunk_size=_canonical_rows(node.child) // chunks,
                    num_chunks=chunks)
            if isinstance(node, phys.MergeAgg):
                return run_agg(node)
            raise TypeError(node)

        out = run(proot)
        if isinstance(out, Table):
            if sharded(out):
                out = dist.gather_table(out, axes)
                # Drop the whole-padding chunks appended for shard counts
                # that don't divide the grid: the caller-visible capacity
                # is the canonical (chunk-grid) one of the mesh=None
                # compile (the dropped rows are all invalid p = 0).
                n = _canonical_rows(proot)
                if n < out.capacity:
                    out = Table({k: v[:n] for k, v in out.columns.items()},
                                out.prob[:n], out.valid[:n], out.part)
            return out.with_part(phys.Replicated())
        return out

    def compiled(tables: Dict[str, Table]):
        # Every compile pads every base table to the canonical chunk grid
        # (the chunk boundaries define the deterministic fold tree) plus
        # whole padding chunks so any shard count owns equal chunk runs.
        padded = {k: t.pad_to_multiple(chunks)
                   .pad_to(shard_capacity(t.capacity, chunks, shards))
                  for k, t in tables.items()}
        caps = {k: t.capacity for k, t in padded.items()}
        canon_caps.clear()
        canon_caps.update({k: -(-t.capacity // chunks) * chunks
                           for k, t in tables.items()})
        proot = phys.lower_plan(root, caps, n_shards=shards,
                                sharded=mesh_mode and bool(axes),
                                join_gather_budget=join_gather_budget,
                                shuffle_slack=shuffle_slack,
                                copartition=copartition,
                                agg_shuffle_budget=agg_shuffle_budget,
                                canonical_chunks=chunks,
                                model=cost_model, tables=padded)
        if not mesh_mode:
            return run_plan(padded, proot)
        fn = shard_map(lambda sh: run_plan(sh, proot), mesh=mesh,
                       in_specs=(P(axes),), out_specs=P(),
                       check_vma=False)
        return fn(padded)

    return compiled
