"""Synthetic TPC-H-like probabilistic workload (paper §VIII).

The paper evaluates on TPC-H with an added uniform-random `p` column per
relation ("a randomly selected number between 0.0 and 1.0").  We reproduce
the schema subset its queries touch, a size-parameterised generator (scale
factor ~ rows, CPU-feasible), and the probabilistic query variants in the
paper's four modes:

    deterministic      the plain query (p ignored)
    confidence         P(result non-empty)        = AtLeastOne over the result
    group_confidence   P(group non-empty) per group
    aggregate          full PGF aggregate distribution per group
                       (exact log-CF / Normal / moment-based, §V)

Queries: Q1, Q3, Q6, Q18 and the paper's worked example Q20 (Fig. 6).
Every probabilistic mode is expressed as a `Plan` DAG and executed through
``compile_plan`` — pass ``mesh=`` to any query and the same plan runs the
WHOLE pipeline sharded (scans, selects, FK joins, group-id assignment and
aggregation all consume row-partitioned shard-local tables inside one
shard_map; see db/plans.py), with results BIT-IDENTICAL to the
single-device compile and O(rows / shards) per-device memory.  This is how
the TPC-H benchmarks exercise the planner end-to-end on one device and on
a pod.  Dates are day numbers (int), prices/quantities integers — the
paper's own integer-grid restriction (§V-C.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import operators as ops
from .plans import (FKJoin, GroupAgg, Map, Param, Parameterized, Project,
                    ReweightGreater, Scan, Select, compile_plan)
from .table import Table

DAY0_1995 = 9131          # days since epoch-ish origin for synthetic dates


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TPCH:
    """A scale-parameterised synthetic TPC-H instance with p columns."""

    lineitem: Table
    orders: Table
    customer: Table
    part: Table
    partsupp: Table
    supplier: Table
    nation: Table
    scale: dict

    _TABLES = ("lineitem", "orders", "customer", "part", "partsupp",
               "supplier", "nation")

    def tree_flatten(self):
        return (tuple(getattr(self, t) for t in self._TABLES),
                (tuple(sorted(self.scale.items())),))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, scale=dict(aux[0]))

    def tables(self) -> Dict[str, Table]:
        """The plan-compiler catalogue view: name -> Table."""
        return {t: getattr(self, t) for t in self._TABLES}


def generate(n_orders: int = 2000, lines_per_order: int = 4,
             n_parts: int = 200, n_suppliers: int = 50,
             n_customers: int = 300, n_nations: int = 25,
             seed: int = 0, prob_mode: str = "uniform") -> TPCH:
    rng = np.random.default_rng(seed)
    n_lineitem = n_orders * lines_per_order
    if n_suppliers < 4:
        raise ValueError(
            f"generate() needs n_suppliers >= 4 (got {n_suppliers}): the "
            "partsupp schema keys 4 DISTINCT suppliers per part")
    n_partsupp = n_parts * 4

    def probs(n):
        if prob_mode == "uniform":
            return rng.uniform(0.0, 1.0, n).astype(np.float64)
        return np.ones(n)

    nation = Table.from_columns({
        "n_nationkey": jnp.arange(n_nations),
        "n_name": jnp.arange(n_nations),          # name id; 3 == "CANADA"
    }, prob=jnp.asarray(probs(n_nations)))

    supplier = Table.from_columns({
        "s_suppkey": jnp.arange(n_suppliers),
        "s_nationkey": jnp.asarray(rng.integers(0, n_nations, n_suppliers)),
        "s_name": jnp.arange(n_suppliers),
        "s_address": jnp.arange(n_suppliers),
    }, prob=jnp.asarray(probs(n_suppliers)))

    part = Table.from_columns({
        "p_partkey": jnp.arange(n_parts),
        "p_name_forest": jnp.asarray(rng.uniform(0, 1, n_parts) < 0.1),
        "p_retailprice": jnp.asarray(rng.integers(100, 2000, n_parts)),
    }, prob=jnp.asarray(probs(n_parts)))

    ps_part = np.repeat(np.arange(n_parts), 4)
    # 4 DISTINCT suppliers per part: ps_pskey is an FK-join build key, and
    # fk_join's many-to-one contract rejects duplicate valid build keys (a
    # duplicate would silently drop one world's probability mass).
    ps_supp = np.argsort(rng.random((n_parts, n_suppliers)),
                         axis=1)[:, :4].reshape(-1)
    partsupp = Table.from_columns({
        "ps_partkey": jnp.asarray(ps_part),
        "ps_suppkey": jnp.asarray(ps_supp),
        "ps_availqty": jnp.asarray(rng.integers(1, 1000, n_partsupp)),
        "ps_pskey": jnp.asarray(ps_part * (1 << 10) + ps_supp),
    }, prob=jnp.asarray(probs(n_partsupp)))

    customer = Table.from_columns({
        "c_custkey": jnp.arange(n_customers),
        "c_mktsegment": jnp.asarray(rng.integers(0, 5, n_customers)),
    }, prob=jnp.asarray(probs(n_customers)))

    orders = Table.from_columns({
        "o_orderkey": jnp.arange(n_orders),
        "o_custkey": jnp.asarray(rng.integers(0, n_customers, n_orders)),
        "o_orderdate": jnp.asarray(rng.integers(DAY0_1995 - 800,
                                                DAY0_1995 + 800, n_orders)),
        "o_totalprice": jnp.asarray(rng.integers(1000, 100000, n_orders)),
    }, prob=jnp.asarray(probs(n_orders)))

    l_part = rng.integers(0, n_parts, n_lineitem)
    # pick a supplier that actually supplies the part (partsupp has 4/part)
    l_supp = ps_supp[l_part * 4 + rng.integers(0, 4, n_lineitem)]
    lineitem = Table.from_columns({
        "l_orderkey": jnp.asarray(np.repeat(np.arange(n_orders),
                                            lines_per_order)),
        "l_partkey": jnp.asarray(l_part),
        "l_suppkey": jnp.asarray(l_supp),
        "l_pskey": jnp.asarray(l_part * (1 << 10) + l_supp),
        "l_quantity": jnp.asarray(rng.integers(1, 51, n_lineitem)),
        "l_extendedprice": jnp.asarray(rng.integers(100, 10000, n_lineitem)),
        "l_discount": jnp.asarray(rng.integers(0, 11, n_lineitem)),  # percent
        "l_shipdate": jnp.asarray(rng.integers(DAY0_1995 - 900,
                                               DAY0_1995 + 900, n_lineitem)),
        "l_returnflag": jnp.asarray(rng.integers(0, 3, n_lineitem)),
        "l_linestatus": jnp.asarray(rng.integers(0, 2, n_lineitem)),
    }, prob=jnp.asarray(probs(n_lineitem)))

    return TPCH(lineitem, orders, customer, part, partsupp, supplier, nation,
                dict(n_orders=n_orders, n_lineitem=n_lineitem,
                     n_parts=n_parts, n_suppliers=n_suppliers,
                     n_customers=n_customers, n_nations=n_nations))


# ------------------------------------------------------ plan constructors
# The aggregate-mode logical plans, exposed as standalone constructors so
# the serving layer (repro.db.serving) can submit them without running a
# query function: two calls build STRUCTURALLY EQUAL plans
# (plans.plan_key), which is what the bounded plan cache keys on.
def _q1_select():
    return Select(Scan("lineitem"),
                  lambda t: t["l_shipdate"] <= DAY0_1995 + 500)


def q1_plan():
    """Q1 aggregate-mode plan: pricing summary GROUP BY (returnflag,
    linestatus) with SUM/COUNT/cumulant riders in one pass."""
    return GroupAgg(_q1_select(), ("l_returnflag", "l_linestatus"),
                    "l_quantity", "SUM", 8, "normal",
                    extra=(("price", "l_extendedprice", "SUM", "normal"),
                           ("count", "", "COUNT", "normal"),
                           ("cumulants_qty", "l_quantity", "SUM",
                            "cumulants")))


def _q3_join(segment: int = 1, order_join_budget: int | None = None):
    cust = Select(Scan("customer"), lambda t: t["c_mktsegment"] == segment)
    orders = Select(Scan("orders"), lambda t: t["o_orderdate"] < DAY0_1995)
    o = FKJoin(orders, cust, "o_custkey", "c_custkey", ("c_mktsegment",))
    li = Select(Scan("lineitem"), lambda t: t["l_shipdate"] > DAY0_1995)
    return FKJoin(li, o, "l_orderkey", "o_orderkey",
                  ("o_orderdate", "o_custkey"),
                  gather_budget=order_join_budget)


def q3_plan(segment: int = 1, max_groups: int = 512,
            order_join_budget: int | None = None):
    """Q3 aggregate-mode plan: revenue per order of one market segment."""
    return GroupAgg(_q3_join(segment, order_join_budget), ("l_orderkey",),
                    "l_extendedprice", "SUM", max_groups, "normal",
                    extra=(("cumulants", "l_extendedprice", "SUM",
                            "cumulants"),))


def _q6_select():
    return Select(
        Scan("lineitem"),
        lambda t: (t["l_shipdate"] >= DAY0_1995 - 400)
        & (t["l_shipdate"] < DAY0_1995)
        & (t["l_discount"] >= 5) & (t["l_discount"] <= 7)
        & (t["l_quantity"] < 24))


def q6_plan(num_freq: int | None = None):
    """Q6 aggregate-mode plan: the single-group scalar revenue SUM."""
    val = Map(_q6_select(), "q6_value",
              lambda t: t["l_quantity"] * t["l_discount"])
    extra = (("cumulants", "q6_value", "SUM", "cumulants"),)
    if num_freq:
        extra += (("exact", "q6_value", "SUM", "exact"),)
    return GroupAgg(val, (), "q6_value", "SUM", 1, "normal", extra=extra,
                    num_freq=num_freq or 0)


def q18_plan(qty_threshold: float = 150.0, max_groups: int = 2048):
    """Q18 reweight plan: keep each order with p *= P(SUM(qty) > cutoff)
    (Table I row III — the group_confidence shape)."""
    return ReweightGreater(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                           "", max_groups, threshold=float(qty_threshold))


def _q20_r10(nation_name: int = 3, max_groups: int = 1024,
             avail_frac: float = 0.05):
    r1 = Select(Scan("part"), lambda t: t["p_name_forest"])
    r2 = FKJoin(Scan("partsupp"), r1, "ps_partkey", "p_partkey",
                ("p_name_forest",))
    r3 = Select(Scan("lineitem"),
                lambda t: (t["l_shipdate"] >= DAY0_1995 - 365)
                & (t["l_shipdate"] < DAY0_1995))
    r4 = FKJoin(r3, r2, "l_pskey", "ps_pskey",
                ("ps_availqty", "ps_suppkey", "ps_pskey"))
    r4t = Map(r4, "q20_thresh",
              lambda t: t["ps_availqty"].astype(t.prob.dtype) * avail_frac)
    r7 = ReweightGreater(r4t, ("ps_pskey",), "l_quantity", "q20_thresh",
                         max_groups, carry_cols=("ps_suppkey",))
    nat = Select(Scan("nation"), lambda t: t["n_name"] == nation_name)
    r9 = FKJoin(Scan("supplier"), nat, "s_nationkey", "n_nationkey",
                ("n_name",))
    return FKJoin(r7, r9, "ps_suppkey", "s_suppkey",
                  ("s_name", "s_address"))


def q20_plan(nation_name: int = 3, max_groups: int = 1024,
             avail_frac: float = 0.05):
    """Q20 plan (the paper's Fig. 6): project(s_name) of the reweighted
    excess-stock pipeline."""
    return Project(_q20_r10(nation_name, max_groups, avail_frac),
                   ("s_name",), 64)


def serving_plans(max_groups: int = 512) -> dict:
    """One representative logical plan per TPC-H query — the serving
    workload (`launch/serve.py --db`) and the cache-hit bit-equality
    tests submit exactly these."""
    return {"q1": q1_plan(), "q3": q3_plan(max_groups=max_groups),
            "q6": q6_plan(), "q18": q18_plan(max_groups=4 * max_groups),
            "q20": q20_plan()}


# ------------------------------------------------- parameterized families
def q6_family():
    """Q6 as a parameterized family: the discount window and quantity
    limit are lifted :class:`~repro.db.plans.Param` holes
    (``disc_lo`` / ``disc_hi`` / ``qty_lim``), so ONE compiled
    executable serves every setting and a what-if sweep over N settings
    runs as one batched device program
    (:meth:`repro.db.serving.QueryService.sweep`)."""
    sel = Select(Scan("lineitem"), Parameterized(
        lambda t, lo, hi, lim: (t["l_shipdate"] >= DAY0_1995 - 400)
        & (t["l_shipdate"] < DAY0_1995)
        & (t["l_discount"] >= lo) & (t["l_discount"] <= hi)
        & (t["l_quantity"] < lim),
        ("disc_lo", "disc_hi", "qty_lim")))
    val = Map(sel, "q6_value", lambda t: t["l_quantity"] * t["l_discount"])
    return GroupAgg(val, (), "q6_value", "SUM", 1, "normal",
                    extra=(("cumulants", "q6_value", "SUM", "cumulants"),))


def q18_family(max_groups: int = 2048):
    """Q18 as a parameterized family: the quantity cutoff is the lifted
    ``qty_threshold`` param of the reweight — threshold what-if sweeps
    share one executable."""
    return ReweightGreater(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                           "", max_groups,
                           threshold=Param("qty_threshold"))


# --------------------------------------------------------------- queries
def _confidence_of(plan, db: TPCH, mesh, opts=None):
    """P(result non-empty): one-group AtLeastOne over the plan's output."""
    agg = GroupAgg(plan, keys=(), value="", agg="COUNT", max_groups=1)
    out = compile_plan(agg, mesh, **(opts or {}))(db.tables())
    return dict(confidence=out["confidence"][0])


def q1(db: TPCH, mode: str = "aggregate", mesh=None, plan_opts=None):
    """Pricing summary: GROUP BY (returnflag, linestatus); SUM(quantity),
    SUM(extendedprice), COUNT(*) over shipped lineitems.

    ``plan_opts`` (every query): extra ``compile_plan`` keywords —
    ``join_gather_budget``, ``shuffle_slack``, ``canonical_chunks``, ... —
    so callers steer the physical planner's strategy choices (e.g. force
    the shuffle-partitioned join with a tiny gather budget) without
    rebuilding the logical plans."""
    sel = _q1_select()
    keys = ("l_returnflag", "l_linestatus")
    if mode == "deterministic":
        li = compile_plan(sel)(db.tables())
        ids, _, gvalid = ops.group_ids(li, list(keys), 8)
        m = li.valid
        qty = jax.ops.segment_sum(jnp.where(m, li["l_quantity"], 0), ids,
                                  num_segments=8)
        price = jax.ops.segment_sum(jnp.where(m, li["l_extendedprice"], 0),
                                    ids, num_segments=8)
        cnt = jax.ops.segment_sum(m.astype(jnp.int32), ids, num_segments=8)
        return dict(valid=gvalid, sum_qty=qty, sum_price=price, count=cnt)
    if mode == "confidence":
        return _confidence_of(sel, db, mesh, plan_opts)
    if mode == "group_confidence":
        out = compile_plan(GroupAgg(sel, keys, "", "COUNT", 8), mesh,
                           **(plan_opts or {}))(db.tables())
        return dict(valid=out["valid"], confidence=out["confidence"])
    # aggregate: Normal + moment terms per group, all in ONE UDA pass
    out = compile_plan(q1_plan(), mesh, **(plan_opts or {}))(db.tables())
    return dict(valid=out["valid"], qty=out["sum"], price=out["price"],
                count=out["count"], cumulants_qty=out["cumulants_qty"])


def q3(db: TPCH, mode: str = "aggregate", segment: int = 1,
       max_groups: int = 512, mesh=None, plan_opts=None,
       order_join_budget: int | None = None):
    """Shipping priority: revenue per order for one market segment.

    The GROUP BY keys on ``l_orderkey`` — the probe key of the
    lineitem |x| orders join — so on a mesh the planner's cost model can
    fuse that join with the aggregation (CoPartitionedJoin +
    PartitionedAgg: matched rows stay at their ``l_orderkey % n_shards``
    owner, zero shuffle-home round-trips).  ``order_join_budget`` is the
    per-join gather budget of exactly that join: set it below the orders
    capacity to exercise the fused pipeline while the small customer
    dimension still gathers (``plan_opts=dict(join_gather_budget=...)``
    would shuffle both).  Results are bit-identical either way."""
    j = _q3_join(segment, order_join_budget)
    if mode == "deterministic":
        jt = compile_plan(j)(db.tables())
        ids, _, gvalid = ops.group_ids(jt, ["l_orderkey"], max_groups)
        rev = jax.ops.segment_sum(
            jnp.where(jt.valid, jt["l_extendedprice"], 0), ids,
            num_segments=max_groups)
        return dict(valid=gvalid, revenue=rev)
    if mode == "confidence":
        return _confidence_of(j, db, mesh, plan_opts)
    if mode == "group_confidence":
        out = compile_plan(GroupAgg(j, ("l_orderkey",), "", "COUNT",
                                    max_groups), mesh,
                           **(plan_opts or {}))(db.tables())
        return dict(valid=out["valid"], confidence=out["confidence"])
    plan = q3_plan(segment, max_groups, order_join_budget)
    out = compile_plan(plan, mesh, **(plan_opts or {}))(db.tables())
    return dict(valid=out["valid"], revenue=out["sum"],
                cumulants=out["cumulants"])


def q6(db: TPCH, mode: str = "aggregate", num_freq: int | None = None,
       mesh=None, plan_opts=None):
    """Forecast revenue change: scalar SUM over filtered lineitem.

    The single-group scalar aggregate — the paper's Figure 9 COUNT(*)
    experiment is this query with values == 1.
    """
    sel = _q6_select()
    if mode == "deterministic":
        li = compile_plan(sel)(db.tables())
        return dict(revenue=jnp.sum(jnp.where(li.valid, li["l_quantity"]
                                              * li["l_discount"], 0)))
    if mode in ("confidence", "group_confidence"):
        return _confidence_of(sel, db, mesh, plan_opts)
    # Integer-typed computed column (q6_plan's Map): keeps the exact-CF
    # aggregate eligible for the Pallas kernel's integer-phase arithmetic
    # (uda.accumulate casts to the prob dtype itself and tracks source
    # integrality).  num_freq requests the exact distribution (Figure 9).
    r = compile_plan(q6_plan(num_freq), mesh,
                     **(plan_opts or {}))(db.tables())
    mu, var = r["sum"]
    out = dict(normal=(mu[0], var[0]), cumulants=r["cumulants"][0])
    if num_freq:
        out["exact_coeffs"] = r["exact"][0]
    return out


def q18(db: TPCH, mode: str = "aggregate", qty_threshold: int = 150,
        max_groups: int = 2048, mesh=None, method: str = "normal",
        num_freq: int = 256, plan_opts=None):
    """Large-volume customers: orders whose SUM(l_quantity) > threshold.

    The probabilistic version keeps every order with
    p = p_order * P(SUM > threshold)  (Table I row III reweight).
    ``method="exact"`` (aggregate mode) computes the per-order quantity
    distribution with the grouped exact-CF planner path — ``num_freq``
    must exceed the max per-order quantity sum (lines_per_order * 50 for
    the synthetic generator) — and derives P(SUM > threshold) from the
    exact tail mass instead of the Normal approximation.

    The aggregations key on ``l_orderkey`` over a bare lineitem scan, so
    ``plan_opts=dict(agg_shuffle_budget=N)`` (rows above N) runs them as
    the co-partitioned pipeline on a mesh: tuples hash-exchange to their
    order's owner shard (``Repartition``) and aggregate in place
    (``PartitionedAgg``, one psum merge) — bit-identical to the default
    RowBlocked PartialAgg lowering."""
    li = Scan("lineitem")
    if mode == "deterministic":
        t = db.lineitem
        ids, _, gvalid = ops.group_ids(t, ["l_orderkey"], max_groups)
        qty = jax.ops.segment_sum(jnp.where(t.valid, t["l_quantity"], 0),
                                  ids, num_segments=max_groups)
        return dict(valid=gvalid & (qty > qty_threshold), sum_qty=qty)
    rew = q18_plan(qty_threshold, max_groups)
    if mode == "confidence":
        # P(at least one order qualifies) = 1 - prod_g (1 - conf_g * p_gt_g)
        return _confidence_of(rew, db, mesh, plan_opts)
    if mode == "group_confidence":
        t = compile_plan(rew, mesh, **(plan_opts or {}))(db.tables())
        return dict(valid=t.valid, confidence=t.prob)
    if method == "exact":
        plan = GroupAgg(li, ("l_orderkey",), "l_quantity", "SUM", max_groups,
                        "exact", num_freq=num_freq)
        out = compile_plan(plan, mesh, **(plan_opts or {}))(db.tables())
        coeffs = out["exact"]                        # (G, num_freq) rows
        gt = jnp.arange(num_freq) > qty_threshold
        p_gt = jnp.sum(coeffs * gt[None, :], axis=-1)
        return dict(valid=out["valid"], sum_dist=coeffs, p_qualifies=p_gt)
    plan = GroupAgg(li, ("l_orderkey",), "l_quantity", "SUM", max_groups,
                    "normal")
    out = compile_plan(plan, mesh, **(plan_opts or {}))(db.tables())
    mu, var = out["sum"]
    p_gt = ops.normal_greater(mu, var, jnp.asarray(qty_threshold, mu.dtype))
    return dict(valid=out["valid"], sum_qty=(mu, var), p_qualifies=p_gt)


def q18_topk(db: TPCH, max_groups: int = 2048, kappa: int = 8, mesh=None,
             plan_opts=None):
    """Top-k variant of Q18: the per-order MAX(l_quantity) distribution
    with the paper's §V-B.2 truncation bound exposed per group.

    The MinMax UDA keeps the ``kappa`` best distinct values per group
    (§V-B.1 masses are exact on that support).  What used to be invisible
    to callers is the truncation remainder: the probability that a
    group's true MAX lies STRICTLY beyond the kept support.  It is
    returned here as ``tail_mass`` — per group,

        tail_mass_g = prod_{kept values} Q_j * (1 - prod_{evicted} (1-p))

    (see :meth:`repro.core.uda.MinMax.tail_mass`), which §V-B.2 shows
    bounds the total probability unaccounted for by the reported
    per-value masses; it is exactly 0 when kappa covered every distinct
    value.  A caller ranking orders by MAX quantity can therefore certify
    each group's answer to that bound — or hand the plan to
    :func:`repro.db.plans.run_plan` with ``RetryPolicy(tail_tol=...)``,
    which doubles kappa until the bound is within tolerance.

    Returns per-run arrays (the flattened G*kappa support grid of
    ``operators.minmax_runs``) plus the per-group ``p_empty`` and
    ``tail_mass``.
    """
    plan = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                    "MAX", max_groups, kappa=kappa)
    out = compile_plan(plan, mesh, **(plan_opts or {}))(db.tables())
    mm = out["minmax"]
    return dict(valid=out["valid"], keys=out["keys"],
                run_group=mm["run_group"], run_value=mm["run_value"],
                run_mass=mm["run_mass"], run_valid=mm["run_valid"],
                p_empty=mm["p_empty"], tail_mass=mm["tail_mass"])


def q20(db: TPCH, mode: str = "aggregate", nation_name: int = 3,
        max_groups: int = 1024, avail_frac: float = 0.05, mesh=None,
        plan_opts=None):
    """The paper's Fig. 6 plan: suppliers in one nation with excess stock of
    'forest' parts.

        R1 = sigma_forest(part)
        R2 = partsupp |x| R1
        R3 = sigma_shipdate(lineitem)
        R4 = R3 |x| R2                       (on partkey & suppkey)
        R6 = GROUP R4 BY ps key; SUM(l_quantity)
        R7 = reweight p *= P(SUM > availqty) (Table I row III)
        R9 = supplier |x| sigma_CANADA(nation)
        Q  = project(s_name) of R7 |x| R9
    """
    r10 = _q20_r10(nation_name, max_groups, avail_frac)
    if mode == "deterministic":
        t = compile_plan(r10, mesh, **(plan_opts or {}))(db.tables())
        return dict(valid=t.valid & (t.prob > 0.5), s_name=t["s_name"])
    proj = Project(r10, ("s_name",), 64)
    if mode == "confidence":
        return _confidence_of(proj, db, mesh, plan_opts)
    result = compile_plan(proj, mesh, **(plan_opts or {}))(db.tables())
    if mode == "group_confidence":
        return dict(valid=result.valid, s_name=result["s_name"],
                    confidence=result.prob)
    return dict(valid=result.valid, s_name=result["s_name"],
                prob=result.prob)


QUERIES = {"q1": q1, "q3": q3, "q6": q6, "q18": q18, "q20": q20}
MODES = ("deterministic", "confidence", "group_confidence", "aggregate")
