"""Synthetic TPC-H-like probabilistic workload (paper §VIII).

The paper evaluates on TPC-H with an added uniform-random `p` column per
relation ("a randomly selected number between 0.0 and 1.0").  We reproduce
the schema subset its queries touch, a size-parameterised generator (scale
factor ~ rows, CPU-feasible), and the probabilistic query variants in the
paper's four modes:

    deterministic      the plain query (p ignored)
    confidence         P(result non-empty)        = AtLeastOne over the result
    group_confidence   P(group non-empty) per group
    aggregate          full PGF aggregate distribution per group
                       (exact log-CF / Normal / moment-based, §V)

Queries: Q1, Q3, Q6, Q18 and the paper's worked example Q20 (Fig. 6).
Dates are day numbers (int), prices/quantities integers — the paper's own
integer-grid restriction (§V-C.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core import poisson_binomial as pb
from . import operators as ops
from .table import Table

DAY0_1995 = 9131          # days since epoch-ish origin for synthetic dates


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TPCH:
    """A scale-parameterised synthetic TPC-H instance with p columns."""

    lineitem: Table
    orders: Table
    customer: Table
    part: Table
    partsupp: Table
    supplier: Table
    nation: Table
    scale: dict

    _TABLES = ("lineitem", "orders", "customer", "part", "partsupp",
               "supplier", "nation")

    def tree_flatten(self):
        return (tuple(getattr(self, t) for t in self._TABLES),
                (tuple(sorted(self.scale.items())),))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, scale=dict(aux[0]))


def generate(n_orders: int = 2000, lines_per_order: int = 4,
             n_parts: int = 200, n_suppliers: int = 50,
             n_customers: int = 300, n_nations: int = 25,
             seed: int = 0, prob_mode: str = "uniform") -> TPCH:
    rng = np.random.default_rng(seed)
    n_lineitem = n_orders * lines_per_order
    n_partsupp = n_parts * 4

    def probs(n):
        if prob_mode == "uniform":
            return rng.uniform(0.0, 1.0, n).astype(np.float64)
        return np.ones(n)

    nation = Table.from_columns({
        "n_nationkey": jnp.arange(n_nations),
        "n_name": jnp.arange(n_nations),          # name id; 3 == "CANADA"
    }, prob=jnp.asarray(probs(n_nations)))

    supplier = Table.from_columns({
        "s_suppkey": jnp.arange(n_suppliers),
        "s_nationkey": jnp.asarray(rng.integers(0, n_nations, n_suppliers)),
        "s_name": jnp.arange(n_suppliers),
        "s_address": jnp.arange(n_suppliers),
    }, prob=jnp.asarray(probs(n_suppliers)))

    part = Table.from_columns({
        "p_partkey": jnp.arange(n_parts),
        "p_name_forest": jnp.asarray(rng.uniform(0, 1, n_parts) < 0.1),
        "p_retailprice": jnp.asarray(rng.integers(100, 2000, n_parts)),
    }, prob=jnp.asarray(probs(n_parts)))

    ps_part = np.repeat(np.arange(n_parts), 4)
    ps_supp = rng.integers(0, n_suppliers, n_partsupp)
    partsupp = Table.from_columns({
        "ps_partkey": jnp.asarray(ps_part),
        "ps_suppkey": jnp.asarray(ps_supp),
        "ps_availqty": jnp.asarray(rng.integers(1, 1000, n_partsupp)),
        "ps_pskey": jnp.asarray(ps_part * (1 << 10) + ps_supp),
    }, prob=jnp.asarray(probs(n_partsupp)))

    customer = Table.from_columns({
        "c_custkey": jnp.arange(n_customers),
        "c_mktsegment": jnp.asarray(rng.integers(0, 5, n_customers)),
    }, prob=jnp.asarray(probs(n_customers)))

    orders = Table.from_columns({
        "o_orderkey": jnp.arange(n_orders),
        "o_custkey": jnp.asarray(rng.integers(0, n_customers, n_orders)),
        "o_orderdate": jnp.asarray(rng.integers(DAY0_1995 - 800,
                                                DAY0_1995 + 800, n_orders)),
        "o_totalprice": jnp.asarray(rng.integers(1000, 100000, n_orders)),
    }, prob=jnp.asarray(probs(n_orders)))

    l_part = rng.integers(0, n_parts, n_lineitem)
    # pick a supplier that actually supplies the part (partsupp has 4/part)
    l_supp = ps_supp[l_part * 4 + rng.integers(0, 4, n_lineitem)]
    lineitem = Table.from_columns({
        "l_orderkey": jnp.asarray(np.repeat(np.arange(n_orders),
                                            lines_per_order)),
        "l_partkey": jnp.asarray(l_part),
        "l_suppkey": jnp.asarray(l_supp),
        "l_pskey": jnp.asarray(l_part * (1 << 10) + l_supp),
        "l_quantity": jnp.asarray(rng.integers(1, 51, n_lineitem)),
        "l_extendedprice": jnp.asarray(rng.integers(100, 10000, n_lineitem)),
        "l_discount": jnp.asarray(rng.integers(0, 11, n_lineitem)),  # percent
        "l_shipdate": jnp.asarray(rng.integers(DAY0_1995 - 900,
                                               DAY0_1995 + 900, n_lineitem)),
        "l_returnflag": jnp.asarray(rng.integers(0, 3, n_lineitem)),
        "l_linestatus": jnp.asarray(rng.integers(0, 2, n_lineitem)),
    }, prob=jnp.asarray(probs(n_lineitem)))

    return TPCH(lineitem, orders, customer, part, partsupp, supplier, nation,
                dict(n_orders=n_orders, n_lineitem=n_lineitem,
                     n_parts=n_parts, n_suppliers=n_suppliers,
                     n_customers=n_customers, n_nations=n_nations))


# --------------------------------------------------------------- queries
def q1(db: TPCH, mode: str = "aggregate"):
    """Pricing summary: GROUP BY (returnflag, linestatus); SUM(quantity),
    SUM(extendedprice), COUNT(*) over shipped lineitems."""
    li = ops.select(db.lineitem,
                    lambda t: t["l_shipdate"] <= DAY0_1995 + 500)
    ids, _, gvalid = ops.group_ids(li, ["l_returnflag", "l_linestatus"], 8)
    if mode == "deterministic":
        m = li.valid
        qty = jax.ops.segment_sum(jnp.where(m, li["l_quantity"], 0), ids, num_segments=8)
        price = jax.ops.segment_sum(jnp.where(m, li["l_extendedprice"], 0), ids, num_segments=8)
        cnt = jax.ops.segment_sum(m.astype(jnp.int32), ids, num_segments=8)
        return dict(valid=gvalid, sum_qty=qty, sum_price=price, count=cnt)
    if mode == "confidence":
        from ..core.aggregates import AtLeastOne
        st = AtLeastOne.accumulate(AtLeastOne.init(), li.masked_prob())
        return dict(confidence=AtLeastOne.finalize(st))
    if mode == "group_confidence":
        return dict(valid=gvalid, confidence=ops.group_atleastone(li, ids, 8))
    # aggregate: Normal + moment terms per group; COUNT exactly via CF
    qty = li["l_quantity"].astype(li.prob.dtype)
    price = li["l_extendedprice"].astype(li.prob.dtype)
    mu_q, var_q = ops.group_normal_terms(li, qty, ids, 8)
    mu_p, var_p = ops.group_normal_terms(li, price, ids, 8)
    cum_q = ops.group_cumulant_terms(li, qty, ids, 8)
    ones = jnp.ones_like(qty)
    mu_c, var_c = ops.group_normal_terms(li, ones, ids, 8)
    return dict(valid=gvalid, qty=(mu_q, var_q), price=(mu_p, var_p),
                count=(mu_c, var_c), cumulants_qty=cum_q)


def q3(db: TPCH, mode: str = "aggregate", segment: int = 1,
       max_groups: int = 512):
    """Shipping priority: revenue per order for one market segment."""
    cust = ops.select(db.customer, lambda t: t["c_mktsegment"] == segment)
    orders = ops.select(db.orders, lambda t: t["o_orderdate"] < DAY0_1995)
    o = ops.fk_join(orders, cust, "o_custkey", "c_custkey", ["c_mktsegment"])
    li = ops.select(db.lineitem, lambda t: t["l_shipdate"] > DAY0_1995)
    j = ops.fk_join(li, o, "l_orderkey", "o_orderkey",
                    ["o_orderdate", "o_custkey"])
    ids, codes, gvalid = ops.group_ids(j, ["l_orderkey"], max_groups)
    if mode == "deterministic":
        rev = jax.ops.segment_sum(
            jnp.where(j.valid, j["l_extendedprice"], 0), ids,
            num_segments=max_groups)
        return dict(valid=gvalid, revenue=rev)
    if mode == "confidence":
        from ..core.aggregates import AtLeastOne
        st = AtLeastOne.accumulate(AtLeastOne.init(), j.masked_prob())
        return dict(confidence=AtLeastOne.finalize(st))
    if mode == "group_confidence":
        return dict(valid=gvalid,
                    confidence=ops.group_atleastone(j, ids, max_groups))
    price = j["l_extendedprice"].astype(j.prob.dtype)
    mu, var = ops.group_normal_terms(j, price, ids, max_groups)
    cum = ops.group_cumulant_terms(j, price, ids, max_groups)
    return dict(valid=gvalid, revenue=(mu, var), cumulants=cum)


def q6(db: TPCH, mode: str = "aggregate", num_freq: int | None = None):
    """Forecast revenue change: scalar SUM over filtered lineitem.

    The single-group scalar aggregate — the paper's Figure 9 COUNT(*)
    experiment is this query with values == 1.
    """
    li = ops.select(
        db.lineitem,
        lambda t: (t["l_shipdate"] >= DAY0_1995 - 400)
        & (t["l_shipdate"] < DAY0_1995)
        & (t["l_discount"] >= 5) & (t["l_discount"] <= 7)
        & (t["l_quantity"] < 24))
    p = li.masked_prob()
    if mode == "deterministic":
        return dict(revenue=jnp.sum(jnp.where(li.valid, li["l_quantity"]
                                              * li["l_discount"], 0)))
    if mode in ("confidence", "group_confidence"):
        from ..core.aggregates import AtLeastOne
        st = AtLeastOne.accumulate(AtLeastOne.init(), p)
        return dict(confidence=AtLeastOne.finalize(st))
    v = (li["l_quantity"] * li["l_discount"]).astype(p.dtype)
    from ..core import approx
    terms = approx.cumulant_terms(p, v, 8)
    mu = jnp.sum(v * p)
    var = jnp.sum(v * v * p * (1 - p))
    out = dict(normal=(mu, var), cumulants=terms)
    if num_freq:  # exact distribution on request (Figure 9's exact path)
        la, an = pb.logcf_terms(p, v, num_freq)
        out["exact_coeffs"] = pb.logcf_finalize(la, an)
    return out


def q18(db: TPCH, mode: str = "aggregate", qty_threshold: int = 150,
        max_groups: int = 2048):
    """Large-volume customers: orders whose SUM(l_quantity) > threshold.

    The probabilistic version keeps every order with
    p = p_order * P(SUM > threshold)  (Table I row III reweight)."""
    li = db.lineitem
    ids, codes, gvalid = ops.group_ids(li, ["l_orderkey"], max_groups)
    if mode == "deterministic":
        qty = jax.ops.segment_sum(jnp.where(li.valid, li["l_quantity"], 0),
                                  ids, num_segments=max_groups)
        return dict(valid=gvalid & (qty > qty_threshold), sum_qty=qty)
    qty = li["l_quantity"].astype(li.prob.dtype)
    mu, var = ops.group_normal_terms(li, qty, ids, max_groups)
    p_gt = ops.normal_greater(mu, var, jnp.asarray(qty_threshold, mu.dtype))
    conf = ops.group_atleastone(li, ids, max_groups)
    if mode == "confidence":
        # P(at least one order qualifies) = 1 - prod_g (1 - conf_g * p_gt_g)
        peach = jnp.where(gvalid, conf * p_gt, 0.0)
        return dict(confidence=1.0 - jnp.exp(jnp.sum(jnp.log1p(-peach))))
    if mode == "group_confidence":
        return dict(valid=gvalid, confidence=conf * p_gt)
    return dict(valid=gvalid, sum_qty=(mu, var), p_qualifies=p_gt)


def q20(db: TPCH, mode: str = "aggregate", nation_name: int = 3,
        max_groups: int = 1024, avail_frac: float = 0.05):
    """The paper's Fig. 6 plan: suppliers in one nation with excess stock of
    'forest' parts.

        R1 = sigma_forest(part)
        R2 = partsupp |x| R1
        R3 = sigma_shipdate(lineitem)
        R4 = R3 |x| R2                       (on partkey & suppkey)
        R6 = GROUP R4 BY ps key; SUM(l_quantity)
        R7 = reweight p *= P(SUM > availqty) (Table I row III)
        R9 = supplier |x| sigma_CANADA(nation)
        Q  = project(s_name) of R7 |x| R9
    """
    r1 = ops.select(db.part, lambda t: t["p_name_forest"])
    r2 = ops.fk_join(db.partsupp, r1, "ps_partkey", "p_partkey",
                     ["p_name_forest"])
    r3 = ops.select(db.lineitem,
                    lambda t: (t["l_shipdate"] >= DAY0_1995 - 365)
                    & (t["l_shipdate"] < DAY0_1995))
    r4 = ops.fk_join(r3, r2, "l_pskey", "ps_pskey",
                     ["ps_availqty", "ps_suppkey", "ps_pskey"])
    ids, codes, gvalid = ops.group_ids(r4, ["ps_pskey"], max_groups)
    qty = r4["l_quantity"].astype(r4.prob.dtype)
    mu, var = ops.group_normal_terms(r4, qty, ids, max_groups)

    # availqty / suppkey per group (all valid rows in a group agree).
    gcols = ops.group_key_columns(
        r4, ["ps_pskey", "ps_availqty", "ps_suppkey"], ids, max_groups)
    avail, suppk = gcols["ps_availqty"], gcols["ps_suppkey"]

    p_excess = ops.normal_greater(mu, var, avail.astype(mu.dtype) * avail_frac)
    conf = ops.group_atleastone(r4, ids, max_groups)
    r7 = Table({"ps_suppkey": suppk, "ps_pskey": gcols["ps_pskey"]},
               conf * p_excess, gvalid)

    nat = ops.select(db.nation, lambda t: t["n_name"] == nation_name)
    r9 = ops.fk_join(db.supplier, nat, "s_nationkey", "n_nationkey",
                     ["n_name"])
    r10 = ops.fk_join(r7, r9, "ps_suppkey", "s_suppkey",
                      ["s_name", "s_address"])
    if mode == "deterministic":
        return dict(valid=r10.valid & (r10.prob > 0.5), s_name=r10["s_name"])
    result = ops.project(r10, ["s_name"], max_groups=64)
    if mode == "confidence":
        from ..core.aggregates import AtLeastOne
        st = AtLeastOne.accumulate(AtLeastOne.init(), result.masked_prob())
        return dict(confidence=AtLeastOne.finalize(st))
    if mode == "group_confidence":
        return dict(valid=result.valid, s_name=result["s_name"],
                    confidence=result.prob)
    return dict(valid=result.valid, s_name=result["s_name"],
                prob=result.prob)


QUERIES = {"q1": q1, "q3": q3, "q6": q6, "q18": q18, "q20": q20}
MODES = ("deterministic", "confidence", "group_confidence", "aggregate")
