"""Structured run diagnostics: the ExecutionReport pytree threaded out of
every ``compile_plan(..., with_report=True)`` run.

The engine's failure modes used to be detect-or-die: shuffle bucket
overflow NaN-poisons the answer (and can slip past boolean/integer
output columns silently — see ``dist.shuffle_fk_join``), MIN/MAX
truncation mass (``tail_log_none``, the paper's §V-B.2 approximation
error) was computed but never surfaced, and nothing distinguished "the
answer is NaN because an exchange dropped rows" from "the input data was
NaN".  An :class:`ExecutionReport` carries every detection signal out of
the compiled run as a pytree of (mostly scalar) arrays, so callers — and
the escalating retry controller :func:`repro.db.plans.run_plan` — can
DIAGNOSE a run instead of squinting at NaNs:

    exchange_overflow   per exchange leg: rows dropped for static bucket
                        capacity (psum'd — every shard agrees); > 0 means
                        the NaN poison fired (or would have — boolean
                        consumers included)
    exchange_demand     per exchange leg: the observed peak
                        per-(sender, owner) send demand (pmax'd) — the
                        concrete capacity a retry needs to make overflow
                        impossible
    exchange_capacity   per exchange leg: the static bucket capacity the
                        run used (demand > capacity <=> overflow)
    group_overflow      per aggregation pass: live rows whose group code
                        was dropped past ``max_groups`` (the group-id
                        protocol stays exact for KEPT groups; this counts
                        the lost ones)
    tail_mass           per MIN/MAX aggregate: the per-group §V-B.2
                        truncation mass (see :meth:`repro.core.uda.
                        MinMax.tail_mass`) — exactly 0 when ``kappa``
                        covers every distinct value
    state_nan           per aggregate state: NaN count in the FOLDED UDA
                        state (NaN poison propagates through the p
                        column into every additive state; legitimate
                        non-finite values — MinMax +inf padding,
                        log1p(-1) = -inf of deterministic tuples — are
                        NOT counted)
    waves               streamed runs: retired wave count, total waves,
                        and transfer-fault retries (host-side ints); the
                        retry controller adds ``attempts`` and
                        ``final_params``

NaN poisoning stays as the in-band backstop — the report is the
out-of-band signal that survives boolean/integer consumers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

#: report fields, in flatten order (all dicts: label -> scalar/array).
_FIELDS = ("exchange_overflow", "exchange_demand", "exchange_capacity",
           "group_overflow", "tail_mass", "state_nan", "waves")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ExecutionReport:
    """Diagnostics pytree of one compiled run (see module docstring).

    A registered pytree (dict keys are static structure, values are
    leaves), so it crosses jit / shard_map boundaries; all values are
    replicated scalars or per-group arrays.  The ``issues`` /
    ``ok`` helpers read concrete values and must run OUTSIDE jit —
    i.e. on the report an executed run returned.
    """
    exchange_overflow: dict = dataclasses.field(default_factory=dict)
    exchange_demand: dict = dataclasses.field(default_factory=dict)
    exchange_capacity: dict = dataclasses.field(default_factory=dict)
    group_overflow: dict = dataclasses.field(default_factory=dict)
    tail_mass: dict = dataclasses.field(default_factory=dict)
    state_nan: dict = dataclasses.field(default_factory=dict)
    waves: dict = dataclasses.field(default_factory=dict)
    #: set by the retry controller on the returned report (host-side,
    #: not part of the pytree): the compile overrides of the final
    #: attempt — {"shuffle_slack", "shuffle_bucket_floor",
    #: "stream_wave_chunks", "kappa_scale", "groups_scale"}.
    final_params: dict = dataclasses.field(default_factory=dict)

    def tree_flatten(self):
        keys = tuple(tuple(sorted(getattr(self, f))) for f in _FIELDS)
        children = tuple(getattr(self, f)[k]
                         for f, ks in zip(_FIELDS, keys) for k in ks)
        return children, keys

    @classmethod
    def tree_unflatten(cls, aux, children):
        it = iter(children)
        return cls(*({k: next(it) for k in ks} for ks in aux))

    # ------------------------------------------------ host-side diagnosis
    def issues(self, tail_tol: float = 0.0) -> dict:
        """Concrete problem summary (call OUTSIDE jit, on an executed
        run's report): {} when the run is trustworthy.  Keys:

        * ``"overflow"``: {exchange leg: rows dropped} (> 0 only)
        * ``"group_overflow"``: {pass: live rows whose group was lost}
        * ``"tail"``: {aggregate: max per-group truncation mass}, only
          entries above ``tail_tol``
        * ``"nan"``: {state: NaN count} — reported only when no exchange
          overflowed (overflow explains the NaN; without one, the NaN
          came in with the data and no escalation can remove it)
        """
        out: dict = {}
        over = {k: int(v) for k, v in self.exchange_overflow.items()
                if int(v) > 0}
        if over:
            out["overflow"] = over
        gover = {k: int(v) for k, v in self.group_overflow.items()
                 if int(v) > 0}
        if gover:
            out["group_overflow"] = gover
        tails = {k: float(jnp.max(v)) for k, v in self.tail_mass.items()}
        tails = {k: t for k, t in tails.items() if t > tail_tol}
        if tails:
            out["tail"] = tails
        if not over:
            nans = {k: int(v) for k, v in self.state_nan.items()
                    if int(v) > 0}
            if nans:
                out["nan"] = nans
        return out

    def ok(self, tail_tol: float = 0.0) -> bool:
        return not self.issues(tail_tol)

    def overflow_total(self) -> int:
        return sum(int(v) for v in self.exchange_overflow.values())

    def max_tail_mass(self) -> float:
        """Largest per-group §V-B.2 truncation mass over every MIN/MAX
        aggregate of the run (0.0 when none ran or none truncated)."""
        if not self.tail_mass:
            return 0.0
        return max(float(jnp.max(v)) for v in self.tail_mass.values())

    def describe(self, tail_tol: float = 0.0) -> str:
        iss = self.issues(tail_tol)
        if not iss:
            return "clean"
        return "; ".join(f"{k}: {v}" for k, v in sorted(iss.items()))


@dataclasses.dataclass
class ServingStats:
    """Per-request counters of one :class:`repro.db.serving.QueryService`
    — host-side ints, NOT a pytree (they never cross a trace).

    ``cache_hits`` / ``cache_misses`` count whether a request's FIRST
    compile was served from the plan cache; ``batched_points`` sums the
    parameter points executed through vmapped sweeps (each sweep is one
    request); ``retry_attempts`` counts escalation re-compiles beyond
    each request's first attempt.
    """
    requests: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    batched_requests: int = 0
    batched_points: int = 0
    retry_attempts: int = 0

    def record(self, hit: bool, points: int = 1, attempts: int = 1) -> None:
        self.requests += 1
        if hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        if points > 1:
            self.batched_requests += 1
            self.batched_points += points
        self.retry_attempts += max(0, attempts - 1)

    @property
    def hit_rate(self) -> float:
        return self.cache_hits / max(1, self.requests)

    def as_dict(self) -> dict:
        return dict(requests=self.requests, cache_hits=self.cache_hits,
                    cache_misses=self.cache_misses,
                    batched_requests=self.batched_requests,
                    batched_points=self.batched_points,
                    retry_attempts=self.retry_attempts,
                    hit_rate=round(self.hit_rate, 4))


def nan_count(state):
    """Total NaN count over the inexact leaves of a UDA state pytree.
    NaN — not isfinite — is the poison signal: MinMax pads values with
    +inf and AtLeastOne legitimately reaches log1p(-1) = -inf for
    deterministic (p = 1) tuples."""
    total = jnp.zeros((), jnp.int32)
    for leaf in jax.tree.leaves(state):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
            total = total + jnp.sum(jnp.isnan(leaf)).astype(jnp.int32)
    return total


class ReportBuilder:
    """Trace-time collector behind one compiled run: the executor calls
    the record methods while the plan traces (or executes eagerly) and
    :meth:`build` assembles the :class:`ExecutionReport`.  Labels are
    assigned from per-kind counters in execution order, so a plan's
    report structure is deterministic across traces (the jit cache and
    shard_map out-trees depend on it)."""

    def __init__(self):
        self._counters: dict = {}
        self._report = ExecutionReport()

    def _next(self, kind: str) -> str:
        i = self._counters.get(kind, 0)
        self._counters[kind] = i + 1
        return f"{kind}[{i}]"

    # ------------------------------------------------------- exchanges
    def begin_exchange(self, kind: str) -> str:
        """Label one exchange operator (shuffle_join / copartitioned_join
        / repartition); its legs record under ``label.leg``."""
        return self._next(kind)

    def exchange_leg(self, label: str, leg: str, overflow, demand,
                     capacity: int) -> None:
        key = f"{label}.{leg}"
        self._report.exchange_overflow[key] = jnp.asarray(overflow,
                                                          jnp.int32)
        self._report.exchange_demand[key] = jnp.asarray(demand, jnp.int32)
        self._report.exchange_capacity[key] = jnp.asarray(capacity,
                                                          jnp.int32)

    # ---------------------------------------------- aggregation passes
    def begin_agg(self, kind: str) -> str:
        return self._next(f"agg:{kind}")

    def group_overflow(self, label: str, count) -> None:
        self._report.group_overflow[label] = jnp.asarray(count, jnp.int32)

    def tail(self, name: str, per_group) -> None:
        self._report.tail_mass[name] = per_group

    def state_nan(self, name: str, count) -> None:
        self._report.state_nan[name] = jnp.asarray(count, jnp.int32)

    # ------------------------------------------------------- streaming
    def set_waves(self, completed: int, total: int, retries: int) -> None:
        self._report.waves["completed"] = completed
        self._report.waves["total"] = total
        self._report.waves["retries"] = retries

    # ------------------------------------------- trace-boundary plumbing
    def fork(self) -> "ReportBuilder":
        """A child builder whose label counters CONTINUE from this one —
        for a plan suffix traced under its own shard_map: the child
        collects inside the trace, its built report rides the traced
        outputs, and :meth:`absorb` merges the concrete copy back."""
        child = ReportBuilder()
        child._counters = dict(self._counters)
        return child

    def absorb(self, report: ExecutionReport) -> None:
        """Merge a (concrete) report produced by a forked builder."""
        for f in _FIELDS:
            getattr(self._report, f).update(getattr(report, f))

    def build(self) -> ExecutionReport:
        return self._report
