"""Probabilistic relational operators (paper §IV-F / Table I), vectorised.

Each operator is the deterministic-plan translation of Table I:

    I    R -> R^p                Table.from_columns(prob=...)
    II   sigma_C (deterministic) `select`: valid &= C
    III  sigma_{A theta B}       `reweight`: p *= P(theta); PGF comparisons
                                 come from repro.core.compare / approx cdfs
    IV   R join_C S              `fk_join` (many-to-one) / `general_join`
    V    pi_A                    `project`: GROUP BY + AtLeastOne UDA
    VI   aggregation             `group_*`: GROUP BY + PGF UDA per group

All operators run under jit with static capacities; liveness is carried by
the validity mask (a dead tuple behaves exactly like p = 0 for every UDA).
Grouping uses a fixed `max_groups`; overflows are detectable (group id ==
max_groups-1 fill bucket is flagged invalid).

The grouped aggregation functions below are thin views over the ONE
segment-UDA subsystem in :mod:`repro.core.uda`: each `group_*` builds the
matching registered UDA and runs the canonical blocked accumulation loop.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from ..core import uda
from .table import Table

# --------------------------------------------------------------- grouping
def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def check_nonneg_keys(table: Table, keys: Sequence[str]) -> None:
    """Enforce the nonnegative-key contract of :func:`encode_keys` /
    :func:`group_key_columns`.

    The positional key packing of :func:`encode_keys` multiplies fields
    into one nonnegative code, so a negative value in a valid row would
    silently corrupt the grouping (and overflow the hash routing of the
    shuffle exchanges, which reduce codes mod the shard count).  The
    check runs when the data is concrete — direct operator calls and the
    eager ``compile_plan`` execution path — and is skipped under tracing
    (shard_map / jit), where only shapes are visible.
    """
    import numpy as np
    if not _is_concrete(table.valid):
        return
    valid = np.asarray(table.valid)
    for k in keys:
        col = table[k]
        if not _is_concrete(col):
            continue
        live = np.asarray(col)[valid]
        if live.size and live.min() < 0:
            raise ValueError(
                f"group key column {k!r} contains negative values in valid "
                "rows; group-id codes assume nonnegative keys (the "
                "positional packing of encode_keys and the mod-shard hash "
                "routing) — shift or re-encode the column first")


def encode_keys(table: Table, keys: Sequence[str],
                multipliers: Sequence[int] | None = None) -> jnp.ndarray:
    """Combine key columns into one sortable int64-ish code (f64-safe ints).

    multipliers[i] must exceed max(keys[i+1:]) range; defaults assume each
    key < 2**20 which holds for every workload in repro.db.tpch.  Keys
    must be nonnegative (see :func:`check_nonneg_keys`).
    """
    code = jnp.zeros((table.capacity,), jnp.int64 if jax.config.jax_enable_x64
                     else jnp.int32)
    for i, k in enumerate(keys):
        m = multipliers[i] if multipliers else (1 << 20)
        code = code * m + table[k].astype(code.dtype)
    return code


def live_key_codes(table: Table, keys: Sequence[str]):
    """Per-row key codes with dead rows pushed to the ``big`` sentinel.

    Returns (code_live, big).  This is phase 0 of the (possibly
    distributed) group-id protocol: the sentinel sorts after every live
    code, so unique/searchsorted treat dead rows as one overflow key.
    """
    check_nonneg_keys(table, keys)
    code = encode_keys(table, keys)
    big = jnp.iinfo(code.dtype).max
    return jnp.where(table.valid, code, big), big


def merge_group_codes(codes: jnp.ndarray, max_groups: int) -> jnp.ndarray:
    """The ``max_groups`` smallest distinct codes, padded with the
    sentinel.

    Exact under sharding: if a code is dropped by a shard-local pass
    (> max_groups local distinct), at least max_groups smaller codes exist
    on that shard alone, so the drop can never evict a code from the
    global top-``max_groups`` — merging per-shard code tables therefore
    reproduces the single-pass result bit-for-bit, overflow included.
    """
    big = jnp.iinfo(codes.dtype).max
    return jnp.unique(codes, size=max_groups, fill_value=big)


def codes_to_ids(code_live: jnp.ndarray, group_codes: jnp.ndarray):
    """Row codes -> group ids in [0, max_groups) against a merged code
    table (dead/overflow rows land in the last, fill bucket).

    Dead rows (the ``big`` sentinel) go to the fill bucket EXPLICITLY, not
    to their searchsorted position: the first empty slot of a non-full
    code table would otherwise collect dead writers' identity values,
    making dead-group representatives depend on how much invalid padding
    a compile added (the sharded frontend pads more than mesh=None for
    shard counts that don't divide the chunk grid)."""
    big = jnp.iinfo(code_live.dtype).max
    ids = jnp.searchsorted(group_codes, code_live)
    ids = jnp.clip(ids, 0, group_codes.shape[0] - 1)
    return jnp.where(code_live == big, group_codes.shape[0] - 1, ids)


def group_ids(table: Table, keys: Sequence[str], max_groups: int):
    """Assign each valid row a group id in [0, max_groups).

    Returns (ids, group_codes, group_valid): `ids` is per-row (invalid rows
    get id max_groups-1 but contribute p=0 everywhere), `group_codes` the
    representative key code per group, `group_valid` marks live groups.
    The distributed form (``db.distributed.group_ids_sharded``) composes
    the same three phases with one all-gather of the per-shard code tables
    between :func:`merge_group_codes` passes.
    """
    code_live, big = live_key_codes(table, keys)
    uniq = merge_group_codes(code_live, max_groups)
    return codes_to_ids(code_live, uniq), uniq, uniq != big


def group_key_columns(table: Table, keys: Sequence[str], ids, max_groups: int):
    """Representative value of each key column per group.

    All valid writers of a group agree by construction; invalid rows write
    the segment_max IDENTITY (integer min / -inf), so they are
    indistinguishable from absent rows and a group with no valid writers
    keeps the identity in every compile — however much invalid padding a
    given mesh added.  Nonnegative key columns remain the grouping
    contract (:func:`check_nonneg_keys`, for the positional key packing
    of :func:`encode_keys`).
    """
    check_nonneg_keys(table, keys)
    out = {}
    for k in keys:
        col = table[k]
        if col.dtype == jnp.bool_:
            ident = jnp.zeros((), col.dtype)       # False: the OR identity
        elif jnp.issubdtype(col.dtype, jnp.integer):
            ident = jnp.asarray(jnp.iinfo(col.dtype).min, col.dtype)
        else:
            ident = jnp.asarray(-jnp.inf, col.dtype)
        out[k] = jax.ops.segment_max(
            jnp.where(table.valid, col, ident), ids,
            num_segments=max_groups)
    return out


# -------------------------------------------------------------- selection
def select(table: Table, pred: Callable[[Table], jnp.ndarray]) -> Table:
    """sigma_C, deterministic condition (Table I row II)."""
    return table.with_valid(table.valid & pred(table))


def reweight(table: Table, p_cond: jnp.ndarray) -> Table:
    """sigma with probabilistic condition (Table I row III): p *= P(cond).

    The caller computes P(cond) from the PGF ADT (compare.py / approx cdfs);
    the condition attributes are then discarded per the language restriction.
    """
    return table.with_prob(table.prob * p_cond)


# -------------------------------------------------------------- projection
def project(table: Table, keys: Sequence[str], max_groups: int) -> Table:
    """pi_A (Table I row V): GROUP BY keys + AtLeastOne UDA.

    p_group = 1 - prod_{tuples in group} (1 - p).
    """
    ids, _, gvalid = group_ids(table, keys, max_groups)
    prob = group_atleastone(table, ids, max_groups)
    cols = group_key_columns(table, keys, ids, max_groups)
    return Table(cols, prob, gvalid)


# -------------------------------------------------------------------- joins
def check_unique_fk_keys(right: Table, right_key: str) -> None:
    """Reject duplicate valid build-side keys in :func:`fk_join`.

    The many-to-one contract means each left row matches at most one valid
    right row; a duplicated key would silently pick the first occurrence
    and drop the other world's probability mass.  Checked when the build
    side is concrete (direct calls / eager ``compile_plan``); traced
    execution skips it.
    """
    import numpy as np
    rk, valid = right[right_key], right.valid
    if not (_is_concrete(rk) and _is_concrete(valid)):
        return
    live = np.asarray(rk)[np.asarray(valid)]
    if live.size != np.unique(live).size:
        raise ValueError(
            f"fk_join build side has duplicate valid keys in {right_key!r}; "
            "the many-to-one join contract needs the right key unique among "
            "valid rows (deduplicate or Project the build side first)")


def fk_join(left: Table, right: Table, left_key: str, right_key: str,
            right_cols: Sequence[str], suffix: str = "") -> Table:
    """Many-to-one equijoin (fact -> dimension), Table I row IV.

    Each left row matches at most one VALID right row (right_key unique
    among valid rows — the TPC-H FK pattern; duplicates are rejected when
    the build side is concrete).  Output capacity = left capacity;
    p = p_l * p_r.  Right lookup is sort + searchsorted, the XLA-friendly
    hash-join stand-in.  Under the sharded frontend the build side arrives
    pre-gathered (`db.distributed.gather_table`) — or only its key-matched
    responses do (`db.distributed.shuffle_fk_join`) — while `left` stays a
    shard-local block.

    Dead output rows — a miss (no valid key match) or an invalid left row
    — carry p = 0 and ZERO-FILLED right columns: deterministic dead
    values, so every execution strategy of the same join (gathered,
    shuffled, replicated) produces bit-identical Tables including the
    dead rows.
    """
    check_unique_fk_keys(right, right_key)
    rkey = right[right_key]
    big = jnp.iinfo(jnp.int32).max
    rk = jnp.where(right.valid, rkey.astype(jnp.int32), big)
    order = jnp.argsort(rk)
    rk_sorted = rk[order]
    lk = left[left_key].astype(jnp.int32)
    pos = jnp.searchsorted(rk_sorted, lk)
    pos = jnp.clip(pos, 0, right.capacity - 1)
    src = order[pos]
    hit = rk_sorted[pos] == lk

    valid = left.valid & hit
    cols = dict(left.columns)
    for c in right_cols:
        fetched = right[c][src]
        cols[c + suffix] = jnp.where(valid, fetched,
                                     jnp.zeros_like(fetched))
    prob = jnp.where(valid, left.prob * right.prob[src],
                     jnp.zeros_like(left.prob))
    return Table(cols, prob, valid, left.part)


# ------------------------------------------- shuffle-exchange bucket math
def bucket_slots(dest: jnp.ndarray, ok: jnp.ndarray, n_shards: int,
                 capacity: int):
    """Static-shape send-bucket slot assignment for a shuffle exchange.

    Row i with ``ok[i]`` goes to bucket ``dest[i]`` (in [0, n_shards)) at
    its rank among earlier ok-rows of the same destination; ranks >=
    ``capacity`` overflow and are dropped (but counted).  Rows with
    ``ok[i]`` False are parked in a phantom bucket and never sent.

    Returns ``(slot, sent, overflow_count)``: ``slot[i]`` indexes the flat
    (n_shards * capacity,) send buffer — out-of-range (== the buffer size)
    exactly for unsent rows, so a ``.at[slot].set(..., mode="drop")``
    scatter places rows and drops the rest; ``sent = ok & fits``;
    ``overflow_count`` = ok rows dropped for capacity.  Pure integer math
    (stable sorts), shared by the collective exchange
    (`db.distributed.shuffle_by_key`) and the host-side protocol tests.
    """
    n = dest.shape[0]
    d = jnp.where(ok, dest.astype(jnp.int32), n_shards)
    order = jnp.argsort(d, stable=True)
    ds = d[order]
    # rank within its destination run = sorted position - run start
    starts = jnp.searchsorted(ds, jnp.arange(n_shards + 1))
    rank_sorted = jnp.arange(n) - starts[jnp.clip(ds, 0, n_shards)]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    sent = ok & (rank < capacity)
    slot = jnp.where(sent, d * capacity + rank, n_shards * capacity)
    overflow = jnp.sum(ok & ~sent)
    return slot.astype(jnp.int32), sent, overflow


def bucket_fill_index(slot: jnp.ndarray, size: int) -> jnp.ndarray:
    """Inverse of a bucket slot assignment: ``inv[s]`` = the row filling
    buffer slot s, or ``n`` (the zero-pad row) for empty slots.  Sent
    rows occupy distinct slots, so ONE int32 scatter builds it — and
    every payload column then fills its buffer with a gather, which XLA
    CPU executes far faster than a per-column scatter."""
    n = slot.shape[0]
    return jnp.full((size,), n, jnp.int32).at[slot].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")


def scatter_to_buckets(cols: dict, slot: jnp.ndarray, size: int,
                       inv=None) -> dict:
    """Place rows into the flat (size,) send buffer at ``slot`` (unsent
    rows carry slot == size and are dropped); empty slots are zero.
    Implemented as one shared :func:`bucket_fill_index` + per-column
    gathers against a zero-padded copy."""
    if inv is None:
        inv = bucket_fill_index(slot, size)
    out = {}
    for k, v in cols.items():
        pad = jnp.concatenate([v, jnp.zeros((1,) + v.shape[1:], v.dtype)])
        out[k] = pad[inv]
    return out


def take_from_buckets(cols: dict, slot: jnp.ndarray, sent: jnp.ndarray):
    """Inverse of :func:`scatter_to_buckets` for response routing: read
    each row's bucket slot back (zero / False for unsent rows)."""
    out = {}
    for k, v in cols.items():
        safe = v[jnp.clip(slot, 0, v.shape[0] - 1)]
        out[k] = jnp.where(sent, safe, jnp.zeros_like(safe))
    return out


def general_join(left: Table, right: Table,
                 cond: Callable[[Table, Table, jnp.ndarray, jnp.ndarray], jnp.ndarray],
                 right_cols: Sequence[str], suffix: str = "") -> Table:
    """Nested-loop theta-join for small relations: capacity |L| x |R|.

    cond(left, right, i_idx, j_idx) -> bool over the flattened pair grid.
    """
    nl, nr = left.capacity, right.capacity
    ii = jnp.repeat(jnp.arange(nl), nr)
    jj = jnp.tile(jnp.arange(nr), nl)
    cols = {k: v[ii] for k, v in left.columns.items()}
    for c in right_cols:
        cols[c + suffix] = right[c][jj]
    ok = cond(left, right, ii, jj)
    prob = left.prob[ii] * right.prob[jj]
    valid = left.valid[ii] & right.valid[jj] & ok
    return Table(cols, prob, valid)


# ------------------------------------------------- grouped aggregation UDAs
def group_atleastone(table: Table, ids, max_groups: int) -> jnp.ndarray:
    """Per-group confidence 1 - prod(1-p) — the 'group confidence' query mode."""
    u = uda.AtLeastOne()
    st = uda.accumulate({"a": u}, table.masked_prob(), None, ids,
                        max_groups=max_groups)["a"]
    return u.finalize(st)


def group_normal_terms(table: Table, values, ids, max_groups: int):
    """Per-group (mean, var) of the probabilistic SUM (paper §V-C.3 Normal,
    with the variance erratum fixed: var = sum v^2 p (1-p))."""
    u = uda.SumNormal()
    st = uda.accumulate({"n": u}, table.masked_prob(), values, ids,
                        max_groups=max_groups)["n"]
    return u.finalize(st)


def group_cumulant_terms(table: Table, values, ids, max_groups: int,
                         orders: int = 8) -> jnp.ndarray:
    """Per-group cumulant partial sums (G, orders) for the moment method."""
    st = uda.accumulate({"c": uda.SumCumulants(orders)}, table.masked_prob(),
                        values, ids, max_groups=max_groups)["c"]
    return st.terms


def group_logcf(table: Table, values, ids, max_groups: int, num_freq: int,
                block: int = 512):
    """Per-group summed log CF -> (G, F) log_abs and angle (exact SUM/COUNT
    per group), via the canonical loop of core/uda.py — which dispatches
    grouped CF states to the (G, F)-tiled Pallas kernel
    (:mod:`repro.kernels.group_cf`) on TPU backends and to the blocked scan
    elsewhere.  Plans reach the same path as ``GroupAgg(method="exact")``,
    which additionally chunks the (G, F) state over frequency slabs."""
    st = uda.accumulate({"cf": uda.SumCF(num_freq)}, table.masked_prob(),
                        values, ids, max_groups=max_groups, block=block)["cf"]
    return st.log_abs, st.angle


def group_logcf_finalize(la: jnp.ndarray, an: jnp.ndarray) -> jnp.ndarray:
    """(G, F) log CF -> (G, F) coefficient rows via one batched FFT."""
    return uda.SumCF(la.shape[-1]).finalize(uda.CFState(la, an))


def minmax_runs(u: uda.MinMax, state: uda.MinMaxState) -> dict:
    """Flatten a grouped MinMax state into the per-run dict consumed by the
    query modes: (run_group, run_value, run_mass, run_valid) over the G*kappa
    buffer grid, plus per-group p_empty and the truncation p_tail."""
    values, mass, p_tail = u.finalize(state)
    g, k = values.shape
    finite = jnp.isfinite(values)
    return dict(run_group=jnp.repeat(jnp.arange(g), k),
                run_value=values.reshape(-1),
                run_mass=jnp.where(finite, mass, 0.0).reshape(-1),
                run_valid=finite.reshape(-1),
                p_empty=u.p_empty(state), p_tail=p_tail,
                tail_mass=u.tail_mass(state))


def group_minmax(table: Table, values, ids, max_groups: int, sign: float = 1.0,
                 kappa: int | None = None):
    """Grouped MIN (sign=+1) / MAX (sign=-1) masses via the MinMax UDA
    (paper §V-B.1):

        P(agg = v_j) = prod_{v_l better than v_j} Q_l * (1 - Q_j),
        Q_l = prod_{tuples at v_l} (1 - p).

    `kappa` bounds the per-group support kept (default: exact up to 128
    distinct values; overflow mass is reported in `p_tail`, §V-B.2).
    Returns the flattened run dict of :func:`minmax_runs`.
    """
    if kappa is None:
        kappa = min(table.capacity, 128)
    u = uda.MinMax(kappa=kappa, sign=sign)
    st = uda.accumulate({"m": u}, table.masked_prob(), values, ids,
                        max_groups=max_groups)["m"]
    return minmax_runs(u, st)


# --------------------------------------------- scalar comparison epilogues
def normal_greater(mu, var, threshold):
    """P(N(mu, var) > threshold), vectorised over groups (§VII-D epilogue)."""
    sigma = jnp.sqrt(jnp.maximum(var, 1e-30))
    z = (threshold - mu) / sigma
    return 0.5 * jax.lax.erfc(z / math.sqrt(2.0))


def cf_greater(la, an, threshold):
    """Exact P(S > t) from per-group log CF rows (G, F)."""
    coeffs = group_logcf_finalize(la, an)
    f = la.shape[-1]
    idx = jnp.arange(f)
    mask = idx[None, :] > jnp.asarray(threshold)[:, None]
    return jnp.sum(coeffs * mask, axis=-1)
