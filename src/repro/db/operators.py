"""Probabilistic relational operators (paper §IV-F / Table I), vectorised.

Each operator is the deterministic-plan translation of Table I:

    I    R -> R^p                Table.from_columns(prob=...)
    II   sigma_C (deterministic) `select`: valid &= C
    III  sigma_{A theta B}       `reweight`: p *= P(theta); PGF comparisons
                                 come from repro.core.compare / approx cdfs
    IV   R join_C S              `fk_join` (many-to-one) / `general_join`
    V    pi_A                    `project`: GROUP BY + AtLeastOne UDA
    VI   aggregation             `group_*`: GROUP BY + PGF UDA per group

All operators run under jit with static capacities; liveness is carried by
the validity mask (a dead tuple behaves exactly like p = 0 for every UDA).
Grouping uses a fixed `max_groups`; overflows are detectable (group id ==
max_groups-1 fill bucket is flagged invalid).
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from ..core import poisson_binomial as pb
from ..core.approx import MAX_ORDER, _bernoulli_cumulant_polys
from .table import Table

# --------------------------------------------------------------- grouping
def encode_keys(table: Table, keys: Sequence[str],
                multipliers: Sequence[int] | None = None) -> jnp.ndarray:
    """Combine key columns into one sortable int64-ish code (f64-safe ints).

    multipliers[i] must exceed max(keys[i+1:]) range; defaults assume each
    key < 2**20 which holds for every workload in repro.db.tpch.
    """
    code = jnp.zeros((table.capacity,), jnp.int64 if jax.config.jax_enable_x64
                     else jnp.int32)
    for i, k in enumerate(keys):
        m = multipliers[i] if multipliers else (1 << 20)
        code = code * m + table[k].astype(code.dtype)
    return code


def group_ids(table: Table, keys: Sequence[str], max_groups: int):
    """Assign each valid row a group id in [0, max_groups).

    Returns (ids, group_codes, group_valid): `ids` is per-row (invalid rows
    get id max_groups-1 but contribute p=0 everywhere), `group_codes` the
    representative key code per group, `group_valid` marks live groups.
    """
    code = encode_keys(table, keys)
    big = jnp.iinfo(code.dtype).max
    code_live = jnp.where(table.valid, code, big)
    uniq = jnp.unique(code_live, size=max_groups, fill_value=big)
    ids = jnp.searchsorted(uniq, code_live)
    ids = jnp.clip(ids, 0, max_groups - 1)
    return ids, uniq, uniq != big


def group_key_columns(table: Table, keys: Sequence[str], ids, max_groups: int):
    """Representative value of each key column per group.

    All valid writers of a group agree by construction; invalid rows write
    the identity 0, so this requires nonnegative key columns (true for every
    repro.db workload — keys are ids/dates/quantities).
    """
    out = {}
    for k in keys:
        col = table[k]
        out[k] = jax.ops.segment_max(
            jnp.where(table.valid, col, jnp.zeros_like(col)), ids,
            num_segments=max_groups)
    return out


# -------------------------------------------------------------- selection
def select(table: Table, pred: Callable[[Table], jnp.ndarray]) -> Table:
    """sigma_C, deterministic condition (Table I row II)."""
    return table.with_valid(table.valid & pred(table))


def reweight(table: Table, p_cond: jnp.ndarray) -> Table:
    """sigma with probabilistic condition (Table I row III): p *= P(cond).

    The caller computes P(cond) from the PGF ADT (compare.py / approx cdfs);
    the condition attributes are then discarded per the language restriction.
    """
    return table.with_prob(table.prob * p_cond)


# -------------------------------------------------------------- projection
def project(table: Table, keys: Sequence[str], max_groups: int) -> Table:
    """pi_A (Table I row V): GROUP BY keys + AtLeastOne UDA.

    p_group = 1 - prod_{tuples in group} (1 - p).
    """
    ids, _, gvalid = group_ids(table, keys, max_groups)
    logq = jnp.where(table.valid, jnp.log1p(-table.masked_prob()), 0.0)
    acc = jax.ops.segment_sum(logq, ids, num_segments=max_groups)
    prob = 1.0 - jnp.exp(acc)
    cols = group_key_columns(table, keys, ids, max_groups)
    return Table(cols, prob, gvalid)


# -------------------------------------------------------------------- joins
def fk_join(left: Table, right: Table, left_key: str, right_key: str,
            right_cols: Sequence[str], suffix: str = "") -> Table:
    """Many-to-one equijoin (fact -> dimension), Table I row IV.

    Each left row matches at most one VALID right row (right_key unique
    among valid rows — the TPC-H FK pattern).  Output capacity = left
    capacity; p = p_l * p_r.  Right lookup is sort + searchsorted, the
    XLA-friendly hash-join stand-in.
    """
    rkey = right[right_key]
    big = jnp.iinfo(jnp.int32).max
    rk = jnp.where(right.valid, rkey.astype(jnp.int32), big)
    order = jnp.argsort(rk)
    rk_sorted = rk[order]
    lk = left[left_key].astype(jnp.int32)
    pos = jnp.searchsorted(rk_sorted, lk)
    pos = jnp.clip(pos, 0, right.capacity - 1)
    src = order[pos]
    hit = rk_sorted[jnp.clip(pos, 0, right.capacity - 1)] == lk

    cols = dict(left.columns)
    for c in right_cols:
        cols[c + suffix] = right[c][src]
    prob = left.prob * jnp.where(hit, right.prob[src], 0.0)
    valid = left.valid & hit
    return Table(cols, prob, valid)


def general_join(left: Table, right: Table,
                 cond: Callable[[Table, Table, jnp.ndarray, jnp.ndarray], jnp.ndarray],
                 right_cols: Sequence[str], suffix: str = "") -> Table:
    """Nested-loop theta-join for small relations: capacity |L| x |R|.

    cond(left, right, i_idx, j_idx) -> bool over the flattened pair grid.
    """
    nl, nr = left.capacity, right.capacity
    ii = jnp.repeat(jnp.arange(nl), nr)
    jj = jnp.tile(jnp.arange(nr), nl)
    cols = {k: v[ii] for k, v in left.columns.items()}
    for c in right_cols:
        cols[c + suffix] = right[c][jj]
    ok = cond(left, right, ii, jj)
    prob = left.prob[ii] * right.prob[jj]
    valid = left.valid[ii] & right.valid[jj] & ok
    return Table(cols, prob, valid)


# ------------------------------------------------- grouped aggregation UDAs
def group_atleastone(table: Table, ids, max_groups: int) -> jnp.ndarray:
    """Per-group confidence 1 - prod(1-p) — the 'group confidence' query mode."""
    logq = jnp.log1p(-table.masked_prob())
    acc = jax.ops.segment_sum(logq, ids, num_segments=max_groups)
    return 1.0 - jnp.exp(acc)


def group_normal_terms(table: Table, values, ids, max_groups: int):
    """Per-group (mean, var) of the probabilistic SUM (paper §V-C.3 Normal,
    with the variance erratum fixed: var = sum v^2 p (1-p))."""
    p = table.masked_prob()
    mu = jax.ops.segment_sum(values * p, ids, num_segments=max_groups)
    var = jax.ops.segment_sum(values ** 2 * p * (1 - p), ids,
                              num_segments=max_groups)
    return mu, var


def group_cumulant_terms(table: Table, values, ids, max_groups: int,
                         orders: int = 8) -> jnp.ndarray:
    """Per-group cumulant partial sums (G, orders) for the moment method."""
    p = table.masked_prob()
    dtype = p.dtype
    table_c = jnp.asarray(_bernoulli_cumulant_polys()[1:orders + 1], dtype)
    powers = p[None, :] ** jnp.arange(MAX_ORDER + 1, dtype=dtype)[:, None]
    kappas = table_c @ powers                               # (orders, n)
    vpow = values[None, :] ** jnp.arange(1, orders + 1, dtype=dtype)[:, None]
    terms = (kappas * vpow).T                               # (n, orders)
    return jax.ops.segment_sum(terms, ids, num_segments=max_groups)


def group_logcf(table: Table, values, ids, max_groups: int, num_freq: int,
                block: int = 512):
    """Per-group summed log CF -> (G, F) log_abs and angle (exact SUM/COUNT
    per group).  Blocked over tuples so the (block, F) tile stays bounded —
    the grouped twin of kernels/pb_cf.py.
    """
    p = table.masked_prob()
    dtype = p.dtype
    n = p.shape[0]
    v = jnp.asarray(values, dtype)
    block = max(64, min(block, (1 << 22) // max(1, num_freq)))
    nfull = ((n + block - 1) // block) * block
    p = jnp.pad(p, (0, nfull - n))
    v = jnp.pad(v, (0, nfull - n))
    ids_p = jnp.pad(ids, (0, nfull - n), constant_values=max_groups - 1)
    k = jnp.arange(num_freq, dtype=dtype)

    def body(carry, chunk):
        la, an = carry
        pc, vc, gc = chunk
        phase = (k[None, :] * vc[:, None]) % num_freq
        theta = (2.0 * math.pi / num_freq) * phase
        q = 1.0 - pc[:, None]
        re = q + pc[:, None] * jnp.cos(theta)
        im = pc[:, None] * jnp.sin(theta)
        tiny = 1e-30 if dtype == jnp.float32 else 1e-300
        l = 0.5 * jnp.log(jnp.maximum(re * re + im * im, tiny))
        t = jnp.arctan2(im, re)
        la = la.at[gc].add(l)
        an = an.at[gc].add(t)
        return (la, an), None

    init = (jnp.zeros((max_groups, num_freq), dtype),
            jnp.zeros((max_groups, num_freq), dtype))
    chunks = (p.reshape(-1, block), v.reshape(-1, block), ids_p.reshape(-1, block))
    (la, an), _ = jax.lax.scan(body, init, chunks)
    return la, an


def group_logcf_finalize(la: jnp.ndarray, an: jnp.ndarray) -> jnp.ndarray:
    """(G, F) log CF -> (G, F) coefficient rows via one batched FFT."""
    q = jnp.exp(la) * jax.lax.complex(jnp.cos(an), jnp.sin(an))
    coeffs = jnp.fft.fft(q, axis=-1).real / la.shape[-1]
    return jnp.clip(coeffs, 0.0, None)


def group_minmax(table: Table, values, ids, max_groups: int, sign: float = 1.0):
    """Grouped MIN (sign=+1) / MAX (sign=-1) masses, fully vectorised.

    Sort rows by (group, sign*value); fold duplicates; per-group prefix
    survival products (paper §V-B.1):

        P(agg = v_j) = prod_{v_l better than v_j} Q_l * (1 - Q_j),
        Q_l = prod_{tuples at v_l} (1 - p).

    Returns per-row (sorted order) arrays: (gid, value, mass, is_seg_head)
    plus per-group p_empty.  Densification/top-kappa happens downstream.
    """
    p = table.masked_prob()
    v = jnp.asarray(values, p.dtype) * sign
    n = p.shape[0]
    # Lexsort by (group, value) via two stable argsorts — a combined float
    # key would lose the value bits to f64 ULP at large group ids.
    ord1 = jnp.argsort(v, stable=True)
    ord2 = jnp.argsort(ids[ord1], stable=True)
    order = ord1[ord2]
    gs, vs, ps = ids[order], v[order], p[order]
    logq = jnp.log1p(-ps)

    # Segment heads: first row of each (group, value) run.
    head = jnp.concatenate([jnp.ones((1,), bool),
                            (gs[1:] != gs[:-1]) | (vs[1:] != vs[:-1])])
    seg = jnp.cumsum(head) - 1                         # (n,) run index
    run_logq = jax.ops.segment_sum(logq, seg, num_segments=n)  # log Q per run

    # prefix[r] = sum of log Q over same-group runs strictly better than r
    #           = (row prefix sum at r's head row) - (at r's group head row).
    cs = jnp.concatenate([jnp.zeros((1,), logq.dtype),
                          jnp.cumsum(logq)[:-1]])      # sum before each row
    grp_head = jnp.concatenate([jnp.ones((1,), bool), gs[1:] != gs[:-1]])
    run_head_cs = jax.ops.segment_sum(jnp.where(head, cs, 0.0), seg,
                                      num_segments=n)  # one head per run
    grp_base = jax.ops.segment_sum(jnp.where(grp_head, cs, 0.0), gs,
                                   num_segments=max_groups)
    grp_of_run = jnp.clip(jax.ops.segment_max(gs, seg, num_segments=n),
                          0, max_groups - 1)
    prefix = run_head_cs - grp_base[grp_of_run]
    mass_run = jnp.exp(prefix) * (1.0 - jnp.exp(run_logq))

    total_logq = jax.ops.segment_sum(jnp.log1p(-p), ids,
                                     num_segments=max_groups)
    p_empty = jnp.exp(total_logq)

    run_value = jax.ops.segment_min(vs, seg, num_segments=n) * sign
    run_valid = jax.ops.segment_max(ps, seg, num_segments=n) > 0
    return dict(run_group=grp_of_run, run_value=run_value,
                run_mass=jnp.where(run_valid, mass_run, 0.0),
                run_valid=run_valid, p_empty=p_empty)


# --------------------------------------------- scalar comparison epilogues
def normal_greater(mu, var, threshold):
    """P(N(mu, var) > threshold), vectorised over groups (§VII-D epilogue)."""
    sigma = jnp.sqrt(jnp.maximum(var, 1e-30))
    z = (threshold - mu) / sigma
    return 0.5 * jax.lax.erfc(z / math.sqrt(2.0))


def cf_greater(la, an, threshold):
    """Exact P(S > t) from per-group log CF rows (G, F)."""
    coeffs = group_logcf_finalize(la, an)
    f = la.shape[-1]
    idx = jnp.arange(f)
    mask = idx[None, :] > jnp.asarray(threshold)[:, None]
    return jnp.sum(coeffs * mask, axis=-1)
