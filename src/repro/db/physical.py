"""Physical plan IR: explicit, cost-chosen execution strategies for the
sharded relational frontend.

``repro.db.plans.compile_plan`` splits compilation into two stages:

    logical plan (plans.Node DAG)
        --lower_plan-->  physical plan (this module's PhysNode DAG)
        --plans executor-->  one jit-able tables -> result function

``lower_plan`` is itself a two-phase optimizer:

    1. ENUMERATE — per logical node, build every legal physical candidate
       (GatherJoin / ShuffleJoin / CoPartitionedJoin for an FKJoin;
       PartialAgg on RowBlocked input vs Repartition + PartitionedAgg for
       an aggregation, plus the fused CoPartitionedJoin + PartitionedAgg
       pipeline when a GROUP BY keys on the join key);
    2. COST — price each candidate with the explicit, unit-tested model
       in :mod:`repro.db.cost` (bytes moved per collective, peak rows per
       device, UDA flops) and pick the cheapest.

The old budget knobs survive ONLY as cost-model overrides (an
infinite-cost penalty on the forbidden side of the flip point), so
``join_gather_budget`` reproduces the PR-4 golden flip behaviour exactly
while everything inside the allowed region is decided by the estimates.
Chosen nodes carry their modeled ``cost``; :func:`explain` prints it.

Partitioning properties
-----------------------
Every physical node carries ``part``, the placement of its output rows on
the mesh's data shards — one of three points of a small lattice:

    Replicated              every shard holds the identical full table.
                            Top of the lattice: valid input for every
                            operator, and the only property with no
                            per-device memory savings.
    RowBlocked              contiguous equal row blocks, shard s holding
                            rows [s*B, (s+1)*B) of the canonical
                            (chunk-grid padded) global row order.  The
                            O(rows/shards) workhorse; shard-major
                            concatenation IS the global row order.
    HashPartitioned(key)    row lives on shard ``key % n_shards``.  The
                            co-location property: two relations hashed on
                            their join keys join shard-locally, and a
                            GROUP BY on the hash key aggregates
                            shard-locally (every group wholly at one
                            owner).

Exchange operators move between the points:

    all-gather   RowBlocked       -> Replicated      (dist.gather_table)
    shuffle      RowBlocked       -> HashPartitioned (dist.shuffle_by_key;
                                                      ShuffleJoin's build
                                                      leg, Repartition,
                                                      CoPartitionedJoin)
    shuffle home HashPartitioned  -> RowBlocked      (responses routed back
                                                      through the same
                                                      static send buckets
                                                      — ONLY ShuffleJoin
                                                      pays this leg)

Residency and the wave-schedule lattice
---------------------------------------
Partitioning says WHERE on the mesh a row lives; residency says WHEN it
is on the mesh at all.  Orthogonal to the placement lattice, every base
scan sits at one of three points of a residency lattice, ordered by
per-device footprint:

    Resident       the whole (padded) table is on the mesh for the whole
                   query — ShardScan; footprint rows/shards.  Top of the
                   lattice: every strategy below it is legal.
    Streamed       the table lives HOST-side and visits the mesh as
                   ``n_waves`` uniform slabs of ``chunks_per_wave``
                   canonical-chunk slots — StreamedScan; footprint
                   2 slabs/device (double buffer) + the aggregation
                   state, INDEPENDENT of the table size.
    (Absent)       bottom: a table no operator reads — never planned.

``cost.wave_schedule`` picks the point and the wave size from the
``device_row_budget`` override: a scan whose per-shard rows exceed the
budget streams, with the largest wave whose TWO slabs fit the budget
(``local_chunks_per_wave = budget // (2 * chunk_rows)``, clamped to
[1, chunk slots per shard]).  Waves are aligned to the canonical chunk
grid, so each wave's slab is a run of whole chunk slots and the host
table is padded until every wave has the same shape — one compiled wave
function, and per-chunk UDA states whose values cannot depend on the
wave size.  That is the streaming exactness argument in one line: the
canonical-chunk contract already computes each chunk's state from that
chunk's rows alone and merges chunk states in ONE fixed tree
(``uda.tree_fold``), so slicing the chunk sequence into waves changes
*when* a chunk state is produced, never *what* is folded — results are
bit-identical to resident execution for ANY wave size.

Streaming restricts the strategy menu to the candidates whose per-wave
semantics are the resident ones verbatim: joins below a streamed scan
lower to GatherJoin (the resident build side is replicated once; every
wave probes it locally) and aggregations to PartialAgg (per-wave,
per-chunk Accumulate; the executor gathers each wave's chunk states and
folds ONCE after the last wave).  A build side over the budget raises —
only the probe side may stream.

Node zoo (the executor in plans.py interprets these inside shard_map):

    ShardScan(name)                  base table; RowBlocked on a mesh,
                                     Replicated single-device
    StreamedScan(name, schedule)     out-of-core base table: host-side
                                     rows, shipped as schedule.n_waves
                                     chunk-aligned slabs, double-buffered
                                     (device_put of wave k+1 overlaps the
                                     accumulate of wave k); each slab is
                                     RowBlocked on the mesh
    PhysSelect / PhysMap             elementwise on the local block;
                                     preserve the child's partitioning
    GatherJoin(l, r, ...)            broadcast FK join: build side
                                     all-gathered to Replicated (a no-op
                                     when it already is), probe local
    ShuffleJoin(l, r, ...)           hash-partitioned FK join: build rows
                                     shuffled to HashPartitioned(right_key)
                                     owners, probe keys shuffled to the
                                     same owners as requests, matched
                                     shard-locally, responses shuffled home
                                     — output stays RowBlocked and
                                     bit-identical to GatherJoin, with
                                     O(build/shards) peak build rows/device
    CoPartitionedJoin(l, r, ...)     the fused shuffle -> aggregate
                                     pipeline's join half: same build and
                                     probe exchanges, but probe rows carry
                                     their probability, canonical-chunk id
                                     and the aggregation's value columns,
                                     and matched rows STAY at their
                                     ``key % n_shards`` owner (NO
                                     shuffle-home round-trip); output is
                                     HashPartitioned(left_key)
    Repartition(child, key, ...)     hash-exchange of aggregation inputs
                                     to their group-key owner (the no-join
                                     path into PartitionedAgg)
    PartialAgg(child, keys, specs)   per-shard, per-canonical-chunk UDA
                                     Accumulate over the RowBlocked local
                                     tuples; output = partitioned partial
                                     states, merged by ONE all-gather of
                                     all chunk states + the canonical fold
    PartitionedAgg(child, ...)       UDA Accumulate over a HashPartitioned
                                     buffer: group-id assignment runs
                                     owner-locally, every canonical chunk
                                     state is computed at the owner (one
                                     compound (chunk, group) pass), each
                                     owner finishes the canonical fold
                                     LOCALLY, and the merge is ONE psum of
                                     the folded additive states (groups
                                     are owner-disjoint, so the psum adds
                                     exact zeros — bit-identical to the
                                     RowBlocked fold) + an n-way
                                     gather-fold for MinMax states
    MergeAgg(partial, kind)          the merge + replicated Finalize;
                                     kind selects the epilogue (groupagg
                                     dict / project Table / reweight
                                     Table)

Worked example — TPC-H Q3 (revenue per order, GROUP BY l_orderkey) on a
4-shard mesh with the orders build side over the gather budget::

    MergeAgg[groupagg] :: Replicated
      PartitionedAgg(keys=[l_orderkey], ...) :: HashPartitioned(l_orderkey)
        CoPartitionedJoin(l_orderkey=o_orderkey, carry=[l_extendedprice])
            :: HashPartitioned(l_orderkey)
          Select :: RowBlocked            (lineitem, shipdate filter)
            ShardScan(lineitem) :: RowBlocked
          ShuffleJoin(o_custkey=c_custkey, ...) :: RowBlocked
            ...                           (orders |x| customer)

    lineitem rows hash to shard ``l_orderkey % 4`` carrying
    (p, chunk, l_extendedprice); orders rows hash to the same owners; the
    match and the whole GROUP BY run at the owner; the only remaining
    collective is one psum of the folded (G, 2) normal state.  The
    ShuffleJoin alternative pays the same two exchanges PLUS the response
    round-trip home and an all-gather of all canonical chunk states —
    ``lower_plan`` picks the fused pipeline because
    :func:`repro.db.cost.copartitioned_join` +
    :func:`repro.db.cost.partitioned_agg` price strictly fewer bytes.

Worked example — streamed TPC-H Q1 (SUM(l_quantity) GROUP BY returnflag,
linestatus) on a 2-shard mesh, lineitem at 64k rows against
``device_row_budget=8192``::

    MergeAgg[groupagg] :: Replicated
      PartialAgg(keys=[l_returnflag, l_linestatus], ...) :: RowBlocked
        Select :: RowBlocked              (shipdate filter, per wave)
          StreamedScan(lineitem, rows=65536, waves=4x2chunks@8192rows)
              :: RowBlocked cost{bytes=1572864, rows=49152, flops=0}

    65536 rows / 8 canonical chunks = 8192-row chunk slots; the budget
    holds 2 slabs of 8192 rows per device, so each wave carries ONE
    chunk slot per shard (2 globally) and the schedule needs 4 waves.
    The executor runs two passes over the host table: wave pass A
    discovers the global group-code table (per-wave ``unique`` codes,
    merged incrementally — exact under hierarchical merging), then wave
    pass B re-streams the slabs, accumulates per-chunk UDA states with
    the final group ids, all-gathers each wave's chunk states, and after
    wave 4 folds all 8 canonical chunk states in the same
    ``uda.tree_fold`` tree the resident compile uses — bit-identical
    output, with peak device residency 2 slabs + the (G, 2) sum state
    instead of the 64k-row table.  While wave k's accumulate runs on
    device, wave k+1's slab is already crossing host→device (async
    dispatch double-buffering); ``explain`` prints the modeled one-way
    transfer bytes and the 2-slab peak rows/device on the StreamedScan.

Bit-reproducibility of the fused pipeline: each probe row ships its
canonical-chunk id; the owner accumulates one compound (chunk, group)
scatter pass whose received rows arrive in (sender, rank) = global row
order, so every (chunk, group) slot folds the SAME tuples in the SAME
order as the RowBlocked chunk pass; all chunks of a group live at its
owner, so the owner's local canonical ``tree_fold`` equals the global
one for its groups and the final psum adds exact zeros elsewhere.  The
contract requires the group-key cardinality to fit ``max_groups`` (the
overflow fill bucket is flagged invalid in every path but its garbage
value is only deterministic per-layout).

ShuffleJoin / CoPartitionedJoin / Repartition bucket capacities are
static (XLA shapes): each shard sends at most ``*_bucket`` rows to each
owner.  When the exchange key column is CONCRETE at lowering time (eager
compiles), the capacity is sized from the actual ``key % n_shards``
histogram of the base table — ``max`` per (sender, owner) demand, so a
skewed key distribution gets exactly the buckets it needs and overflow is
impossible; traced keys (jit) fall back to ``ceil(local_rows * slack /
n_shards)`` capped at ``local_rows``, where overflow is *accounted*
(dropped rows are counted, the count is psum-shared, and the executor
poisons the output probabilities with NaN — see ``dist.shuffle_fk_join``
for the boolean-consumer caveat; ``slack >= n_shards`` makes overflow
impossible).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import types
from typing import Callable

import numpy as np

from . import cost as C

#: reserved column carrying each exchanged row's canonical-chunk id
#: through a hash exchange ("\x00" keeps it out of the legal namespace).
CHUNK_COL = "\x00chunk"


# ---------------------------------------------------------------- properties
@dataclasses.dataclass(frozen=True)
class Replicated:
    """Every shard holds the identical full table."""


@dataclasses.dataclass(frozen=True)
class RowBlocked:
    """Contiguous equal row blocks of the canonical global row order."""


@dataclasses.dataclass(frozen=True)
class HashPartitioned:
    """Row lives on shard ``key % n_shards`` (key = this column)."""
    key: str


# ---------------------------------------------------------------------- IR
class PhysNode:
    pass


@dataclasses.dataclass(frozen=True)
class ShardScan(PhysNode):
    name: str
    part: object
    rows: int              # global (padded) capacity of the base table


@dataclasses.dataclass(frozen=True)
class StreamedScan(PhysNode):
    """Out-of-core base table: rows live HOST-side and reach the mesh as
    ``schedule.n_waves`` canonical-chunk-aligned slabs (see the wave
    lattice in the module docstring).  ``part`` is the placement of each
    wave's slab (RowBlocked on a mesh); ``rows`` is the global chunk-grid
    capacity of the host table; ``cost`` prices the one-way host→device
    bytes and the 2-slab double-buffered residency.  ``columns`` is the
    static required-column demand set of the plan above the scan
    (:func:`required_scan_columns`): wave slabs ship ONLY these columns
    (plus prob/valid); ``None`` means the analysis could not bound the
    reads and every column streams."""
    name: str
    part: object
    rows: int
    schedule: C.WaveSchedule
    cost: object = None
    columns: tuple | None = None


@dataclasses.dataclass(frozen=True)
class PhysSelect(PhysNode):
    child: PhysNode
    pred: Callable
    part: object


@dataclasses.dataclass(frozen=True)
class PhysMap(PhysNode):
    child: PhysNode
    name: str
    fn: Callable
    part: object


@dataclasses.dataclass(frozen=True)
class GatherJoin(PhysNode):
    left: PhysNode
    right: PhysNode
    left_key: str
    right_key: str
    right_cols: tuple
    build_rows: int        # global capacity of the build side
    part: object           # = left.part
    cost: object = None    # modeled repro.db.cost.Cost of the choice


@dataclasses.dataclass(frozen=True)
class ShuffleJoin(PhysNode):
    left: PhysNode
    right: PhysNode
    left_key: str
    right_key: str
    right_cols: tuple
    build_rows: int
    exchange: HashPartitioned   # intermediate placement of both sides
    build_bucket: int           # static per-(sender, owner) bucket rows
    probe_bucket: int
    part: object                # = left.part (responses shuffled home)
    cost: object = None


@dataclasses.dataclass(frozen=True)
class CoPartitionedJoin(PhysNode):
    """ShuffleJoin without the trip home: matched rows stay at their
    ``left_key % n_shards`` owner, probe rows carry (p, chunk id, carry
    columns), and only the build columns the consumer reads are fetched
    (``right_cols`` here is already pruned to the aggregation's needs)."""
    left: PhysNode
    right: PhysNode
    left_key: str
    right_key: str
    right_cols: tuple           # build columns the aggregation reads
    carry_cols: tuple           # probe columns shipped with the requests
    build_rows: int
    build_bucket: int
    probe_bucket: int
    part: HashPartitioned       # = HashPartitioned(left_key)
    cost: object = None


@dataclasses.dataclass(frozen=True)
class Repartition(PhysNode):
    """Hash-exchange aggregation inputs to their group-key owner."""
    child: PhysNode
    key: str
    carry_cols: tuple           # value/threshold columns the pass reads
    bucket: int
    part: HashPartitioned       # = HashPartitioned(key)
    cost: object = None


@dataclasses.dataclass(frozen=True)
class PartialAgg(PhysNode):
    child: PhysNode
    keys: tuple
    specs: tuple           # ((name, value_col, agg, method), ...)
    max_groups: int
    kappa: int
    num_freq: int
    part: object           # = child.part (states partial per shard)
    cost: object = None


@dataclasses.dataclass(frozen=True)
class PartitionedAgg(PhysNode):
    """PartialAgg's HashPartitioned twin: owner-local group ids, one
    compound (chunk, group) accumulate, owner-local canonical fold, ONE
    psum merge (see module docstring)."""
    child: PhysNode
    keys: tuple
    specs: tuple
    max_groups: int
    kappa: int
    num_freq: int
    part: HashPartitioned  # = child.part
    cost: object = None


@dataclasses.dataclass(frozen=True)
class MergeAgg(PhysNode):
    child: PhysNode        # PartialAgg | PartitionedAgg
    kind: str              # groupagg | project | reweight
    threshold_col: str = ""
    threshold: float | None = None
    carry_cols: tuple = ()
    part: object = Replicated()


_RESERVED_OUT_KEYS = frozenset({"valid", "keys", "confidence"})


# ------------------------------------------------------ structural identity
def structural_key(obj) -> tuple:
    """A stable, hashable fingerprint of a plan object's STRUCTURE.

    Frozen dataclasses (logical ``plans.Node``s, the PhysNode IR, cost
    models, wave schedules) fingerprint as (class, field fingerprints);
    plain Python functions — the lambdas a Select/Map carries — by their
    compiled bytecode, constants and captured closure CELL VALUES, so two
    separately constructed but textually identical plans produce EQUAL
    keys (the property identity-keyed caches miss on), while a lambda
    capturing a different constant produces a different key.  Containers
    recurse; small concrete arrays fingerprint by dtype/shape/bytes.

    Anything unrecognised falls back to ``id()`` — an identity key can
    only cause a cache MISS, never a false hit, so the fingerprint is
    safe to key compiled executables on (the serving layer's plan cache
    and the streamed executor's wave cache both do).
    """
    if obj is None or isinstance(obj, (bool, int, float, complex, str,
                                       bytes)):
        return ("a", type(obj).__name__, obj)
    if isinstance(obj, (tuple, list)):
        return ("t", tuple(structural_key(x) for x in obj))
    if isinstance(obj, dict):
        return ("d", tuple(sorted((str(k), structural_key(v))
                                  for k, v in obj.items())))
    if isinstance(obj, (set, frozenset)):
        return ("s", tuple(sorted(map(structural_key, obj), key=repr)))
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        cls = type(obj)
        return ("dc", f"{cls.__module__}.{cls.__qualname__}",
                tuple((f.name, structural_key(getattr(obj, f.name)))
                      for f in dataclasses.fields(obj)))
    if isinstance(obj, functools.partial):
        return ("p", structural_key(obj.func), structural_key(obj.args),
                structural_key(obj.keywords))
    if isinstance(obj, types.MethodType):
        return ("m", structural_key(obj.__func__),
                structural_key(obj.__self__))
    if isinstance(obj, types.CodeType):
        return ("c", obj.co_code, obj.co_names, obj.co_varnames,
                obj.co_argcount, structural_key(obj.co_consts))
    if isinstance(obj, types.FunctionType):
        cells = ()
        if obj.__closure__:
            vals = []
            for cell in obj.__closure__:
                try:
                    vals.append(structural_key(cell.cell_contents))
                except ValueError:          # empty cell
                    vals.append(("empty",))
            cells = tuple(vals)
        return ("f", structural_key(obj.__code__), cells,
                structural_key(obj.__defaults__))
    try:
        import numpy as np
        arr = np.asarray(obj)
        if arr.dtype != object and arr.size <= (1 << 16):
            return ("arr", str(arr.dtype), arr.shape, arr.tobytes())
    except Exception:
        pass
    return ("id", type(obj).__qualname__, id(obj))


# ----------------------------------------------- required-column analysis
class _SpyColumns(dict):
    """Recording stand-in for ``Table.columns``: every name looked up (or
    even probed for membership) is charged to the demand set."""

    def __init__(self, seen):
        super().__init__()
        self._seen = seen

    def __getitem__(self, name):
        self._seen.add(name)
        return np.zeros((1,), np.float64)

    def __contains__(self, name):
        self._seen.add(name)
        return True

    def get(self, name, default=None):
        self._seen.add(name)
        return np.zeros((1,), np.float64)


class _ColumnSpy:
    """One-row numpy stand-in Table fed to a Select predicate / Map column
    function to RECORD which columns it reads.  Mirrors the read-only
    Table surface predicates use (``t["col"]``, ``t.columns``,
    ``t.prob`` / ``t.valid`` / ``t.masked_prob()``); anything it cannot
    stand in for raises out to the analyser, which then gives up on
    pruning (ship every column) rather than under-approximate."""

    def __init__(self):
        self.seen: set = set()
        self.columns = _SpyColumns(self.seen)
        self.prob = np.full((1,), 0.5, np.float64)
        self.valid = np.ones((1,), bool)
        self.part = None
        self.capacity = 1

    def __getitem__(self, name):
        return self.columns[name]

    def masked_prob(self):
        return np.where(self.valid, self.prob, 0.0)


def _callable_columns(fn) -> frozenset | None:
    """The column names a predicate / column function reads, discovered
    by EXECUTING it against a recording :class:`_ColumnSpy`; ``None``
    when the callable cannot be analysed (data-dependent control flow,
    exotic Table use) — the caller must then ship every column.  Over-
    approximation (a name probed but never used) only costs bytes;
    under-approximation would be a correctness bug, hence the blanket
    except."""
    from . import plans as L
    spy = _ColumnSpy()
    try:
        if isinstance(fn, L.Parameterized):
            fn.fn(spy, *(np.float64(0.5) for _ in fn.params))
        else:
            fn(spy)
    except Exception:
        return None
    return frozenset(spy.seen)


def required_scan_columns(root) -> dict:
    """Per-base-scan required-column demand of a logical plan: map
    ``id(Scan node) -> frozenset`` of the column names the plan above it
    reads (``None`` = analysis failed, ship everything).  Walked
    top-down with the downstream demand in hand:

    * Select adds its predicate's reads;
    * Map satisfies the demand for its defined column and adds its
      function's reads;
    * FKJoin's probe side needs the downstream demand minus the fetched
      build columns, plus the probe key; the build side needs its key
      plus the fetched columns;
    * aggregations reset the demand to their group keys + value /
      carry / threshold columns (the plan above an aggregation reads
      group-level output, not scan columns).

    prob/valid always ride the slabs and are not tracked here."""
    from . import plans as L
    out: dict = {}

    def note(scan, need):
        prev = out.get(id(scan), frozenset())
        out[id(scan)] = None if (need is None or prev is None) \
            else prev | need

    def walk(node, need):
        if isinstance(node, L.Scan):
            note(node, need)
        elif isinstance(node, L.Select):
            cols = _callable_columns(node.pred)
            walk(node.child, None if (need is None or cols is None)
                 else need | cols)
        elif isinstance(node, L.Map):
            cols = _callable_columns(node.fn)
            walk(node.child, None if (need is None or cols is None)
                 else (need - {node.name}) | cols)
        elif isinstance(node, L.FKJoin):
            rc = frozenset(node.right_cols)
            walk(node.left, None if need is None
                 else (need - rc) | {node.left_key})
            walk(node.right, frozenset((node.right_key,)) | rc)
        elif isinstance(node, L.Project):
            walk(node.child, frozenset(node.keys))
        elif isinstance(node, L.GroupAgg):
            specs = ((node.value,),) + tuple((e[1],) for e in node.extra)
            vals = {v for (v,) in specs if v}
            walk(node.child, frozenset(node.keys) | vals)
        elif isinstance(node, L.ReweightGreater):
            need_c = set(node.keys) | {node.value} | set(node.carry_cols)
            if node.threshold_col:
                need_c.add(node.threshold_col)
            walk(node.child, frozenset(need_c))
        else:
            # Unknown node: every column of every scan below it.
            for f in ("child", "left", "right"):
                c = getattr(node, f, None)
                if isinstance(c, L.Node):
                    walk(c, None)

    walk(root, None)
    return out


def bucket_capacity(local_rows: int, n_shards: int, slack: float) -> int:
    """Static per-(sender, owner) shuffle bucket rows: ``slack`` times the
    uniform share, capped at the sender's local rows (at which point
    overflow is impossible) and floored at 1."""
    return max(1, min(local_rows,
                      int(math.ceil(local_rows * slack / n_shards))))


def concrete_bucket_capacity(table, key: str, n_shards: int) -> int | None:
    """Skew-adaptive static bucket rows: the max per-(sender, owner)
    demand of the ACTUAL ``key % n_shards`` histogram of a base table's
    (shard-padded) key column, so heavy hitters get exactly the capacity
    they need instead of the uniform ``slack`` tax — and overflow is
    impossible, because downstream selection can only shrink the demand.
    Returns None when the column is traced (jit compiles keep the slack
    sizing and its overflow-NaN guard) or absent.

    The histogram is sized by the table's LOGICAL ``capacity``, not the
    stored array length — a virtually padded :class:`HostTable` keeps
    its stored rows and records the pad separately, and pad rows are
    invalid (they route nowhere), so only stored rows that land in a
    shard's slot range are counted."""
    from .operators import _is_concrete
    col = None if table is None else table.columns.get(key)
    if col is None or not (_is_concrete(col) and _is_concrete(table.valid)):
        return None
    k = np.asarray(col)
    ok = np.asarray(table.valid)
    cap = table.capacity
    if k.ndim != 1 or cap % n_shards:
        return None
    local = cap // n_shards
    stored = k.shape[0]
    # Mirror the runtime routing exactly (dist.shuffle_by_key hashes the
    # int32-CAST key): a wider key must wrap the same way here, or the
    # histogram would count a different owner than the exchange uses.
    # sender = row // local over the logical (padded) row order; rows at
    # or past `stored` are virtual pad (invalid) and never counted.
    sender = np.arange(stored) // local
    dest = k.astype(np.int32) % n_shards
    pair = (sender * n_shards + dest)[ok]
    peak = 0
    if pair.size:
        peak = int(np.bincount(pair,
                               minlength=n_shards * n_shards).max())
    return max(1, peak)


def _contains_streamed(node) -> bool:
    """Does any base scan of this physical subtree stream from host?"""
    if isinstance(node, StreamedScan):
        return True
    return any(_contains_streamed(c) for c in
               (getattr(node, "child", None), getattr(node, "left", None),
                getattr(node, "right", None)) if c is not None)


def lower_plan(root, caps: dict, *, n_shards: int = 1, sharded: bool = False,
               join_gather_budget: int = 1 << 20,
               shuffle_slack: float = 4.0,
               copartition: object = "auto",
               agg_shuffle_budget: int | None = None,
               canonical_chunks: int = 8,
               model: C.CostModel | None = None,
               tables: dict | None = None,
               device_row_budget: int | None = None,
               stream_wave_chunks: int | None = None,
               stream_prune_columns: bool = True,
               bucket_floor: int | None = None) -> PhysNode:
    """Lower a logical plan to the physical IR: enumerate physical
    candidates per node, cost them with :mod:`repro.db.cost`, pick the
    cheapest.

    caps: base-table name -> global padded capacity (the compiler pads to
    the canonical chunk grid and the shard count first; golden tests may
    pass any capacities).  ``sharded`` selects mesh mode.  The budget
    knobs are cost-model overrides (see :class:`repro.db.cost.CostModel`):

    * ``join_gather_budget`` — builds over it may not gather, builds at or
      under it must (``FKJoin.gather_budget`` per-node override wins);
    * ``copartition`` — "auto" lets the estimates choose between
      ShuffleJoin + PartialAgg and the fused CoPartitionedJoin +
      PartitionedAgg pipeline (when a GROUP BY keys on the probe join
      key); True forces the fused pipeline whenever it is legal and the
      join may not gather; False disables it;
    * ``agg_shuffle_budget`` — when set, a single-key aggregation over
      more input rows must Repartition + PartitionedAgg instead of
      PartialAgg (None keeps PartialAgg, the PR-4 behaviour);
    * ``device_row_budget`` — out-of-core: a Scan whose per-shard rows
      exceed it lowers to :class:`StreamedScan` with a
      :class:`repro.db.cost.wave_schedule`-chosen wave size; subtrees
      containing a streamed scan restrict joins to GatherJoin (the
      resident build side is gathered once, each wave probes it) and
      aggregations to PartialAgg — the strategies whose per-wave
      semantics are the resident ones verbatim.  A BUILD side over the
      budget raises (only the probe side may stream);
      ``stream_wave_chunks`` pins the wave size (global chunk slots per
      wave) for tests.  ``stream_prune_columns`` (default on) runs
      :func:`required_scan_columns` over the plan and records each
      streamed scan's exact demand set on ``StreamedScan.columns`` —
      wave slabs then ship only those columns, and (when ``tables``
      reveals the full column count) the wave WIDENS so the same
      ``device_row_budget`` bytes hold more rows per slab.

    ``model`` overrides the knob-derived CostModel wholesale (pure
    estimates: ``CostModel(gather_budget=None)``).  ``canonical_chunks``
    is the compile's accumulation grid, which prices the chunked
    PartialAgg merge.  ``tables`` (the
    compiler's padded base tables) enables the skew-adaptive concrete-key
    bucket sizing of :func:`concrete_bucket_capacity`; goldens that pass
    only ``caps`` keep the deterministic slack sizing.  Pure: no table
    DATA is consumed beyond the optional key histograms.

    ``bucket_floor`` raises every slack-sized exchange bucket to at least
    this many rows (still capped at the sender's local rows, where
    overflow is impossible) — the retry controller's concrete-capacity
    escalation: re-lowering with the observed peak demand from
    ``ExecutionReport.exchange_demand`` as the floor makes the retried
    run overflow-free in one step.
    """
    from . import plans as L

    m = model if model is not None else C.CostModel(
        n_shards=n_shards, gather_budget=join_gather_budget,
        copartition=copartition, agg_shuffle_budget=agg_shuffle_budget,
        shuffle_slack=shuffle_slack, device_row_budget=device_row_budget)

    # Required-column demand per base scan (id(Scan) -> frozenset|None);
    # only computed when something may actually stream.
    scan_cols: dict = {}
    if stream_prune_columns and m.device_row_budget is not None:
        scan_cols = required_scan_columns(root)

    def pick(cands):
        """cands: [(penalty, cost, build_fn)] -> built cheapest node."""
        best = min(cands, key=lambda c: c[0] + m.total(c[1]))
        return best[2]()

    def lineage_scan(node, key):
        """The base Scan a subtree's rows (and the exchange key column)
        descend from, or None when the key is computed/fetched en route."""
        while True:
            if isinstance(node, L.Select):
                node = node.child
            elif isinstance(node, L.Map):
                if node.name == key:
                    return None
                node = node.child
            elif isinstance(node, L.FKJoin):
                if key in node.right_cols:
                    return None
                node = node.left
            else:
                break
        return node if isinstance(node, L.Scan) else None

    hist_cache: dict = {}

    def exchange_bucket(logical, key, rows):
        """Static bucket rows for hashing `logical`'s rows on `key`:
        the concrete-key histogram when available (memoized per base
        table and key — the fused enumeration prices the same exchange
        for several candidates), slack sizing else."""
        scan = lineage_scan(logical, key)
        if scan is not None and tables is not None:
            ck = (scan.name, key)
            if ck not in hist_cache:
                hist_cache[ck] = concrete_bucket_capacity(
                    tables.get(scan.name), key, n_shards)
            if hist_cache[ck] is not None:
                return hist_cache[ck]
        local_rows = -(-rows // n_shards)
        cap = bucket_capacity(local_rows, n_shards, m.shuffle_slack)
        if bucket_floor is not None:
            cap = max(cap, min(bucket_floor, local_rows))
        return cap

    def join_budget(node):
        return node.gather_budget if node.gather_budget is not None \
            else m.gather_budget

    def join_candidates(node, left, lrows, right, rrows):
        """The unfused FKJoin candidates: GatherJoin always; ShuffleJoin
        when both inputs are RowBlocked on a mesh.  Budget override: over
        budget forbids gather, at/under forbids the exchange; with the
        budget disabled (None) neither side is penalized and the pure
        estimates decide."""
        if _contains_streamed(right):
            raise NotImplementedError(
                "FK-join build side exceeds device_row_budget: only the "
                "probe side of a join may stream (raise the budget or "
                "keep the build table resident)")
        streamed = _contains_streamed(left)
        budget = join_budget(node)
        over = budget is not None and rrows > budget
        exch_pen = 0.0 if (budget is None or over) else C.INF
        w = len(node.right_cols)
        gcost = C.gather_join(m, rrows, w)
        # A streamed probe must gather: each wave re-probes the resident
        # replicated build, which is the resident semantics verbatim.
        gather_pen = 0.0 if streamed \
            else (C.INF if (sharded and over) else 0.0)
        cands = [(gather_pen, gcost,
                  lambda: GatherJoin(left, right, node.left_key,
                                     node.right_key, tuple(node.right_cols),
                                     rrows, left.part, gcost))]
        if sharded and not streamed and isinstance(left.part, RowBlocked) \
                and isinstance(right.part, RowBlocked):
            bb = exchange_bucket(node.right, node.right_key, rrows)
            pb = exchange_bucket(node.left, node.left_key, lrows)
            scost = C.shuffle_join(m, bb, pb, w)
            cands.append(
                (exch_pen, scost,
                 lambda: ShuffleJoin(left, right, node.left_key,
                                     node.right_key,
                                     tuple(node.right_cols), rrows,
                                     HashPartitioned(node.right_key),
                                     bb, pb, left.part, scost)))
        return cands

    def lower_agg(child_logical, keys, specs, max_groups, kappa, num_freq,
                  extra_cols=()):
        """Enumerate + cost the aggregation pipelines over `child_logical`
        and return the chosen PartialAgg / PartitionedAgg node.

        ``extra_cols``: non-spec columns the pass reads (reweight
        threshold / carry columns) — shipped by the fused exchanges."""
        keys = tuple(keys)
        needed = {v for _n, v, _a, _mth in specs if v}
        needed |= set(extra_cols)
        add_e, fold_e, rflops = C.agg_state_elems(specs, max_groups, kappa,
                                                  num_freq)
        chunks = canonical_chunks      # the compile's accumulation grid

        cands = []
        fusable = (sharded and isinstance(child_logical, L.FKJoin)
                   and keys == (child_logical.left_key,))
        if fusable:
            j = child_logical
            left, lrows = go(j.left)
            right, rrows = go(j.right)
            budget = join_budget(j)
            over = budget is not None and rrows > budget
            exchangeable = isinstance(left.part, RowBlocked) \
                and isinstance(right.part, RowBlocked) \
                and not (_contains_streamed(left)
                         or _contains_streamed(right))
            force = m.copartition is True and over and exchangeable
            for pen, jcost, build in join_candidates(j, left, lrows,
                                                     right, rrows):
                pcost = C.partial_agg(m, -(-lrows // n_shards),
                                      chunks, add_e, fold_e, rflops)
                def mk(build=build, pcost=pcost):
                    c = build()
                    return PartialAgg(c, keys, specs, max_groups, kappa,
                                      num_freq, c.part, pcost)
                cands.append((C.INF if force else pen, jcost + pcost, mk))
            if exchangeable and m.copartition is not False:
                right_keep = tuple(c for c in j.right_cols if c in needed)
                carry = tuple(sorted(needed - set(j.right_cols)
                                     - {j.left_key}))
                bb = exchange_bucket(j.right, j.right_key, rrows)
                pb = exchange_bucket(j.left, j.left_key, lrows)
                jcost = C.copartitioned_join(m, bb, pb, len(right_keep),
                                             len(carry))
                pcost = C.partitioned_agg(m, n_shards * pb, chunks,
                                          add_e, fold_e, rflops)

                def mk_fused(jcost=jcost, pcost=pcost, right_keep=right_keep,
                             carry=carry, bb=bb, pb=pb):
                    cj = CoPartitionedJoin(
                        left, right, j.left_key, j.right_key, right_keep,
                        carry, rrows, bb, pb,
                        HashPartitioned(j.left_key), jcost)
                    return PartitionedAgg(cj, keys, specs, max_groups,
                                          kappa, num_freq, cj.part, pcost)
                cands.append((0.0 if (budget is None or over) else C.INF,
                              jcost + pcost, mk_fused))
            return pick(cands)

        child, rows = go(child_logical)
        pcost = C.partial_agg(m, -(-rows // n_shards), chunks,
                              add_e, fold_e, rflops)
        repartable = (sharded and len(keys) == 1
                      and isinstance(child.part, RowBlocked)
                      and m.agg_shuffle_budget is not None
                      and not _contains_streamed(child))
        repart = repartable and rows > m.agg_shuffle_budget
        cands = [(C.INF if repart else 0.0, pcost,
                  lambda: PartialAgg(child, keys, specs, max_groups, kappa,
                                     num_freq, child.part, pcost))]
        if repartable:
            carry = tuple(sorted(needed - {keys[0]}))
            pb = exchange_bucket(child_logical, keys[0], rows)
            rcost = C.repartition(m, pb, len(carry))
            acost = C.partitioned_agg(m, n_shards * pb, chunks,
                                      add_e, fold_e, rflops)

            def mk_repart(pb=pb, carry=carry, rcost=rcost, acost=acost):
                rp = Repartition(child, keys[0], carry, pb,
                                 HashPartitioned(keys[0]), rcost)
                return PartitionedAgg(rp, keys, specs, max_groups, kappa,
                                      num_freq, rp.part, acost)
            cands.append((0.0 if repart else C.INF, rcost + acost,
                          mk_repart))
        return pick(cands)

    def go(node):
        """-> (phys_node, global output rows of the subtree)."""
        if isinstance(node, L.Scan):
            part = RowBlocked() if sharded else Replicated()
            rows = caps[node.name]
            budget = m.device_row_budget
            if budget is not None and -(-rows // n_shards) > budget:
                # chunk rows of the canonical grid: caps are shard-padded
                # (slots * csz) when they come from the compiler; golden
                # caps fall back to the chunk-grid division.
                slots = n_shards * (-(-canonical_chunks // n_shards))
                csz = rows // slots if rows % slots == 0 \
                    else -(-rows // canonical_chunks)
                t = None if tables is None else tables.get(node.name)
                total_cols = len(t.columns) if t is not None else None
                need = scan_cols.get(id(node)) if scan_cols else None
                if need is not None and t is not None:
                    need = frozenset(need) & set(t.columns)
                cols = None if need is None else tuple(sorted(need))
                ncols = len(cols) if cols is not None else (total_cols or 1)
                # Pruned rows are narrower: widen the wave so the same
                # byte budget (calibrated on full rows) still fills it.
                width = 1.0
                if cols is not None and total_cols:
                    width = (ncols + 2) / (total_cols + 2)
                sched = C.wave_schedule(csz, canonical_chunks, n_shards,
                                        budget, stream_wave_chunks,
                                        width=width)
                scost = C.streamed_scan(m, rows, sched.wave_rows, ncols)
                return StreamedScan(node.name, part, rows, sched, scost,
                                    cols), rows
            return ShardScan(node.name, part, rows), rows
        if isinstance(node, L.Select):
            c, rows = go(node.child)
            return PhysSelect(c, node.pred, c.part), rows
        if isinstance(node, L.Map):
            c, rows = go(node.child)
            return PhysMap(c, node.name, node.fn, c.part), rows
        if isinstance(node, L.FKJoin):
            left, lrows = go(node.left)
            right, rrows = go(node.right)
            return pick(join_candidates(node, left, lrows, right, rrows)), \
                lrows
        if isinstance(node, L.Project):
            pa = lower_agg(node.child, node.keys, (), node.max_groups,
                           64, 0)
            return MergeAgg(pa, "project"), node.max_groups
        if isinstance(node, L.GroupAgg):
            specs = ((L._out_key(node.agg, node.method), node.value,
                      node.agg, node.method),) + tuple(node.extra)
            names = [s[0] for s in specs]
            clashes = set(names) & _RESERVED_OUT_KEYS
            if clashes or len(set(names)) != len(names):
                raise ValueError(
                    f"GroupAgg aggregate names must be unique and avoid "
                    f"{sorted(_RESERVED_OUT_KEYS)}; got {names}")
            pa = lower_agg(node.child, node.keys, specs, node.max_groups,
                           node.kappa, node.num_freq)
            return MergeAgg(pa, "groupagg"), node.max_groups
        if isinstance(node, L.ReweightGreater):
            if not node.threshold_col and node.threshold is None:
                raise ValueError("ReweightGreater needs threshold_col "
                                 "or a constant threshold")
            extra = tuple(node.carry_cols)
            if node.threshold_col:
                extra += (node.threshold_col,)
            pa = lower_agg(node.child, node.keys,
                           (("sum", node.value, "SUM", "normal"),),
                           node.max_groups, 64, 0, extra_cols=extra)
            return MergeAgg(pa, "reweight", node.threshold_col,
                            node.threshold, tuple(node.carry_cols)), \
                node.max_groups
        raise TypeError(node)

    return go(root)[0]


def explain(node: PhysNode, indent: int = 0) -> str:
    """Human/golden-test-readable rendering of a physical plan; chosen
    nodes print their modeled cost (bytes moved, peak rows/device)."""
    pad = "  " * indent

    def tag(n):
        t = type(n.part).__name__ if not isinstance(n.part,
                                                    HashPartitioned) \
            else f"HashPartitioned({n.part.key})"
        c = getattr(n, "cost", None)
        return t if c is None else f"{t} cost{{{c.fmt()}}}"

    if isinstance(node, ShardScan):
        return f"{pad}ShardScan({node.name}, rows={node.rows}) :: {tag(node)}"
    if isinstance(node, StreamedScan):
        s = node.schedule
        cols = "*" if node.columns is None else ",".join(node.columns)
        return (f"{pad}StreamedScan({node.name}, rows={node.rows}, "
                f"waves={s.n_waves}x{s.chunks_per_wave}chunks"
                f"@{s.chunk_rows}rows, cols=[{cols}]) :: {tag(node)}")
    if isinstance(node, PhysSelect):
        return (f"{pad}Select :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    if isinstance(node, PhysMap):
        return (f"{pad}Map({node.name}) :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    if isinstance(node, GatherJoin):
        return (f"{pad}GatherJoin({node.left_key}={node.right_key}, "
                f"build={node.build_rows}) :: {tag(node)}\n"
                + explain(node.left, indent + 1) + "\n"
                + explain(node.right, indent + 1))
    if isinstance(node, ShuffleJoin):
        return (f"{pad}ShuffleJoin({node.left_key}={node.right_key}, "
                f"build={node.build_rows}, "
                f"exchange=HashPartitioned({node.exchange.key}), "
                f"buckets=(build={node.build_bucket}, "
                f"probe={node.probe_bucket})) :: {tag(node)}\n"
                + explain(node.left, indent + 1) + "\n"
                + explain(node.right, indent + 1))
    if isinstance(node, CoPartitionedJoin):
        return (f"{pad}CoPartitionedJoin({node.left_key}={node.right_key}, "
                f"build={node.build_rows}, "
                f"carry={list(node.carry_cols)}, "
                f"buckets=(build={node.build_bucket}, "
                f"probe={node.probe_bucket})) :: {tag(node)}\n"
                + explain(node.left, indent + 1) + "\n"
                + explain(node.right, indent + 1))
    if isinstance(node, Repartition):
        return (f"{pad}Repartition({node.key}, "
                f"carry={list(node.carry_cols)}, "
                f"bucket={node.bucket}) :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    if isinstance(node, PartialAgg):
        return (f"{pad}PartialAgg(keys={list(node.keys)}, "
                f"specs={[s[0] for s in node.specs]}, "
                f"G={node.max_groups}) :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    if isinstance(node, PartitionedAgg):
        return (f"{pad}PartitionedAgg(keys={list(node.keys)}, "
                f"specs={[s[0] for s in node.specs]}, "
                f"G={node.max_groups}) :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    if isinstance(node, MergeAgg):
        return (f"{pad}MergeAgg[{node.kind}] :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    raise TypeError(node)
