"""Physical plan IR: explicit execution strategies for the sharded
relational frontend.

``repro.db.plans.compile_plan`` used to be one 500-line recursive closure
whose distribution strategy lived in ``if mesh_mode and ...`` branches.
This module splits compilation into two stages:

    logical plan (plans.Node DAG)
        --lower_plan-->  physical plan (this module's PhysNode DAG)
        --plans executor-->  one jit-able tables -> result function

so the *strategy* — which join exchanges what, where each relation's rows
live, where aggregation state is partial vs merged — is an inspectable,
testable data structure instead of control flow (tests/test_physical.py
golden-asserts the strategies picked at each budget).

Partitioning properties
-----------------------
Every physical node carries ``part``, the placement of its output rows on
the mesh's data shards — one of three points of a small lattice:

    Replicated              every shard holds the identical full table.
                            Top of the lattice: valid input for every
                            operator, and the only property with no
                            per-device memory savings.
    RowBlocked              contiguous equal row blocks, shard s holding
                            rows [s*B, (s+1)*B) of the canonical
                            (chunk-grid padded) global row order.  The
                            O(rows/shards) workhorse; shard-major
                            concatenation IS the global row order.
    HashPartitioned(key)    row lives on shard ``key % n_shards``.  The
                            co-location property: two relations hashed on
                            their join keys can be joined shard-locally.

Exchange operators move between the points:

    all-gather   RowBlocked       -> Replicated      (dist.gather_table)
    shuffle      RowBlocked       -> HashPartitioned (dist.shuffle_by_key)
    shuffle home HashPartitioned  -> RowBlocked      (responses routed back
                                                      through the same
                                                      static send buckets)

Node zoo (the executor in plans.py interprets these inside shard_map):

    ShardScan(name)                  base table; RowBlocked on a mesh,
                                     Replicated single-device
    PhysSelect / PhysMap             elementwise on the local block;
                                     preserve the child's partitioning
    GatherJoin(l, r, ...)            broadcast FK join: build side
                                     all-gathered to Replicated (a no-op
                                     when it already is), probe local
    ShuffleJoin(l, r, ...)           hash-partitioned FK join: build rows
                                     shuffled to HashPartitioned(right_key)
                                     owners, probe keys shuffled to the
                                     same owners as requests, matched
                                     shard-locally, responses shuffled home
                                     — output stays RowBlocked and
                                     bit-identical to GatherJoin, with
                                     O(build/shards) peak build rows/device
    PartialAgg(child, keys, specs)   per-shard, per-canonical-chunk UDA
                                     Accumulate over the local tuples;
                                     output = partitioned partial states
    MergeAgg(partial, kind)          ONE collective per aggregation pass
                                     assembling every canonical chunk
                                     state, the shard-count-invariant
                                     tree fold, and the replicated
                                     Finalize; kind selects the epilogue
                                     (groupagg dict / project Table /
                                     reweight Table)

Join strategy choice (the lowering pass): an FKJoin whose build-side
capacity exceeds ``join_gather_budget`` (the per-node override first, then
the compile_plan global) lowers to ShuffleJoin whenever both inputs are
RowBlocked; everything else — small builds, single-device compiles,
replicated inputs (e.g. group-level tables) — lowers to GatherJoin.  There
is no replicated-subtree fallback anymore: every base table is fed
row-partitioned.

ShuffleJoin bucket capacities are static (XLA shapes): each shard sends at
most ``*_bucket`` rows to each owner, ``ceil(local_rows * slack /
n_shards)`` capped at ``local_rows``.  With ``slack >= n_shards`` overflow
is impossible; below that a skewed key distribution can overflow a bucket,
which is *accounted* (dropped rows are counted, the count is psum-shared,
and the executor poisons the join output probabilities with NaN, which
every probabilistic epilogue propagates — see ``dist.shuffle_fk_join``
for the boolean-consumer caveat and how to make overflow impossible).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable


# ---------------------------------------------------------------- properties
@dataclasses.dataclass(frozen=True)
class Replicated:
    """Every shard holds the identical full table."""


@dataclasses.dataclass(frozen=True)
class RowBlocked:
    """Contiguous equal row blocks of the canonical global row order."""


@dataclasses.dataclass(frozen=True)
class HashPartitioned:
    """Row lives on shard ``key % n_shards`` (key = this column)."""
    key: str


# ---------------------------------------------------------------------- IR
class PhysNode:
    pass


@dataclasses.dataclass(frozen=True)
class ShardScan(PhysNode):
    name: str
    part: object
    rows: int              # global (padded) capacity of the base table


@dataclasses.dataclass(frozen=True)
class PhysSelect(PhysNode):
    child: PhysNode
    pred: Callable
    part: object


@dataclasses.dataclass(frozen=True)
class PhysMap(PhysNode):
    child: PhysNode
    name: str
    fn: Callable
    part: object


@dataclasses.dataclass(frozen=True)
class GatherJoin(PhysNode):
    left: PhysNode
    right: PhysNode
    left_key: str
    right_key: str
    right_cols: tuple
    build_rows: int        # global capacity of the build side
    part: object           # = left.part


@dataclasses.dataclass(frozen=True)
class ShuffleJoin(PhysNode):
    left: PhysNode
    right: PhysNode
    left_key: str
    right_key: str
    right_cols: tuple
    build_rows: int
    exchange: HashPartitioned   # intermediate placement of both sides
    build_bucket: int           # static per-(sender, owner) bucket rows
    probe_bucket: int
    part: object                # = left.part (responses shuffled home)


@dataclasses.dataclass(frozen=True)
class PartialAgg(PhysNode):
    child: PhysNode
    keys: tuple
    specs: tuple           # ((name, value_col, agg, method), ...)
    max_groups: int
    kappa: int
    num_freq: int
    part: object           # = child.part (states partial per shard)


@dataclasses.dataclass(frozen=True)
class MergeAgg(PhysNode):
    child: PartialAgg
    kind: str              # groupagg | project | reweight
    threshold_col: str = ""
    threshold: float | None = None
    carry_cols: tuple = ()
    part: object = Replicated()


_RESERVED_OUT_KEYS = frozenset({"valid", "keys", "confidence"})


def bucket_capacity(local_rows: int, n_shards: int, slack: float) -> int:
    """Static per-(sender, owner) shuffle bucket rows: ``slack`` times the
    uniform share, capped at the sender's local rows (at which point
    overflow is impossible) and floored at 1."""
    return max(1, min(local_rows,
                      int(math.ceil(local_rows * slack / n_shards))))


def lower_plan(root, caps: dict, *, n_shards: int = 1, sharded: bool = False,
               join_gather_budget: int = 1 << 20,
               shuffle_slack: float = 4.0) -> PhysNode:
    """Lower a logical plan to the physical IR.

    caps: base-table name -> global padded capacity (the compiler pads to
    the canonical chunk grid and the shard count first; golden tests may
    pass any capacities).  ``sharded`` selects mesh mode: scans become
    RowBlocked and join strategies are chosen against
    ``join_gather_budget`` — an ``FKJoin.gather_budget`` override wins
    over the global.  Pure: no tables are touched.
    """
    from . import plans as L

    def go(node):
        """-> (phys_node, global output rows of the subtree)."""
        if isinstance(node, L.Scan):
            part = RowBlocked() if sharded else Replicated()
            return ShardScan(node.name, part, caps[node.name]), \
                caps[node.name]
        if isinstance(node, L.Select):
            c, rows = go(node.child)
            return PhysSelect(c, node.pred, c.part), rows
        if isinstance(node, L.Map):
            c, rows = go(node.child)
            return PhysMap(c, node.name, node.fn, c.part), rows
        if isinstance(node, L.FKJoin):
            left, lrows = go(node.left)
            right, rrows = go(node.right)
            budget = node.gather_budget if node.gather_budget is not None \
                else join_gather_budget
            if sharded and rrows > budget \
                    and isinstance(left.part, RowBlocked) \
                    and isinstance(right.part, RowBlocked):
                bb = bucket_capacity(-(-rrows // n_shards), n_shards,
                                     shuffle_slack)
                pb = bucket_capacity(-(-lrows // n_shards), n_shards,
                                     shuffle_slack)
                return ShuffleJoin(
                    left, right, node.left_key, node.right_key,
                    tuple(node.right_cols), rrows,
                    HashPartitioned(node.right_key), bb, pb,
                    left.part), lrows
            return GatherJoin(left, right, node.left_key, node.right_key,
                              tuple(node.right_cols), rrows, left.part), \
                lrows
        if isinstance(node, L.Project):
            c, _ = go(node.child)
            pa = PartialAgg(c, tuple(node.keys), (), node.max_groups,
                            64, 0, c.part)
            return MergeAgg(pa, "project"), node.max_groups
        if isinstance(node, L.GroupAgg):
            c, _ = go(node.child)
            specs = ((L._out_key(node.agg, node.method), node.value,
                      node.agg, node.method),) + tuple(node.extra)
            names = [s[0] for s in specs]
            clashes = set(names) & _RESERVED_OUT_KEYS
            if clashes or len(set(names)) != len(names):
                raise ValueError(
                    f"GroupAgg aggregate names must be unique and avoid "
                    f"{sorted(_RESERVED_OUT_KEYS)}; got {names}")
            pa = PartialAgg(c, tuple(node.keys), specs, node.max_groups,
                            node.kappa, node.num_freq, c.part)
            return MergeAgg(pa, "groupagg"), node.max_groups
        if isinstance(node, L.ReweightGreater):
            if not node.threshold_col and node.threshold is None:
                raise ValueError("ReweightGreater needs threshold_col "
                                 "or a constant threshold")
            c, _ = go(node.child)
            pa = PartialAgg(c, tuple(node.keys),
                            (("sum", node.value, "SUM", "normal"),),
                            node.max_groups, 64, 0, c.part)
            return MergeAgg(pa, "reweight", node.threshold_col,
                            node.threshold, tuple(node.carry_cols)), \
                node.max_groups
        raise TypeError(node)

    return go(root)[0]


def explain(node: PhysNode, indent: int = 0) -> str:
    """Human/golden-test-readable rendering of a physical plan."""
    pad = "  " * indent

    def tag(n):
        return type(n.part).__name__ if not isinstance(n.part,
                                                       HashPartitioned) \
            else f"HashPartitioned({n.part.key})"

    if isinstance(node, ShardScan):
        return f"{pad}ShardScan({node.name}, rows={node.rows}) :: {tag(node)}"
    if isinstance(node, PhysSelect):
        return (f"{pad}Select :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    if isinstance(node, PhysMap):
        return (f"{pad}Map({node.name}) :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    if isinstance(node, GatherJoin):
        return (f"{pad}GatherJoin({node.left_key}={node.right_key}, "
                f"build={node.build_rows}) :: {tag(node)}\n"
                + explain(node.left, indent + 1) + "\n"
                + explain(node.right, indent + 1))
    if isinstance(node, ShuffleJoin):
        return (f"{pad}ShuffleJoin({node.left_key}={node.right_key}, "
                f"build={node.build_rows}, "
                f"exchange=HashPartitioned({node.exchange.key}), "
                f"buckets=(build={node.build_bucket}, "
                f"probe={node.probe_bucket})) :: {tag(node)}\n"
                + explain(node.left, indent + 1) + "\n"
                + explain(node.right, indent + 1))
    if isinstance(node, PartialAgg):
        return (f"{pad}PartialAgg(keys={list(node.keys)}, "
                f"specs={[s[0] for s in node.specs]}, "
                f"G={node.max_groups}) :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    if isinstance(node, MergeAgg):
        return (f"{pad}MergeAgg[{node.kind}] :: {tag(node)}\n"
                + explain(node.child, indent + 1))
    raise TypeError(node)
