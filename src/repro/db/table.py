"""Columnar probabilistic tables (paper Definition 3, JAX edition).

A probabilistic table is a fixed-capacity struct-of-arrays:

    columns: dict[str, (capacity,) array]   single-valued attributes
    prob:    (capacity,) float              the p column (tuple probability)
    valid:   (capacity,) bool               row liveness mask

JAX requires static shapes, so relational operators never shrink a table —
selection flips `valid` bits (the paper's Glade engine similarly streams
tuples through predicates; our mask is the vectorised equivalent), and
operators that grow rows (joins) have static output capacities.

A *deterministic* relation is the paper's gamma-embedding (§IV-E): the same
structure with prob = 1.  PGF-valued attributes (aggregation results) are
carried outside the Table as UDA states / dense PGFs by the plan layer —
1NF columns here are scalars only, matching the paper's "single valued" vs
"probability distribution" column split (§VI-C).

Sharded layout (the distributed frontend of ``db/plans.py``): a Table is
row-partitioned over a mesh's data axes as contiguous equal blocks — each
shard holds a plain Table whose arrays are its local rows, valid mask
included, so every relational operator runs unchanged on the block.
``pad_to_multiple`` grows the capacity to the compiler's canonical chunk
grid first (pad rows are invalid with p = 0, indistinguishable from absent
tuples for every operator), which makes the global row order the
concatenation of the shard blocks and keeps chunk boundaries aligned
across shard counts.

``part`` is the table's partitioning metadata: which placement the rows of
this (possibly shard-local) Table have on the mesh.  It is any hashable
marker — the physical planner (:mod:`repro.db.physical`) uses its
``Replicated`` / ``RowBlocked`` / ``HashPartitioned(key)`` properties —
carried as static pytree aux data, so functional updates and jit
boundaries preserve it and operators can assert/propagate layout without
a side table.  ``None`` means "unspecified" (plain single-device use).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    columns: Dict[str, jnp.ndarray]
    prob: jnp.ndarray
    valid: jnp.ndarray
    #: partitioning metadata (static, hashable; see module docstring).
    part: object = None

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return ((tuple(self.columns[k] for k in names), self.prob, self.valid),
                (names, self.part))

    @classmethod
    def tree_unflatten(cls, aux, children):
        cols, prob, valid = children
        return cls(dict(zip(aux[0], cols)), prob, valid, aux[1])

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_columns(cls, columns: Dict[str, jnp.ndarray],
                     prob: jnp.ndarray | None = None,
                     valid: jnp.ndarray | None = None) -> "Table":
        n = next(iter(columns.values())).shape[0]
        for k, v in columns.items():
            assert v.shape[0] == n, f"column {k} length mismatch"
        if prob is None:  # deterministic relation: gamma-embedding, p = 1
            prob = jnp.ones((n,), jnp.float32)
        if valid is None:
            valid = jnp.ones((n,), bool)
        return cls(dict(columns), prob, valid)

    # -- properties ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.prob.shape[0]

    def num_valid(self) -> jnp.ndarray:
        return jnp.sum(self.valid)

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    # -- functional updates ----------------------------------------------------
    def with_valid(self, valid: jnp.ndarray) -> "Table":
        return Table(self.columns, self.prob, valid, self.part)

    def with_prob(self, prob: jnp.ndarray) -> "Table":
        return Table(self.columns, prob, self.valid, self.part)

    def with_part(self, part) -> "Table":
        """Retag the partitioning metadata (rows untouched)."""
        return Table(self.columns, self.prob, self.valid, part)

    def with_column(self, name: str, values: jnp.ndarray) -> "Table":
        cols = dict(self.columns)
        cols[name] = values
        return Table(cols, self.prob, self.valid, self.part)

    def select_columns(self, names) -> "Table":
        return Table({k: self.columns[k] for k in names}, self.prob,
                     self.valid, self.part)

    def masked_prob(self) -> jnp.ndarray:
        """p with invalid rows zeroed — the UDA-facing view (a dead tuple is
        indistinguishable from a p = 0 tuple for every aggregate)."""
        return jnp.where(self.valid, self.prob, 0.0)

    # -- host-side materialisation (tests / demos) -----------------------------
    def to_pandas_like(self) -> dict:
        mask = np.asarray(self.valid)
        out = {k: np.asarray(v)[mask] for k, v in self.columns.items()}
        out["p"] = np.asarray(self.prob)[mask]
        return out

    def pad_to(self, capacity: int) -> "Table":
        n = self.capacity
        assert capacity >= n
        pad = capacity - n
        cols = {k: jnp.pad(v, (0, pad)) for k, v in self.columns.items()}
        return Table(cols, jnp.pad(self.prob, (0, pad)),
                     jnp.pad(self.valid, (0, pad)), self.part)

    def pad_to_multiple(self, multiple: int) -> "Table":
        """Pad with invalid p = 0 rows so `multiple` divides the capacity —
        the entry point of the plan compiler's canonical chunk grid (and
        of even row-sharding: the grid is a multiple of the shard count)."""
        return self.pad_to(-(-self.capacity // multiple) * multiple)


def concat(a: Table, b: Table) -> Table:
    keys = sorted(a.columns)
    assert keys == sorted(b.columns)
    cols = {k: jnp.concatenate([a.columns[k], b.columns[k]]) for k in keys}
    return Table(cols, jnp.concatenate([a.prob, b.prob]),
                 jnp.concatenate([a.valid, b.valid]), a.part)
