"""Columnar probabilistic tables (paper Definition 3, JAX edition).

A probabilistic table is a fixed-capacity struct-of-arrays:

    columns: dict[str, (capacity,) array]   single-valued attributes
    prob:    (capacity,) float              the p column (tuple probability)
    valid:   (capacity,) bool               row liveness mask

JAX requires static shapes, so relational operators never shrink a table —
selection flips `valid` bits (the paper's Glade engine similarly streams
tuples through predicates; our mask is the vectorised equivalent), and
operators that grow rows (joins) have static output capacities.

A *deterministic* relation is the paper's gamma-embedding (§IV-E): the same
structure with prob = 1.  PGF-valued attributes (aggregation results) are
carried outside the Table as UDA states / dense PGFs by the plan layer —
1NF columns here are scalars only, matching the paper's "single valued" vs
"probability distribution" column split (§VI-C).

Sharded layout (the distributed frontend of ``db/plans.py``): a Table is
row-partitioned over a mesh's data axes as contiguous equal blocks — each
shard holds a plain Table whose arrays are its local rows, valid mask
included, so every relational operator runs unchanged on the block.
``pad_to_multiple`` grows the capacity to the compiler's canonical chunk
grid first (pad rows are invalid with p = 0, indistinguishable from absent
tuples for every operator), which makes the global row order the
concatenation of the shard blocks and keeps chunk boundaries aligned
across shard counts.

``part`` is the table's partitioning metadata: which placement the rows of
this (possibly shard-local) Table have on the mesh.  It is any hashable
marker — the physical planner (:mod:`repro.db.physical`) uses its
``Replicated`` / ``RowBlocked`` / ``HashPartitioned(key)`` properties —
carried as static pytree aux data, so functional updates and jit
boundaries preserve it and operators can assert/propagate layout without
a side table.  ``None`` means "unspecified" (plain single-device use).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    columns: Dict[str, jnp.ndarray]
    prob: jnp.ndarray
    valid: jnp.ndarray
    #: partitioning metadata (static, hashable; see module docstring).
    part: object = None

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return ((tuple(self.columns[k] for k in names), self.prob, self.valid),
                (names, self.part))

    @classmethod
    def tree_unflatten(cls, aux, children):
        cols, prob, valid = children
        return cls(dict(zip(aux[0], cols)), prob, valid, aux[1])

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_columns(cls, columns: Dict[str, jnp.ndarray],
                     prob: jnp.ndarray | None = None,
                     valid: jnp.ndarray | None = None) -> "Table":
        n = next(iter(columns.values())).shape[0]
        for k, v in columns.items():
            assert v.shape[0] == n, f"column {k} length mismatch"
        if prob is None:  # deterministic relation: gamma-embedding, p = 1
            prob = jnp.ones((n,), jnp.float32)
        if valid is None:
            valid = jnp.ones((n,), bool)
        return cls(dict(columns), prob, valid)

    # -- properties ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.prob.shape[0]

    def num_valid(self) -> jnp.ndarray:
        return jnp.sum(self.valid)

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    # -- functional updates ----------------------------------------------------
    def with_valid(self, valid: jnp.ndarray) -> "Table":
        return Table(self.columns, self.prob, valid, self.part)

    def with_prob(self, prob: jnp.ndarray) -> "Table":
        return Table(self.columns, prob, self.valid, self.part)

    def with_part(self, part) -> "Table":
        """Retag the partitioning metadata (rows untouched)."""
        return Table(self.columns, self.prob, self.valid, part)

    def with_column(self, name: str, values: jnp.ndarray) -> "Table":
        cols = dict(self.columns)
        cols[name] = values
        return Table(cols, self.prob, self.valid, self.part)

    def select_columns(self, names) -> "Table":
        return Table({k: self.columns[k] for k in names}, self.prob,
                     self.valid, self.part)

    def masked_prob(self) -> jnp.ndarray:
        """p with invalid rows zeroed — the UDA-facing view (a dead tuple is
        indistinguishable from a p = 0 tuple for every aggregate)."""
        return jnp.where(self.valid, self.prob, 0.0)

    # -- host-side materialisation (tests / demos) -----------------------------
    def to_pandas_like(self) -> dict:
        mask = np.asarray(self.valid)
        out = {k: np.asarray(v)[mask] for k, v in self.columns.items()}
        out["p"] = np.asarray(self.prob)[mask]
        return out

    def pad_to(self, capacity: int) -> "Table":
        n = self.capacity
        assert capacity >= n
        if capacity == n:               # already there: no copy, no new pytree
            return self
        pad = capacity - n
        cols = {k: jnp.pad(v, (0, pad)) for k, v in self.columns.items()}
        return Table(cols, jnp.pad(self.prob, (0, pad)),
                     jnp.pad(self.valid, (0, pad)), self.part)

    #: chunk-grid cache: the last `multiple` this table was padded to (a
    #: plain instance attribute, NOT pytree data — it is a memo, lost on
    #: functional updates, which only costs a re-check).
    _chunk_multiple: int = dataclasses.field(default=0, compare=False,
                                             repr=False)

    def pad_to_multiple(self, multiple: int) -> "Table":
        """Pad with invalid p = 0 rows so `multiple` divides the capacity —
        the entry point of the plan compiler's canonical chunk grid (and
        of even row-sharding: the grid is a multiple of the shard count).
        A table already on the grid is returned as-is (the canonical chunk
        count is cached on the instance, so repeated ``compile_plan``
        calls — and every per-wave slab of the streamed executor — skip
        the re-pad entirely)."""
        if self._chunk_multiple == multiple:
            return self
        out = self.pad_to(-(-self.capacity // multiple) * multiple)
        out._chunk_multiple = multiple
        return out


def _cut(a, start: int, rows: int, out=None):
    """Copy rows ``[start, start + rows)`` of host array ``a`` into a
    contiguous buffer, zero-filling past the stored length (the virtual
    pad / wave-schedule tail: pad rows are invalid with p = 0, so zeros
    are exactly ``np.pad`` semantics).  With ``out`` the copy lands in
    the caller's preallocated buffer via ``np.copyto`` — no allocation,
    the ping-pong half of the zero-alloc slab assembly."""
    stop = min(start + rows, a.shape[0])
    got = max(0, stop - start)
    if out is None:
        if got == rows:
            return np.ascontiguousarray(a[start:stop])
        buf = np.zeros((rows,) + a.shape[1:], a.dtype)
        if got:
            buf[:got] = a[start:stop]
        return buf
    if got:
        np.copyto(out[:got], a[start:stop])
    if got < rows:
        out[got:rows] = 0
    return out


class HostTable:
    """Host-resident probabilistic table: the out-of-core twin of
    :class:`Table`.

    Columns, prob and valid are kept as host ``numpy`` arrays (or
    ``np.memmap`` views of on-disk column files, see :meth:`save` /
    :meth:`open`) and are NEVER shipped to the device whole — the
    streamed executor of ``db/plans.py`` ships one
    canonical-chunk-aligned *slab* of rows per wave (:meth:`slab`) and
    folds the per-chunk UDA states across waves, so device residency is
    two slabs (double-buffered) plus the group-level accumulator,
    independent of the table size.

    Padding is VIRTUAL: :meth:`pad_to` records extra capacity instead of
    copying every column (``columns`` / ``prob`` / ``valid`` keep the
    stored arrays; ``capacity``, the slab cutters and :meth:`to_table`
    present the padded view, materialising the invalid p = 0 pad rows as
    zeros on read).  This is what lets a terabyte-scale memory-mapped
    table be chunk-grid-padded without touching the disk.

    Deliberately NOT a pytree: a HostTable must never cross a jit
    boundary.  It mirrors the small read-only surface the planner needs
    (``columns`` / ``prob`` / ``valid`` / ``capacity``), so the concrete
    key histograms of ``physical.concrete_bucket_capacity`` work on it
    unchanged.
    """

    def __init__(self, columns, prob=None, valid=None, part=None, pad=0):
        # keep ndarray instances as-is (np.asarray would strip the
        # np.memmap subclass of disk-backed columns); coerce the rest
        asarr = lambda v: v if isinstance(v, np.ndarray) else np.asarray(v)
        self.columns = {k: asarr(v) for k, v in columns.items()}
        if self.columns:
            n = next(iter(self.columns.values())).shape[0]
        else:       # column-pruned to nothing (pure COUNT): p/valid only
            assert prob is not None, "empty HostTable needs prob"
            n = np.asarray(prob).shape[0]
        for k, v in self.columns.items():
            assert v.shape[0] == n, f"column {k} length mismatch"
        self.prob = (np.ones((n,), np.float32) if prob is None
                     else asarr(prob))
        self.valid = (np.ones((n,), bool) if valid is None
                      else asarr(valid))
        self.part = part
        self._pad = int(pad)
        self._chunk_multiple = 0

    @classmethod
    def from_table(cls, t: Table) -> "HostTable":
        """Pull a (device) Table to host memory."""
        return cls({k: np.asarray(v) for k, v in t.columns.items()},
                   np.asarray(t.prob), np.asarray(t.valid), t.part)

    @property
    def capacity(self) -> int:
        """Logical row count: stored rows plus the virtual pad."""
        return self.prob.shape[0] + self._pad

    @property
    def stored_rows(self) -> int:
        """Physically stored rows (what :meth:`save` writes to disk)."""
        return self.prob.shape[0]

    def __getitem__(self, name: str):
        return self.columns[name]

    def pad_to(self, capacity: int) -> "HostTable":
        n = self.capacity
        assert capacity >= n
        if capacity == n:
            return self
        out = HostTable(self.columns, self.prob, self.valid, self.part,
                        pad=self._pad + (capacity - n))
        return out

    def pad_to_multiple(self, multiple: int) -> "HostTable":
        """Host-side chunk-grid padding (same cache as Table's)."""
        if self._chunk_multiple == multiple:
            return self
        out = self.pad_to(-(-self.capacity // multiple) * multiple)
        out._chunk_multiple = multiple
        return out

    def select_columns(self, names) -> "HostTable":
        """Pruned view sharing the same arrays (the lowered
        ``StreamedScan.columns`` demand set: waves slice only these)."""
        out = HostTable({k: self.columns[k] for k in names}, self.prob,
                        self.valid, self.part, pad=self._pad)
        out._chunk_multiple = self._chunk_multiple
        return out

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        """Persist to ``path/``: one ``.npy`` file per column plus
        ``prob.npy`` / ``valid.npy`` and a ``manifest.json`` mapping
        column names to files (names are not trusted as filenames).
        Only stored rows hit the disk — virtual padding is recorded in
        the manifest and restored by :meth:`open` as virtual padding."""
        os.makedirs(path, exist_ok=True)
        names = sorted(self.columns)
        files = {k: f"col{i}.npy" for i, k in enumerate(names)}
        for k, fname in files.items():
            np.save(os.path.join(path, fname), np.asarray(self.columns[k]),
                    allow_pickle=False)
        np.save(os.path.join(path, "prob.npy"), np.asarray(self.prob),
                allow_pickle=False)
        np.save(os.path.join(path, "valid.npy"), np.asarray(self.valid),
                allow_pickle=False)
        manifest = {"version": 1, "capacity": int(self.capacity),
                    "stored_rows": int(self.stored_rows),
                    "columns": files, "prob": "prob.npy",
                    "valid": "valid.npy"}
        with open(os.path.join(path, "manifest.json"), "w") as fh:
            json.dump(manifest, fh, indent=1, sort_keys=True)

    @classmethod
    def open(cls, path: str, mmap_mode: str = "r") -> "HostTable":
        """Open a :meth:`save` directory with every array backed by
        ``np.memmap`` — slabs then read only the touched row ranges of
        the touched columns from disk, so dataset size decouples from
        host RAM.  Pass ``mmap_mode=None`` to load into RAM instead."""
        with open(os.path.join(path, "manifest.json")) as fh:
            manifest = json.load(fh)
        load = lambda f: np.load(os.path.join(path, f), mmap_mode=mmap_mode,
                                 allow_pickle=False)
        cols = {k: load(f) for k, f in manifest["columns"].items()}
        return cls(cols, load(manifest["prob"]), load(manifest["valid"]),
                   pad=manifest["capacity"] - manifest["stored_rows"])

    # -- slab cutters --------------------------------------------------------
    def alloc_slab(self, rows: int) -> Table:
        """Preallocated (uninitialised numpy) slab buffers matching this
        table's dtypes — the ping-pong targets of ``wave_slab(out=)``."""
        mk = lambda a: np.empty((rows,) + a.shape[1:], a.dtype)
        return Table({k: mk(v) for k, v in self.columns.items()},
                     mk(self.prob), mk(self.valid), self.part)

    def slab(self, start: int, rows: int) -> Table:
        """One wave's slab: rows [start, start + rows), zero-padded with
        invalid p = 0 rows past the stored rows (virtual pad and
        schedule tail alike), as a device-ready :class:`Table` of host
        numpy arrays (the executor ``device_put``s it with the mesh
        sharding; the copy into contiguous buffers is the host half of
        the double-buffered transfer)."""
        cut = lambda a: _cut(a, start, rows)
        return Table({k: cut(v) for k, v in self.columns.items()},
                     cut(self.prob), cut(self.valid), self.part)

    def wave_slab(self, starts, rows: int, out: Table | None = None) -> Table:
        """One MESH wave's slab: the concatenation of the per-shard runs
        ``[start, start + rows)`` for each start in ``starts`` (shard
        order).  On a mesh the rows of one wave are NOT contiguous — each
        shard contributes the next ``rows`` of ITS slot range — so the
        host gathers the strided runs into one contiguous buffer that
        ``device_put`` with the mesh sharding then splits back per
        device.  Runs past the stored rows (the virtual pad region) read
        as invalid p = 0 zeros.  With ``out`` (an :meth:`alloc_slab`
        buffer of ``len(starts) * rows`` rows) the gather is zero-alloc:
        ``np.copyto`` into the caller's ping-pong buffer."""
        def cut(a, buf):
            if buf is None:
                if len(starts) == 1:
                    return _cut(a, starts[0], rows)
                buf = np.empty((len(starts) * rows,) + a.shape[1:], a.dtype)
            for i, s in enumerate(starts):
                _cut(a, s, rows, out=buf[i * rows:(i + 1) * rows])
            return buf
        if out is None:
            return Table({k: cut(v, None) for k, v in self.columns.items()},
                         cut(self.prob, None), cut(self.valid, None),
                         self.part)
        return Table({k: cut(v, out.columns[k])
                      for k, v in self.columns.items()},
                     cut(self.prob, out.prob), cut(self.valid, out.valid),
                     self.part)

    def slabs(self, rows: int):
        """Iterate the whole table as ``ceil(capacity / rows)`` fixed-size
        slabs (the last one zero-padded) — the wave schedule's host side."""
        for start in range(0, self.capacity, rows):
            yield start, self.slab(start, rows)

    def to_table(self) -> Table:
        """Full device materialisation (resident fallback / tests) —
        virtual pad rows materialise as invalid p = 0 zeros."""
        def full(a):
            a = np.asarray(a)
            if not self._pad:
                return jnp.asarray(a)
            z = np.zeros((self._pad,) + a.shape[1:], a.dtype)
            return jnp.asarray(np.concatenate([a, z]))
        return Table({k: full(v) for k, v in self.columns.items()},
                     full(self.prob), full(self.valid), self.part)


def concat(a: Table, b: Table) -> Table:
    keys = sorted(a.columns)
    assert keys == sorted(b.columns)
    cols = {k: jnp.concatenate([a.columns[k], b.columns[k]]) for k in keys}
    return Table(cols, jnp.concatenate([a.prob, b.prob]),
                 jnp.concatenate([a.valid, b.valid]), a.part)
