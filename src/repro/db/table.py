"""Columnar probabilistic tables (paper Definition 3, JAX edition).

A probabilistic table is a fixed-capacity struct-of-arrays:

    columns: dict[str, (capacity,) array]   single-valued attributes
    prob:    (capacity,) float              the p column (tuple probability)
    valid:   (capacity,) bool               row liveness mask

JAX requires static shapes, so relational operators never shrink a table —
selection flips `valid` bits (the paper's Glade engine similarly streams
tuples through predicates; our mask is the vectorised equivalent), and
operators that grow rows (joins) have static output capacities.

A *deterministic* relation is the paper's gamma-embedding (§IV-E): the same
structure with prob = 1.  PGF-valued attributes (aggregation results) are
carried outside the Table as UDA states / dense PGFs by the plan layer —
1NF columns here are scalars only, matching the paper's "single valued" vs
"probability distribution" column split (§VI-C).

Sharded layout (the distributed frontend of ``db/plans.py``): a Table is
row-partitioned over a mesh's data axes as contiguous equal blocks — each
shard holds a plain Table whose arrays are its local rows, valid mask
included, so every relational operator runs unchanged on the block.
``pad_to_multiple`` grows the capacity to the compiler's canonical chunk
grid first (pad rows are invalid with p = 0, indistinguishable from absent
tuples for every operator), which makes the global row order the
concatenation of the shard blocks and keeps chunk boundaries aligned
across shard counts.

``part`` is the table's partitioning metadata: which placement the rows of
this (possibly shard-local) Table have on the mesh.  It is any hashable
marker — the physical planner (:mod:`repro.db.physical`) uses its
``Replicated`` / ``RowBlocked`` / ``HashPartitioned(key)`` properties —
carried as static pytree aux data, so functional updates and jit
boundaries preserve it and operators can assert/propagate layout without
a side table.  ``None`` means "unspecified" (plain single-device use).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    columns: Dict[str, jnp.ndarray]
    prob: jnp.ndarray
    valid: jnp.ndarray
    #: partitioning metadata (static, hashable; see module docstring).
    part: object = None

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return ((tuple(self.columns[k] for k in names), self.prob, self.valid),
                (names, self.part))

    @classmethod
    def tree_unflatten(cls, aux, children):
        cols, prob, valid = children
        return cls(dict(zip(aux[0], cols)), prob, valid, aux[1])

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_columns(cls, columns: Dict[str, jnp.ndarray],
                     prob: jnp.ndarray | None = None,
                     valid: jnp.ndarray | None = None) -> "Table":
        n = next(iter(columns.values())).shape[0]
        for k, v in columns.items():
            assert v.shape[0] == n, f"column {k} length mismatch"
        if prob is None:  # deterministic relation: gamma-embedding, p = 1
            prob = jnp.ones((n,), jnp.float32)
        if valid is None:
            valid = jnp.ones((n,), bool)
        return cls(dict(columns), prob, valid)

    # -- properties ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.prob.shape[0]

    def num_valid(self) -> jnp.ndarray:
        return jnp.sum(self.valid)

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    # -- functional updates ----------------------------------------------------
    def with_valid(self, valid: jnp.ndarray) -> "Table":
        return Table(self.columns, self.prob, valid, self.part)

    def with_prob(self, prob: jnp.ndarray) -> "Table":
        return Table(self.columns, prob, self.valid, self.part)

    def with_part(self, part) -> "Table":
        """Retag the partitioning metadata (rows untouched)."""
        return Table(self.columns, self.prob, self.valid, part)

    def with_column(self, name: str, values: jnp.ndarray) -> "Table":
        cols = dict(self.columns)
        cols[name] = values
        return Table(cols, self.prob, self.valid, self.part)

    def select_columns(self, names) -> "Table":
        return Table({k: self.columns[k] for k in names}, self.prob,
                     self.valid, self.part)

    def masked_prob(self) -> jnp.ndarray:
        """p with invalid rows zeroed — the UDA-facing view (a dead tuple is
        indistinguishable from a p = 0 tuple for every aggregate)."""
        return jnp.where(self.valid, self.prob, 0.0)

    # -- host-side materialisation (tests / demos) -----------------------------
    def to_pandas_like(self) -> dict:
        mask = np.asarray(self.valid)
        out = {k: np.asarray(v)[mask] for k, v in self.columns.items()}
        out["p"] = np.asarray(self.prob)[mask]
        return out

    def pad_to(self, capacity: int) -> "Table":
        n = self.capacity
        assert capacity >= n
        if capacity == n:               # already there: no copy, no new pytree
            return self
        pad = capacity - n
        cols = {k: jnp.pad(v, (0, pad)) for k, v in self.columns.items()}
        return Table(cols, jnp.pad(self.prob, (0, pad)),
                     jnp.pad(self.valid, (0, pad)), self.part)

    #: chunk-grid cache: the last `multiple` this table was padded to (a
    #: plain instance attribute, NOT pytree data — it is a memo, lost on
    #: functional updates, which only costs a re-check).
    _chunk_multiple: int = dataclasses.field(default=0, compare=False,
                                             repr=False)

    def pad_to_multiple(self, multiple: int) -> "Table":
        """Pad with invalid p = 0 rows so `multiple` divides the capacity —
        the entry point of the plan compiler's canonical chunk grid (and
        of even row-sharding: the grid is a multiple of the shard count).
        A table already on the grid is returned as-is (the canonical chunk
        count is cached on the instance, so repeated ``compile_plan``
        calls — and every per-wave slab of the streamed executor — skip
        the re-pad entirely)."""
        if self._chunk_multiple == multiple:
            return self
        out = self.pad_to(-(-self.capacity // multiple) * multiple)
        out._chunk_multiple = multiple
        return out


class HostTable:
    """Host-resident probabilistic table: the out-of-core twin of
    :class:`Table`.

    Columns, prob and valid are kept as host ``numpy`` arrays and are
    NEVER shipped to the device whole — the streamed executor of
    ``db/plans.py`` ships one canonical-chunk-aligned *slab* of rows per
    wave (:meth:`slab`) and folds the per-chunk UDA states across waves,
    so device residency is two slabs (double-buffered) plus the
    group-level accumulator, independent of the table size.

    Deliberately NOT a pytree: a HostTable must never cross a jit
    boundary.  It mirrors the small read-only surface the planner needs
    (``columns`` / ``prob`` / ``valid`` / ``capacity``), so the concrete
    key histograms of ``physical.concrete_bucket_capacity`` work on it
    unchanged.
    """

    def __init__(self, columns, prob=None, valid=None, part=None):
        self.columns = {k: np.asarray(v) for k, v in columns.items()}
        n = next(iter(self.columns.values())).shape[0]
        for k, v in self.columns.items():
            assert v.shape[0] == n, f"column {k} length mismatch"
        self.prob = (np.ones((n,), np.float32) if prob is None
                     else np.asarray(prob))
        self.valid = (np.ones((n,), bool) if valid is None
                      else np.asarray(valid))
        self.part = part
        self._chunk_multiple = 0

    @classmethod
    def from_table(cls, t: Table) -> "HostTable":
        """Pull a (device) Table to host memory."""
        return cls({k: np.asarray(v) for k, v in t.columns.items()},
                   np.asarray(t.prob), np.asarray(t.valid), t.part)

    @property
    def capacity(self) -> int:
        return self.prob.shape[0]

    def __getitem__(self, name: str):
        return self.columns[name]

    def pad_to(self, capacity: int) -> "HostTable":
        n = self.capacity
        assert capacity >= n
        if capacity == n:
            return self
        pad = capacity - n
        cols = {k: np.pad(v, (0, pad)) for k, v in self.columns.items()}
        return HostTable(cols, np.pad(self.prob, (0, pad)),
                         np.pad(self.valid, (0, pad)), self.part)

    def pad_to_multiple(self, multiple: int) -> "HostTable":
        """Host-side chunk-grid padding (same cache as Table's)."""
        if self._chunk_multiple == multiple:
            return self
        out = self.pad_to(-(-self.capacity // multiple) * multiple)
        out._chunk_multiple = multiple
        return out

    def slab(self, start: int, rows: int) -> Table:
        """One wave's slab: rows [start, start + rows), zero-padded with
        invalid p = 0 rows past the capacity, as a device-ready
        :class:`Table` of host numpy arrays (the executor ``device_put``s
        it with the mesh sharding; the copy into fresh contiguous buffers
        is the host half of the double-buffered transfer)."""
        stop = min(start + rows, self.capacity)
        pad = rows - (stop - start)

        def cut(a):
            s = a[start:stop]
            return np.pad(s, ((0, pad),) + ((0, 0),) * (s.ndim - 1)) \
                if pad else np.ascontiguousarray(s)
        return Table({k: cut(v) for k, v in self.columns.items()},
                     cut(self.prob), cut(self.valid), self.part)

    def wave_slab(self, starts, rows: int) -> Table:
        """One MESH wave's slab: the concatenation of the per-shard runs
        ``[start, start + rows)`` for each start in ``starts`` (shard
        order).  On a mesh the rows of one wave are NOT contiguous — each
        shard contributes the next ``rows`` of ITS slot range — so the
        host gathers the strided runs into one contiguous buffer that
        ``device_put`` with the mesh sharding then splits back per
        device.  The table must already be padded to the wave schedule's
        ``padded_capacity`` (no tail handling here)."""
        def cut(a):
            if len(starts) == 1:
                return np.ascontiguousarray(a[starts[0]:starts[0] + rows])
            return np.concatenate([a[s:s + rows] for s in starts])
        return Table({k: cut(v) for k, v in self.columns.items()},
                     cut(self.prob), cut(self.valid), self.part)

    def slabs(self, rows: int):
        """Iterate the whole table as ``ceil(capacity / rows)`` fixed-size
        slabs (the last one zero-padded) — the wave schedule's host side."""
        for start in range(0, self.capacity, rows):
            yield start, self.slab(start, rows)

    def to_table(self) -> Table:
        """Full device materialisation (resident fallback / tests)."""
        return Table({k: jnp.asarray(v) for k, v in self.columns.items()},
                     jnp.asarray(self.prob), jnp.asarray(self.valid),
                     self.part)


def concat(a: Table, b: Table) -> Table:
    keys = sorted(a.columns)
    assert keys == sorted(b.columns)
    cols = {k: jnp.concatenate([a.columns[k], b.columns[k]]) for k in keys}
    return Table(cols, jnp.concatenate([a.prob, b.prob]),
                 jnp.concatenate([a.valid, b.valid]), a.part)
