"""Version-tolerant shims over the moving parts of the JAX API.

The repo targets whatever jax the container bakes in; the three surfaces
that have churned across 0.4.x -> 0.5+ are wrapped here once so every
other module (and the tests) can import them from a single place:

    shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)
        `jax.shard_map` when it exists, else
        `jax.experimental.shard_map.shard_map`; the replication-check
        kwarg is renamed (check_vma <-> check_rep) as needed.

    make_mesh(shape, axis_names)
        `jax.make_mesh`, passing `axis_types=(AxisType.Auto, ...)` only
        on versions that accept it (explicit-sharding-era jax).

    AxisType
        the real `jax.sharding.AxisType` when present, else a stand-in
        enum so call sites can spell `AxisType.Auto` unconditionally.
"""
from __future__ import annotations

import enum
import inspect
from typing import Sequence

import jax

__all__ = ["AxisType", "make_mesh", "shard_map"]


# ----------------------------------------------------------------- shard_map
try:  # jax >= 0.6-ish: top-level export with check_vma
    from jax import shard_map as _shard_map
except ImportError:  # 0.4.x: experimental module with check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """`shard_map` with the replication-check kwarg translated per version."""
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
        # else: the installed jax has no replication check knob; drop it.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


# ------------------------------------------------------------------- meshes
if hasattr(jax.sharding, "AxisType"):
    AxisType = jax.sharding.AxisType
else:
    class AxisType(enum.Enum):  # stand-in: pre-explicit-sharding jax
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              **kwargs):
    """`jax.make_mesh` with Auto axis types where the API supports them."""
    if "axis_types" in _MAKE_MESH_PARAMS and "axis_types" not in kwargs \
            and hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
