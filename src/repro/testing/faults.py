"""Deterministic fault injection for the execution engine.

The streamed executor (``db/plans.py::_streamed_exec``) and the shuffle
exchange (``db/distributed.py::shuffle_by_key``) call the module-level
hooks below at their host-visible failure points:

    on_transfer(wave, rows)   before every host→device wave transfer
                              (``jax.device_put`` of one slab)
    on_exchange()             at every ``shuffle_by_key`` trace — the
                              collective-launch stand-in (the exchange
                              itself runs inside shard_map, so trace
                              time is the only host-visible point)

With no plan installed both hooks are no-ops (one attribute read — the
production cost of the harness).  Tests install a :class:`FaultPlan`
with :func:`inject` to fail chosen occurrences deterministically:

    with faults.inject(faults.FaultPlan(transfer_calls={5})) as fp:
        result = compiled(tables)       # 6th transfer raises once
    assert fp.consumed()                # the fault actually fired

Every injected failure raises :class:`TransferFault`.  The wave loop
resumes the failed wave from the ``ChunkStateAccumulator`` checkpoint
(completed waves are never re-streamed — assert on ``fp.log``); a fault
that exhausts the in-loop retries propagates annotated with the wave
size (``wave_chunks``) so :class:`repro.db.plans.RetryPolicy` can
re-lower with a halved wave.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterable


class TransferFault(RuntimeError):
    """An injected (or, in principle, real) host↔device transfer /
    collective-launch failure.  When the fault escapes the streamed
    executor's in-loop wave retries it is annotated: ``wave_chunks`` is
    the HALVED wave size (global chunk slots) the retry controller
    should re-lower with, and ``at_minimum`` marks a schedule already at
    one chunk slot per shard — where no smaller wave exists and the
    fault is terminal."""

    wave_chunks: int | None = None
    at_minimum: bool = False


@dataclasses.dataclass
class FaultPlan:
    """Deterministic injection schedule.

    transfer_calls   global occurrence indices of ``on_transfer`` calls
                     (0-based, counted across phases and retries) that
                     fail ONCE each — the transient-fault model: the
                     retried transfer succeeds.
    exchange_calls   global occurrence indices of ``on_exchange`` calls
                     that fail once each.
    transfer_rows_over   when set, EVERY transfer of more than this many
                     rows fails (persistent): models a transfer too big
                     for the link, so in-loop retries can't help and
                     only a smaller wave (RetryPolicy halving) succeeds.
    """

    transfer_calls: Iterable[int] = ()
    exchange_calls: Iterable[int] = ()
    transfer_rows_over: int | None = None

    def __post_init__(self):
        self._transfer_pending = set(self.transfer_calls)
        self._exchange_pending = set(self.exchange_calls)
        self._n_transfer = 0
        self._n_exchange = 0
        #: every on_transfer call as (occurrence, wave, rows, failed) —
        #: the resume assertions read this.
        self.log: list = []

    # ------------------------------------------------------------ hooks
    def on_transfer(self, wave: int, rows: int) -> None:
        i = self._n_transfer
        self._n_transfer += 1
        fail = False
        if i in self._transfer_pending:
            self._transfer_pending.discard(i)
            fail = True
        if (self.transfer_rows_over is not None
                and rows > self.transfer_rows_over):
            fail = True
        self.log.append((i, wave, rows, fail))
        if fail:
            raise TransferFault(
                f"injected transfer fault: occurrence {i}, wave {wave}, "
                f"{rows} rows")

    def on_exchange(self) -> None:
        i = self._n_exchange
        self._n_exchange += 1
        if i in self._exchange_pending:
            self._exchange_pending.discard(i)
            raise TransferFault(f"injected exchange fault: occurrence {i}")

    def consumed(self) -> bool:
        """Every one-shot fault fired (the test exercised what it meant
        to)."""
        return not self._transfer_pending and not self._exchange_pending


#: the installed plan (None = hooks are no-ops).  Single-threaded test
#: harness state, mirroring dist.COLLECTIVE_COUNTS.
_ACTIVE: FaultPlan | None = None


def on_transfer(wave: int, rows: int) -> None:
    if _ACTIVE is not None:
        _ACTIVE.on_transfer(wave, rows)


def on_exchange() -> None:
    if _ACTIVE is not None:
        _ACTIVE.on_exchange()


@contextlib.contextmanager
def inject(plan: FaultPlan):
    """Install ``plan`` for the with-block (exclusive — nesting raises:
    overlapping schedules would race their occurrence counters)."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("a FaultPlan is already installed")
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None
