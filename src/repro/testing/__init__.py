"""Deterministic test harnesses for the execution engine (fault
injection, see :mod:`repro.testing.faults`).  Kept importable from the
hot path — the hooks are no-ops unless a plan is installed."""
