"""User-defined aggregates (paper §VI-A): Initialize / Accumulate / Merge /
Finalize over JAX pytree states.

The paper packages every probabilistic aggregate as a Glade UDA so that a
deterministic engine can run probabilistic plans.  Here the same four-phase
contract is expressed as pure functions over pytree states, which makes the
*engine* be XLA: `Accumulate` maps over locally-sharded tuple chunks,
`Merge` is an elementwise reduction that lowers to one `psum` inside
shard_map (DESIGN.md §2, Glade row of the adaptation table), and `Finalize`
is a single device (FFT) or host (mixture solve) epilogue.

Every UDA also accepts a `mask` so that fixed-shape relations with validity
masks (selection pushdown) aggregate only live tuples: a masked-out tuple is
equivalent to p = 0 for SUM/COUNT/AtLeastOne and to "not in the list" for
MIN/MAX.

Provided UDAs (paper §V / §VII):
    CountCF / SumCF         exact distributions via log-CF          (§V-A/C)
    SumCumulants            moment terms for the gamma mixture      (§V-C.3)
    SumNormal               mean/variance terms                     (§V-C.3)
    MinUDA / MaxUDA         top-kappa (value, AtLeastOne) list      (§V-B, §VII-C)
    AtLeastOne              the projection/group-confidence UDA     (§VI row V)
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import approx, poisson_binomial as pb
from .config import default_float
from .pgf import PGF


def _masked_probs(probs, mask):
    if mask is None:
        return probs
    return jnp.where(mask, probs, 0.0)


# ------------------------------------------------------------- AtLeastOne
class AtLeastOneState(NamedTuple):
    log_none: jnp.ndarray  # sum of log(1 - p) over accumulated tuples


class AtLeastOne:
    """P(at least one tuple present) = 1 - prod (1 - p_i)  (§VI row V)."""

    @staticmethod
    def init(dtype=None) -> AtLeastOneState:
        return AtLeastOneState(jnp.zeros((), dtype or default_float()))

    @staticmethod
    def accumulate(state: AtLeastOneState, probs, mask=None) -> AtLeastOneState:
        p = _masked_probs(probs, mask)
        return AtLeastOneState(state.log_none + jnp.sum(jnp.log1p(-p)))

    @staticmethod
    def merge(a: AtLeastOneState, b: AtLeastOneState) -> AtLeastOneState:
        return AtLeastOneState(a.log_none + b.log_none)

    @staticmethod
    def finalize(state: AtLeastOneState):
        return 1.0 - jnp.exp(state.log_none)


# ------------------------------------------------------------ CF exact UDAs
class CFState(NamedTuple):
    log_abs: jnp.ndarray  # (num_freq,)
    angle: jnp.ndarray    # (num_freq,)


class SumCF:
    """Exact SUM (and COUNT, with values == 1) over integer values via the
    log-characteristic-function representation.  `num_freq` = max_sum + 1 is
    the static distribution capacity, fixed at Initialize time (the JAX
    analogue of the paper's pre-sized FFT buffers)."""

    def __init__(self, num_freq: int):
        self.num_freq = int(num_freq)

    def init(self, dtype=None) -> CFState:
        dtype = dtype or default_float()
        z = jnp.zeros((self.num_freq,), dtype)
        return CFState(z, z)

    def accumulate(self, state: CFState, probs, values=None, mask=None) -> CFState:
        p = _masked_probs(probs, mask)
        v = jnp.ones_like(p) if values is None else values
        la, an = pb.logcf_terms(p, v, self.num_freq)
        return CFState(state.log_abs + la, state.angle + an)

    @staticmethod
    def merge(a: CFState, b: CFState) -> CFState:
        return CFState(a.log_abs + b.log_abs, a.angle + b.angle)

    @staticmethod
    def psum_merge(state: CFState, axis_name) -> CFState:
        return CFState(jax.lax.psum(state.log_abs, axis_name),
                       jax.lax.psum(state.angle, axis_name))

    @staticmethod
    def finalize(state: CFState) -> PGF:
        return PGF(pb.logcf_finalize(state.log_abs, state.angle), 0)


def CountCF(capacity: int) -> SumCF:
    """COUNT = SUM of T_COUNT-translated values (all ones), §IV-F step 1."""
    return SumCF(capacity + 1)


# ------------------------------------------------------- moment-based UDAs
class CumulantState(NamedTuple):
    terms: jnp.ndarray  # (2p,) partial cumulant sums


class SumCumulants:
    """Streaming cumulants for Lindsay's gamma-mixture approximation."""

    def __init__(self, p_components: int = 3):
        self.p = int(p_components)

    def init(self, dtype=None) -> CumulantState:
        return CumulantState(jnp.zeros((2 * self.p,), dtype or default_float()))

    def accumulate(self, state, probs, values=None, mask=None) -> CumulantState:
        pr = _masked_probs(probs, mask)
        v = jnp.ones_like(pr) if values is None else values
        return CumulantState(state.terms + approx.cumulant_terms(pr, v, 2 * self.p))

    @staticmethod
    def merge(a, b) -> CumulantState:
        return CumulantState(a.terms + b.terms)

    @staticmethod
    def psum_merge(state, axis_name) -> CumulantState:
        return CumulantState(jax.lax.psum(state.terms, axis_name))

    def finalize(self, state) -> approx.GammaMixture:
        return approx.fit_gamma_mixture(np.asarray(state.terms), p=self.p)


class NormalState(NamedTuple):
    terms: jnp.ndarray  # (2,) = (mean, variance) partial sums


class SumNormal:
    @staticmethod
    def init(dtype=None) -> NormalState:
        return NormalState(jnp.zeros((2,), dtype or default_float()))

    @staticmethod
    def accumulate(state, probs, values=None, mask=None) -> NormalState:
        pr = _masked_probs(probs, mask)
        v = jnp.ones_like(pr) if values is None else values
        return NormalState(state.terms + approx.normal_terms(pr, v))

    @staticmethod
    def merge(a, b) -> NormalState:
        return NormalState(a.terms + b.terms)

    @staticmethod
    def psum_merge(state, axis_name) -> NormalState:
        return NormalState(jax.lax.psum(state.terms, axis_name))

    @staticmethod
    def finalize(state) -> approx.NormalApprox:
        t = np.asarray(state.terms)
        return approx.NormalApprox(float(t[0]), math.sqrt(max(float(t[1]), 0.0)))


# ------------------------------------------------------------- MIN / MAX
class MinMaxState(NamedTuple):
    values: jnp.ndarray    # (kappa,) distinct values, sorted best-first; pad=+inf
    log_none: jnp.ndarray  # (kappa,) sum log(1-p) of tuples at that value
    tail_log_none: jnp.ndarray  # () log prod(1-p) over *evicted* values
    total_log_none: jnp.ndarray  # () log prod(1-p) over all tuples seen


@dataclasses.dataclass(frozen=True)
class MinUDA:
    """The paper's ordered (value, AtLeastOne) list with capacity kappa
    (§VII-C), as fixed-shape arrays: JAX needs static shapes, so the linked
    list becomes a sorted top-kappa buffer merged by sort (DESIGN.md §2).

    `sign` = +1 for MIN (keep smallest), -1 for MAX (keep largest, stored
    negated so the merge logic is shared).
    """

    kappa: int = 64
    sign: float = 1.0

    def init(self, dtype=None) -> MinMaxState:
        dtype = dtype or default_float()
        z = jnp.zeros((), dtype)
        return MinMaxState(jnp.full((self.kappa,), jnp.inf, dtype),
                           jnp.zeros((self.kappa,), dtype), z, z)

    def accumulate(self, state, probs, values, mask=None) -> MinMaxState:
        dtype = state.values.dtype
        p = _masked_probs(jnp.asarray(probs, dtype), mask)
        v = jnp.asarray(values, dtype) * self.sign
        v = jnp.where(p > 0, v, jnp.inf)  # masked/p=0 tuples never matter
        logq = jnp.log1p(-p)
        # Combine duplicates within the chunk on a fixed-size grid.
        uniq, inv = jnp.unique(v, size=v.shape[0], fill_value=jnp.inf,
                               return_inverse=True)
        combined = jax.ops.segment_sum(logq, inv, num_segments=v.shape[0])
        chunk = MinMaxState(uniq, combined, jnp.zeros((), dtype),
                            jnp.sum(logq))
        return self.merge(state, chunk)

    def merge(self, a: MinMaxState, b: MinMaxState) -> MinMaxState:
        dtype = a.values.dtype
        v = jnp.concatenate([a.values, b.values])
        lq = jnp.concatenate([a.log_none, b.log_none])
        uniq, inv = jnp.unique(v, size=v.shape[0], fill_value=jnp.inf,
                               return_inverse=True)
        lq = jax.ops.segment_sum(lq, inv, num_segments=v.shape[0])
        kept_v = uniq[: self.kappa]
        kept_lq = lq[: self.kappa]
        evicted = jnp.where(jnp.isfinite(uniq[self.kappa:]), lq[self.kappa:], 0.0)
        return MinMaxState(kept_v, kept_lq,
                           a.tail_log_none + b.tail_log_none + evicted.sum(),
                           a.total_log_none + b.total_log_none)

    def finalize(self, state: MinMaxState):
        """P(min = v_j) = prod_{v_l < v_j} Q_l * (1 - Q_{v_j})  (§V-B.1),
        where Q_l = prod over tuples at value v_l of (1 - p).

        Returns (values, masses, p_tail): values are un-negated (true MAX
        values for sign = -1); p_tail is the probability that the aggregate
        falls beyond the kept support — evicted values *or* the empty world
        (the paper's X^inf term plus its §V-B.2 truncation remainder).
        """
        finite = jnp.isfinite(state.values)
        lq = jnp.where(finite, state.log_none, 0.0)
        prefix = jnp.concatenate([jnp.zeros((1,), lq.dtype), jnp.cumsum(lq)[:-1]])
        mass = jnp.exp(prefix) * (1.0 - jnp.exp(lq)) * finite
        p_tail = jnp.exp(jnp.sum(lq))  # all kept absent: evicted or empty
        return state.values * self.sign, mass, p_tail

    def p_empty(self, state: MinMaxState):
        """Exact P(aggregate undefined) = prod over all tuples of (1-p)."""
        return jnp.exp(state.total_log_none)

    def to_pgf(self, state: MinMaxState, lo: int, hi: int) -> PGF:
        """Densify onto integer grid [lo, hi); truncation tail -> inf mass."""
        values, mass, p_tail = self.finalize(state)
        k = hi - lo
        idx = jnp.clip((jnp.where(jnp.isfinite(values), values, lo) - lo)
                       .astype(jnp.int32), 0, k - 1)
        coeffs = jnp.zeros((k,), mass.dtype).at[idx].add(
            jnp.where(jnp.isfinite(values), mass, 0.0))
        if self.sign > 0:
            return PGF(coeffs, lo, p_pos_inf=p_tail)
        return PGF(coeffs, lo, p_neg_inf=p_tail)


def MaxUDA(kappa: int = 64) -> MinUDA:
    return MinUDA(kappa=kappa, sign=-1.0)
