"""Scalar UDA facade (paper §VI-A): Initialize / Accumulate / Merge /
Finalize over JAX pytree states.

The actual aggregate math lives ONCE in :mod:`repro.core.uda`, vectorised
over groups; this module is the scalar (max_groups == 1) view of it, kept
for the paper-shaped single-stream API: lift the scalar state to one group,
run the canonical blocked accumulation loop, drop the group axis again.
`Accumulate` maps over locally-sharded tuple chunks, `Merge` is an
elementwise reduction that lowers to one `psum` inside shard_map (DESIGN.md
§2, Glade row of the adaptation table), and `Finalize` is a single device
(FFT) or host (mixture solve) epilogue.

Every UDA also accepts a `mask` so that fixed-shape relations with validity
masks (selection pushdown) aggregate only live tuples: a masked-out tuple is
equivalent to p = 0 for SUM/COUNT/AtLeastOne and to "not in the list" for
MIN/MAX.

Provided UDAs (paper §V / §VII):
    CountCF / SumCF         exact distributions via log-CF          (§V-A/C)
    SumCumulants            moment terms for the gamma mixture      (§V-C.3)
    SumNormal               mean/variance terms                     (§V-C.3)
    MinUDA / MaxUDA         top-kappa (value, AtLeastOne) list      (§V-B, §VII-C)
    AtLeastOne              the projection/group-confidence UDA     (§VI row V)
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import approx, uda
from .config import default_float
from .pgf import PGF

_masked_probs = uda.masked_probs


def _run(u: uda.UDA, state, probs, values=None, mask=None):
    """One-group accumulate through the canonical loop in core/uda.py.

    `values` is passed uncast: the loop casts to the probs dtype itself and
    uses the ORIGINAL dtype to decide Pallas-kernel eligibility (the exact
    CF kernel only applies to integer-typed values)."""
    p = _masked_probs(jnp.asarray(probs), mask)
    vals = None if values is None else jnp.asarray(values)
    return uda.accumulate({"u": u}, p, vals, None, max_groups=1,
                          states={"u": state})["u"]


# ------------------------------------------------------------- AtLeastOne
class AtLeastOneState(NamedTuple):
    log_none: jnp.ndarray  # sum of log(1 - p) over accumulated tuples


class AtLeastOne:
    """P(at least one tuple present) = 1 - prod (1 - p_i)  (§VI row V)."""

    _U = uda.AtLeastOne()

    @staticmethod
    def init(dtype=None) -> AtLeastOneState:
        return AtLeastOneState(jnp.zeros((), dtype or default_float()))

    @staticmethod
    def accumulate(state: AtLeastOneState, probs, mask=None) -> AtLeastOneState:
        st = _run(AtLeastOne._U, uda.AtLeastOneState(state.log_none[None]),
                  probs, mask=mask)
        return AtLeastOneState(st.log_none[0])

    @staticmethod
    def merge(a: AtLeastOneState, b: AtLeastOneState) -> AtLeastOneState:
        return AtLeastOneState(a.log_none + b.log_none)

    @staticmethod
    def finalize(state: AtLeastOneState):
        return 1.0 - jnp.exp(state.log_none)


# ------------------------------------------------------------ CF exact UDAs
class CFState(NamedTuple):
    log_abs: jnp.ndarray  # (num_freq,)
    angle: jnp.ndarray    # (num_freq,)


class SumCF:
    """Exact SUM (and COUNT, with values == 1) over integer values via the
    log-characteristic-function representation.  `num_freq` = max_sum + 1 is
    the static distribution capacity, fixed at Initialize time (the JAX
    analogue of the paper's pre-sized FFT buffers)."""

    def __init__(self, num_freq: int):
        self.num_freq = int(num_freq)
        self._u = uda.SumCF(self.num_freq)

    def init(self, dtype=None) -> CFState:
        dtype = dtype or default_float()
        z = jnp.zeros((self.num_freq,), dtype)
        return CFState(z, z)

    def accumulate(self, state: CFState, probs, values=None, mask=None) -> CFState:
        st = _run(self._u, uda.CFState(state.log_abs[None], state.angle[None]),
                  probs, values, mask)
        return CFState(st.log_abs[0], st.angle[0])

    @staticmethod
    def merge(a: CFState, b: CFState) -> CFState:
        return CFState(a.log_abs + b.log_abs, a.angle + b.angle)

    @staticmethod
    def psum_merge(state: CFState, axis_name) -> CFState:
        return CFState(jax.lax.psum(state.log_abs, axis_name),
                       jax.lax.psum(state.angle, axis_name))

    def finalize(self, state: CFState) -> PGF:
        coeffs = self._u.finalize(uda.CFState(state.log_abs[None],
                                              state.angle[None]))
        return PGF(coeffs[0], 0)


def CountCF(capacity: int) -> SumCF:
    """COUNT = SUM of T_COUNT-translated values (all ones), §IV-F step 1."""
    return SumCF(capacity + 1)


# ------------------------------------------------------- moment-based UDAs
class CumulantState(NamedTuple):
    terms: jnp.ndarray  # (2p,) partial cumulant sums


class SumCumulants:
    """Streaming cumulants for Lindsay's gamma-mixture approximation."""

    def __init__(self, p_components: int = 3):
        self.p = int(p_components)
        self._u = uda.SumCumulants(2 * self.p)

    def init(self, dtype=None) -> CumulantState:
        return CumulantState(jnp.zeros((2 * self.p,), dtype or default_float()))

    def accumulate(self, state, probs, values=None, mask=None) -> CumulantState:
        st = _run(self._u, uda.CumulantState(state.terms[None]),
                  probs, values, mask)
        return CumulantState(st.terms[0])

    @staticmethod
    def merge(a, b) -> CumulantState:
        return CumulantState(a.terms + b.terms)

    @staticmethod
    def psum_merge(state, axis_name) -> CumulantState:
        return CumulantState(jax.lax.psum(state.terms, axis_name))

    def finalize(self, state) -> approx.GammaMixture:
        return approx.fit_gamma_mixture(np.asarray(state.terms), p=self.p)


class NormalState(NamedTuple):
    terms: jnp.ndarray  # (2,) = (mean, variance) partial sums


class SumNormal:
    _U = uda.SumNormal()

    @staticmethod
    def init(dtype=None) -> NormalState:
        return NormalState(jnp.zeros((2,), dtype or default_float()))

    @staticmethod
    def accumulate(state, probs, values=None, mask=None) -> NormalState:
        st = _run(SumNormal._U, uda.NormalState(state.terms[None]),
                  probs, values, mask)
        return NormalState(st.terms[0])

    @staticmethod
    def merge(a, b) -> NormalState:
        return NormalState(a.terms + b.terms)

    @staticmethod
    def psum_merge(state, axis_name) -> NormalState:
        return NormalState(jax.lax.psum(state.terms, axis_name))

    @staticmethod
    def finalize(state) -> approx.NormalApprox:
        t = np.asarray(state.terms)
        return approx.NormalApprox(float(t[0]), math.sqrt(max(float(t[1]), 0.0)))


# ------------------------------------------------------------- MIN / MAX
class MinMaxState(NamedTuple):
    values: jnp.ndarray    # (kappa,) distinct values, sorted best-first; pad=+inf
    log_none: jnp.ndarray  # (kappa,) sum log(1-p) of tuples at that value
    tail_log_none: jnp.ndarray  # () log prod(1-p) over *evicted* values
    total_log_none: jnp.ndarray  # () log prod(1-p) over all tuples seen


def _lift_minmax(s: MinMaxState) -> uda.MinMaxState:
    return uda.MinMaxState(s.values[None], s.log_none[None],
                           s.tail_log_none[None], s.total_log_none[None])


def _drop_minmax(s: uda.MinMaxState) -> MinMaxState:
    return MinMaxState(s.values[0], s.log_none[0],
                       s.tail_log_none[0], s.total_log_none[0])


@dataclasses.dataclass(frozen=True)
class MinUDA:
    """The paper's ordered (value, AtLeastOne) list with capacity kappa
    (§VII-C); the scalar view of :class:`repro.core.uda.MinMax`, which keeps
    fixed-shape sorted top-kappa buffers merged by sort (DESIGN.md §2).

    `sign` = +1 for MIN (keep smallest), -1 for MAX (keep largest, stored
    negated so the merge logic is shared).
    """

    kappa: int = 64
    sign: float = 1.0

    @property
    def _u(self) -> uda.MinMax:
        return uda.MinMax(kappa=self.kappa, sign=self.sign)

    def init(self, dtype=None) -> MinMaxState:
        return _drop_minmax(self._u.init(1, dtype))

    def accumulate(self, state, probs, values, mask=None) -> MinMaxState:
        dtype = state.values.dtype
        p = _masked_probs(jnp.asarray(probs, dtype), mask)
        st = uda.accumulate({"u": self._u}, p, jnp.asarray(values, dtype),
                            None, max_groups=1, states={"u": _lift_minmax(state)})
        return _drop_minmax(st["u"])

    def merge(self, a: MinMaxState, b: MinMaxState) -> MinMaxState:
        return _drop_minmax(self._u.merge(_lift_minmax(a), _lift_minmax(b)))

    def finalize(self, state: MinMaxState):
        """Per-value masses and the beyond-support tail (§V-B.1/.2); see
        :meth:`repro.core.uda.MinMax.finalize`."""
        values, mass, p_tail = self._u.finalize(_lift_minmax(state))
        return values[0], mass[0], p_tail[0]

    def p_empty(self, state: MinMaxState):
        """Exact P(aggregate undefined) = prod over all tuples of (1-p)."""
        return self._u.p_empty(_lift_minmax(state))[0]

    def to_pgf(self, state: MinMaxState, lo: int, hi: int) -> PGF:
        """Densify onto integer grid [lo, hi); truncation tail -> inf mass."""
        values, mass, p_tail = self.finalize(state)
        k = hi - lo
        idx = jnp.clip((jnp.where(jnp.isfinite(values), values, lo) - lo)
                       .astype(jnp.int32), 0, k - 1)
        coeffs = jnp.zeros((k,), mass.dtype).at[idx].add(
            jnp.where(jnp.isfinite(values), mass, 0.0))
        if self.sign > 0:
            return PGF(coeffs, lo, p_pos_inf=p_tail)
        return PGF(coeffs, lo, p_neg_inf=p_tail)


def MaxUDA(kappa: int = 64) -> MinUDA:
    return MinUDA(kappa=kappa, sign=-1.0)
