"""The paper's primary contribution: PGF-based probabilistic aggregation.

Layout (DESIGN.md §3):
    monoids.py            aggregation monoids + the T_AGG translation
    pgf.py                dense PGF value type, exact products, product tree
    poisson_binomial.py   log-CF exact COUNT/SUM (the TPU adaptation)
    uda.py                THE grouped segment-UDA subsystem (one accumulate/
                          merge implementation per aggregate, registry)
    aggregates.py         scalar UDA facade over uda.py
    approx.py             Normal + Lindsay gamma-mixture approximations
    compare.py            PGF ADT comparisons (paper Fig. 5)
"""
from . import aggregates, approx, compare, monoids, pgf, poisson_binomial, uda
from .config import default_float, enable_x64
from .pgf import PGF

__all__ = [
    "PGF", "aggregates", "approx", "compare", "monoids", "pgf",
    "poisson_binomial", "uda", "default_float", "enable_x64",
]
