"""Grouped segment-UDA subsystem: the ONE implementation of every
probabilistic aggregate (paper §VI-A, Glade Initialize/Accumulate/Merge/
Finalize).

Before this module the same UDA math lived three times (scalar classes in
``core/aggregates.py``, grouped segment reductions in ``db/operators.py``,
and inline again in ``db/distributed.py``), each copy with its own blocking
heuristics and tail handling.  Here each aggregate is defined once as

    init(max_groups, dtype)      -> pytree state, leaves lead with (G, ...)
    update(state, p, v, g)       -> state   (one tuple block; streaming UDAs)
    merge(a, b)                  -> state   (additive for streaming UDAs,
                                             hence one `psum` inside shard_map)
    finalize(state)              -> per-group device-side results

vectorised over ``max_groups`` groups — the scalar case is just
``max_groups == 1`` with all-zero group ids, which is how the thin wrappers
in :mod:`repro.core.aggregates` and the delegating helpers in
:mod:`repro.core.poisson_binomial` / :mod:`repro.core.approx` use it.

:func:`accumulate` below is the single canonical accumulation loop (the
blocked-scan tiling previously private to ``db/distributed.py``): ONE
``lax.scan`` over tuple blocks feeds every streaming UDA at once, so a
multi-aggregate query reads its tuples exactly once, and the (block, F)
phase tile of the exact-CF path is the only large live intermediate.  On
TPU the CF / cumulant accumulations dispatch to the Pallas kernels: scalar
states to :mod:`repro.kernels.pb_cf` / :mod:`repro.kernels.cumulants`,
grouped CF states to the (G, F)-tiled :mod:`repro.kernels.group_cf`
(``SumCF.accumulate_full``), with the pure-JAX oracles as CPU fallback.

Registered UDAs (paper §V / §VI / §VII):

    atleastone   P(group non-empty) = 1 - prod(1-p)        (§VI row V)
    normal       (sum v p, sum v^2 p (1-p)) terms          (§V-C.3)
    cumulants    sum v^j kappa_j(p) moment terms           (§V-C.3, Lindsay)
    cf           exact SUM/COUNT log-characteristic fn     (§V-A/C)
    min / max    top-kappa ordered (value, survival) list  (§V-B, §VII-C)

Distributed execution (``db/distributed.py``) is generic over this
protocol: Accumulate per shard, ``reduce_data`` = one psum over the tuple
sharding axes, ``reduce_model`` reassembles model-axis frequency slices,
Finalize replicated.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .approx import MAX_ORDER, _bernoulli_cumulant_polys
from .config import default_float

# The canonical tiling constants: bound the scan body's working set to
# ~2^23 elements so the (block, F) tile stays cache/VMEM sized regardless
# of distribution width; the floor of 64 keeps even num_freq ~ 2^20 tiles
# within budget (a higher floor would override the budget at large F).
_BLOCK_FLOOR = 64
_ELEM_BUDGET = 1 << 23


def _tiny(dtype):
    """Log-underflow guard, unified across all former copies."""
    return 1e-30 if dtype == jnp.float32 else 1e-300


def _scatter_add(acc, g, contrib):
    """acc[g] += contrib with the G == 1 (scalar) fast path."""
    if acc.shape[0] == 1:
        return acc + jnp.sum(contrib, axis=0, keepdims=True)
    return acc.at[g].add(contrib)


def masked_probs(probs, mask):
    """A masked-out tuple is exactly a p = 0 tuple for every UDA."""
    if mask is None:
        return probs
    return jnp.where(mask, probs, jnp.zeros_like(probs))


# ======================================================================
# protocol
# ======================================================================
class UDA:
    """Base grouped UDA.  Subclasses define init/update (or accumulate_full)
    /finalize; merge and the collective reductions default to the additive
    behaviour shared by every streaming UDA."""

    #: streaming UDAs accumulate block-by-block inside the canonical scan;
    #: non-streaming ones (MinMax) consume the full column at once.
    streaming: bool = True
    #: additive states merge by elementwise add (psum-able inside
    #: shard_map); non-additive ones (MinMax) must gather-fold instead.
    additive: bool = True
    #: a scalar UDA ignores group ids and keeps one global group (e.g. the
    #: exact global CF of the canonical query step).
    scalar: bool = False

    def init(self, max_groups: int, dtype=None):
        raise NotImplementedError

    def update(self, state, probs, values, gids):
        """Fold one block of (already masked) tuples into the state."""
        raise NotImplementedError

    def accumulate_full(self, state, probs, values, gids, max_groups):
        """Whole-column accumulate for non-streaming UDAs."""
        raise NotImplementedError

    def merge(self, a, b):
        """Combine two partial states; additive => psum-able."""
        return jax.tree.map(jnp.add, a, b)

    def reduce_data(self, state, axis_names):
        """Merge across the tuple-sharding mesh axes (inside shard_map)."""
        axis_names = tuple(axis_names)
        if not axis_names:
            return state
        return jax.tree.map(lambda x: jax.lax.psum(x, axis_names), state)

    def reduce_model(self, state, axis_name):
        """Reconcile model-axis replicas (tuples are replicated there)."""
        return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), state)

    def finalize(self, state):
        raise NotImplementedError

    #: per-tuple-row working-set width, used by the canonical block sizing.
    def row_budget(self) -> int:
        return 1


# ======================================================================
# AtLeastOne — group confidence (§VI row V)
# ======================================================================
class AtLeastOneState(NamedTuple):
    log_none: jnp.ndarray        # (G,) sum log(1-p) over accumulated tuples


class AtLeastOne(UDA):
    """P(at least one tuple present) per group: 1 - prod(1 - p)."""

    def init(self, max_groups: int, dtype=None) -> AtLeastOneState:
        return AtLeastOneState(
            jnp.zeros((max_groups,), dtype or default_float()))

    def update(self, state, probs, values, gids) -> AtLeastOneState:
        return AtLeastOneState(
            _scatter_add(state.log_none, gids, jnp.log1p(-probs)))

    def finalize(self, state):
        return 1.0 - jnp.exp(state.log_none)


# ======================================================================
# SumNormal — (mean, variance) terms (§V-C.3, with the variance erratum fix)
# ======================================================================
class NormalState(NamedTuple):
    terms: jnp.ndarray           # (G, 2) = (sum v p, sum v^2 p (1-p))


class SumNormal(UDA):
    def init(self, max_groups: int, dtype=None) -> NormalState:
        return NormalState(jnp.zeros((max_groups, 2),
                                     dtype or default_float()))

    def update(self, state, probs, values, gids) -> NormalState:
        mu_t = values * probs
        var_t = values * values * probs * (1.0 - probs)
        return NormalState(_scatter_add(state.terms, gids,
                                        jnp.stack([mu_t, var_t], axis=-1)))

    def finalize(self, state):
        return state.terms[:, 0], state.terms[:, 1]


# ======================================================================
# SumCumulants — moment terms for the Lindsay gamma mixture (§V-C.3)
# ======================================================================
class CumulantState(NamedTuple):
    terms: jnp.ndarray           # (G, orders) partial cumulant sums


class SumCumulants(UDA):
    """s_j[g] = sum_{i in g} v_i^j kappa_j(p_i), j = 1..orders."""

    def __init__(self, orders: int = 8):
        assert orders <= MAX_ORDER
        self.orders = int(orders)

    def init(self, max_groups: int, dtype=None) -> CumulantState:
        return CumulantState(jnp.zeros((max_groups, self.orders),
                                       dtype or default_float()))

    def update(self, state, probs, values, gids) -> CumulantState:
        dtype = probs.dtype
        table = jnp.asarray(_bernoulli_cumulant_polys()[1:self.orders + 1],
                            dtype)
        powers = probs[None, :] ** jnp.arange(MAX_ORDER + 1,
                                              dtype=dtype)[:, None]
        kappas = table @ powers                         # (orders, B)
        vpow = values[None, :] ** jnp.arange(1, self.orders + 1,
                                             dtype=dtype)[:, None]
        return CumulantState(_scatter_add(state.terms, gids,
                                          (kappas * vpow).T))

    def finalize(self, state):
        return state.terms

    def row_budget(self) -> int:
        return MAX_ORDER + 1


# ======================================================================
# SumCF — exact SUM/COUNT via the log characteristic function (§V-A/C)
# ======================================================================
class CFState(NamedTuple):
    log_abs: jnp.ndarray         # (G, F_loc)
    angle: jnp.ndarray           # (G, F_loc)


class SumCF(UDA):
    """log Q(w^k) = sum_i log((1-p_i) + p_i w^{k v_i}), w = e^{2 pi i / N}.

    ``num_freq`` (= max_sum + 1) is the static distribution capacity.  For
    model-axis frequency sharding, ``freq_cnt`` frequencies starting at
    ``freq_lo`` are accumulated locally (``freq_lo`` may be a traced
    ``axis_index`` expression inside shard_map); ``reduce_model``
    reassembles the slices with one tiled all-gather.
    """

    def __init__(self, num_freq: int, freq_lo=0, freq_cnt: int | None = None):
        self.num_freq = int(num_freq)
        self.freq_lo = freq_lo
        self.freq_cnt = int(freq_cnt) if freq_cnt is not None else self.num_freq

    def init(self, max_groups: int, dtype=None) -> CFState:
        z = jnp.zeros((max_groups, self.freq_cnt), dtype or default_float())
        return CFState(z, z)

    def accumulate_full(self, state, probs, values, gids, max_groups,
                        use_kernel: bool | None = None,
                        operands=None) -> CFState:
        """Whole-column accumulate, dispatching to the (G, F)-tiled Pallas
        kernel (:mod:`repro.kernels.group_cf`) when eligible; the pure-JAX
        oracle handles small inputs and non-f32 dtypes, and the kernel
        itself runs in interpret mode on CPU backends.  Requires a static
        int ``freq_lo`` (the model-sharded traced case stays on the blocked
        scan path) and integer-valued ``values``.  ``operands`` are
        pre-sorted kernel columns (:func:`cf_chunk_operands`) so the
        frequency-slab loop hoists the argsort above the slabs.
        """
        from ..kernels import ops as kops
        if max_groups == 1 and use_kernel and self.freq_lo == 0 \
                and self.freq_cnt == self.num_freq:
            la, an = kops.logcf(probs, values, self.num_freq)
            return CFState(state.log_abs + la[None], state.angle + an[None])
        if gids is None:
            gids = jnp.zeros(probs.shape, jnp.int32)
        la, an = kops.group_logcf(probs, values, gids, max_groups,
                                  self.num_freq, freq_lo=self.freq_lo,
                                  freq_cnt=self.freq_cnt,
                                  use_kernel=use_kernel, operands=operands)
        return CFState(state.log_abs + la, state.angle + an)

    def update(self, state, probs, values, gids) -> CFState:
        dtype = probs.dtype
        k = self.freq_lo + jnp.arange(self.freq_cnt, dtype=dtype)
        # (B, F_loc) phase tile — the one large live intermediate of the
        # canonical loop; mod num_freq keeps theta exact at large k*v.
        phase = (values[:, None] * k[None, :]) % self.num_freq
        theta = (2.0 * math.pi / self.num_freq) * phase
        q = 1.0 - probs[:, None]
        re = q + probs[:, None] * jnp.cos(theta)
        im = probs[:, None] * jnp.sin(theta)
        la = 0.5 * jnp.log(jnp.maximum(re * re + im * im, _tiny(dtype)))
        an = jnp.arctan2(im, re)
        return CFState(_scatter_add(state.log_abs, gids, la),
                       _scatter_add(state.angle, gids, an))

    def reduce_model(self, state, axis_name):
        return CFState(
            jax.lax.all_gather(state.log_abs, axis_name, axis=-1, tiled=True),
            jax.lax.all_gather(state.angle, axis_name, axis=-1, tiled=True))

    def finalize(self, state):
        """(G, F) summed log CF -> (G, F) coefficient rows, one batched FFT."""
        q = jnp.exp(state.log_abs) * jax.lax.complex(jnp.cos(state.angle),
                                                     jnp.sin(state.angle))
        coeffs = jnp.fft.fft(q, axis=-1).real / state.log_abs.shape[-1]
        return jnp.clip(coeffs, 0.0, None)

    def row_budget(self) -> int:
        return self.freq_cnt


def CountCF(capacity: int) -> SumCF:
    """COUNT = SUM of T_COUNT-translated all-ones values (§IV-F step 1)."""
    return SumCF(capacity + 1)


# ======================================================================
# MinMax — grouped top-kappa (value, survival) lists (§V-B, §VII-C)
# ======================================================================
class MinMaxState(NamedTuple):
    values: jnp.ndarray          # (G, kappa) sign-folded values, sorted, pad +inf
    log_none: jnp.ndarray        # (G, kappa) sum log(1-p) of tuples at value
    tail_log_none: jnp.ndarray   # (G,) log prod(1-p) over *evicted* values
    total_log_none: jnp.ndarray  # (G,) log prod(1-p) over all tuples seen


class MinMax(UDA):
    """The paper's ordered (value, AtLeastOne) list with capacity kappa, as
    fixed-shape (G, kappa) buffers: JAX needs static shapes, so the linked
    list becomes a sorted top-kappa buffer merged by row-wise sort + run
    folding.  ``sign`` = +1 for MIN (keep smallest), -1 for MAX (values
    stored negated so the merge logic is shared).

    Not additive: ``reduce_data`` all-gathers shard states and folds
    ``merge`` over the (static) shard count instead of psum-ing.
    """

    streaming = False
    additive = False

    def __init__(self, kappa: int = 64, sign: float = 1.0):
        self.kappa = int(kappa)
        self.sign = float(sign)

    def init(self, max_groups: int, dtype=None) -> MinMaxState:
        dtype = dtype or default_float()
        return MinMaxState(
            jnp.full((max_groups, self.kappa), jnp.inf, dtype),
            jnp.zeros((max_groups, self.kappa), dtype),
            jnp.zeros((max_groups,), dtype),
            jnp.zeros((max_groups,), dtype))

    def accumulate_full(self, state, probs, values, gids, max_groups):
        """``state=None`` means "fresh init" (the canonical loop's hint):
        the constructed chunk buffer is returned directly."""
        dtype = probs.dtype if state is None else state.values.dtype
        p = jnp.asarray(probs, dtype)
        v = jnp.asarray(values, dtype) * self.sign
        v = jnp.where(p > 0, v, jnp.inf)     # masked / p=0 tuples never matter
        logq = jnp.log1p(-p)
        n = p.shape[0]
        # Lexsort rows by (group, folded value): ONE stable two-key
        # lax.sort carrying the payload column — the same permutation the
        # old argsort(v)-then-argsort(gids) pair produced (stable lexsort
        # is unique), without the second sort and the three gathers.  A
        # combined float key would lose value bits to ULP at large group
        # ids, hence two keys.
        gs, vs, lqs = jax.lax.sort((gids, v, logq), dimension=0,
                                   is_stable=True, num_keys=2)

        # Fold duplicate (group, value) runs.
        head = jnp.concatenate([jnp.ones((1,), bool),
                                (gs[1:] != gs[:-1]) | (vs[1:] != vs[:-1])])
        seg = jnp.cumsum(head) - 1
        run_idx = jnp.arange(n)
        exists = run_idx < seg[-1] + 1
        run_lq = jax.ops.segment_sum(lqs, seg, num_segments=n)
        run_v = jax.ops.segment_min(vs, seg, num_segments=n)   # +inf if empty
        run_g = jnp.clip(jax.ops.segment_max(gs, seg, num_segments=n),
                         0, max_groups - 1)
        run_g = jnp.where(exists, run_g, max_groups - 1)

        # Rank of each run within its group = run index - group's first run.
        grp_first = jax.ops.segment_min(jnp.where(exists, run_idx, n), run_g,
                                        num_segments=max_groups)
        rank = run_idx - grp_first[run_g]

        keep = exists & jnp.isfinite(run_v) & (rank < self.kappa)
        col = jnp.where(keep, rank, self.kappa)      # out-of-range -> dropped
        chunk_v = jnp.full((max_groups, self.kappa), jnp.inf, dtype) \
            .at[run_g, col].set(run_v, mode="drop")
        chunk_lq = jnp.zeros((max_groups, self.kappa), dtype) \
            .at[run_g, col].add(run_lq, mode="drop")
        evicted = exists & jnp.isfinite(run_v) & (rank >= self.kappa)
        chunk_tail = jnp.zeros((max_groups,), dtype) \
            .at[run_g].add(jnp.where(evicted, run_lq, 0.0))
        chunk_total = jnp.zeros((max_groups,), dtype).at[gids].add(logq)
        chunk = MinMaxState(chunk_v, chunk_lq, chunk_tail, chunk_total)
        # A fresh-init state needs no merge: the chunk buffer already
        # satisfies the invariant (sorted, distinct, inf-padded) and
        # merge(init, x) == x bitwise — the canonical chunked path calls
        # this once per chunk with a fresh state, so skipping the merge
        # halves the chunked MinMax merge count.
        return chunk if state is None else self.merge(state, chunk)

    def merge(self, a: MinMaxState, b: MinMaxState) -> MinMaxState:
        """Bitonic two-way merge + in-network run fold + top-k truncation,
        sort-free: both inputs keep their rows sorted (the state
        invariant), so ascending(a) ++ descending(b) is bitonic and
        log2(2k) elementwise compare-exchange stages finish the merge —
        XLA CPU row sorts serialise and were the hot spot of the
        chunked/tree merge path.

        A value present in both inputs lands in two adjacent slots of the
        sorted 2k buffer; the run fold collapses each equal-value run
        into its head slot (log_none sums — the masses telescope exactly:
        exp(prefix) (1-Q_a) + exp(prefix) Q_a (1-Q_b) == the folded-run
        mass) BEFORE the top-k truncation, so duplicates never compete
        for the kappa capacity and the §V-B.2 truncation tail stays tight
        under heavy duplication — at one segment-sum on top of the
        bitonic stages."""
        k = self.kappa
        pw = 1 << (k - 1).bit_length()       # bitonic needs a 2^m half
        inf_pad = ((0, 0), (0, pw - k))
        v = jnp.concatenate(
            [jnp.pad(a.values, inf_pad, constant_values=jnp.inf),
             jnp.pad(b.values, inf_pad, constant_values=jnp.inf)[:, ::-1]],
            axis=1)
        lq = jnp.concatenate([jnp.pad(a.log_none, inf_pad),
                              jnp.pad(b.log_none, inf_pad)[:, ::-1]], axis=1)
        g = v.shape[0]
        s = pw
        while s >= 1:
            vr = v.reshape(g, -1, 2, s)
            lr = lq.reshape(g, -1, 2, s)
            swap = vr[:, :, 0] > vr[:, :, 1]
            v = jnp.stack([jnp.where(swap, vr[:, :, 1], vr[:, :, 0]),
                           jnp.where(swap, vr[:, :, 0], vr[:, :, 1])],
                          axis=2).reshape(g, -1)
            lq = jnp.stack([jnp.where(swap, lr[:, :, 1], lr[:, :, 0]),
                            jnp.where(swap, lr[:, :, 0], lr[:, :, 1])],
                           axis=2).reshape(g, -1)
            s //= 2
        # Run fold, scatter-free (XLA CPU scatters serialise): both inputs
        # hold DISTINCT values (the state invariant this fold maintains),
        # so an equal-value run in the sorted buffer spans at most TWO
        # slots and its log_none total is one pairwise add; heads then
        # compact to their run index — dense, still sorted — with a
        # batched binary search + gather.  (Empty +inf slots form one
        # trailing run; their log_none is 0, so any fold of it is exact.)
        w = v.shape[1]
        finite = jnp.isfinite(v)
        dup = jnp.concatenate([jnp.zeros_like(finite[:, :1]),
                               v[:, 1:] == v[:, :-1]], axis=1)
        head = ~dup
        absorb = jnp.concatenate([dup[:, 1:],
                                  jnp.zeros_like(dup[:, :1])], axis=1)
        lq_next = jnp.concatenate([lq[:, 1:],
                                   jnp.zeros_like(lq[:, :1])], axis=1)
        tot = lq + jnp.where(absorb, lq_next, 0.0)   # per-run log_none
        run = jnp.cumsum(head, axis=1) - 1           # run index per slot
        evicted = jnp.where(head & finite & (run >= k), tot, 0.0)
        # Branchless batched lower_bound over the k KEPT output slots only
        # (truncation discards the rest): src[g, j] = head slot of run j
        # (first position with run >= j).  XLA CPU gathers dominate this
        # epilogue, so: probe width k not 2k, one complex gather fetches
        # (v, tot) together, and run-existence is a slice compare instead
        # of another gather.
        idx = jnp.arange(k)
        pos = jnp.full((g, k), -1, jnp.int32)        # last slot with run < j
        step = w
        while step > 1:
            step //= 2
            cand = jnp.minimum(pos + step, w - 1)
            less = jnp.take_along_axis(run, cand, axis=1) < idx[None, :]
            pos = jnp.where(less, cand, pos)
        src = jnp.clip(pos + 1, 0, w - 1)            # head slot of run j
        ok = idx[None, :] <= run[:, -1:]             # run j exists
        got = jnp.take_along_axis(jax.lax.complex(v, tot), src, axis=1)
        v = jnp.where(ok, got.real, jnp.inf)
        lq = jnp.where(ok, got.imag, 0.0)
        return MinMaxState(v, lq,
                           a.tail_log_none + b.tail_log_none + evicted.sum(1),
                           a.total_log_none + b.total_log_none)

    def reduce_data(self, state, axis_names):
        axis_names = tuple(axis_names)
        if not axis_names:
            return state
        gathered = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis_names, axis=0, tiled=False),
            state)
        shards = jax.tree.leaves(gathered)[0].shape[0]   # static
        out = jax.tree.map(lambda x: x[0], gathered)
        for s in range(1, shards):
            out = self.merge(out, jax.tree.map(lambda x, s=s: x[s], gathered))
        return out

    def reduce_model(self, state, axis_name):
        return state     # tuples are replicated over the model axis

    def finalize(self, state: MinMaxState):
        """P(agg = v_j) = prod_{v_l better} Q_l * (1 - Q_j)  (§V-B.1), with
        Q_l = prod over tuples at value v_l of (1 - p).  Returns per-group
        (values, masses, p_tail): values un-folded (true MAX values for
        sign = -1); p_tail = P(aggregate beyond the kept support) — evicted
        values *or* the empty world (the paper's X^inf term plus its §V-B.2
        truncation remainder)."""
        finite = jnp.isfinite(state.values)
        lq = jnp.where(finite, state.log_none, 0.0)
        prefix = jnp.concatenate(
            [jnp.zeros_like(lq[:, :1]), jnp.cumsum(lq, axis=1)[:, :-1]],
            axis=1)
        mass = jnp.exp(prefix) * (1.0 - jnp.exp(lq)) * finite
        p_tail = jnp.exp(jnp.sum(lq, axis=1))
        return state.values * self.sign, mass, p_tail

    def p_empty(self, state: MinMaxState):
        """Exact P(aggregate undefined) = prod over all tuples of (1-p)."""
        return jnp.exp(state.total_log_none)

    def tail_mass(self, state: MinMaxState):
        """Per-group §V-B.2 truncation mass: the probability the exact
        aggregate lies STRICTLY beyond the kept kappa-support (evicted
        values present while every kept value is absent) — i.e. the
        ``p_tail`` of :meth:`finalize` minus its empty-world component.
        ``tail_log_none`` accumulates log(1-p) over exactly the evicted
        tuples, so the mass is

            prod_kept Q_j * (1 - prod_evicted (1-p))

        and is exactly 0 when kappa covered every distinct value (nothing
        evicted => tail_log_none = 0).  This is the quantity a caller (or
        the retry controller) compares against a tolerance to decide
        whether kappa must escalate."""
        finite = jnp.isfinite(state.values)
        lq = jnp.where(finite, state.log_none, 0.0)
        return jnp.exp(jnp.sum(lq, axis=1)) * -jnp.expm1(state.tail_log_none)


# ======================================================================
# registry
# ======================================================================
REGISTRY = {
    "atleastone": AtLeastOne,
    "normal": SumNormal,
    "cumulants": SumCumulants,
    "cf": SumCF,
    "count_cf": CountCF,
    "min": lambda **kw: MinMax(sign=1.0, **kw),
    "max": lambda **kw: MinMax(sign=-1.0, **kw),
}


def make(name: str, **kwargs) -> UDA:
    return REGISTRY[name](**kwargs)


# ======================================================================
# the canonical accumulation loop
# ======================================================================
def _block_size(udas, block: int, n: int) -> int:
    budget = max([1] + [u.row_budget() for u in udas.values()])
    bsz = max(_BLOCK_FLOOR, min(block, _ELEM_BUDGET // max(1, budget)))
    # Never pad past the column: a short column (e.g. one canonical chunk
    # of a chunked accumulate) runs as a single right-sized block instead
    # of being zero-padded up to the full block budget.  The floor keeps
    # bsz positive for empty columns (the scan then runs zero steps and
    # returns the init states).
    return min(bsz, max(_BLOCK_FLOOR, -(-n // _BLOCK_FLOOR) * _BLOCK_FLOOR))


def _groups_of(u: UDA, max_groups: int) -> int:
    return 1 if u.scalar else max_groups


def _use_pallas(kernel: str) -> bool:
    """The ONE backend half of the kernel-dispatch predicate, shared by
    :func:`accumulate` and :func:`cf_chunk_operands` so the operand hoist
    can never diverge from the dispatch it feeds."""
    return kernel == "pallas" or (kernel == "auto"
                                  and jax.default_backend() == "tpu")


def _integral_dtype(dtype) -> bool:
    """Does this source dtype carry exact integers (CF-kernel-eligible)?"""
    return jnp.issubdtype(dtype, jnp.integer) or dtype == jnp.bool_


def _kernel_eligible(u: UDA, max_groups: int, probs, values_integral: bool) \
        -> bool:
    """CF / cumulant accumulations can run on the Pallas kernels — only
    under the same guards as kernels/ops.py (f32, enough tuples to amortise
    block padding), and for CF only with integer-typed values and a static
    frequency window (the kernel's exact phase arithmetic truncates to
    int32; a traced model-sharded freq_lo can't parameterise the static
    grid).  Grouped CF states dispatch to the (G, F)-tiled group_cf kernel;
    cumulants stay scalar-only."""
    from ..kernels import ops as kops
    if probs.dtype != jnp.float32 or probs.shape[0] < kops.MIN_KERNEL_TUPLES:
        return False
    if isinstance(u, SumCF):
        return values_integral and isinstance(u.freq_lo, int) \
            and u.num_freq <= kops.MAX_KERNEL_FREQ
    return isinstance(u, SumCumulants) and _groups_of(u, max_groups) == 1


def _kernel_accumulate(u: UDA, state, probs, values, gids, max_groups,
                       operands=None):
    from ..kernels import ops as kops
    if isinstance(u, SumCF):
        g = _groups_of(u, max_groups)
        return u.accumulate_full(state, probs, values,
                                 None if g == 1 else gids, g,
                                 use_kernel=True, operands=operands)
    sums = kops.cumulant_sums(probs, values, orders=u.orders)
    return CumulantState(state.terms + sums[None])


def accumulate(udas, probs, values=None, gids=None, *, max_groups: int = 1,
               states=None, block: int = 8192, kernel: str = "auto",
               cf_operands=None):
    """Accumulate every UDA in ``udas`` over one column of tuples.

    udas:    {name: UDA}.  Streaming UDAs share ONE blocked ``lax.scan``
             (each tuple block is read once and fed to every update);
             non-streaming UDAs (MinMax) consume the full column.
    probs:   (n,) tuple probabilities, already masked (invalid rows p = 0).
    values:  (n,) array shared by all UDAs, or {name: (n,) array} for
             per-aggregate value columns; None means all-ones (COUNT).
    gids:    (n,) int group ids in [0, max_groups); None = all group 0.
    states:  optional prior states to continue from (default: init).
    kernel:  'auto' | 'pallas' | 'xla' — 'auto' dispatches eligible
             accumulations (scalar CF / cumulants, grouped CF) to the
             Pallas kernels on TPU backends.
    cf_operands: optional {name: operands} pre-sorted grouped-CF kernel
             columns for this call's tuples (see :func:`cf_chunk_operands`)
             — used only when the named UDA actually dispatches to the
             grouped kernel, ignored otherwise.

    Returns {name: state}.
    """
    probs = jnp.asarray(probs)
    dtype = probs.dtype
    n = probs.shape[0]
    gids_full = (jnp.zeros((n,), jnp.int32) if gids is None
                 else jnp.asarray(gids))

    # Normalise values to one array per UDA, deduplicated by identity so the
    # scan carries each distinct column once.
    if not isinstance(values, dict):
        values = {name: values for name in udas}
    ones = None
    # Convert each distinct source column exactly once, keyed on the
    # caller's object (alive in `values` for the whole call, so ids are
    # stable): a column shared by several UDAs keeps one scan-carried copy
    # even when the cast to the prob dtype would otherwise fork it.  The
    # pre-cast source rides along in `val_sources` — the exact-CF kernels
    # consume integer columns directly (a float32 round-trip would corrupt
    # values above 2^24).
    casts: dict = {}
    val_arrays, val_index, val_integral, val_sources = [], {}, [], []
    for name in udas:
        v = values.get(name)
        if v is None:
            if ones is None:
                ones = jnp.ones((n,), dtype)
            v = src = ones
            integral = True        # COUNT: all-ones
        else:
            if id(v) not in casts:
                s = jnp.asarray(v)
                casts[id(v)] = (
                    s.astype(dtype) if s.dtype != dtype else s,
                    _integral_dtype(s.dtype), s)
            v, integral, src = casts[id(v)]
        for i, existing in enumerate(val_arrays):
            if existing is v:
                val_index[name] = i
                break
        else:
            val_index[name] = len(val_arrays)
            val_arrays.append(v)
            val_integral.append(integral)
            val_sources.append(src)

    if states is None:
        states = {}
    states = dict(states)
    fresh = {name for name in udas if name not in states}
    for name in fresh:
        # Fresh non-streaming states stay unmaterialized: accumulate_full
        # receives None and skips the no-op merge with the init buffer.
        if udas[name].streaming:
            states[name] = udas[name].init(
                _groups_of(udas[name], max_groups), dtype)

    use_pallas = _use_pallas(kernel)

    scan_udas, full_udas, kernel_udas = {}, {}, {}
    for name, u in udas.items():
        if not u.streaming:
            full_udas[name] = u
        elif use_pallas and _kernel_eligible(
                u, max_groups, probs, val_integral[val_index[name]]):
            kernel_udas[name] = u
        else:
            scan_udas[name] = u

    for name, u in full_udas.items():
        g_u = jnp.zeros_like(gids_full) if u.scalar else gids_full
        # A fresh init state is passed as None so non-streaming UDAs can
        # skip the no-op merge with it (MinMax: merge(init, x) == x).
        states[name] = u.accumulate_full(
            None if name in fresh else states[name], probs,
            val_arrays[val_index[name]], g_u, _groups_of(u, max_groups))
    for name, u in kernel_udas.items():
        # CF kernels take the pre-cast (integer) source; the cumulant
        # kernel computes float value powers and takes the cast column.
        i = val_index[name]
        vals = val_sources[i] if isinstance(u, SumCF) else val_arrays[i]
        ops_u = cf_operands.get(name) if cf_operands else None
        states[name] = _kernel_accumulate(u, states[name], probs, vals,
                                          gids_full, max_groups,
                                          operands=ops_u)
    if not scan_udas:
        return states

    bsz = _block_size(scan_udas, block, n)
    nfull = ((n + bsz - 1) // bsz) * bsz
    pad = nfull - n
    p = jnp.pad(probs, (0, pad))                    # p = 0: no contribution
    g = jnp.pad(gids_full, (0, pad), constant_values=max_groups - 1)
    vs = tuple(jnp.pad(v, (0, pad)) for v in val_arrays)

    def body(carry, chunk):
        pc, gc, vc = chunk
        return {name: u.update(carry[name], pc, vc[val_index[name]], gc)
                for name, u in scan_udas.items()}, None

    init = {name: states[name] for name in scan_udas}
    chunks = (p.reshape(-1, bsz), g.reshape(-1, bsz),
              tuple(v.reshape(-1, bsz) for v in vs))
    from ..models.runmode import unroll_mode
    if unroll_mode():
        carry = init
        for i in range(nfull // bsz):
            carry, _ = body(carry, jax.tree.map(lambda c: c[i], chunks))
    else:
        carry, _ = jax.lax.scan(body, init, chunks)
    states.update(carry)
    return states


def merge(udas, a, b):
    """Merge two state dicts UDA-wise (any merge tree gives the same result)."""
    return {name: u.merge(a[name], b[name]) for name, u in udas.items()}


def tree_fold(u: UDA, parts):
    """Fold partial states with ``u.merge`` in the ONE canonical tree
    shape: a balanced pairwise tree over the largest power-of-two prefix,
    then a sequential left fold of the tail leaves.

    For a power-of-two leaf count this is exactly the balanced pairwise
    tree; the pow2-base + sequential-tail form extends the fixed shape to
    ANY chunk count.  The tree depends only on the leaf count — never on
    how leaves are distributed over shards — which is the
    bit-reproducibility contract of :func:`accumulate_chunked`: the
    sharded frontend computes every canonical chunk's state on exactly one
    shard, gathers all C chunk states, and every shard finishes this SAME
    tree (``db.distributed.allgather_merge``), so any shard count — power
    of two or not — reproduces the single-device fold bit for bit.
    """
    parts = list(parts)
    if not parts:
        raise ValueError("tree_fold needs at least one partial state")
    base_len = 1 << (len(parts).bit_length() - 1)   # largest pow2 <= len
    base, tail = parts[:base_len], parts[base_len:]
    while len(base) > 1:
        base = [u.merge(base[i], base[i + 1])
                for i in range(0, len(base), 2)]
    out = base[0]
    for t in tail:
        out = u.merge(out, t)
    return out


def accumulate_chunk_states(udas, probs, values=None, gids=None, *,
                            max_groups: int = 1, num_chunks: int = 8,
                            block: int = 8192, kernel: str = "auto",
                            cf_operands=None) -> list:
    """Per-canonical-chunk partial states: the Accumulate half of
    :func:`accumulate_chunked`, without the fold.

    The tuple column is split into ``num_chunks`` contiguous equal chunks
    (zero-padded with p = 0 rows to a chunk multiple); each chunk runs the
    ONE canonical loop (:func:`accumulate`) independently.  Returns the
    list of per-chunk ``{name: state}`` dicts in chunk order — the sharded
    frontend gathers these across shards so every shard can finish the
    identical :func:`tree_fold`.

    ``cf_operands``: optional ``{name: [per-chunk operands]}`` pre-sorted
    grouped-CF kernel operands (:func:`cf_chunk_operands`) so the exact-CF
    frequency-slab loop pays the argsort once, not once per slab.
    """
    probs = jnp.asarray(probs)
    n = probs.shape[0]
    csz = -(-n // num_chunks)
    pad = csz * num_chunks - n
    if pad:
        probs = jnp.pad(probs, (0, pad))
    if gids is not None and pad:
        gids = jnp.pad(jnp.asarray(gids), (0, pad),
                       constant_values=max_groups - 1)
    if not isinstance(values, dict):
        values = {name: values for name in udas}
    # Pad each distinct source column once so aggregates sharing a column
    # keep sharing it (accumulate dedups value columns by identity).
    cols: dict = {}
    cache: dict = {}
    for name in udas:
        v = values.get(name)
        if v is None:
            cols[name] = None
            continue
        if id(v) not in cache:
            a = jnp.asarray(v)
            cache[id(v)] = jnp.pad(a, (0, pad)) if pad else a
        cols[name] = cache[id(v)]
    parts = []
    for i in range(num_chunks):
        sl = slice(i * csz, (i + 1) * csz)
        ccache: dict = {}
        vals_i = {name: None if c is None else ccache.setdefault(id(c), c[sl])
                  for name, c in cols.items()}
        ops_i = ({name: per_chunk[i]
                  for name, per_chunk in cf_operands.items()}
                 if cf_operands else None)
        parts.append(accumulate(udas, probs[sl], vals_i,
                                None if gids is None else gids[sl],
                                max_groups=max_groups, block=block,
                                kernel=kernel, cf_operands=ops_i))
    return parts


class ChunkStateAccumulator:
    """Cross-wave chunk-state collection: the out-of-core entry point of
    the canonical chunk contract.

    The streamed executor (``db/plans.py``) computes per-canonical-chunk
    partial states one WAVE at a time — each wave covers a set of chunk
    slots and yields their state dicts via :func:`accumulate_chunk_states`
    + a cross-shard gather.  This accumulator files each wave's states
    under their global canonical chunk ids, drops padding slots (ids at or
    past ``num_chunks`` — the shard-alignment and wave-alignment chunks,
    whose states are pure identities), and :meth:`fold` finishes the ONE
    fixed :func:`tree_fold` over exactly the ``num_chunks`` canonical
    leaves.  Because each chunk's state is computed from that chunk's rows
    alone and the fold tree depends only on the leaf count, the result is
    bit-identical to :func:`accumulate_chunked` on the resident table —
    for ANY wave schedule.
    """

    def __init__(self, udas: dict, num_chunks: int):
        self.udas = udas
        self.num_chunks = num_chunks
        self._chunks: list = [None] * num_chunks

    @property
    def filed(self) -> int:
        """Canonical chunks collected so far — the wave-resume checkpoint
        marker: a retried wave must only bring chunks not yet filed."""
        return sum(st is not None for st in self._chunks)

    def add_wave(self, chunk_ids, parts: list) -> None:
        """File one wave's per-chunk state dicts under their global
        canonical chunk ids (parallel lists; waves partition the slots, so
        each canonical chunk arrives exactly once)."""
        for g, st in zip(chunk_ids, parts):
            if g < self.num_chunks:
                assert self._chunks[g] is None, f"chunk {g} seen twice"
                self._chunks[g] = st

    def fold(self) -> dict:
        """The canonical fold over all collected chunks — call after the
        last wave."""
        missing = [g for g, st in enumerate(self._chunks) if st is None]
        assert not missing, f"canonical chunks never streamed: {missing}"
        return {name: tree_fold(u, [c[name] for c in self._chunks])
                for name, u in self.udas.items()}


def accumulate_chunked(udas, probs, values=None, gids=None, *,
                       max_groups: int = 1, num_chunks: int = 8,
                       block: int = 8192, kernel: str = "auto",
                       cf_operands=None):
    """Canonical chunk-grid Accumulate + tree Merge (the sharded-frontend
    accumulation semantics).

    :func:`accumulate_chunk_states` computes one partial state per
    contiguous chunk and the partials fold in the fixed pow2-base +
    sequential-tail tree of :func:`tree_fold`.  The plan compiler uses the
    same grid in every compile: on a mesh each shard computes the states
    of its contiguous chunk run and the cross-shard Merge
    (``db.distributed.allgather_merge``) gathers ALL chunk states and
    finishes the SAME tree — which is what makes
    ``compile_plan(root, mesh)`` outputs bit-identical to the
    single-device compile for ANY shard count.
    """
    if num_chunks <= 1:
        ops_0 = ({name: per_chunk[0]
                  for name, per_chunk in cf_operands.items()}
                 if cf_operands else None)
        return accumulate(udas, probs, values, gids, max_groups=max_groups,
                          block=block, kernel=kernel, cf_operands=ops_0)
    parts = accumulate_chunk_states(udas, probs, values, gids,
                                    max_groups=max_groups,
                                    num_chunks=num_chunks, block=block,
                                    kernel=kernel, cf_operands=cf_operands)
    return {name: tree_fold(u, [p[name] for p in parts])
            for name, u in udas.items()}


def cf_chunk_operands(num_freq: int, probs, values, gids, *,
                      max_groups: int, num_chunks: int,
                      kernel: str = "auto"):
    """Pre-sorted per-chunk grouped-CF kernel operands for an exact-CF
    aggregation, or None when the Pallas kernel would not be dispatched.

    The exact-CF frequency-slab loop re-runs :func:`accumulate` once per
    slab over the SAME tuples; the grouped kernel's argsort(gids) and
    split-modmult operand prep depend only on (values, gids, num_freq) —
    not on the slab window — so the planner calls this once per
    aggregation pass and threads the result through every slab's
    ``cf_operands``.  Mirrors the dispatch guards of :func:`accumulate`
    (backend, dtype, size, integrality); a None return means the caller
    should simply not pass operands (the scan/oracle paths sort nothing).
    """
    from ..kernels import ops as kops
    probs = jnp.asarray(probs)
    n = probs.shape[0]
    if n % num_chunks:
        return None            # planner columns divide the grid exactly
    csz = n // num_chunks
    if values is None:
        vals = jnp.ones((n,), probs.dtype)
        integral = True        # COUNT: all-ones
    else:
        vals = jnp.asarray(values)
        integral = _integral_dtype(vals.dtype)
    probe = SumCF(num_freq)    # static freq_lo=0: same verdict as any slab
    if not (_use_pallas(kernel)
            and _kernel_eligible(probe, max_groups, probs[:csz], integral)):
        return None
    g = (jnp.zeros((n,), jnp.int32) if gids is None
         else jnp.asarray(gids))
    return [kops.presort_group_operands(probs[i * csz:(i + 1) * csz],
                                        vals[i * csz:(i + 1) * csz],
                                        g[i * csz:(i + 1) * csz], num_freq)
            for i in range(num_chunks)]


def reduce_collective(udas, states, data_axes, model_axis=None):
    """The distributed Merge: one psum (or gather-fold) per UDA over the
    tuple-sharding axes, then model-axis reconciliation.  Call inside
    shard_map."""
    out = {}
    for name, u in udas.items():
        st = u.reduce_data(states[name], data_axes)
        if model_axis is not None:
            st = u.reduce_model(st, model_axis)
        out[name] = st
    return out


def finalize(udas, states):
    return {name: u.finalize(states[name]) for name, u in udas.items()}
