"""Dense PGF value type and exact polynomial products (paper §IV-B..D, §V).

A PGF over an *integer* support grid is stored densely:

    ``coeffs[k] = P(A = offset + k)``            (k = 0..K-1)
    ``p_pos_inf = P(A = +inf)``  (MIN neutral)   ``p_neg_inf = P(A = -inf)``

The paper's generalized-exponents polynomials allow real exponents; for exact
computation it restricts to integers {0..m} (rationals via scaling, §V-C.2) —
we do the same.  Real-valued supports are handled by the approximation layer
(:mod:`repro.core.approx`) exactly as in the paper.

Products:
  * :meth:`PGF.mul_sum`  — exponent addition = coefficient convolution
                           (schoolbook below FFT_THRESHOLD, else FFT),
                           the paper's §VII-B dispatch.
  * :meth:`PGF.mul_min` / :meth:`PGF.mul_max` — the ×_MIN / ×_MAX products of
                           §V-B via prefix/suffix survival sums, O(K) instead
                           of the paper's O(K²) pairwise term combination.
  * :func:`product_tree` — the paper's divide-and-conquer product, with each
                           tree level executed as one *batched* FFT (TPU
                           adaptation of FFTW plan-per-pair).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .config import default_float

# Paper §VII-B: "classical O(n^2) method for polynomials of degree smaller
# than [5000] and the O(n log^2 n) algorithm for larger".  Our crossover is
# lower because XLA's convolve is less favourable than hand-tuned schoolbook.
FFT_THRESHOLD = 1024


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PGF:
    """A probability generating function on an integer grid.

    ``coeffs`` is a dynamic (traced) array; ``offset`` is static metadata.
    Coefficients sum to 1 together with the two infinity masses
    (polynomial-monoid membership, Proposition 1).
    """

    coeffs: jnp.ndarray
    offset: int = 0
    p_pos_inf: jnp.ndarray | float = 0.0
    p_neg_inf: jnp.ndarray | float = 0.0

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.coeffs, self.p_pos_inf, self.p_neg_inf), (self.offset,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        coeffs, ppi, pni = children
        return cls(coeffs, aux[0], ppi, pni)

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_scalar(cls, value: int, dtype=None):
        """gamma(a) = X^a — the deterministic embedding (paper §IV-E)."""
        dtype = dtype or default_float()
        return cls(jnp.ones((1,), dtype), int(value))

    @classmethod
    def bernoulli(cls, p, value: int, monoid_name: str = "SUM", dtype=None):
        """(1-p)·X^neutral + p·X^value — one tuple's PGF (paper §IV-F step 2)."""
        dtype = dtype or default_float()
        p = jnp.asarray(p, dtype)
        if monoid_name in ("SUM", "COUNT"):
            value = 1 if monoid_name == "COUNT" else int(value)
            lo, hi = min(0, value), max(0, value)
            coeffs = jnp.zeros((hi - lo + 1,), dtype)
            coeffs = coeffs.at[0 - lo].add(1 - p).at[value - lo].add(p)
            return cls(coeffs, lo)
        if monoid_name == "MIN":   # absent tuple contributes X^{+inf}
            return cls(jnp.array([p], dtype), int(value), p_pos_inf=1 - p)
        if monoid_name == "MAX":
            return cls(jnp.array([p], dtype), int(value), p_neg_inf=1 - p)
        raise ValueError(monoid_name)

    # -- basic properties ---------------------------------------------------
    @property
    def support(self) -> jnp.ndarray:
        return self.offset + jnp.arange(self.coeffs.shape[0])

    def total_mass(self):
        return self.coeffs.sum() + self.p_pos_inf + self.p_neg_inf

    def normalize(self) -> "PGF":
        z = self.total_mass()
        return PGF(self.coeffs / z, self.offset, self.p_pos_inf / z,
                   self.p_neg_inf / z)

    def mass_at(self, value):
        """P(A = value); handles out-of-support gracefully."""
        idx = jnp.asarray(value) - self.offset
        k = self.coeffs.shape[0]
        ok = (idx >= 0) & (idx < k)
        return jnp.where(ok, self.coeffs[jnp.clip(idx, 0, k - 1)], 0.0)

    def cdf(self, value):
        """P(A <= value) over the finite support plus -inf mass."""
        idx = jnp.asarray(value) - self.offset
        cum = jnp.cumsum(self.coeffs)
        k = self.coeffs.shape[0]
        below = idx < 0
        val = cum[jnp.clip(idx, 0, k - 1)]
        return self.p_neg_inf + jnp.where(below, 0.0, jnp.where(idx >= k, cum[-1], val))

    def mean(self):
        return jnp.sum(self.coeffs * self.support.astype(self.coeffs.dtype))

    def variance(self):
        s = self.support.astype(self.coeffs.dtype)
        mu = self.mean()
        return jnp.sum(self.coeffs * (s - mu) ** 2)

    def confidence_interval(self, gamma: float = 0.95):
        """Central interval [lo, hi] with P(lo <= A <= hi) >= gamma (Fig. 5 ADT)."""
        tail = (1.0 - gamma) / 2.0
        cum = jnp.cumsum(self.coeffs)
        lo = jnp.searchsorted(cum, tail)
        hi = jnp.searchsorted(cum, 1.0 - tail)
        return self.offset + lo, self.offset + jnp.minimum(hi, self.coeffs.shape[0] - 1)

    # -- products (Theorem 1 in each monoid) --------------------------------
    def mul_sum(self, other: "PGF") -> "PGF":
        """PGF of A + B: exponents add ⇒ coefficient convolution (§V-A/C)."""
        k1, k2 = self.coeffs.shape[0], other.coeffs.shape[0]
        if min(k1, k2) * max(k1, k2) <= FFT_THRESHOLD ** 2 and max(k1, k2) <= FFT_THRESHOLD:
            out = jnp.convolve(self.coeffs, other.coeffs)          # schoolbook
        else:
            out = fft_convolve(self.coeffs, other.coeffs)          # paper's FFTW path
        return PGF(out, self.offset + other.offset)

    def _survival(self):
        """P(A >= s_k) including +inf mass, aligned with self.support."""
        rev = jnp.cumsum(self.coeffs[::-1])[::-1]
        return rev + self.p_pos_inf

    def mul_min(self, other: "PGF") -> "PGF":
        """×_MIN of §V-B: P(min=k) = P(A=k)P(B>=k) + P(A>k)P(B=k).

        The paper forms all pairwise terms (O(K²)); with suffix survival sums
        this is O(K) on the union grid — same numbers, TPU-friendly layout.
        """
        lo = min(self.offset, other.offset)
        hi = max(self.offset + self.coeffs.shape[0],
                 other.offset + other.coeffs.shape[0])
        a = _embed(self, lo, hi)
        b = _embed(other, lo, hi)
        sa, sb = a._survival(), b._survival()
        # P(A > k) = P(A >= k) - P(A = k)
        out = a.coeffs * sb + (sa - a.coeffs) * b.coeffs
        return PGF(out, lo, p_pos_inf=self.p_pos_inf * other.p_pos_inf)

    def mul_max(self, other: "PGF") -> "PGF":
        lo = min(self.offset, other.offset)
        hi = max(self.offset + self.coeffs.shape[0],
                 other.offset + other.coeffs.shape[0])
        a = _embed(self, lo, hi)
        b = _embed(other, lo, hi)
        ca = jnp.cumsum(a.coeffs) + a.p_neg_inf     # P(A <= k)
        cb = jnp.cumsum(b.coeffs) + b.p_neg_inf
        out = a.coeffs * cb + (ca - a.coeffs) * b.coeffs
        return PGF(out, lo, p_neg_inf=self.p_neg_inf * other.p_neg_inf)

    def mul(self, other: "PGF", monoid_name: str = "SUM") -> "PGF":
        if monoid_name in ("SUM", "COUNT"):
            return self.mul_sum(other)
        if monoid_name == "MIN":
            return self.mul_min(other)
        if monoid_name == "MAX":
            return self.mul_max(other)
        raise ValueError(monoid_name)

    # -- §V-B.2 truncation ---------------------------------------------------
    def truncate_smallest(self, kappa: int) -> "PGF":
        """Keep the κ smallest support values (MIN approximation §V-B.2).

        Dropped mass is *not* renormalised — it is reported as the +inf tail,
        mirroring the paper's 'eliminate the largest value' capacity rule.
        """
        k = min(kappa, self.coeffs.shape[0])
        dropped = self.coeffs[k:].sum()
        return PGF(self.coeffs[:k], self.offset,
                   p_pos_inf=self.p_pos_inf + dropped, p_neg_inf=self.p_neg_inf)

    def stretch(self, factor: int) -> "PGF":
        """Evaluate at X^factor: spread coefficients `factor` apart (§VII-D).

        For list item (3, 0.2z² + 0.3z + 0.5) the paper creates
        0.2z⁶ + 0.3z³ + 0.5 — exactly this operation.
        """
        factor = int(factor)
        if factor == 0:
            one = jnp.zeros((1,), self.coeffs.dtype).at[0].set(self.coeffs.sum())
            return PGF(one, 0, self.p_pos_inf, self.p_neg_inf)
        k = self.coeffs.shape[0]
        out = jnp.zeros(((k - 1) * factor + 1,), self.coeffs.dtype)
        out = out.at[::factor].set(self.coeffs)
        return PGF(out, self.offset * factor, self.p_pos_inf, self.p_neg_inf)

    def to_numpy(self):
        return np.asarray(self.coeffs), self.offset, float(self.p_pos_inf), float(self.p_neg_inf)


def _embed(f: PGF, lo: int, hi: int) -> PGF:
    """Re-grid a PGF onto [lo, hi) (static bounds)."""
    pad_l = f.offset - lo
    pad_r = (hi - lo) - pad_l - f.coeffs.shape[0]
    return PGF(jnp.pad(f.coeffs, (pad_l, pad_r)), lo, f.p_pos_inf, f.p_neg_inf)


def fft_convolve(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Real FFT linear convolution — the paper's FFTW product, via XLA FFT."""
    n = a.shape[0] + b.shape[0] - 1
    nfft = 1 << max(1, (n - 1).bit_length())
    fa = jnp.fft.rfft(a, nfft)
    fb = jnp.fft.rfft(b, nfft)
    out = jnp.fft.irfft(fa * fb, nfft)[:n]
    # Convolutions of probability vectors are nonnegative; clamp FFT noise.
    return jnp.clip(out, 0.0, None)


def convolve_batch(polys: jnp.ndarray) -> jnp.ndarray:
    """One divide-and-conquer tree *level*: multiply polys[2i] by polys[2i+1].

    polys: (B, K) with B even. Returns (B//2, 2K-1). Executed as a single
    batched FFT — the TPU replacement for FFTW plan-per-pair.
    """
    b, k = polys.shape
    n = 2 * k - 1
    nfft = 1 << max(1, (n - 1).bit_length())
    f = jnp.fft.rfft(polys, nfft, axis=-1)
    prod = f[0::2] * f[1::2]
    out = jnp.fft.irfft(prod, nfft, axis=-1)[:, :n]
    return jnp.clip(out, 0.0, None)


def product_tree(factors: jnp.ndarray, offsets: Sequence[int] | None = None) -> PGF:
    """Exact product of many small PGFs (paper §VII-B 'two by two ... until
    we get a single polynomial').

    factors: (B, K) equal-width coefficient rows (pad small ones with a
    leading 1-mass if needed).  Rows are multiplied pairwise level by level;
    odd rows are carried to the next level.  Total work O(n log² n).
    """
    rows = [factors[i] for i in range(factors.shape[0])]
    if offsets is None:
        offsets = [0] * len(rows)
    offset = sum(int(o) for o in offsets)
    while len(rows) > 1:
        if len(rows) % 2 == 1:
            carry, rows = rows[-1], rows[:-1]
        else:
            carry = None
        width = max(r.shape[0] for r in rows)
        batch = jnp.stack([jnp.pad(r, (0, width - r.shape[0])) for r in rows])
        merged = convolve_batch(batch)
        rows = [merged[i] for i in range(merged.shape[0])]
        if carry is not None:
            rows.append(jnp.pad(carry, (0, merged.shape[1] - carry.shape[0]))
                        if carry.shape[0] < merged.shape[1] else carry)
    return PGF(rows[0], offset)


def possible_worlds_pgf(probs, values, monoid_name: str = "SUM") -> dict:
    """Brute-force 2^n possible-worlds oracle (Fig. 2 semantics). Host-side,
    n <= ~20. Returns {outcome: probability} including math.inf/-math.inf."""
    from . import monoids as M
    probs = np.asarray(probs, dtype=np.float64)
    values = np.asarray(values, dtype=np.float64)
    m = M.BY_NAME[monoid_name]
    n = len(probs)
    out: dict = {}
    for world in range(1 << n):
        pr, acc = 1.0, m.neutral
        for i in range(n):
            if world >> i & 1:
                pr *= probs[i]
                v = 1.0 if monoid_name == "COUNT" else values[i]
                acc = acc + v if m.name in ("SUM", "COUNT") else (
                    min(acc, v) if m.name == "MIN" else max(acc, v))
            else:
                pr *= 1.0 - probs[i]
        out[acc] = out.get(acc, 0.0) + pr
    return out
