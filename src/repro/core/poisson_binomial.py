"""Exact COUNT / SUM distributions via log-domain characteristic functions.

This is the headline TPU adaptation of the paper's FFTW product tree
(DESIGN.md §2).  The COUNT PGF

    Q(X) = prod_i (q_i + p_i X)                       (paper Eq. 4)

is a degree-n polynomial; instead of multiplying factors pairwise we evaluate
Q at the (N)-th roots of unity w^k = exp(2*pi*i*k/N), N = n+1:

    log Q(w^k) = sum_i log(q_i + p_i w^k)

The product over billions of tuples becomes a **sum of complex logs** — an
additive reduction that maps onto one `psum` over the mesh — followed by a
single length-N FFT to recover the coefficients:

    coeffs = FFT(exp(logQ)) / N        (since Q_k = N * IFFT(coeffs)_k)

Branch cuts of the complex log are harmless: exp(sum of logs) equals the
product regardless of the 2*pi*i branch each term lands on.

SUM with nonnegative integer values a_i (§V-C.2) is the same machinery with
w^{k a_i}: one pass, O(n * M) VPU-friendly flops, M = sum(a_i).  The
paper-faithful alternative (group by value, COUNT per group, stretch, FFT
product tree, §V-C eq. for Q_M) is `sum_pgf_grouped` below; both are exact
and tested against each other and the possible-worlds oracle.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .config import default_float
from .pgf import PGF, product_tree


def logcf_terms(probs: jnp.ndarray, values: jnp.ndarray, num_freq: int,
                block: int = 4096):
    """Accumulated (sum over tuples) log CF at the num_freq DFT frequencies.

    Returns (log_abs_sum, angle_sum), each (num_freq,).  This is the
    `Accumulate` half of the CF UDA — the scalar view of the ONE
    implementation in :class:`repro.core.uda.SumCF`, run through the
    canonical blocked loop; `Merge` is elementwise `+` / `psum`.
    """
    from . import uda
    st = uda.accumulate({"cf": uda.SumCF(num_freq)}, probs, values, None,
                        max_groups=1, block=block)["cf"]
    return st.log_abs[0], st.angle[0]


def logcf_finalize(log_abs: jnp.ndarray, angle: jnp.ndarray) -> jnp.ndarray:
    """exp + FFT: recover the coefficient vector from summed log CF."""
    from . import uda
    return uda.SumCF(log_abs.shape[-1]).finalize(
        uda.CFState(log_abs[None], angle[None]))[0]


# Above this size the O(n log^2 n) FFT product tree beats the O(n*F)
# log-CF evaluation on a single host (the paper's §VII-B dispatch, one
# level up).  The log-CF stays the distributed/TPU path: bounded-F,
# one-psum-merge (DESIGN.md §2).
TREE_THRESHOLD = 8192


def count_pgf_tree(probs: jnp.ndarray) -> PGF:
    """Exact COUNT via the paper-faithful pairwise FFT product tree."""
    probs = jnp.asarray(probs, default_float())
    factors = jnp.stack([1.0 - probs, probs], axis=1)   # (n, 2) rows
    f = product_tree(factors)
    return PGF(f.coeffs[: probs.shape[0] + 1], 0)


def count_pgf(probs: jnp.ndarray, block: int = 4096,
              method: str = "auto") -> PGF:
    """Exact Poisson-binomial COUNT distribution (paper Eq. 4).

    method: 'cf' (log-CF + FFT), 'tree' (pairwise FFT product tree), or
    'auto' (paper §VII-B-style dispatch on size).
    """
    probs = jnp.asarray(probs, default_float())
    n = probs.shape[0]
    if method == "tree" or (method == "auto" and n >= TREE_THRESHOLD):
        return count_pgf_tree(probs)
    la, an = logcf_terms(probs, jnp.ones_like(probs), n + 1, block)
    return PGF(logcf_finalize(la, an), 0)


def sum_pgf(probs: jnp.ndarray, values: jnp.ndarray,
            max_sum: int | None = None, block: int = 4096,
            method: str = "auto") -> PGF:
    """Exact SUM distribution for nonnegative-integer values (§V-C.2).

    method 'auto' routes large single-host inputs to the paper-faithful
    grouped/stretch/FFT path (O(sum log^2) instead of O(n * sum)); 'cf'
    forces the log-CF path (the distributed building block).
    """
    dtype = default_float()
    probs = jnp.asarray(probs, dtype)
    values = jnp.asarray(values, dtype)
    if method == "grouped" or (method == "auto"
                               and probs.shape[0] >= TREE_THRESHOLD):
        return sum_pgf_grouped(probs, values)
    if max_sum is None:
        max_sum = int(np.asarray(jnp.sum(values)))
    la, an = logcf_terms(probs, values, max_sum + 1, block)
    return PGF(logcf_finalize(la, an), 0)


def sum_pgf_grouped(probs: jnp.ndarray, values: jnp.ndarray) -> PGF:
    """Paper-faithful SUM: group tuples by value, COUNT-PGF per group,
    'evaluate at X^{alpha_k}' by coefficient stretching, FFT product tree
    (§V-C general case + §VII-D implementation).  Host-driven loop over the
    d distinct values; exact, used as the baseline in §Perf.
    """
    probs_np = np.asarray(probs, np.float64)
    vals_np = np.asarray(values)
    distinct = np.unique(vals_np)
    factors: list[PGF] = []
    for alpha in distinct:
        sel = vals_np == alpha
        g = count_pgf(jnp.asarray(probs_np[sel]))
        if int(alpha) == 0:
            continue  # value-0 tuples do not move the sum
        factors.append(g.stretch(int(alpha)))
    if not factors:
        return PGF(jnp.ones((1,), default_float()), 0)
    acc = factors[0]
    for f in factors[1:]:
        acc = acc.mul_sum(f)
    return acc


# ------------------------------------------------------------------ sharded
def sharded_logcf(probs, values, num_freq: int, axis_name: str | tuple):
    """Per-shard accumulate + cross-shard psum merge, for use inside
    shard_map: tuples sharded over `axis_name`, frequencies replicated (or
    sharded over a different axis by the caller).  One collective total.
    """
    la, an = logcf_terms(probs, values, num_freq)
    la = jax.lax.psum(la, axis_name)
    an = jax.lax.psum(an, axis_name)
    return la, an
