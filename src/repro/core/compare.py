"""PGF ADT comparison operations (paper Fig. 5 and §VII-A).

Implements, for dense PGFs and for the approximation objects (anything with
``cdf`` / ``mass_at``):

    Equal / Greater / GreaterEq   vs scalar
    Equal / Greater / GreaterEq   vs another independent PGF
    confidence intervals

Scalar comparisons on a dense PGF reduce to prefix sums of the coefficient
vector; PGF-vs-PGF comparisons iterate one distribution and accumulate the
other's cdf/survival — the paper's §VII-A algorithm, vectorised.
"""
from __future__ import annotations

import jax.numpy as jnp

from .pgf import PGF


# -------------------------------------------------------------- vs scalar
def equal(f: PGF, a) -> jnp.ndarray:
    return f.mass_at(a)


def greater(f: PGF, a) -> jnp.ndarray:
    """P(F > a).  +inf mass counts as greater; cdf excludes it already."""
    return 1.0 - f.cdf(a)


def greater_eq(f: PGF, a) -> jnp.ndarray:
    return 1.0 - f.cdf(a) + f.mass_at(a)


def less(f: PGF, a) -> jnp.ndarray:
    return f.cdf(a) - f.mass_at(a)


def less_eq(f: PGF, a) -> jnp.ndarray:
    return f.cdf(a)


# ------------------------------------------------------------- vs PGF
def _aligned(f: PGF, g: PGF):
    lo = min(f.offset, g.offset)
    hi = max(f.offset + f.coeffs.shape[0], g.offset + g.coeffs.shape[0])
    fa = jnp.pad(f.coeffs, (f.offset - lo, hi - f.offset - f.coeffs.shape[0]))
    ga = jnp.pad(g.coeffs, (g.offset - lo, hi - g.offset - g.coeffs.shape[0]))
    return fa, ga


def equal_pgf(f: PGF, g: PGF) -> jnp.ndarray:
    """P(F = G) = sum_v P(F=v) P(G=v) over the shared domain (§VII-A),
    assuming independence (enforced by the hierarchical-query restriction)."""
    fa, ga = _aligned(f, g)
    return jnp.sum(fa * ga) + f.p_pos_inf * g.p_pos_inf + f.p_neg_inf * g.p_neg_inf


def greater_pgf(f: PGF, g: PGF) -> jnp.ndarray:
    """P(F > G) = sum_v P(G=v) P(F > v), ties at +/-inf excluded (§VII-A)."""
    fa, ga = _aligned(f, g)
    surv_f_finite = fa.sum() - jnp.cumsum(fa)  # P(F > v, F finite)
    finite = jnp.sum(ga * surv_f_finite)
    return (finite
            + f.p_pos_inf * (1.0 - g.p_pos_inf)   # F=+inf beats all but G=+inf
            + fa.sum() * g.p_neg_inf)             # F finite beats G=-inf


def greater_eq_pgf(f: PGF, g: PGF) -> jnp.ndarray:
    return greater_pgf(f, g) + equal_pgf(f, g)


# ------------------------------------------------ generic (approx objects)
def prob_greater(dist, a) -> float:
    """P(D > a) for any object exposing cdf (NormalApprox, GammaMixture)."""
    return float(1.0 - dist.cdf(a))


def prob_greater_eq(dist, a) -> float:
    if hasattr(dist, "mass_at"):
        return float(1.0 - dist.cdf(a) + dist.mass_at(a))
    return float(1.0 - dist.cdf(a))


def prob_equal(dist, a) -> float:
    return float(dist.mass_at(a))
