"""Numeric configuration for the PGF engine.

The PGF engine (the paper's contribution) is precision-sensitive: products over
millions of per-tuple factors and 8th-order cumulant sums want float64 on CPU.
The LM stack targets bf16/f32 on TPU and passes dtypes explicitly, so the two
subsystems never fight over a global default.

``default_float()`` returns float64 when the host has x64 enabled (tests and
CPU benchmarks enable it via ``enable_x64()``), else float32 (the TPU target,
where the distributed query step runs with the f32 log-CF kernel).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

def enable_x64() -> None:
    """Enable 64-bit mode. Call at entry points that need CPU f64 precision."""
    jax.config.update("jax_enable_x64", True)

def x64_enabled() -> bool:
    return bool(jax.config.jax_enable_x64)

def default_float():
    return jnp.float64 if x64_enabled() else jnp.float32

def default_complex():
    return jnp.complex128 if x64_enabled() else jnp.complex64

def default_int():
    return jnp.int64 if x64_enabled() else jnp.int32
