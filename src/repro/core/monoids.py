"""Aggregation monoids (paper §IV-F, Proposition 2).

A probabilistic aggregate is a sum of independent random variables carried out
in a monoid over the reals:

    SUM : (R, +,   0)
    MIN : (R, min, +inf)
    MAX : (R, max, -inf)
    COUNT = SUM after the translation T_COUNT(X^a) = X^1.

The PGF of the monoid-sum is the product of per-tuple PGFs where *exponent
addition* is the monoid operation (Theorem 1).  The neutral element is the
exponent contributed by an absent tuple: ``(1-p)·X^neutral + p·X^a``.

These objects are plain metadata consumed by the UDA layer and the dense-PGF
product routines; they carry no array state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Monoid:
    """An aggregation monoid (R, op, neutral)."""

    name: str
    op: Callable  # binary, works elementwise on jnp arrays
    neutral: float

    def fold(self, values):
        """Reference fold of a 1-D array in this monoid (host-side oracle)."""
        acc = self.neutral
        for v in values:
            acc = float(self.op(acc, v))
        return acc


SUM = Monoid("SUM", lambda a, b: a + b, 0.0)
MIN = Monoid("MIN", jnp.minimum, math.inf)
MAX = Monoid("MAX", jnp.maximum, -math.inf)
# COUNT is SUM over the translated values T_COUNT(a) = 1 (paper §IV-F step 1).
COUNT = Monoid("COUNT", lambda a, b: a + b, 0.0)

BY_NAME = {m.name: m for m in (SUM, MIN, MAX, COUNT)}


def translate(agg: str, values):
    """T_AGG from paper §IV-F: put tuple values in the aggregate's monoid.

    COUNT maps every value to 1.  SUM after MIN/MAX maps ±inf (the previous
    monoid's neutral) to 0; MIN after MAX maps -inf to +inf and vice versa.
    For plain scalar attributes this is the identity (COUNT aside).
    """
    values = jnp.asarray(values)
    if agg == "COUNT":
        return jnp.ones_like(values)
    target = BY_NAME[agg]
    # Re-map foreign neutral elements onto this monoid's neutral element.
    is_foreign_neutral = jnp.isinf(values) & (values != target.neutral)
    return jnp.where(is_foreign_neutral, target.neutral, values)
