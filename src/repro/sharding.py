"""GSPMD sharding rules: pod=DP, data=DP/FSDP, model=TP/EP (DESIGN.md §5).

One rule table serves all 10 heterogeneous architectures because every rule
is *divisibility-aware*: an axis that does not divide the dimension is
dropped (replicated) instead of failing — e.g. yi-6b's 4 KV heads on a
16-way model axis fall back to replicated KV, granite's MQA likewise.

Usage:
    rules = Rules(mesh, fsdp=True)
    with rules.activate():
        ... jit(step, in_shardings=rules.params_tree(shapes), ...) ...

Inside model code, ``sharding.constrain(x, "residual")`` applies the active
rule (no-op outside an activation context — models stay runnable on CPU
with no mesh).
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar("rules", default=None)


def _dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class Rules:
    """Sharding rule table bound to a mesh."""

    # parameter rules: leaf-name regex -> spec over the TRAILING dims.
    # 'dp' expands to the FSDP axis ('data') when fsdp=True, else None.
    PARAM_RULES = [
        (r"embed$",                ("model", "dp")),     # (V, D)
        (r"(wq|wk|wv|wqkv)$",      ("dp", "model")),     # (D, H*hd)
        (r"wo$",                   ("model", "dp")),     # (H*hd, D)
        (r"(w_in|w_gate)$",        ("dp", "model")),     # (D, F)
        (r"w_out$",                ("model", "dp")),     # (F, D)
        (r"(experts_in|experts_gate)$", ("model", "dp", None)),  # (E, D, F)
        (r"experts_out$",          ("model", None, "dp")),       # (E, F, D)
        (r"router$",               ("dp", None)),        # (D, E)
        (r"head$",                 ("dp", "model")),     # (D, V)
        (r"(w_a|w_ix|w_rg|w_x|w_y)$", ("dp", "model")),  # rglru dense (W, W)
        (r"w_rnn_out$",            ("model", "dp")),
        (r"(lora_a.*|lora_b.*)$",  (None, None)),
        (r".*",                    None),                # norms/bias/scalars
    ]

    ACT_RULES = {
        "residual":   lambda dp: P(dp, None, None),        # (B, S, D)
        "heads":      lambda dp: P(dp, None, "model", None),  # (B,S,H,hd)
        "kv_heads":   lambda dp: P(dp, None, "model", None),
        "ffn":        lambda dp: P(dp, None, "model"),     # (B, S, F)
        "logits":     lambda dp: P(dp, None, "model"),     # (B, S, V)
        "tokens":     lambda dp: P(dp, None),              # (B, S)
        "moe_buffer": lambda dp: P("model", None, None),   # (E, C, D)
        "moe_hidden": lambda dp: P("model", None, None),   # (E, C, F)
        "rnn_state":  lambda dp: P(dp, None),              # (B, W)
        "wkv_state":  lambda dp: P(dp, "model", None, None),  # (B,H,K,V)
    }

    def __init__(self, mesh: Mesh, fsdp: bool = True, sp: bool = False):
        self.mesh = mesh
        self.fsdp = fsdp
        # sp: shard the residual stream's d_model over the model axis
        # (sequence-parallel-style memory posture for the big configs;
        # XLA inserts all-gather/reduce-scatter at layer boundaries).
        self.sp = sp
        self.dp = _dp_axes(mesh)

    # ------------------------------------------------------------ params
    def _resolve(self, axes, shape):
        """Map rule axes onto the trailing dims of `shape`, dropping axes
        that are absent from the mesh or do not divide the dim."""
        if axes is None:
            return P()
        spec = [None] * len(shape)
        trailing = shape[len(shape) - len(axes):] if len(shape) >= len(axes) \
            else shape
        offset = len(shape) - len(trailing)
        for i, ax in enumerate(axes[-len(trailing):] if len(shape) < len(axes)
                               else axes):
            dim = trailing[i]
            name = "data" if ax == "dp" else ax
            if ax == "dp" and not self.fsdp:
                continue
            if name is None or name not in self.mesh.axis_names:
                continue
            if dim % self.mesh.shape[name] != 0:
                continue
            spec[offset + i] = name
        return P(*spec)

    def param_spec(self, path: str, shape) -> P:
        leaf = path.split("/")[-1]
        for pat, axes in self.PARAM_RULES:
            if re.fullmatch(pat, leaf):
                return self._resolve(axes, shape)
        return P()

    def params_tree(self, shapes_pytree):
        """NamedSharding pytree for a params pytree of ShapeDtypeStructs."""
        def visit(path, leaf):
            keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
            name = "/".join(str(k) for k in keys)
            return NamedSharding(self.mesh, self.param_spec(name, leaf.shape))
        return jax.tree_util.tree_map_with_path(visit, shapes_pytree)

    # --------------------------------------------------------- activations
    def act_spec(self, name: str, rank: int | None = None) -> P:
        if name == "residual" and self.sp:
            spec = P(self.dp, None, "model")
        else:
            spec = self.ACT_RULES[name](self.dp)
        # divisibility is handled by GSPMD padding for constraints; but drop
        # axes not in the mesh.
        parts = []
        for ax in spec:
            if isinstance(ax, tuple):
                kept = tuple(a for a in ax if a in self.mesh.axis_names)
                parts.append(kept if kept else None)
            elif ax is None or ax in self.mesh.axis_names:
                parts.append(ax)
            else:
                parts.append(None)
        return P(*parts)

    def input_sharding(self, name: str, shape) -> NamedSharding:
        spec = self.act_spec(name)
        # drop non-dividing axes for *input* shardings (jit is strict-er
        # about layouts we hand it than about internal constraints).
        parts = []
        for i, ax in enumerate(spec):
            if i >= len(shape):
                break
            axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            parts.append(ax if size and shape[i] % max(size, 1) == 0 else None)
        return NamedSharding(self.mesh, P(*parts))

    # ------------------------------------------------------------- context
    @contextlib.contextmanager
    def activate(self):
        token = _ACTIVE.set(self)
        try:
            yield self
        finally:
            _ACTIVE.reset(token)


def constrain(x, name: str):
    rules: Rules | None = _ACTIVE.get()
    if rules is None:
        return x
    try:
        spec = rules.act_spec(name)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, spec))
    except Exception:
        return x


def _spec_fits(mesh: Mesh, spec: P, shape) -> bool:
    for i, ax in enumerate(spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            if a not in mesh.axis_names:
                return False
            size *= mesh.shape[a]
        if i >= len(shape) or shape[i] % size != 0:
            return False
    return True


def constrain_first_fit(x, specs: Sequence[P]):
    """Constrain with the first spec whose named axes all exist and divide;
    no-op if none fit or no rules are active.  The mechanism behind
    divisibility-aware attention sharding across heterogeneous GQA configs.
    """
    rules: Rules | None = _ACTIVE.get()
    if rules is None:
        return x
    for spec in specs:
        if _spec_fits(rules.mesh, spec, x.shape):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(rules.mesh, spec))
    return x


def current_rules() -> Rules | None:
    return _ACTIVE.get()


def current_dp() -> tuple:
    """The active data-parallel axes, e.g. ('pod', 'data'); () if inactive."""
    rules = _ACTIVE.get()
    return rules.dp if rules is not None else ()
