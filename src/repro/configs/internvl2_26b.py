"""InternVL2-26B — InternViT frontend + InternLM2-20B backbone [arXiv:2404.16821; hf].

Assigned spec: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The ViT frontend is a STUB per assignment: input_specs deliver precomputed
patch embeddings (B, S, d_model); the backbone is the lowered/rooflined part.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    mlp="swiglu", rope_theta=1_000_000.0,
    embedding_inputs=True,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2_26b_smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, mlp="swiglu",
        embedding_inputs=True, dtype="float32",
    )
