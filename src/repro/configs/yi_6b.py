"""Yi-6B — llama-architecture GQA decoder [arXiv:2403.04652; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi_6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab_size=64000,
    mlp="swiglu", rope_theta=5_000_000.0,
    source="arXiv:2403.04652; hf:01-ai/Yi-6B",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi_6b_smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab_size=512, mlp="swiglu", dtype="float32",
    )
