"""RecurrentGemma-2B — Griffin: RG-LRU + local attention 1:2 [arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
window 2048.  Pattern: (recurrent, recurrent, local-attn) repeating; 26
layers = 8 full triplets + a trailing (recurrent, recurrent).  Sub-
quadratic => runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    mlp="swiglu", pattern=("rglru", "rglru", "attn_local"),
    tail_pattern=("rglru", "rglru"), window=2048,
    rglru_width=2560, conv_width=4, rnn_heads=10,
    subquadratic=True,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_2b_smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, mlp="swiglu",
        pattern=("rglru", "rglru", "attn_local"), window=16,
        rglru_width=64, conv_width=4, rnn_heads=4,
        subquadratic=True, dtype="float32",
    )
