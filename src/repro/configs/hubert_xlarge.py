"""HuBERT-XLarge — encoder-only audio transformer [arXiv:2106.07447].

48L d_model=1280 16H (kv=16, i.e. full MHA) d_ff=5120 vocab=504 (cluster
targets).  Encoder-only: bidirectional attention, no KV cache, no decode
shapes.  The CNN waveform frontend is a STUB: input_specs deliver
precomputed frame embeddings (B, S, d_model).  LayerNorm + GELU per the
wav2vec2 lineage; no rotary (conv positional embeddings stubbed out).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert_xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    mlp="gelu", norm="layer", causal=False, rotary_pct=0.0,
    attn_bias=True, embedding_inputs=True,
    source="arXiv:2106.07447",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hubert_xlarge_smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=64, mlp="gelu", norm="layer",
        causal=False, rotary_pct=0.0, attn_bias=True,
        embedding_inputs=True, dtype="float32",
    )
