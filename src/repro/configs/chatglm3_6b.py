"""ChatGLM3-6B — GQA kv=2, 2d (half-rotary) RoPE, qkv bias [arXiv:2406.12793; hf].

d_ff=13696 is already the gated hidden width (SwiGLU).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3_6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=65024,
    mlp="swiglu", rotary_pct=0.5, attn_bias=True,
    source="arXiv:2406.12793; hf:THUDM/chatglm3-6b",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chatglm3_6b_smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab_size=512, mlp="swiglu", rotary_pct=0.5,
        attn_bias=True, dtype="float32",
    )
