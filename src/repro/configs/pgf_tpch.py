"""The paper's own workload as an 11th dry-run cell: the distributed
PGF aggregate-query step (repro.db.distributed.make_query_step).

Not a ModelConfig — a query-step config.  `input_specs` mirror the LM
cells: tuple columns sharded over (pod, data), frequency grid over model.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class QueryConfig:
    name: str = "pgf_tpch"
    n_tuples: int = 1 << 28          # 268M probabilistic tuples (per step)
    max_groups: int = 4096
    num_freq: int = 1 << 16          # exact-CF distribution capacity
    orders: int = 8


CONFIG = QueryConfig()


def reduced() -> QueryConfig:
    return QueryConfig(name="pgf_tpch_smoke", n_tuples=4096, max_groups=64,
                       num_freq=256, orders=8)
