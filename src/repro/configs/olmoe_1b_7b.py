"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060; hf].

16L d_model=2048 16H (kv=16) vocab=50304; every MLP is MoE with
expert_d_ff=1024, no shared expert.  ~7B total, ~1.3B active.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe_1b_7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    mlp="swiglu", n_experts=64, top_k=8, expert_d_ff=1024,
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe_1b_7b_smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab_size=512, mlp="swiglu",
        n_experts=8, top_k=2, expert_d_ff=96, dtype="float32",
        # smoke scale: dropless capacity so prefill/decode agree exactly
        # (random-init routers are unbalanced; cf=1.25 drops tokens)
        capacity_factor=4.0,
    )
