"""Granite-34B-Code — GPT-BigCode lineage, MQA (kv=1) [arXiv:2405.04324; hf].

Non-gated GELU MLP (d_ff = 4*d_model), attention biases.  The released
model uses learned absolute positions; we adapt to RoPE for the shared
decode path (hardware-adaptation note in DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite_34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    mlp="gelu", attn_bias=True,
    source="arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite_34b_smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=256, vocab_size=512, mlp="gelu", attn_bias=True,
        dtype="float32",
    )
