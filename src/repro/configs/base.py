"""Architecture configuration schema + registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` / ``--arch <id>`` select them.
Each config also provides ``reduced()`` — the same family at smoke-test
scale — and ``input_specs(shape)`` returns ShapeDtypeStruct stand-ins for
every model input of a workload shape (no device allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Sequence

import jax
import jax.numpy as jnp

# The four assigned LM workload shapes (global).
SHAPES = {
    "train_4k":    dict(kind="train",   seq_len=4096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524288, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None     # default d_model // n_heads
    mlp: str = "swiglu"             # swiglu | gelu | relu2
    norm: str = "rms"               # rms | layer
    causal: bool = True             # False => encoder-only (no decode)
    rotary_pct: float = 1.0         # chatglm "2d" RoPE rotates half the dims
    rope_theta: float = 10000.0
    attn_bias: bool = False
    # hybrid / ssm layer pattern: one entry per layer in the repeating period
    # e.g. ("rglru", "rglru", "attn_local") for RecurrentGemma.  ("attn",) for
    # pure transformers; ("rwkv6",) for RWKV.
    pattern: tuple = ("attn",)
    # trailing layers that don't complete a period (recurrentgemma's final
    # (rglru, rglru)); applied unstacked after the period scan
    tail_pattern: tuple = ()
    window: int = 0                 # local-attention window (attn_local)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # rwkv6 / rglru
    rnn_heads: int = 0
    rglru_width: int = 0            # recurrence width (d_model multiple)
    conv_width: int = 4
    # modality frontend: inputs are precomputed embeddings, not token ids
    embedding_inputs: bool = False
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return all(p == "rwkv6" for p in self.pattern)

    @property
    def n_periods(self) -> int:
        body = self.n_layers - len(self.tail_pattern)
        assert body % len(self.pattern) == 0, \
            f"{self.name}: {body} body layers not divisible by pattern"
        return body // len(self.pattern)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n = v * d * (1 if self.tie_embeddings else 2)
        def per_layer(kind):
            per = 0
            if kind in ("attn", "attn_local"):
                per += d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
            elif kind == "rglru":
                w = self.rglru_width or d
                per += 2 * d * w + w * d + 2 * w * self.conv_width + 3 * w
            elif kind == "rwkv6":
                per += 4 * d * d + 2 * d * d // 16  # qkvg + lora decays
            if self.n_experts:
                per += self.n_experts * 3 * d * self.expert_d_ff
                per += self.n_shared_experts * 3 * d * self.d_ff
                per += d * self.n_experts
            else:
                mults = 3 if self.mlp == "swiglu" else 2
                per += mults * d * f
            return per + 2 * d  # + norms

        for kind in self.pattern:
            n += per_layer(kind) * self.n_periods
        for kind in self.tail_pattern:
            n += per_layer(kind)
        return n

    def active_param_count(self) -> int:
        """Active N per token (MoE: only routed top_k + shared experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() \
            - self.n_layers * self.n_experts * 3 * d * self.expert_d_ff
        active = self.n_layers * self.top_k * 3 * d * self.expert_d_ff
        return dense + active


def input_specs(cfg: ModelConfig, shape_name: str, *, batch_override=None):
    """ShapeDtypeStruct stand-ins for every model input of one workload cell.

    train:   {tokens (B,S) i32, labels (B,S) i32}     [embedding_inputs:
              embeds (B,S,D) bf16 instead of tokens]
    prefill: {tokens (B,S)}
    decode:  {tokens (B,1), cache (per-layer KV / recurrent state),
              cache_len ()}
    """
    from repro.models import api
    spec = SHAPES[shape_name]
    b = batch_override or spec["global_batch"]
    s = spec["seq_len"]
    dt = jnp.bfloat16
    tok = (jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
           if cfg.embedding_inputs else jax.ShapeDtypeStruct((b, s), jnp.int32))
    if spec["kind"] == "train":
        return dict(tokens=tok, labels=jax.ShapeDtypeStruct((b, s), jnp.int32))
    if spec["kind"] == "prefill":
        return dict(tokens=tok)
    # decode: one new token against an s-long cache
    cache = jax.eval_shape(lambda: api.init_cache(cfg, b, s, dtype=dt))
    tok1 = (jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
            if cfg.embedding_inputs else jax.ShapeDtypeStruct((b, 1), jnp.int32))
    return dict(tokens=tok1, cache=cache,
                cache_len=jax.ShapeDtypeStruct((), jnp.int32))


def runnable_cells(cfg: ModelConfig) -> list:
    """The (shape) cells this arch runs (DESIGN.md §4 skip rules)."""
    cells = ["train_4k", "prefill_32k"]
    if cfg.causal:
        cells.append("decode_32k")
        if cfg.subquadratic:
            cells.append("long_500k")
    return cells


ARCH_IDS = [
    "internvl2_26b", "yi_6b", "granite_34b", "nemotron_4_340b",
    "chatglm3_6b", "hubert_xlarge", "olmoe_1b_7b", "llama4_scout_17b_a16e",
    "recurrentgemma_2b", "rwkv6_1b6",
]


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.reduced()
