"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay [arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536; 32 WKV heads of dim 64.
O(1)-state decode => runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_1b6", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    mlp="rwkv_channel", pattern=("rwkv6",), rnn_heads=32,
    subquadratic=True,
    source="arXiv:2404.05892",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6_smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=224, vocab_size=512, mlp="rwkv_channel",
        pattern=("rwkv6",), rnn_heads=4,
        subquadratic=True, dtype="float32",
    )
