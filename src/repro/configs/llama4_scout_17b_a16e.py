"""Llama-4-Scout-17B-16E — 16-expert top-1 MoE + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (kv=8) d_ff=8192 vocab=202048; each MoE layer routes
top-1 over 16 experts and always adds one shared expert.  "Early fusion"
multimodality is out of scope per the assignment (text backbone only).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4_scout_17b_a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    mlp="swiglu", rope_theta=500_000.0,
    n_experts=16, top_k=1, expert_d_ff=8192, n_shared_experts=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4_scout_smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, mlp="swiglu",
        n_experts=4, top_k=1, expert_d_ff=128, n_shared_experts=1,
        dtype="float32", capacity_factor=4.0,
    )
