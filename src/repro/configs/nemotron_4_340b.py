"""Nemotron-4-340B — GQA, squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (kv=8) d_ff=73728 vocab=256000; partial rotary (50%).
The largest assigned cell: FSDP+TP+remat+microbatching gate (DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron_4_340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab_size=256000,
    mlp="relu2", rotary_pct=0.5,
    source="arXiv:2402.16819",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron_4_340b_smoke", family="dense",
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2,
        d_ff=384, vocab_size=512, mlp="relu2", rotary_pct=0.5,
        dtype="float32",
    )
