"""Assigned-architecture configs + the paper's own workload (pgf_tpch).

Select with ``get_config("<arch_id>")`` or ``--arch <id>`` on the
launchers.  Each module exports CONFIG (full published scale) and
``reduced()`` (smoke-test scale, same family).
"""
from .base import (ARCH_IDS, SHAPES, ModelConfig, get_config, get_reduced,
                   input_specs, runnable_cells)

__all__ = ["ARCH_IDS", "SHAPES", "ModelConfig", "get_config", "get_reduced",
           "input_specs", "runnable_cells"]
