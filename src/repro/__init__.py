"""repro — "Making Massive Probabilistic Databases Practical" (Todor et al.,
2013) as a multi-pod JAX framework.

Subsystems:
    repro.core      PGF probabilistic-aggregation engine (the paper)
    repro.db        probabilistic relational operators, TPC-H workload
    repro.kernels   Pallas TPU kernels for the engine's hot spots
    repro.models    assigned LM architectures (exercise the runtime)
    repro.train     optimizer / trainer / checkpoint / data substrate
    repro.launch    production meshes, dry-run, train/serve entry points
"""
__version__ = "1.0.0"
