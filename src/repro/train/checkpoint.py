"""Sharded, manifest-ed, atomically-committed checkpoints.

Layout of one checkpoint:

    <dir>/step_000123/
        manifest.json      step, flat key list, shapes/dtypes, mesh info,
                           per-file SHA-256 content hashes
        shard_00000.npz    this host's param/optimizer shards

Fault-tolerance contract:
  * write to  step_X.tmp-<nonce>/  then os.replace -> step_X/  (atomic on
    POSIX): a crash mid-save never corrupts the latest checkpoint;
  * every file carries a content hash, verified on restore;
  * `latest_step` scans for the newest COMMITTED checkpoint (tmp dirs are
    ignored), so restart-after-failure is `restore(dir, latest_step(dir))`;
  * restore accepts a different mesh (elastic): arrays are re-placed with
    the target sharding (train/elastic.py handles cross-mesh resharding).

Multi-host note: in a real pod each host saves the shards it owns
(`process_index` in the filename) and rank 0 writes the manifest; on this
single-process container that degenerates to one shard file, but the format
and the restore path are the multi-host ones.
"""
from __future__ import annotations

import hashlib
import json
import os
import secrets
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat, jax.tree_util.tree_structure(tree)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None):
    """Atomically save `tree` (params/opt state/anything pytree)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp-" + secrets.token_hex(4)
    os.makedirs(tmp)
    try:
        flat, _ = _flatten(tree)
        pidx = jax.process_index()
        shard_file = os.path.join(tmp, f"shard_{pidx:05d}.npz")
        np.savez(shard_file, **{k: np.asarray(v) for k, v in flat.items()})
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "shapes": {k: list(np.shape(v)) for k, v in flat.items()},
            "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat.items()},
            "process_count": jax.process_count(),
            "hashes": {os.path.basename(shard_file): _sha256(shard_file)},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and ".tmp" not in name \
                and os.path.isfile(os.path.join(ckpt_dir, name,
                                                "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedShardings for device placement (elastic restore)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    for fname, want in manifest["hashes"].items():
        got = _sha256(os.path.join(d, fname))
        if got != want:
            raise IOError(f"checkpoint corruption: {fname} hash mismatch")
    data = {}
    for name in os.listdir(d):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(d, name)) as z:
                data.update({k: z[k] for k in z.files})

    flat_like, _ = _flatten(like)
    missing = set(flat_like) - set(data)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        if key in flat_sh:
            arr = jax.device_put(arr, flat_sh[key])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest
