"""Deterministic, shardable data pipeline + the paper's PGF tie-in.

``TokenStream`` produces synthetic LM batches keyed only by (seed, step,
shard) — any host can regenerate any shard of any step, which is the
property that makes checkpoint-restart and straggler-failover trivial
(restart at step k needs no data-state file) and keeps multi-pod input
pipelines coordination-free.

``ProbabilisticSampler`` is the paper-as-substrate piece (DESIGN.md §3):
each example carries an inclusion probability p_i (quality weight /
dedup-confidence — the tuple-independence model applied to a training
corpus).  The sampler draws inclusion as independent Bernoullis, and the
PGF engine gives the *exact* distribution of the effective batch size
(Poisson-binomial, paper Eq. 4) — used to pick a padded batch capacity
with overflow probability < eps instead of a heuristic, and to report
exact per-mixture token-count distributions for data QC.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core import poisson_binomial as pb


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embedding_dim: int | None = None   # [vlm]/[audio]: emit embeddings

    def batch(self, step: int, shard: int = 0, num_shards: int = 1):
        """The (step, shard)-th batch slice; deterministic, stateless."""
        assert self.global_batch % num_shards == 0
        b = self.global_batch // num_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), shard)
        kt, kl = jax.random.split(key)
        if self.embedding_dim:
            tokens = jax.random.normal(
                kt, (b, self.seq_len, self.embedding_dim), jnp.float32)
        else:
            tokens = jax.random.randint(kt, (b, self.seq_len), 0,
                                        self.vocab_size)
        labels = jax.random.randint(kl, (b, self.seq_len), 0,
                                    self.vocab_size)
        return dict(tokens=tokens, labels=labels)


@dataclasses.dataclass
class ProbabilisticSampler:
    """Tuple-independent example inclusion; exact batch-size PGF."""

    inclusion_probs: np.ndarray        # (pool,) example inclusion probs
    seed: int = 0

    def batch_size_pgf(self):
        """Exact Poisson-binomial distribution of #included examples."""
        return pb.count_pgf(jnp.asarray(self.inclusion_probs, jnp.float64
                                        if jax.config.jax_enable_x64
                                        else jnp.float32))

    def capacity_for(self, eps: float = 1e-6) -> int:
        """Smallest capacity C with P(#included > C) < eps — the PGF ADT's
        GreaterEq answering a systems question exactly."""
        f = self.batch_size_pgf()
        cdf = np.cumsum(np.asarray(f.coeffs))
        idx = int(np.searchsorted(cdf, 1.0 - eps))
        return min(idx + 1, len(cdf))

    def draw(self, step: int):
        """Bernoulli world at this step (the 'random instance' of Fig. 2)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        u = jax.random.uniform(key, (len(self.inclusion_probs),))
        return np.asarray(u) < self.inclusion_probs
