"""Training substrate: optimizer, trainer, checkpointing, data, elasticity."""
from . import checkpoint, data, elastic, optimizer, trainer

__all__ = ["checkpoint", "data", "elastic", "optimizer", "trainer"]
