"""Elastic scaling: restore / reshard state across different meshes.

A checkpoint written under mesh A (say 2x16x16) must restore onto mesh B
(16x16, or a degraded 15-host pod) — that is what makes node failures
survivable without identical spare capacity.  Because checkpoints store
full logical arrays per key (host-sharded only along the process
dimension), resharding is a pure placement decision:

    reshard(tree, rules_B)   ->   device_put with mesh-B shardings

`degrade_mesh` builds the largest (data, model)-factorable mesh from a
reduced device count — the pod-loses-hosts path; `scale_batch` recomputes
per-shard batch so the global batch is preserved under the new data-axis
size (synchronous elastic semantics: the optimizer trajectory is unchanged
because the *global* batch, not the per-device batch, is the contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..sharding import Rules


def reshard(tree, rules: Rules):
    """Re-place every leaf with the sharding rules of a (new) mesh."""
    shapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    shardings = rules.params_tree(shapes)
    return jax.tree.map(jax.device_put, tree, shardings)


def degrade_mesh(devices, prefer_model: int = 16) -> Mesh:
    """Largest (data, model) mesh from an arbitrary device count.

    Keeps the model axis at the largest power-of-two divisor <= prefer_model
    so TP groups stay intact; leftover devices are dropped (they rejoin at
    the next resize) — the simple, deterministic policy a 1000-node fleet
    can agree on without coordination.
    """
    n = len(devices)
    model = 1
    while model * 2 <= prefer_model and n // (model * 2) >= 1 \
            and (model * 2) <= n:
        model *= 2
    data = n // model
    dev = devices[: data * model]
    import numpy as np
    return Mesh(np.asarray(dev).reshape(data, model), ("data", "model"))


def scale_batch(global_batch: int, mesh: Mesh) -> int:
    """Per-data-shard batch preserving the global batch (synchronous
    elasticity).  Requires divisibility; callers pad the batch up."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    assert global_batch % dp == 0, (global_batch, dp)
    return global_batch // dp
