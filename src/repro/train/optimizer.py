"""AdamW with dtype-configurable moments + int8 gradient compression.

No optax in this environment — the optimizer is a pair of pure functions
over pytrees, deliberately shaped like the UDA contract the paper uses for
its aggregates (init / accumulate-update), and sharding-transparent: moment
pytrees inherit parameter shardings under GSPMD.

Moments can be stored in bf16 (``moment_dtype``) — the memory gate for the
340B cell (DESIGN.md §5) — with f32 math at update time.

``compress_int8`` / ``decompress_int8`` implement per-tensor-max int8
quantisation with error feedback; ``compressed_psum`` is the shard_map
building block that all-reduces 4x fewer bytes across the pod axis (the
cross-pod link is the slow one).  Error feedback keeps the quantisation
noise from accumulating: the residual is carried and re-added next step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str | None = None     # None => same as param dtype
    warmup: int = 100

    def _mdt(self, p):
        return jnp.dtype(self.moment_dtype) if self.moment_dtype else p.dtype

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros_like(p, dtype=self._mdt(p))
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(z, params), jax.tree.map(z, params))

    def schedule(self, step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (s + 1.0) / self.warmup)
        return self.lr * warm

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr = self.schedule(state.step)
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            upd32 = (m32 / c1) / (jnp.sqrt(v32 / c2) + self.eps)
            upd32 = upd32 + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * upd32
            return (newp.astype(p.dtype), m32.astype(m.dtype),
                    v32.astype(v.dtype))

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, AdamWState(step, mu, nu)


# -------------------------------------------------- gradient compression
def compress_int8(g, err):
    """Quantise g + err to int8 with per-tensor max scaling.

    Returns (q, scale, new_err): decompress(q, scale) + new_err == g + err.
    """
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(g, err, axis_name: str):
    """All-reduce an int8-compressed gradient over `axis_name` (shard_map).

    The scale must be SHARED across the group (a sum of int8 payloads
    quantised with different scales is not decodable): one scalar pmax
    picks it, every shard quantises with it, the int8 payload is psum'd
    (XLA widens the accumulator), and the caller carries `new_err` to the
    next step (error feedback).  4x fewer bytes over the slow cross-pod
    links at the cost of one scalar collective.
    """
    x = g.astype(jnp.float32) + err
    local = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(local, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(1, axis_name)
    return total.astype(jnp.float32) * scale / n, new_err


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), n
