"""Training loop: microbatch accumulation, checkpoint-restart, failure
injection, straggler-free determinism.

`make_train_step` builds the jit'd step for any ModelConfig:

    (params, opt_state, batch) -> (params', opt_state', metrics)

with gradient accumulation as a lax.scan over microbatches (the pod-axis
all-reduce overlaps the next microbatch's backward under XLA's latency-
hiding scheduler — the accumulation structure is what makes that legal),
gradient clipping, and the AdamW update.  `Trainer` drives it with
checkpoint-every-N and restart-from-latest semantics; `run_with_failures`
is the fault-tolerance harness used by tests (kill the loop at arbitrary
steps, restart, assert bit-identical convergence vs an uninterrupted run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import api
from . import checkpoint as ckpt_lib
from .optimizer import AdamW, clip_by_global_norm


def make_train_step(cfg, opt: AdamW, *, accum: int = 1, remat: bool = True,
                    donate: bool = True, clip: float = 1.0,
                    accum_dtype=jnp.float32, jit: bool = True):
    """Build the train step with `accum` microbatches per step.

    accum_dtype: gradient-accumulator dtype (bf16 for the 340B memory gate).
    jit=False returns the raw callable (the dry-run jits it itself with
    explicit in_shardings).
    """

    def loss_of(params, tokens, labels):
        loss, metrics = api.loss_fn(cfg, params, tokens, labels, remat=remat)
        return loss, metrics

    def step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if accum > 1:
            b = tokens.shape[0]
            mb = b // accum
            tok = tokens.reshape(accum, mb, *tokens.shape[1:])
            lab = labels.reshape(accum, mb, *labels.shape[1:])

            def micro(carry, xs):
                g_acc, l_acc = carry
                (loss, m), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, xs[0], xs[1])
                g_acc = jax.tree.map(
                    lambda a, x: a + (x.astype(jnp.float32) / accum
                                      ).astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + loss / accum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              params)
            from ..models.runmode import unroll_mode
            if unroll_mode():
                carry = (g0, 0.0)
                for i in range(accum):
                    carry, _ = micro(carry, (tok[i], lab[i]))
                grads, loss = carry
            else:
                (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), (tok, lab))
        else:
            (loss, m), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, tokens, labels)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, dict(loss=loss, grad_norm=gnorm)

    if not jit:
        return step
    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums)


@dataclasses.dataclass
class Trainer:
    cfg: Any
    opt: AdamW
    stream: Any                          # train.data.TokenStream
    ckpt_dir: str
    accum: int = 1
    ckpt_every: int = 50
    remat: bool = True

    def __post_init__(self):
        self.step_fn = make_train_step(self.cfg, self.opt, accum=self.accum,
                                       remat=self.remat)

    def init_state(self, seed: int = 0):
        params = api.init_params(self.cfg, jax.random.PRNGKey(seed))
        return params, self.opt.init(params)

    def restore_or_init(self, seed: int = 0):
        last = ckpt_lib.latest_step(self.ckpt_dir)
        params, opt_state = self.init_state(seed)
        if last is None:
            return params, opt_state, 0
        like = {"params": params, "opt": opt_state}
        tree, manifest = ckpt_lib.restore(self.ckpt_dir, last, like)
        return tree["params"], tree["opt"], int(manifest["step"])

    def run(self, num_steps: int, *, seed: int = 0,
            fail_at: Callable[[int], bool] | None = None):
        """Train to `num_steps` global steps, restarting from the latest
        checkpoint.  `fail_at(step)` True simulates a node failure (raises
        after the optimizer update, before the checkpoint barrier —
        the worst-case crash point)."""
        params, opt_state, start = self.restore_or_init(seed)
        history = []
        for step in range(start, num_steps):
            batch = self.stream.batch(step)
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            history.append(float(metrics["loss"]))
            done = step + 1
            if done % self.ckpt_every == 0 or done == num_steps:
                ckpt_lib.save(self.ckpt_dir, done,
                              {"params": params, "opt": opt_state},
                              extra={"loss": history[-1]})
            if fail_at is not None and fail_at(step):
                raise RuntimeError(f"injected failure at step {step}")
        return params, opt_state, history


def run_with_failures(trainer: Trainer, num_steps: int,
                      fail_steps: set[int], seed: int = 0):
    """Drive `trainer` to completion across injected failures — the
    checkpoint-restart integration harness.  Each step in `fail_steps`
    kills the loop once; the loop restarts from the latest checkpoint.
    Returns (params, opt_state, history, attempts)."""
    fired: set[int] = set()

    def fail_at(s: int) -> bool:
        if s in fail_steps and s not in fired:
            fired.add(s)
            return True
        return False

    attempts = 0
    while True:
        attempts += 1
        try:
            params, opt_state, hist = trainer.run(num_steps, seed=seed,
                                                  fail_at=fail_at)
            return params, opt_state, hist, attempts
        except RuntimeError as e:
            if "injected failure" not in str(e):
                raise
