"""PGF ADT comparison operators (paper Fig. 5, §VII-A)."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compare as C
from repro.core.pgf import PGF
from repro.core.config import default_float


def mk(masses: dict, ppi=0.0, pni=0.0):
    lo, hi = min(masses), max(masses)
    coeffs = np.zeros(hi - lo + 1)
    for v, p in masses.items():
        coeffs[v - lo] = p
    return PGF(jnp.asarray(coeffs, default_float()), lo, ppi, pni)


def brute(fa: dict, ga: dict, op):
    return sum(pa * pb for (a, pa), (b, pb)
               in itertools.product(fa.items(), ga.items()) if op(a, b))


def test_scalar_comparisons():
    f = mk({1: 0.2, 3: 0.5, 6: 0.3})
    assert float(C.equal(f, 3)) == pytest.approx(0.5)
    assert float(C.equal(f, 2)) == 0.0
    assert float(C.greater(f, 3)) == pytest.approx(0.3)
    assert float(C.greater_eq(f, 3)) == pytest.approx(0.8)
    assert float(C.less(f, 3)) == pytest.approx(0.2)
    assert float(C.less_eq(f, 3)) == pytest.approx(0.7)


def test_pgf_vs_pgf(rng):
    fa = {1: 0.2, 3: 0.5, 6: 0.3}
    ga = {0: 0.1, 3: 0.4, 7: 0.5}
    f, g = mk(fa), mk(ga)
    assert float(C.equal_pgf(f, g)) == pytest.approx(
        brute(fa, ga, lambda a, b: a == b), abs=1e-12)
    assert float(C.greater_pgf(f, g)) == pytest.approx(
        brute(fa, ga, lambda a, b: a > b), abs=1e-12)
    assert float(C.greater_eq_pgf(f, g)) == pytest.approx(
        brute(fa, ga, lambda a, b: a >= b), abs=1e-12)


def test_pgf_vs_pgf_with_inf_masses():
    """MIN/MAX results carry +/-inf masses through comparisons."""
    fa = {2: 0.5}
    ga = {1: 0.3, 4: 0.3}
    f = mk(fa, ppi=0.5)            # P(F=+inf)=0.5
    g = mk(ga, pni=0.4)            # P(G=-inf)=0.4
    # brute force with inf outcomes
    fa_full = {**fa, 10 ** 9: 0.5}
    ga_full = {**ga, -10 ** 9: 0.4}
    assert float(C.greater_pgf(f, g)) == pytest.approx(
        brute(fa_full, ga_full, lambda a, b: a > b), abs=1e-12)
    assert float(C.equal_pgf(f, g)) == pytest.approx(
        brute(fa, ga, lambda a, b: a == b), abs=1e-12)


def test_comparisons_on_approx_objects(rng):
    from repro.core import approx
    probs = rng.uniform(0.2, 0.8, 2000)
    values = rng.integers(1, 10, 2000).astype(float)
    gm = approx.fit_from_data(probs, values, p=3)
    mu = float(np.sum(probs * values))
    assert C.prob_greater(gm, mu - 500) > 0.99
    assert C.prob_greater(gm, mu + 500) < 0.01
    assert 0.3 < C.prob_greater(gm, mu) < 0.7
