"""Training substrate: optimizer math, checkpoint-restart fault tolerance,
data determinism, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.train import checkpoint as ck
from repro.train.data import ProbabilisticSampler, TokenStream
from repro.train.optimizer import (AdamW, clip_by_global_norm, compress_int8,
                                   decompress_int8, global_norm)
from repro.train.trainer import Trainer, make_train_step, run_with_failures


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup=1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_bf16_moments_roundtrip():
    opt = AdamW(lr=1e-2, moment_dtype="bfloat16", warmup=1)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.mu["w"].dtype == jnp.bfloat16
    params, state = opt.update({"w": jnp.ones((4,), jnp.bfloat16)},
                               state, params)
    assert params["w"].dtype == jnp.bfloat16
    assert float(params["w"][0]) < 1.0


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert float(n) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-6)


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, 1000), jnp.float32)
    err = jnp.zeros_like(g)
    # accumulated decompressed signal converges to accumulated g
    total_sent = jnp.zeros_like(g)
    total_true = jnp.zeros_like(g)
    for _ in range(20):
        q, scale, err = compress_int8(g, err)
        total_sent = total_sent + decompress_int8(q, scale)
        total_true = total_true + g
    rel = float(jnp.abs(total_sent - total_true).max()
                / jnp.abs(total_true).max())
    assert rel < 1e-2


def test_checkpoint_atomic_and_verified(tmp_path):
    tree = {"w": jnp.arange(10.0), "b": {"x": jnp.ones((3,))}}
    d = str(tmp_path / "ck")
    ck.save(d, 7, tree)
    assert ck.latest_step(d) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    restored, manifest = ck.restore(d, 7, like)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    # corruption detection
    shard = os.path.join(d, "step_00000007", "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(50)
        f.write(b"\x00\x01\x02")
    with pytest.raises(IOError, match="corruption"):
        ck.restore(d, 7, like)


def test_checkpoint_restart_bit_identical(tmp_path):
    cfg = get_reduced("yi_6b")
    stream = TokenStream(cfg.vocab_size, seq_len=16, global_batch=4)
    opt = AdamW(lr=1e-3, warmup=5)
    t1 = Trainer(cfg, opt, stream, str(tmp_path / "a"), ckpt_every=3)
    p1, _, h1 = t1.run(8)
    t2 = Trainer(cfg, opt, stream, str(tmp_path / "b"), ckpt_every=3)
    p2, _, h2, attempts = run_with_failures(t2, 8, {4})
    assert attempts == 2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert bool(jnp.array_equal(a, b))


def test_grad_accumulation_equivalence():
    """accum=2 == accum=1 on the same global batch (linearity of grads)."""
    cfg = get_reduced("yi_6b")
    opt = AdamW(lr=0.0, weight_decay=0.0, warmup=1)   # lr=0: compare grads?
    # instead compare one step with lr>0
    opt = AdamW(lr=1e-2, weight_decay=0.0, warmup=1)
    step1 = make_train_step(cfg, opt, accum=1, donate=False)
    step2 = make_train_step(cfg, opt, accum=2, donate=False)
    from repro.models import api
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    key = jax.random.PRNGKey(1)
    batch = dict(tokens=jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
                 labels=jax.random.randint(key, (4, 16), 0, cfg.vocab_size))
    p1, _, m1 = step1(params, state, batch)
    p2, _, m2 = step2(params, state, batch)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 1e-4, d    # f32 association-order noise through AdamW


def test_token_stream_deterministic_and_shardable():
    s = TokenStream(1000, seq_len=8, global_batch=8, seed=3)
    b1 = s.batch(5)
    b2 = s.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    sh0 = s.batch(5, shard=0, num_shards=2)
    assert sh0["tokens"].shape[0] == 4
    assert not np.array_equal(np.asarray(sh0["tokens"]),
                              np.asarray(s.batch(5, shard=1,
                                                 num_shards=2)["tokens"]))


def test_probabilistic_sampler_capacity():
    """The PGF-backed capacity bound is sound: simulate inclusion draws."""
    rng = np.random.default_rng(0)
    probs = rng.uniform(0.2, 0.9, 128)
    s = ProbabilisticSampler(probs, seed=1)
    cap = s.capacity_for(1e-4)
    draws = np.array([s.draw(i).sum() for i in range(500)])
    assert (draws > cap).mean() < 0.01
    mean = float(s.batch_size_pgf().mean())
    assert mean == pytest.approx(probs.sum(), rel=1e-6)


def test_elastic_reshard_roundtrip(tmp_path):
    """Save under implicit single-device, restore + reshard to a 1x1 mesh
    (degenerate on CPU but exercises the code path end-to-end)."""
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import Rules
    from repro.train import elastic
    cfg = get_reduced("yi_6b")
    from repro.models import api
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    ck.save(d, 1, {"params": params})
    mesh = make_host_mesh()
    rules = Rules(mesh)
    like = {"params": jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)}
    tree, _ = ck.restore(d, 1, like)
    resharded = elastic.reshard(tree["params"], rules)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(resharded)):
        assert bool(jnp.array_equal(a, b))
