"""Golden tests for the logical -> physical lowering pass: which strategy
the planner picks at each budget, which partitioning property every node
carries, and the static shuffle bucket sizing — all without executing a
single table (physical.lower_plan is pure)."""
import pytest

from repro.db import physical as phys
from repro.db.plans import (FKJoin, GroupAgg, Map, Project, ReweightGreater,
                            Scan, Select)

CAPS = {"lineitem": 4096, "orders": 1024, "customer": 256}


def _q3ish(budget=None):
    li = Select(Scan("lineitem"), lambda t: t["x"] > 0)
    o = FKJoin(Scan("orders"), Scan("customer"), "o_custkey", "c_custkey",
               ("c_mktsegment",))
    j = FKJoin(li, o, "l_orderkey", "o_orderkey", ("o_orderdate",),
               gather_budget=budget)
    return GroupAgg(j, ("l_orderkey",), "l_quantity", "SUM", 512)


def test_single_device_lowers_fully_replicated():
    p = phys.lower_plan(_q3ish(), CAPS, n_shards=1, sharded=False)
    assert isinstance(p, phys.MergeAgg) and p.kind == "groupagg"
    assert isinstance(p.part, phys.Replicated)
    pa = p.child
    assert isinstance(pa, phys.PartialAgg)
    j = pa.child
    assert isinstance(j, phys.GatherJoin)       # never shuffles off-mesh
    assert isinstance(j.part, phys.Replicated)
    assert isinstance(j.right, phys.GatherJoin)


def test_strategy_flips_to_shuffle_at_the_budget():
    """The build side (orders joined customer: 1024 rows) gathers at
    budget >= 1024 and shuffles below it; the inner customer join (256)
    flips independently."""
    lowered = lambda b: phys.lower_plan(
        _q3ish(), CAPS, n_shards=4, sharded=True, join_gather_budget=b)
    big = lowered(1024).child.child
    assert isinstance(big, phys.GatherJoin)
    assert isinstance(big.right, phys.GatherJoin)
    mid = lowered(1023).child.child
    assert isinstance(mid, phys.ShuffleJoin)
    assert mid.build_rows == 1024
    assert mid.exchange == phys.HashPartitioned("o_orderkey")
    assert isinstance(mid.part, phys.RowBlocked)    # responses come home
    assert isinstance(mid.right, phys.GatherJoin)   # customer still small
    small = lowered(255).child.child
    assert isinstance(small, phys.ShuffleJoin)
    assert isinstance(small.right, phys.ShuffleJoin)
    assert small.right.exchange == phys.HashPartitioned("c_custkey")


def test_per_join_gather_budget_override_wins():
    """FKJoin.gather_budget overrides the global: mixed plans gather the
    small dim while shuffling the big one (and vice versa)."""
    p = phys.lower_plan(_q3ish(budget=1 << 20), CAPS, n_shards=4,
                        sharded=True, join_gather_budget=1)
    outer = p.child.child
    assert isinstance(outer, phys.GatherJoin)       # forced gather
    assert isinstance(outer.right, phys.ShuffleJoin)  # global budget 1
    p2 = phys.lower_plan(_q3ish(budget=1), CAPS, n_shards=4, sharded=True,
                         join_gather_budget=1 << 20)
    outer2 = p2.child.child
    assert isinstance(outer2, phys.ShuffleJoin)     # forced shuffle
    assert isinstance(outer2.right, phys.GatherJoin)


def test_replicated_build_or_probe_never_shuffles():
    """Group-level (Replicated) inputs can't hash-exchange: a join probing
    from a ReweightGreater output stays a GatherJoin even over budget."""
    rew = ReweightGreater(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                          "", 2048, threshold=1.0)
    j = FKJoin(rew, Scan("orders"), "l_orderkey", "o_orderkey", ("o_x",))
    p = phys.lower_plan(j, CAPS, n_shards=4, sharded=True,
                        join_gather_budget=1)
    assert isinstance(p, phys.GatherJoin)
    assert isinstance(p.left, phys.MergeAgg) and p.left.kind == "reweight"
    assert isinstance(p.part, phys.Replicated)      # = left's property


def test_partitioning_properties_propagate():
    plan = Map(Select(Scan("lineitem"), lambda t: t["x"]), "y",
               lambda t: t["x"])
    p = phys.lower_plan(plan, CAPS, n_shards=2, sharded=True)
    assert isinstance(p, phys.PhysMap)
    assert isinstance(p.part, phys.RowBlocked)
    assert isinstance(p.child.part, phys.RowBlocked)
    assert isinstance(p.child.child.part, phys.RowBlocked)


def test_agg_lowering_pairs_partial_and_merge():
    proj = Project(Scan("orders"), ("o_custkey",), 64)
    p = phys.lower_plan(proj, CAPS, n_shards=2, sharded=True)
    assert isinstance(p, phys.MergeAgg) and p.kind == "project"
    assert isinstance(p.child, phys.PartialAgg)
    assert p.child.specs == () and p.child.max_groups == 64
    assert isinstance(p.child.part, phys.RowBlocked)

    agg = GroupAgg(Scan("orders"), ("o_custkey",), "o_totalprice", "SUM",
                   128, "exact", num_freq=256,
                   extra=(("cnt", "", "COUNT", "normal"),))
    p = phys.lower_plan(agg, CAPS, n_shards=2, sharded=True)
    assert p.child.specs == (("exact", "o_totalprice", "SUM", "exact"),
                             ("cnt", "", "COUNT", "normal"))
    assert p.child.num_freq == 256


def test_lowering_validates_spec_names():
    bad = GroupAgg(Scan("orders"), ("o_custkey",), "o_totalprice", "SUM",
                   128, extra=(("valid", "", "COUNT", "normal"),))
    with pytest.raises(ValueError, match="unique and avoid"):
        phys.lower_plan(bad, CAPS)
    bad2 = ReweightGreater(Scan("orders"), ("o_custkey",), "o_totalprice",
                           "", 128)
    with pytest.raises(ValueError, match="threshold"):
        phys.lower_plan(bad2, CAPS)


def test_bucket_capacity_bounds():
    """slack x uniform share, floored at 1, capped at the sender's local
    rows (where overflow becomes impossible)."""
    assert phys.bucket_capacity(1024, 4, 4.0) == 1024   # slack >= shards
    assert phys.bucket_capacity(1024, 8, 4.0) == 512
    assert phys.bucket_capacity(1024, 8, 1.0) == 128
    assert phys.bucket_capacity(3, 8, 1.0) == 1         # floor
    sj = phys.lower_plan(
        FKJoin(Scan("lineitem"), Scan("orders"), "a", "b", ()), CAPS,
        n_shards=8, sharded=True, join_gather_budget=1, shuffle_slack=2.0)
    assert sj.build_bucket == phys.bucket_capacity(1024 // 8, 8, 2.0)
    assert sj.probe_bucket == phys.bucket_capacity(4096 // 8, 8, 2.0)


def test_explain_renders_every_node():
    text = phys.explain(phys.lower_plan(
        _q3ish(), CAPS, n_shards=4, sharded=True, join_gather_budget=1))
    for token in ("MergeAgg[groupagg]", "PartialAgg", "ShuffleJoin",
                  "HashPartitioned(o_orderkey)", "ShardScan(lineitem",
                  "RowBlocked", "Replicated"):
        assert token in text, (token, text)
