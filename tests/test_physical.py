"""Golden tests for the logical -> physical lowering pass: which strategy
the enumerate -> cost -> pick optimizer chooses at each budget override,
which partitioning property every node carries, the static shuffle bucket
sizing (slack and concrete-key adaptive), and the cost-annotated explain
rendering — all without executing a single table (physical.lower_plan is
pure up to the optional key histograms)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.db import physical as phys
from repro.db.plans import (FKJoin, GroupAgg, Map, Project, ReweightGreater,
                            Scan, Select)
from repro.db.table import Table

CAPS = {"lineitem": 4096, "orders": 1024, "customer": 256}


def _q3ish(budget=None, keys=("l_orderkey",)):
    li = Select(Scan("lineitem"), lambda t: t["x"] > 0)
    o = FKJoin(Scan("orders"), Scan("customer"), "o_custkey", "c_custkey",
               ("c_mktsegment",))
    j = FKJoin(li, o, "l_orderkey", "o_orderkey", ("o_orderdate",),
               gather_budget=budget)
    return GroupAgg(j, keys, "l_quantity", "SUM", 512)


def test_single_device_lowers_fully_replicated():
    p = phys.lower_plan(_q3ish(), CAPS, n_shards=1, sharded=False)
    assert isinstance(p, phys.MergeAgg) and p.kind == "groupagg"
    assert isinstance(p.part, phys.Replicated)
    pa = p.child
    assert isinstance(pa, phys.PartialAgg)
    j = pa.child
    assert isinstance(j, phys.GatherJoin)       # never shuffles off-mesh
    assert isinstance(j.part, phys.Replicated)
    assert isinstance(j.right, phys.GatherJoin)


def test_strategy_flips_at_the_pr4_budget_points():
    """The gather/exchange flip points are unchanged from PR 4 (the budget
    knob survives as a cost override): the build side (orders joined
    customer: 1024 rows) gathers at budget >= 1024 and hash-exchanges
    below it; the inner customer join (256) flips independently.  What
    runs above the flip is now the cost model's pick — for a GROUP BY on
    the probe join key that is the fused CoPartitionedJoin +
    PartitionedAgg pipeline."""
    lowered = lambda b: phys.lower_plan(
        _q3ish(), CAPS, n_shards=4, sharded=True, join_gather_budget=b)
    big = lowered(1024).child.child
    assert isinstance(big, phys.GatherJoin)
    assert isinstance(big.right, phys.GatherJoin)
    mid = lowered(1023)
    assert isinstance(mid.child, phys.PartitionedAgg)
    cj = mid.child.child
    assert isinstance(cj, phys.CoPartitionedJoin)
    assert cj.build_rows == 1024
    assert cj.part == phys.HashPartitioned("l_orderkey")
    assert cj.carry_cols == ("l_quantity",)     # pruned to the agg's needs
    assert cj.right_cols == ()                  # o_orderdate unused by it
    assert isinstance(cj.right, phys.GatherJoin)   # customer still small
    small = lowered(255).child.child
    assert isinstance(small, phys.CoPartitionedJoin)
    assert isinstance(small.right, phys.ShuffleJoin)
    assert small.right.exchange == phys.HashPartitioned("c_custkey")


def test_non_matching_keys_keep_the_pr4_shuffle_strategies():
    """A GROUP BY that does NOT key on the join key can't fuse: the PR-4
    ShuffleJoin + PartialAgg lowering survives at the same flip points."""
    lowered = lambda b: phys.lower_plan(
        _q3ish(keys=("l_partkey",)), CAPS, n_shards=4, sharded=True,
        join_gather_budget=b)
    assert isinstance(lowered(1024).child.child, phys.GatherJoin)
    mid = lowered(1023)
    assert isinstance(mid.child, phys.PartialAgg)
    assert isinstance(mid.child.child, phys.ShuffleJoin)
    assert mid.child.child.exchange == phys.HashPartitioned("o_orderkey")
    assert isinstance(mid.child.child.part, phys.RowBlocked)
    small = lowered(255).child.child
    assert isinstance(small, phys.ShuffleJoin)
    assert isinstance(small.right, phys.ShuffleJoin)


def test_copartition_override_forces_and_disables():
    """The ``copartition`` knob is a cost override: False restores the
    ShuffleJoin + PartialAgg pipeline, True forbids it whenever the fused
    pipeline is legal and the join may not gather."""
    off = phys.lower_plan(_q3ish(), CAPS, n_shards=4, sharded=True,
                          join_gather_budget=1, copartition=False)
    assert isinstance(off.child, phys.PartialAgg)
    assert isinstance(off.child.child, phys.ShuffleJoin)
    on = phys.lower_plan(_q3ish(), CAPS, n_shards=4, sharded=True,
                         join_gather_budget=1, copartition=True)
    assert isinstance(on.child, phys.PartitionedAgg)
    assert isinstance(on.child.child, phys.CoPartitionedJoin)
    # under budget the gather override still wins, even forced
    under = phys.lower_plan(_q3ish(), CAPS, n_shards=4, sharded=True,
                            join_gather_budget=1 << 20, copartition=True)
    assert isinstance(under.child, phys.PartialAgg)
    assert isinstance(under.child.child, phys.GatherJoin)


def test_per_join_gather_budget_override_wins():
    """FKJoin.gather_budget overrides the global: mixed plans gather the
    small dim while hash-exchanging the big one (and vice versa)."""
    p = phys.lower_plan(_q3ish(budget=1 << 20), CAPS, n_shards=4,
                        sharded=True, join_gather_budget=1)
    outer = p.child.child
    assert isinstance(outer, phys.GatherJoin)       # forced gather
    assert isinstance(outer.right, phys.ShuffleJoin)  # global budget 1
    p2 = phys.lower_plan(_q3ish(budget=1), CAPS, n_shards=4, sharded=True,
                         join_gather_budget=1 << 20)
    outer2 = p2.child.child
    assert isinstance(outer2, phys.CoPartitionedJoin)  # forced exchange
    assert isinstance(outer2.right, phys.GatherJoin)


def test_repartitioned_agg_at_the_agg_shuffle_budget():
    """``agg_shuffle_budget`` is the aggregation-side override: a
    single-key GROUP BY over more input rows hash-exchanges its tuples to
    per-group owners (Repartition + PartitionedAgg); at or under it (or
    with the knob off) the RowBlocked PartialAgg survives."""
    agg = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM",
                   128)
    low = lambda **kw: phys.lower_plan(agg, CAPS, n_shards=4, sharded=True,
                                       **kw)
    default = low()
    assert isinstance(default.child, phys.PartialAgg)
    on = low(agg_shuffle_budget=4)
    assert isinstance(on.child, phys.PartitionedAgg)
    rp = on.child.child
    assert isinstance(rp, phys.Repartition)
    assert rp.key == "l_orderkey"
    assert rp.carry_cols == ("l_quantity",)
    assert rp.part == phys.HashPartitioned("l_orderkey")
    off = low(agg_shuffle_budget=CAPS["lineitem"])
    assert isinstance(off.child, phys.PartialAgg)
    # multi-key aggregations can't hash on one column
    multi = GroupAgg(Scan("lineitem"), ("a", "b"), "l_quantity", "SUM", 128)
    p = phys.lower_plan(multi, CAPS, n_shards=4, sharded=True,
                        agg_shuffle_budget=4)
    assert isinstance(p.child, phys.PartialAgg)


def test_reweight_fused_ships_threshold_and_carry_columns():
    rew = ReweightGreater(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                          "l_thresh", 128, carry_cols=("l_extra",))
    p = phys.lower_plan(rew, CAPS, n_shards=4, sharded=True,
                        agg_shuffle_budget=4)
    assert isinstance(p.child, phys.PartitionedAgg)
    assert p.child.child.carry_cols == ("l_extra", "l_quantity", "l_thresh")


def test_replicated_build_or_probe_never_shuffles():
    """Group-level (Replicated) inputs can't hash-exchange: a join probing
    from a ReweightGreater output stays a GatherJoin even over budget."""
    rew = ReweightGreater(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                          "", 2048, threshold=1.0)
    j = FKJoin(rew, Scan("orders"), "l_orderkey", "o_orderkey", ("o_x",))
    p = phys.lower_plan(j, CAPS, n_shards=4, sharded=True,
                        join_gather_budget=1)
    assert isinstance(p, phys.GatherJoin)
    assert isinstance(p.left, phys.MergeAgg) and p.left.kind == "reweight"
    assert isinstance(p.part, phys.Replicated)      # = left's property


def test_partitioning_properties_propagate():
    plan = Map(Select(Scan("lineitem"), lambda t: t["x"]), "y",
               lambda t: t["x"])
    p = phys.lower_plan(plan, CAPS, n_shards=2, sharded=True)
    assert isinstance(p, phys.PhysMap)
    assert isinstance(p.part, phys.RowBlocked)
    assert isinstance(p.child.part, phys.RowBlocked)
    assert isinstance(p.child.child.part, phys.RowBlocked)


def test_agg_lowering_pairs_partial_and_merge():
    proj = Project(Scan("orders"), ("o_custkey",), 64)
    p = phys.lower_plan(proj, CAPS, n_shards=2, sharded=True)
    assert isinstance(p, phys.MergeAgg) and p.kind == "project"
    assert isinstance(p.child, phys.PartialAgg)
    assert p.child.specs == () and p.child.max_groups == 64
    assert isinstance(p.child.part, phys.RowBlocked)

    agg = GroupAgg(Scan("orders"), ("o_custkey",), "o_totalprice", "SUM",
                   128, "exact", num_freq=256,
                   extra=(("cnt", "", "COUNT", "normal"),))
    p = phys.lower_plan(agg, CAPS, n_shards=2, sharded=True)
    assert p.child.specs == (("exact", "o_totalprice", "SUM", "exact"),
                             ("cnt", "", "COUNT", "normal"))
    assert p.child.num_freq == 256


def test_lowering_validates_spec_names():
    bad = GroupAgg(Scan("orders"), ("o_custkey",), "o_totalprice", "SUM",
                   128, extra=(("valid", "", "COUNT", "normal"),))
    with pytest.raises(ValueError, match="unique and avoid"):
        phys.lower_plan(bad, CAPS)
    bad2 = ReweightGreater(Scan("orders"), ("o_custkey",), "o_totalprice",
                           "", 128)
    with pytest.raises(ValueError, match="threshold"):
        phys.lower_plan(bad2, CAPS)


def test_bucket_capacity_bounds():
    """slack x uniform share, floored at 1, capped at the sender's local
    rows (where overflow becomes impossible)."""
    assert phys.bucket_capacity(1024, 4, 4.0) == 1024   # slack >= shards
    assert phys.bucket_capacity(1024, 8, 4.0) == 512
    assert phys.bucket_capacity(1024, 8, 1.0) == 128
    assert phys.bucket_capacity(3, 8, 1.0) == 1         # floor
    sj = phys.lower_plan(
        FKJoin(Scan("lineitem"), Scan("orders"), "a", "b", ()), CAPS,
        n_shards=8, sharded=True, join_gather_budget=1, shuffle_slack=2.0)
    assert sj.build_bucket == phys.bucket_capacity(1024 // 8, 8, 2.0)
    assert sj.probe_bucket == phys.bucket_capacity(4096 // 8, 8, 2.0)


# ------------------------------------------- concrete-key adaptive buckets
def test_concrete_bucket_capacity_is_the_histogram_max():
    """Skewed keys: capacity = the worst (sender, owner) demand of the
    actual key % n_shards histogram, valid rows only."""
    t = Table.from_columns({"k": jnp.asarray([0, 2, 4, 6, 1, 3, 5, 7])})
    # shard 0 rows [0,2,4,6] all hit owner 0; shard 1 rows odd -> owner 1
    assert phys.concrete_bucket_capacity(t, "k", 2) == 4
    t2 = t.with_valid(jnp.asarray([True, False, False, False] + [True] * 4))
    assert phys.concrete_bucket_capacity(t2, "k", 2) == 4
    assert phys.concrete_bucket_capacity(t, "missing", 2) is None
    assert phys.concrete_bucket_capacity(None, "k", 2) is None
    # balanced keys: exactly the uniform share, no slack tax
    t3 = Table.from_columns({"k": jnp.asarray(np.arange(16))})
    assert phys.concrete_bucket_capacity(t3, "k", 4) == 1


def test_lowering_sizes_buckets_from_concrete_keys():
    """With the padded base tables in hand, ShuffleJoin buckets come from
    the real histogram instead of slack x uniform share — skew gets the
    capacity it needs, balanced keys shed the slack tax."""
    n = CAPS["orders"]
    tables = {
        "lineitem": Table.from_columns(
            {"a": jnp.asarray(np.arange(CAPS["lineitem"]) % 64)}),
        # all build keys hash to owner 0
        "orders": Table.from_columns({"b": jnp.asarray(np.zeros(n, int))}),
    }
    join = FKJoin(Scan("lineitem"), Scan("orders"), "a", "b", ())
    sj = phys.lower_plan(join, CAPS, n_shards=8, sharded=True,
                         join_gather_budget=1, shuffle_slack=2.0,
                         tables=tables)
    assert sj.build_bucket == n // 8        # full skewed demand, no drop
    assert sj.probe_bucket == CAPS["lineitem"] // 8 // 8  # balanced share
    # without tables: the PR-4 slack sizing (golden determinism)
    sj2 = phys.lower_plan(join, CAPS, n_shards=8, sharded=True,
                          join_gather_budget=1, shuffle_slack=2.0)
    assert sj2.build_bucket == phys.bucket_capacity(n // 8, 8, 2.0)


# ------------------------------------------------- out-of-core lowering
def test_device_row_budget_lowers_scan_to_streamed():
    """A Scan whose per-shard rows exceed the budget becomes a
    StreamedScan with a double-buffer-sized wave schedule; scans under
    the budget stay resident ShardScans."""
    agg = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM",
                   128)
    p = phys.lower_plan(agg, CAPS, n_shards=1, sharded=False,
                        device_row_budget=1024)
    sc = p.child.child
    assert isinstance(sc, phys.StreamedScan)
    s = sc.schedule
    # csz = 4096 / 8 chunks = 512; budget 1024 holds 2 slabs of 1 chunk
    assert (s.chunk_rows, s.local_chunks_per_wave, s.n_waves,
            s.n_shards) == (512, 1, 8, 1)
    assert s.padded_capacity == 4096
    # column pruning bounds the payload to the demand set (l_orderkey,
    # l_quantity): 2 double-buffered slabs x (2 cols + p + valid)
    # resident, whole pruned table crossing the transfer once per pass
    assert sc.columns == ("l_orderkey", "l_quantity")
    assert sc.cost.peak_rows == 2 * 512 * 4
    assert sc.cost.bytes_moved == 4096 * 4 * 8
    over = phys.lower_plan(agg, CAPS, n_shards=1, sharded=False,
                           device_row_budget=4096)
    assert isinstance(over.child.child, phys.ShardScan)
    # on a mesh the budget is per SHARD: 4 shards x 1024 rows fit
    mesh4 = phys.lower_plan(agg, CAPS, n_shards=4, sharded=True,
                            device_row_budget=1024)
    assert isinstance(mesh4.child.child, phys.ShardScan)
    mesh2 = phys.lower_plan(agg, CAPS, n_shards=2, sharded=True,
                            device_row_budget=1024)
    sc2 = mesh2.child.child
    assert isinstance(sc2, phys.StreamedScan)
    assert sc2.schedule.n_shards == 2
    assert sc2.schedule.chunks_per_wave == 2      # 1 local slot per shard


def test_stream_wave_chunks_pins_the_schedule():
    agg = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM",
                   128)
    p = phys.lower_plan(agg, CAPS, n_shards=1, sharded=False,
                        device_row_budget=1024, stream_wave_chunks=3)
    s = p.child.child.schedule
    assert (s.local_chunks_per_wave, s.n_waves) == (3, 3)
    assert s.padded_capacity == 9 * 512           # one ragged padding wave


def test_streamed_build_side_raises_in_lowering():
    join = FKJoin(Scan("lineitem"), Scan("orders"), "l_orderkey",
                  "o_orderkey", ())
    with pytest.raises(NotImplementedError, match="build side"):
        phys.lower_plan(GroupAgg(join, ("l_orderkey",), "l_quantity",
                                 "SUM", 128), CAPS, n_shards=1,
                        sharded=False, device_row_budget=512)


def test_streamed_probe_forces_gather_join():
    """A streamed probe side cannot hash-exchange (host rows only ever
    move one wave at a time): the join gathers its build side regardless
    of the gather budget, and the aggregation stays a PartialAgg even
    when the fused pipeline would otherwise win."""
    p = phys.lower_plan(_q3ish(), CAPS, n_shards=4, sharded=True,
                        join_gather_budget=1, device_row_budget=256)
    assert isinstance(p.child, phys.PartialAgg)
    j = p.child.child
    assert isinstance(j, phys.GatherJoin)
    assert phys._contains_streamed(j.left)
    assert isinstance(j.right, phys.ShuffleJoin)  # resident side still free


def test_explain_snapshot_streamed_plan():
    """Full-text snapshot: the streamed scan with its wave schedule and
    modeled transfer/residency costs."""
    agg = GroupAgg(Select(Scan("lineitem"), lambda t: t["x"] > 0),
                   ("l_orderkey",), "l_quantity", "SUM", 512)
    text = phys.explain(phys.lower_plan(
        agg, CAPS, n_shards=1, sharded=False, device_row_budget=1024))
    assert text == """\
MergeAgg[groupagg] :: Replicated
  PartialAgg(keys=['l_orderkey'], specs=['sum'], G=512) :: Replicated cost{bytes=0, rows=12288, flops=12288}
    Select :: Replicated
      StreamedScan(lineitem, rows=4096, waves=8x1chunks@512rows, cols=[l_orderkey,l_quantity,x]) :: Replicated cost{bytes=163840, rows=5120, flops=0}"""


# --------------------------------------------------- explain snapshots
def test_explain_renders_every_node():
    text = phys.explain(phys.lower_plan(
        _q3ish(), CAPS, n_shards=4, sharded=True, join_gather_budget=1))
    for token in ("MergeAgg[groupagg]", "PartitionedAgg",
                  "CoPartitionedJoin", "ShuffleJoin",
                  "HashPartitioned(l_orderkey)",
                  "HashPartitioned(c_custkey)", "ShardScan(lineitem",
                  "RowBlocked", "Replicated", "cost{bytes="):
        assert token in text, (token, text)
    rp = phys.explain(phys.lower_plan(
        GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM",
                 128), CAPS, n_shards=4, sharded=True,
        agg_shuffle_budget=4))
    assert "Repartition(l_orderkey" in rp


def test_explain_snapshot_copartitioned_plan():
    """Full-text snapshot: the fused pipeline with its modeled costs."""
    text = phys.explain(phys.lower_plan(
        _q3ish(), CAPS, n_shards=4, sharded=True, join_gather_budget=512))
    assert text == """\
MergeAgg[groupagg] :: Replicated
  PartitionedAgg(keys=['l_orderkey'], specs=['sum'], G=512) :: HashPartitioned(l_orderkey) cost{bytes=18432, rows=16384, flops=12288}
    CoPartitionedJoin(l_orderkey=o_orderkey, build=1024, carry=['l_quantity'], buckets=(build=256, probe=1024)) :: HashPartitioned(l_orderkey) cost{bytes=110592, rows=18432, flops=0}
      Select :: RowBlocked
        ShardScan(lineitem, rows=4096) :: RowBlocked
      GatherJoin(o_custkey=c_custkey, build=256) :: RowBlocked cost{bytes=6144, rows=1024, flops=0}
        ShardScan(orders, rows=1024) :: RowBlocked
        ShardScan(customer, rows=256) :: RowBlocked"""


def test_explain_snapshot_forced_shuffle_plan():
    """Full-text snapshot: the unfused shuffle + gather-home pipeline (a
    GROUP BY off the join key), with its modeled costs."""
    text = phys.explain(phys.lower_plan(
        _q3ish(keys=("l_partkey",)), CAPS, n_shards=4, sharded=True,
        join_gather_budget=512))
    assert text == """\
MergeAgg[groupagg] :: Replicated
  PartialAgg(keys=['l_partkey'], specs=['sum'], G=512) :: RowBlocked cost{bytes=73728, rows=12288, flops=3072}
    ShuffleJoin(l_orderkey=o_orderkey, build=1024, exchange=HashPartitioned(o_orderkey), buckets=(build=256, probe=1024)) :: RowBlocked cost{bytes=116736, rows=19456, flops=0}
      Select :: RowBlocked
        ShardScan(lineitem, rows=4096) :: RowBlocked
      GatherJoin(o_custkey=c_custkey, build=256) :: RowBlocked cost{bytes=6144, rows=1024, flops=0}
        ShardScan(orders, rows=1024) :: RowBlocked
        ShardScan(customer, rows=256) :: RowBlocked"""


# ------------------------------------------- required-column analysis
def test_required_scan_columns_goldens():
    """Demand propagation per operator: Select adds predicate reads, Map
    satisfies its defined column, FKJoin splits probe/build demand, and
    aggregations reset demand to keys + value/carry columns."""
    agg = GroupAgg(Map(Select(Scan("lineitem"),
                              lambda t: t["l_shipdate"] > 10),
                       "v", lambda t: t["l_quantity"] * t["l_discount"]),
                   ("l_returnflag",), "v", "SUM", 8)
    (need,) = phys.required_scan_columns(agg).values()
    # "v" is produced by the Map — its inputs stream instead
    assert need == {"l_shipdate", "l_quantity", "l_discount",
                    "l_returnflag"}

    join = GroupAgg(FKJoin(Scan("lineitem"), Scan("orders"), "l_orderkey",
                           "o_orderkey", ("o_orderdate",)),
                    ("o_orderdate",), "l_quantity", "SUM", 8)
    got = phys.required_scan_columns(join)
    sides = {frozenset(v) for v in got.values()}
    # probe: demand minus fetched build cols, plus the probe key;
    # build: its key plus the fetched cols
    assert frozenset({"l_orderkey", "l_quantity"}) in sides
    assert frozenset({"o_orderkey", "o_orderdate"}) in sides

    rw = ReweightGreater(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                         "", 64, threshold=5.0, carry_cols=("l_partkey",))
    (need,) = phys.required_scan_columns(rw).values()
    assert need == {"l_orderkey", "l_quantity", "l_partkey"}


def test_unanalysable_predicate_disables_pruning():
    """A predicate the column spy cannot execute (data-dependent control
    flow) must NOT under-approximate: the scan's demand becomes None and
    every column streams."""
    def hostile(t):
        raise RuntimeError("no analysis")
    agg2 = GroupAgg(Select(Scan("lineitem"), hostile), ("l_orderkey",),
                    "l_quantity", "SUM", 8)
    (need,) = phys.required_scan_columns(agg2).values()
    assert need is None
    p = phys.lower_plan(agg2, CAPS, n_shards=1, sharded=False,
                        device_row_budget=1024)
    assert p.child.child.child.columns is None


def test_stream_prune_columns_off_ships_everything():
    agg = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM",
                   128)
    p = phys.lower_plan(agg, CAPS, n_shards=1, sharded=False,
                        device_row_budget=1024,
                        stream_prune_columns=False)
    assert p.child.child.columns is None


def test_pruned_wave_widens_to_fill_the_budget():
    """With the full column count known (tables passed), a pruned slab's
    narrower rows widen the wave: width (2+2)/(10+2) = 1/3 turns a
    1-chunk wave into a 3-chunk wave under the same byte budget."""
    cols = {f"c{i}": np.arange(4096) for i in range(8)}
    cols["l_orderkey"] = np.arange(4096)
    cols["l_quantity"] = np.arange(4096, dtype=np.float64)
    t = Table.from_columns({k: jnp.asarray(v) for k, v in cols.items()})
    agg = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM",
                   128)
    wide = phys.lower_plan(agg, CAPS, n_shards=1, sharded=False,
                           device_row_budget=1024,
                           tables={"lineitem": t})
    assert wide.child.child.schedule.local_chunks_per_wave == 3
    flat = phys.lower_plan(agg, CAPS, n_shards=1, sharded=False,
                           device_row_budget=1024,
                           tables={"lineitem": t},
                           stream_prune_columns=False)
    assert flat.child.child.schedule.local_chunks_per_wave == 1
