"""UDA layer (paper §VI-A): Initialize/Accumulate/Merge/Finalize semantics.

The key structural property: any partition of the tuples into chunks, any
merge tree over the chunk states, gives the same final distribution —
that's what makes the shard_map/psum execution valid (DESIGN.md §2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregates as agg
from repro.core import pgf as P
from repro.core.config import default_float


def _rand(rng, n):
    return (rng.uniform(0.05, 0.95, n), rng.integers(1, 9, n).astype(float))


def test_atleastone(rng):
    probs, _ = _rand(rng, 20)
    st = agg.AtLeastOne.init()
    st = agg.AtLeastOne.accumulate(st, jnp.asarray(probs, default_float()))
    want = 1 - np.prod(1 - probs)
    assert float(agg.AtLeastOne.finalize(st)) == pytest.approx(want, abs=1e-12)


def test_merge_equals_single_accumulate(rng):
    """Chunked accumulate + merge == one-shot accumulate (all UDAs)."""
    probs, values = _rand(rng, 64)
    pj = jnp.asarray(probs, default_float())
    vj = jnp.asarray(values, default_float())

    uda = agg.SumCF(num_freq=int(values.sum()) + 1)
    one = uda.accumulate(uda.init(), pj, vj)
    st = uda.init()
    for lo in range(0, 64, 16):
        chunk = uda.accumulate(uda.init(), pj[lo:lo + 16], vj[lo:lo + 16])
        st = uda.merge(st, chunk)
    np.testing.assert_allclose(np.asarray(one.log_abs),
                               np.asarray(st.log_abs), atol=1e-10)
    np.testing.assert_allclose(np.asarray(uda.finalize(one).coeffs),
                               np.asarray(uda.finalize(st).coeffs),
                               atol=1e-10)

    m = agg.MinUDA(kappa=16)
    one_m = m.accumulate(m.init(), pj, vj)
    st_m = m.init()
    for lo in range(0, 64, 16):
        st_m = m.merge(st_m, m.accumulate(m.init(), pj[lo:lo + 16],
                                          vj[lo:lo + 16]))
    v1, m1, t1 = m.finalize(one_m)
    v2, m2, t2 = m.finalize(st_m)
    # The bitonic merge's in-network run fold collapses duplicate values
    # into one slot (masses telescope exactly), so both layouts hold
    # DISTINCT values; compare per-value mass (ULP-level association
    # differences remain between the two merge trees).
    v1, m1 = np.asarray(v1), np.asarray(m1)
    v2, m2 = np.asarray(v2), np.asarray(m2)
    fin = v2[np.isfinite(v2)]
    assert fin.size == np.unique(fin).size       # runs folded
    for val in np.unique(v1[np.isfinite(v1)]):
        np.testing.assert_allclose(m1[v1 == val].sum(), m2[v2 == val].sum(),
                                   atol=1e-12)
    np.testing.assert_allclose(float(t1), float(t2), atol=1e-12)


@pytest.mark.parametrize("sign,name", [(1.0, "MIN"), (-1.0, "MAX")])
def test_minmax_uda_vs_possible_worlds(rng, sign, name):
    probs, values = _rand(rng, 12)
    u = agg.MinUDA(kappa=16, sign=sign)
    st = u.accumulate(u.init(), jnp.asarray(probs, default_float()),
                      jnp.asarray(values, default_float()))
    vals, mass, p_tail = u.finalize(st)
    vals, mass = np.asarray(vals), np.asarray(mass)
    oracle = P.possible_worlds_pgf(probs, values, name)
    for outcome, pr in oracle.items():
        if np.isinf(outcome):
            assert float(p_tail) == pytest.approx(pr, abs=1e-12)
        else:
            got = mass[vals == outcome].sum()
            assert got == pytest.approx(pr, abs=1e-12), outcome


def test_minmax_truncation_tail(rng):
    """kappa smaller than support: dropped mass lands in the tail (§V-B.2)."""
    probs = np.full(10, 0.5)
    values = np.arange(10, dtype=float)
    u = agg.MinUDA(kappa=4)
    st = u.accumulate(u.init(), jnp.asarray(probs, default_float()),
                      jnp.asarray(values, default_float()))
    vals, mass, p_tail = u.finalize(st)
    kept = np.asarray(mass).sum()
    assert kept + float(p_tail) == pytest.approx(1.0, abs=1e-12)
    # P(min >= 4) = all of 0..3 absent = 0.5^4
    assert float(p_tail) == pytest.approx(0.5 ** 4, abs=1e-12)
    assert float(u.p_empty(st)) == pytest.approx(0.5 ** 10, abs=1e-12)


def test_masked_tuples_are_ignored(rng):
    probs, values = _rand(rng, 10)
    mask = np.arange(10) < 6
    uda = agg.SumCF(num_freq=64)
    a = uda.accumulate(uda.init(), jnp.asarray(probs, default_float()),
                       jnp.asarray(values, default_float()),
                       mask=jnp.asarray(mask))
    b = uda.accumulate(uda.init(), jnp.asarray(probs[:6], default_float()),
                       jnp.asarray(values[:6], default_float()))
    np.testing.assert_allclose(np.asarray(uda.finalize(a).coeffs),
                               np.asarray(uda.finalize(b).coeffs),
                               atol=1e-10)


def test_count_cf_capacity():
    uda = agg.CountCF(capacity=10)
    st = uda.accumulate(uda.init(), jnp.asarray([0.5] * 5, default_float()))
    f = uda.finalize(st)
    assert f.coeffs.shape[0] == 11
    assert float(f.coeffs.sum()) == pytest.approx(1.0, abs=1e-9)
