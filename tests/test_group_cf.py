"""The (G, F)-tiled grouped log-CF Pallas kernel and the planner's
``GroupAgg(method="exact")`` path built on it.

Kernel tests run in interpret mode (same BlockSpec tiling as the TPU
target) and carry the ``kernels`` marker so the Pallas path is exercised in
tier-1 on CPU-only machines; planner tests check the possible-worlds
oracle, frequency-slab chunking, and (in a subprocess) 2-device mesh ==
single-device equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_sub
from repro.core import uda
from repro.core.config import default_float
from repro.core.pgf import possible_worlds_pgf
from repro.db.plans import GroupAgg, Scan, compile_plan
from repro.db.table import Table
from repro.kernels import group_cf, pb_cf, ref
from repro.kernels import ops as kops


def _inputs(rng, n, num_groups, vmax=50):
    p = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32)
    v = jnp.asarray(rng.integers(0, vmax, n), jnp.int32)
    g = jnp.asarray(rng.integers(0, num_groups, n), jnp.int32)
    return p, v, g


def _assert_angles_close(got, want, atol):
    """Angle sums are per-factor principal values whose 2*pi branch offsets
    cancel at exp() time (the ref.py contract); f32-vs-f64 sin at theta ==
    pi flips individual branches, so compare modulo 2*pi."""
    d = np.asarray(got, np.float64) - np.asarray(want, np.float64)
    wrapped = np.abs(np.mod(d + np.pi, 2 * np.pi) - np.pi)
    np.testing.assert_array_less(wrapped, atol)


# ------------------------------------------------------------- kernel
@pytest.mark.kernels
@pytest.mark.parametrize("n,num_groups,num_freq", [
    (100, 3, 64), (300, 5, 129), (1000, 12, 300), (513, 9, 64),
    (2048, 64, 512), (1500, 200, 257),
])
def test_group_logcf_kernel_sweep(rng, n, num_groups, num_freq):
    p, v, g = _inputs(rng, n, num_groups)
    la_k, an_k = group_cf.group_logcf(p, v, g, num_groups=num_groups,
                                      num_freq=num_freq, interpret=True)
    la_r, an_r = ref.group_logcf_ref(jnp.asarray(p, jnp.float64), v, g,
                                     num_groups, num_freq)
    np.testing.assert_allclose(np.asarray(la_k), np.asarray(la_r),
                               atol=5e-4 * max(1, n / 500))
    # f32 sin/atan2 near the theta == pi branch cut loses a few more bits
    # against the f64 reference than the log-abs path does.
    _assert_angles_close(an_k, an_r, 2e-3 * max(1, n / 500))


@pytest.mark.kernels
def test_group_logcf_tiled_vs_scalar_per_group(rng):
    """Tiled grouped kernel == the scalar pb_cf kernel run per group (the
    per-group loop the (G, F) tiling replaces)."""
    n, num_groups, num_freq = 700, 6, 200
    p, v, g = _inputs(rng, n, num_groups)
    la_g, an_g = group_cf.group_logcf(p, v, g, num_groups=num_groups,
                                      num_freq=num_freq, interpret=True)
    for gi in range(num_groups):
        pg = jnp.where(g == gi, p, 0.0)
        la_s, an_s = pb_cf.logcf(pg, v, num_freq=num_freq, interpret=True)
        np.testing.assert_allclose(np.asarray(la_g[gi]), np.asarray(la_s),
                                   atol=2e-3)
        np.testing.assert_allclose(np.asarray(an_g[gi]), np.asarray(an_s),
                                   atol=2e-3)


@pytest.mark.kernels
def test_group_logcf_block_sizes(rng):
    """Every (gb, fb, tb) tiling computes the same (G, F) state."""
    n, num_groups, num_freq = 900, 20, 192
    p, v, g = _inputs(rng, n, num_groups)
    want = group_cf.group_logcf(p, v, g, num_groups=num_groups,
                                num_freq=num_freq, interpret=True)
    for gb, fb, tb in ((8, 128, 256), (16, 256, 512), (8, 256, 1024),
                      (24, 128, 128)):
        got = group_cf.group_logcf(p, v, g, num_groups=num_groups,
                                   num_freq=num_freq, gb=gb, fb=fb, tb=tb,
                                   interpret=True)
        for a, b in zip(want, got):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, err_msg=str((gb, fb, tb)))


@pytest.mark.kernels
def test_group_logcf_freq_slabs(rng):
    """Slab runs [lo, lo+cnt) concatenate to the full-range run — the
    planner's memory-budget chunking contract."""
    n, num_groups, num_freq = 600, 10, 320
    p, v, g = _inputs(rng, n, num_groups)
    full = group_cf.group_logcf(p, v, g, num_groups=num_groups,
                                num_freq=num_freq, interpret=True)
    slabs = [group_cf.group_logcf(p, v, g, num_groups=num_groups,
                                  num_freq=num_freq, freq_lo=lo,
                                  freq_cnt=cnt, interpret=True)
             for lo, cnt in ((0, 128), (128, 128), (256, 64))]
    cat = tuple(jnp.concatenate([s[i] for s in slabs], axis=-1)
                for i in range(2))
    for a, b in zip(full, cat):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.kernels
def test_group_logcf_large_values_exact_phase(rng):
    """k*a far beyond int32/f32 exactness: the split-modmult must hold for
    the grouped kernel exactly as for the scalar one."""
    n, num_groups, num_freq = 500, 4, 1 << 14
    p = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32)
    v = jnp.asarray(rng.integers(0, num_freq, n), jnp.int32)
    g = jnp.asarray(rng.integers(0, num_groups, n), jnp.int32)
    la_k, _ = group_cf.group_logcf(p, v, g, num_groups=num_groups,
                                   num_freq=num_freq, freq_cnt=256,
                                   interpret=True)
    la_r, _ = ref.group_logcf_ref(jnp.asarray(p, jnp.float64),
                                  jnp.asarray(v, jnp.float64), g,
                                  num_groups, num_freq, freq_cnt=256)
    np.testing.assert_allclose(np.asarray(la_k), np.asarray(la_r), atol=2e-3)


@pytest.mark.kernels
def test_oracle_phase_exact_with_f32_probs(rng):
    """The small-n oracle route must stay phase-exact with f32 probs and
    large k*v (the phase grid runs at f64 under x64, not the probs dtype)."""
    n, num_groups, num_freq = 100, 3, 1 << 14
    p = jnp.asarray(rng.uniform(0.1, 0.9, n), jnp.float32)
    v = jnp.asarray(rng.integers(0, num_freq, n), jnp.int32)
    g = jnp.asarray(rng.integers(0, num_groups, n), jnp.int32)
    la, _ = kops.group_logcf(p, v, g, num_groups, num_freq)  # auto: oracle
    la_r, _ = ref.group_logcf_ref(jnp.asarray(p, jnp.float64),
                                  jnp.asarray(v, jnp.int64), g,
                                  num_groups, num_freq)
    np.testing.assert_allclose(np.asarray(la), np.asarray(la_r), atol=1e-3)


@pytest.mark.kernels
def test_kernel_int64_values_nonpow2_freq(rng):
    """64-bit values >= 2^31 with a non-power-of-two grid: the mod-N
    reduction must run in the source dtype before the int32 narrowing."""
    n, num_groups, num_freq = 300, 4, 1001
    p = jnp.asarray(rng.uniform(0.1, 0.9, n), jnp.float32)
    v = jnp.asarray(rng.integers(1 << 31, 1 << 40, n), jnp.int64)
    g = jnp.asarray(rng.integers(0, num_groups, n), jnp.int32)
    la_k, _ = group_cf.group_logcf(p, v, g, num_groups=num_groups,
                                   num_freq=num_freq, interpret=True)
    la_r, _ = ref.group_logcf_ref(jnp.asarray(p, jnp.float64), v, g,
                                  num_groups, num_freq)
    np.testing.assert_allclose(np.asarray(la_k), np.asarray(la_r), atol=1e-3)


@pytest.mark.kernels
def test_ops_dispatch_small_uses_ref(rng):
    """Tiny inputs route to the oracle (padding would dominate)."""
    p = jnp.asarray(rng.uniform(0.1, 0.9, 8), jnp.float32)
    v = jnp.ones((8,), jnp.int32)
    g = jnp.asarray([0, 1, 0, 1, 2, 2, 0, 1], jnp.int32)
    la, an = kops.group_logcf(p, v, g, 3, 9)
    la_r, an_r = ref.group_logcf_ref(p, v, g, 3, 9)
    np.testing.assert_allclose(np.asarray(la), np.asarray(la_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(an), np.asarray(an_r), atol=1e-6)


# ------------------------------------------------- UDA / oracle parity
G = 4


def _data(seed, n=14):
    r = np.random.default_rng(seed)
    p = r.uniform(0.05, 0.95, n)
    v = r.integers(1, 8, n)
    g = r.integers(0, G, n)
    mask = r.uniform(0, 1, n) > 0.25
    return np.where(mask, p, 0.0), v, g


@pytest.mark.parametrize("seed", range(3))
def test_sumcf_accumulate_full_oracle_parity(seed):
    """SumCF.accumulate_full (the grouped kernel dispatch entry, pure-JAX
    fallback at this size) vs the 2^n possible-worlds oracle — masked, and
    with the state merged in two halves."""
    p, v, g = _data(seed)
    dt = default_float()
    num_freq = int(v.sum()) + 1
    u = uda.SumCF(num_freq)
    pj, vj, gj = jnp.asarray(p, dt), jnp.asarray(v), jnp.asarray(g)
    one = u.accumulate_full(u.init(G, dt), pj, vj, gj, G)
    h = len(p) // 2
    a = u.accumulate_full(u.init(G, dt), pj[:h], vj[:h], gj[:h], G)
    b = u.accumulate_full(u.init(G, dt), pj[h:], vj[h:], gj[h:], G)
    for st in (one, u.merge(a, b)):
        coeffs = np.asarray(u.finalize(st))
        for gi in range(G):
            oracle = possible_worlds_pgf(p[g == gi],
                                         v[g == gi].astype(float), "SUM")
            for outcome, pr in oracle.items():
                assert coeffs[gi, int(outcome)] == pytest.approx(
                    pr, abs=1e-9), (seed, gi, outcome)


@pytest.mark.parametrize("seed", range(2))
def test_groupagg_exact_planner_oracle(seed):
    """compile_plan GroupAgg(method='exact') == possible worlds, and the
    frequency-slab chunked compile is bit-identical to the unchunked one."""
    p, v, g = _data(seed)
    num_freq = int(v.sum()) + 1
    t = Table.from_columns({"g": jnp.asarray(g), "v": jnp.asarray(v)},
                           prob=jnp.asarray(p))
    plan = GroupAgg(Scan("t"), ("g",), "v", "SUM", G, "exact",
                    num_freq=num_freq,
                    extra=(("cnt", "", "COUNT", "exact"),))
    out = compile_plan(plan)({"t": t})
    chunked = compile_plan(plan, cf_budget_elems=2 * G)({"t": t})
    coeffs, cnt = np.asarray(out["exact"]), np.asarray(out["cnt"])
    for gi in range(G):
        sel = g == gi
        for outcome, pr in possible_worlds_pgf(
                p[sel], v[sel].astype(float), "SUM").items():
            assert coeffs[gi, int(outcome)] == pytest.approx(pr, abs=1e-9)
        for outcome, pr in possible_worlds_pgf(
                p[sel], np.ones(sel.sum()), "COUNT").items():
            assert cnt[gi, int(outcome)] == pytest.approx(pr, abs=1e-9)
    for k in ("exact", "cnt", "confidence"):
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(chunked[k]), err_msg=k)


@pytest.mark.kernels
def test_kernel_dispatch_preserves_big_integer_values(rng):
    """Values above 2^24 must reach the kernel uncast: an f32 round-trip
    would corrupt them before the exact mod-num_freq phase."""
    n, num_groups, num_freq = 512, 4, 1 << 16
    p = jnp.asarray(rng.uniform(0.1, 0.9, n), jnp.float32)
    v = jnp.asarray(rng.integers(0, 1 << 28, n), jnp.int32)
    g = jnp.asarray(rng.integers(0, num_groups, n), jnp.int32)
    st = uda.accumulate({"cf": uda.SumCF(num_freq, freq_cnt=128)}, p, v, g,
                        max_groups=num_groups, kernel="pallas")["cf"]
    la_r, _ = ref.group_logcf_ref(jnp.asarray(p, jnp.float64),
                                  jnp.asarray(v, jnp.int64), g,
                                  num_groups, num_freq, freq_cnt=128)
    np.testing.assert_allclose(np.asarray(st.log_abs), np.asarray(la_r),
                               atol=2e-3)


def test_groupagg_exact_rejects_minmax():
    t = Table.from_columns({"g": jnp.zeros((4,), jnp.int32),
                            "v": jnp.ones((4,), jnp.int32)},
                           prob=jnp.full((4,), 0.5))
    plan = GroupAgg(Scan("t"), ("g",), "v", "MIN", 2, "exact", num_freq=8)
    with pytest.raises(ValueError, match="SUM/COUNT"):
        compile_plan(plan)({"t": t})


def test_groupagg_exact_requires_num_freq():
    t = Table.from_columns({"g": jnp.zeros((4,), jnp.int32),
                            "v": jnp.ones((4,), jnp.int32)},
                           prob=jnp.full((4,), 0.5))
    plan = GroupAgg(Scan("t"), ("g",), "v", "SUM", 2, "exact")
    with pytest.raises(ValueError, match="num_freq"):
        compile_plan(plan)({"t": t})


def test_groupagg_unknown_method_error_names_exact():
    plan = GroupAgg(Scan("t"), ("g",), "v", "SUM", 2, "bogus")
    t = Table.from_columns({"g": jnp.zeros((4,), jnp.int32),
                            "v": jnp.ones((4,), jnp.int32)},
                           prob=jnp.full((4,), 0.5))
    with pytest.raises(ValueError, match="'normal', 'cumulants' or 'exact'"):
        compile_plan(plan)({"t": t})


# --------------------------------------------------- mesh equivalence
@pytest.mark.multidevice
def test_groupagg_exact_mesh_equivalence():
    """Exact GroupAgg on a 2-device mesh == single device, both unchunked
    and with a slab budget small enough to force multi-pass psum merges."""
    out = run_sub("""
import jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db import tpch
from repro.db.plans import GroupAgg, Scan, compile_plan
mesh = make_mesh((2,), ("data",))
db = tpch.generate(n_orders=64, seed=5)
tables = db.tables()
plan = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM",
                128, "exact", num_freq=256,
                extra=(("cnt", "", "COUNT", "exact"),))
ref = compile_plan(plan, None)(tables)
for got in (compile_plan(plan, mesh)(tables),
            compile_plan(plan, mesh, cf_budget_elems=1 << 12)(tables)):
    for k in ("exact", "cnt", "confidence"):
        d = float(jnp.max(jnp.abs(jnp.asarray(ref[k]) -
                                  jnp.asarray(got[k]))))
        assert d < 1e-9, (k, d)
print("OK")
""")
    assert "OK" in out


def test_presorted_operands_bit_equal():
    """group_logcf with hoisted presort_operands == the self-sorting call,
    bit for bit, across frequency slabs (the exact-CF slab loop reuses ONE
    prep for every slab)."""
    import numpy as np
    from repro.kernels import group_cf, ops as kops
    r = np.random.default_rng(0)
    n, G, F = 640, 24, 96
    p = jnp.asarray(r.uniform(0.01, 0.99, n), jnp.float32)
    v = jnp.asarray(r.integers(0, 4, n), jnp.int32)
    g = jnp.asarray(r.integers(0, G, n), jnp.int32)
    operands = group_cf.presort_operands(p, v, g, F)
    for lo, cnt in ((0, F), (0, 32), (32, 32), (64, F - 64)):
        la_ref, an_ref = group_cf.group_logcf(
            p, v, g, num_groups=G, num_freq=F, freq_lo=lo, freq_cnt=cnt)
        la, an = group_cf.group_logcf(
            p, v, g, num_groups=G, num_freq=F, freq_lo=lo, freq_cnt=cnt,
            operands=operands)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(la_ref))
        np.testing.assert_array_equal(np.asarray(an), np.asarray(an_ref))
    # the dispatch wrapper threads operands through to the kernel too
    la, an = kops.group_logcf(p, v, g, G, F, use_kernel=True,
                              operands=kops.presort_group_operands(p, v, g,
                                                                   F))
    la_ref, an_ref = kops.group_logcf(p, v, g, G, F, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(la_ref))


def test_cf_chunk_operands_planner_hoist():
    """uda.cf_chunk_operands mirrors the kernel dispatch guards (None when
    the kernel would not run) and its operands reproduce the accumulate
    result bit for bit when forced through the kernel path."""
    import numpy as np
    from repro.core import uda
    r = np.random.default_rng(1)
    n, G, F = 1024, 8, 64
    p = jnp.asarray(r.uniform(0.01, 0.99, n), jnp.float32)
    v = jnp.asarray(r.integers(0, 3, n), jnp.int32)
    g = jnp.asarray(r.integers(0, G, n), jnp.int32)
    # CPU backend ('auto' => no Pallas dispatch): must decline the hoist
    assert uda.cf_chunk_operands(F, p, v, g, max_groups=G,
                                 num_chunks=4) is None \
        or jax.default_backend() == "tpu"
    ops4 = uda.cf_chunk_operands(F, p, v, g, max_groups=G, num_chunks=4,
                                 kernel="pallas")
    assert ops4 is not None and len(ops4) == 4
    udas = {"cf": uda.SumCF(F)}
    a = uda.accumulate_chunked(udas, p, v, g, max_groups=G, num_chunks=4,
                               kernel="pallas")["cf"]
    b = uda.accumulate_chunked(udas, p, v, g, max_groups=G, num_chunks=4,
                               kernel="pallas",
                               cf_operands={"cf": ops4})["cf"]
    np.testing.assert_array_equal(np.asarray(a.log_abs),
                                  np.asarray(b.log_abs))
    np.testing.assert_array_equal(np.asarray(a.angle), np.asarray(b.angle))
    # ragged columns (chunks don't divide) decline rather than misalign
    assert uda.cf_chunk_operands(F, p, v, g, max_groups=G,
                                 num_chunks=3, kernel="pallas") is None
