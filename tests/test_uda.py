"""The grouped segment-UDA subsystem vs the 2^n possible-worlds oracle.

Every registered UDA is checked grouped, masked, and with its state merged
in two halves (any partition + any merge tree must give the same final
distribution — that's what makes the sharded execution valid), plus
BIT-EQUAL compile_plan(mesh) == compile_plan(None) checks on 2- and
4-device CPU meshes through the conftest mesh-equivalence harness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import uda
from repro.core.config import default_float
from repro.core.pgf import possible_worlds_pgf

G = 4


def _data(seed, n=14):
    r = np.random.default_rng(seed)
    p = r.uniform(0.05, 0.95, n)
    v = r.integers(1, 8, n).astype(float)
    g = r.integers(0, G, n)
    mask = r.uniform(0, 1, n) > 0.25
    return p, v, g, mask


def _states(u, p, v, g):
    """(one-shot state, merged-in-two-halves state) through the canonical
    accumulation loop."""
    dt = default_float()
    pj, vj, gj = (jnp.asarray(p, dt), jnp.asarray(v, dt), jnp.asarray(g))
    one = uda.accumulate({"u": u}, pj, vj, gj, max_groups=G)["u"]
    h = p.shape[0] // 2
    a = uda.accumulate({"u": u}, pj[:h], vj[:h], gj[:h], max_groups=G)["u"]
    b = uda.accumulate({"u": u}, pj[h:], vj[h:], gj[h:], max_groups=G)["u"]
    return one, u.merge(a, b)


def _oracles(p, v, g, mask, monoid):
    p = np.where(mask, p, 0.0)
    return {gi: possible_worlds_pgf(p[g == gi], v[g == gi], monoid)
            for gi in range(G)}


def _moment(oracle, k, mu=0.0):
    return sum(pr * (x - mu) ** k for x, pr in oracle.items()
               if np.isfinite(x))


@pytest.mark.parametrize("seed", range(3))
def test_atleastone_parity(seed):
    p, v, g, mask = _data(seed)
    pm = np.where(mask, p, 0.0)
    for st in _states(uda.AtLeastOne(), pm, v, g):
        conf = np.asarray(uda.AtLeastOne().finalize(st))
        for gi, oracle in _oracles(p, v, g, mask, "COUNT").items():
            want = 1.0 - oracle.get(0.0, 0.0)
            assert conf[gi] == pytest.approx(want, abs=1e-12), (seed, gi)


@pytest.mark.parametrize("seed", range(3))
def test_normal_parity(seed):
    p, v, g, mask = _data(seed)
    pm = np.where(mask, p, 0.0)
    u = uda.SumNormal()
    for st in _states(u, pm, v, g):
        mu, var = map(np.asarray, u.finalize(st))
        for gi, oracle in _oracles(p, v, g, mask, "SUM").items():
            m1 = _moment(oracle, 1)
            assert mu[gi] == pytest.approx(m1, abs=1e-10)
            assert var[gi] == pytest.approx(_moment(oracle, 2, m1), abs=1e-9)


@pytest.mark.parametrize("seed", range(3))
def test_cumulants_parity(seed):
    p, v, g, mask = _data(seed)
    pm = np.where(mask, p, 0.0)
    u = uda.SumCumulants(6)
    for st in _states(u, pm, v, g):
        terms = np.asarray(u.finalize(st))
        for gi, oracle in _oracles(p, v, g, mask, "SUM").items():
            m1 = _moment(oracle, 1)
            m2 = _moment(oracle, 2, m1)
            m3 = _moment(oracle, 3, m1)      # 3rd central == 3rd cumulant
            assert terms[gi, 0] == pytest.approx(m1, abs=1e-10)
            assert terms[gi, 1] == pytest.approx(m2, abs=1e-9)
            assert terms[gi, 2] == pytest.approx(m3, abs=1e-9)


@pytest.mark.parametrize("seed", range(3))
def test_cf_parity(seed):
    p, v, g, mask = _data(seed)
    pm = np.where(mask, p, 0.0)
    num_freq = int(v.sum()) + 1
    u = uda.SumCF(num_freq)
    for st in _states(u, pm, v, g):
        coeffs = np.asarray(u.finalize(st))
        for gi, oracle in _oracles(p, v, g, mask, "SUM").items():
            for outcome, pr in oracle.items():
                assert coeffs[gi, int(outcome)] == pytest.approx(
                    pr, abs=1e-10), (seed, gi, outcome)


@pytest.mark.parametrize("name,monoid", [("min", "MIN"), ("max", "MAX")])
@pytest.mark.parametrize("seed", range(3))
def test_minmax_parity(name, monoid, seed):
    p, v, g, mask = _data(seed)
    pm = np.where(mask, p, 0.0)
    u = uda.make(name, kappa=16)
    for st in _states(u, pm, v, g):
        vals, mass, p_tail = map(np.asarray, u.finalize(st))
        pe = np.asarray(u.p_empty(st))
        for gi, oracle in _oracles(p, v, g, mask, monoid).items():
            for outcome, pr in oracle.items():
                if np.isinf(outcome):
                    assert p_tail[gi] == pytest.approx(pr, abs=1e-12)
                    assert pe[gi] == pytest.approx(pr, abs=1e-12)
                else:
                    got = mass[gi][vals[gi] == outcome].sum()
                    assert got == pytest.approx(pr, abs=1e-12), \
                        (name, seed, gi, outcome)


def test_minmax_merge_run_fold_keeps_tail_tight():
    """§V-B.2 under heavy duplication: the SAME kappa values in both
    merge inputs used to occupy 2x the buffer slots (split slots competed
    for capacity and inflated the truncation tail); the in-network run
    fold collapses them, so the merged state keeps the full support and
    the tail stays exactly the beyond-support mass."""
    k = 4
    u = uda.MinMax(kappa=k)
    p = jnp.full((6,), 0.5, default_float())
    v = jnp.asarray([0, 1, 2, 3, 4, 5], default_float())
    a = uda.accumulate({"u": u}, p, v, None, max_groups=1)["u"]
    b = uda.accumulate({"u": u}, p, v, None, max_groups=1)["u"]
    st = u.merge(a, b)
    vals = np.asarray(st.values[0])
    fin = vals[np.isfinite(vals)]
    assert fin.size == np.unique(fin).size == k     # runs folded, full k
    np.testing.assert_allclose(np.asarray(st.log_none[0]),
                               2 * np.asarray(a.log_none[0]), rtol=1e-12)
    _, mass, p_tail = u.finalize(st)
    # tail = P(min >= 4) over BOTH copies = (1-p)^(2 tuples per value * 4)
    assert float(p_tail[0]) == pytest.approx(0.25 ** k, abs=1e-12)
    assert float(mass.sum() + p_tail[0]) == pytest.approx(1.0, abs=1e-12)


def test_minmax_merge_with_init_is_identity():
    """merge(init, x) == x bitwise — the invariant the partitioned
    (HashPartitioned) merge leans on: non-owner shards contribute exact
    init states, so the cross-owner fold must preserve the owner's state
    bit for bit (db.distributed.partitioned_merge)."""
    import jax
    r = np.random.default_rng(2)
    u = uda.MinMax(kappa=8)
    p = jnp.asarray(r.uniform(0.05, 0.95, 40), default_float())
    v = jnp.asarray(r.integers(1, 12, 40), default_float())
    g = jnp.asarray(r.integers(0, G, 40))
    x = uda.accumulate({"u": u}, p, v, g, max_groups=G)["u"]
    init = u.init(G, default_float())
    for m in (u.merge(init, x), u.merge(x, init)):
        for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(m)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_minmax_truncation_tail():
    """kappa smaller than the support: dropped mass lands in the tail and
    the kept+tail masses stay a distribution (§V-B.2)."""
    n = 10
    p = np.full(n, 0.5)
    v = np.arange(n, dtype=float)
    u = uda.MinMax(kappa=4)
    st = uda.accumulate({"u": u}, jnp.asarray(p, default_float()),
                        jnp.asarray(v, default_float()), None,
                        max_groups=1)["u"]
    _, mass, p_tail = u.finalize(st)
    assert float(np.asarray(mass).sum() + p_tail[0]) == pytest.approx(1.0)
    assert float(p_tail[0]) == pytest.approx(0.5 ** 4)
    assert float(u.p_empty(st)[0]) == pytest.approx(0.5 ** n)


def test_scalar_is_one_group(rng):
    """gids=None (the scalar facade's path) == explicit single group."""
    p = jnp.asarray(rng.uniform(0.1, 0.9, 20), default_float())
    v = jnp.asarray(rng.integers(1, 5, 20), default_float())
    u = uda.SumCF(int(np.asarray(v).sum()) + 1)
    a = uda.accumulate({"u": u}, p, v, None, max_groups=1)["u"]
    b = uda.accumulate({"u": u}, p, v, jnp.zeros((20,), jnp.int32),
                       max_groups=1)["u"]
    np.testing.assert_allclose(np.asarray(a.log_abs), np.asarray(b.log_abs),
                               atol=1e-12)


def test_every_registered_uda_constructs():
    import jax
    args = {"cf": dict(num_freq=8), "count_cf": dict(capacity=7)}
    for name in uda.REGISTRY:
        u = uda.make(name, **args.get(name, {}))
        st = u.init(3)
        for leaf in jax.tree.leaves(st):
            assert leaf.shape[0] == 3, name     # vectorised over groups
        m = u.merge(st, st)                     # merge preserves shapes
        assert jax.tree.map(jnp.shape, m) == jax.tree.map(jnp.shape, st)


# --------------------------------------------------- mesh-aware compilation
@pytest.mark.multidevice
def test_compile_plan_mesh_equivalence(mesh_equiv):
    """compile_plan(root, mesh) is BIT-EQUAL to compile_plan(root) on a
    2-device CPU mesh, across GroupAgg methods, MIN/MAX, and
    ReweightGreater (the sharded frontend's canonical-chunk fold tree)."""
    mesh_equiv("""
db = tpch.generate(n_orders=64, seed=5)
tables = db.tables()
plans = {
    "normal": GroupAgg(Scan("lineitem"), ("l_returnflag", "l_linestatus"),
                       "l_quantity", "SUM", 8, "normal",
                       extra=(("c", "l_quantity", "SUM", "cumulants"),
                              ("n", "", "COUNT", "normal"))),
    "exact": GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                      "SUM", 128, "exact", num_freq=256),
    "min": GroupAgg(Scan("lineitem"), ("l_returnflag",), "l_quantity",
                    "MIN", 8, kappa=64),
    "max": GroupAgg(Scan("lineitem"), ("l_returnflag",), "l_quantity",
                    "MAX", 8, kappa=64),
    "reweight": ReweightGreater(Scan("lineitem"), ("l_orderkey",),
                                "l_quantity", "", 128, threshold=80.0),
}
pairs = [(name, compile_plan(p, None)(tables), compile_plan(p, mesh)(tables))
         for name, p in plans.items()]
""")


@pytest.mark.multidevice
def test_compile_plan_4dev_and_jit_bit_equal(mesh_equiv):
    """The determinism contract holds for any power-of-two shard count
    dividing the canonical chunk grid (here 4), and under jit (comparing
    jitted against jitted — XLA fusion differs between jit and eager, but
    sharding never does)."""
    mesh_equiv("""
db = tpch.generate(n_orders=64, seed=5)
tables = db.tables()
plan = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM", 128,
                "normal", extra=(("c", "l_quantity", "SUM", "cumulants"),))
pairs = [
    ("eager", compile_plan(plan, None)(tables),
     compile_plan(plan, mesh)(tables)),
    ("jit", jax.jit(compile_plan(plan, None))(tables),
     jax.jit(compile_plan(plan, mesh))(tables)),
]
""", devices=4)


# ------------------------------------------- generalized canonical fold
def test_tree_fold_pow2_base_plus_sequential_tail():
    """The fixed tree shape: balanced pairwise over the largest pow2
    prefix, then a sequential left fold of the tail — checked structurally
    with a symbolic merge."""
    class Sym(uda.UDA):
        def merge(self, a, b):
            return f"({a}+{b})"

    u = Sym()
    assert uda.tree_fold(u, ["a"]) == "a"
    assert uda.tree_fold(u, list("abcd")) == "((a+b)+(c+d))"
    assert uda.tree_fold(u, list("abcde")) == "(((a+b)+(c+d))+e)"
    assert uda.tree_fold(u, list("abcdef")) == "((((a+b)+(c+d))+e)+f)"
    assert uda.tree_fold(u, list("abc")) == "((a+b)+c)"
    with pytest.raises(ValueError):
        uda.tree_fold(u, [])


@pytest.mark.parametrize("num_chunks", [2, 3, 5, 6, 8])
def test_accumulate_chunk_states_fold_matches_chunked(num_chunks):
    """accumulate_chunked == tree_fold over accumulate_chunk_states, bit
    for bit, for any chunk count (the sharded frontend composes the two
    across shards) — and stays allclose to the unchunked accumulate."""
    import jax
    r = np.random.default_rng(3)
    n = 30
    p = jnp.asarray(r.uniform(0.05, 0.95, n), default_float())
    v = jnp.asarray(r.integers(1, 6, n), default_float())
    g = jnp.asarray(r.integers(0, G, n))
    udas = {"n": uda.SumNormal(), "c": uda.AtLeastOne()}
    folded = uda.accumulate_chunked(udas, p, v, g, max_groups=G,
                                    num_chunks=num_chunks)
    parts = uda.accumulate_chunk_states(udas, p, v, g, max_groups=G,
                                        num_chunks=num_chunks)
    assert len(parts) == num_chunks
    for name, u in udas.items():
        refold = uda.tree_fold(u, [q[name] for q in parts])
        for a, b in zip(jax.tree.leaves(folded[name]),
                        jax.tree.leaves(refold)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    flat = uda.accumulate(udas, p, v, g, max_groups=G)
    np.testing.assert_allclose(np.asarray(folded["n"].terms),
                               np.asarray(flat["n"].terms), rtol=1e-12)


@pytest.mark.multidevice
def test_compile_plan_3dev_non_pow2_bit_equal(mesh_equiv):
    """The determinism contract now covers shard counts that do NOT
    divide the canonical chunk grid: every chunk state is computed on one
    shard, gathered, and folded in the one fixed tree — 3 devices against
    the 8-chunk grid, eager and jit, plus a non-pow2 grid."""
    mesh_equiv("""
db = tpch.generate(n_orders=64, seed=5)
tables = db.tables()
plan = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity", "SUM", 128,
                "normal", extra=(("c", "l_quantity", "SUM", "cumulants"),))
mk = lambda mesh=None, **kw: compile_plan(plan, mesh, **kw)(tables)
pairs = [
    ("eager", mk(), mk(mesh)),
    ("jit", jax.jit(compile_plan(plan, None))(tables),
     jax.jit(compile_plan(plan, mesh))(tables)),
    ("chunks6", mk(canonical_chunks=6), mk(mesh, canonical_chunks=6)),
]
""", devices=3)
