"""TPC-H workload (paper §VIII): all query/mode cells + semantic checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.db import operators as ops
from repro.db import tpch


@pytest.fixture(scope="module")
def db():
    return tpch.generate(n_orders=200, seed=7)


@pytest.mark.parametrize("qname", list(tpch.QUERIES))
@pytest.mark.parametrize("mode", tpch.MODES)
def test_all_query_mode_cells_run(db, qname, mode):
    out = tpch.QUERIES[qname](db, mode)
    for leaf in jax.tree.leaves(out):
        arr = np.asarray(leaf)
        assert not np.isnan(arr.astype(float)).any(), (qname, mode)


def test_q1_deterministic_matches_numpy(db):
    out = tpch.q1(db, "deterministic")
    li = db.lineitem
    mask = np.asarray(li.valid) & (np.asarray(li["l_shipdate"])
                                   <= tpch.DAY0_1995 + 500)
    rf = np.asarray(li["l_returnflag"])
    ls = np.asarray(li["l_linestatus"])
    qty = np.asarray(li["l_quantity"])
    # group codes sorted ascending; recompute the same grouping
    codes = rf * (1 << 20) + ls
    got_total = np.asarray(out["sum_qty"])[np.asarray(out["valid"])].sum()
    assert got_total == qty[mask].sum()


def test_q1_aggregate_mean_matches_deterministic_expectation(db):
    """E[SUM] over worlds == sum of p_i * v_i (per group)."""
    agg = tpch.q1(db, "aggregate")
    li = db.lineitem
    sel = ops.select(li, lambda t: t["l_shipdate"] <= tpch.DAY0_1995 + 500)
    ids, _, _ = ops.group_ids(sel, ["l_returnflag", "l_linestatus"], 8)
    p = np.asarray(sel.masked_prob())
    v = np.asarray(sel["l_quantity"])
    mu_want = np.bincount(np.asarray(ids), p * v, minlength=8)
    np.testing.assert_allclose(np.asarray(agg["qty"][0]), mu_want,
                               rtol=1e-10)


def test_q6_exact_vs_moment_vs_normal(db):
    out = tpch.q6(db, "aggregate", num_freq=1 << 12)
    mu, var = out["normal"]
    coeffs = np.asarray(out["exact_coeffs"])
    grid = np.arange(len(coeffs))
    mean_exact = float((coeffs * grid).sum())
    var_exact = float((coeffs * (grid - mean_exact) ** 2).sum())
    assert float(mu) == pytest.approx(mean_exact, rel=1e-6)
    assert float(var) == pytest.approx(var_exact, rel=1e-4)
    # moment path agrees on first two cumulants
    cum = np.asarray(out["cumulants"])
    assert cum[0] == pytest.approx(mean_exact, rel=1e-6)
    assert cum[1] == pytest.approx(var_exact, rel=1e-4)


def test_q18_reweight_is_probability(db):
    out = tpch.q18(db, "aggregate")
    p = np.asarray(out["p_qualifies"])[np.asarray(out["valid"])]
    assert ((p >= 0) & (p <= 1)).all()
    gc = tpch.q18(db, "group_confidence")
    c = np.asarray(gc["confidence"])[np.asarray(gc["valid"])]
    assert ((c >= 0) & (c <= 1 + 1e-9)).all()


def test_q20_full_plan_probabilities_valid(db):
    out = tpch.q20(db, "aggregate")
    p = np.asarray(out["prob"])[np.asarray(out["valid"])]
    assert ((p >= -1e-12) & (p <= 1 + 1e-9)).all()
    conf = tpch.q20(db, "confidence")["confidence"]
    assert 0.0 <= float(conf) <= 1.0


def test_queries_scale_invariant_shapes():
    """Static capacities: output shapes don't depend on the data."""
    small = tpch.generate(n_orders=50, seed=1)
    big = tpch.generate(n_orders=400, seed=2)
    a = tpch.q1(small, "aggregate")
    b = tpch.q1(big, "aggregate")
    assert jax.tree.map(jnp.shape, a) == jax.tree.map(jnp.shape, b)


# --------------------------------------------------- sharded frontend
@pytest.mark.multidevice
@pytest.mark.parametrize("qname", sorted(tpch.QUERIES))
def test_query_mesh_bit_equal(mesh_equiv, qname):
    """Each TPC-H plan through the sharded frontend on a 2-device mesh is
    BIT-IDENTICAL to the single-device compile, in every probabilistic
    mode (scan/join/group-id inputs sharded end-to-end)."""
    mesh_equiv(f"""
db = tpch.generate(n_orders=48, seed=3)
fn = tpch.QUERIES[{qname!r}]
pairs = [(mode, fn(db, mode), fn(db, mode, mesh=mesh))
         for mode in ("confidence", "group_confidence", "aggregate")]
""")


@pytest.mark.multidevice
@pytest.mark.parametrize("devices", [2, 3, 4])
def test_all_queries_mesh_bit_equal_any_shard_count(mesh_equiv, devices):
    """The determinism contract on 2-, 3- and 4-shard meshes for ALL five
    TPC-H queries (aggregate mode), both with the default gather-join
    lowering and with a tiny join_gather_budget that lowers every
    over-budget FK join to a hash-exchange strategy — pinned to the
    unfused ShuffleJoin + shuffle-home + PartialAgg path with
    copartition=False (the cost model would otherwise fuse q3's GROUP
    BY; the fused pipeline has its own dedicated parity test) — one
    subprocess per shard count."""
    mesh_equiv("""
db = tpch.generate(n_orders=48, seed=3)
shuffle = dict(join_gather_budget=4, copartition=False)
pairs = []
for qname, fn in sorted(tpch.QUERIES.items()):
    ref = fn(db, "aggregate")
    pairs.append((qname, ref, fn(db, "aggregate", mesh=mesh)))
    pairs.append((qname + "/shuffle", ref,
                  fn(db, "aggregate", mesh=mesh, plan_opts=shuffle)))
""", devices=devices)


@pytest.mark.multidevice
@pytest.mark.parametrize("devices", [2, 3, 4])
def test_q3_q18_copartitioned_bit_equal_zero_roundtrips(devices):
    """The fused shuffle -> aggregate pipeline on real queries: Q3 with a
    per-join budget that hash-exchanges the orders join (the GROUP BY
    keys on l_orderkey, so the cost model fuses it) and Q18 with
    ``agg_shuffle_budget`` repartitioning the lineitem aggregation — both
    BIT-IDENTICAL to the single-device compile on 2-, 3- and 4-shard
    meshes, with ZERO shuffle_back round-trips (asserted via the
    collective counter) and the one-psum partitioned merge."""
    from conftest import run_sub
    run_sub("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db import distributed as dist
from repro.db import physical as phys, tpch
mesh = make_mesh((__D__,), ("data",))
db = tpch.generate(n_orders=48, seed=3)
for qname, kwargs, opts in (("q3", dict(order_join_budget=4), {}),
                            ("q18", {}, dict(agg_shuffle_budget=4))):
    fn = tpch.QUERIES[qname]
    for mode in ("group_confidence", "aggregate"):
        ref = fn(db, mode, **kwargs)
        dist.reset_collective_counts()
        got = fn(db, mode, mesh=mesh, plan_opts=opts, **kwargs)
        c = dict(dist.COLLECTIVE_COUNTS)
        assert c.get("shuffle_back", 0) == 0, (qname, mode, c)
        assert c.get("merge_psum", 0) >= 1, (qname, mode, c)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), \\
                (qname, mode)
print("FUSED OK")
""".replace("__D__", str(devices)), devices=devices)


def test_deterministic_db_gives_deterministic_answers():
    """p = 1 everywhere: aggregate mode's mean == deterministic answer,
    variance == 0 (the gamma-embedding sanity check, §IV-E)."""
    db1 = tpch.generate(n_orders=100, seed=3, prob_mode="ones")
    det = tpch.q1(db1, "deterministic")
    agg = tpch.q1(db1, "aggregate")
    np.testing.assert_allclose(np.asarray(agg["qty"][0]),
                               np.asarray(det["sum_qty"]).astype(float),
                               rtol=1e-12)
    np.testing.assert_allclose(np.asarray(agg["qty"][1]), 0.0, atol=1e-9)
