"""Log-CF exact COUNT/SUM (the TPU adaptation) vs oracles (paper §V-A/C)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pgf as P
from repro.core import poisson_binomial as pb
from repro.core.config import default_float


def test_count_matches_possible_worlds(rng):
    probs = rng.uniform(0.05, 0.95, 14)
    oracle = P.possible_worlds_pgf(probs, np.ones(14), "COUNT")
    f = pb.count_pgf(jnp.asarray(probs, default_float()))
    for k, pr in oracle.items():
        assert float(f.coeffs[int(k)]) == pytest.approx(pr, abs=1e-12)


def test_sum_matches_possible_worlds(rng):
    probs = rng.uniform(0.05, 0.95, 12)
    values = rng.integers(0, 7, 12)
    oracle = P.possible_worlds_pgf(probs, values, "SUM")
    f = pb.sum_pgf(jnp.asarray(probs, default_float()),
                   jnp.asarray(values, default_float()))
    for k, pr in oracle.items():
        assert float(f.coeffs[int(k)]) == pytest.approx(pr, abs=1e-12)


def test_grouped_sum_equals_cf_sum(rng):
    """Paper-faithful grouped/stretch/FFT path == log-CF path (§V-C)."""
    probs = rng.uniform(0.05, 0.95, 40)
    values = rng.integers(0, 9, 40)
    a = pb.sum_pgf(jnp.asarray(probs, default_float()),
                   jnp.asarray(values, default_float()))
    b = pb.sum_pgf_grouped(jnp.asarray(probs, default_float()),
                           jnp.asarray(values))
    ka = np.asarray(a.coeffs)
    kb = np.asarray(b.coeffs)
    n = min(len(ka), len(kb))
    np.testing.assert_allclose(ka[:n], kb[:n], atol=1e-10)
    assert np.all(ka[n:] < 1e-10) and np.all(kb[n:] < 1e-10)


def test_count_binomial_closed_form():
    """All p equal: Poisson binomial == Binomial(n, p)."""
    import math
    n, p = 25, 0.3
    f = pb.count_pgf(jnp.full((n,), p, default_float()))
    for k in range(n + 1):
        want = math.comb(n, k) * p ** k * (1 - p) ** (n - k)
        assert float(f.coeffs[k]) == pytest.approx(want, rel=1e-9, abs=1e-13)


def test_blocked_scan_equals_unblocked(rng):
    probs = jnp.asarray(rng.uniform(0.01, 0.99, 1000), default_float())
    values = jnp.asarray(rng.integers(0, 5, 1000), default_float())
    la1, an1 = pb.logcf_terms(probs, values, 301, block=64)
    la2, an2 = pb.logcf_terms(probs, values, 301, block=4096)
    np.testing.assert_allclose(np.asarray(la1), np.asarray(la2), atol=1e-9)


def test_zero_and_one_probability_tuples():
    """p=0 is absent (no effect); p=1 shifts deterministically."""
    probs = jnp.asarray([0.0, 1.0, 0.5], default_float())
    values = jnp.asarray([3.0, 2.0, 4.0], default_float())
    f = pb.sum_pgf(probs, values)
    assert float(f.coeffs[2]) == pytest.approx(0.5, abs=1e-9)   # only p=1
    assert float(f.coeffs[6]) == pytest.approx(0.5, abs=1e-9)   # 2 + 4
    assert float(f.coeffs.sum()) == pytest.approx(1.0, abs=1e-9)
