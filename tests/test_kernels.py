"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles
(interpret=True on CPU; BlockSpec tiling identical to the TPU target).
The grouped (G, F)-tiled CF kernel is covered in test_group_cf.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import pb_cf, polymul, cumulants

pytestmark = pytest.mark.kernels


@pytest.mark.parametrize("n,num_freq", [
    (1, 8), (100, 129), (256, 256), (300, 257), (1000, 1001),
    (2048, 4096), (5000, 2047),
])
def test_logcf_kernel_sweep(rng, n, num_freq):
    p = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32)
    v = jnp.asarray(rng.integers(0, 50, n), jnp.int32)
    la_k, an_k = pb_cf.logcf(p, v, num_freq=num_freq, interpret=True)
    la_r, an_r = ref.logcf_ref(p, v.astype(jnp.float32), num_freq)
    np.testing.assert_allclose(np.asarray(la_k), np.asarray(la_r),
                               atol=5e-4 * max(1, n / 500))
    np.testing.assert_allclose(np.asarray(an_k), np.asarray(an_r),
                               atol=5e-4 * max(1, n / 500))


def test_logcf_kernel_large_values(rng):
    """k*a far beyond int32/f32 exactness: the split-modmult must hold."""
    n, num_freq = 500, 1 << 14
    p = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32)
    v = jnp.asarray(rng.integers(0, num_freq, n), jnp.int32)
    la_k, an_k = pb_cf.logcf(p, v, num_freq=num_freq, interpret=True)
    la_r, an_r = ref.logcf_ref(jnp.asarray(p, jnp.float64),
                               jnp.asarray(v, jnp.float64), num_freq)
    np.testing.assert_allclose(np.asarray(la_k),
                               np.asarray(la_r, dtype=np.float32), atol=2e-3)


@pytest.mark.parametrize("na,nb", [
    (1, 1), (5, 130), (129, 129), (130, 200), (512, 512), (1000, 300),
    (2000, 2000),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_polymul_kernel_sweep(rng, na, nb, dtype):
    a = jnp.asarray(rng.uniform(0, 1, na), dtype)
    b = jnp.asarray(rng.uniform(0, 1, nb), dtype)
    ck = polymul.polymul(a, b, interpret=True)
    cr = ref.polymul_ref(a, b)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr),
                               rtol=1e-5, atol=1e-4)


def test_polymul_block_sizes(rng):
    a = jnp.asarray(rng.uniform(0, 1, 700), jnp.float32)
    b = jnp.asarray(rng.uniform(0, 1, 500), jnp.float32)
    want = np.asarray(ref.polymul_ref(a, b))
    for bsize in (128, 256, 512):
        got = np.asarray(polymul.polymul(a, b, bsize=bsize, interpret=True))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n", [1, 100, 1024, 3000])
@pytest.mark.parametrize("orders", [4, 8])
def test_cumulants_kernel_sweep(rng, n, orders):
    p = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32)
    v = jnp.asarray(rng.uniform(0, 5, n), jnp.float32)
    sk = cumulants.cumulant_sums(p, v, orders=orders, interpret=True)
    sr = ref.cumulants_ref(p, v, orders)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr),
                               rtol=5e-3, atol=1e-4)


def test_ops_dispatch_small_uses_ref(rng):
    """Tiny inputs route to the oracle (padding would dominate)."""
    p = jnp.asarray(rng.uniform(0.1, 0.9, 8), jnp.float32)
    v = jnp.ones((8,), jnp.float32)
    la, an = ops.logcf(p, v, 9)
    la_r, an_r = ref.logcf_ref(p, v, 9)
    np.testing.assert_allclose(np.asarray(la), np.asarray(la_r), atol=1e-6)


def test_kernel_end_to_end_distribution(rng):
    """Kernel log-CF -> FFT == possible-worlds, closing the loop."""
    from repro.core import pgf as P, poisson_binomial as pb
    n = 300
    probs = rng.uniform(0.05, 0.95, n)
    p = jnp.asarray(probs, jnp.float32)
    la, an = pb_cf.logcf(p, jnp.ones((n,), jnp.int32), num_freq=n + 1,
                         interpret=True)
    coeffs = pb.logcf_finalize(jnp.asarray(la, jnp.float64),
                               jnp.asarray(an, jnp.float64))
    mean = float(jnp.sum(coeffs * jnp.arange(n + 1)))
    assert mean == pytest.approx(float(probs.sum()), rel=1e-3)
    assert float(coeffs.sum()) == pytest.approx(1.0, abs=1e-3)
