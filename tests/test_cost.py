"""Unit tests for the planner's cost model (db/cost.py): the estimates
the enumerate -> cost -> pick pass of physical.lower_plan compares, and
the parity of the budget-knob overrides with the PR-4 golden strategies."""
import pytest

from repro.db import cost as C
from repro.db import physical as phys
from repro.db.plans import FKJoin, GroupAgg, Scan, Select


def _model(n, **kw):
    return C.CostModel(n_shards=n, **kw)


def test_cost_addition_streams_bytes_and_peaks_residency():
    a = C.Cost(bytes_moved=10, peak_rows=100, flops=1)
    b = C.Cost(bytes_moved=5, peak_rows=40, flops=2)
    c = a + b
    assert c.bytes_moved == 15 and c.peak_rows == 100 and c.flops == 3


def test_total_weighs_all_three_axes():
    m = _model(4)
    assert m.total(C.Cost(bytes_moved=1000)) == 1000
    assert m.total(C.Cost(peak_rows=10)) == pytest.approx(
        m.peak_weight * m.elem_bytes * 10)
    assert m.total(C.Cost(flops=10)) == pytest.approx(m.flop_weight * 10)


def test_gather_monotone_in_build_rows():
    m = _model(4)
    costs = [m.total(C.gather_join(m, rows, 2))
             for rows in (64, 256, 1024, 4096)]
    assert costs == sorted(costs) and costs[0] < costs[-1]


def test_shuffle_cheaper_with_more_shards():
    """Fixed relation sizes: the hash exchange's per-device traffic
    shrinks as shards grow, once the shard count exceeds the bucket
    slack (below it the slack pins buckets at the full local rows and
    only the (n-1)/n transfer fraction moves)."""
    rows_b, rows_p = 1 << 14, 1 << 16
    totals = []
    for n in (4, 8, 16, 32):            # n >= slack = 4.0
        m = _model(n)
        bb = phys.bucket_capacity(rows_b // n, n, m.shuffle_slack)
        pb = phys.bucket_capacity(rows_p // n, n, m.shuffle_slack)
        totals.append(m.total(C.shuffle_join(m, bb, pb, 2)))
    assert totals == sorted(totals, reverse=True)


def test_gather_vs_shuffle_crossover_in_build_size():
    """Pure estimates (no budget override): tiny builds gather, huge
    builds exchange — the cost model reproduces the rule the budget knob
    used to hard-code, from physics instead of a constant."""
    n, probe = 8, 1 << 15
    m = _model(n)
    pb = phys.bucket_capacity(probe // n, n, m.shuffle_slack)

    def pick(build):
        bb = phys.bucket_capacity(build // n, n, m.shuffle_slack)
        g = m.total(C.gather_join(m, build, 2))
        s = m.total(C.shuffle_join(m, bb, pb, 2))
        return "gather" if g <= s else "shuffle"

    assert pick(1 << 8) == "gather"
    assert pick(1 << 22) == "shuffle"


def test_copartitioned_beats_shuffle_home_on_q3_shape():
    """Same buckets, GROUP BY on the probe key: skipping the response
    round-trip (and shipping only the aggregation's columns) is strictly
    cheaper than shuffle + gather-home, and the partitioned merge moves
    less than the chunked all-gather — the decision behind the fused
    pipeline."""
    m = _model(4)
    bb, pb = 256, 1024
    sj = C.shuffle_join(m, bb, pb, n_right_cols=2)
    cj = C.copartitioned_join(m, bb, pb, n_right_keep=0, n_carry=1)
    assert cj.bytes_moved < sj.bytes_moved
    add, fold, rf = C.agg_state_elems(
        (("sum", "v", "SUM", "normal"),), 512, 64, 0)
    pa = C.partial_agg(m, pb, 8, add, fold, rf)
    pt = C.partitioned_agg(m, m.n_shards * pb, 8, add, fold, rf)
    assert pt.bytes_moved < pa.bytes_moved
    assert m.total(cj + pt) < m.total(sj + pa)


def test_partitioned_merge_traffic_is_chunk_count_free():
    m = _model(4)
    add, fold, rf = C.agg_state_elems((("sum", "v", "SUM", "normal"),),
                                      1024, 64, 0)
    pa8 = C.partial_agg(m, 1000, 8, add, fold, rf)
    pa32 = C.partial_agg(m, 1000, 32, add, fold, rf)
    pt8 = C.partitioned_agg(m, 4000, 8, add, fold, rf)
    pt32 = C.partitioned_agg(m, 4000, 32, add, fold, rf)
    assert pa32.bytes_moved == 4 * pa8.bytes_moved
    assert pt32.bytes_moved == pt8.bytes_moved      # one psum either way
    assert pt8.bytes_moved == 2 * add * m.elem_bytes * m.xfer


def test_agg_state_elems_by_method():
    specs = (("sum", "v", "SUM", "normal"),
             ("c", "v", "SUM", "cumulants"),
             ("e", "v", "SUM", "exact"),
             ("m", "v", "MIN", "normal"))
    add, fold, flops = C.agg_state_elems(specs, 16, kappa=8, num_freq=32)
    # confidence + normal(2) + cumulants(8) + exact(2 * 32)
    assert add == 16 * (1 + 2 + C.CUMULANT_ORDERS + 64)
    assert fold == 16 * (2 * 8 + 2)                 # MinMax buffers+tails
    assert flops > 32                               # exact dominates


def test_minmax_prefers_the_chunked_merge():
    """MinMax states gather-fold across ALL owners in the partitioned
    merge (n x the state), so a MIN/MAX-heavy pass can keep PartialAgg
    even where a normal pass would fuse — the choice is per-pass."""
    m = _model(16)
    add, fold, rf = C.agg_state_elems((("minmax", "v", "MIN", "normal"),),
                                      1024, 64, 0)
    pa = C.partial_agg(m, 1000, 8, add, fold, rf)
    pt = C.partitioned_agg(m, 16000, 8, add, fold, rf)
    assert pt.bytes_moved > pa.bytes_moved


# ---------------------------------------------- override parity with PR 4
CAPS = {"lineitem": 4096, "orders": 1024, "customer": 256}


def _plan(keys=("l_partkey",)):
    li = Select(Scan("lineitem"), lambda t: t["x"] > 0)
    o = FKJoin(Scan("orders"), Scan("customer"), "o_custkey", "c_custkey",
               ("c_mktsegment",))
    j = FKJoin(li, o, "l_orderkey", "o_orderkey", ("o_orderdate",))
    return GroupAgg(j, keys, "l_quantity", "SUM", 512)


@pytest.mark.parametrize("budget,outer,inner", [
    (1 << 20, phys.GatherJoin, phys.GatherJoin),
    (1024, phys.GatherJoin, phys.GatherJoin),
    (1023, phys.ShuffleJoin, phys.GatherJoin),
    (256, phys.ShuffleJoin, phys.GatherJoin),
    (255, phys.ShuffleJoin, phys.ShuffleJoin),
    (1, phys.ShuffleJoin, phys.ShuffleJoin),
])
def test_budget_override_matches_pr4_rule(budget, outer, inner):
    """The PR-4 rule — shuffle iff build_rows > budget, per join — falls
    out of the cost override at every flip point (non-fusable GROUP BY so
    the strategies are exactly PR 4's)."""
    p = phys.lower_plan(_plan(), CAPS, n_shards=4, sharded=True,
                        join_gather_budget=budget)
    j = p.child.child
    assert isinstance(j, outer), phys.explain(p)
    assert isinstance(j.right, inner), phys.explain(p)


def test_chosen_nodes_carry_their_modeled_cost():
    p = phys.lower_plan(_plan(("l_orderkey",)), CAPS, n_shards=4,
                        sharded=True, join_gather_budget=1)
    agg = p.child
    assert isinstance(agg, phys.PartitionedAgg)
    assert isinstance(agg.cost, C.Cost) and agg.cost.bytes_moved > 0
    assert isinstance(agg.child.cost, C.Cost)
    assert agg.child.cost.bytes_moved > 0


def test_custom_cost_model_overrides_knobs():
    """A caller-supplied CostModel replaces the knob-derived one: with
    gather_budget=None the pure estimates run (and for this tiny build
    they pick the gather the budget would have forbidden)."""
    m = C.CostModel(n_shards=4, gather_budget=None)
    p = phys.lower_plan(_plan(), CAPS, n_shards=4, sharded=True,
                        join_gather_budget=1, model=m)
    assert isinstance(p.child.child, phys.GatherJoin)


def test_pure_estimates_pick_the_exchange_at_scale():
    """With the budget override disabled, BOTH sides compete unpenalized:
    a build side whose all-gather dwarfs the hash exchange lowers to the
    exchange strategies with no knob set — the estimate-driven planner
    the knobs are overrides OF."""
    caps = {"lineitem": 1 << 20, "orders": 1 << 18, "customer": 256}
    m = C.CostModel(n_shards=64, gather_budget=None)
    p = phys.lower_plan(_plan(), caps, n_shards=64, sharded=True, model=m)
    j = p.child.child
    assert isinstance(j, phys.ShuffleJoin), phys.explain(p)
    assert isinstance(j.right, phys.GatherJoin)     # customer stays tiny
    fused = phys.lower_plan(_plan(("l_orderkey",)), caps, n_shards=64,
                            sharded=True, model=m)
    assert isinstance(fused.child, phys.PartitionedAgg), phys.explain(fused)
    assert isinstance(fused.child.child, phys.CoPartitionedJoin)


# ----------------------------------------------------- out-of-core scans
def test_wave_schedule_sizes_from_double_buffered_budget():
    """The largest wave whose TWO in-flight slabs fit the per-device
    budget: budget // (2 * chunk_rows) local chunk slots."""
    s = C.wave_schedule(chunk_rows=512, chunks=8, shards=1, budget=2048)
    assert (s.local_chunks_per_wave, s.n_waves) == (2, 4)
    assert s.wave_rows == 1024 and s.padded_capacity == 4096
    # tighter budget -> more, smaller waves; never below one chunk slot
    t = C.wave_schedule(chunk_rows=512, chunks=8, shards=1, budget=100)
    assert (t.local_chunks_per_wave, t.n_waves) == (1, 8)


def test_wave_schedule_widens_for_pruned_columns():
    """A column-pruned slab's rows are narrower, so the same byte budget
    holds more of them: width (pruned+2)/(full+2) divides the effective
    row budget.  Width 1.0 is exactly the unpruned schedule; the
    override hook still pins the wave regardless of width."""
    base = C.wave_schedule(chunk_rows=512, chunks=8, shards=1, budget=1024)
    assert base.local_chunks_per_wave == 1
    wide = C.wave_schedule(chunk_rows=512, chunks=8, shards=1, budget=1024,
                           width=0.5)
    assert (wide.local_chunks_per_wave, wide.n_waves) == (2, 4)
    third = C.wave_schedule(chunk_rows=512, chunks=8, shards=1, budget=1024,
                            width=1 / 3)
    assert third.local_chunks_per_wave == 3
    same = C.wave_schedule(chunk_rows=512, chunks=8, shards=1, budget=1024,
                           width=1.0)
    assert same == base
    pinned = C.wave_schedule(chunk_rows=512, chunks=8, shards=1,
                             budget=1024, override_chunks=1, width=0.25)
    assert pinned.local_chunks_per_wave == 1


def test_wave_schedule_clamps_to_the_chunk_grid():
    """A budget larger than the table collapses to one wave holding every
    chunk slot (the streamed path degenerates to resident-in-one-wave)."""
    s = C.wave_schedule(chunk_rows=512, chunks=8, shards=1, budget=1 << 30)
    assert (s.local_chunks_per_wave, s.n_waves) == (8, 1)
    assert s.padded_capacity == 4096


def test_wave_schedule_ragged_tail_pads_a_final_wave():
    """3 of 8 chunk slots per wave: 3 waves cover 9 slots, so the host
    table pads one extra slot and the last wave is partly padding —
    uniform wave shapes keep one compiled wave function."""
    s = C.wave_schedule(chunk_rows=512, chunks=8, shards=1, budget=None,
                        override_chunks=3)
    assert (s.local_chunks_per_wave, s.n_waves) == (3, 3)
    assert s.padded_capacity == 9 * 512 > 8 * 512


def test_wave_schedule_splits_chunk_slots_across_shards():
    """8 chunk slots on 3 shards: ceil(8/3) = 3 local slots per shard;
    a 1-chunk-per-wave schedule then runs 3 waves of 3 global chunks."""
    s = C.wave_schedule(chunk_rows=512, chunks=8, shards=3, budget=1024)
    assert (s.local_chunks_per_wave, s.n_shards) == (1, 3)
    assert s.chunks_per_wave == 3 and s.n_waves == 3
    assert s.padded_capacity == 9 * 512


def test_streamed_scan_cost_charges_transfer_not_collective():
    """Every row crosses host->device once (no (n-1)/n discount) and
    residency is two double-buffered per-device slabs, independent of the
    table size — the flat-memory contract the smoke gate checks."""
    m = _model(1)
    c = C.streamed_scan(m, rows=4096, wave_rows=1024, n_cols=1)
    assert c.bytes_moved == 4096 * 3 * m.elem_bytes
    assert c.peak_rows == 2 * 1024 * 3
    big = C.streamed_scan(m, rows=8 * 4096, wave_rows=1024, n_cols=1)
    assert big.peak_rows == c.peak_rows          # flat under 8x growth
    assert big.bytes_moved == 8 * c.bytes_moved  # transfer scales linearly
    m4 = _model(4)
    c4 = C.streamed_scan(m4, rows=4096, wave_rows=1024, n_cols=1)
    assert c4.bytes_moved == c.bytes_moved       # transfer, not collective
    assert c4.peak_rows == c.peak_rows // 4      # slabs split over shards
