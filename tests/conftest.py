# NOTE: no --xla_force_host_platform_device_count here (smoke tests and
# benches must see 1 device; only launch/dryrun pins 512).  Multi-device
# tests spawn subprocesses with their own XLA_FLAGS.
import os
import subprocess
import sys

import jax
import pytest

from repro.core import enable_x64

enable_x64()  # the PGF engine's exactness tests need f64 on CPU

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str, devices: int = 2) -> str:
    """Run a test script in a subprocess with its own multi-device CPU
    XLA_FLAGS (the conftest pins the parent process to 1 device) — the ONE
    copy of the boilerplate shared by every `multidevice` test module."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.fixture(autouse=True, scope="module")
def _flat_compiler_footprint():
    """The CPU backend here (jaxlib 0.4.36) segfaults inside
    backend_compile once a single process accretes a few hundred live
    compiled executables — the unmodified full suite dies with a fatal
    SIGSEGV in whichever test file crosses the threshold (reproduced in
    test_models and test_group_cf, always under compile_or_get_cached).
    Dropping the jit caches at module boundaries keeps the compiler
    footprint flat; cross-module cache reuse is negligible since each
    file compiles its own shapes."""
    yield
    jax.clear_caches()


@pytest.fixture
def rng():
    import numpy as np
    return np.random.default_rng(0)


# --------------------------------------------------- mesh-equivalence harness
# `compile_plan(root, mesh)` promises BIT-IDENTICAL results to the
# single-device compile (the canonical-chunk fold tree, db/plans.py).  The
# harness runs a setup script under a multi-device CPU subprocess and
# asserts exact equality — shapes, dtypes and every bit of every leaf.
_MESH_EQUIV_TEMPLATE = '''
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db import tpch
from repro.db.plans import (FKJoin, GroupAgg, Map, Project, ReweightGreater,
                            Scan, Select, compile_plan)
from repro.db.table import Table
mesh = make_mesh((__DEVICES__,), ("data",))

__SETUP__

if "pairs" not in dir():
    # default harness shape: setup defined `plan` and `tables`
    pairs = [("plan", compile_plan(plan, None)(tables),
              compile_plan(plan, mesh)(tables))]

for name, ref, got in pairs:
    la, ta = jax.tree.flatten(ref)
    lb, tb = jax.tree.flatten(got)
    assert str(ta) == str(tb), (name, str(ta), str(tb))
    for i, (a, b) in enumerate(zip(la, lb)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.dtype == b.dtype, \\
            (name, i, a.shape, b.shape, a.dtype, b.dtype)
        if not np.array_equal(a, b):
            eq = a == b
            f = a.astype(np.float64, copy=False)
            g = b.astype(np.float64, copy=False)
            eq |= np.isnan(f) & np.isnan(g)      # NaN == NaN for the diff
            bad = np.flatnonzero(~eq)
            raise AssertionError(
                name + " leaf " + str(i) + ": " + str(bad.size)
                + " of " + str(a.size) + " elements differ, max |d| = "
                + str(np.nanmax(np.abs(f - g))))
print("BITEQ OK")
'''


@pytest.fixture
def mesh_equiv():
    """Run `setup` under a multi-device CPU subprocess and assert that
    compile_plan on the 1-D data mesh is bit-equal to the single-device
    compile.  `setup` either defines `plan` and `tables`, or a `pairs`
    list of (name, ref_pytree, got_pytree) for query-level checks; the
    subprocess exposes `mesh`, `tpch`, every plan Node and `compile_plan`.
    """
    def check(setup: str, devices: int = 2) -> str:
        # setup first so its own __DEVICES__ occurrences resolve too
        script = (_MESH_EQUIV_TEMPLATE
                  .replace("__SETUP__", setup)
                  .replace("__DEVICES__", str(devices)))
        out = run_sub(script, devices=devices)
        assert "BITEQ OK" in out
        return out
    return check
