# NOTE: no --xla_force_host_platform_device_count here (smoke tests and
# benches must see 1 device; only launch/dryrun pins 512).  Multi-device
# tests spawn subprocesses with their own XLA_FLAGS.
import os
import subprocess
import sys

import jax
import pytest

from repro.core import enable_x64

enable_x64()  # the PGF engine's exactness tests need f64 on CPU

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str, devices: int = 2) -> str:
    """Run a test script in a subprocess with its own multi-device CPU
    XLA_FLAGS (the conftest pins the parent process to 1 device) — the ONE
    copy of the boilerplate shared by every `multidevice` test module."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.fixture
def rng():
    import numpy as np
    return np.random.default_rng(0)
