# NOTE: no --xla_force_host_platform_device_count here (smoke tests and
# benches must see 1 device; only launch/dryrun pins 512).  Multi-device
# tests spawn subprocesses with their own XLA_FLAGS.
import jax
import pytest

from repro.core import enable_x64

enable_x64()  # the PGF engine's exactness tests need f64 on CPU


@pytest.fixture
def rng():
    import numpy as np
    return np.random.default_rng(0)
