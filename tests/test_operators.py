"""Relational operators (paper Table I) vs brute-force semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pgf import possible_worlds_pgf
from repro.db import operators as ops
from repro.db.table import Table


def small_table(rng, n=20, groups=3):
    return Table.from_columns(
        {"g": jnp.asarray(rng.integers(0, groups, n)),
         "v": jnp.asarray(rng.integers(1, 8, n).astype(float)),
         "key": jnp.arange(n)},
        prob=jnp.asarray(rng.uniform(0.05, 0.95, n)))


def test_select_masks_only(rng):
    t = small_table(rng)
    s = ops.select(t, lambda x: x["v"] > 3)
    assert s.capacity == t.capacity
    np.testing.assert_array_equal(
        np.asarray(s.valid), np.asarray(t.valid & (t["v"] > 3)))


def test_project_atleastone(rng):
    t = small_table(rng)
    out = ops.project(t, ["g"], max_groups=8)
    g_np = np.asarray(t["g"])
    p_np = np.asarray(t.prob)
    live = np.asarray(out.valid)
    for i in np.nonzero(live)[0]:
        gval = int(np.asarray(out["g"])[i])
        want = 1 - np.prod(1 - p_np[g_np == gval])
        assert float(out.prob[i]) == pytest.approx(want, abs=1e-12)


def test_fk_join_semantics(rng):
    left = small_table(rng, n=30, groups=5)
    right = Table.from_columns(
        {"rkey": jnp.arange(5), "payload": jnp.asarray([10., 11, 12, 13, 14])},
        prob=jnp.asarray(rng.uniform(0.2, 0.9, 5)))
    j = ops.fk_join(left, right, "g", "rkey", ["payload"])
    for i in range(left.capacity):
        g = int(left["g"][i])
        assert float(j["payload"][i]) == 10.0 + g
        assert float(j.prob[i]) == pytest.approx(
            float(left.prob[i]) * float(right.prob[g]), abs=1e-12)
    # invalid right rows kill matches
    right2 = right.with_valid(jnp.asarray([True, False, True, True, True]))
    j2 = ops.fk_join(left, right2, "g", "rkey", ["payload"])
    dead = np.asarray(left["g"]) == 1
    assert not np.asarray(j2.valid)[dead].any()


def test_general_join_cross_product(rng):
    a = Table.from_columns({"x": jnp.asarray([1, 2])},
                           prob=jnp.asarray([0.5, 0.6]))
    b = Table.from_columns({"y": jnp.asarray([7, 8, 9])},
                           prob=jnp.asarray([0.1, 0.2, 0.3]))
    j = ops.general_join(a, b, lambda l, r, i, jj: jnp.ones_like(i, bool),
                         ["y"])
    assert j.capacity == 6
    # p = px * py (Table I row IV)
    want = np.outer([0.5, 0.6], [0.1, 0.2, 0.3]).reshape(-1)
    np.testing.assert_allclose(np.asarray(j.prob), want, atol=1e-12)


def test_group_normal_and_cumulants_consistent(rng):
    t = small_table(rng, n=40, groups=4)
    ids, _, _ = ops.group_ids(t, ["g"], 8)
    v = t["v"].astype(t.prob.dtype)
    mu, var = ops.group_normal_terms(t, v, ids, 8)
    cum = ops.group_cumulant_terms(t, v, ids, 8)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(cum[:, 0]),
                               atol=1e-10)
    np.testing.assert_allclose(np.asarray(var), np.asarray(cum[:, 1]),
                               atol=1e-10)


def test_group_logcf_exact_sum(rng):
    t = small_table(rng, n=12, groups=2)
    ids, codes, gvalid = ops.group_ids(t, ["g"], 4)
    F = 64
    la, an = ops.group_logcf(t, t["v"], ids, 4, F)
    coeffs = np.asarray(ops.group_logcf_finalize(la, an))
    g_np, v_np, p_np = (np.asarray(t["g"]), np.asarray(t["v"]),
                        np.asarray(t.prob))
    codes_np = np.asarray(codes)
    for g in range(2):
        gi = int(np.searchsorted(codes_np, g))
        oracle = possible_worlds_pgf(p_np[g_np == g], v_np[g_np == g], "SUM")
        for outcome, pr in oracle.items():
            assert coeffs[gi, int(outcome)] == pytest.approx(pr, abs=1e-10)


@pytest.mark.parametrize("sign,name", [(1.0, "MIN"), (-1.0, "MAX")])
def test_group_minmax_vs_possible_worlds(rng, sign, name):
    for seed in range(3):
        r = np.random.default_rng(seed)
        n, G = 18, 4
        g_np = r.integers(0, G, n)
        p_np = r.uniform(0.05, 0.95, n)
        v_np = r.integers(1, 8, n).astype(float)
        valid = r.uniform(0, 1, n) > 0.2
        t = Table.from_columns({"g": jnp.asarray(g_np),
                                "v": jnp.asarray(v_np)},
                               prob=jnp.asarray(p_np),
                               valid=jnp.asarray(valid))
        ids, codes, _ = ops.group_ids(t, ["g"], G + 2)
        res = ops.group_minmax(t, t["v"], ids, G + 2, sign=sign)
        rg = np.asarray(res["run_group"])
        rv = np.asarray(res["run_value"])
        rm = np.asarray(res["run_mass"])
        pe = np.asarray(res["p_empty"])
        codes_np = np.asarray(codes)
        for g in range(G):
            sel = (g_np == g) & valid
            if not sel.any():
                continue
            oracle = possible_worlds_pgf(p_np[sel], v_np[sel], name)
            gi = int(np.searchsorted(codes_np, g))
            for outcome, pr in oracle.items():
                got = pe[gi] if np.isinf(outcome) \
                    else rm[(rg == gi) & (rv == outcome)].sum()
                assert got == pytest.approx(pr, abs=1e-12), (seed, g, outcome)


def test_reweight_and_normal_greater(rng):
    t = small_table(rng)
    p_cond = jnp.asarray(rng.uniform(0, 1, t.capacity))
    r = ops.reweight(t, p_cond)
    np.testing.assert_allclose(np.asarray(r.prob),
                               np.asarray(t.prob) * np.asarray(p_cond),
                               atol=1e-12)
    # normal_greater against scipy
    from scipy.stats import norm
    mu = jnp.asarray([10.0, 0.0])
    var = jnp.asarray([4.0, 1.0])
    got = np.asarray(ops.normal_greater(mu, var, jnp.asarray([11.0, 0.0])))
    want = 1 - norm.cdf([11.0, 0.0], loc=[10, 0], scale=[2, 1])
    np.testing.assert_allclose(got, want, atol=1e-7)


def test_plan_dsl_matches_direct_operators(rng):
    from repro.db import plans
    from repro.db.plans import Scan, Select, GroupAgg
    t = small_table(rng, n=30)
    tables = {"t": t}
    plan = GroupAgg(Select(Scan("t"), lambda x: x["v"] > 2),
                    keys=("g",), value="v", agg="SUM", max_groups=8)
    out = plans.compile_plan(plan)(tables)
    s = ops.select(t, lambda x: x["v"] > 2)
    ids, _, _ = ops.group_ids(s, ["g"], 8)
    mu, var = ops.group_normal_terms(s, s["v"].astype(s.prob.dtype), ids, 8)
    np.testing.assert_allclose(np.asarray(out["sum"][0]), np.asarray(mu),
                               atol=1e-12)
