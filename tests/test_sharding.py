"""Sharding rule table: divisibility-aware fallback, first-fit constraints."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import Rules, _spec_fits
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1, 1)


def test_param_rules_shard_matching_dims(mesh):
    r = Rules(mesh, fsdp=True)
    # 1x1 mesh: every axis has size 1, divisibility always holds
    spec = r.param_spec("blocks/0/mixer/wq", (64, 128))
    assert spec == P("data", "model")
    spec = r.param_spec("blocks/0/mixer/wo", (128, 64))
    assert spec == P("model", "data")
    assert r.param_spec("blocks/0/norm1/gamma", (64,)) == P()
    assert r.param_spec("embed", (512, 64)) == P("model", "data")
    assert r.param_spec("blocks/0/ffn/experts_in", (8, 64, 96)) == \
        P("model", "data", None)


def test_param_rules_drop_non_dividing_axes():
    import numpy as np
    from jax.sharding import Mesh
    # fake a (1, 16)-shaped logical mesh over 1 device repeated? Use the
    # divisibility check directly instead.
    mesh = make_host_mesh(1, 1)
    r = Rules(mesh, fsdp=True)
    # simulate: dim 7 is never divisible by >1 axes; on 1x1 everything
    # divides, so exercise _resolve via a crafted mesh-shape view
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 16}
    r.mesh = FakeMesh()
    assert r.param_spec("blocks/0/mixer/wk", (64, 4 * 7)) == P("data", None)
    assert r.param_spec("blocks/0/mixer/wk", (63, 32)) == P(None, "model")


def test_stacked_leading_dim_gets_none(mesh):
    r = Rules(mesh)
    spec = r.param_spec("blocks/0/mixer/wq", (4, 64, 128))
    assert spec == P(None, "data", "model")


def test_fsdp_off_drops_dp(mesh):
    r = Rules(mesh, fsdp=False)
    assert r.param_spec("blocks/0/mixer/wq", (64, 128)) == P(None, "model")


def test_spec_fits():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 16}
    m = FakeMesh()
    assert _spec_fits(m, P(None, "model"), (3, 32))
    assert not _spec_fits(m, P(None, "model"), (3, 31))
    assert not _spec_fits(m, P("pod", None), (8, 8))
    assert _spec_fits(m, P(("data",), "model"), (8, 16))


def test_constrain_noop_outside_context():
    import jax.numpy as jnp
    from repro import sharding
    x = jnp.ones((4, 4))
    assert sharding.constrain(x, "residual") is x
    assert sharding.constrain_first_fit(x, [P("model", None)]) is x


def test_act_spec_sp_mode(mesh):
    r = Rules(mesh, sp=True)
    assert r.act_spec("residual")[2] == "model"
    r2 = Rules(mesh, sp=False)
    assert r2.act_spec("residual")[2] is None
