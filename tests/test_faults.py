"""Self-healing execution: ExecutionReport diagnostics, the RetryPolicy
escalation ladder, and fault-tolerant wave resume (db/plans.py,
db/report.py, testing/faults.py).

The contracts under test:

* a clean run's report is CLEAN (``issues() == {}``) and collecting it
  changes no result bit;
* every failure mode — exchange overflow, group-code-table overflow,
  MIN/MAX truncation tail mass, injected transfer faults — is DETECTED
  in the report (including through boolean outputs that swallow the NaN
  poison) and HEALED by ``run_plan``'s escalation within
  ``RetryPolicy.max_attempts``;
* the healed answer is BIT-IDENTICAL to a run launched with the final
  escalated parameters from the start (every comparison here is exact
  equality, never allclose);
* a fault-injected streamed run resumes from the last completed wave —
  completed waves are never re-streamed.
"""
import jax
import numpy as np
import pytest

from repro.db import tpch
from repro.db.plans import (GroupAgg, RetryExhausted, RetryPolicy, Scan,
                            compile_plan, run_plan)
from repro.testing import faults

pytestmark = pytest.mark.faults


@pytest.fixture(autouse=True)
def _bounded_compile_cache():
    """Every retry attempt is a fresh compile at escalated parameters, so
    this module accretes far more live executables than any other test
    file; dropping them after each test keeps the single-process suite's
    compiler footprint flat for the files that run after."""
    yield
    jax.clear_caches()


def _db():
    # lineitem 192 rows (csz 24 on the 8-chunk grid): device_row_budget=64
    # streams only lineitem, same scale as tests/test_streamed.py.
    return tpch.generate(n_orders=48, lines_per_order=4, n_parts=24,
                         n_suppliers=8, n_customers=24, seed=0)


def _assert_biteq(name, ref, got):
    la, ta = jax.tree.flatten(ref)
    lb, tb = jax.tree.flatten(got)
    assert str(ta) == str(tb), (name, str(ta), str(tb))
    for i, (a, b) in enumerate(zip(la, lb)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.dtype == b.dtype, (name, i)
        if not np.array_equal(a, b):
            f = a.astype(np.float64, copy=False)
            g = b.astype(np.float64, copy=False)
            assert ((a == b) | (np.isnan(f) & np.isnan(g))).all(), (name, i)


# ===================================================== clean-path report
def test_clean_run_report_is_clean_and_free():
    """Happy path: report collection flags nothing and changes no bit."""
    db = _db()
    root = GroupAgg(Scan("lineitem"), ("l_returnflag", "l_linestatus"),
                    "l_quantity", "SUM", 8, "normal")
    ref = compile_plan(root)(db.tables())
    out, rep = compile_plan(root, with_report=True)(db.tables())
    _assert_biteq("clean", ref, out)
    assert rep.issues() == {} and rep.ok()
    assert rep.describe() == "clean"
    assert rep.overflow_total() == 0
    # one aggregation pass was diagnosed: confidence + sum states counted
    assert any(k.endswith(".sum") for k in rep.state_nan)
    assert all(int(v) == 0 for v in rep.state_nan.values())


def test_minmax_tail_mass_surfaced():
    """Satellite: the §V-B.2 truncation mass is a public per-group result
    (q18_topk) AND a report signal — exactly 0 when kappa covers every
    distinct value, positive when it truncates."""
    db = _db()
    wide = tpch.q18_topk(db, max_groups=64, kappa=50)   # 50 >= distinct qtys
    assert wide["tail_mass"].shape == (64,)
    np.testing.assert_array_equal(np.asarray(wide["tail_mass"]),
                                  np.zeros(64))
    narrow = tpch.q18_topk(db, max_groups=64, kappa=1)
    tails = np.asarray(narrow["tail_mass"])
    valid = np.asarray(narrow["valid"])
    assert (tails[valid] > 0).any()
    assert (tails >= 0).all() and (tails <= 1).all()
    root = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                    "MAX", 64, kappa=1)
    _, rep = compile_plan(root, with_report=True)(db.tables())
    assert rep.max_tail_mass() > 0 and "tail" in rep.issues()
    assert rep.issues(tail_tol=1.0) == {}        # tolerance gates it


def test_kappa_escalation_converges_bit_equal():
    """Tail mass above tolerance -> kappa doubles until exact; the healed
    answer equals an oversized-from-the-start run bit for bit."""
    db = _db()
    root = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                    "MAX", 64, kappa=1)
    out, rep = run_plan(root, db.tables(),
                        policy=RetryPolicy(max_attempts=6, tail_tol=0.0))
    scale = rep.final_params["kappa_scale"]
    assert rep.waves["attempts"] > 1 and scale > 1
    assert rep.max_tail_mass() == 0.0
    big = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                   "MAX", 64, kappa=scale)
    ref = compile_plan(big)(db.tables())
    _assert_biteq("kappa", ref, out)


def test_group_overflow_escalation():
    """48 live orders into a 16-entry group-code table: the lost rows are
    counted (NaN never fires — the kept groups stay exact) and max_groups
    doubles until nothing is lost."""
    db = _db()
    root = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                    "SUM", 16, "normal")
    _, rep = compile_plan(root, with_report=True)(db.tables())
    assert "group_overflow" in rep.issues()
    out, rep2 = run_plan(root, db.tables(),
                         policy=RetryPolicy(max_attempts=4))
    assert rep2.issues() == {}
    scale = rep2.final_params["groups_scale"]
    assert scale >= 4                            # 16 -> 64 holds 48 groups
    ref = compile_plan(GroupAgg(Scan("lineitem"), ("l_orderkey",),
                                "l_quantity", "SUM", 16 * scale,
                                "normal"))(db.tables())
    _assert_biteq("groups", ref, out)


def test_retry_exhausted_carries_report():
    db = _db()
    root = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                    "MAX", 64, kappa=1)
    with pytest.raises(RetryExhausted) as ei:
        run_plan(root, db.tables(), policy=RetryPolicy(max_attempts=1))
    assert ei.value.report is not None
    assert "tail" in ei.value.report.issues()


# ================================================= streamed wave resume
def test_streamed_transient_fault_resumes_bit_equal():
    """A transfer fault mid-wave re-ships ONLY the faulted wave: the log
    shows the same wave re-shipped, no completed wave re-streamed, and
    the result is bit-identical to the fault-free run — for the plain-agg
    (Q1) and exact-CF (Q6) streamed shapes."""
    db = _db()
    for qname, call in (("q1", lambda **kw: tpch.q1(db, "aggregate", **kw)),
                        ("q6", lambda **kw: tpch.q6(db, "aggregate",
                                                    num_freq=256, **kw))):
        ref = call()
        opts = dict(device_row_budget=64, stream_wave_chunks=1)
        # 8 waves per phase: occurrence 10 is phase B, wave 2
        with faults.inject(faults.FaultPlan(transfer_calls={10})) as fp:
            got = call(plan_opts=opts)
        assert fp.consumed(), qname
        _assert_biteq(qname, ref, got)
        (fi, fw), = [(i, w) for i, w, _r, f in fp.log if f]
        after = [w for i, w, _r, f in fp.log if i > fi]
        # ship order is monotone within one wave loop; a later loop
        # (next phase / next slab pass) restarts at wave 0 — only judge
        # the loop the fault happened in.
        seg = []
        for w in after:
            if seg and w < seg[-1]:
                break
            seg.append(w)
        assert seg[0] == fw, (qname, "retry must re-ship the SAME wave")
        # monotone + starts at fw => no completed wave re-streamed


def test_streamed_fault_during_prefetch_no_double_file():
    """A fault on the DOUBLE-BUFFERED prefetch (wave w+1 ships while wave
    w computes): the wave loop retires w first, so the retry cannot file
    any chunk twice (ChunkStateAccumulator asserts exactly-once)."""
    db = _db()
    ref = tpch.q1(db, "aggregate")
    for occ in (1, 9, 12, 15):
        with faults.inject(faults.FaultPlan(transfer_calls={occ})) as fp:
            got = tpch.q1(db, "aggregate",
                          plan_opts=dict(device_row_budget=64,
                                         stream_wave_chunks=1))
        assert fp.consumed(), occ
        _assert_biteq(f"q1/occ{occ}", ref, got)


def test_streamed_fault_exhausts_inloop_retries_annotated():
    """A persistent fault escapes after ``stream_wave_retries`` re-ships,
    annotated with the halved wave size for the controller."""
    db = _db()
    with faults.inject(faults.FaultPlan(transfer_rows_over=50)):
        with pytest.raises(faults.TransferFault) as ei:
            tpch.q1(db, "aggregate",
                    plan_opts=dict(device_row_budget=64,
                                   stream_wave_chunks=4))
    assert ei.value.wave_chunks == 2 and not ei.value.at_minimum


def test_wave_halving_retry():
    """Persistent too-big-transfer fault (96-row waves fail, 48-row waves
    pass): run_plan re-lowers with the halved wave and the result is
    bit-identical to the resident answer."""
    db = _db()
    root = GroupAgg(Scan("lineitem"), ("l_returnflag", "l_linestatus"),
                    "l_quantity", "SUM", 8, "normal")
    with faults.inject(faults.FaultPlan(transfer_rows_over=50)):
        out, rep = run_plan(root, db.tables(),
                            policy=RetryPolicy(max_attempts=4),
                            device_row_budget=64, stream_wave_chunks=4)
    assert rep.waves["attempts"] == 2
    assert rep.final_params["stream_wave_chunks"] == 2
    ref = compile_plan(root, device_row_budget=64)(db.tables())
    _assert_biteq("halved", ref, out)


# ==================================== multi-device overflow + silent NaN
_OVERFLOW_SCRIPT = r"""
import jax, numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db import plans as L
from repro.db.table import Table

mesh = make_mesh((3,), ("data",))
n = 48
# probe keys == 1 (mod 3): every row routes to one owner, so slack 0.25
# buckets overflow under jit (traced keys keep the slack sizing).
left = Table({"k": jnp.asarray((np.arange(n) %% 30) * 3 + 1)},
             jnp.full((n,), 0.5), jnp.ones((n,), bool))
rk = np.arange(0, 200) * 3 + 1
right = Table({"rk": jnp.asarray(rk), "w": jnp.asarray(rk %% 7)},
              jnp.full((rk.size,), 0.9), jnp.ones((rk.size,), bool))
tables = {"left": left, "right": right}
join = L.FKJoin(L.Scan("left"), L.Scan("right"), "k", "rk", ("w",))
opts = dict(join_gather_budget=1, copartition=%(copart)s)
root = L.GroupAgg(join, ("k",), "w", "SUM", 64)

# 1. plain jit run: overflow fires and the report sees it
fn = jax.jit(L.compile_plan(root, mesh, with_report=True,
                            shuffle_slack=0.25, **opts))
out, rep = fn(tables)
assert rep.overflow_total() > 0, "expected an overflowing exchange"
assert "overflow" in rep.issues()
mu = np.asarray(out["sum"][0])
assert np.isnan(mu).any(), "NaN poison backstop must fire"

# 2. boolean-output regression: the NaN poison collapses to False in a
# boolean column, but the report still detects the overflow.
flag = L.Map(join, "flag", lambda t: t.prob > 0.5)
bfn = jax.jit(L.compile_plan(flag, mesh, with_report=True,
                             shuffle_slack=0.25, **opts))
bt, brep = bfn(tables)
fl = np.asarray(bt["flag"])
assert fl.dtype == np.bool_ and not fl.any(), "NaN collapsed silently"
assert brep.overflow_total() > 0, "report must catch the silent overflow"

# 3. an injected exchange fault surfaces from the shuffle trace
if not %(copart)s:
    from repro.testing import faults
    with faults.inject(faults.FaultPlan(exchange_calls={0})) as fpx:
        try:
            jax.jit(L.compile_plan(root, mesh, shuffle_slack=3.0,
                                   **opts))(tables)
            raise AssertionError("expected TransferFault")
        except faults.TransferFault:
            pass
    assert fpx.consumed()

# 4. RetryPolicy heals it within <=3 attempts, bit-equal to a run
# launched at the final escalated parameters.
out2, rep2 = L.run_plan(root, tables, mesh,
                        policy=L.RetryPolicy(max_attempts=3), jit=True,
                        shuffle_slack=0.25, **opts)
assert rep2.issues() == {}
assert rep2.waves["attempts"] <= 3
fp = rep2.final_params
fn3 = jax.jit(L.compile_plan(root, mesh, with_report=True,
                             shuffle_slack=fp["shuffle_slack"],
                             shuffle_bucket_floor=fp["shuffle_bucket_floor"],
                             **opts))
out3, rep3 = fn3(tables)
assert rep3.issues() == {}
for a, b in zip(jax.tree.leaves(out2), jax.tree.leaves(out3)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
assert np.isfinite(np.asarray(out2["sum"][0])[np.asarray(out2["valid"])]).all()
print("OVERFLOW RETRY OK")
"""


@pytest.mark.multidevice
@pytest.mark.parametrize("copart", [False, True])
def test_overflow_retry_3shard(copart):
    """An overflowing 3-shard exchange under jit: detected in the report
    (through a boolean output too), healed by RetryPolicy in <=3
    attempts, bit-equal to a run at the final escalated parameters —
    for both the ShuffleJoin and CoPartitionedJoin lowerings."""
    from conftest import run_sub
    out = run_sub(_OVERFLOW_SCRIPT % dict(copart=copart), devices=3)
    assert "OVERFLOW RETRY OK" in out


_FUZZ_SCRIPT = r"""
import jax, numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db import plans as L
from repro.db.table import Table

mesh = make_mesh((3,), ("data",))

def trial(seed, copart):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(24, 72))
    # skewed keys: most rows hash to one owner mod 3
    owner = int(rng.integers(0, 3))
    base = rng.integers(0, 40, n) * 3 + owner
    mix = rng.integers(0, 120, n)
    keys = np.where(rng.random(n) < 0.85, base, mix).astype(np.int64)
    left = Table({"k": jnp.asarray(keys)},
                 jnp.asarray(rng.uniform(0.1, 0.9, n)),
                 jnp.asarray(rng.random(n) < 0.9))
    rk = np.arange(0, 120)
    right = Table({"rk": jnp.asarray(rk), "w": jnp.asarray(rk %% 5)},
                  jnp.full((rk.size,), 0.8), jnp.ones((rk.size,), bool))
    tables = {"left": left, "right": right}
    root = L.GroupAgg(L.FKJoin(L.Scan("left"), L.Scan("right"),
                               "k", "rk", ("w",)),
                      ("k",), "w", "SUM", 128)
    opts = dict(join_gather_budget=1, copartition=copart)
    out, rep = L.run_plan(root, tables, mesh,
                          policy=L.RetryPolicy(max_attempts=3), jit=True,
                          shuffle_slack=0.25, **opts)
    assert rep.issues() == {}, (seed, copart, rep.describe())
    assert rep.waves["attempts"] <= 3
    # oversized from the start: slack = n_shards pins buckets at the
    # sender's local rows, overflow impossible
    big = jax.jit(L.compile_plan(root, mesh, shuffle_slack=3.0, **opts))
    ref = big(tables)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (seed, copart)

for seed in %(seeds)s:
    for copart in (False, True):
        trial(seed, copart)
print("CONVERGENCE FUZZ OK")
"""


@pytest.mark.multidevice
def test_retry_convergence_fuzz_seeded():
    """Seeded-fallback fuzz (always runs): skewed key distributions on a
    3-shard mesh converge under RetryPolicy within max_attempts and
    match the oversized-from-the-start run bit for bit, for both
    ShuffleJoin and CoPartitionedJoin."""
    from conftest import run_sub
    out = run_sub(_FUZZ_SCRIPT % dict(seeds=[0, 1, 2]), devices=3)
    assert "CONVERGENCE FUZZ OK" in out


@pytest.mark.multidevice
@pytest.mark.slow
def test_retry_convergence_fuzz_hypothesis():
    """The hypothesis-driven sweep (skipped without hypothesis, matching
    the repo's seeded-fallback pattern): random seeds drive the same
    trial harness."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st
    from conftest import run_sub

    @hyp.given(st.lists(st.integers(0, 10_000), min_size=2, max_size=4,
                        unique=True))
    @hyp.settings(max_examples=3, deadline=None)
    def check(seeds):
        out = run_sub(_FUZZ_SCRIPT % dict(seeds=seeds), devices=3)
        assert "CONVERGENCE FUZZ OK" in out

    check()


# ================================================== fault-plan mechanics
def test_fault_plan_mechanics():
    fp = faults.FaultPlan(transfer_calls={1}, exchange_calls={0},
                          transfer_rows_over=100)
    with faults.inject(fp):
        faults.on_transfer(0, 10)
        with pytest.raises(faults.TransferFault):
            faults.on_transfer(0, 10)            # one-shot occurrence 1
        faults.on_transfer(1, 10)                # consumed: passes now
        with pytest.raises(faults.TransferFault):
            faults.on_transfer(2, 101)           # persistent rows_over
        with pytest.raises(faults.TransferFault):
            faults.on_exchange()
        faults.on_exchange()
        with pytest.raises(RuntimeError):
            with faults.inject(faults.FaultPlan()):   # no nesting
                pass
    assert fp.consumed()
    assert [f for *_x, f in fp.log] == [False, True, False, True]
    faults.on_transfer(0, 10**9)                 # hooks are no-ops outside
    faults.on_exchange()


def test_queryservice_healed_replay():
    """Serving-layer retries neither poison nor duplicate cache entries:
    each escalation attempt keys its own entry, the service remembers the
    converged final_params, and a RESUBMIT of the healed plan runs one
    clean attempt, hits the cache, and answers bit-identically."""
    from repro.db.serving import QueryService

    db = _db()
    root = GroupAgg(Scan("lineitem"), ("l_orderkey",), "l_quantity",
                    "SUM", 16, "normal")            # overflows: 48 groups
    svc = QueryService(db.tables(), capacity=16,
                       policy=RetryPolicy(max_attempts=4))
    out1, info1 = svc.submit(root)
    assert info1["attempts"] > 1
    assert info1["report"].issues() == {}
    misses_after_heal = svc.cache.misses
    out2, info2 = svc.submit(root)
    assert info2["attempts"] == 1                   # replays final_params
    assert info2["hit"] and svc.cache.misses == misses_after_heal
    _assert_biteq("healed-replay", out1, out2)
    # the healed hit also equals a from-scratch escalated run
    out3, _ = run_plan(root, db.tables(),
                       policy=RetryPolicy(max_attempts=4))
    _assert_biteq("healed-vs-fresh", out1, out3)
