"""End-to-end behaviour: the paper's pipeline from raw probabilistic table
to finished distribution, and a short real training run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compare
from repro.core.pgf import possible_worlds_pgf
from repro.db import operators as ops, tpch
from repro.db.table import Table


def test_paper_worked_example_section_iv_a():
    """The paper's own COUNT example (Fig. 1): p = .7/.8/.5 =>
    F(X) = 0.28X^3 + 0.47X^2 + 0.22X + 0.03."""
    from repro.core import poisson_binomial as pb
    from repro.core.config import default_float
    f = pb.count_pgf(jnp.asarray([0.7, 0.8, 0.5], default_float()))
    c = np.asarray(f.coeffs)
    np.testing.assert_allclose(c, [0.03, 0.22, 0.47, 0.28], atol=1e-12)


def test_paper_worked_example_sum():
    """§IV-A SUM example: values 3/8/5 => 0.28X^16 + 0.12X^13 + 0.28X^11 +
    0.19X^8 + 0.03X^5 + 0.07X^3 + 0.03."""
    from repro.core import poisson_binomial as pb
    from repro.core.config import default_float
    f = pb.sum_pgf(jnp.asarray([0.7, 0.8, 0.5], default_float()),
                   jnp.asarray([3.0, 8.0, 5.0], default_float()))
    c = np.asarray(f.coeffs)
    want = {16: 0.28, 13: 0.12, 11: 0.28, 8: 0.19, 5: 0.03, 3: 0.07, 0: 0.03}
    for k, v in want.items():
        assert c[k] == pytest.approx(v, abs=1e-12)
    # paper text lists 0.19 X^8; total must be 1
    assert c.sum() == pytest.approx(1.0, abs=1e-12)


def test_paper_min_example():
    """§IV-A MIN of first two tuples: 0.06X^inf + 0.24X^8 + 0.7X^3."""
    from repro.core.pgf import PGF
    f1 = PGF.bernoulli(0.7, 3, "MIN")
    f2 = PGF.bernoulli(0.8, 8, "MIN")
    f = f1.mul_min(f2)
    assert float(f.p_pos_inf) == pytest.approx(0.06, abs=1e-12)
    assert float(f.mass_at(8)) == pytest.approx(0.24, abs=1e-12)
    assert float(f.mass_at(3)) == pytest.approx(0.70, abs=1e-12)


def test_query_pipeline_vs_possible_worlds():
    """Full mini-pipeline (select -> group -> SUM dist -> compare) against
    brute-force possible-worlds enumeration of the whole query."""
    rng = np.random.default_rng(11)
    n = 10
    g = rng.integers(0, 2, n)
    v = rng.integers(1, 5, n).astype(float)
    p = rng.uniform(0.1, 0.9, n)
    t = Table.from_columns({"g": jnp.asarray(g), "v": jnp.asarray(v)},
                           prob=jnp.asarray(p))
    sel = ops.select(t, lambda x: x["v"] >= 2)
    ids, codes, _ = ops.group_ids(sel, ["g"], 4)
    F = 64
    la, an = ops.group_logcf(sel, sel["v"], ids, 4, F)
    coeffs = np.asarray(ops.group_logcf_finalize(la, an))
    keep = (v >= 2)
    for gv in (0, 1):
        m = keep & (g == gv)
        oracle = possible_worlds_pgf(p[m], v[m], "SUM")
        gi = int(np.searchsorted(np.asarray(codes), gv))
        for outcome, pr in oracle.items():
            assert coeffs[gi, int(outcome)] == pytest.approx(pr, abs=1e-10)


def test_training_loss_decreases_e2e(tmp_path):
    from repro.configs import get_reduced
    from repro.train.data import TokenStream
    from repro.train.optimizer import AdamW
    from repro.train.trainer import Trainer
    cfg = get_reduced("yi_6b")
    # tiny vocab so 30 steps show real learning signal
    stream = TokenStream(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    trainer = Trainer(cfg, AdamW(lr=3e-3, warmup=10), stream,
                      str(tmp_path / "ck"), ckpt_every=100)
    _, _, hist = trainer.run(30)
    assert np.mean(hist[-5:]) < np.mean(hist[:5]) - 0.05


def test_serve_generates_tokens():
    from repro.launch.serve import generate
    from repro.configs import get_reduced
    from repro.models import api
    cfg = get_reduced("yi_6b")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    toks = generate(cfg, params, prompt, 32, 5)
    assert toks.shape == (2, 5)
    assert (np.asarray(toks) >= 0).all()
    assert (np.asarray(toks) < cfg.vocab_size).all()


def test_tpch_modes_are_consistent():
    """group_confidence probabilities multiply up to the confidence mode."""
    db = tpch.generate(n_orders=60, seed=9)
    gc = tpch.q18(db, "group_confidence")
    conf = tpch.q18(db, "confidence")["confidence"]
    peach = np.asarray(gc["confidence"])[np.asarray(gc["valid"])]
    want = 1 - np.prod(1 - peach)
    assert float(conf) == pytest.approx(want, rel=1e-6)
