"""Per-arch smoke tests (assignment requirement): reduced config of the
same family, one forward/train step on CPU, output shapes + no NaNs;
decode-vs-forward equivalence for every causal family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.models import api


def _batch(cfg, key, b=2, s=16):
    if cfg.embedding_inputs:
        tokens = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return tokens, labels


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    tokens, labels = _batch(cfg, key)
    logits, _, aux = api.forward(cfg, params, tokens)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    (loss, m), grads = jax.value_and_grad(
        lambda p: api.loss_fn(cfg, p, tokens, labels), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert not bool(jnp.isnan(leaf.astype(jnp.float32)).any()), arch
    # loss near ln(V) at init (uniform predictions)
    assert float(m["ce"]) == pytest.approx(np.log(cfg.vocab_size), rel=0.25)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_reduced(a).causal])
def test_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = api.init_params(cfg, key)
    b, s = 2, 20
    tokens, _ = _batch(cfg, key, b, s)
    logits_full, _, _ = api.forward(cfg, params, tokens)
    cache = api.init_cache(cfg, b, s, dtype=jnp.float32)
    cl = jnp.zeros((), jnp.int32)
    step = jax.jit(lambda p, t, c, l: api.decode_step(cfg, p, t, c, l))
    outs = []
    for i in range(s):
        tok = tokens[:, i:i + 1]
        lg, cache, cl = step(params, tok, cache, cl)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(logits_full - jnp.stack(outs, axis=1))))
    assert err < 5e-5, (arch, err)


def test_encoder_has_no_decode_cells():
    from repro.configs import runnable_cells, get_config
    cells = runnable_cells(get_config("hubert_xlarge"))
    assert "decode_32k" not in cells and "long_500k" not in cells


def test_long_context_only_for_subquadratic():
    from repro.configs import runnable_cells, get_config
    assert "long_500k" in runnable_cells(get_config("rwkv6_1b6"))
    assert "long_500k" in runnable_cells(get_config("recurrentgemma_2b"))
    assert "long_500k" not in runnable_cells(get_config("yi_6b"))


def test_encoder_attends_bidirectionally():
    cfg = get_reduced("hubert_xlarge")
    key = jax.random.PRNGKey(2)
    params = api.init_params(cfg, key)
    x = jax.random.normal(key, (1, 12, cfg.d_model), jnp.float32)
    base, _, _ = api.forward(cfg, params, x)
    # random perturbation of the LAST frame (a constant shift would sit in
    # LayerNorm's null space and prove nothing)
    noise = jax.random.normal(jax.random.PRNGKey(9), (cfg.d_model,)) * 3.0
    pert, _, _ = api.forward(cfg, params, x.at[:, -1].add(noise))
    # position 0 must change (bidirectional) — for causal it could not
    assert float(jnp.abs(pert[:, 0] - base[:, 0]).max()) > 1e-5


def test_causal_models_are_causal():
    cfg = get_reduced("yi_6b")
    key = jax.random.PRNGKey(3)
    params = api.init_params(cfg, key)
    tokens = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    base, _, _ = api.forward(cfg, params, tokens)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab_size)
    pert, _, _ = api.forward(cfg, params, tokens2)
    np.testing.assert_allclose(np.asarray(base[:, :-1]),
                               np.asarray(pert[:, :-1]), atol=1e-6)


def test_local_window_limits_attention():
    """recurrentgemma's local layers must not see beyond the window."""
    cfg = get_reduced("recurrentgemma_2b")   # window 16
    assert cfg.window == 16


def test_moe_routing_activates_multiple_experts():
    from repro.models import moe as MOE
    cfg = get_reduced("olmoe_1b_7b")
    key = jax.random.PRNGKey(4)
    p = MOE.moe_params(cfg, key)
    x = jax.random.normal(key, (1, 64, cfg.d_model), jnp.float32)
    out, aux = MOE.moe(cfg, p, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # aux loss ~ 1 when balanced; << n_experts when not collapsed
    assert 0.5 < float(aux) < cfg.n_experts


def test_rwkv_chunk_boundary_invariance():
    """Chunked WKV == stepwise decode across a chunk boundary is already
    covered by decode_matches_forward; here: different sequence lengths
    around CHUNK agree on the shared prefix."""
    from repro.models import rwkv6 as RW
    cfg = get_reduced("rwkv6_1b6")
    key = jax.random.PRNGKey(5)
    params = api.init_params(cfg, key)
    s_long = RW.CHUNK + 7
    tokens = jax.random.randint(key, (1, s_long), 0, cfg.vocab_size)
    full, _, _ = api.forward(cfg, params, tokens)
    half, _, _ = api.forward(cfg, params, tokens[:, :RW.CHUNK - 3])
    np.testing.assert_allclose(np.asarray(full[:, :RW.CHUNK - 3]),
                               np.asarray(half), atol=2e-5)


def test_param_count_formula_close_to_actual():
    for arch in ("yi_6b", "olmoe_1b_7b", "rwkv6_1b6"):
        cfg = get_reduced(arch)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.30, \
            (arch, actual, predicted)
