"""Out-of-core streamed execution (db/plans.py StreamedScan path).

The contract under test: with ``device_row_budget`` set, a base table
whose per-shard rows exceed the budget stays HOST-side and the
aggregation pass above it runs as waves — and the result is
BIT-IDENTICAL to the fully-resident compile for ANY wave size and ANY
shard count (the canonical-chunk fold contract of db/plans.py extended
across host→device waves).  Every comparison here is exact equality,
never allclose.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.db import plans as P
from repro.db import tpch
from repro.db.plans import (GroupAgg, Scan, Select, compile_plan,
                            shard_capacity)
from repro.db.table import HostTable, Table

pytestmark = pytest.mark.outofcore


def _db():
    # lineitem = 192 rows (csz 24 on the default 8-chunk grid); orders 48,
    # partsupp 96, everything else smaller — so device_row_budget=64
    # streams ONLY lineitem (and 128 for q20, whose partsupp build side
    # must stay resident).
    return tpch.generate(n_orders=48, lines_per_order=4, n_parts=24,
                         n_suppliers=8, n_customers=24, seed=0)


def _assert_biteq(name, ref, got):
    la, ta = jax.tree.flatten(ref)
    lb, tb = jax.tree.flatten(got)
    assert str(ta) == str(tb), (name, str(ta), str(tb))
    for i, (a, b) in enumerate(zip(la, lb)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.dtype == b.dtype, (name, i)
        if not np.array_equal(a, b):
            f = a.astype(np.float64, copy=False)
            g = b.astype(np.float64, copy=False)
            eq = (a == b) | (np.isnan(f) & np.isnan(g))
            assert eq.all(), (name, i, a, b)


# ------------------------------------------------ single-device streaming
_QUERY_BUDGET = {"q1": 64, "q3": 64, "q6": 64, "q18": 64, "q20": 128}


def _run_query(db, qname, plan_opts=None):
    kw = dict(plan_opts=plan_opts) if plan_opts else {}
    if qname == "q1":
        return tpch.q1(db, "aggregate", **kw)
    if qname == "q3":
        return tpch.q3(db, "aggregate", max_groups=64, **kw)
    if qname == "q6":
        return tpch.q6(db, "aggregate", num_freq=256, **kw)
    if qname == "q18":
        return tpch.q18(db, "aggregate", max_groups=64, **kw)
    return tpch.q20(db, "aggregate", max_groups=64, **kw)


@pytest.mark.parametrize("prune", [True, False])
@pytest.mark.parametrize("qname", sorted(tpch.QUERIES))
def test_streamed_bit_equal_resident(qname, prune):
    """Every TPC-H query: streamed lineitem == resident, bit for bit —
    with required-column pruning on (the default) and off."""
    db = _db()
    ref = _run_query(db, qname)
    got = _run_query(db, qname,
                     dict(device_row_budget=_QUERY_BUDGET[qname],
                          stream_prune_columns=prune))
    _assert_biteq(f"{qname}/prune={prune}", ref, got)


def _plan_for(qname):
    return {"q1": lambda: tpch.q1_plan(),
            "q3": lambda: tpch.q3_plan(),
            "q6": lambda: tpch.q6_plan(num_freq=256),
            "q18": lambda: tpch.q18_plan(),
            "q20": lambda: tpch.q20_plan()}[qname]()


@pytest.mark.parametrize("qname", sorted(tpch.QUERIES))
def test_streamed_bit_equal_disk_backed(qname, tmp_path):
    """Every TPC-H query streaming from a DISK-BACKED (save -> open,
    np.memmap columns) lineitem is bit-identical to the resident compile
    — pruned and unpruned, across wave sizes."""
    db = _db()
    plan = _plan_for(qname)
    tabs = db.tables()
    ref = compile_plan(plan)(tabs)
    HostTable.from_table(tabs["lineitem"]).save(str(tmp_path / "li"))
    disk = dict(tabs)
    disk["lineitem"] = HostTable.open(str(tmp_path / "li"))
    for prune in (True, False):
        got = compile_plan(plan,
                           device_row_budget=_QUERY_BUDGET[qname],
                           stream_prune_columns=prune)(disk)
        _assert_biteq(f"{qname}/disk/prune={prune}", ref, got)
    for wc in (1, 3, 8):
        got = compile_plan(plan,
                           device_row_budget=_QUERY_BUDGET[qname],
                           stream_wave_chunks=wc)(disk)
        _assert_biteq(f"{qname}/disk/wc={wc}", ref, got)


def test_save_open_roundtrip(tmp_path):
    """save -> open restores every array (values, dtypes) and the
    VIRTUAL padding (only stored rows hit the disk); mmap_mode=None
    loads into RAM instead."""
    ht = HostTable({"a": np.arange(10), "b": np.linspace(0, 1, 10)},
                   prob=np.full(10, 0.5),
                   valid=np.arange(10) % 3 != 0).pad_to(16)
    ht.save(str(tmp_path))
    assert ht.stored_rows == 10 and ht.capacity == 16
    back = HostTable.open(str(tmp_path))
    assert back.capacity == 16 and back.stored_rows == 10
    for k in ("a", "b"):
        assert isinstance(back[k], np.memmap)
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(ht[k]))
        assert back[k].dtype == ht[k].dtype
    np.testing.assert_array_equal(back.prob, ht.prob)
    np.testing.assert_array_equal(back.valid, ht.valid)
    _assert_biteq("roundtrip/to_table", ht.to_table(), back.to_table())
    ram = HostTable.open(str(tmp_path), mmap_mode=None)
    assert not isinstance(ram["a"], np.memmap)
    np.testing.assert_array_equal(ram["a"], ht["a"])


@pytest.mark.parametrize("wave_chunks", [1, 3, 8])
def test_wave_size_invariance(wave_chunks):
    """The wave schedule is invisible in the results: one chunk per wave,
    a ragged tail (3 of 8 chunk slots per wave => a padding wave), and the
    whole table in one wave all reproduce the resident bits."""
    db = _db()
    for qname in ("q1", "q6", "q18"):
        ref = _run_query(db, qname)
        got = _run_query(db, qname,
                         dict(device_row_budget=64,
                              stream_wave_chunks=wave_chunks))
        _assert_biteq(f"{qname}/wc{wave_chunks}", ref, got)


def test_sync_transfer_matches_double_buffered():
    """stream_double_buffer only changes the transfer schedule, never the
    numbers."""
    db = _db()
    ref = _run_query(db, "q1")
    got = _run_query(db, "q1", dict(device_row_budget=64,
                                    stream_double_buffer=False))
    _assert_biteq("q1/sync", ref, got)


def test_streamed_exact_cf_frequency_slabs():
    """Exact-CF aggregation with a cf budget forcing multiple frequency
    slabs, streamed: the per-wave slab passes and the cross-wave chunk
    fold compose with the frequency-slab loop bit-exactly."""
    db = _db()
    ref = tpch.q6(db, "aggregate", num_freq=256,
                  plan_opts=dict(cf_budget_elems=256))
    got = tpch.q6(db, "aggregate", num_freq=256,
                  plan_opts=dict(cf_budget_elems=256, device_row_budget=64))
    _assert_biteq("q6/cf_slabs", ref, got)


# ----------------------------------------------------- host-table surface
def test_host_table_streams_and_materialises():
    """A HostTable input streams under a budget, materialises without one,
    and both reproduce the device-resident bits."""
    db = _db()
    plan = GroupAgg(Scan("lineitem"), ("l_returnflag",), "l_quantity",
                    "SUM", 4, "normal")
    dev = db.tables()
    host = dict(dev)
    host["lineitem"] = HostTable.from_table(db.lineitem)
    ref = compile_plan(plan, None)(dev)
    _assert_biteq("host/resident", ref, compile_plan(plan, None)(host))
    _assert_biteq("host/streamed", ref,
                  compile_plan(plan, None, device_row_budget=64)(host))


def test_host_table_slabs():
    ht = HostTable({"a": np.arange(10)}, prob=np.full(10, 0.5))
    s = ht.slab(8, 4)
    assert isinstance(s, Table) and s.capacity == 4
    np.testing.assert_array_equal(np.asarray(s["a"]), [8, 9, 0, 0])
    np.testing.assert_array_equal(np.asarray(s.valid),
                                  [True, True, False, False])
    np.testing.assert_array_equal(np.asarray(s.prob), [0.5, 0.5, 0.0, 0.0])
    ws = ht.pad_to(12).wave_slab((0, 6), 3)
    np.testing.assert_array_equal(np.asarray(ws["a"]), [0, 1, 2, 6, 7, 8])
    starts = [s0 for s0, _ in ht.slabs(4)]
    assert starts == [0, 4, 8]


def test_wave_slab_strided_non_contiguous_starts():
    """Per-shard runs with gaps between them (the mesh wave layout):
    each shard contributes its own run, concatenated in shard order —
    and a run reaching past the stored rows zero-fills (virtual pad)."""
    ht = HostTable({"a": np.arange(20)}, prob=np.full(20, 0.25)).pad_to(24)
    ws = ht.wave_slab((2, 11, 21), 3)
    np.testing.assert_array_equal(np.asarray(ws["a"]),
                                  [2, 3, 4, 11, 12, 13, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(ws.valid)[-4:],
                                  [True, False, False, False])
    np.testing.assert_array_equal(np.asarray(ws.prob)[-3:], [0, 0, 0])


def test_wave_slab_zero_alloc_out_buffers():
    """wave_slab(out=) fills the caller's preallocated buffers in place
    (the streamed executor's ping-pong pair) and returns the same
    arrays; a second fill overwrites, including zeroed tails."""
    ht = HostTable({"a": np.arange(10, dtype=np.int64)},
                   prob=np.full(10, 0.5)).pad_to(12)
    buf = ht.alloc_slab(6)
    out = ht.wave_slab((0, 6), 3, out=buf)
    assert out.columns["a"] is buf.columns["a"]
    assert out.prob is buf.prob and out.valid is buf.valid
    np.testing.assert_array_equal(buf.columns["a"], [0, 1, 2, 6, 7, 8])
    out2 = ht.wave_slab((3, 9), 3, out=buf)
    np.testing.assert_array_equal(buf.columns["a"], [3, 4, 5, 9, 0, 0])
    np.testing.assert_array_equal(buf.valid, [1, 1, 1, 1, 0, 0])
    np.testing.assert_array_equal(buf.prob[-2:], [0.0, 0.0])


def test_single_row_and_one_chunk_tables():
    """Degenerate sizes: a single-row table padded to one chunk slot
    slabs/streams correctly, and a one-chunk table streams in one wave."""
    ht = HostTable({"a": np.asarray([7])}, prob=np.asarray([0.5]))
    p = ht.pad_to_multiple(8)
    assert p.capacity == 8 and p.stored_rows == 1
    s = p.slab(0, 8)
    np.testing.assert_array_equal(np.asarray(s["a"]),
                                  [7, 0, 0, 0, 0, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(s.valid)[:2], [True, False])
    db = _db()
    one = Table.from_columns(
        {"k": db.lineitem["l_returnflag"][:8],
         "v": db.lineitem["l_quantity"][:8]},
        prob=db.lineitem.prob[:8])
    plan = GroupAgg(Scan("t"), ("k",), "v", "SUM", 4, "normal")
    ref = compile_plan(plan, None, canonical_chunks=1)({"t": one})
    got = compile_plan(plan, None, canonical_chunks=1,
                       device_row_budget=4)(
        {"t": HostTable.from_table(one)})
    _assert_biteq("one-chunk", ref, got)


def test_select_columns_shares_arrays_and_pad():
    ht = HostTable({"a": np.arange(10), "b": np.arange(10) * 2},
                   prob=np.full(10, 0.5)).pad_to(16)
    pruned = ht.select_columns(["a"])
    assert set(pruned.columns) == {"a"}
    assert pruned["a"] is ht["a"] and pruned.prob is ht.prob
    assert pruned.capacity == 16
    np.testing.assert_array_equal(np.asarray(pruned.slab(8, 4)["a"]),
                                  [8, 9, 0, 0])


def test_pruned_stream_ships_fewer_bytes():
    """The runtime byte counters: Q6 (3 of 10 lineitem columns) pruned
    ships strictly fewer slab bytes than unpruned, and the host-slice
    timer advances."""
    db = _db()
    host = dict(db.tables())
    host["lineitem"] = HostTable.from_table(db.lineitem)
    plan = tpch.q6_plan()
    seen = {}
    for prune in (True, False):
        P.reset_stream_stats()
        compile_plan(plan, None, device_row_budget=64,
                     stream_wave_chunks=1,    # pin: isolate the payload
                     stream_prune_columns=prune)(host)
        seen[prune] = P.stream_stats()
    assert seen[True]["slab_bytes"] < seen[False]["slab_bytes"]
    assert seen[True]["waves"] == seen[False]["waves"]
    assert seen[True]["slice_s"] >= 0.0


def test_stats_tables_accepts_host_table():
    """compile_plan(stats_tables=...) histograms a HostTable's numpy
    columns directly: under jit (traced runtime tables) the concrete
    stats size the exchange buckets, same answer as eager."""
    db = _db()
    plan = tpch.q3_plan()
    tabs = db.tables()
    ref = compile_plan(plan)(tabs)
    stats = {k: HostTable.from_table(t) for k, t in tabs.items()}
    fn = compile_plan(plan, stats_tables=stats,
                      join_gather_budget=1)   # force exchanges
    got = jax.jit(fn)(tabs)
    _assert_biteq("stats/host", ref, got)


def test_pad_to_multiple_cached():
    """The chunk-grid pad memo: re-padding to the same grid is free (the
    streamed executor re-pads every compiled() call)."""
    t = Table.from_columns({"a": np.arange(10)})
    p = t.pad_to_multiple(8)
    assert p.capacity == 16
    assert p.pad_to_multiple(8) is p
    ht = HostTable({"a": np.arange(10)})
    hp = ht.pad_to_multiple(8)
    assert hp.capacity == 16 and hp.pad_to_multiple(8) is hp


# -------------------------------------------------------- error surfaces
def test_streamed_build_side_rejected():
    """Only the probe side of a join may stream: a budget that would
    stream a build-side table is a loud NotImplementedError, not a wrong
    answer."""
    db = _db()
    with pytest.raises(NotImplementedError, match="build side"):
        tpch.q20(db, "aggregate", max_groups=64,
                 plan_opts=dict(device_row_budget=64))


def test_streamed_requires_aggregation():
    """A StreamedScan with no aggregation above it cannot execute (the
    wave loop folds per-chunk UDA states, not raw relational output)."""
    db = _db()
    fn = compile_plan(Select(Scan("lineitem"),
                             lambda t: t["l_quantity"] > 0),
                      None, device_row_budget=64)
    with pytest.raises(NotImplementedError,
                       match="grouped aggregation") as ei:
        fn(db.tables())
    # the error names the workaround knobs
    assert "device_row_budget" in str(ei.value)
    assert "to_table" in str(ei.value)


# ------------------------------------------------------------ mesh waves
@pytest.mark.multidevice
@pytest.mark.parametrize("devices", [2, 3])
def test_streamed_mesh_bit_equal(devices):
    """Streamed execution on a real multi-device mesh — including the
    3-shard count that does not divide the 8-chunk grid — is bit-equal to
    the single-device RESIDENT compile, across query shapes (plain agg,
    join spine, scalar agg, reweight, plan suffix above the streamed
    pass) and a 1-chunk wave schedule."""
    from conftest import run_sub
    out = run_sub("""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db import tpch

mesh = make_mesh((%(devices)d,), ("data",))
db = tpch.generate(n_orders=48, lines_per_order=4, n_parts=24,
                   n_suppliers=8, n_customers=24, seed=0)

def biteq(name, ref, got):
    la, ta = jax.tree.flatten(ref)
    lb, tb = jax.tree.flatten(got)
    assert str(ta) == str(tb), name
    for a, b in zip(la, lb):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.dtype == b.dtype, name
        f = a.astype(np.float64, copy=False)
        g = b.astype(np.float64, copy=False)
        assert ((a == b) | (np.isnan(f) & np.isnan(g))).all(), name

opts = dict(device_row_budget=128)
biteq("q1", tpch.q1(db, "aggregate"),
      tpch.q1(db, "aggregate", mesh=mesh, plan_opts=opts))
biteq("q3", tpch.q3(db, "aggregate", max_groups=64),
      tpch.q3(db, "aggregate", max_groups=64, mesh=mesh, plan_opts=opts))
biteq("q6", tpch.q6(db, "aggregate", num_freq=256),
      tpch.q6(db, "aggregate", num_freq=256, mesh=mesh, plan_opts=opts))
biteq("q18", tpch.q18(db, "aggregate", max_groups=64),
      tpch.q18(db, "aggregate", max_groups=64, mesh=mesh, plan_opts=opts))
biteq("q20", tpch.q20(db, "aggregate", max_groups=64),
      tpch.q20(db, "aggregate", max_groups=64, mesh=mesh, plan_opts=opts))
biteq("q1_wc1", tpch.q1(db, "aggregate"),
      tpch.q1(db, "aggregate", mesh=mesh,
              plan_opts=dict(device_row_budget=128, stream_wave_chunks=1)))

# disk-backed (save -> open, mmap columns) lineitem on the mesh, with
# and without column pruning
import tempfile
from repro.db.plans import compile_plan
from repro.db.table import HostTable
tabs = db.tables()
ref = compile_plan(tpch.q1_plan(), mesh)(tabs)
with tempfile.TemporaryDirectory() as d:
    HostTable.from_table(tabs["lineitem"]).save(d)
    disk = dict(tabs)
    disk["lineitem"] = HostTable.open(d)
    for prune in (True, False):
        got = compile_plan(tpch.q1_plan(), mesh, device_row_budget=128,
                           stream_prune_columns=prune)(disk)
        biteq("q1_disk_prune=%%s" %% prune, ref, got)
print("STREAM BITEQ OK")
""" % dict(devices=devices), devices=devices)
    assert "STREAM BITEQ OK" in out
