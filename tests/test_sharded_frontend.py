"""The sharded relational frontend's protocol pieces vs brute force.

Four layers:
  * the distributed group-id protocol (local unique -> merge of per-shard
    code tables -> searchsorted) is pure integer math, so it is fuzzed
    in-process against the single-pass `jnp.unique` oracle — under
    `hypothesis` when installed, and always via seeded fallbacks (the
    test_pgf.py pattern);
  * fk_join contract enforcement (duplicate build keys, nonnegative group
    keys) and possible-worlds parity, single-device;
  * the shuffle-partitioned join protocol (operators.bucket_slots /
    scatter_to_buckets / take_from_buckets + the per-owner fk_join), also
    pure math once the all_to_all is emulated host-side: fuzzed against
    the global fk_join oracle and the possible-worlds enumeration,
    duplicate-key rejection and bucket-overflow accounting included;
  * subprocess tests on real 2- and 3-device meshes: sharded fk_join
    possible-worlds parity, gather- and shuffle-strategy bit-equality,
    and the overflow NaN poisoning.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.db import operators as ops
from repro.db.plans import FKJoin, GroupAgg, Scan, compile_plan
from repro.db.table import Table


# ------------------------------------------------ group-id protocol fuzz
def _check_group_ids_protocol(keys, valid, max_groups, n_shards):
    """Sharded two-phase group ids == single-pass oracle, bit for bit."""
    keys = np.asarray(keys, np.int64)
    valid = np.asarray(valid, bool)
    t = Table.from_columns({"k": jnp.asarray(keys)}, valid=jnp.asarray(valid))
    ids_ref, codes_ref, gv_ref = ops.group_ids(t, ["k"], max_groups)

    code_live, big = ops.live_key_codes(t, ["k"])
    n = keys.shape[0]
    per = -(-n // n_shards)
    cl = jnp.pad(code_live, (0, per * n_shards - n), constant_values=big)
    local = [ops.merge_group_codes(cl[s * per:(s + 1) * per], max_groups)
             for s in range(n_shards)]
    merged = ops.merge_group_codes(jnp.concatenate(local), max_groups)
    ids = ops.codes_to_ids(code_live, merged)

    np.testing.assert_array_equal(np.asarray(merged), np.asarray(codes_ref))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_ref))
    np.testing.assert_array_equal(np.asarray(merged != big),
                                  np.asarray(gv_ref))


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("n_shards", [2, 3, 4, 8])
def test_group_ids_protocol_seeded(seed, n_shards):
    """Duplicates, invalid rows, and near/over-capacity cardinality: the
    merge of per-shard code tables is exact even when shards drop codes
    (operators.merge_group_codes), so overflow clipping matches too."""
    r = np.random.default_rng(seed)
    n = int(r.integers(4, 65))
    max_groups = int(r.integers(2, 17))
    # key range around max_groups drives near- and over-capacity cases
    keys = r.integers(0, max(1, int(max_groups * r.uniform(0.5, 2.0))), n)
    valid = r.uniform(0, 1, n) > 0.3
    _check_group_ids_protocol(keys, valid, max_groups, n_shards)


def test_group_ids_protocol_edge_cases():
    # all rows invalid; single live key; exactly max_groups distinct keys
    _check_group_ids_protocol([3, 1, 4], [False, False, False], 4, 2)
    _check_group_ids_protocol([7] * 6, [True] * 6, 4, 3)
    _check_group_ids_protocol(np.arange(8), [True] * 8, 8, 4)


def test_group_ids_protocol_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.integers(0, 24), min_size=1, max_size=48),
           st.data(), st.integers(2, 16), st.sampled_from([2, 3, 4, 8]))
    def run(keys, data, max_groups, n_shards):
        valid = data.draw(st.lists(st.booleans(), min_size=len(keys),
                                   max_size=len(keys)))
        _check_group_ids_protocol(keys, valid, max_groups, n_shards)

    run()


# ------------------------------------------- nonnegative-key enforcement
def test_group_ids_rejects_negative_keys():
    t = Table.from_columns({"k": jnp.asarray([1, -2, 3])})
    with pytest.raises(ValueError, match="negative"):
        ops.group_ids(t, ["k"], 4)


def test_group_key_columns_rejects_negative_keys():
    t = Table.from_columns({"k": jnp.asarray([0, 1, 2]),
                            "c": jnp.asarray([5, -1, 7])})
    ids, _, _ = ops.group_ids(t, ["k"], 4)
    with pytest.raises(ValueError, match="negative"):
        ops.group_key_columns(t, ["c"], ids, 4)


def test_negative_key_on_invalid_row_is_fine():
    """Dead rows never write representatives — only valid rows are
    checked (the identity-0 write is exactly what the mask is for)."""
    t = Table.from_columns({"k": jnp.asarray([1, -2, 3])},
                           valid=jnp.asarray([True, False, True]))
    ids, codes, gvalid = ops.group_ids(t, ["k"], 4)
    assert int(np.asarray(gvalid).sum()) == 2


def test_compile_plan_surfaces_negative_key_error():
    t = Table.from_columns({"g": jnp.asarray([0, -1, 2]),
                            "v": jnp.asarray([1, 1, 1])})
    plan = GroupAgg(Scan("t"), ("g",), "v", "SUM", 4)
    with pytest.raises(ValueError, match="negative"):
        compile_plan(plan)({"t": t})


def test_compile_plan_accepts_any_chunk_grid():
    """Non-power-of-two canonical chunk grids are legal now (the pow2-base
    + sequential-tail tree of uda.tree_fold covers any chunk count); only
    non-positive grids are rejected."""
    t = Table.from_columns({"g": jnp.asarray([0, 1, 0, 1, 0]),
                            "v": jnp.asarray([1, 2, 3, 4, 5])},
                           prob=jnp.asarray([.5, .4, .3, .2, .1]))
    plan = GroupAgg(Scan("t"), ("g",), "v", "SUM", 4)
    mu8, _ = compile_plan(plan, canonical_chunks=8)({"t": t})["sum"]
    mu6, _ = compile_plan(plan, canonical_chunks=6)({"t": t})["sum"]
    np.testing.assert_allclose(np.asarray(mu8), np.asarray(mu6), rtol=1e-12)
    with pytest.raises(ValueError, match="positive"):
        compile_plan(plan, canonical_chunks=0)


# ---------------------------------------------------- fk_join semantics
def test_fk_join_rejects_duplicate_valid_build_keys():
    left = Table.from_columns({"k": jnp.asarray([0, 1])})
    right = Table.from_columns({"k": jnp.asarray([1, 1, 2]),
                                "pay": jnp.asarray([10, 11, 12])})
    with pytest.raises(ValueError, match="duplicate valid keys"):
        ops.fk_join(left, right, "k", "k", ["pay"])
    # the same key duplicated on an INVALID row is fine
    right2 = right.with_valid(jnp.asarray([True, False, True]))
    out = ops.fk_join(left, right2, "k", "k", ["pay"])
    assert int(out["pay"][1]) == 10


def _worlds_fk_join_marginals(left, right, lk, rk):
    """Brute-force P(output row present) per left row: enumerate presence
    worlds of both relations; a row survives iff its tuple and its unique
    valid key match are both present."""
    lp = np.asarray(left.prob)
    rp = np.asarray(right.prob)
    lv = np.asarray(left.valid)
    rv = np.asarray(right.valid)
    lkv = np.asarray(left[lk])
    rkv = np.asarray(right[rk])
    nl, nr = lp.size, rp.size
    marg = np.zeros(nl)
    for wl in range(1 << nl):
        pl_w = np.prod([lp[i] if wl >> i & 1 else 1 - lp[i]
                        for i in range(nl)])
        for wr in range(1 << nr):
            pw = pl_w * np.prod([rp[j] if wr >> j & 1 else 1 - rp[j]
                                 for j in range(nr)])
            for i in range(nl):
                if not (lv[i] and wl >> i & 1):
                    continue
                match = [j for j in range(nr)
                         if rv[j] and (wr >> j & 1) and rkv[j] == lkv[i]]
                if match:
                    marg[i] += pw
    return marg


def _tiny_join_tables(rng):
    # left keys include 3 (missing from the valid build side) and an
    # invalid left row; right carries a probability column via `pay`.
    left = Table.from_columns(
        {"k": jnp.asarray([0, 1, 2, 3, 1, 0]),
         "lv": jnp.asarray([5, 6, 7, 8, 9, 4])},
        prob=jnp.asarray(rng.uniform(0.1, 0.9, 6)),
        valid=jnp.asarray([True, True, True, True, False, True]))
    right = Table.from_columns(
        {"k": jnp.asarray([0, 1, 2, 3]),
         "pay": jnp.asarray([10, 11, 12, 13])},
        prob=jnp.asarray(rng.uniform(0.1, 0.9, 4)),
        valid=jnp.asarray([True, True, True, False]))  # key 3 dead
    return left, right


def test_fk_join_possible_worlds_parity(rng):
    left, right = _tiny_join_tables(rng)
    out = ops.fk_join(left, right, "k", "k", ["pay"])
    marg = _worlds_fk_join_marginals(left, right, "k", "k")
    got = np.where(np.asarray(out.valid), np.asarray(out.prob), 0.0)
    np.testing.assert_allclose(got, marg, atol=1e-12)
    # carried columns come from the unique match
    for i in np.flatnonzero(np.asarray(out.valid)):
        assert int(out["pay"][i]) == 10 + int(out["k"][i])


# ------------------------------------------------- sharded-path parity
@pytest.mark.multidevice
def test_fk_join_sharded_worlds_parity(mesh_equiv):
    """FKJoin through the sharded frontend: bit-equal to the single-device
    compile, possible-worlds parity for the carried probabilities, and the
    same answers when a tiny join_gather_budget lowers the join to the
    shuffle-partitioned strategy (NO replicated fallback exists anymore —
    asserted against the physical plan)."""
    mesh_equiv("""
import numpy as np
from repro.db import physical as phys
rng = np.random.default_rng(7)
left = Table.from_columns(
    {"k": jnp.asarray([0, 1, 2, 3, 1, 0, 2, 1]),
     "lv": jnp.asarray([5, 6, 7, 8, 9, 4, 3, 2])},
    prob=jnp.asarray(rng.uniform(0.1, 0.9, 8)),
    valid=jnp.asarray([True, True, True, True, False, True, True, True]))
right = Table.from_columns(
    {"k": jnp.asarray([0, 1, 2, 3]),
     "pay": jnp.asarray([10, 11, 12, 13])},
    prob=jnp.asarray(rng.uniform(0.1, 0.9, 4)),
    valid=jnp.asarray([True, True, True, False]))
tables = {"L": left, "R": right}
plan = FKJoin(Scan("L"), Scan("R"), "k", "k", ("pay",))
proot = phys.lower_plan(plan, {"L": 8, "R": 8}, n_shards=__DEVICES__,
                        sharded=True, join_gather_budget=1)
assert isinstance(proot, phys.ShuffleJoin), phys.explain(proot)
ref = compile_plan(plan, None)(tables)
got = compile_plan(plan, mesh)(tables)
shuf = compile_plan(plan, mesh, join_gather_budget=1)(tables)
pairs = [("gathered", ref, got), ("shuffled", ref, shuf)]

# possible-worlds parity of the sharded output (padded rows are invalid)
lp, rp = np.asarray(left.prob), np.asarray(right.prob)
lv, rv = np.asarray(left.valid), np.asarray(right.valid)
lk, rk = np.asarray(left["k"]), np.asarray(right["k"])
marg = np.zeros(lp.size)
for wl in range(1 << lp.size):
    plw = np.prod([lp[i] if wl >> i & 1 else 1 - lp[i]
                   for i in range(lp.size)])
    for wr in range(1 << rp.size):
        pw = plw * np.prod([rp[j] if wr >> j & 1 else 1 - rp[j]
                            for j in range(rp.size)])
        for i in range(lp.size):
            if lv[i] and wl >> i & 1 and any(
                    rv[j] and wr >> j & 1 and rk[j] == lk[i]
                    for j in range(rp.size)):
                marg[i] += pw
p_out = np.where(np.asarray(got.valid), np.asarray(got.prob), 0.0)
assert p_out.shape[0] >= lp.size and not p_out[lp.size:].any()
np.testing.assert_allclose(p_out[:lp.size], marg, atol=1e-12)
for i in np.flatnonzero(np.asarray(got.valid)):
    assert int(got["pay"][i]) == 10 + int(got["k"][i])
""")


@pytest.mark.multidevice
def test_group_ids_sharded_on_mesh(mesh_equiv):
    """The real shard_map path of db.distributed.group_ids_sharded against
    the single-device oracle, including near-capacity cardinality."""
    mesh_equiv("""
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.db import distributed as dist
from repro.db import operators as ops
rng = np.random.default_rng(11)
n, MG = 64, 16
t = Table.from_columns(
    {"k": jnp.asarray(rng.integers(0, 24, n))},
    valid=jnp.asarray(rng.uniform(0, 1, n) > 0.3))
ids_ref, codes_ref, gv_ref = ops.group_ids(t, ["k"], MG)

def f(tt):
    ids, codes, gv = dist.group_ids_sharded(tt, ["k"], MG, ("data",))
    return jax.lax.all_gather(ids, "data", axis=0, tiled=True), codes, gv

ids, codes, gv = shard_map(f, mesh=mesh, in_specs=(P("data"),),
                           out_specs=P(), check_vma=False)(t)
pairs = [("group_ids", (ids_ref, codes_ref, gv_ref), (ids, codes, gv))]
""")


# ------------------------------------------- shuffle-exchange protocol
def _emulated_shuffle_fk_join(left, right, lk, rk, right_cols, n_shards,
                              probe_cap, build_cap):
    """Host-side emulation of dist.shuffle_fk_join: same per-shard bucket
    math and per-owner fk_join, with the two all_to_alls replaced by a
    numpy transpose of the (sender, owner) bucket grid.  Returns the
    reassembled global output Table pieces + the total overflow count."""
    nl, nr = left.capacity, right.capacity
    assert nl % n_shards == 0 and nr % n_shards == 0
    bl, br = nl // n_shards, nr // n_shards

    def shard(t, s, n):
        sl = slice(s * n, (s + 1) * n)
        return Table({k: v[sl] for k, v in t.columns.items()},
                     t.prob[sl], t.valid[sl])

    # per-shard send buckets (build side and probe requests)
    bsend, bmask, psend, pmask, slots, sents = [], [], [], [], [], []
    overflow = 0
    for s in range(n_shards):
        rt = shard(right, s, br)
        key = rt[rk].astype(jnp.int32)
        slot, sent, over = ops.bucket_slots(key % n_shards, rt.valid,
                                            n_shards, build_cap)
        overflow += int(over)
        cols = {"_key": key, "_prob": rt.prob,
                **{c: rt[c] for c in right_cols}}
        bsend.append(ops.scatter_to_buckets(cols, slot,
                                            n_shards * build_cap))
        bmask.append(np.asarray(jnp.zeros((n_shards * build_cap,), bool)
                                .at[slot].set(sent, mode="drop")))
        lt = shard(left, s, bl)
        lkey = lt[lk].astype(jnp.int32)
        slot, sent, over = ops.bucket_slots(lkey % n_shards, lt.valid,
                                            n_shards, probe_cap)
        overflow += int(over)
        psend.append(ops.scatter_to_buckets({"_key": lkey}, slot,
                                            n_shards * probe_cap))
        pmask.append(np.asarray(jnp.zeros((n_shards * probe_cap,), bool)
                                .at[slot].set(sent, mode="drop")))
        slots.append(slot)
        sents.append(sent)

    def transpose(bufs, cap):   # the all_to_all: out_d[s] = in_s[d]
        return [{k: np.concatenate([np.asarray(b[k]).reshape(
            n_shards, cap, -1)[d, :, 0] if np.asarray(b[k]).ndim == 1
            else np.asarray(b[k])[d * cap:(d + 1) * cap]
            for b in bufs]) for k in bufs[0]} for d in range(n_shards)]

    brecv = transpose(bsend, build_cap)
    bmrecv = [np.concatenate([m.reshape(n_shards, build_cap)[d]
                              for m in bmask]) for d in range(n_shards)]
    precv = transpose(psend, probe_cap)
    pmrecv = [np.concatenate([m.reshape(n_shards, probe_cap)[d]
                              for m in pmask]) for d in range(n_shards)]

    # per-owner local match, responses transposed home
    resp = []
    for d in range(n_shards):
        build = Table({rk: jnp.asarray(brecv[d]["_key"]),
                       **{c: jnp.asarray(brecv[d][c]) for c in right_cols}},
                      jnp.asarray(brecv[d]["_prob"]),
                      jnp.asarray(bmrecv[d]))
        req = Table({lk: jnp.asarray(precv[d]["_key"])},
                    jnp.ones((n_shards * probe_cap,), left.prob.dtype),
                    jnp.asarray(pmrecv[d]))
        m = ops.fk_join(req, build, lk, rk, right_cols)
        resp.append({"_p": m.prob, "_hit": m.valid,
                     **{c: m[c] for c in right_cols}})
    back = transpose(resp, probe_cap)

    # per-origin reassembly into the original row positions
    probs, valids, cols_out = [], [], {c: [] for c in right_cols}
    for s in range(n_shards):
        got = ops.take_from_buckets(
            {k: jnp.asarray(v) for k, v in back[s].items()},
            slots[s], sents[s])
        lt = shard(left, s, bl)
        probs.append(np.asarray(lt.prob * got["_p"]))
        valids.append(np.asarray(lt.valid & got["_hit"]))
        for c in right_cols:
            cols_out[c].append(np.asarray(got[c]))
    return (np.concatenate(probs), np.concatenate(valids),
            {c: np.concatenate(v) for c, v in cols_out.items()}, overflow)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("n_shards", [2, 3, 4])
def test_shuffle_join_protocol_matches_fk_join_oracle(seed, n_shards):
    """The emulated shuffle protocol == the global fk_join, bit for bit
    (probabilities, validity, carried columns including the deterministic
    zero-fill of miss rows), at full bucket capacity (no overflow)."""
    r = np.random.default_rng(seed)
    nl, nr = 6 * n_shards, 2 * n_shards
    left = Table.from_columns(
        {"k": jnp.asarray(r.integers(0, nr + 2, nl)),
         "lv": jnp.asarray(r.integers(0, 50, nl))},
        prob=jnp.asarray(r.uniform(0.05, 0.95, nl)),
        valid=jnp.asarray(r.uniform(0, 1, nl) > 0.2))
    right = Table.from_columns(
        {"k": jnp.asarray(np.arange(nr)),
         "pay": jnp.asarray(r.integers(10, 99, nr))},
        prob=jnp.asarray(r.uniform(0.05, 0.95, nr)),
        valid=jnp.asarray(r.uniform(0, 1, nr) > 0.2))
    ref = ops.fk_join(left, right, "k", "k", ["pay"])
    prob, valid, cols, overflow = _emulated_shuffle_fk_join(
        left, right, "k", "k", ["pay"], n_shards,
        probe_cap=nl // n_shards, build_cap=nr // n_shards)
    assert overflow == 0
    np.testing.assert_array_equal(prob, np.asarray(ref.prob))
    np.testing.assert_array_equal(valid, np.asarray(ref.valid))
    np.testing.assert_array_equal(cols["pay"], np.asarray(ref["pay"]))


def test_shuffle_join_protocol_possible_worlds_parity(rng):
    """End-to-end semantics of the shuffled join against the 2^n worlds
    enumeration (not just against fk_join)."""
    left, right = _tiny_join_tables(rng)
    left = left.pad_to(6)
    right = right.pad_to(6)
    marg = _worlds_fk_join_marginals(left, right, "k", "k")
    prob, valid, _, overflow = _emulated_shuffle_fk_join(
        left, right, "k", "k", ["pay"], 3, probe_cap=2, build_cap=2)
    assert overflow == 0
    np.testing.assert_allclose(np.where(valid, prob, 0.0), marg, atol=1e-12)


def test_shuffle_join_protocol_rejects_duplicate_build_keys():
    """Duplicate valid build keys land on the same hash owner, where the
    local fk_join's many-to-one contract check rejects them (concrete
    data, as in eager execution)."""
    left = Table.from_columns({"k": jnp.asarray([0, 1, 2, 3])})
    right = Table.from_columns({"k": jnp.asarray([1, 3, 3, 2]),
                                "pay": jnp.asarray([10, 11, 12, 13])})
    with pytest.raises(ValueError, match="duplicate valid keys"):
        _emulated_shuffle_fk_join(left, right, "k", "k", ["pay"], 2,
                                  probe_cap=2, build_cap=2)


def test_bucket_slots_overflow_accounting():
    """Rows beyond a bucket's capacity are dropped but counted; in-range
    ranks are dense per destination."""
    dest = jnp.asarray([0, 0, 0, 1, 0, 1])
    ok = jnp.asarray([True, True, True, True, True, False])
    slot, sent, over = ops.bucket_slots(dest, ok, 2, 2)
    assert int(over) == 2                      # 4 ok-rows to bucket 0, cap 2
    np.testing.assert_array_equal(np.asarray(sent),
                                  [True, True, False, True, False, False])
    np.testing.assert_array_equal(np.asarray(slot)[np.asarray(sent)],
                                  [0, 1, 2])   # dest*cap + rank
    # dropped and not-ok rows park out of range (scatter mode="drop")
    assert (np.asarray(slot)[~np.asarray(sent)] == 4).all()


@pytest.mark.parametrize("seed", range(6))
def test_concrete_bucket_capacity_covers_every_demand(seed):
    """Fuzz the skew-adaptive sizing: the histogram capacity equals the
    worst (sender, owner) demand, so bucket_slots never overflows at that
    capacity — for any key distribution."""
    from repro.db import physical as phys

    r = np.random.default_rng(seed)
    shards = int(r.integers(2, 5))
    local = int(r.integers(1, 9))
    n = shards * local
    skew = int(r.integers(1, 4 * shards))
    keys = r.integers(0, skew, n)
    valid = r.uniform(0, 1, n) > 0.25
    t = Table.from_columns({"k": jnp.asarray(keys)},
                           valid=jnp.asarray(valid))
    cap = phys.concrete_bucket_capacity(t, "k", shards)
    want = 1
    for s in range(shards):
        d = (keys[s * local:(s + 1) * local]
             [valid[s * local:(s + 1) * local]]) % shards
        if d.size:
            want = max(want, int(np.bincount(d, minlength=shards).max()))
        _, _, over = ops.bucket_slots(
            jnp.asarray(keys[s * local:(s + 1) * local] % shards),
            jnp.asarray(valid[s * local:(s + 1) * local]), shards, cap)
        assert int(over) == 0
    assert cap == want


@pytest.mark.parametrize("seed", range(6))
def test_bucket_slots_roundtrip_fuzz(seed):
    """scatter_to_buckets o take_from_buckets is the identity on sent rows
    (the response-routing invariant of the shuffle join)."""
    r = np.random.default_rng(seed)
    n, shards = int(r.integers(4, 40)), int(r.integers(2, 5))
    cap = int(r.integers(1, 6))
    dest = jnp.asarray(r.integers(0, shards, n))
    ok = jnp.asarray(r.uniform(0, 1, n) > 0.25)
    payload = jnp.asarray(r.integers(0, 1000, n))
    slot, sent, over = ops.bucket_slots(dest, ok, shards, cap)
    assert int(jnp.sum(sent)) + int(over) == int(jnp.sum(ok))
    bufs = ops.scatter_to_buckets({"x": payload}, slot, shards * cap)
    got = ops.take_from_buckets(bufs, slot, sent)["x"]
    np.testing.assert_array_equal(np.asarray(got)[np.asarray(sent)],
                                  np.asarray(payload)[np.asarray(sent)])
    assert (np.asarray(got)[~np.asarray(sent)] == 0).all()


@pytest.mark.multidevice
def test_shuffle_join_3shard_mesh_skew_and_overflow_poisoning():
    """On a real 3-device mesh with every key hashing to owner 0: the
    shuffle-lowered plan is bit-equal to mesh=None.  Eager compiles see
    the concrete keys and size buckets from the real histogram, so even
    slack 1.0 cannot overflow (the skew-adaptive capacities); under jit
    the keys are traced, the slack sizing comes back, and overflowing
    buckets poison the join probabilities with NaN (accounted, never
    silently wrong)."""
    from conftest import run_sub
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db.plans import FKJoin, Scan, compile_plan
from repro.db.table import Table
mesh = make_mesh((3,), ("data",))
rng = np.random.default_rng(5)
# skewed: every left key hits owner 0 (key % 3 == 0)
left = Table.from_columns(
    {"k": jnp.asarray([0, 3, 6, 9, 0, 3, 6, 9, 0, 3, 6, 9])},
    prob=jnp.asarray(rng.uniform(0.1, 0.9, 12)))
right = Table.from_columns(
    {"k": jnp.asarray([0, 3, 6, 9, 12, 15]),
     "pay": jnp.asarray([10, 11, 12, 13, 14, 15])},
    prob=jnp.asarray(rng.uniform(0.1, 0.9, 6)))
tables = {"L": left, "R": right}
plan = FKJoin(Scan("L"), Scan("R"), "k", "k", ("pay",))
ref = compile_plan(plan, None)(tables)
ok = compile_plan(plan, mesh, join_gather_budget=1)(tables)
for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(ok)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
# eager + concrete keys: histogram-sized buckets absorb the skew even at
# slack 1.0 (no overflow, bit-equal)
adaptive = compile_plan(plan, mesh, join_gather_budget=1,
                        shuffle_slack=1.0)(tables)
for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(adaptive)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
# jit: traced keys -> slack 1.0 buckets ceil(local/3) < the skewed
# demand -> overflow NaN-poisons
bad = jax.jit(compile_plan(plan, mesh, join_gather_budget=1,
                           shuffle_slack=1.0))(tables)
assert np.isnan(np.asarray(bad.prob)).all(), np.asarray(bad.prob)
print("OK")
""", devices=3)


@pytest.mark.multidevice
def test_stats_tables_make_jit_buckets_skew_adaptive():
    """The carried traced-key item: ``compile_plan(stats_tables=...)``
    hands the lowering concrete stand-in tables, so the key % n_shards
    histograms size the jit path's buckets OUTSIDE the trace.  The same
    skewed join that NaN-poisons under jit with flat slack 1.0 buckets
    (previous test) is bit-equal to mesh=None when the stats tables
    carry the real key population — and a WRONG histogram still has the
    NaN overflow guard as the backstop."""
    from conftest import run_sub
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db.plans import FKJoin, Scan, compile_plan
from repro.db.table import Table
mesh = make_mesh((3,), ("data",))
rng = np.random.default_rng(5)
left = Table.from_columns(
    {"k": jnp.asarray([0, 3, 6, 9, 0, 3, 6, 9, 0, 3, 6, 9])},
    prob=jnp.asarray(rng.uniform(0.1, 0.9, 12)))
right = Table.from_columns(
    {"k": jnp.asarray([0, 3, 6, 9, 12, 15]),
     "pay": jnp.asarray([10, 11, 12, 13, 14, 15])},
    prob=jnp.asarray(rng.uniform(0.1, 0.9, 6)))
tables = {"L": left, "R": right}
plan = FKJoin(Scan("L"), Scan("R"), "k", "k", ("pay",))
ref = compile_plan(plan, None)(tables)
# jit + stats tables: the traced compile sizes buckets from the concrete
# stand-ins' histograms -> the skew fits even at slack 1.0, bit-equal
good = jax.jit(compile_plan(plan, mesh, join_gather_budget=1,
                            shuffle_slack=1.0,
                            stats_tables=tables))(tables)
for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(good)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
# unrepresentative stats (uniform keys) undersize owner 0's bucket: the
# overflow guard still NaN-poisons instead of dropping rows silently
fake = {"L": Table.from_columns(
            {"k": jnp.asarray([0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11])},
            prob=left.prob),
        "R": right}
bad = jax.jit(compile_plan(plan, mesh, join_gather_budget=1,
                           shuffle_slack=1.0,
                           stats_tables=fake))(tables)
assert np.isnan(np.asarray(bad.prob)).any(), np.asarray(bad.prob)
print("STATS OK")
""", devices=3)
