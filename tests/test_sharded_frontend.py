"""The sharded relational frontend's protocol pieces vs brute force.

Three layers:
  * the distributed group-id protocol (local unique -> merge of per-shard
    code tables -> searchsorted) is pure integer math, so it is fuzzed
    in-process against the single-pass `jnp.unique` oracle — under
    `hypothesis` when installed, and always via seeded fallbacks (the
    test_pgf.py pattern);
  * fk_join contract enforcement (duplicate build keys, nonnegative group
    keys) and possible-worlds parity, single-device;
  * subprocess tests on a real 2-device mesh: sharded fk_join
    possible-worlds parity and the replicated build-side budget fallback.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.db import operators as ops
from repro.db.plans import FKJoin, GroupAgg, Scan, compile_plan
from repro.db.table import Table


# ------------------------------------------------ group-id protocol fuzz
def _check_group_ids_protocol(keys, valid, max_groups, n_shards):
    """Sharded two-phase group ids == single-pass oracle, bit for bit."""
    keys = np.asarray(keys, np.int64)
    valid = np.asarray(valid, bool)
    t = Table.from_columns({"k": jnp.asarray(keys)}, valid=jnp.asarray(valid))
    ids_ref, codes_ref, gv_ref = ops.group_ids(t, ["k"], max_groups)

    code_live, big = ops.live_key_codes(t, ["k"])
    n = keys.shape[0]
    per = -(-n // n_shards)
    cl = jnp.pad(code_live, (0, per * n_shards - n), constant_values=big)
    local = [ops.merge_group_codes(cl[s * per:(s + 1) * per], max_groups)
             for s in range(n_shards)]
    merged = ops.merge_group_codes(jnp.concatenate(local), max_groups)
    ids = ops.codes_to_ids(code_live, merged)

    np.testing.assert_array_equal(np.asarray(merged), np.asarray(codes_ref))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_ref))
    np.testing.assert_array_equal(np.asarray(merged != big),
                                  np.asarray(gv_ref))


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("n_shards", [2, 3, 4, 8])
def test_group_ids_protocol_seeded(seed, n_shards):
    """Duplicates, invalid rows, and near/over-capacity cardinality: the
    merge of per-shard code tables is exact even when shards drop codes
    (operators.merge_group_codes), so overflow clipping matches too."""
    r = np.random.default_rng(seed)
    n = int(r.integers(4, 65))
    max_groups = int(r.integers(2, 17))
    # key range around max_groups drives near- and over-capacity cases
    keys = r.integers(0, max(1, int(max_groups * r.uniform(0.5, 2.0))), n)
    valid = r.uniform(0, 1, n) > 0.3
    _check_group_ids_protocol(keys, valid, max_groups, n_shards)


def test_group_ids_protocol_edge_cases():
    # all rows invalid; single live key; exactly max_groups distinct keys
    _check_group_ids_protocol([3, 1, 4], [False, False, False], 4, 2)
    _check_group_ids_protocol([7] * 6, [True] * 6, 4, 3)
    _check_group_ids_protocol(np.arange(8), [True] * 8, 8, 4)


def test_group_ids_protocol_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.integers(0, 24), min_size=1, max_size=48),
           st.data(), st.integers(2, 16), st.sampled_from([2, 3, 4, 8]))
    def run(keys, data, max_groups, n_shards):
        valid = data.draw(st.lists(st.booleans(), min_size=len(keys),
                                   max_size=len(keys)))
        _check_group_ids_protocol(keys, valid, max_groups, n_shards)

    run()


# ------------------------------------------- nonnegative-key enforcement
def test_group_ids_rejects_negative_keys():
    t = Table.from_columns({"k": jnp.asarray([1, -2, 3])})
    with pytest.raises(ValueError, match="negative"):
        ops.group_ids(t, ["k"], 4)


def test_group_key_columns_rejects_negative_keys():
    t = Table.from_columns({"k": jnp.asarray([0, 1, 2]),
                            "c": jnp.asarray([5, -1, 7])})
    ids, _, _ = ops.group_ids(t, ["k"], 4)
    with pytest.raises(ValueError, match="negative"):
        ops.group_key_columns(t, ["c"], ids, 4)


def test_negative_key_on_invalid_row_is_fine():
    """Dead rows never write representatives — only valid rows are
    checked (the identity-0 write is exactly what the mask is for)."""
    t = Table.from_columns({"k": jnp.asarray([1, -2, 3])},
                           valid=jnp.asarray([True, False, True]))
    ids, codes, gvalid = ops.group_ids(t, ["k"], 4)
    assert int(np.asarray(gvalid).sum()) == 2


def test_compile_plan_surfaces_negative_key_error():
    t = Table.from_columns({"g": jnp.asarray([0, -1, 2]),
                            "v": jnp.asarray([1, 1, 1])})
    plan = GroupAgg(Scan("t"), ("g",), "v", "SUM", 4)
    with pytest.raises(ValueError, match="negative"):
        compile_plan(plan)({"t": t})


def test_compile_plan_rejects_bad_chunk_grids():
    t = Table.from_columns({"g": jnp.asarray([0, 1]),
                            "v": jnp.asarray([1, 1])})
    plan = GroupAgg(Scan("t"), ("g",), "v", "SUM", 4)
    with pytest.raises(ValueError, match="power of two"):
        compile_plan(plan, canonical_chunks=6)


# ---------------------------------------------------- fk_join semantics
def test_fk_join_rejects_duplicate_valid_build_keys():
    left = Table.from_columns({"k": jnp.asarray([0, 1])})
    right = Table.from_columns({"k": jnp.asarray([1, 1, 2]),
                                "pay": jnp.asarray([10, 11, 12])})
    with pytest.raises(ValueError, match="duplicate valid keys"):
        ops.fk_join(left, right, "k", "k", ["pay"])
    # the same key duplicated on an INVALID row is fine
    right2 = right.with_valid(jnp.asarray([True, False, True]))
    out = ops.fk_join(left, right2, "k", "k", ["pay"])
    assert int(out["pay"][1]) == 10


def _worlds_fk_join_marginals(left, right, lk, rk):
    """Brute-force P(output row present) per left row: enumerate presence
    worlds of both relations; a row survives iff its tuple and its unique
    valid key match are both present."""
    lp = np.asarray(left.prob)
    rp = np.asarray(right.prob)
    lv = np.asarray(left.valid)
    rv = np.asarray(right.valid)
    lkv = np.asarray(left[lk])
    rkv = np.asarray(right[rk])
    nl, nr = lp.size, rp.size
    marg = np.zeros(nl)
    for wl in range(1 << nl):
        pl_w = np.prod([lp[i] if wl >> i & 1 else 1 - lp[i]
                        for i in range(nl)])
        for wr in range(1 << nr):
            pw = pl_w * np.prod([rp[j] if wr >> j & 1 else 1 - rp[j]
                                 for j in range(nr)])
            for i in range(nl):
                if not (lv[i] and wl >> i & 1):
                    continue
                match = [j for j in range(nr)
                         if rv[j] and (wr >> j & 1) and rkv[j] == lkv[i]]
                if match:
                    marg[i] += pw
    return marg


def _tiny_join_tables(rng):
    # left keys include 3 (missing from the valid build side) and an
    # invalid left row; right carries a probability column via `pay`.
    left = Table.from_columns(
        {"k": jnp.asarray([0, 1, 2, 3, 1, 0]),
         "lv": jnp.asarray([5, 6, 7, 8, 9, 4])},
        prob=jnp.asarray(rng.uniform(0.1, 0.9, 6)),
        valid=jnp.asarray([True, True, True, True, False, True]))
    right = Table.from_columns(
        {"k": jnp.asarray([0, 1, 2, 3]),
         "pay": jnp.asarray([10, 11, 12, 13])},
        prob=jnp.asarray(rng.uniform(0.1, 0.9, 4)),
        valid=jnp.asarray([True, True, True, False]))  # key 3 dead
    return left, right


def test_fk_join_possible_worlds_parity(rng):
    left, right = _tiny_join_tables(rng)
    out = ops.fk_join(left, right, "k", "k", ["pay"])
    marg = _worlds_fk_join_marginals(left, right, "k", "k")
    got = np.where(np.asarray(out.valid), np.asarray(out.prob), 0.0)
    np.testing.assert_allclose(got, marg, atol=1e-12)
    # carried columns come from the unique match
    for i in np.flatnonzero(np.asarray(out.valid)):
        assert int(out["pay"][i]) == 10 + int(out["k"][i])


# ------------------------------------------------- sharded-path parity
@pytest.mark.multidevice
def test_fk_join_sharded_worlds_parity(mesh_equiv):
    """FKJoin through the sharded frontend: bit-equal to the single-device
    compile, possible-worlds parity for the carried probabilities, and the
    same answers when the build side falls back to replicated under a
    tiny join_gather_budget."""
    mesh_equiv("""
import numpy as np
rng = np.random.default_rng(7)
left = Table.from_columns(
    {"k": jnp.asarray([0, 1, 2, 3, 1, 0, 2, 1]),
     "lv": jnp.asarray([5, 6, 7, 8, 9, 4, 3, 2])},
    prob=jnp.asarray(rng.uniform(0.1, 0.9, 8)),
    valid=jnp.asarray([True, True, True, True, False, True, True, True]))
right = Table.from_columns(
    {"k": jnp.asarray([0, 1, 2, 3]),
     "pay": jnp.asarray([10, 11, 12, 13])},
    prob=jnp.asarray(rng.uniform(0.1, 0.9, 4)),
    valid=jnp.asarray([True, True, True, False]))
tables = {"L": left, "R": right}
plan = FKJoin(Scan("L"), Scan("R"), "k", "k", ("pay",))
ref = compile_plan(plan, None)(tables)
got = compile_plan(plan, mesh)(tables)
repl = compile_plan(plan, mesh, join_gather_budget=1)(tables)
pairs = [("gathered", ref, got), ("replicated-fallback", ref, repl)]

# possible-worlds parity of the sharded output (padded rows are invalid)
lp, rp = np.asarray(left.prob), np.asarray(right.prob)
lv, rv = np.asarray(left.valid), np.asarray(right.valid)
lk, rk = np.asarray(left["k"]), np.asarray(right["k"])
marg = np.zeros(lp.size)
for wl in range(1 << lp.size):
    plw = np.prod([lp[i] if wl >> i & 1 else 1 - lp[i]
                   for i in range(lp.size)])
    for wr in range(1 << rp.size):
        pw = plw * np.prod([rp[j] if wr >> j & 1 else 1 - rp[j]
                            for j in range(rp.size)])
        for i in range(lp.size):
            if lv[i] and wl >> i & 1 and any(
                    rv[j] and wr >> j & 1 and rk[j] == lk[i]
                    for j in range(rp.size)):
                marg[i] += pw
p_out = np.where(np.asarray(got.valid), np.asarray(got.prob), 0.0)
assert p_out.shape[0] >= lp.size and not p_out[lp.size:].any()
np.testing.assert_allclose(p_out[:lp.size], marg, atol=1e-12)
for i in np.flatnonzero(np.asarray(got.valid)):
    assert int(got["pay"][i]) == 10 + int(got["k"][i])
""")


@pytest.mark.multidevice
def test_group_ids_sharded_on_mesh(mesh_equiv):
    """The real shard_map path of db.distributed.group_ids_sharded against
    the single-device oracle, including near-capacity cardinality."""
    mesh_equiv("""
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.db import distributed as dist
from repro.db import operators as ops
rng = np.random.default_rng(11)
n, MG = 64, 16
t = Table.from_columns(
    {"k": jnp.asarray(rng.integers(0, 24, n))},
    valid=jnp.asarray(rng.uniform(0, 1, n) > 0.3))
ids_ref, codes_ref, gv_ref = ops.group_ids(t, ["k"], MG)

def f(tt):
    ids, codes, gv = dist.group_ids_sharded(tt, ["k"], MG, ("data",))
    return jax.lax.all_gather(ids, "data", axis=0, tiled=True), codes, gv

ids, codes, gv = shard_map(f, mesh=mesh, in_specs=(P("data"),),
                           out_specs=P(), check_vma=False)(t)
pairs = [("group_ids", (ids_ref, codes_ref, gv_ref), (ids, codes, gv))]
""")
